"""Golden-trace regression: per-frame cache-decision digests on a fixed
trajectory, committed to ``tests/golden/serve_trace.json``.

The serving stack asserts images only to float32 ulp (XLA reorders FMA
contractions across program variants), so a *silent semantic drift* in
``render_step``/``shade_phase`` — a changed hit decision, a shifted sort
cadence, a different LRU victim — could hide inside the ulp tolerance and
still pass every parity test.  This test pins the INTEGER decision stream
instead, bit-exactly, for both backends:

* ``sorted``  — the per-frame sort cadence (S^2 window schedule);
* ``hits``    — the radiance-cache hit count (the hit MASK is pinned
  transitively: tags pin which groups inserted — the miss set — and the
  age digest pins which entries the LRU touched, i.e. the hit set);
* ``tags`` / ``age`` / ``clock`` — sha256 of the cache's integer state
  after the frame: every insert/evict/touch decision in order.

If this test fails and the change is INTENTIONAL (a new cache policy, a
different sort schedule), regenerate the golden file and commit it with
the explanation::

    PYTHONPATH=src python tests/test_golden_trace.py

If it fails and you didn't mean to change cache behavior: that's the
regression it exists to catch — ``render_step`` or ``shade_phase`` is
making different decisions than it did yesterday.
"""
import hashlib
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.core.pipeline import LuminaConfig, init_viewer_state, render_step
from repro.data.scenes import structured_scene
from repro.data.trajectory import orbit_trajectory

GOLDEN = pathlib.Path(__file__).parent / 'golden' / 'serve_trace.json'
BACKENDS = ('reference', 'pallas')

# the fixed trajectory: must never change, or the golden file is void
SEED, GAUSSIANS, FRAMES, WIDTH = 7, 800, 8, 64
CAPACITY, WINDOW = 128, 3


def _digest(arr) -> str:
    return hashlib.sha256(np.ascontiguousarray(np.asarray(arr))
                          .tobytes()).hexdigest()[:16]


def trace_digests(backend: str) -> list:
    scene = structured_scene(jax.random.PRNGKey(SEED), GAUSSIANS)
    cfg = LuminaConfig(capacity=CAPACITY, window=WINDOW, backend=backend)
    cams = orbit_trajectory(FRAMES, width=WIDTH, height_px=WIDTH)
    state = init_viewer_state(scene, cfg, cams[0])
    step = jax.jit(lambda st, cm: render_step(scene, st, cm, cfg))
    rows = []
    for f, cam in enumerate(cams):
        state, image, stats = step(state, cam)
        n_pix = int(np.prod(np.asarray(image).shape[:2]))
        hit_rate = float(stats.hit_rate)
        hits = round(hit_rate * n_pix)
        # hit_rate is hits / n_pix with a power-of-two n_pix: the count
        # recovers exactly or the stat itself drifted
        assert abs(hits - hit_rate * n_pix) < 1e-3, 'hit_rate not a count'
        cache = state.cache
        rows.append({
            'frame': f,
            'sorted': int(float(stats.sorted_this_frame)),
            'hits': hits,
            'tags': _digest(cache.tags),
            'age': _digest(cache.age),
            'clock': int(np.asarray(cache.clock).max()),
        })
    return rows


@pytest.mark.parametrize('backend', BACKENDS)
def test_cache_decisions_match_golden_trace(backend):
    assert GOLDEN.exists(), (
        f'{GOLDEN} missing — regenerate with: '
        f'PYTHONPATH=src python {__file__}')
    golden = json.loads(GOLDEN.read_text())
    meta = golden['meta']
    assert (meta['seed'], meta['gaussians'], meta['frames'], meta['width'],
            meta['capacity'], meta['window']) == (
        SEED, GAUSSIANS, FRAMES, WIDTH, CAPACITY, WINDOW), (
        'golden file was generated for a different fixed trajectory')
    got = trace_digests(backend)
    want = golden[backend]
    for g, w in zip(got, want):
        assert g == w, (
            f'{backend} frame {g["frame"]}: cache decisions drifted.\n'
            f'  got  {g}\n  want {w}\n'
            f'(intentional? regenerate: PYTHONPATH=src python {__file__})')
    assert len(got) == len(want)


def test_backends_agree_on_decision_stream():
    """Both backends must make the SAME integer decisions (images may
    differ by ulps; decisions may not) — asserted via the committed file so
    a drifting backend is flagged even when its own column was regenerated.
    """
    golden = json.loads(GOLDEN.read_text())
    assert golden['reference'] == golden['pallas']


def _regenerate():
    payload = {'meta': {'seed': SEED, 'gaussians': GAUSSIANS,
                        'frames': FRAMES, 'width': WIDTH,
                        'capacity': CAPACITY, 'window': WINDOW}}
    for backend in BACKENDS:
        payload[backend] = trace_digests(backend)
        print(f'{backend}: {len(payload[backend])} frames')
    GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN.write_text(json.dumps(payload, indent=1) + '\n')
    print(f'wrote {GOLDEN}')


if __name__ == '__main__':
    _regenerate()