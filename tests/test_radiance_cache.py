"""Functional radiance cache: exactness, LRU, and hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import radiance_cache as rc

CFG = rc.CacheConfig(n_sets=16, n_ways=2, k=3)


def _ids(*rows):
    return jnp.asarray(rows, jnp.int32)


def _rgb(n, base=0.1):
    return jnp.asarray([[base + i, base + i, base + i] for i in range(n)],
                       jnp.float32)


def test_insert_then_lookup_hits():
    cache = rc.init_cache(1, CFG)
    ids = _ids([1, 2, 3], [4, 5, 6])
    rgb = _rgb(2)
    cache = rc.insert(cache, 0, ids, rgb, jnp.asarray([True, True]), CFG)
    hit, val, _, _, _ = rc.lookup(cache, 0, ids, CFG)
    assert bool(hit.all())
    np.testing.assert_allclose(np.asarray(val), np.asarray(rgb))


def test_miss_on_unknown_tag():
    cache = rc.init_cache(1, CFG)
    cache = rc.insert(cache, 0, _ids([1, 2, 3]), _rgb(1),
                      jnp.asarray([True]), CFG)
    hit, _, _, _, _ = rc.lookup(cache, 0, _ids([1, 2, 4]), CFG)
    assert not bool(hit.any())


def test_padding_id_is_not_invalid_tag():
    """-1 is legal record padding; must be storable and matchable."""
    cache = rc.init_cache(1, CFG)
    ids = _ids([7, -1, -1])
    cache = rc.insert(cache, 0, ids, _rgb(1), jnp.asarray([True]), CFG)
    hit, _, _, _, _ = rc.lookup(cache, 0, ids, CFG)
    assert bool(hit.all())


def test_lru_eviction_prefers_oldest():
    cfg = rc.CacheConfig(n_sets=1, n_ways=2, k=2)   # one set, two ways
    cache = rc.init_cache(1, cfg)
    a, b, c = _ids([1, 1]), _ids([2, 2]), _ids([3, 3])
    one = jnp.asarray([True])
    cache = rc.insert(cache, 0, a, _rgb(1, 0.1), one, cfg)
    cache = rc.insert(cache, 0, b, _rgb(1, 0.2), one, cfg)
    # touch a -> b becomes LRU
    _, _, _, _, cache = rc.lookup(cache, 0, a, cfg)
    cache = rc.insert(cache, 0, c, _rgb(1, 0.3), one, cfg)
    hit_a, _, _, _, _ = rc.lookup(cache, 0, a, cfg)
    hit_b, _, _, _, _ = rc.lookup(cache, 0, b, cfg)
    hit_c, _, _, _, _ = rc.lookup(cache, 0, c, cfg)
    assert bool(hit_a.all()) and bool(hit_c.all()) and not bool(hit_b.any())


def test_insert_conflict_lowest_pixel_wins():
    cfg = rc.CacheConfig(n_sets=1, n_ways=1, k=2, insert_rounds=1)
    cache = rc.init_cache(1, cfg)
    ids = _ids([5, 5], [6, 6])     # same set (only one), same victim way
    cache = rc.insert(cache, 0, ids, _rgb(2), jnp.asarray([True, True]), cfg)
    hit, val, _, _, _ = rc.lookup(cache, 0, ids, cfg)
    assert bool(hit[0]) and not bool(hit[1])


def test_duplicate_tags_single_entry():
    cache = rc.init_cache(1, CFG)
    ids = _ids([9, 9, 9], [9, 9, 9])
    cache = rc.insert(cache, 0, ids, _rgb(2), jnp.asarray([True, True]), CFG)
    tags = np.asarray(cache.tags[0])
    n_present = (np.all(tags == np.asarray([9, 9, 9]), axis=-1)).sum()
    assert n_present == 1


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 500), st.integers(0, 500),
                          st.integers(0, 500)), min_size=1, max_size=16,
                unique=True))
def test_property_inserted_retrievable(tag_rows):
    """Any batch of unique tags inserted into an empty, large-enough cache
    is fully retrievable with its own values."""
    cfg = rc.CacheConfig(n_sets=64, n_ways=4, k=3, insert_rounds=8)
    cache = rc.init_cache(1, cfg)
    ids = jnp.asarray(tag_rows, jnp.int32)
    n = ids.shape[0]
    rgb = jnp.arange(n * 3, dtype=jnp.float32).reshape(n, 3)
    cache = rc.insert(cache, 0, ids, rgb, jnp.ones((n,), bool), cfg)
    hit, val, _, _, _ = rc.lookup(cache, 0, ids, cfg)
    # every tag either hits with ITS value, or lost a (rare) way conflict —
    # with 64 sets x 4 ways >= 256 slots and <=16 inserts, conflicts need
    # >4 of 16 tags in one set: possible but then values must still match
    hits = np.asarray(hit)
    vals = np.asarray(val)
    assert hits.mean() >= 0.75
    np.testing.assert_allclose(vals[hits], np.asarray(rgb)[hits])


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 2), st.integers(1, 8))
def test_property_set_index_in_range(seed, k):
    cfg = rc.CacheConfig(n_sets=32, n_ways=2, k=k)
    ids = jax.random.randint(jax.random.PRNGKey(seed), (20, k), -1, 10000)
    idx = np.asarray(rc.set_index(ids.astype(jnp.int32), cfg))
    assert ((idx >= 0) & (idx < 32)).all()


def test_bitconcat_index_mode():
    cfg = rc.CacheConfig(n_sets=64, n_ways=2, k=3, index_mode='bitconcat')
    ids = jnp.asarray([[8, 16, 24], [8, 16, 25]], jnp.int32)
    idx = np.asarray(rc.set_index(ids, cfg))
    assert ((idx >= 0) & (idx < 64)).all()
