"""Chaos suite: deterministic fault injection, hardened host loop,
crash-consistent checkpoint/restore (``repro.serve.faults``,
``repro.serve.session`` hardened helpers, ``repro.checkpoint``).

The contracts under test:

* a **fault trace** round-trips through ``to_dict``/``from_dict`` and the
  injector consumes it one-shot, so ``serve.faults{kind=...}`` counters can
  be matched against the injected schedule **exactly**;
* both drivers **drain** every seeded fault trace — injected planner
  exceptions, transient + persistent dispatch failures, device stalls,
  poisoned frames and (threaded) worker deaths degrade service, never stop
  it — and non-finite values never reach the shared scene cache;
* with the fault layer present but **disabled** (an enabled injector with
  an empty trace — strictly stronger than the NULL default every other
  test runs under), the serving run is bit-identical to the unhardened
  path;
* a run killed at tick ``k`` and **restored** from its newest checkpoint
  continues bit-identically to the uninterrupted golden run — images,
  cache tags, LRU ages/clock and sort cadence — on both shade backends.
"""
import dataclasses
import hashlib
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core import radiance_cache as rc
from repro.core.pipeline import LuminaConfig
from repro.data.trajectory import orbit_trajectory
from repro.serve import faults
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper

CFG = LuminaConfig(capacity=192, window=3)
FRAMES = 3
ARRIVALS = (0, 0, 1, 6, 9)


def _digest(arr) -> str:
    return hashlib.sha256(np.asarray(arr).tobytes()).hexdigest()


def _sessions(frames=FRAMES, arrivals=ARRIVALS):
    out = []
    for sid, arrival in enumerate(arrivals):
        cams = orbit_trajectory(frames, width=64, height_px=64,
                                start_deg=72.0 * sid)
        out.append(ViewerSession(sid=sid, cams=cams, arrival_tick=arrival))
    return out


class TickRecorder:
    """Stepper wrapper recording per-device-tick image digests + the sort
    accounting entry, keyed by the stepper's ``global_tick`` — the key
    survives kill/restore, so a restored continuation can be compared
    tick-by-tick against the golden run's tail."""

    def __init__(self, stepper):
        self._s = stepper
        self.ticks = {}

    def __getattr__(self, name):
        return getattr(self._s, name)

    def _record(self, tick, out):
        self.ticks[tick] = ({slot: _digest(img)
                             for slot, (img, _st, _t) in out.items()},
                            dict(self._s.sort_log[-1]))
        return out

    def step(self, cams, plan=None):
        tick = self._s.global_tick
        return self._record(tick, self._s.step(cams, plan=plan))

    def step_dispatch(self, cams, plan=None):
        return self._s.step_dispatch(cams, plan)

    def step_finish(self, infl):
        tick = self._s.global_tick - 1   # dispatch already advanced it
        return self._record(tick, self._s.step_finish(infl))


@pytest.fixture(scope='module')
def chaos_stepper(small_scene):
    """One compiled stepper shared by every run in this module (reset
    between runs) — recompiling per test would dominate the suite."""
    cams0 = orbit_trajectory(1, width=64, height_px=64)
    return BatchedStepper(small_scene, CFG, cams0[0], slots=2)


# ---------------------------------------------------------------------------
# Fault traces and the injector
# ---------------------------------------------------------------------------

def test_fault_trace_roundtrip():
    trace = faults.make_trace(faults.KINDS, 40, seed=3, rate=0.2, slots=4)
    assert trace.events, 'rate 0.2 over 40 ticks x 7 kinds must schedule'
    again = faults.FaultTrace.from_dict(trace.to_dict())
    assert again == trace
    assert again.counts() == trace.counts()
    # same arguments -> same trace, always
    assert faults.make_trace(faults.KINDS, 40, seed=3, rate=0.2,
                             slots=4) == trace
    with pytest.raises(ValueError):
        faults.make_trace(('no_such_kind',), 10)
    with pytest.raises(ValueError):
        faults.FaultEvent(tick=0, kind='no_such_kind')


def test_injector_one_shot_and_deferred_firing():
    trace = faults.FaultTrace(seed=0, events=(
        faults.FaultEvent(tick=2, kind='stall'),
        faults.FaultEvent(tick=5, kind='stall'),
        faults.FaultEvent(tick=3, kind='nan_poison', slot=1),
    ))
    inj = faults.FaultInjector(trace)
    assert inj.take('stall', 0) is None          # not armed yet
    assert inj.peek('stall', 2)
    ev = inj.take('stall', 4)                    # deferred past tick 2: fires
    assert ev is not None and ev.tick == 2
    assert inj.take('stall', 4) is None          # one-shot; next arms at 5
    assert inj.take('stall', 7).tick == 5
    assert inj.fired_counts() == {'stall': 2}
    assert inj.outstanding() == {'nan_poison': 1}
    # preferred slot if eligible, else lowest eligible
    ev = inj.take('nan_poison', 3)
    assert faults.FaultInjector.poison_slot(ev, [0, 1]) == 1
    assert faults.FaultInjector.poison_slot(ev, [0, 2]) == 0


# ---------------------------------------------------------------------------
# The isfinite insert gate: NaN never lands in a shared scene cache
# ---------------------------------------------------------------------------

def test_insert_gate_blocks_nonfinite_rgb():
    cfg = rc.CacheConfig(n_sets=16, n_ways=2)
    cache = rc.init_cache(1, cfg)
    ids = jnp.arange(4 * cfg.k, dtype=jnp.int32).reshape(1, 4, cfg.k)
    rgb = jnp.ones((1, 4, 3), jnp.float32)
    rgb = rgb.at[0, 1, 0].set(jnp.nan).at[0, 3, 2].set(jnp.inf)
    do = jnp.ones((1, 4), bool)
    out = rc.insert_all_groups(cache, ids, rgb, do, cfg)
    assert bool(jnp.isfinite(out.values).all()), \
        'non-finite rgb reached the cache'
    # the two finite records landed, the two poisoned ones did not
    live = int((out.tags[..., 0] != rc.INVALID_TAG).sum())
    assert live == 2
    # the gate is bit-neutral on finite data
    clean = jnp.ones((1, 4, 3), jnp.float32)
    gated = rc.insert_all_groups(cache, ids, clean, do, cfg)
    plain = rc.insert_all_groups(cache, ids, clean,
                                 do & jnp.isfinite(clean).all(axis=-1), cfg)
    for a, b in zip(gated, plain):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nan_camera_cannot_poison_shared_cache(chaos_stepper):
    """Drive a genuinely NaN camera through the real jitted shade: whatever
    the rasterizer makes of it, nothing non-finite may be published to the
    scene cache other viewers read."""
    st = chaos_stepper
    st.reset()
    cams = orbit_trajectory(2, width=64, height_px=64)
    st.admit(0)
    st.step({0: cams[0]})
    st.step({0: faults.poison_camera(cams[1])})
    assert bool(jnp.isfinite(st.shared.cache.values).all())


# ---------------------------------------------------------------------------
# Serving under injected faults
# ---------------------------------------------------------------------------

def _chaos_run(stepper, driver, injector, sessions=None, **mgr_kw):
    stepper.reset()
    rec = TickRecorder(stepper)
    mgr = SessionManager(rec, slots=stepper.slots, injector=injector,
                         **mgr_kw)
    for s in (sessions if sessions is not None else _sessions()):
        mgr.submit(s)
    finished = mgr.run(driver=driver)
    return mgr, rec, finished


def _counter(mgr, name):
    return mgr.metrics[name].value if name in mgr.metrics else 0


def _assert_counters_match_fired(mgr, inj):
    for kind, n in inj.fired_counts().items():
        key = f'serve.faults{{kind={kind}}}'
        assert key in mgr.metrics, f'missing counter for fired {kind}'
        assert mgr.metrics[key].value == n, \
            f'{kind}: {mgr.metrics[key].value} counted vs {n} fired'
    # and nothing was counted that never fired
    fired = inj.fired_counts()
    for key in mgr.metrics.names():
        if key.startswith('serve.faults{'):
            kind = key[len('serve.faults{kind='):-1]
            assert fired.get(kind, 0) == mgr.metrics[key].value


SYNC_KINDS = ('plan_exc', 'dispatch_transient', 'dispatch_persistent',
              'stall', 'nan_poison')
# every kind a single-device driver can consume ('device_loss' only has a
# seam in the fleet drivers — tests/test_fleet.py)
HOST_KINDS = tuple(k for k in faults.KINDS if k != 'device_loss')


def test_sync_driver_drains_under_faults(chaos_stepper):
    # horizon 10 = the last arrival tick + 1: every event arms while the
    # fleet is still serving, so deferred firing drains the whole trace
    trace = faults.make_trace(SYNC_KINDS, 10, seed=11, rate=0.3, slots=2,
                              stall_s=0.01)
    assert len(trace.counts()) >= 4, 'seed must schedule a broad mix'
    inj = faults.FaultInjector(trace)
    with warnings.catch_warnings():
        warnings.simplefilter('ignore', RuntimeWarning)
        mgr, _rec, finished = _chaos_run(chaos_stepper, 'sync', inj)
    assert sorted(s.sid for s in finished) == [0, 1, 2, 3, 4]
    assert all(s.telemetry.frames == FRAMES for s in finished)
    assert not inj.outstanding(), 'every scheduled event must fire'
    _assert_counters_match_fired(mgr, inj)
    assert _counter(mgr, 'serve.quarantined') \
        == inj.fired_counts().get('nan_poison', 0)
    assert bool(jnp.isfinite(chaos_stepper.shared.cache.values).all()), \
        'NaN reached the shared scene cache'


def test_threaded_driver_drains_under_faults_with_worker_death(
        chaos_stepper):
    trace = faults.make_trace(HOST_KINDS, 10, seed=5, rate=0.3, slots=2,
                              stall_s=0.01)
    assert 'worker_death' in trace.counts()
    inj = faults.FaultInjector(trace)
    with warnings.catch_warnings():
        warnings.simplefilter('ignore', RuntimeWarning)
        mgr, _rec, finished = _chaos_run(chaos_stepper, 'threaded', inj,
                                         watchdog_s=5.0)
    assert sorted(s.sid for s in finished) == [0, 1, 2, 3, 4]
    assert all(s.telemetry.frames == FRAMES for s in finished)
    assert not inj.outstanding()
    _assert_counters_match_fired(mgr, inj)
    # every worker death degraded at least one tick and was survived
    deaths = inj.fired_counts().get('worker_death', 0)
    assert deaths > 0
    assert _counter(mgr, 'serve.degraded_ticks') >= deaths
    assert bool(jnp.isfinite(chaos_stepper.shared.cache.values).all())


def test_enabled_empty_injector_is_bit_identical(chaos_stepper):
    """The hardened helpers must reduce exactly to the plain path — run the
    full hardened machinery with an *enabled* injector whose trace is empty
    (every peek/take is a live call, containment scans every tick) and
    demand bit-parity with the NULL default."""
    _mgr, base, fin0 = _chaos_run(chaos_stepper, 'sync', faults.NULL)
    empty = faults.FaultInjector(faults.FaultTrace(seed=0, events=()))
    _mgr, hard, fin1 = _chaos_run(chaos_stepper, 'sync', empty)
    assert base.ticks == hard.ticks, 'hardened run diverged bitwise'
    assert [s.telemetry.frames for s in fin0] \
        == [s.telemetry.frames for s in fin1]


def test_load_shedding_bounds_the_backlog(chaos_stepper):
    sessions = _sessions(frames=2, arrivals=(0, 0, 0, 0, 0))
    chaos_stepper.reset()
    mgr = SessionManager(chaos_stepper, slots=chaos_stepper.slots,
                         max_pending=3)
    accepted = [mgr.submit(s) for s in sessions]
    # the backlog bound counts queued sessions (slots drain at admission
    # ticks, not submit time): 3 backlog seats, then load-shed
    assert accepted == [True, True, True, False, False]
    assert [s.sid for s in mgr.shed] == [3, 4]
    assert mgr.metrics['serve.shed'].value == 2
    finished = mgr.run()
    assert sorted(s.sid for s in finished) == [0, 1, 2]


def test_quarantine_resets_slot_and_keeps_neighbors(chaos_stepper):
    """A poisoned frame is dropped, its viewer retries the same frame, and
    the other viewer's stream is untouched (blast radius = one slot)."""
    trace = faults.FaultTrace(seed=0, events=(
        faults.FaultEvent(tick=2, kind='nan_poison', slot=1),))
    inj = faults.FaultInjector(trace)
    sessions = _sessions(frames=3, arrivals=(0, 0))
    mgr, _rec, finished = _chaos_run(chaos_stepper, 'sync', inj,
                                     sessions=sessions)
    assert inj.fired_counts() == {'nan_poison': 1}
    assert mgr.metrics['serve.quarantined'].value == 1
    by_sid = {s.sid: s for s in finished}
    # both completed every frame; the poisoned viewer needed an extra tick
    assert by_sid[0].telemetry.frames == 3
    assert by_sid[1].telemetry.frames == 3
    assert by_sid[1].telemetry.finished_tick \
        > by_sid[0].telemetry.finished_tick


# ---------------------------------------------------------------------------
# Serve-state checkpointing
# ---------------------------------------------------------------------------

def test_serve_state_roundtrip_is_exact(chaos_stepper):
    """``state_dict``/``load_state`` preserve dtypes, treedef, host
    scheduler mirrors and the LRU clock exactly — and a stepper restored
    mid-run continues bit-identically to the donor."""
    st = chaos_stepper
    st.reset()
    cams = orbit_trajectory(4, width=64, height_px=64)
    st.admit(0)
    st.admit(1)
    st.step({0: cams[0], 1: cams[1]})
    st.step({0: cams[1], 1: cams[2]})
    arrays, meta = st.state_dict()

    # round-trip through the serializable forms (what a checkpoint stores)
    leaves0, tree0 = jax.tree_util.tree_flatten(arrays)
    host = jax.tree.map(np.asarray, arrays)
    st.step({0: cams[2], 1: cams[3]})       # mutate the donor past snapshot
    golden = st.step({0: cams[3], 1: cams[0]})

    st.reset()
    st.load_state(host, meta)
    arrays2, meta2 = st.state_dict()
    assert meta2 == meta, 'host scheduler mirrors did not round-trip'
    leaves2, tree2 = jax.tree_util.tree_flatten(arrays2)
    assert tree2 == tree0, 'treedef changed through restore'
    for a, b in zip(leaves0, leaves2):
        assert jnp.asarray(a).dtype == jnp.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(st.shared.cache.clock.sum()) \
        == int(np.asarray(host['shared'].cache.clock).sum())

    st.step({0: cams[2], 1: cams[3]})       # replay the donor's tail
    replay = st.step({0: cams[3], 1: cams[0]})
    for slot in golden:
        np.testing.assert_array_equal(np.asarray(golden[slot][0]),
                                      np.asarray(replay[slot][0]))


def test_checkpoint_checksum_mismatch_falls_back(tmp_path):
    """Corrupted shard bytes (same names/shapes/dtypes, different values)
    must fail the manifest checksum and fall back one step."""
    def tree(fill):
        return {'a': np.full((4, 3), fill, np.float32),
                'b': np.arange(6, dtype=np.float32).reshape(2, 3)}

    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(tree(1.0), step=1, blocking=True)
    mgr.save(tree(2.0), step=2, blocking=True)
    # flip bytes inside step 2's shard, keeping structure identical
    shard = tmp_path / 'step_0000000002' / 'host0.npz'
    with np.load(shard) as z:
        arrs = {k: z[k] for k in z.files}
    k0 = sorted(arrs)[0]   # npz keys are keystr-derived, e.g. "['a']"
    arrs[k0] = arrs[k0] + 17.0
    with open(shard, 'wb') as f:
        np.savez(f, **arrs)
    with pytest.warns(RuntimeWarning, match='checksum mismatch'):
        out = mgr.restore_latest(tree(0.0))
    assert out is not None
    restored, step, _extra = out
    assert step == 1
    np.testing.assert_array_equal(restored['a'], tree(1.0)['a'])
    assert mgr.metrics['ckpt.restore_fallback'].value == 1


# ---------------------------------------------------------------------------
# Kill-and-restore: the crash-consistency oracle
# ---------------------------------------------------------------------------

def _restore_oracle(scene, backend, tmp_path):
    cfg = dataclasses.replace(CFG, backend=backend)
    cams0 = orbit_trajectory(1, width=64, height_px=64)
    stepper = BatchedStepper(scene, cfg, cams0[0], slots=2)

    # golden: uninterrupted run, per-tick digests + final cache state
    rec = TickRecorder(stepper)
    mgr = SessionManager(rec, slots=2)
    for s in _sessions():
        mgr.submit(s)
    mgr.run()
    golden = {'ticks': dict(rec.ticks),
              'tags': np.asarray(stepper.shared.cache.tags),
              'age': np.asarray(stepper.shared.cache.age),
              'clock': np.asarray(stepper.shared.cache.clock),
              'total': mgr.tick}

    # victim: checkpoint every 4 ticks, killed mid-run at tick 9
    stepper.reset()
    mgr = SessionManager(stepper, slots=2)
    ckpt = CheckpointManager(tmp_path / backend, keep=3)
    mgr.enable_checkpoints(ckpt, every=4)
    for s in _sessions():
        mgr.submit(s)
    while not mgr.drained() and mgr.tick < 9:
        mgr.run_tick()
        mgr.evict_finished()
        mgr.maybe_checkpoint()
    assert not mgr.drained(), 'kill point must land mid-run'
    ckpt.wait()   # the crash loses in-flight RAM, not published renames

    # survivor: fresh manager + session objects, state restored from disk
    stepper.reset()
    rec = TickRecorder(stepper)
    mgr = SessionManager(rec, slots=2)
    restored = mgr.restore_serving(CheckpointManager(tmp_path / backend),
                                   _sessions())
    assert restored == 8, 'newest complete checkpoint is tick 8'
    assert mgr.tick == 8
    mgr.run()
    assert mgr.metrics['serve.restores'].value == 1

    # continuation == golden tail, bit for bit
    want = {t: v for t, v in golden['ticks'].items() if t >= 8}
    assert rec.ticks == want, \
        f'{backend}: restored continuation diverged from golden tail'
    assert mgr.tick == golden['total']
    np.testing.assert_array_equal(
        np.asarray(stepper.shared.cache.tags), golden['tags'],
        err_msg=f'{backend}: cache tags')
    np.testing.assert_array_equal(
        np.asarray(stepper.shared.cache.age), golden['age'],
        err_msg=f'{backend}: LRU ages')
    np.testing.assert_array_equal(
        np.asarray(stepper.shared.cache.clock), golden['clock'],
        err_msg=f'{backend}: LRU clock')


def test_kill_and_restore_bitwise_reference(small_scene, tmp_path):
    _restore_oracle(small_scene, 'reference', tmp_path)


def test_kill_and_restore_bitwise_pallas(small_scene, tmp_path):
    _restore_oracle(small_scene, 'pallas', tmp_path)
