"""3DGS core invariants: projection, tiling, sorting, S^2."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.camera import expand_viewport, look_at, make_camera, slerp
from repro.core.gaussians import quat_to_rotmat
from repro.core.pipeline import LuminaConfig, LuminSys, render_frame_baseline
from repro.core.projection import project
from repro.core.metrics import psnr, ssim
from repro.core.s2 import predict_pose, shared_features, speculative_sort
from repro.core.sorting import pairwise_order_agreement, sort_scene
from repro.core.tiling import (TILE, gather_tile_features, tile_grid,
                               tile_lists_dense, tile_lists_sorted)


def test_quat_rotmat_orthonormal():
    q = jax.random.normal(jax.random.PRNGKey(1), (32, 4))
    r = quat_to_rotmat(q)
    eye = jnp.eye(3)
    err = jnp.abs(r @ jnp.swapaxes(r, -1, -2) - eye).max()
    assert float(err) < 1e-5
    det = jnp.linalg.det(r)
    np.testing.assert_allclose(np.asarray(det), 1.0, atol=1e-5)


def test_projection_depth_and_frustum(small_scene, cams64):
    proj = project(small_scene, cams64[0])
    valid = np.asarray(proj.valid)
    depth = np.asarray(proj.depth)
    assert valid.any()
    assert (depth[valid] > 0).all()
    assert np.isinf(depth[~valid]).all()
    # culled Gaussians contribute nothing
    assert (np.asarray(proj.opacity)[~valid] == 0).all()


def test_tile_lists_sorted_matches_dense(small_scene, cams64):
    """The scalable duplicate+sort path agrees with the dense oracle."""
    cam = cams64[0]
    proj = project(small_scene, cam)
    dense = tile_lists_dense(proj, cam.width, cam.height, capacity=64)
    fast = tile_lists_sorted(proj, cam.width, cam.height, capacity=64,
                             max_tiles_per_gaussian=64)
    depth = np.asarray(proj.depth)
    d_idx, f_idx = np.asarray(dense.indices), np.asarray(fast.indices)
    # same membership per tile (order may tie-break differently)
    for t in range(d_idx.shape[0]):
        ds = set(d_idx[t][d_idx[t] >= 0].tolist())
        fs = set(f_idx[t][f_idx[t] >= 0].tolist())
        assert ds == fs, f'tile {t} membership differs'
        # both sorted by depth
        for idx in (d_idx[t], f_idx[t]):
            sel = idx[idx >= 0]
            dd = depth[sel]
            assert (np.diff(dd) >= -1e-6).all()


def test_tile_lists_depth_sorted(small_scene, cams64):
    cam = cams64[0]
    proj = project(small_scene, cam)
    lists = sort_scene(proj, cam.width, cam.height, capacity=128)
    depth = np.asarray(proj.depth)
    idx = np.asarray(lists.indices)
    cnt = np.asarray(lists.count)
    for t in range(idx.shape[0]):
        sel = idx[t, :cnt[t]]
        assert (sel >= 0).all()
        dd = depth[sel]
        assert (np.diff(dd) >= -1e-6).all()


def test_s2_exact_at_same_pose(small_scene, cams64):
    """Sorting-shared render at the SORTING pose == full pipeline render."""
    cam = cams64[0]
    cfg = LuminaConfig(capacity=1200, margin=0, use_rc=False)
    shared = speculative_sort(small_scene, cam, margin=0, capacity=1200)
    feats, lists = shared_features(small_scene, cam, shared)
    from repro.core.rasterize import assemble_image, rasterize_tiles
    colors, _ = rasterize_tiles(feats, lists.tiles_x)
    img_s2 = assemble_image(colors, lists.tiles_x, lists.tiles_y, 64, 64)
    img_base, _, _, _ = render_frame_baseline(small_scene, cam, cfg)
    np.testing.assert_allclose(np.asarray(img_s2), np.asarray(img_base),
                               atol=1e-5)


def test_s2_quality_close_at_nearby_pose(small_scene, cams64):
    """Within a sharing window, S^2-only stays within ~1 dB of exact
    (paper Fig. 20: indistinguishable at VR frame rates)."""
    cfg = LuminaConfig(capacity=1200, window=3, margin=4, use_rc=False)
    sys_ = LuminSys(small_scene, cfg, cams64[0])
    for i, cam in enumerate(cams64):
        img, _ = sys_.step(cam)
        base, _, _, _ = render_frame_baseline(small_scene, cam, cfg)
        p = float(psnr(img, base))
        assert p > 35.0, f'frame {i}: S2 degraded to {p:.1f} dB'


def test_order_agreement_high_for_nearby_poses(small_scene, cams64):
    """Paper Sec. 3.1: ~0.2% of pairwise orders flip between VR frames."""
    cfg_cap = 256
    proj0 = project(small_scene, cams64[0])
    proj1 = project(small_scene, cams64[1])
    l0 = sort_scene(proj0, 64, 64, cfg_cap)
    l1 = sort_scene(proj1, 64, 64, cfg_cap)
    agree = float(pairwise_order_agreement(l0, l1))
    # paper reports 99.8% on full-scale scenes; our 64px procedural scene
    # at capacity 256 has coarser lists — still strongly coherent
    assert agree > 0.9, agree


def test_expand_viewport_preserves_geometry(small_scene, cams64):
    """World geometry projects to the same place, offset by the margin."""
    cam = cams64[0]
    cam_e = expand_viewport(cam, 16)
    p0 = project(small_scene, cam)
    p1 = project(small_scene, cam_e)
    m = np.asarray(p0.valid) & np.asarray(p1.valid)
    d = np.asarray(p1.mean2d)[m] - np.asarray(p0.mean2d)[m]
    np.testing.assert_allclose(d, 16.0, atol=1e-3)


def test_predict_pose_constant_velocity():
    p0, q0 = look_at((0.0, 0.0, 2.0), (0, 0, 0))
    p1, q1 = look_at((0.1, 0.0, 2.0), (0, 0, 0))
    c0 = make_camera(p0, q0, 60.0, 64, 64)
    c1 = make_camera(p1, q1, 60.0, 64, 64)
    pred = predict_pose(c0, c1, window=6)
    # position extrapolates linearly: prev + (1 + w/2) * delta
    expect = np.asarray(p0) + 4.0 * (np.asarray(p1) - np.asarray(p0))
    np.testing.assert_allclose(np.asarray(pred.position), expect, atol=1e-5)


def test_ssim_psnr_sanity():
    a = jnp.zeros((32, 32, 3)) + 0.5
    assert float(psnr(a, a)) > 100
    assert float(ssim(a, a)) > 0.99
    b = a + 0.1
    assert float(psnr(a, b)) < 25


def test_rasterize_early_exit_matches_dense_scan(small_scene, cams64):
    """The chunked early-exit walk is a pure compute saving: bit-identical
    to the dense scan formulation on every output."""
    from repro.core.rasterize import rasterize_tiles
    from repro.core.sorting import sort_scene
    from repro.core.tiling import gather_tile_features
    cam = cams64[0]
    proj = project(small_scene, cam)
    lists = sort_scene(proj, cam.width, cam.height, 128)
    feats = gather_tile_features(proj, lists)
    colors_w, aux_w = rasterize_tiles(feats, lists.tiles_x, early_exit=True)
    colors_s, aux_s = rasterize_tiles(feats, lists.tiles_x, early_exit=False)
    np.testing.assert_array_equal(np.asarray(colors_w), np.asarray(colors_s))
    for a, b in zip(aux_w, aux_s):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_finetune_loss_is_differentiable(small_scene, cams64):
    """Regression: the fine-tuning loss must stay reverse-mode
    differentiable (the rasterizer's early-exit while_loop is not, so the
    loss renders through the dense-scan formulation)."""
    from repro.core import finetune
    cfg = finetune.FinetuneConfig()
    render_cfg = LuminaConfig(capacity=64)
    cam = cams64[0]
    gt = render_frame_baseline(small_scene, cam, render_cfg)[0]
    (loss, aux), grads = jax.value_and_grad(
        finetune.total_loss, has_aux=True)(small_scene, cam, gt, cfg,
                                           render_cfg)
    assert np.isfinite(float(loss))
    finite = jax.tree.map(lambda g: bool(jnp.all(jnp.isfinite(g))), grads)
    assert all(jax.tree.leaves(finite))
