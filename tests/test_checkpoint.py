"""Checkpoint manager: round-trip, atomicity, keep-K, auto-resume."""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {'w': jax.random.normal(k, (8, 4)),
            'opt': {'mu': jnp.zeros((8, 4)), 'step': jnp.int32(seed)}}


def test_roundtrip(tmp_path):
    tree = _tree(3)
    save_checkpoint(tmp_path, tree, step=7, extra={'note': 'hi'})
    got, extra = load_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, tree),
                                 step=7)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), got, tree)
    assert extra['note'] == 'hi'


def test_atomic_no_tmp_visible(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(_tree(), step=1, blocking=True)
    names = [p.name for p in Path(tmp_path).iterdir()]
    assert not any(n.endswith('.tmp') for n in names)
    assert mgr.latest() == 1


def test_partial_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(_tree(1), step=1, blocking=True)
    # simulate a crash mid-write: a .tmp dir with garbage
    bad = Path(tmp_path) / 'step_0000000002.tmp'
    bad.mkdir()
    (bad / 'host0.npz').write_bytes(b'garbage')
    assert mgr.latest() == 1
    out = mgr.restore_latest(_tree(0))
    assert out is not None and out[1] == 1


def test_corrupt_latest_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(_tree(1), step=1, blocking=True)
    mgr.save(_tree(2), step=2, blocking=True)
    # corrupt step 2's shard
    (Path(tmp_path) / 'step_0000000002' / 'host0.npz').write_bytes(b'junk')
    tree, step, _ = mgr.restore_latest(_tree(0))
    assert step == 1
    np.testing.assert_allclose(np.asarray(tree['w']),
                               np.asarray(_tree(1)['w']))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in range(1, 6):
        mgr.save(_tree(s), step=s, blocking=True)
    assert mgr.all_steps() == [4, 5]


def test_keep_every_protects(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=1, keep_every=2)
    for s in range(1, 6):
        mgr.save(_tree(s), step=s, blocking=True)
    assert mgr.all_steps() == [2, 4, 5]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(9)
    mgr.save(tree, step=3)          # async
    mgr.wait()
    out = mgr.restore_latest(jax.tree.map(jnp.zeros_like, tree))
    assert out is not None
    got, step, _ = out
    assert step == 3
    np.testing.assert_allclose(np.asarray(got['w']), np.asarray(tree['w']))


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, _tree(), step=1)
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, {'different': jnp.zeros(3)}, step=1)


def test_multihost_shards_assemble(tmp_path):
    """Each host writes its own leaves; restore assembles all of them."""
    tree = _tree(4)
    # non-zero hosts write their shards FIRST; host 0 publishes (renames)
    # last — the barrier ordering of a real multi-host run
    for h in (1, 0):
        save_checkpoint(tmp_path, tree, step=5, host_id=h, num_hosts=2)
    got, _ = load_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, tree),
                             step=5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), got, tree)


def test_train_resume_end_to_end(tmp_path):
    """launch.train: interrupt + resume == uninterrupted run."""
    from repro.launch.train import train
    kw = dict(steps=6, batch=2, seq=32, ckpt_every=3, log_every=0,
              print_fn=lambda *a, **k: None)
    # uninterrupted
    p_full, _, hist_full = train('smollm-360m', ckpt_dir='', **kw)
    # interrupted at 3 then resumed
    d = str(tmp_path / 'ck')
    train('smollm-360m', ckpt_dir=d, **dict(kw, steps=3))
    p_res, _, hist_res = train('smollm-360m', ckpt_dir=d, **kw)
    leaves_a = jax.tree.leaves(p_full)
    leaves_b = jax.tree.leaves(p_res)
    for a, b in zip(leaves_a, leaves_b):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)
