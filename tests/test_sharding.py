"""Sharding substrate: adaptive specs, param spec rules, mesh builders."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ALL_LM_ARCHS, get_config
from repro.models import registry
from repro.runtime.sharding import (adaptive_spec, axes_size, batch_axes,
                                    padded_heads, pad_to_multiple,
                                    replicated_kv_heads)


class FakeMesh:
    """Shape-only stand-in (adaptive_spec touches only .shape/.axis_names)."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh(data=16, model=16)


def test_adaptive_spec_basic():
    spec = adaptive_spec((256, 4096, 1024), MESH,
                         [(0, ('data',)), (1, 'model')])
    assert spec == P(('data',), 'model')


def test_adaptive_spec_skips_indivisible():
    spec = adaptive_spec((15, 4096), MESH, [(0, 'model'), (1, 'model')])
    assert spec == P(None, 'model')


def test_adaptive_spec_no_axis_reuse():
    spec = adaptive_spec((64, 64), MESH, [(0, 'model'), (1, 'model')])
    assert spec == P('model')      # second use of 'model' dropped


def test_adaptive_spec_negative_dim():
    spec = adaptive_spec((4, 4, 64), MESH, [(-1, 'model')])
    assert spec == P(None, None, 'model')


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(1, 512), min_size=1, max_size=4),
       st.lists(st.tuples(st.integers(-4, 3),
                          st.sampled_from(['data', 'model', None])),
                max_size=4))
def test_adaptive_spec_properties(shape, assignments):
    """Every produced spec is divisibility-sound and never reuses an axis."""
    spec = adaptive_spec(shape, MESH, assignments)
    seen = set()
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        size = axes_size(MESH, entry)
        assert shape[i] % size == 0
        names = entry if isinstance(entry, tuple) else (entry,)
        assert not (set(names) & seen)
        seen.update(names)


@pytest.mark.parametrize('arch', ALL_LM_ARCHS)
def test_param_specs_divisible(arch):
    """Every param spec divides its tensor on the production mesh shape."""
    cfg = get_config(arch)
    params_abs = registry.abstract_params(cfg, tp=16)
    specs = registry.param_specs(cfg, params_abs, MESH)

    def check(leaf, spec):
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            assert leaf.shape[i] % axes_size(MESH, entry) == 0, \
                (arch, leaf.shape, spec)
    jax.tree.map(check, params_abs, specs,
                 is_leaf=lambda x: isinstance(x, P))


def test_padded_heads_and_kv():
    assert padded_heads(56, 16) == 64
    assert padded_heads(15, 16) == 16
    assert padded_heads(48, 16) == 48
    assert replicated_kv_heads(8, 16) == 16
    assert replicated_kv_heads(8, 8) == 8
    assert pad_to_multiple(49155, 128) == 49280


def test_make_production_mesh_requires_devices():
    """On the 1-CPU test process the production mesh must refuse to build
    (the dry-run process forces 512 host devices instead)."""
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(RuntimeError):
        make_production_mesh()


def test_batch_shardings_decode_token():
    cfg = get_config('yi-34b')
    tok = jax.ShapeDtypeStruct((1, 1), np.int32)
    spec = registry.batch_shardings(cfg, MESH, tok)
    assert spec == P()    # batch=1: nothing shardable, stays replicated
