"""Observability layer (``repro.obs``) + bench regression gating.

Four contracts:

* **Tracer/export schema** — spans/instants/explicit device windows record
  with correct nesting depth and export as Chrome trace-event JSON that
  passes the loadability schema (tracks as named thread lanes, µs
  timestamps, ``M`` metadata);
* **Structure determinism** — under the virtual-clock ``SyncDriver`` the
  span *structure* (per-track (ph, name, depth, args) sequences, no
  timestamps) of two replays of the same traffic trace is identical, and a
  threaded run shows a ``host-worker`` plan span genuinely overlapping a
  ``device`` shade window — the plan(t+1) ∥ device(t) picture;
* **Metrics registry** — typed get-or-create instruments (kind conflicts
  raise), label keying, exact percentiles, JSON snapshots; and the
  registry's tick series reproduce ``tick_rollup`` **bit-identically** to
  the ``SessionManager.tick_log`` dict path on a real serving run;
* **Bench history gating** — ``benchmarks.history.check_payloads`` passes a
  fresh payload equal to its baseline and fails degraded copies
  (fps collapse, p95 blow-up, host_overlap -> 0, chunk-savings sign flip).

Satellites ride along: ``aggregate``'s frame-weighted ``fleet_fps``,
heterogeneous ``format_table``, and the ``tick_rollup`` edge cases
(legacy logs, mixed profiling, all-warmup slicing, overlap > 1 warning).
"""
import json
import warnings

import numpy as np
import pytest

from repro.core.pipeline import LuminaConfig
from repro.data.trajectory import orbit_trajectory
from repro.obs import (NULL, Registry, Tracer, TRACK_DEVICE, TRACK_HOST,
                       TRACK_WORKER, publish_tick, span_structure,
                       tick_log_from_registry, tick_rollup_from_metrics,
                       to_chrome_trace, track_spans, validate_chrome_trace,
                       write_trace)
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper
from repro.serve.telemetry import aggregate, format_table, tick_rollup

from benchmarks import history


# ---------------------------------------------------------------- tracer --

def test_tracer_span_nesting_depth_and_args():
    tr = Tracer()
    with tr.span('tick', tick=3):
        with tr.span('plan_tick', tick=3):
            pass
        tr.instant('admit', slot=1, sid=7)
    tr.complete('shade', 1.0, 1.5, tick=3, slots=2)
    structure = span_structure(tr.events)
    # children exit (and record) before parents; depth counts nesting
    assert structure[TRACK_HOST] == (
        ('X', 'plan_tick', 1, (('tick', 3),)),
        ('i', 'admit', 0, (('sid', 7), ('slot', 1))),
        ('X', 'tick', 0, (('tick', 3),)),
    )
    assert structure[TRACK_DEVICE] == (
        ('X', 'shade', 0, (('slots', 2), ('tick', 3))),)
    (ev,) = [e for e in tr.events if e.track == TRACK_DEVICE]
    assert ev.ts == 1.0 and ev.dur == pytest.approx(0.5)


def test_null_tracer_is_inert():
    with NULL.span('tick', tick=0):
        NULL.instant('admit')
        NULL.complete('shade', 0.0, 1.0)
    assert NULL.events == [] and not NULL.enabled


def test_chrome_trace_export_schema_and_tracks(tmp_path):
    tr = Tracer()
    with tr.span('tick', tick=0):
        pass
    tr.complete('shade', 2.0, 2.25, tick=0)
    tr.instant('arrival', sid=0)
    path = tmp_path / 'trace.json'
    write_trace(str(path), tr)
    payload = json.loads(path.read_text())
    events = validate_chrome_trace(payload)
    assert payload['displayTimeUnit'] == 'ms'
    # named thread lanes for every track, stable order host < device
    lanes = {e['args']['name']: e['tid'] for e in events
             if e['ph'] == 'M' and e['name'] == 'thread_name'}
    assert set(lanes) == {TRACK_HOST, TRACK_DEVICE}
    assert lanes[TRACK_HOST] < lanes[TRACK_DEVICE]
    # timestamps are µs relative to the earliest event; instants are
    # thread-scoped
    ts = [e['ts'] for e in events if e['ph'] != 'M']
    assert min(ts) == 0.0
    (shade,) = track_spans(payload, TRACK_DEVICE)
    assert shade[2] == 'shade' and shade[1] - shade[0] == \
        pytest.approx(0.25e6)
    (inst,) = [e for e in events if e['ph'] == 'i']
    assert inst['s'] == 't'


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError, match='traceEvents'):
        validate_chrome_trace({'events': []})
    bad = to_chrome_trace([])
    bad['traceEvents'].append({'ph': 'X', 'name': 'x', 'pid': 1, 'tid': 1,
                               'ts': 0.0})   # span without dur
    with pytest.raises(ValueError, match='dur'):
        validate_chrome_trace(bad)


# -------------------------------------------------------------- registry --

def test_registry_typed_instruments_and_labels():
    reg = Registry()
    c = reg.counter('sort.executed', scene=0, cell=17)
    c.inc()
    c.inc(2)
    # get-or-create: same (name, labels) -> same instrument; label order
    # in the call does not matter (keys are sorted)
    assert reg.counter('sort.executed', cell=17, scene=0) is c
    assert c.value == 3
    assert 'sort.executed{cell=17,scene=0}' in reg
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge('serve.queue_depth')
    g.set(3)
    g.set(1)
    assert (g.value, g.min, g.max) == (1, 1, 3)
    h = reg.histogram('serve.tick_latency_ms')
    samples = [5.0, 1.0, 9.0, 3.0]
    for s in samples:
        h.observe(s)
    assert h.count == 4 and h.sum == pytest.approx(18.0)
    assert h.percentile(50) == float(np.percentile(samples, 50))
    # a name is permanently typed
    with pytest.raises(TypeError, match='already registered as counter'):
        reg.gauge('sort.executed', scene=0, cell=17)


def test_registry_snapshot_is_json_serializable():
    reg = Registry()
    reg.counter('serve.frames').inc(4)
    reg.gauge('cache.occupancy').set(np.float32(0.5))   # device-ish scalar
    reg.histogram('serve.tick_latency_ms').observe(2.0)
    reg.series('tick.frames').record(0, np.int64(2))
    snap = json.loads(reg.to_json())
    assert snap['serve.frames']['value'] == 4
    assert snap['cache.occupancy']['value'] == pytest.approx(0.5)
    assert snap['tick.frames'] == {'type': 'series', 'ticks': 1, 'last': 2}


def test_publish_tick_roundtrip_and_rollup_bit_identity_synthetic():
    """The registry's tick series reconstruct the tick log (including the
    awkward shapes: ``kernel_ms`` None vs dict, fields present on some
    ticks only) and the registry rollup equals the dict rollup exactly."""
    log = [
        {'tick': 0, 'frames': 2, 'sorted_slots': 1, 'sort_ms': 0.5,
         'shade_ms': 3.0, 'kernel_ms': None},
        {'tick': 1, 'frames': 2, 'sorted_slots': 0, 'sort_ms': 0.0,
         'shade_ms': 2.5, 'kernel_ms': {'prep': 0.1, 'lookup': 0.7},
         'latency_ms': 3.1, 'host_ms': 0.4, 'overlap_ms': 0.2,
         'occupancy': np.float32(0.25)},
        {'tick': 2, 'frames': 1, 'sorted_slots': 2, 'sort_ms': 0.9,
         'shade_ms': 2.0, 'kernel_ms': {'prep': 0.2, 'lookup': 0.5},
         'latency_ms': 2.9, 'host_ms': 0.3, 'overlap_ms': 0.1,
         'occupancy': np.float32(0.5), 'sort_pool_live': 2},
    ]
    reg = Registry()
    for entry in log:
        publish_tick(reg, entry)
    rebuilt = tick_log_from_registry(reg)
    assert [e['tick'] for e in rebuilt] == [0, 1, 2]
    assert rebuilt[0]['kernel_ms'] is None
    assert rebuilt[1]['kernel_ms'] == {'prep': 0.1, 'lookup': 0.7}
    assert 'sort_pool_live' not in rebuilt[1]
    for want, got in zip(log, rebuilt):
        for key, val in want.items():
            if key != 'kernel_ms':
                assert got[key] is val or got[key] == val
    for warmup in (0, 1):
        assert tick_rollup_from_metrics(reg, warmup_ticks=warmup) == \
            tick_rollup(log, warmup_ticks=warmup)


# ------------------------------------------------- serving integration ----

CFG = LuminaConfig(capacity=192, window=3)
ARRIVALS = (0, 0, 2)
FRAMES = 3


def _sessions():
    return [ViewerSession(sid=sid,
                          cams=orbit_trajectory(FRAMES, width=64,
                                                height_px=64,
                                                start_deg=120.0 * sid),
                          arrival_tick=arrival)
            for sid, arrival in enumerate(ARRIVALS)]


@pytest.fixture(scope='module')
def obs_stepper(small_scene):
    cams0 = orbit_trajectory(1, width=64, height_px=64)
    return BatchedStepper(small_scene, CFG, cams0[0], slots=2)


def _run(stepper, driver):
    stepper.reset()
    tracer = Tracer()
    mgr = SessionManager(stepper, slots=stepper.slots, tracer=tracer)
    for s in _sessions():
        mgr.submit(s)
    mgr.run(driver=driver)
    return tracer, mgr


def test_sync_driver_span_structure_is_deterministic(obs_stepper):
    """Two SyncDriver replays of the same traffic trace record the same
    span structure per track — names, nesting, per-tick args; only the
    timestamps (excluded from the structure) differ."""
    tr_a, _ = _run(obs_stepper, 'sync')
    tr_b, _ = _run(obs_stepper, 'sync')
    sa, sb = span_structure(tr_a.events), span_structure(tr_b.events)
    assert sa == sb
    # and the structure is substantive: nested host spans + device windows
    host_names = {rec[1] for rec in sa[TRACK_HOST]}
    assert {'tick', 'plan_tick', 'apply_plan', 'observe_tick',
            'arrival', 'admit'} <= host_names
    assert any(rec[2] > 0 for rec in sa[TRACK_HOST])
    assert {'shade'} <= {rec[1] for rec in sa[TRACK_DEVICE]}


def test_metrics_rollup_bit_identical_on_real_run(obs_stepper):
    """Acceptance: ``tick_rollup`` computed from the metrics registry is
    bit-identical to the dict path on a recorded serving tick_log."""
    _, mgr = _run(obs_stepper, 'sync')
    assert mgr.tick_log, 'run recorded no ticks'
    for warmup in (0, 1):
        assert tick_rollup_from_metrics(mgr.metrics, warmup_ticks=warmup) \
            == tick_rollup(mgr.tick_log, warmup_ticks=warmup)
    # the traffic/scheduler counters landed
    frames = mgr.metrics['serve.frames'].value
    assert frames == sum(t['frames'] for t in mgr.tick_log)
    assert mgr.metrics['serve.admitted'].value == len(ARRIVALS)
    assert any(name.startswith('sort.executed')
               for name in mgr.metrics.names())


def test_threaded_trace_shows_worker_plan_overlapping_device(obs_stepper):
    """Acceptance: the exported threaded-driver trace has >= 2 tracks and a
    host-worker ``plan_tick`` span overlapping a ``device`` shade span —
    the plan(t+1) ∥ device(t) double-buffering, visible in Perfetto rather
    than inferred from a scalar."""
    tracer, _ = _run(obs_stepper, 'threaded')
    payload = to_chrome_trace(tracer.events)
    validate_chrome_trace(payload)
    worker = track_spans(payload, TRACK_WORKER)
    device = track_spans(payload, TRACK_DEVICE)
    assert worker and device
    assert all(name == 'plan_tick' for _, _, name, _ in worker)
    overlaps = [(w, d) for w in worker for d in device
                if max(w[0], d[0]) < min(w[1], d[1])]
    assert overlaps, 'no host-worker plan span overlapped a device span'


# ------------------------------------------------------- bench history ----

def _serve_payload(fps=30.0, p95=40.0, overlap=0.5, hit=0.8):
    return {'suite': 'serve', 'rows': [{
        'viewers': 2, 'mode': 'batched', 'backend': 'pallas',
        'viewers_per_scene': 1, 'driver': 'threaded', 'stagger': 0,
        'fps_per_viewer': fps, 'p95_frame_ms': p95,
        'host_overlap': overlap, 'hit_rate': hit,
    }]}


def _kernel_payload(savings=27.7):
    return {'suite': 'kernel', 'rows': [
        {'metric': 'chunk_savings_%', 'value': savings, 'note': ''},
        {'metric': 'hit_rate_mean', 'value': 0.94, 'note': ''},
    ]}


def test_history_passes_identical_payloads():
    for suite, payload in (('serve', _serve_payload()),
                           ('kernel', _kernel_payload())):
        violations, report = history.check_payloads(suite, payload, payload)
        assert violations == [] and report


def test_history_fails_degraded_copies():
    base = _serve_payload()
    cases = {
        'fps_per_viewer': _serve_payload(fps=10.0),      # < 50% of baseline
        'p95_frame_ms': _serve_payload(p95=140.0),       # > 2.5x baseline
        'host_overlap': _serve_payload(overlap=0.0),     # hard floor
        'hit_rate': _serve_payload(hit=0.5),             # structural drop
    }
    for metric, fresh in cases.items():
        violations, _ = history.check_payloads('serve', base, fresh)
        assert violations and metric in violations[0], (metric, violations)
    violations, _ = history.check_payloads(
        'kernel', _kernel_payload(), _kernel_payload(savings=-5.0))
    assert violations and 'chunk_savings_%' in violations[0]


def test_history_tolerates_noise_and_row_intersection():
    base = _serve_payload()
    # within-band wobble passes
    ok = _serve_payload(fps=20.0, p95=90.0, overlap=0.2, hit=0.75)
    violations, _ = history.check_payloads('serve', base, ok)
    assert violations == []
    # a fresh row with no baseline counterpart is skipped, not failed —
    # but it leaves the baseline row unmeasured (a missing-row regression)
    # and gating nothing at all fails too
    extra = _serve_payload()
    extra['rows'][0]['viewers'] = 64
    violations, report = history.check_payloads('serve', base, extra)
    assert any('MISSING' in line for line in violations)
    assert (f'serve: no gateable metric pairs between payloads'
            in violations)
    assert any('no baseline row' in line for line in report)


def test_history_fails_dropped_baseline_row():
    """A baseline row the fresh payload stopped producing is itself a
    regression — the dropped cell would otherwise silently un-gate every
    metric it carried."""
    base = _serve_payload()
    dropped = dict(base['rows'][0], backend='reference')
    base['rows'].append(dropped)
    fresh = _serve_payload()   # only the pallas row survives
    violations, report = history.check_payloads('serve', base, fresh)
    assert len(violations) == 1 and 'MISSING' in violations[0]
    assert 'backend=reference' in violations[0]


def test_history_missing_row_allowlists():
    base = _serve_payload()
    dropped = dict(base['rows'][0], backend='reference')
    base['rows'].append(dropped)
    fresh = _serve_payload()
    # programmatic allowlist: identity-subset match clears the violation
    violations, report = history.check_payloads(
        'serve', base, fresh,
        allow_missing=({'backend': 'reference'},))
    assert violations == []
    assert any('allow_missing' in line for line in report)
    # RETIRED_ROWS: the committed allowlist works the same way
    old = history.RETIRED_ROWS['serve']
    history.RETIRED_ROWS['serve'] = ({'backend': 'reference'},)
    try:
        violations, report = history.check_payloads('serve', base, fresh)
    finally:
        history.RETIRED_ROWS['serve'] = old
    assert violations == []
    assert any('retired' in line for line in report)
    # a non-matching spec does NOT clear it
    violations, _ = history.check_payloads(
        'serve', base, fresh, allow_missing=({'backend': 'cuda'},))
    assert len(violations) == 1 and 'MISSING' in violations[0]


def test_history_quick_fresh_skips_full_only_rows():
    """A --quick fresh payload may legitimately miss rows the full run
    stamped ``quick_row: false`` — but quick-measured rows must still be
    present."""
    base = _serve_payload()
    full_only = dict(base['rows'][0], backend='reference',
                     quick_row=False)
    base['rows'][0]['quick_row'] = True
    base['rows'].append(full_only)
    fresh = _serve_payload()
    fresh['quick'] = True
    violations, report = history.check_payloads('serve', base, fresh)
    assert violations == []
    assert any('full-run-only' in line for line in report)
    # ...but dropping a quick-measured row still fails under --quick
    fresh['rows'] = []
    violations, _ = history.check_payloads('serve', base, fresh)
    assert any('MISSING' in line for line in violations)
    # and a full fresh payload gets no quick carve-out at all
    full_fresh = _serve_payload()
    full_fresh['rows'][0]['backend'] = 'reference'
    violations, _ = history.check_payloads('serve', base, full_fresh)
    assert any('MISSING' in line and 'backend=pallas' in line
               for line in violations)


def test_history_cli_check(tmp_path):
    base, fresh = tmp_path / 'base.json', tmp_path / 'fresh.json'
    base.write_text(json.dumps(_serve_payload()))
    fresh.write_text(json.dumps(_serve_payload()))
    argv = ['--check', '--suite', 'serve', '--fresh', str(fresh),
            '--baseline', str(base)]
    assert history.main(argv) == 0
    fresh.write_text(json.dumps(_serve_payload(overlap=0.0)))
    assert history.main(argv) == 1


# -------------------------------------------- telemetry satellites --------

def _summary(fps, frames, **extra):
    out = {'frames': frames, 'fps': fps, 'hit_rate': 0.8, 'p99_ms': 10.0}
    out.update(extra)
    return out


def test_aggregate_fleet_fps_is_frame_weighted():
    agg = aggregate([_summary(10.0, 2), _summary(100.0, 198)])
    assert agg['fleet_fps'] == pytest.approx(np.average([10.0, 100.0],
                                                        weights=[2, 198]))
    # the deprecated unweighted mean_fps field is gone for good
    assert 'mean_fps' not in agg
    # zero-frame / non-finite sessions cannot poison the fleet rate
    agg = aggregate([_summary(float('inf'), 0), _summary(50.0, 10)])
    assert agg['fleet_fps'] == pytest.approx(50.0)


def test_format_table_tolerates_heterogeneous_summaries():
    table = format_table([{'sid': 0, 'fps': 30.0},
                          {'sid': 1, 'fps': 25.0, 'host_ms': 1.5}])
    lines = table.splitlines()
    assert lines[0].split() == ['sid', 'fps', 'host_ms']
    assert len(lines) == 3
    assert lines[1].split() == ['0', '30']          # missing cell is blank
    assert lines[2].split() == ['1', '25', '1.5']


def _tick(tick, **extra):
    entry = {'tick': tick, 'frames': 2, 'sorted_slots': 1, 'sort_ms': 0.2,
             'shade_ms': 2.0}
    entry.update(extra)
    return entry


def test_tick_rollup_legacy_logs_omit_async_keys():
    roll = tick_rollup([_tick(0), _tick(1)])
    for key in ('p50_frame_ms', 'p95_frame_ms', 'host_ms', 'host_overlap'):
        assert key not in roll
    assert roll['ticks'] == 2 and roll['kernel_ms'] == {}


def test_tick_rollup_mixed_profiled_ticks():
    roll = tick_rollup([_tick(0, kernel_ms=None),
                        _tick(1, kernel_ms={'prep': 1.0, 'lookup': 3.0}),
                        _tick(2, kernel_ms={'prep': 3.0, 'lookup': 5.0})])
    assert roll['kernel_ms'] == {'prep': 2.0, 'lookup': 4.0}


def test_tick_rollup_warmup_slices_everything():
    roll = tick_rollup([_tick(0), _tick(1)], warmup_ticks=5)
    assert roll == {'ticks': 0, 'mean_sorts_per_tick': 0.0,
                    'max_sorts_per_tick': 0, 'mean_sort_ms': 0.0,
                    'mean_shade_ms': 0.0, 'kernel_ms': {}}


def test_tick_rollup_overlap_gt_one_warns_unclamped():
    """Satellite (b): overlap is a subset of host time, so ratio > 1 is an
    accounting bug — surfaced as a warning and an UNclamped value, not
    silently min()'d to 1.0."""
    log = [_tick(0, host_ms=1.0, overlap_ms=1.5),
           _tick(1, host_ms=1.0, overlap_ms=1.5)]
    with pytest.warns(RuntimeWarning, match='accounting bug'):
        roll = tick_rollup(log)
    assert roll['host_overlap'] == pytest.approx(1.5)
    # and the legitimate range stays warning-free
    with warnings.catch_warnings():
        warnings.simplefilter('error')
        roll = tick_rollup([_tick(0, host_ms=2.0, overlap_ms=1.0)])
    assert roll['host_overlap'] == pytest.approx(0.5)
