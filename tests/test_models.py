"""Model zoo: per-arch reduced smoke + serving-path consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_LM_ARCHS, get_config
from repro.data.tokens import synthetic_batch
from repro.models import registry
from repro.optim import adam


def _batch(cfg, b=2, s=32):
    batch = synthetic_batch(0, 0, b, s, cfg.vocab)
    if cfg.family == 'encdec':
        batch['frames'] = jnp.ones((b, s, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize('arch', ALL_LM_ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one train step on CPU — shapes + finite loss + the
    loss actually DECREASES over a few steps (gradients are real)."""
    cfg = get_config(arch).reduced()
    ctx = registry.make_ctx(None, cfg)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    step, acfg = registry.make_train_step(
        cfg, ctx, adam.AdamConfig(lr=3e-3, state_dtype=jnp.float32))
    opt = adam.init(params, acfg)
    batch = _batch(cfg)
    jstep = jax.jit(step)
    losses = []
    for _ in range(4):
        params, opt, m = jstep(params, opt, batch)
        losses.append(float(m['loss']))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize('arch', ALL_LM_ARCHS)
def test_arch_smoke_serve(arch):
    cfg = get_config(arch).reduced()
    ctx = registry.make_ctx(None, cfg)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    lg = jax.jit(registry.make_prefill(cfg, ctx))(params, {
        k: v for k, v in batch.items() if k != 'labels'})
    assert lg.shape[0] == b and np.isfinite(np.asarray(lg)).all()

    dstep = jax.jit(registry.make_decode_step(cfg, ctx))
    state = registry.init_decode_state(cfg, b, s)
    if cfg.family == 'encdec':
        from repro.models import whisper
        state['cross'] = whisper.prepare_cross(params, batch['frames'],
                                               cfg, ctx)
    lg2, state = dstep(params, jnp.ones((b, 1), jnp.int32), state,
                       jnp.int32(0))
    assert np.isfinite(np.asarray(lg2)).all()


@pytest.mark.parametrize('arch', ['smollm-360m', 'xlstm-1.3b',
                                  'zamba2-1.2b'])
def test_decode_matches_forward(arch):
    """Greedy decode continuation == teacher-forced forward logits.

    Feeds the same tokens (a) all at once through forward and (b) one at a
    time through decode_step; the last-position logits must agree.  This is
    the core correctness property of KV caching / recurrent decode state.
    """
    cfg = get_config(arch).reduced()
    ctx = registry.make_ctx(None, cfg)
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    mod = registry.module_for(cfg)
    b, s = 2, 12
    toks = synthetic_batch(0, 0, b, s, cfg.vocab)['tokens']

    from repro.models import layers as L
    h = mod.forward(params, toks, cfg, ctx)
    lg_fwd = L.logits(params['tok'], h[:, -1:], cfg, ctx)[:, 0]

    dstep = jax.jit(registry.make_decode_step(cfg, ctx))
    state = registry.init_decode_state(cfg, b, s + 4)
    lg = None
    for t in range(s):
        lg, state = dstep(params, toks[:, t:t + 1], state, jnp.int32(t))
    # decode attention keeps f32 probabilities; the training path's flash
    # uses bf16 PV (see layers.flash_attention), hence the loose tolerance
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_fwd),
                               atol=2e-2, rtol=2e-2)


def test_flash_attention_matches_naive():
    from repro.models.layers import flash_attention
    key = jax.random.PRNGKey(0)
    b, s, h, hd = 2, 64, 4, 16
    q, k, v = (jax.random.normal(kk, (b, s, h, hd))
               for kk in jax.random.split(key, 3))
    out = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=32)
    # naive reference
    sc = jnp.einsum('bqhd,bkhd->bhqk', q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None], sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    want = jnp.einsum('bhqk,bkhd->bqhd', w, v)
    # tolerance set by the bf16 PV matmul (the layout real flash kernels
    # use); stats (m, l) remain f32
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-2, rtol=2e-2)


def test_flash_attention_grad_finite():
    from repro.models.layers import flash_attention
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(kk, (1, 32, 2, 8))
               for kk in jax.random.split(key, 3))

    def loss(q):
        return flash_attention(q, k, v, causal=True, q_chunk=8,
                               kv_chunk=16).sum()
    g = jax.grad(loss)(q)
    assert np.isfinite(np.asarray(g)).all()


def test_gqa_repeat_kv_grouping():
    from repro.models.layers import repeat_kv
    k = jnp.arange(5, dtype=jnp.float32)[None, None, :, None]  # [1,1,5,1]
    out = repeat_kv(k, 16, n_heads=15)
    idx = np.asarray(out[0, 0, :, 0], np.int32)
    # real heads i in 0..14 -> kv i//3; padded head 15 -> clamped
    want = [i // 3 for i in range(15)] + [4]
    assert idx.tolist() == want


def test_chunked_linear_attention_matches_step():
    """Chunkwise-parallel core == sequential recurrence (mLSTM/Mamba2)."""
    from repro.models.linear_scan import (chunked_linear_attention,
                                          linear_attention_step)
    key = jax.random.PRNGKey(3)
    b, s, h, dk, dv = 2, 24, 2, 8, 8
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    for normalize in (False, True):
        y_par, st_par = chunked_linear_attention(q, k, v, log_a, chunk=8,
                                                 normalize=normalize)
        st = jnp.zeros((b, h, dk, dv + (1 if normalize else 0)), jnp.float32)
        ys = []
        for t in range(s):
            y_t, st = linear_attention_step(st, q[:, t], k[:, t], v[:, t],
                                            log_a[:, t], normalize=normalize)
            ys.append(y_t)
        y_seq = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                                   atol=2e-4, rtol=2e-3)
        np.testing.assert_allclose(np.asarray(st_par), np.asarray(st),
                                   atol=2e-4, rtol=2e-3)


def test_moe_dispatch_exact_vs_dense():
    """Sort-based dispatch == brute-force per-token expert sum (no drops)."""
    from repro.models import moe
    cfg = get_config('granite-moe-1b-a400m').reduced(
        n_experts=4, top_k=2, capacity_factor=8.0)  # capacity ample
    ctx = registry.make_ctx(None, cfg)
    key = jax.random.PRNGKey(0)
    p = moe.moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, drop = moe.moe_ffn(p, x, cfg, ctx)
    assert float(drop) == 0.0

    # dense reference: every token through its top-k experts
    xf = x.reshape(-1, cfg.d_model)
    weights, top_idx = moe._route(p['router'], xf, cfg.top_k)
    want = jnp.zeros_like(xf)
    for i in range(xf.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.top_k):
            e = int(top_idx[i, j])
            buck = xf[i][None, None]
            y = moe._expert_ffn(buck, p['w_up'][e][None], p['w_gate'][e][None],
                                p['w_down'][e][None], cfg)[0, 0]
            acc = acc + weights[i, j] * y
        want = want.at[i].set(acc)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(want), atol=1e-4, rtol=1e-3)
