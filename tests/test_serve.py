"""Multi-viewer serving: functional-core parity, session lifecycle, CLI."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.camera import stack_cameras
from repro.core.pipeline import (LuminaConfig, LuminSys, batched_render_step,
                                 init_viewer_state, render_step)
from repro.data.trajectory import orbit_trajectory
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper, SequentialStepper
from repro.serve.telemetry import SessionTelemetry, aggregate


CFG = LuminaConfig(capacity=256, window=3)


def _trajectories(n, frames):
    return [orbit_trajectory(frames, width=64, height_px=64,
                             start_deg=120.0 * i) for i in range(n)]


def test_render_step_matches_luminsys(small_scene, cams64):
    """The jitted functional step IS LuminSys: identical image stream."""
    import functools
    sys_ = LuminSys(small_scene, CFG, cams64[0])
    state = init_viewer_state(small_scene, CFG, cams64[0])
    step = jax.jit(functools.partial(render_step, cfg=CFG))
    for cam in cams64:
        img_w, st_w = sys_.step(cam)
        state, img_f, st_f = step(small_scene, state, cam)
        np.testing.assert_array_equal(np.asarray(img_w), np.asarray(img_f))
        assert float(st_w.hit_rate) == float(st_f.hit_rate)
    assert int(state.frame_idx) == len(cams64)


def test_batched_vmap_parity_with_sequential(small_scene):
    """N viewers stepped via one vmapped call match N independent LuminSys
    runs: every integer cache decision (tags, LRU age, clock, hit counts)
    is bitwise identical; images agree to float32 ulp (XLA's batched
    lowering reorders FMA contractions in the projection einsums, so exact
    bit equality across the two compiled programs is not attainable on CPU).
    """
    n, frames = 3, 5
    trajs = _trajectories(n, frames)
    refs = [LuminSys(small_scene, CFG, t[0]) for t in trajs]
    states = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[init_viewer_state(small_scene, CFG, t[0]) for t in trajs])
    step_b = jax.jit(
        lambda st, cm: batched_render_step(small_scene, st, cm, CFG))

    for f in range(frames):
        cams = stack_cameras([t[f] for t in trajs])
        states, images, stats = step_b(states, cams)
        for v in range(n):
            img_ref, st_ref = refs[v].step(trajs[v][f])
            np.testing.assert_allclose(
                np.asarray(images[v]), np.asarray(img_ref), atol=1e-5,
                err_msg=f'viewer {v} frame {f}')
            assert float(stats.hit_rate[v]) == pytest.approx(
                float(st_ref.hit_rate), abs=1e-6)
            assert float(stats.sorted_this_frame[v]) == float(
                st_ref.sorted_this_frame)

    for v in range(n):
        cache_b = jax.tree.map(lambda x: x[v], states.cache)
        cache_s = refs[v].state.cache
        np.testing.assert_array_equal(np.asarray(cache_b.tags),
                                      np.asarray(cache_s.tags))
        np.testing.assert_array_equal(np.asarray(cache_b.age),
                                      np.asarray(cache_s.age))
        np.testing.assert_array_equal(np.asarray(cache_b.clock),
                                      np.asarray(cache_s.clock))
        np.testing.assert_allclose(np.asarray(cache_b.values),
                                   np.asarray(cache_s.values), atol=1e-5)


def test_batched_and_sequential_steppers_agree(small_scene):
    """The two serve engines produce the same per-session hit statistics."""
    trajs = _trajectories(2, 4)
    results = {}
    for engine in (BatchedStepper, SequentialStepper):
        stepper = engine(small_scene, CFG, trajs[0][0], slots=2)
        mgr = SessionManager(stepper, slots=2)
        for sid, t in enumerate(trajs):
            mgr.submit(ViewerSession(sid=sid, cams=t))
        finished = mgr.run()
        results[engine.__name__] = {
            s.sid: s.telemetry.hit_rates for s in finished}
    for sid in (0, 1):
        np.testing.assert_allclose(results['BatchedStepper'][sid],
                                   results['SequentialStepper'][sid],
                                   atol=1e-6)


def test_session_manager_admit_evict_lifecycle(small_scene):
    """More viewers than slots: arrivals queue, slots are reused, everyone
    finishes with exactly their trajectory's frame count."""
    trajs = _trajectories(4, 3)
    stepper = BatchedStepper(small_scene, CFG, trajs[0][0], slots=2)
    mgr = SessionManager(stepper, slots=2)
    for sid, t in enumerate(trajs):
        mgr.submit(ViewerSession(sid=sid, cams=t, arrival_tick=sid))

    # tick 0: only viewer 0 has arrived
    mgr.run_tick()
    assert len(mgr.active_slots()) == 1
    # tick 1: viewer 1 arrives -> both slots busy, viewers 2/3 must queue
    mgr.run_tick()
    assert len(mgr.active_slots()) == 2
    assert len(mgr.pending) == 2

    finished = mgr.run()
    assert sorted(s.sid for s in finished) == [0, 1, 2, 3]
    for s in finished:
        assert s.telemetry.frames == 3
        assert s.telemetry.admitted_tick >= s.arrival_tick
    # late viewers could not be admitted on arrival: they queued for a slot
    late = [s for s in finished if s.sid >= 2]
    assert all(s.telemetry.summary()['queue_ticks'] > 0 for s in late)
    # slots were reused across sessions
    assert mgr.drained() and mgr.tick < 20


def test_telemetry_summary():
    t = SessionTelemetry(sid=7, arrival_tick=1)
    t.admitted_tick = 3
    for i in range(10):
        t.observe_frame(latency_s=0.01 * (i + 1), hit_rate=0.5,
                        saved_frac=0.25, sorted_flag=float(i % 3 == 0))
    s = t.summary()
    assert s['sid'] == 7 and s['frames'] == 10
    assert s['queue_ticks'] == 2
    assert s['hit_rate'] == pytest.approx(0.5)
    assert s['sorts_per_frame'] == pytest.approx(0.4)
    assert 0 < s['p50_ms'] < s['p99_ms'] <= 100.0
    agg = aggregate([s])
    assert agg['sessions'] == 1 and agg['frames'] == 10


def test_serve_cli_smoke(capsys):
    from repro.serve import render as serve_render
    serve_render.main(['--viewers', '2', '--frames', '3', '--width', '64',
                       '--gaussians', '600', '--capacity', '128'])
    out = capsys.readouterr().out
    assert 'hit_rate' in out and 'batched: 2 sessions' in out
