"""Multi-viewer serving: two-phase core parity, cohort scheduling, session
lifecycle, donation hygiene, CLI."""
import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.camera import stack_cameras
from repro.core.pipeline import (LuminaConfig, LuminSys, ViewerState,
                                 batched_render_step, init_viewer_state,
                                 render_step, shade_phase, sort_phase)
from repro.data.trajectory import orbit_trajectory
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper, SequentialStepper
from repro.serve.telemetry import SessionTelemetry, aggregate, tick_rollup


CFG = LuminaConfig(capacity=256, window=3)


def _trajectories(n, frames):
    return [orbit_trajectory(frames, width=64, height_px=64,
                             start_deg=120.0 * i) for i in range(n)]


def assert_images_ulp_close(got, want, *, ulps=128, err_msg=''):
    """Image comparison with an explicitly ulp-scaled float32 tolerance.

    Why not exact equality: the batched (vmapped) and sequential paths
    compile to *different* XLA programs, and on CPU the batched lowering
    reorders/contracts FMAs in the projection einsums and the rasterizer's
    weighted color sums.  Every integer decision (cache tags, hit masks,
    sort orders) is asserted bitwise elsewhere; the images legitimately
    differ by a few ulps of the accumulated magnitude, so the bound is
    ``ulps`` x float32-eps x magnitude (floored at 1.0, the compositing
    scale) instead of an ad-hoc atol.
    """
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    scale = np.maximum(np.maximum(np.abs(got), np.abs(want)), 1.0)
    tol = np.float32(ulps) * np.finfo(np.float32).eps * scale
    err = np.abs(got - want)
    worst = float((err / (np.finfo(np.float32).eps * scale)).max()) \
        if err.size else 0.0
    assert (err <= tol).all(), (
        f'{err_msg}: images differ by {worst:.0f} ulps (> {ulps} allowed)')


def test_render_step_matches_luminsys(small_scene, cams64):
    """The jitted functional step IS LuminSys: identical image stream."""
    sys_ = LuminSys(small_scene, CFG, cams64[0])
    state = init_viewer_state(small_scene, CFG, cams64[0])
    step = jax.jit(functools.partial(render_step, cfg=CFG))
    for cam in cams64:
        img_w, st_w = sys_.step(cam)
        state, img_f, st_f = step(small_scene, state, cam)
        np.testing.assert_array_equal(np.asarray(img_w), np.asarray(img_f))
        assert float(st_w.hit_rate) == float(st_f.hit_rate)
    assert int(state.frame_idx) == len(cams64)


def test_two_phase_composition_matches_render_step(small_scene, cams64):
    """Manually scheduling sort_phase + shade_phase at the per-viewer cadence
    reproduces the monolithic render_step stream: the split is a pure
    refactor, the schedule is the only new degree of freedom."""
    state_m = init_viewer_state(small_scene, CFG, cams64[0])
    state_p = init_viewer_state(small_scene, CFG, cams64[0])
    step = jax.jit(functools.partial(render_step, cfg=CFG))
    sortp = jax.jit(functools.partial(sort_phase, cfg=CFG))
    shadep = jax.jit(functools.partial(shade_phase, cfg=CFG))
    for f, cam in enumerate(cams64):
        state_m, img_m, st_m = step(small_scene, state_m, cam)
        shared, priv = state_p.scene_shared, state_p.viewer
        if f % CFG.window == 0:
            shared = sortp(small_scene, shared, priv, cam)
        shared, priv, img_p, st_p = shadep(small_scene, shared, priv, cam)
        state_p = ViewerState(scene_shared=shared, viewer=priv)
        np.testing.assert_allclose(np.asarray(img_m), np.asarray(img_p),
                                   atol=1e-6, err_msg=f'frame {f}')
        assert float(st_m.hit_rate) == pytest.approx(float(st_p.hit_rate),
                                                     abs=1e-6)
    np.testing.assert_array_equal(np.asarray(state_m.cache.tags),
                                  np.asarray(state_p.cache.tags))


def test_batched_vmap_parity_with_sequential(small_scene):
    """N viewers stepped via one vmapped call match N independent LuminSys
    runs: every integer cache decision (tags, LRU age, clock, hit counts)
    is bitwise identical; images agree to float32 ulp (XLA's batched
    lowering reorders FMA contractions in the projection einsums, so exact
    bit equality across the two compiled programs is not attainable on CPU).
    """
    n, frames = 3, 5
    trajs = _trajectories(n, frames)
    refs = [LuminSys(small_scene, CFG, t[0]) for t in trajs]
    states = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[init_viewer_state(small_scene, CFG, t[0]) for t in trajs])
    step_b = jax.jit(
        lambda st, cm: batched_render_step(small_scene, st, cm, CFG))

    for f in range(frames):
        cams = stack_cameras([t[f] for t in trajs])
        states, images, stats = step_b(states, cams)
        for v in range(n):
            img_ref, st_ref = refs[v].step(trajs[v][f])
            assert_images_ulp_close(images[v], img_ref,
                                    err_msg=f'viewer {v} frame {f}')
            assert float(stats.hit_rate[v]) == pytest.approx(
                float(st_ref.hit_rate), abs=1e-6)
            assert float(stats.sorted_this_frame[v]) == float(
                st_ref.sorted_this_frame)

    for v in range(n):
        cache_b = jax.tree.map(lambda x: x[v], states.cache)
        cache_s = refs[v].state.cache
        np.testing.assert_array_equal(np.asarray(cache_b.tags),
                                      np.asarray(cache_s.tags))
        np.testing.assert_array_equal(np.asarray(cache_b.age),
                                      np.asarray(cache_s.age))
        np.testing.assert_array_equal(np.asarray(cache_b.clock),
                                      np.asarray(cache_s.clock))
        np.testing.assert_allclose(np.asarray(cache_b.values),
                                   np.asarray(cache_s.values), atol=1e-5)


def test_cohort_single_viewer_matches_sequential(small_scene):
    """Satellite (a): for one viewer in slot 0 admitted at tick 0, the cohort
    cadence coincides with the per-viewer cadence — the cohort-scheduled
    batched engine and the sequential reference agree on every sort
    decision, every integer cache decision and the images."""
    traj = orbit_trajectory(2 * CFG.window + 1, width=64, height_px=64)
    bat = BatchedStepper(small_scene, CFG, traj[0], slots=1)
    seq = SequentialStepper(small_scene, CFG, traj[0], slots=1)
    bat.admit(0)
    seq.admit(0)
    for f, cam in enumerate(traj):
        img_b, st_b, _ = bat.step({0: cam})[0]
        img_s, st_s, _ = seq.step({0: cam})[0]
        assert float(st_b.sorted_this_frame) == float(st_s.sorted_this_frame)
        assert_images_ulp_close(img_b, img_s, err_msg=f'frame {f}')
        assert float(st_b.hit_rate) == pytest.approx(float(st_s.hit_rate),
                                                     abs=1e-6)
    cache_b = jax.tree.map(lambda x: x[0], bat.shared.cache)
    cache_s = seq._states[0].cache
    for field in ('tags', 'age', 'clock'):
        np.testing.assert_array_equal(np.asarray(getattr(cache_b, field)),
                                      np.asarray(getattr(cache_s, field)))


def test_cohort_multi_viewer_matches_replayed_cadence(small_scene):
    """Multi-slot cohort gather/scatter parity: the batched engine equals an
    oracle that replays the exact cohort schedule (sort-on-admit at tick 0,
    then slot i sorts when tick % window == i % window) through the
    single-viewer phases.  3 slots with window 2 makes the scheduled cohort
    alternate between a full gather (slots 0,2) and a padded one (slot 1),
    so both the duplicate-index padding and the mode='drop' scatter are on
    the line."""
    cfg = LuminaConfig(capacity=256, window=2)
    s, frames = 3, 5
    trajs = _trajectories(s, frames)
    bat = BatchedStepper(small_scene, cfg, trajs[0][0], slots=s)
    for i in range(s):
        bat.admit(i)

    sortp = jax.jit(functools.partial(sort_phase, cfg=cfg))
    shadep = jax.jit(functools.partial(shade_phase, cfg=cfg))
    oracle = [init_viewer_state(small_scene, cfg, t[0]) for t in trajs]

    for tick in range(frames):
        out = bat.step({i: trajs[i][tick] for i in range(s)})
        for i in range(s):
            cam = trajs[i][tick]
            shared_o, priv_o = oracle[i].scene_shared, oracle[i].viewer
            if tick == 0 or tick % cfg.window == i % cfg.window:
                shared_o = sortp(small_scene, shared_o, priv_o, cam)
                expect_sorted = 1.0
            else:
                expect_sorted = 0.0
            shared_o, priv_o, img_o, st_o = shadep(small_scene, shared_o,
                                                   priv_o, cam)
            oracle[i] = ViewerState(scene_shared=shared_o, viewer=priv_o)
            img_b, st_b, _ = out[i]
            assert float(st_b.sorted_this_frame) == expect_sorted, \
                f'slot {i} tick {tick}'
            assert_images_ulp_close(img_b, img_o,
                                    err_msg=f'slot {i} tick {tick}')
            assert float(st_b.hit_rate) == pytest.approx(float(st_o.hit_rate),
                                                         abs=1e-6)
    for i in range(s):
        cache_b = jax.tree.map(lambda x: x[i], bat.shared.cache)
        for field in ('tags', 'age', 'clock'):
            np.testing.assert_array_equal(
                np.asarray(getattr(cache_b, field)),
                np.asarray(getattr(oracle[i].cache, field)),
                err_msg=f'slot {i} {field}')


def test_cohort_sort_bound_after_warmup(small_scene):
    """Satellite (b): with S viewers at steady state, at most ceil(S/window)
    slots run a speculative sort on any tick — the whole point of the cohort
    scheduler (the old per-lane cond sorted all S lanes every tick)."""
    s, frames = 5, 8
    cfg = LuminaConfig(capacity=256, window=3)
    trajs = _trajectories(s, frames)
    stepper = BatchedStepper(small_scene, cfg, trajs[0][0], slots=s)
    mgr = SessionManager(stepper, slots=s)
    for sid, t in enumerate(trajs):
        mgr.submit(ViewerSession(sid=sid, cams=t))
    mgr.run()
    bound = -(-s // cfg.window)
    # tick 0 carries the sort-on-admit burst (outside the scheduled cohort)
    steady = stepper.sort_log[1:]
    assert steady, 'run too short to observe steady state'
    assert all(e['admit'] == 0 for e in steady)
    assert max(e['scheduled'] for e in steady) <= bound
    # and the realised cadence amortizes to 1/window per viewer
    total_sorts = sum(e['scheduled'] + e['admit'] for e in stepper.sort_log)
    assert total_sorts <= s * (1 + frames / cfg.window)
    roll = tick_rollup(mgr.tick_log, warmup_ticks=1)
    assert roll['max_sorts_per_tick'] <= bound


def test_sort_on_admit_mid_flight(small_scene):
    """Satellite (c): a viewer admitted mid-flight (slot reuse) sorts on
    admit and its first frame matches a cold-start single-viewer render —
    no stale SortShared, no stale radiance cache."""
    trajs = _trajectories(3, 4)
    stepper = BatchedStepper(small_scene, CFG, trajs[0][0], slots=2)
    stepper.admit(0)
    stepper.admit(1)
    for f in range(3):
        stepper.step({0: trajs[0][f], 1: trajs[1][f]})
    # viewer 2 takes slot 0 mid-flight, off the shared sort cadence
    stepper.admit(0)
    out = stepper.step({0: trajs[2][0], 1: trajs[1][3]})
    img, st, timing = out[0]
    assert float(st.sorted_this_frame) == 1.0
    assert timing.sorted_slots >= 1
    ref = LuminSys(small_scene, CFG, trajs[2][0])
    img_ref, st_ref = ref.step(trajs[2][0])
    assert_images_ulp_close(img, img_ref, err_msg='sort-on-admit frame')
    assert float(st.hit_rate) == pytest.approx(float(st_ref.hit_rate),
                                               abs=1e-6)


def test_steppers_no_donation_warnings(small_scene):
    """Both engines donate their ViewerState buffers into the jitted calls;
    a 'donated buffer' warning means the donation silently degraded back to
    a full per-tick state copy."""
    trajs = _trajectories(2, 4)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter('always')
        for engine in (BatchedStepper, SequentialStepper):
            stepper = engine(small_scene, CFG, trajs[0][0], slots=2)
            mgr = SessionManager(stepper, slots=2)
            for sid, t in enumerate(trajs):
                mgr.submit(ViewerSession(sid=sid, cams=t))
            mgr.run()
    donated = [w for w in caught if 'donat' in str(w.message).lower()]
    assert not donated, [str(w.message) for w in donated]


def test_session_manager_admit_evict_lifecycle(small_scene):
    """More viewers than slots: arrivals queue, slots are reused, everyone
    finishes with exactly their trajectory's frame count."""
    trajs = _trajectories(4, 3)
    stepper = BatchedStepper(small_scene, CFG, trajs[0][0], slots=2)
    mgr = SessionManager(stepper, slots=2)
    for sid, t in enumerate(trajs):
        mgr.submit(ViewerSession(sid=sid, cams=t, arrival_tick=sid))

    # tick 0: only viewer 0 has arrived
    mgr.run_tick()
    assert len(mgr.active_slots()) == 1
    # tick 1: viewer 1 arrives -> both slots busy, viewers 2/3 must queue
    mgr.run_tick()
    assert len(mgr.active_slots()) == 2
    assert len(mgr.pending) == 2

    finished = mgr.run()
    assert sorted(s.sid for s in finished) == [0, 1, 2, 3]
    for s in finished:
        assert s.telemetry.frames == 3
        assert s.telemetry.admitted_tick >= s.arrival_tick
        # every session's first frame rode a sort (scheduled or on-admit)
        assert s.telemetry.sorted_flags[0] == 1.0
    # late viewers could not be admitted on arrival: they queued for a slot
    late = [s for s in finished if s.sid >= 2]
    assert all(s.telemetry.summary()['queue_ticks'] > 0 for s in late)
    # slots were reused across sessions
    assert mgr.drained() and mgr.tick < 20
    # the manager kept per-tick phase attribution for every rendered tick
    assert mgr.tick_log and all(
        t['sort_ms'] >= 0.0 and t['shade_ms'] > 0.0 for t in mgr.tick_log)


def test_telemetry_summary():
    t = SessionTelemetry(sid=7, arrival_tick=1)
    t.admitted_tick = 3
    for i in range(10):
        t.observe_frame(latency_s=0.01 * (i + 1), hit_rate=0.5,
                        saved_frac=0.25, sorted_flag=float(i % 3 == 0),
                        sort_ms=2.0, shade_ms=8.0)
    s = t.summary()
    assert s['sid'] == 7 and s['frames'] == 10
    assert s['queue_ticks'] == 2
    assert s['hit_rate'] == pytest.approx(0.5)
    assert s['sorts_per_frame'] == pytest.approx(0.4)
    assert s['sort_ms'] == pytest.approx(2.0)
    assert s['shade_ms'] == pytest.approx(8.0)
    assert 0 < s['p50_ms'] < s['p99_ms'] <= 100.0
    agg = aggregate([s])
    assert agg['sessions'] == 1 and agg['frames'] == 10
    assert agg['mean_sort_ms'] == pytest.approx(2.0)
    assert agg['mean_shade_ms'] == pytest.approx(8.0)


def test_tick_rollup():
    log = [{'tick': 0, 'frames': 4, 'sorted_slots': 4, 'sort_ms': 9.0,
            'shade_ms': 20.0},
           {'tick': 1, 'frames': 4, 'sorted_slots': 1, 'sort_ms': 2.0,
            'shade_ms': 10.0},
           {'tick': 2, 'frames': 4, 'sorted_slots': 2, 'sort_ms': 4.0,
            'shade_ms': 12.0}]
    roll = tick_rollup(log, warmup_ticks=1)
    assert roll['ticks'] == 2
    assert roll['max_sorts_per_tick'] == 2
    assert roll['mean_sorts_per_tick'] == pytest.approx(1.5)
    assert roll['mean_sort_ms'] == pytest.approx(3.0)
    assert roll['mean_shade_ms'] == pytest.approx(11.0)


def test_serve_cli_smoke(capsys):
    from repro.serve import render as serve_render
    serve_render.main(['--viewers', '2', '--frames', '3', '--width', '64',
                       '--gaussians', '600', '--capacity', '128'])
    out = capsys.readouterr().out
    assert 'hit_rate' in out and 'batched (reference): 2 sessions' in out
    assert 'sort_ms' in out and 'sorts/tick' in out


def test_serve_cli_pallas_backend_with_profile(capsys):
    """--backend pallas serves end-to-end and the sampled per-kernel
    breakdown (prep/prefix/lookup/resume/insert) reaches the rollup."""
    from repro.serve import render as serve_render
    serve_render.main(['--viewers', '2', '--frames', '4', '--width', '64',
                       '--gaussians', '600', '--capacity', '128',
                       '--stagger', '0', '--backend', 'pallas',
                       '--profile-every', '2'])
    out = capsys.readouterr().out
    assert 'batched (pallas): 2 sessions' in out
    assert 'shade kernels (ms/tick, sampled):' in out
    for stage in ('prep', 'prefix', 'lookup', 'resume', 'insert'):
        assert stage in out
