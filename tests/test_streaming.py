"""Streaming scene residency (``repro.serve.streaming`` + the chunked
scene container in ``repro.data.scenes``).

The contracts, in dependency order:

* **Exact partition** — ``structured_scene`` produces exactly the
  requested Gaussian count for any ``num_gaussians`` (the partitioner
  relies on exact counts), and ``partition_scene`` covers every source
  Gaussian exactly once, cell-tags every chunk correctly, orders each
  chunk significance-descending and pads with neutral lanes —
  deterministically.
* **LOD algebra** — ``level_rows`` maps FULL to the fill, LOD to the
  non-empty significance prefix, ABSENT to zero; ``masked_scene`` at full
  rows is the identity on real lanes.
* **Bit-identity** — a budget-constrained streaming run whose arena covers
  the live working set renders **bit-identically** to the unbounded
  (fully-resident-arena) streaming run, with zero stalls and a resident
  footprint strictly below the full scene; with the radiance cache off the
  streamed (chunk-permuted) scene also matches the plain non-streaming
  stepper exactly (the pure render is permutation+neutral-pad invariant).
* **Determinism** — two SyncDriver replays of the same traffic produce
  identical frames AND identical stream counters (loads, prefetch hits,
  stalls, evictions): residency planning is a pure function of the
  replayed schedule.
* **Degraded, not dead** — when the union working set exceeds the arena
  the epoch-rotated capacity reservation timeshares the arena (every
  viewer drains, evictions reclaim frames); a single viewer whose own
  requirement cannot fit raises a configuration error instead of stalling
  forever.
* **Crash-consistent residency** — checkpoint/restore at a partially
  resident state resumes bit-identically to the uninterrupted run,
  including the loads that happen after the restore point.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.pipeline import LuminaConfig
from repro.data.scenes import (BYTES_PER_GAUSSIAN, LEVEL_ABSENT, LEVEL_FULL,
                               LEVEL_LOD, level_rows, masked_scene,
                               neutral_scene, partition_scene,
                               structured_scene)
from repro.data.trajectory import orbit_trajectory
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper
from repro.serve.streaming import ResidencyManager

WIDTH = 64
CELL = 0.4
CAP = 64
FRAME_BYTES = CAP * BYTES_PER_GAUSSIAN


@pytest.fixture(scope='module')
def scene600():
    return structured_scene(jax.random.PRNGKey(0), 600)


def _mgr(scene, budget_frames=None, **kw):
    ch = partition_scene(scene, cell_size=CELL, chunk_cap=CAP)
    budget = None if budget_frames is None else budget_frames * FRAME_BYTES
    kw.setdefault('near_radius', 3)
    kw.setdefault('lod_radius', 5)
    return ResidencyManager(ch, budget_bytes=budget, **kw)


def _serve(scene, streaming, *, viewers=2, frames=6, deg_step=40.0,
           cfg=None, max_ticks=300, kill_at=None, ckpt=None):
    """Drive a streaming serving run under the SyncDriver; returns
    ``(session_manager, stepper, {(sid, cursor): frame})``."""
    cfg = cfg or LuminaConfig(capacity=192, window=3)
    cam0 = orbit_trajectory(1, width=WIDTH, height_px=WIDTH)[0]
    stepper = BatchedStepper(scene, cfg, cam0, viewers, streaming=streaming)
    sm = SessionManager(stepper, viewers)
    if ckpt is not None:
        sm.enable_checkpoints(ckpt, every=3)
    for sid in range(viewers):
        traj = orbit_trajectory(frames, width=WIDTH, height_px=WIDTH,
                                start_deg=deg_step * sid)
        sm.submit(ViewerSession(sid=sid, cams=traj, arrival_tick=sid))
    outs = {}
    orig = sm.observe_tick

    def observing(plan, outputs, *a, **k):
        for slot, out in outputs.items():
            sess = sm.slot_session[slot]
            if sess is not None:
                outs[(sess.sid, sess.cursor)] = np.asarray(out[0])
        return orig(plan, outputs, *a, **k)

    sm.observe_tick = observing
    t = 0
    while not sm.drained() and t < max_ticks:
        sm.run_tick()
        sm.evict_finished()
        if ckpt is not None:
            sm.maybe_checkpoint()
        t += 1
        if kill_at is not None and sm.tick >= kill_at:
            break
    return sm, stepper, outs


# ------------------------------------------------- exact partition -------

def test_structured_scene_exact_split():
    """The three-surface split is exact for ANY count — the partitioner
    (and BYTES_PER_GAUSSIAN accounting) relies on it."""
    for n in (1, 2, 3, 7, 100, 599, 1201):
        s = structured_scene(jax.random.PRNGKey(1), n)
        assert s.means.shape == (n, 3)
        for field in ('log_scales', 'quats', 'opacity_logit', 'sh_dc',
                      'sh_rest'):
            assert getattr(s, field).shape[0] == n, (field, n)


def test_partition_exact_cover_and_order(scene600):
    ch = partition_scene(scene600, cell_size=CELL, chunk_cap=CAP)
    host = jax.tree.map(np.asarray, scene600)
    assert ch.source_count == 600
    assert int(ch.fill.sum()) == 600
    assert ch.scene_bytes == 600 * BYTES_PER_GAUSSIAN
    # every real packed lane is a source Gaussian; match by means row
    src = {tuple(np.round(m, 5)) for m in host.means}
    seen = 0
    sig_all = (1.0 / (1.0 + np.exp(-host.opacity_logit.astype(np.float64)))
               * np.exp(host.log_scales.astype(np.float64).mean(axis=-1)))
    by_mean = {tuple(np.round(m, 5)): s
               for m, s in zip(host.means, sig_all)}
    for c in range(ch.num_chunks):
        fill = int(ch.fill[c])
        lo = c * CAP
        block = ch.packed.means[lo:lo + CAP]
        sigs = []
        for j in range(CAP):
            key = tuple(np.round(block[j], 5))
            if j < fill:
                assert key in src, f'chunk {c} lane {j} not a source row'
                # cell tag matches the Gaussian's quantized position
                cell = np.floor(block[j] / CELL).astype(np.int64)
                np.testing.assert_array_equal(cell, ch.cells[c])
                sigs.append(by_mean[key])
                seen += 1
            else:
                assert block[j][0] > 1e5, 'padding must be neutral'
        assert sigs == sorted(sigs, reverse=True), (
            f'chunk {c} not significance-descending')
    assert seen == 600, 'partition must cover every source Gaussian once'
    # determinism: same scene, same partition, bit for bit
    ch2 = partition_scene(scene600, cell_size=CELL, chunk_cap=CAP)
    np.testing.assert_array_equal(ch.cells, ch2.cells)
    np.testing.assert_array_equal(ch.fill, ch2.fill)
    for a, b in zip(jax.tree.leaves(ch.packed), jax.tree.leaves(ch2.packed)):
        np.testing.assert_array_equal(a, b)


def test_level_rows_and_masked_scene(scene600):
    ch = partition_scene(scene600, cell_size=CELL, chunk_cap=CAP)
    n = ch.num_chunks
    full = level_rows(ch, np.full((n,), LEVEL_FULL), 0.5)
    np.testing.assert_array_equal(full, ch.fill)
    lod = level_rows(ch, np.full((n,), LEVEL_LOD), 0.5)
    assert (lod[ch.fill > 0] >= 1).all(), 'LOD prefix never empty'
    assert (lod <= ch.fill).all()
    np.testing.assert_array_equal(
        level_rows(ch, np.full((n,), LEVEL_ABSENT), 0.5), np.zeros((n,)))
    # full mask is the identity on real lanes; zero mask is all-neutral
    ident = masked_scene(ch.packed, full, CAP)
    np.testing.assert_array_equal(np.asarray(ident.means), ch.packed.means)
    nothing = masked_scene(ch.packed, np.zeros((n,), np.int64), CAP)
    neutral = neutral_scene(n * CAP)
    np.testing.assert_array_equal(np.asarray(nothing.means), neutral.means)
    np.testing.assert_array_equal(np.asarray(nothing.opacity_logit),
                                  neutral.opacity_logit)


# ---------------------------------------------- residency management -----

def test_arena_too_small_raises(scene600):
    mgr = _mgr(scene600, budget_frames=2)
    cam = orbit_trajectory(1, width=WIDTH, height_px=WIDTH)[0]
    with pytest.raises(RuntimeError, match='arena too small'):
        mgr.plan(0, {0: cam})


def test_budget_bit_identity_and_counters(scene600):
    """The acceptance contract: a budget covering the live working set
    renders bit-identically to the unbounded arena, without stalls, on a
    resident footprint strictly below the full scene."""
    runs = {}
    for name, frames_budget in (('lim', 63), ('full', None)):
        mgr = _mgr(scene600, budget_frames=frames_budget)
        sm, stepper, outs = _serve(scene600, mgr)
        assert sm.drained()
        runs[name] = (mgr, outs)
    lim_mgr, lim = runs['lim'][0], runs['lim'][1]
    full_mgr, full = runs['full'][0], runs['full'][1]
    assert set(lim) == set(full) and lim, 'frame sets must match'
    for key in lim:
        np.testing.assert_array_equal(lim[key], full[key],
                                      err_msg=f'frame {key} diverged')
    counters = lim_mgr.counters()
    assert counters['stalls'] == 0
    assert counters['prefetch_hits'] > 0, 'neighbor prefetch never warmed'
    assert lim_mgr.arena_slots < full_mgr.arena_slots
    assert lim_mgr.resident_bytes < lim_mgr.chunked.scene_bytes
    assert lim_mgr.resident_bytes > 0


def test_streaming_matches_plain_stepper_pure_render(scene600):
    """With the radiance cache off the render is a pure function of the
    effective Gaussian set — chunk permutation and neutral padding must
    not change a single bit vs the non-streaming stepper.  Every cell sits
    inside the near radius (no LOD trim), so the streamed content equals
    the plain scene exactly."""
    cfg = LuminaConfig(capacity=192, window=3, use_rc=False)
    _, _, plain = _serve(scene600, None, cfg=cfg)
    _, _, streamed = _serve(
        scene600, _mgr(scene600, near_radius=10 ** 6, lod_radius=10 ** 6),
        cfg=cfg)
    assert set(plain) == set(streamed) and plain
    for key in plain:
        np.testing.assert_array_equal(plain[key], streamed[key],
                                      err_msg=f'frame {key} diverged')


def test_replay_determinism_including_prefetch_hits(scene600):
    """Two SyncDriver replays of the same traffic: identical frames and
    identical stream counters — residency planning (prefetch included) is
    a pure function of the replayed schedule."""
    results = []
    for _ in range(2):
        mgr = _mgr(scene600, budget_frames=63)
        sm, _, outs = _serve(scene600, mgr)
        assert sm.drained()
        results.append((mgr.counters(), sm.tick, outs))
    (c1, t1, o1), (c2, t2, o2) = results
    assert c1 == c2, f'stream counters diverged: {c1} vs {c2}'
    assert c1['prefetch_hits'] > 0
    assert t1 == t2
    assert set(o1) == set(o2)
    for key in o1:
        np.testing.assert_array_equal(o1[key], o2[key])


def test_timeshare_drains_oversized_union(scene600):
    """Three viewers whose union working set exceeds the arena: the
    epoch-rotated reservation timeshares the arena — every viewer drains
    (degraded by stalls, reclaimed by evictions), nobody livelocks."""
    mgr = _mgr(scene600, budget_frames=70)
    sm, _, outs = _serve(scene600, mgr, viewers=3, frames=6,
                         deg_step=120.0, max_ticks=400)
    assert sm.drained(), 'timeshare must drain an oversized fleet'
    for sid in range(3):
        assert sum(1 for k in outs if k[0] == sid) == 6, (
            f'viewer {sid} missing frames')
    counters = mgr.counters()
    assert counters['stalls'] > 0, 'an oversized union must stall'
    assert counters['evictions'] > 0, 'timeshare must reclaim frames'


def test_checkpoint_roundtrip_partial_residency(scene600, tmp_path):
    """Kill/restore with the arena only partially resident: the restored
    run must resume bit-identically, including the chunk loads that only
    happen after the restore point (the late viewer's working set)."""
    frames = 6
    # a trickle load budget keeps the prefetch ring streaming across many
    # ticks, so the kill point genuinely lands mid-stream
    kw = dict(budget_frames=63, max_loads_per_tick=4)

    # golden: uninterrupted run
    mgr_g = _mgr(scene600, **kw)
    _, _, golden = _serve(scene600, mgr_g, frames=frames)

    # victim: checkpoint every 3 ticks, die mid-run
    mgr_v = _mgr(scene600, **kw)
    sm_v, _, _ = _serve(scene600, mgr_v, frames=frames,
                        ckpt=CheckpointManager(tmp_path, keep=5), kill_at=4)
    assert not sm_v.drained(), 'kill point must land mid-run'
    sm_v._ckpt.wait()

    # survivor: fresh stepper + fresh residency manager, restore, finish
    cfg = LuminaConfig(capacity=192, window=3)
    cam0 = orbit_trajectory(1, width=WIDTH, height_px=WIDTH)[0]
    mgr_s = _mgr(scene600, **kw)
    stepper2 = BatchedStepper(scene600, cfg, cam0, 2, streaming=mgr_s)
    sm2 = SessionManager(stepper2, 2)
    sessions = [ViewerSession(
        sid=sid, cams=orbit_trajectory(frames, width=WIDTH, height_px=WIDTH,
                                       start_deg=40.0 * sid),
        arrival_tick=sid) for sid in range(2)]
    restored = sm2.restore_serving(CheckpointManager(tmp_path), sessions)
    assert restored == 3
    # the snapshot must be PARTIALLY resident (that is the point)
    loaded = (mgr_s._loaded > 0).sum()
    assert 0 < loaded < mgr_s.chunked.num_chunks
    c0 = mgr_s.counters()
    loads_at_restore = c0['loads'] + c0['prefetch']

    outs = {}
    orig = sm2.observe_tick

    def observing(plan, outputs, *a, **k):
        for slot, out in outputs.items():
            sess = sm2.slot_session[slot]
            if sess is not None:
                outs[(sess.sid, sess.cursor)] = np.asarray(out[0])
        return orig(plan, outputs, *a, **k)

    sm2.observe_tick = observing
    t = 0
    while not sm2.drained() and t < 300:
        sm2.run_tick()
        sm2.evict_finished()
        t += 1
    assert sm2.drained()
    c1 = mgr_s.counters()
    assert c1['loads'] + c1['prefetch'] > loads_at_restore, (
        'continuation must stream in the not-yet-resident chunks')
    # every post-restore frame matches the uninterrupted run bit for bit
    assert outs, 'restored run rendered nothing'
    for key, img in outs.items():
        np.testing.assert_array_equal(img, golden[key],
                                      err_msg=f'frame {key} diverged '
                                              f'after restore')
    assert mgr_s.resident_bytes == mgr_g.resident_bytes


def test_checkpoint_geometry_mismatch_rejected(scene600):
    mgr = _mgr(scene600)
    arrays, meta = mgr.state_dict()
    other = _mgr(structured_scene(jax.random.PRNGKey(2), 400))
    with pytest.raises(ValueError, match='geometry mismatch'):
        other.load_state(arrays, meta)
