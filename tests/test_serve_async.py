"""Conformance harness for the async serving host loop.

The contract under test (``repro.serve.events`` + the plan/apply/observe
decomposition in ``repro.serve.session``):

* the **virtual-clock driver** (``SyncDriver``, ``mgr.run()``) replays a
  recorded arrival/departure trace bit-identically to the pre-pipeline
  synchronous engine — images, cache tags, LRU ages/clock, sorts-per-tick,
  admission/eviction ticks all equal (``legacy_run`` below IS the pre-PR
  ``run_tick`` loop, kept verbatim as the oracle);
* the **threaded driver** reproduces the same control flow (planning ahead
  on a worker changes wall-clock, never decisions) — same images, tags,
  sort cadence;
* no concurrent observer ever sees a **partially-applied admission**: a
  session is pending, or slotted with its ``admitted_tick`` stamped, or
  finished — exactly one of these, at every instant of a threaded run;
* replaying one traffic trace twice is **deterministic**, and paced
  sessions consume frames on their own tick grid.
"""
import dataclasses
import hashlib
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import LuminaConfig
from repro.data.trajectory import orbit_trajectory
from repro.serve import traffic
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper

CFG = LuminaConfig(capacity=192, window=3)
FRAMES = 3
# the recorded parity trace: 5 viewers over 2 slots — a same-tick burst,
# a mid-flight arrival into a busy fleet (slot reuse), an idle-gap arrival
ARRIVALS = (0, 0, 1, 6, 9)


def _digest(arr) -> str:
    return hashlib.sha256(np.asarray(arr).tobytes()).hexdigest()


def _sessions(frames=FRAMES, arrivals=ARRIVALS, paces=None):
    out = []
    for sid, arrival in enumerate(arrivals):
        cams = orbit_trajectory(frames, width=64, height_px=64,
                                start_deg=72.0 * sid)
        out.append(ViewerSession(sid=sid, cams=cams, arrival_tick=arrival,
                                 pace=1 if paces is None else paces[sid]))
    return out


class RecordingStepper:
    """Transparent stepper wrapper that digests every tick's images, so two
    runs can be compared frame-bitwise without holding device buffers."""

    def __init__(self, stepper):
        self._s = stepper
        self.ticks = []          # one {slot: image-sha256} dict per step

    def __getattr__(self, name):
        return getattr(self._s, name)

    def _record(self, out):
        self.ticks.append({slot: _digest(img)
                           for slot, (img, _st, _t) in out.items()})
        return out

    def step(self, cams, plan=None):
        return self._record(self._s.step(cams, plan=plan))

    def step_dispatch(self, cams, plan=None):
        return self._s.step_dispatch(cams, plan)

    def step_finish(self, infl):
        return self._record(self._s.step_finish(infl))


def legacy_run_tick(mgr):
    """The pre-pipeline synchronous ``run_tick``, verbatim — the oracle the
    refactored plan/apply/observe composition must reproduce bit-for-bit."""
    mgr.evict_finished()
    mgr.admit_ready()
    cams = {slot: mgr.slot_session[slot].current_cam()
            for slot in mgr.active_slots()}
    outputs = mgr.stepper.step(cams)
    for slot, (_image, stats, timing) in outputs.items():
        sess = mgr.slot_session[slot]
        sess.telemetry.observe_frame(
            latency_s=timing.latency_s,
            hit_rate=float(stats.hit_rate),
            saved_frac=float(stats.saved_frac),
            sorted_flag=float(stats.sorted_this_frame),
            sort_ms=timing.sort_ms,
            shade_ms=timing.shade_ms)
        sess.cursor += 1
    if outputs:
        tick_timing = mgr.stepper.last_timing
        mgr.tick_log.append({
            'tick': mgr.tick,
            'frames': len(outputs),
            'sorted_slots': tick_timing.sorted_slots,
            'sort_ms': tick_timing.sort_ms,
            'shade_ms': tick_timing.shade_ms,
        })
    mgr.tick += 1
    return len(outputs)


def legacy_run(mgr, max_ticks=1000):
    while not mgr.drained():
        legacy_run_tick(mgr)
        mgr.evict_finished()
        assert mgr.tick < max_ticks, 'legacy loop did not drain'
    return mgr.finished


@pytest.fixture(scope='module')
def parity_stepper(small_scene):
    """One compiled stepper shared by every run in this module (reset
    between runs) — parity must hold on the SAME jitted callables, and
    recompiling per test would dominate the suite."""
    cams0 = orbit_trajectory(1, width=64, height_px=64)
    return BatchedStepper(small_scene, CFG, cams0[0], slots=2)


def _run(stepper, mode, sessions):
    """Drive one fresh run of ``sessions`` and capture everything parity
    compares: per-tick image digests, final cache integer state, executed
    sort cadence, admission/eviction telemetry."""
    stepper.reset()
    rec = RecordingStepper(stepper)
    mgr = SessionManager(rec, slots=stepper.slots)
    for s in sessions:
        mgr.submit(s)
    if mode == 'legacy':
        finished = legacy_run(mgr)
    else:
        finished = mgr.run(driver=mode)
    finished = sorted(finished, key=lambda s: s.sid)
    return {
        'ticks': mgr.tick,
        'images': rec.ticks,
        'tags': np.asarray(stepper.shared.cache.tags),
        'age': np.asarray(stepper.shared.cache.age),
        'clock': np.asarray(stepper.shared.cache.clock),
        'sort_log': list(stepper.sort_log),
        'admitted': [s.telemetry.admitted_tick for s in finished],
        'finished_at': [s.telemetry.finished_tick for s in finished],
        'frames': [s.telemetry.frames for s in finished],
        'sorted_flags': [s.telemetry.sorted_flags for s in finished],
        'hit_rates': [s.telemetry.hit_rates for s in finished],
        'tick_log': list(mgr.tick_log),
    }


def _assert_bitwise_parity(got, want, what):
    assert got['images'] == want['images'], f'{what}: image streams differ'
    for key in ('tags', 'age', 'clock'):
        np.testing.assert_array_equal(got[key], want[key],
                                      err_msg=f'{what}: cache {key}')
    assert got['sort_log'] == want['sort_log'], f'{what}: sort cadence'
    for key in ('ticks', 'admitted', 'finished_at', 'frames',
                'sorted_flags', 'hit_rates'):
        assert got[key] == want[key], f'{what}: {key}'


def test_sync_driver_bitwise_parity_with_legacy_engine(parity_stepper):
    """Satellite (a): the virtual-clock driver replaying the recorded
    arrival trace is bit-identical to the pre-PR synchronous engine —
    images, cache tags, LRU ages, sorts-per-tick, admission timing."""
    legacy = _run(parity_stepper, 'legacy', _sessions())
    sync = _run(parity_stepper, 'sync', _sessions())
    _assert_bitwise_parity(sync, legacy, 'sync vs legacy')
    # the trace really exercised the interesting paths
    assert legacy['ticks'] > FRAMES          # queueing stretched the run
    assert any(a > 0 for a in legacy['admitted'])   # mid-flight admission


def test_threaded_driver_bitwise_parity_with_sync(parity_stepper):
    """The threaded pipeline plans ahead on a worker thread but must make
    the SAME decisions: double-buffering changes wall-clock, never images,
    cache state or sort cadence."""
    sync = _run(parity_stepper, 'sync', _sessions())
    threaded = _run(parity_stepper, 'threaded', _sessions())
    _assert_bitwise_parity(threaded, sync, 'threaded vs sync')
    # and the host attribution is present: every rendered tick carries
    # host_ms; planning for tick t+1 overlapped some tick's device window
    host = [t for t in threaded['tick_log'] if 'host_ms' in t]
    assert host and all(t['host_ms'] >= 0.0 for t in host)
    assert sum(t['overlap_ms'] for t in host) > 0.0


def test_threaded_admission_never_observed_partial(small_scene):
    """Satellite (a), threaded smoke: a concurrent observer hammering
    ``snapshot()`` during a threaded run must never see a session that is
    neither fully pending nor fully admitted (slotted + ``admitted_tick``
    stamped) nor finished — and never see one twice."""
    cams0 = orbit_trajectory(1, width=64, height_px=64)
    stepper = BatchedStepper(small_scene, CFG, cams0[0], slots=2)
    sessions = _sessions(frames=2, arrivals=(0, 0, 0, 1, 2, 3))
    all_sids = sorted(s.sid for s in sessions)
    mgr = SessionManager(stepper, slots=2)
    for s in sessions:
        mgr.submit(s)

    violations = []
    stop = threading.Event()

    def observer():
        while not stop.is_set():
            snap = mgr.snapshot()
            seen = (list(snap['pending'])
                    + [sid for _slot, sid, _at in snap['slotted']]
                    + list(snap['finished']))
            if sorted(seen) != all_sids:
                violations.append(('conservation', snap))
            for slot, sid, admitted_tick in snap['slotted']:
                if admitted_tick < 0 or admitted_tick > snap['tick']:
                    violations.append(('unstamped-admission', snap))
            time.sleep(0)   # yield; keep the lock contended but live

    th = threading.Thread(target=observer, daemon=True)
    th.start()
    try:
        finished = mgr.run(driver='threaded')
    finally:
        stop.set()
        th.join(timeout=5.0)
    assert sorted(s.sid for s in finished) == all_sids
    assert not violations, violations[:3]


def test_virtual_clock_replay_is_deterministic(parity_stepper):
    """Replaying one recorded traffic trace twice through the virtual-clock
    driver is bit-identical — there is no wall clock in the control path."""
    trace = traffic.make_trace('poisson', 4, seed=11, rate=0.8)
    replayed = traffic.TrafficTrace.from_dict(trace.to_dict())
    assert replayed == trace   # the trace itself round-trips
    runs = [_run(parity_stepper, 'sync',
                 _sessions(arrivals=replayed.arrivals, paces=replayed.paces))
            for _ in range(2)]
    _assert_bitwise_parity(runs[1], runs[0], 'replay determinism')


def test_bursty_trace_threaded_smoke(parity_stepper):
    """A bursty flash-crowd trace drains through the threaded driver: every
    session completes its full trajectory, burst admissions queue FIFO."""
    trace = traffic.make_trace('bursty', 5, seed=2, burst=3, gap=4)
    res = _run(parity_stepper, 'threaded',
               _sessions(arrivals=trace.arrivals))
    assert res['frames'] == [FRAMES] * 5
    assert all(f >= 0 for f in res['finished_at'])


def test_paced_sessions_render_on_their_grid(parity_stepper):
    """Frame pacing: a pace-2 viewer sharing the fleet with a pace-1 viewer
    consumes a frame every other tick — its slot idles in between (no
    cursor advance, no rendered frame), and both finish their full
    trajectories."""
    sessions = _sessions(frames=3, arrivals=(0, 0), paces=(1, 2))
    res = _run(parity_stepper, 'sync', sessions)
    assert res['frames'] == [3, 3]
    # pace-1 viewer finishes after 3 ticks; pace-2 needs ticks 0,2,4
    assert res['ticks'] == 5
    per_tick_frames = [len(t) for t in res['images']]
    assert per_tick_frames == [2, 1, 2, 0, 1]


def test_paced_viewer_sort_cadence_never_starves(parity_stepper):
    """A paced viewer whose render ticks never align with its slot's cohort
    residue (pace == window, off-phase slot) must still get sort refreshes:
    the staleness catch-up in ``_due_scheduled`` bounds the gap to
    ``window`` of ITS OWN frames even while a faster co-resident viewer
    keeps ``global_tick`` advancing (without it, the paced viewer rides its
    admission sort for its whole trajectory)."""
    w = CFG.window
    # slot 0: pace-1 viewer alive the whole run; slot 1: pace-w viewer
    # rendering ticks 0, w, 2w, ... — residue w*k % w == 0, never slot 1's
    fast = ViewerSession(sid=0, cams=orbit_trajectory(
        4 * w + 1, width=64, height_px=64), arrival_tick=0, pace=1)
    paced = ViewerSession(sid=1, cams=orbit_trajectory(
        5, width=64, height_px=64, start_deg=72.0), arrival_tick=0, pace=w)
    res = _run(parity_stepper, 'sync', [fast, paced])
    assert res['frames'] == [4 * w + 1, 5]
    paced_flags = res['sorted_flags'][1]
    # no window-of-frames gap without a refresh, on the viewer's own clock
    zero_run = max_run = 0
    for f in paced_flags:
        zero_run = 0 if f else zero_run + 1
        max_run = max(max_run, zero_run)
    assert paced_flags[0] == 1.0            # sort-on-admit
    assert max_run < w, (
        f'paced viewer starved of sort refreshes: flags {paced_flags}')
    # and the pace-1 viewer's cadence is the untouched legacy one
    assert res['sorted_flags'][0][:w + 1] == [1.0] + [0.0] * (w - 1) + [1.0]


def test_plan_tick_is_pure(parity_stepper):
    """``plan_tick`` must not mutate the manager or stepper: planning twice
    yields the same plan and applying after planning twice is identical to
    planning once (the worker thread relies on this)."""
    parity_stepper.reset()
    mgr = SessionManager(parity_stepper, slots=2)
    for s in _sessions():
        mgr.submit(s)
    p1 = mgr.plan_tick()
    p2 = mgr.plan_tick()
    assert (p1.tick, p1.evict, p1.admit) == (p2.tick, p2.evict, p2.admit)
    assert set(p1.cams) == set(p2.cams)
    assert len(mgr.pending) == len(ARRIVALS)       # nothing popped
    assert mgr.active_slots() == []                # nothing placed
    assert p1.sort_plan is not None
    assert p1.sort_plan.admits == tuple(sorted(p1.cams))   # sort-on-admit


def test_stale_plan_rejected(parity_stepper):
    """A plan applied at the wrong tick is a protocol bug — the manager
    refuses it instead of silently corrupting admission state."""
    parity_stepper.reset()
    mgr = SessionManager(parity_stepper, slots=2)
    for s in _sessions():
        mgr.submit(s)
    plan = mgr.plan_tick()
    stale = dataclasses.replace(plan, tick=plan.tick + 3)
    with pytest.raises(RuntimeError, match='stale plan'):
        mgr.apply_plan(stale)