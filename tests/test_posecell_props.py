"""Property tests for ``repro.core.posecell`` — the quantization the
scene-level sort scheduler trusts.

Three families, matching the module's documented contract:

* **margin-budget stability** — any two cameras whose positions sit in the
  interior of one grid cell and whose orientation stays within half an
  angular bin of a bin center quantize identically: the pose drift the
  scheduler treats as "close enough" can never flip a key;
* **zero-centered bins** — upright cameras (roll ~ 0) and axis-aligned
  headings sit at bin CENTERS, so float noise around zero cannot flip a
  bucket (the half-bin offset in ``angle_bucket``);
* **neighbor structure** — moving exactly one grid pitch along one world
  axis changes exactly one bucket coordinate by exactly one (and no
  angular coordinate), i.e. the position grid really is a grid.

Under the real ``hypothesis`` package (CI) these explore the strategy
space; under the conftest shim they run deterministic examples and report
as skipped.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.camera import make_camera
from repro.core.posecell import (ANG_BINS, CELL_SIZE, angle_bucket,
                                 pose_cell_buckets, pose_cell_key)

BIN_W = 2.0 * np.pi / ANG_BINS            # azimuth/roll bucket width (rad)


def _cam(position, quat=(1.0, 0.0, 0.0, 0.0)):
    return make_camera(position, quat, fov_x_deg=60.0, width=64, height=64)


def _axis_quat(axis, theta):
    """Unit quaternion for a rotation of ``theta`` about a unit ``axis``."""
    axis = np.asarray(axis, np.float64)
    s = np.sin(theta / 2.0)
    return (np.cos(theta / 2.0), *(s * axis))


# -- margin-budget stability -------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.tuples(st.integers(-40, 40), st.integers(-40, 40),
                 st.integers(-40, 40)),
       st.tuples(st.floats(-0.45, 0.45), st.floats(-0.45, 0.45),
                 st.floats(-0.45, 0.45)),
       st.tuples(st.floats(-0.45, 0.45), st.floats(-0.45, 0.45),
                 st.floats(-0.45, 0.45)))
def test_key_stable_inside_cell(cell, off_a, off_b):
    """Two cameras anywhere in the interior of one position cell (same
    orientation) share buckets and key — the margin budget's position leg."""
    base = (np.asarray(cell, np.float64) + 0.5) * CELL_SIZE
    pa = base + np.asarray(off_a) * CELL_SIZE
    pb = base + np.asarray(off_b) * CELL_SIZE
    assert pose_cell_buckets(_cam(pa)) == pose_cell_buckets(_cam(pb))
    assert pose_cell_key(_cam(pa)) == pose_cell_key(_cam(pb))


@settings(max_examples=50, deadline=None)
@given(st.sampled_from([(0.0, 0.0, 1.0), (0.0, 1.0, 0.0), (1.0, 0.0, 0.0)]),
       st.floats(-0.45, 0.45),
       st.floats(0.05, 3.0))
def test_key_stable_within_angular_bin(axis, frac, radius):
    """Rotating the camera by less than half the TIGHTEST angular bin (the
    elevation axis spans pi over ANG_BINS, half an azimuth bin) about any
    principal axis, from the upright pose, never flips the key — the margin
    budget's orientation leg, enabled by zero-centered bins."""
    p = (radius, 0.5 * CELL_SIZE, 0.5 * CELL_SIZE)
    bin_w_el = np.pi / ANG_BINS
    theta = frac * bin_w_el * 0.9   # strictly inside the half-bin guard band
    ref = pose_cell_buckets(_cam(p))
    got = pose_cell_buckets(_cam(p, _axis_quat(axis, theta)))
    assert got == ref, (axis, theta)


# -- zero-centered bins ------------------------------------------------------

@settings(max_examples=100, deadline=None)
@given(st.integers(0, ANG_BINS - 1), st.floats(-0.45, 0.45))
def test_angle_bucket_centers(k, frac):
    """Every ``lo + k * width`` is a bin CENTER: noise up to +-0.45 bins
    around it stays in bucket k (mod wrap)."""
    lo, span = -np.pi, 2.0 * np.pi
    center = lo + k * span / ANG_BINS
    assert angle_bucket(center + frac * BIN_W, lo, span,
                        ANG_BINS) == k % ANG_BINS


@settings(max_examples=50, deadline=None)
@given(st.floats(1e-9, 1e-4))
def test_upright_roll_noise_never_flips(eps):
    """The ubiquitous upright camera: tiny roll jitter of either sign (the
    float noise a pose pipeline produces) lands in one bucket — this is the
    whole point of the half-bin offset."""
    p = (1.0, 0.5 * CELL_SIZE, 0.5 * CELL_SIZE)
    plus = pose_cell_buckets(_cam(p, _axis_quat((0, 0, 1.0), eps)))
    minus = pose_cell_buckets(_cam(p, _axis_quat((0, 0, 1.0), -eps)))
    assert plus == minus == pose_cell_buckets(_cam(p))


@settings(max_examples=50, deadline=None)
@given(st.integers(0, ANG_BINS - 1), st.floats(-0.4, 0.4))
def test_angle_bucket_periodic_wrap(k, frac):
    """Periodic axes wrap: x and x + 2*pi share a bucket (away from bin
    boundaries, where float addition noise is irrelevant)."""
    lo, span = -np.pi, 2.0 * np.pi
    x = lo + (k + frac) * span / ANG_BINS
    assert angle_bucket(x, lo, span, ANG_BINS) == \
        angle_bucket(x + span, lo, span, ANG_BINS)


@settings(max_examples=50, deadline=None)
@given(st.floats(-2.0, 2.0))
def test_elevation_clamps_never_wraps(el):
    """The non-periodic elevation axis clamps out-of-range values into
    [0, bins-1] — straight-up must never alias straight-down."""
    b = angle_bucket(el, -np.pi / 2, np.pi, ANG_BINS, periodic=False)
    assert 0 <= b <= ANG_BINS - 1
    lo_b = angle_bucket(-np.pi / 2, -np.pi / 2, np.pi, ANG_BINS,
                        periodic=False)
    hi_b = angle_bucket(np.pi / 2, -np.pi / 2, np.pi, ANG_BINS,
                        periodic=False)
    assert lo_b != hi_b


# -- neighbor structure ------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.tuples(st.integers(-40, 40), st.integers(-40, 40),
                 st.integers(-40, 40)),
       st.tuples(st.floats(0.1, 0.9), st.floats(0.1, 0.9),
                 st.floats(0.1, 0.9)),
       st.integers(0, 2))
def test_neighbor_cells_differ_in_exactly_one_coordinate(cell, frac, axis):
    """One grid pitch along one world axis moves exactly that bucket
    coordinate by exactly one; orientation buckets are untouched."""
    p = (np.asarray(cell, np.float64) + np.asarray(frac)) * CELL_SIZE
    q = np.array(p)
    q[axis] += CELL_SIZE
    a = pose_cell_buckets(_cam(p))
    b = pose_cell_buckets(_cam(q))
    diffs = [i for i in range(6) if a[i] != b[i]]
    assert diffs == [axis]
    assert b[axis] - a[axis] == 1
    assert pose_cell_key(_cam(p)) != pose_cell_key(_cam(q))


# -- key hygiene -------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.tuples(st.floats(-2.0, 2.0), st.floats(-2.0, 2.0),
                 st.floats(-2.0, 2.0)),
       st.tuples(st.floats(-1.0, 1.0), st.floats(-1.0, 1.0),
                 st.floats(-1.0, 1.0), st.floats(-1.0, 1.0)))
def test_key_deterministic_and_sentinel_safe(pos, quat):
    """Keys are deterministic, non-negative and < 2**31 — so the pool's
    -1 'free entry' sentinel can never collide with a real cell."""
    qn = np.asarray(quat, np.float64)
    if np.linalg.norm(qn) < 1e-6:
        qn = np.array([1.0, 0.0, 0.0, 0.0])
    cam = _cam(pos, tuple(qn))
    k1, k2 = pose_cell_key(cam), pose_cell_key(cam)
    assert k1 == k2
    assert 0 <= k1 < 2 ** 31
    assert k1 == pytest.approx(k1)  # plain int, json-safe