"""Optimizer, schedules, gradient compression, and the token pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.tokens import (TokenStream, global_batch_view,
                               synthetic_batch, synthetic_tokens)
from repro.optim import adam, compression, schedule


def test_adam_matches_reference():
    """One step vs the closed-form AdamW update."""
    cfg = adam.AdamConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                          weight_decay=0.0, clip_norm=None)
    p = {'w': jnp.asarray([1.0, -2.0])}
    g = {'w': jnp.asarray([0.5, 0.25])}
    state = adam.init(p, cfg)
    new_p, state, _ = adam.step(p, g, state, cfg)
    m = 0.1 * np.asarray(g['w'])
    v = 0.01 * np.asarray(g['w']) ** 2
    update = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    want = np.asarray(p['w']) - 0.1 * update
    np.testing.assert_allclose(np.asarray(new_p['w']), want, rtol=1e-5)


def test_adam_clip_norm():
    cfg = adam.AdamConfig(lr=0.0, clip_norm=1.0)
    p = {'w': jnp.zeros(3)}
    g = {'w': jnp.asarray([3.0, 4.0, 0.0])}
    state = adam.init(p, cfg)
    _, _, gnorm = adam.step(p, g, state, cfg)
    assert abs(float(gnorm) - 5.0) < 1e-5


def test_adam_bf16_state_dtype():
    cfg = adam.AdamConfig(state_dtype=jnp.bfloat16)
    p = {'w': jnp.ones((4, 4), jnp.bfloat16)}
    state = adam.init(p, cfg)
    assert state.mu['w'].dtype == jnp.bfloat16
    new_p, state, _ = adam.step(p, {'w': jnp.ones((4, 4), jnp.bfloat16)},
                                state, cfg)
    assert new_p['w'].dtype == jnp.bfloat16
    assert state.nu['w'].dtype == jnp.bfloat16


def test_schedule_warmup_cosine():
    s0 = float(schedule.linear_warmup_cosine(0, warmup_steps=10,
                                             total_steps=100))
    s10 = float(schedule.linear_warmup_cosine(10, warmup_steps=10,
                                              total_steps=100))
    s100 = float(schedule.linear_warmup_cosine(100, warmup_steps=10,
                                               total_steps=100))
    assert s0 == 0.0 and abs(s10 - 1.0) < 1e-6 and abs(s100 - 0.1) < 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 64))
def test_compression_roundtrip_bounded_error(seed, n):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 3.0
    comp, residual = compression.compress(x)
    y = compression.decompress(comp)
    # quantization error bounded by scale/2 per element; residual exact
    scale = np.asarray(comp.scale).max()
    assert float(jnp.abs(y - x).max()) <= scale * 0.51 + 1e-6
    np.testing.assert_allclose(np.asarray(x - y), np.asarray(residual),
                               atol=1e-6)


def test_compression_error_feedback_converges():
    """With error feedback the time-average of dequantized gradients
    converges to the true gradient (error bounded by scale/steps)."""
    x = jnp.asarray([0.001, -0.002, 3.0, 0.0005])
    residual = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    steps = 50
    for _ in range(steps):
        comp, residual = compression.compress(x, residual)
        acc = acc + compression.decompress(comp)
    scale = 3.0 / 127.0
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(x),
                               atol=2 * scale / steps)


def test_tokens_deterministic_and_in_range():
    a = synthetic_tokens(1, 5, 4, 16, 997)
    b = synthetic_tokens(1, 5, 4, 16, 997)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(a.min()) >= 0 and int(a.max()) < 997
    c = synthetic_tokens(1, 6, 4, 16, 997)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4).map(lambda k: 2 ** k), st.integers(0, 5))
def test_token_stream_host_sharding_invariant(num_hosts, step0):
    """Concatenating every host's slice == the single-host global batch."""
    gb, seq, vocab = 16, 8, 211
    slices = []
    for h in range(num_hosts):
        s = TokenStream(seed=3, global_batch=gb, seq=seq, vocab=vocab,
                        host_id=h, num_hosts=num_hosts, step=step0)
        slices.append(np.asarray(s.next()['tokens']))
    got = np.concatenate(slices, axis=0)
    want = np.asarray(global_batch_view(3, step0, gb, seq, vocab)['tokens'])
    np.testing.assert_array_equal(got, want)


def test_token_stream_resume():
    s1 = TokenStream(seed=0, global_batch=4, seq=8, vocab=101)
    for _ in range(3):
        s1.next()
    state = s1.state_dict()
    want = s1.next()
    s2 = TokenStream(seed=0, global_batch=4, seq=8, vocab=101)
    s2.load_state_dict(state)
    got = s2.next()
    np.testing.assert_array_equal(np.asarray(got['tokens']),
                                  np.asarray(want['tokens']))


def test_labels_are_shifted_tokens():
    b = synthetic_batch(0, 0, 2, 8, 53)
    full = synthetic_tokens(0, 0, 2, 9, 53)
    np.testing.assert_array_equal(np.asarray(b['tokens']),
                                  np.asarray(full[:, :-1]))
    np.testing.assert_array_equal(np.asarray(b['labels']),
                                  np.asarray(full[:, 1:]))
