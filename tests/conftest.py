"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only launch/dryrun.py forces 512 host devices (in its own process)."""
import jax
import jax.numpy as jnp
import pytest

from repro.data.scenes import structured_scene
from repro.data.trajectory import orbit_trajectory


@pytest.fixture(scope='session')
def small_scene():
    return structured_scene(jax.random.PRNGKey(0), 1200)


@pytest.fixture(scope='session')
def cams64():
    return orbit_trajectory(6, width=64, height_px=64)
