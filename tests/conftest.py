"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only launch/dryrun.py forces 512 host devices (in its own process).

Also installs a minimal ``hypothesis`` fallback when the real package is not
available (the container ships without it): property tests still execute
their examples as deterministic randomized checks (a regression still
fails), but then report as SKIPPED — a shim pass is not real property
coverage (no shrinking, no edge-case strategies, no database) and must not
read as one.  CI installs the real package, so property tests pass or fail
for real there.
"""
import random
import sys
import types

import jax
import jax.numpy as jnp
import pytest

from repro.data.scenes import structured_scene
from repro.data.trajectory import orbit_trajectory


def _install_hypothesis_shim():
    """Register a tiny stand-in ``hypothesis`` module in sys.modules.

    Supports exactly what this suite uses: ``@settings(max_examples=...,
    deadline=...)``, ``@given(...)`` and the ``integers`` / ``floats`` /
    ``lists`` / ``tuples`` / ``sampled_from`` strategies plus ``.map``.
    Examples are drawn from a seeded RNG so runs are deterministic;
    shrinking and the database are (deliberately) absent — which is why a
    shim-backed test that survives its examples reports as skipped, not
    passed (``pytest.skip`` after the example loop): the real strategies
    only run where CI installs real hypothesis.
    """

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rnd):
            return self._draw(rnd)

        def map(self, fn):
            return _Strategy(lambda rnd: fn(self._draw(rnd)))

    def integers(min_value, max_value):
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rnd: opts[rnd.randrange(len(opts))])

    def tuples(*strats):
        return _Strategy(lambda rnd: tuple(s.draw(rnd) for s in strats))

    def lists(elements, *, min_size=0, max_size=10, unique=False):
        def draw(rnd):
            n = rnd.randint(min_size, max_size)
            out = []
            attempts = 0
            while len(out) < n and attempts < 50 * (n + 1):
                v = elements.draw(rnd)
                attempts += 1
                if unique and v in out:
                    continue
                out.append(v)
            return out
        return _Strategy(draw)

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                # read from the wrapper: @settings sits OUTSIDE @given and
                # sets the attribute on the object given returned
                n = getattr(wrapper, '_shim_max_examples',
                            getattr(fn, '_shim_max_examples', 20))
                rnd = random.Random(f'{fn.__name__}:0')
                for _ in range(n):
                    fn(*args, *(s.draw(rnd) for s in strats), **kwargs)
                # every example held, but only against the shim's naive
                # uniform draws: report skipped, not (vacuously) passed
                pytest.skip(f'hypothesis not installed: shim ran {n} '
                            f'deterministic examples (all held); install '
                            f'hypothesis for real property coverage')
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    mod = types.ModuleType('hypothesis')
    mod.given = given
    mod.settings = settings
    mod.__is_repro_shim__ = True
    strategies = types.ModuleType('hypothesis.strategies')
    strategies.integers = integers
    strategies.floats = floats
    strategies.lists = lists
    strategies.tuples = tuples
    strategies.sampled_from = sampled_from
    mod.strategies = strategies
    sys.modules['hypothesis'] = mod
    sys.modules['hypothesis.strategies'] = strategies


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()


@pytest.fixture(scope='session')
def small_scene():
    return structured_scene(jax.random.PRNGKey(0), 1200)


@pytest.fixture(scope='session')
def cams64():
    return orbit_trajectory(6, width=64, height_px=64)
