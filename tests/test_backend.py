"""The shade-backend switch: 'pallas' (chunked kernels, miss-compacted RC
resume) vs 'reference' (pure-JAX rasterizer + functional cache).

Contract: the two backends agree on every *integer* decision — cache tags,
LRU age/clock, hit masks, alpha-records — bitwise, across multi-frame runs
and under the serving ``live`` mask.  Images agree to a documented float32
ulp bound (the kernel evaluates alpha densely per chunk and accumulates in
a different association than the sequential reference).  Miss compaction
and all early-termination paths are pure compute savings: they may never
change any output.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import radiance_cache as rc
from repro.core.gaussians import TRANSMITTANCE_EPS
from repro.core.pipeline import (LuminaConfig, LuminSys, batched_shade_phase,
                                 init_fleet)
from repro.core.projection import project
from repro.core.sorting import sort_scene
from repro.core.tiling import gather_tile_features
from repro.core.camera import stack_cameras
from repro.data.trajectory import orbit_trajectory
from repro.kernels import ops
from repro.kernels import rasterize as rk

# images: kernel-vs-reference reassociation bound (see module docstring);
# matches the kernel suite's atol=3e-5 at unit magnitude
IMG_ULPS = 512


def _ulp_close(got, want, ulps=IMG_ULPS, msg=''):
    got, want = np.asarray(got, np.float32), np.asarray(want, np.float32)
    scale = np.maximum(np.maximum(np.abs(got), np.abs(want)), 1.0)
    err = np.abs(got - want)
    assert (err <= ulps * np.finfo(np.float32).eps * scale).all(), (
        f'{msg}: max {(err / (np.finfo(np.float32).eps * scale)).max():.0f} '
        f'ulps (> {ulps})')


def test_backend_switch_validated():
    with pytest.raises(ValueError):
        LuminaConfig(backend='cuda')


def test_pallas_backend_matches_reference_luminsys(small_scene, cams64):
    """Full multi-frame LuminSys runs: identical hit rates and cache tags
    every frame, images within the documented ulp bound on both backends."""
    cfg_r = LuminaConfig(capacity=128, window=3)
    cfg_p = dataclasses.replace(cfg_r, backend='pallas')
    sys_r = LuminSys(small_scene, cfg_r, cams64[0])
    sys_p = LuminSys(small_scene, cfg_p, cams64[0])
    for f, cam in enumerate(cams64):
        img_r, st_r = sys_r.step(cam)
        img_p, st_p = sys_p.step(cam)
        _ulp_close(img_p, img_r, msg=f'frame {f}')
        assert float(st_p.hit_rate) == float(st_r.hit_rate), f'frame {f}'
    np.testing.assert_array_equal(np.asarray(sys_p.state.cache.tags),
                                  np.asarray(sys_r.state.cache.tags))
    np.testing.assert_array_equal(np.asarray(sys_p.state.cache.age),
                                  np.asarray(sys_r.state.cache.age))
    np.testing.assert_array_equal(np.asarray(sys_p.state.cache.clock),
                                  np.asarray(sys_r.state.cache.clock))


@pytest.mark.parametrize('backend', ['reference', 'pallas'])
def test_live_mask_idle_lane_contributes_nothing(small_scene, backend):
    """Batched shade with one idle lane: the dead lane reports zero iterated
    work on either backend (on the kernel path it also skips its chunk
    loops), and live lanes are bit-unaffected by the dead lane's presence."""
    cfg = LuminaConfig(capacity=128, window=3, backend=backend)
    traj = orbit_trajectory(2, width=64, height_px=64)
    s = 3
    shared, priv = init_fleet(small_scene, cfg, traj[0], slots=s)
    cams = stack_cameras([traj[0]] * s)
    shade = jax.jit(functools.partial(batched_shade_phase, cfg=cfg))
    ones = jnp.ones((s,), jnp.float32)
    _, _, img_all, _ = shade(small_scene, shared, priv, cams, ones,
                             jnp.ones((s,), bool))
    shared2, priv2 = init_fleet(small_scene, cfg, traj[0], slots=s)
    _, _, img_mask, stats = shade(small_scene, shared2, priv2, cams, ones,
                                  jnp.asarray([True, False, True]))
    # dead lane: zero iterated work, zero hits
    assert float(stats.mean_iterated[1]) == 0.0
    assert float(stats.sig_frac[1]) == 0.0
    # live lanes identical to the all-live run (same compiled program;
    # lanes are independent under vmap)
    np.testing.assert_array_equal(np.asarray(img_mask[0]),
                                  np.asarray(img_all[0]))
    np.testing.assert_array_equal(np.asarray(img_mask[2]),
                                  np.asarray(img_all[2]))


def _projected_feats(scene, cam, capacity=128):
    proj = project(scene, cam)
    lists = sort_scene(proj, cam.width, cam.height, capacity)
    return ops.pad_features(gather_tile_features(proj, lists), 32), lists


def test_miss_compaction_round_trip(small_scene, cams64):
    """gather -> compacted resume -> scatter == full-tile resume, for a
    scattered miss mask: integer state exactly, floats to reassociation
    tolerance — compaction is pure routing, never arithmetic."""
    feats, lists = _projected_feats(small_scene, cams64[0])
    st_a = ops.rasterize_prefix(feats, lists.tiles_x, chunk=32,
                                interpret=True)
    # scattered pseudo-random miss pattern (every 7th pixel + a full tile)
    t, p = st_a.trans.shape
    miss = (jnp.arange(t * p) % 7 == 0).reshape(t, p)
    miss = miss.at[1].set(True)

    colors_f, aux_f, _ = ops.rasterize_resume(
        feats, lists.tiles_x, st_a, miss, chunk=32, interpret=True)
    colors_c, aux_c, chunks_c = ops.rasterize_resume_compacted(
        feats, lists.tiles_x, st_a, miss, chunk=32, interpret=True)

    np.testing.assert_allclose(np.asarray(colors_c), np.asarray(colors_f),
                               atol=3e-5, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(aux_c.alpha_record),
                                  np.asarray(aux_f.alpha_record))
    np.testing.assert_array_equal(np.asarray(aux_c.n_significant),
                                  np.asarray(aux_f.n_significant))
    np.testing.assert_array_equal(np.asarray(aux_c.n_iterated),
                                  np.asarray(aux_f.n_iterated))
    np.testing.assert_array_equal(np.asarray(aux_c.iter_at_k),
                                  np.asarray(aux_f.iter_at_k))


def test_miss_compaction_chunks_scale_with_miss_count(small_scene, cams64):
    """The point of compaction: phase-B chunk work tracks the miss count.
    A single missing tile's worth of pixels must cost far fewer chunk
    iterations than the full-tile resume charges."""
    feats, lists = _projected_feats(small_scene, cams64[0])
    st_a = ops.rasterize_prefix(feats, lists.tiles_x, chunk=32,
                                interpret=True)
    t, p = st_a.trans.shape
    # one miss pixel per tile — the worst case for full-tile resume
    miss = (jnp.arange(t * p) % p == 0).reshape(t, p)
    _, _, chunks_full = ops.rasterize_resume(
        feats, lists.tiles_x, st_a, miss, chunk=32, interpret=True)
    _, _, chunks_cmp = ops.rasterize_resume_compacted(
        feats, lists.tiles_x, st_a, miss, chunk=32, interpret=True)
    full, cmp_ = int(jnp.sum(chunks_full)), int(jnp.sum(chunks_cmp))
    # T scattered misses fit in ceil(T/P) compacted tiles
    assert cmp_ < full, (cmp_, full)
    assert cmp_ <= int(jnp.max(ops.chunk_caps(feats.ids, 32))) * (
        (t + p - 1) // p + 1)


@pytest.mark.parametrize('body', ['dense', 'seq'])
def test_early_termination_never_changes_output(small_scene, cams64, body):
    """Count caps + transmittance-floor early exit are pure compute savings:
    the capped kernel equals an uncapped run on both body flavors, while
    processing strictly fewer chunks on short/terminated tiles."""
    feats, lists = _projected_feats(small_scene, cams64[0])
    t = feats.ids.shape[0]
    k_total = feats.ids.shape[1]
    state = (jnp.zeros((t, rk.P, 3), jnp.float32),
             jnp.ones((t, rk.P), jnp.float32),
             jnp.full((t, rk.P, 5), -1, jnp.int32),
             jnp.zeros((t, rk.P), jnp.int32),
             jnp.zeros((t, rk.P), jnp.int32),
             jnp.ones((t, rk.P), jnp.int32))
    args = dict(tiles_x=lists.tiles_x, k_record=5, chunk=32,
                stop_at_k=False, interpret=True, body=body)
    capped = rk.rasterize_pallas(
        feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
        *state, ncap=ops.chunk_caps(feats.ids, 32), **args)
    uncapped = rk.rasterize_pallas(
        feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
        *state, ncap=None, **args)
    for field in ('record', 'rec_cnt', 'n_sig', 'n_iter', 'iter_at_k'):
        np.testing.assert_array_equal(
            np.asarray(getattr(capped, field)),
            np.asarray(getattr(uncapped, field)), err_msg=field)
    np.testing.assert_allclose(np.asarray(capped.acc),
                               np.asarray(uncapped.acc), atol=3e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(capped.trans),
                               np.asarray(uncapped.trans), atol=3e-5,
                               rtol=1e-4)
    assert int(jnp.sum(capped.chunks)) <= int(jnp.sum(uncapped.chunks))


def test_seq_and_dense_bodies_agree(small_scene, cams64):
    """The two chunk-backend flavors implement one contract: integer state
    bitwise, floats to reassociation tolerance, chunk counts identical
    (the skip branch changes work, never the trip count)."""
    feats, lists = _projected_feats(small_scene, cams64[0])
    t = feats.ids.shape[0]
    state = (jnp.zeros((t, rk.P, 3), jnp.float32),
             jnp.ones((t, rk.P), jnp.float32),
             jnp.full((t, rk.P, 5), -1, jnp.int32),
             jnp.zeros((t, rk.P), jnp.int32),
             jnp.zeros((t, rk.P), jnp.int32),
             jnp.ones((t, rk.P), jnp.int32))
    outs = {}
    for body in ('dense', 'seq'):
        outs[body] = rk.rasterize_pallas(
            feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
            *state, tiles_x=lists.tiles_x, k_record=5, chunk=32,
            stop_at_k=True, interpret=True,
            ncap=ops.chunk_caps(feats.ids, 32), body=body)
    for field in ('record', 'rec_cnt', 'n_sig', 'n_iter', 'iter_at_k',
                  'chunks'):
        np.testing.assert_array_equal(
            np.asarray(getattr(outs['seq'], field)),
            np.asarray(getattr(outs['dense'], field)), err_msg=field)
    np.testing.assert_allclose(np.asarray(outs['seq'].acc),
                               np.asarray(outs['dense'].acc), atol=3e-5,
                               rtol=1e-4)


def test_slot_batched_shade_matches_per_slot(small_scene):
    """The slot-batched pallas serving shade (one program per tile covering
    every slot's lanes + cross-slot miss compaction) is bit-identical per
    lane to independent per-slot runs: hit rates and cache tags exactly,
    images to the kernel tolerance — the while-trip coupling across slots
    is pure skipped work."""
    s, frames = 3, 4
    cfg = LuminaConfig(capacity=128, window=2, backend='pallas')
    trajs = [orbit_trajectory(frames, width=64, height_px=64,
                              start_deg=120.0 * i) for i in range(s)]
    shared, priv = init_fleet(small_scene, cfg, trajs[0][0], slots=s)
    refs = [LuminSys(small_scene, cfg, t[0]) for t in trajs]
    from repro.core.pipeline import batched_sort_phase
    sortp = jax.jit(functools.partial(batched_sort_phase, cfg=cfg))
    shade = jax.jit(functools.partial(batched_shade_phase, cfg=cfg))
    sm = jnp.zeros((s,), jnp.float32)
    am = jnp.ones((s,), bool)
    for f in range(frames):
        cams = stack_cameras([t[f] for t in trajs])
        if f % cfg.window == 0:
            entries = sortp(small_scene, priv, cams)       # [S, ...]
            shared = dataclasses.replace(shared, pool=jax.tree.map(
                lambda p, e: p.at[:, 0].set(e), shared.pool, entries))
        shared, priv, images, stats = shade(small_scene, shared, priv, cams,
                                            sm, am)
        for v in range(s):
            img_r, st_r = refs[v].step(trajs[v][f])
            _ulp_close(images[v], img_r, msg=f'slot {v} frame {f}')
            assert float(stats.hit_rate[v]) == float(st_r.hit_rate)
    for v in range(s):
        np.testing.assert_array_equal(
            np.asarray(jax.tree.map(lambda x: x[v], shared.cache).tags),
            np.asarray(refs[v].state.cache.tags), err_msg=f'slot {v}')


def test_pallas_saved_frac_is_measured_not_modeled(small_scene, cams64):
    """On the pallas backend FrameStats.saved_frac is the *realized*
    chunk-level saving vs a count-capped full pass, not the reference
    path's modeled per-pixel saving.  Cold start pays phase A plus a
    near-full resume (strongly negative); once the cache warms, compaction
    shrinks phase B to the miss count and the measured saving must improve
    strictly.  (Whether it crosses zero depends on scene coverage — at
    benchmark scale it does, and CI gates on it via chunk_savings_%.)"""
    cfg = LuminaConfig(capacity=128, window=3, backend='pallas')
    sys_p = LuminSys(small_scene, cfg, cams64[0])
    saved, hits = [], []
    for cam in list(cams64) + list(cams64):
        _, st = sys_p.step(cam)
        saved.append(float(st.saved_frac))
        hits.append(float(st.hit_rate))
    assert hits[-1] > 0.5, hits
    assert saved[-1] > saved[0] + 0.2, saved
