"""Elastic multi-device serving fleet (``repro.serve.fleet``).

The contracts under test:

* the placement planners (``plan_route`` / ``plan_rebalance`` /
  ``plan_shrink``) are pure and deterministic, conserve viewers, are no-ops
  when already balanced, and never place anything on a dead device;
* ``ThreadedFleetDriver`` is **bit-identical** to the virtual N-device
  ``SyncFleetDriver`` oracle — same per-frame images, same routing, same
  final clock — on both shade backends;
* a slot-aligned live migration carries the viewer's whole scene lane and
  continues bit-identically to never having moved (the lockstep
  ``global_tick`` clock is what makes this hold across idle ticks);
  unaligned moves restore cold (frames conserved, at most one sort-window
  of sharing staleness — the fresh-admission bound);
* ``device_loss`` with checkpointing rolls the whole fleet back to its
  last crash-consistent snapshot: survivors and slot-aligned victims
  replay bit-identically vs the unfaulted golden run, spilled victims
  re-queue at their snapshot cursor, **zero viewers are dropped** and
  replayed frames are not double-counted;
* without checkpoints the recovery is cold: victims re-queue at their
  current cursor and no delivered frame is ever re-rendered;
* under degraded capacity the bounded fleet queue sheds *new* arrivals
  (recorded + counted) while every accepted viewer still drains.

The straggler cold-start contract (single host never self-flags,
first-observation EWMA seeding, metrics mirror) rides along — the fleet's
threaded driver is its second consumer.
"""
import dataclasses
import hashlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.core.pipeline import LuminaConfig
from repro.data.trajectory import orbit_trajectory
from repro.obs import metrics as obs_metrics
from repro.runtime.straggler import StragglerDetector
from repro.serve import faults, fleet
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper

CFG = LuminaConfig(capacity=192, window=3)


def _digest(arr) -> str:
    return hashlib.sha256(np.asarray(arr).tobytes()).hexdigest()


def _sessions(frames=(3, 3, 3), arrivals=None, paces=None):
    arrivals = arrivals if arrivals is not None else (0,) * len(frames)
    out = []
    for sid, (n, arr) in enumerate(zip(frames, arrivals)):
        cams = orbit_trajectory(n, width=64, height_px=64,
                                start_deg=60.0 * sid)
        out.append(ViewerSession(sid=sid, cams=cams, arrival_tick=arr,
                                 pace=paces[sid] if paces else 1))
    return out


class FleetRecorder:
    """Stepper wrapper digesting every rendered frame, keyed by
    ``(sid, frame_idx)`` — the key survives migration, rollback and
    re-admission, so continuations compare against a golden run per
    *viewer frame* rather than per slot.  Repeated digests under one key
    are at-least-once replay (rollback recovery re-renders them).

    Setattr passes through to the wrapped stepper: the fleet's lockstep
    clause assigns ``stepper.global_tick`` and the manager assigns
    ``tracer``/``metrics`` — shadowing those on the wrapper would silently
    break the real stepper's cadence clock."""

    _OWN = ('_s', 'mgr', 'frames')

    def __init__(self, stepper):
        object.__setattr__(self, '_s', stepper)
        object.__setattr__(self, 'mgr', None)
        object.__setattr__(self, 'frames', {})

    def __getattr__(self, name):
        return getattr(self._s, name)

    def __setattr__(self, name, value):
        if name in self._OWN:
            object.__setattr__(self, name, value)
        else:
            setattr(self._s, name, value)

    def _record(self, out):
        for slot, (img, _st, _t) in out.items():
            sess = self.mgr.slot_session[slot]
            if sess is not None:
                self.frames.setdefault((sess.sid, sess.cursor),
                                       []).append(_digest(img))
        return out

    def step(self, cams, plan=None):
        return self._record(self._s.step(cams, plan=plan))

    def step_dispatch(self, cams, plan=None):
        return self._s.step_dispatch(cams, plan)

    def step_finish(self, infl):
        return self._record(self._s.step_finish(infl))


def _make_fleet(steppers, *, ckpt_root=None, ckpt_every=0, injector=None,
                max_pending=None):
    """Fleet over module-shared compiled steppers (reset between runs —
    recompiling one stepper per device per test would dominate the
    suite), each wrapped in a digest recorder."""
    dev = None
    workers = []
    for d, stp in enumerate(steppers):
        stp.reset()
        rec = FleetRecorder(stp)
        mgr = SessionManager(rec, slots=stp.slots,
                             metrics=obs_metrics.Registry())
        rec.mgr = mgr
        ckpt = None
        if ckpt_root is not None and ckpt_every > 0:
            ckpt = CheckpointManager(ckpt_root / f'device{d}',
                                     metrics=mgr.metrics)
            mgr.enable_checkpoints(ckpt, ckpt_every)
        workers.append(fleet.FleetWorker(d, dev, mgr, ckpt))
    return fleet.FleetManager(workers, injector=injector,
                              max_pending=max_pending)


def _frames_of(fm):
    merged = {}
    for w in fm.workers:
        for key, digs in w.mgr.stepper.frames.items():
            merged.setdefault(key, []).extend(digs)
    return merged


def _drain(fm, driver='sync', max_ticks=300, **kw):
    return fleet.get_fleet_driver(driver, fm, **kw).run(max_ticks)


@pytest.fixture(scope='module')
def fleet_steppers(small_scene):
    cam0 = orbit_trajectory(1, width=64, height_px=64)[0]
    return [BatchedStepper(small_scene, CFG, cam0, slots=2)
            for _ in range(2)]


# ---------------------------------------------------------------------------
# Pure placement planners
# ---------------------------------------------------------------------------

def test_plan_route_least_loaded_and_sticky_scene():
    pending = ((10, 0), (11, 1), (12, 0))
    routes = fleet.plan_route(pending, {0: 2, 1: 0}, {0, 1})
    assert routes == ((10, 1), (11, 1), (12, 0))
    # a homed scene keeps attracting its viewers even when loaded...
    routes = fleet.plan_route(pending, {0: 2, 1: 0}, {0, 1},
                              scene_home={0: 0})
    assert routes == ((10, 0), (11, 1), (12, 0))
    # ...unless its home is dead
    routes = fleet.plan_route(pending, {1: 0}, {1}, scene_home={0: 0})
    assert routes == ((10, 1), (11, 1), (12, 1))
    with pytest.raises(ValueError):
        fleet.plan_route(pending, {}, set())


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 5), st.lists(st.integers(0, 6), max_size=12),
       st.integers(0, 4))
def test_plan_route_properties(n_alive, scene_ids, load_seed):
    alive = set(range(n_alive))
    pending = tuple((100 + i, sc) for i, sc in enumerate(scene_ids))
    loads = {d: (d * load_seed) % 3 for d in alive}
    routes = fleet.plan_route(pending, loads, alive)
    # deterministic, conserves sids in order, alive targets only
    assert routes == fleet.plan_route(pending, loads, alive)
    assert [sid for sid, _ in routes] == [sid for sid, _ in pending]
    assert all(d in alive for _, d in routes)
    # least-loaded greedy never widens the spread past max(initial, 1)
    final = dict(loads)
    for _, d in routes:
        final[d] += 1
    spread0 = max(loads.values()) - min(loads.values())
    assert max(final.values()) - min(final.values()) <= max(spread0, 1)


def test_plan_rebalance_noop_when_balanced():
    assignments = {0: (1, 2), 1: (3,), 2: (4, 5)}
    assert fleet.plan_rebalance(assignments, {0, 1, 2}) == ()


def test_plan_rebalance_evacuates_dead_then_levels():
    # device 9 is dead: its queued sids must move first, onto alive devices
    assignments = {0: (1, 2, 3, 4), 1: (), 9: (8,)}
    moves = fleet.plan_rebalance(assignments, {0, 1})
    assert moves[0] == (8, 9, 1)
    assert all(dst in {0, 1} for _, _, dst in moves)
    movable = {0: [1, 2, 3, 4], 1: [8]}
    for sid, src, dst in moves[1:]:
        movable[src].remove(sid)
        movable[dst].append(sid)
    assert abs(len(movable[0]) - len(movable[1])) <= 1


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 4), min_size=1, max_size=5),
       st.integers(0, 3), st.integers(1, 2))
def test_plan_rebalance_properties(sizes, dead_n, slack):
    alive = set(range(len(sizes)))
    dead = len(sizes)
    assignments, sid = {}, 0
    for d, n in enumerate(sizes):
        assignments[d] = tuple(range(sid, sid + n))
        sid += n
    if dead_n:
        assignments[dead] = tuple(range(sid, sid + dead_n))
    moves = fleet.plan_rebalance(assignments, alive, slack=slack)
    assert moves == fleet.plan_rebalance(assignments, alive, slack=slack)
    movable = {d: list(assignments[d]) for d in alive}
    for s, src, dst in moves:
        assert dst in alive
        if src in movable:
            movable[src].remove(s)
        movable[dst].append(s)
    # every dead-device sid evacuated onto an alive device
    placed = {s for d in alive for s in movable[d]}
    assert set(assignments.get(dead, ())) <= placed
    # termination invariant: no device still holding movable load sits more
    # than `slack` above the global minimum
    loads = {d: len(movable[d]) for d in alive}
    cands = [d for d in alive if movable[d]]
    if cands:
        assert max(loads[d] for d in cands) - min(loads.values()) <= slack


def test_plan_shrink_prefers_aligned_slots():
    aligned, spilled = fleet.plan_shrink(
        ((7, 0), (8, 1), (9, 1)), {1: (1,), 2: (0, 1)}, {1, 2})
    assert aligned == ((7, 2, 0), (8, 1, 1), (9, 2, 1))
    assert spilled == ()
    aligned, spilled = fleet.plan_shrink(((7, 0), (8, 0)), {1: (0,)}, {1})
    assert aligned == ((7, 1, 0),)
    assert spilled == (8,)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 3), max_size=6), st.integers(1, 3),
       st.integers(0, 3))
def test_plan_shrink_properties(victim_slots, n_alive, mask):
    victims = tuple((200 + i, s) for i, s in enumerate(victim_slots))
    alive = set(range(n_alive))
    free = {d: tuple(s for s in range(4) if (s + d + mask) % 2)
            for d in alive}
    aligned, spilled = fleet.plan_shrink(victims, free, alive)
    assert (aligned, spilled) == fleet.plan_shrink(victims, free, alive)
    # partition of the victims, aligned strictly onto originally-free
    # same-index slots, each (device, slot) used at most once
    assert sorted([s for s, _, _ in aligned] + list(spilled)) \
        == sorted(s for s, _ in victims)
    by_sid = dict(victims)
    seats = [(d, slot) for _, d, slot in aligned]
    assert len(seats) == len(set(seats))
    for s, d, slot in aligned:
        assert d in alive and slot == by_sid[s] and slot in free[d]


def test_get_fleet_driver_rejects_unknown_name():
    with pytest.raises(ValueError, match='unknown fleet driver'):
        fleet.get_fleet_driver('warp', None)


# ---------------------------------------------------------------------------
# Straggler cold-start hardening (the threaded fleet driver's detector)
# ---------------------------------------------------------------------------

def test_straggler_first_observation_seeds_ewma():
    det = StragglerDetector(2)
    det.observe(0, 5.0)
    assert det.stats[0].ewma == 5.0, 'cold start must seed, not zero-mix'


def test_straggler_single_host_never_self_flags():
    det = StragglerDetector(1, patience=1, threshold=1.1)
    for t in (1.0, 9.0, 9.0, 9.0, 9.0):
        det.observe_step({0: t})
    assert not det.flagged, 'a one-host fleet has no one to be slower than'


def test_straggler_metrics_mirror():
    reg = obs_metrics.Registry()
    det = StragglerDetector(4, patience=2, metrics=reg)
    for _ in range(4):
        det.observe_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 4.0})
    assert 3 in det.flagged
    assert reg['straggler.flagged{host=3}'].value == 1
    assert reg['straggler.flagged_total'].value == 1


# ---------------------------------------------------------------------------
# Driver conformance: threaded fleet vs the sync N-device oracle
# ---------------------------------------------------------------------------

def _conformance_run(steppers, driver):
    fm = _make_fleet(steppers)
    for s in _sessions(frames=(3, 3, 3, 2), arrivals=(0, 0, 1, 4),
                       paces=(1, 1, 1, 2)):
        fm.submit(s)
    finished = _drain(fm, driver)
    return fm, _frames_of(fm), finished


def test_threaded_fleet_conforms_to_sync_oracle(fleet_steppers):
    fm_s, frames_s, fin_s = _conformance_run(fleet_steppers, 'sync')
    fm_t, frames_t, fin_t = _conformance_run(fleet_steppers, 'threaded')
    assert frames_s, 'recorder saw no frames'
    assert frames_s == frames_t, 'threaded fleet diverged bitwise'
    assert [s.sid for s in fin_s] == [s.sid for s in fin_t] == [0, 1, 2, 3]
    assert fm_s.tick == fm_t.tick
    assert fm_s.home == fm_t.home, 'routing diverged'
    assert [s.telemetry.frames for s in fin_s] \
        == [s.telemetry.frames for s in fin_t]


def test_threaded_fleet_conforms_to_sync_oracle_pallas(small_scene):
    cfg = dataclasses.replace(CFG, backend='pallas')
    cam0 = orbit_trajectory(1, width=64, height_px=64)[0]
    steppers = [BatchedStepper(small_scene, cfg, cam0, slots=2)
                for _ in range(2)]
    fm_s, frames_s, _ = _conformance_run(steppers, 'sync')
    fm_t, frames_t, _ = _conformance_run(steppers, 'threaded')
    assert frames_s and frames_s == frames_t, \
        'threaded fleet diverged bitwise on the pallas backend'
    assert fm_s.tick == fm_t.tick


# ---------------------------------------------------------------------------
# Live migration
# ---------------------------------------------------------------------------

MIG_FRAMES = (6, 3, 6)   # sid1 drains early, so device 1 runs idle ticks
                         # before the migration lands on it — exercising
                         # the lockstep global_tick clock


@pytest.fixture(scope='module')
def golden_migration(fleet_steppers):
    fm = _make_fleet(fleet_steppers)
    for s in _sessions(frames=MIG_FRAMES):
        fm.submit(s)
    fleet.SyncFleetDriver(fm).run(200)
    frames = _frames_of(fm)
    assert all(len(v) == 1 for v in frames.values())
    return {k: v[0] for k, v in frames.items()}


def test_aligned_migration_is_bit_identical(fleet_steppers,
                                            golden_migration):
    fm = _make_fleet(fleet_steppers)
    for s in _sessions(frames=MIG_FRAMES):
        fm.submit(s)
    for _ in range(4):          # sid1 (device 1) finishes at tick 3
        fm.run_tick()
    assert fm.workers[1].mgr.drained()
    # sid2 sits at device 0 slot 1; slot 1 is free on device 1 -> aligned
    assert fm.migrate(2, 1) == 1
    assert fm.metrics['fleet.migrations{kind=aligned}'].value == 1
    while not fm.drained():
        fm.run_tick()
        assert fm.tick < 200
    frames = {k: v[0] for k, v in _frames_of(fm).items()}
    assert frames == golden_migration, \
        'aligned migration diverged from the never-moved golden run'


def test_cold_migration_conserves_frames(fleet_steppers, golden_migration):
    fm = _make_fleet(fleet_steppers)
    for s in _sessions(frames=MIG_FRAMES):
        fm.submit(s)
    for _ in range(2):
        fm.run_tick()
    # sid0 sits at device 0 slot 0; slot 0 on device 1 is occupied by
    # sid1 -> the move restores cold into the free slot 1
    assert fm.migrate(0, 1) == 1
    assert fm.metrics['fleet.migrations{kind=cold}'].value == 1
    finished = _drain(fm)
    assert [s.sid for s in finished] == [0, 1, 2]
    frames = _frames_of(fm)
    # every frame rendered exactly once (the cursor moved with the viewer)
    for (sid, n) in enumerate(MIG_FRAMES):
        assert {f for (s, f) in frames if s == sid} == set(range(n))
    assert all(len(v) == 1 for v in frames.values())
    assert all(s.telemetry.frames == n
               for s, n in zip(finished, MIG_FRAMES))
    # untouched viewers are unaffected (private scene blocks)
    for key, digs in frames.items():
        if key[0] != 0:
            assert digs[0] == golden_migration[key]


def test_migration_requeues_when_destination_is_full(fleet_steppers):
    fm = _make_fleet(fleet_steppers)
    for s in _sessions(frames=(4, 4, 4, 4)):
        fm.submit(s)
    fm.run_tick()
    assert fm.migrate(0, 1) is None      # both device-1 slots occupied
    assert fm.metrics['fleet.migrations{kind=requeued}'].value == 1
    assert [s.sid for s in fm.pending] == [0]
    assert 0 not in fm.home
    finished = _drain(fm)
    assert [s.sid for s in finished] == [0, 1, 2, 3]
    assert all(s.telemetry.frames == 4 for s in finished)
    frames = _frames_of(fm)
    assert all(len(v) == 1 for v in frames.values()), \
        're-queued viewer re-rendered delivered frames'


def test_migration_rejects_bad_targets(fleet_steppers):
    fm = _make_fleet(fleet_steppers)
    for s in _sessions(frames=(3, 3)):
        fm.submit(s)
    fm.run_tick()
    with pytest.raises(ValueError, match='not alive'):
        fm.migrate(0, 7)
    with pytest.raises(ValueError, match='already on device'):
        fm.migrate(0, fm.home[0])


# ---------------------------------------------------------------------------
# Device loss
# ---------------------------------------------------------------------------

LOSS_FRAMES = (8, 8, 8)
# routing puts sids 0+2 on device 0 (slots 0, 1) and sid 1 on device 1
# (slot 0).  Losing device 0 leaves only slot 1 free on the survivor:
# sid2 restores aligned, sid0 spills to the queue.


@pytest.fixture(scope='module')
def golden_loss(fleet_steppers):
    fm = _make_fleet(fleet_steppers)
    for s in _sessions(frames=LOSS_FRAMES):
        fm.submit(s)
    fleet.SyncFleetDriver(fm).run(200)
    frames = _frames_of(fm)
    assert all(len(v) == 1 for v in frames.values())
    return {k: v[0] for k, v in frames.items()}


def _loss_injector(tick, device=0):
    return faults.FaultInjector(faults.FaultTrace(seed=0, events=(
        faults.FaultEvent(tick=tick, kind='device_loss', slot=device),)))


def test_device_loss_checkpoint_rollback_matches_golden(
        fleet_steppers, golden_loss, tmp_path):
    """The chaos oracle: lose a checkpointed device mid-run; the whole
    fleet rolls back to the last crash-consistent snapshot and every
    surviving or slot-aligned lane replays bit-identically to the
    unfaulted golden run; the spilled lane re-queues at its snapshot
    cursor.  Zero dropped viewers, no double-counted frames."""
    fm = _make_fleet(fleet_steppers, ckpt_root=tmp_path, ckpt_every=2,
                     injector=_loss_injector(tick=5, device=0))
    for s in _sessions(frames=LOSS_FRAMES):
        fm.submit(s)
    finished = _drain(fm)
    # zero dropped viewers; telemetry counts each frame exactly once
    assert [s.sid for s in finished] == [0, 1, 2]
    assert all(s.telemetry.frames == 8 for s in finished)
    m = fm.metrics
    assert m['fleet.device_lost{device=0}'].value == 1
    assert m['fleet.migrations{kind=loss_aligned}'].value == 1
    assert m['fleet.migrations{kind=loss_spilled}'].value == 1
    assert m['fleet.alive_devices'].value == 1
    frames = _frames_of(fm)
    # survivor (sid1, restored own snapshot) and aligned victim (sid2,
    # restored from the dead device's snapshot): every rendering — the
    # pre-loss original AND the rolled-back replay — equals golden
    for sid in (1, 2):
        assert any(len(frames[(sid, f)]) > 1 for f in range(8)), \
            f'sid {sid}: rollback never replayed a frame'
        for f in range(8):
            assert all(d == golden_loss[(sid, f)]
                       for d in frames[(sid, f)]), \
                f'sid {sid} frame {f} diverged from golden'
    # spilled victim: full coverage from its snapshot cursor; its cold
    # re-admission re-sorts, so its continuation carries at most one
    # sort-window of sharing staleness (the fresh-admission bound) and is
    # not required to match golden bitwise
    assert {f for (s, f) in frames if s == 0} == set(range(8))
    for f in range(4):          # pre-divergence frames still match
        assert frames[(0, f)][0] == golden_loss[(0, f)]


def test_device_loss_cold_recovery_requeues_at_cursor(
        fleet_steppers, golden_loss):
    """No checkpoints: host cursors are crash-consistent in-process, so
    victims re-admit cold at their current frame — delivered frames are
    never re-rendered."""
    fm = _make_fleet(fleet_steppers, injector=_loss_injector(tick=3))
    for s in _sessions(frames=LOSS_FRAMES):
        fm.submit(s)
    finished = _drain(fm)
    assert [s.sid for s in finished] == [0, 1, 2]
    assert all(s.telemetry.frames == 8 for s in finished)
    assert fm.metrics['fleet.requeued'].value == 2
    assert fm.metrics['fleet.alive_devices'].value == 1
    frames = _frames_of(fm)
    assert all(len(v) == 1 for v in frames.values()), \
        'cold recovery re-rendered a delivered frame'
    for sid, n in enumerate(LOSS_FRAMES):
        assert {f for (s, f) in frames if s == sid} == set(range(n))
    # frames rendered before the loss are the golden frames
    for sid in range(3):
        for f in range(3):
            assert frames[(sid, f)][0] == golden_loss[(sid, f)]


def test_restore_at_launch_resumes_fleet(fleet_steppers, tmp_path):
    """Kill the whole fleet between ticks and relaunch with ``--restore``
    semantics: ``restore_at_launch`` adopts the newest checkpoint step
    COMMON to every device worker, every restored lane replays
    bit-identically to the unfaulted golden run, and every viewer still
    delivers every frame."""
    frames = (6, 6, 6)
    fm_g = _make_fleet(fleet_steppers)
    for s in _sessions(frames=frames):
        fm_g.submit(s)
    assert [s.sid for s in _drain(fm_g)] == [0, 1, 2]
    golden = {k: v[0] for k, v in _frames_of(fm_g).items()}

    # victim: checkpoint every 2 ticks, die between ticks (SIGKILL)
    fm_v = _make_fleet(fleet_steppers, ckpt_root=tmp_path, ckpt_every=2)
    for s in _sessions(frames=frames):
        fm_v.submit(s)
    while fm_v.tick < 5:
        fm_v.run_tick()
    for w in fm_v.workers:
        w.mgr._ckpt.wait()

    # survivor: fresh fleet, restore at launch instead of submitting
    fm_s = _make_fleet(fleet_steppers, ckpt_root=tmp_path, ckpt_every=2)
    restored = fm_s.restore_at_launch(_sessions(frames=frames))
    assert restored is not None and restored >= 2, restored
    assert fm_s.metrics['fleet.restores'].value == 1
    finished = _drain(fm_s)
    assert sorted(s.sid for s in finished) == [0, 1, 2]
    # fresh session objects only render the continuation — delivery is
    # complete (cursor at the end), not re-counted from frame 0
    assert all(s.cursor == 6 for s in finished)
    assert all(0 < s.telemetry.frames <= 6 for s in finished)
    cont = _frames_of(fm_s)
    for sid in range(3):
        covered = {f for (s, f) in cont if s == sid}
        assert max(covered) == 5, f'sid {sid} never reached its last frame'
        for f in covered:
            assert all(d == golden[(sid, f)] for d in cont[(sid, f)]), \
                f'sid {sid} frame {f} diverged from golden after restore'


def test_restore_at_launch_without_common_step_returns_none(
        fleet_steppers, tmp_path):
    """One worker with no usable snapshot (or no overlap in steps) means
    no crash-consistent fleet state: restore_at_launch refuses rather
    than resuming workers at different ticks."""
    fm_v = _make_fleet(fleet_steppers, ckpt_root=tmp_path, ckpt_every=2)
    for s in _sessions(frames=(6, 6, 6)):
        fm_v.submit(s)
    while fm_v.tick < 5:
        fm_v.run_tick()
    for w in fm_v.workers:
        w.mgr._ckpt.wait()
    # wipe one device's snapshots: no common step remains
    import shutil
    shutil.rmtree(tmp_path / 'device1')
    fm_s = _make_fleet(fleet_steppers, ckpt_root=tmp_path, ckpt_every=2)
    assert fm_s.restore_at_launch(_sessions(frames=(6, 6, 6))) is None


def test_loss_of_last_device_is_refused(fleet_steppers):
    fm = _make_fleet(fleet_steppers[:1], injector=_loss_injector(tick=1))
    for s in _sessions(frames=(3,)):
        fm.submit(s)
    with pytest.warns(RuntimeWarning, match='last alive device'):
        finished = _drain(fm)
    assert [s.sid for s in finished] == [0]
    assert fm.metrics['fleet.device_loss_ignored'].value == 1


def test_degraded_fleet_sheds_new_load_not_accepted_viewers(fleet_steppers):
    """Bounded admission under degraded capacity: excess arrivals shed
    (recorded + counted), every accepted viewer drains to completion."""
    fm = _make_fleet(fleet_steppers, max_pending=3,
                     injector=_loss_injector(tick=2))
    accepted = [fm.submit(s) for s in _sessions(
        frames=(4,) * 6, arrivals=(0, 0, 6, 6, 6, 6))]
    assert accepted == [True, True, True, False, False, False]
    assert [s.sid for s in fm.shed] == [3, 4, 5]
    assert fm.metrics['fleet.shed'].value == 3
    finished = _drain(fm)
    assert [s.sid for s in finished] == [0, 1, 2], \
        'an accepted viewer was dropped under degraded capacity'
    assert all(s.telemetry.frames == 4 for s in finished)
    assert len(fm.alive) == 1
    agg = fm.aggregate()
    assert agg['devices'] == 2 and agg['alive_devices'] == 1
    assert agg['shed'] == 3
