"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Contract: bit-comparable semantics (fp32 allclose) for every mode —
full / prefix(stop-at-k) / resume — plus the set-associative lookup."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import radiance_cache as rc
from repro.core.gaussians import TRANSMITTANCE_EPS
from repro.kernels import ops
from repro.kernels import rasterize as rk
from repro.kernels import rc_lookup as lk
from repro.kernels import ref


def _random_tiles(key, t, k, *, tiles_x=4, spread=60.0):
    ks = jax.random.split(key, 6)
    mean2d = jax.random.uniform(ks[0], (t, k, 2), minval=-4.0,
                                maxval=spread + 4.0)
    # random positive-definite conics
    a = jax.random.uniform(ks[1], (t, k), minval=0.02, maxval=0.35)
    c = jax.random.uniform(ks[2], (t, k), minval=0.02, maxval=0.35)
    b = jax.random.uniform(ks[3], (t, k), minval=-0.05, maxval=0.05)
    b = jnp.clip(b, -0.9 * jnp.sqrt(a * c), 0.9 * jnp.sqrt(a * c))
    conic = jnp.stack([a, b, c], axis=-1)
    color = jax.random.uniform(ks[4], (t, k, 3))
    opacity = jax.random.uniform(ks[5], (t, k), minval=0.1, maxval=0.95)
    ids = jnp.tile(jnp.arange(k, dtype=jnp.int32)[None], (t, 1))
    # sprinkle padding at the tail
    ids = jnp.where(jnp.arange(k)[None, :] < k - 2, ids, -1)
    return mean2d, conic, color, opacity, ids


def _baseline_state(t, k_record):
    p = rk.P
    return (jnp.zeros((t, p, 3), jnp.float32),
            jnp.ones((t, p), jnp.float32),
            jnp.full((t, p, k_record), -1, jnp.int32),
            jnp.zeros((t, p), jnp.int32),
            jnp.zeros((t, p), jnp.int32),
            jnp.ones((t, p), jnp.int32))


def _assert_state_close(a: rk.RasterState, b: rk.RasterState):
    np.testing.assert_allclose(np.asarray(a.acc), np.asarray(b.acc),
                               atol=3e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(a.trans), np.asarray(b.trans),
                               atol=3e-5, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(a.record), np.asarray(b.record))
    np.testing.assert_array_equal(np.asarray(a.rec_cnt), np.asarray(b.rec_cnt))
    np.testing.assert_array_equal(np.asarray(a.n_sig), np.asarray(b.n_sig))
    np.testing.assert_array_equal(np.asarray(a.n_iter), np.asarray(b.n_iter))
    np.testing.assert_array_equal(np.asarray(a.iter_at_k),
                                  np.asarray(b.iter_at_k))


@pytest.mark.parametrize('t,k,chunk', [(1, 32, 16), (4, 64, 32),
                                       (9, 128, 64), (4, 64, 64)])
@pytest.mark.parametrize('stop_at_k', [False, True])
def test_rasterize_kernel_vs_ref_sweep(t, k, chunk, stop_at_k):
    key = jax.random.PRNGKey(t * 1000 + k + chunk)
    feats = _random_tiles(key, t, k, tiles_x=int(np.ceil(np.sqrt(t))))
    state = _baseline_state(t, 5)
    tiles_x = int(np.ceil(np.sqrt(t)))
    got = rk.rasterize_pallas(*feats, *state, tiles_x=tiles_x, k_record=5,
                              chunk=chunk, stop_at_k=stop_at_k,
                              interpret=True)
    want = ref.rasterize_ref(*feats, *state, tiles_x=tiles_x, k_record=5,
                             chunk=chunk, stop_at_k=stop_at_k)
    _assert_state_close(got, want)


@pytest.mark.parametrize('k_record', [1, 3, 5, 8])
def test_rasterize_kernel_k_record_sweep(k_record):
    key = jax.random.PRNGKey(k_record)
    t, k, chunk = 4, 64, 32
    feats = _random_tiles(key, t, k)
    p = rk.P
    state = (jnp.zeros((t, p, 3), jnp.float32),
             jnp.ones((t, p), jnp.float32),
             jnp.full((t, p, k_record), -1, jnp.int32),
             jnp.zeros((t, p), jnp.int32),
             jnp.zeros((t, p), jnp.int32),
             jnp.ones((t, p), jnp.int32))
    got = rk.rasterize_pallas(*feats, *state, tiles_x=2, k_record=k_record,
                              chunk=chunk, stop_at_k=True, interpret=True)
    want = ref.rasterize_ref(*feats, *state, tiles_x=2, k_record=k_record,
                             chunk=chunk, stop_at_k=True)
    _assert_state_close(got, want)


def test_prefix_resume_composes_to_full():
    """phase A (stop at k) + phase B (resume all pixels) == full pass."""
    key = jax.random.PRNGKey(42)
    t, k, chunk = 4, 64, 32
    feats_raw = _random_tiles(key, t, k)
    from repro.core.tiling import TileFeatures
    feats = TileFeatures(*feats_raw)
    full, aux_full, _ = ops.rasterize_full(feats, 2, chunk=chunk,
                                           interpret=True)
    st_a = ops.rasterize_prefix(ops.pad_features(feats, chunk), 2,
                                chunk=chunk, interpret=True)
    miss = jnp.ones(st_a.trans.shape, bool)   # everyone resumes
    colors, aux, _ = ops.rasterize_resume(
        ops.pad_features(feats, chunk), 2, st_a, miss, chunk=chunk,
        interpret=True)
    # pixels whose record filled must end at the same color; pixels whose
    # record never filled completed already in phase A
    np.testing.assert_allclose(np.asarray(colors), np.asarray(full),
                               atol=3e-5, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(aux.n_iterated),
                                  np.asarray(aux_full.n_iterated))


def test_kernel_early_exit_saves_chunks():
    """Opaque front gaussians terminate all pixels -> fewer chunks."""
    key = jax.random.PRNGKey(7)
    t, k, chunk = 1, 128, 16
    mean2d, conic, color, opacity, ids = _random_tiles(key, t, k, tiles_x=1,
                                                       spread=14.0)
    opacity = jnp.full_like(opacity, 0.999)    # near-opaque everywhere
    conic = jnp.tile(jnp.asarray([0.001, 0.0, 0.001])[None, None],
                     (t, k, 1))                # huge footprint covers tile
    state = _baseline_state(t, 5)
    st = rk.rasterize_pallas(mean2d, conic, color, opacity, ids, *state,
                             tiles_x=1, k_record=5, chunk=chunk,
                             interpret=True)
    assert int(st.chunks[0, 0]) < k // chunk, \
        f'no early exit: {int(st.chunks[0, 0])} of {k // chunk} chunks ran'


@pytest.mark.parametrize('g,b,sets,ways,kk', [(1, 64, 16, 2, 3),
                                              (4, 128, 64, 4, 5),
                                              (2, 256, 32, 4, 2)])
def test_rc_lookup_kernel_vs_ref(g, b, sets, ways, kk):
    cfg = rc.CacheConfig(n_sets=sets, n_ways=ways, k=kk)
    key = jax.random.PRNGKey(g * 10 + b)
    cache = rc.init_cache(g, cfg)
    # seed the cache with half the queries
    ids = jax.random.randint(key, (g, b, kk), 0, 200).astype(jnp.int32)
    rgb = jax.random.uniform(jax.random.PRNGKey(1), (g, b, 3))
    do = jnp.arange(b)[None, :].repeat(g, 0) % 2 == 0
    cache = rc.insert_all_groups(cache, ids, rgb, do, cfg)

    got = lk.rc_lookup_pallas(cache.tags, cache.values, ids, cfg,
                              query_chunk=min(64, b), interpret=True)
    want = ref.rc_lookup_ref(cache.tags, cache.values, ids, cfg)
    for a, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(w))
    # hits happen; the exact rate depends on slots vs inserts (tiny caches
    # evict), so only require agreement above plus a nonzero floor
    assert np.asarray(got[0]).mean() > 0.1


def test_kernel_rc_path_matches_functional(small_scene, cams64):
    """ops.rasterize_with_rc == pipeline rc path (same cache cfg), on a
    real projected scene."""
    from repro.core.groups import num_groups, regroup, ungroup
    from repro.core.pipeline import LuminaConfig, rc_apply
    from repro.core.projection import project
    from repro.core.rasterize import rasterize_tiles
    from repro.core.sorting import sort_scene
    from repro.core.tiling import gather_tile_features

    cam = cams64[0]
    cfg = LuminaConfig(capacity=128)
    proj = project(small_scene, cam)
    lists = sort_scene(proj, cam.width, cam.height, cfg.capacity)
    feats = gather_tile_features(proj, lists)

    # functional path
    colors_f, aux_f = rasterize_tiles(feats, lists.tiles_x,
                                      k_record=cfg.k_record)
    cache_f = rc.init_cache(num_groups(64, 64, cfg.group_tiles), cfg.cache)
    final_f, cache_f, hit_f, _ = rc_apply(cache_f, colors_f, aux_f,
                                          lists.tiles_x, lists.tiles_y, cfg)

    # kernel path
    cache_k = rc.init_cache(num_groups(64, 64, cfg.group_tiles), cfg.cache)
    final_k, cache_k, aux_k, st = ops.rasterize_with_rc(
        feats, lists.tiles_x, lists.tiles_y, cache_k, cfg.cache,
        cfg.group_tiles, k_record=cfg.k_record, chunk=32, interpret=True)

    np.testing.assert_allclose(np.asarray(final_k), np.asarray(final_f),
                               atol=3e-5, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(cache_k.tags),
                                  np.asarray(cache_f.tags))
