"""End-to-end integration: LuminSys frames, hwmodel orderings, train/serve
drivers, gradient compression in a step, roofline table construction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hwmodel
from repro.core.metrics import psnr
from repro.core.pipeline import LuminaConfig, LuminSys, render_frame_baseline


def test_luminsys_hits_after_first_frame(small_scene, cams64):
    cfg = LuminaConfig(capacity=256, window=3)
    sys_ = LuminSys(small_scene, cfg, cams64[0])
    hits = []
    for cam in cams64:
        _, st = sys_.step(cam)
        hits.append(float(st.hit_rate))
    assert hits[0] == 0.0                  # cold cache
    assert all(h > 0.3 for h in hits[1:])  # warm: temporal coherence pays
    # paper: ~55% of color integration avoided; ours is scene-dependent
    # but must be materially positive
    _, st = sys_.step(cams64[-1])
    assert float(st.saved_frac) > 0.15


def test_luminsys_sorts_once_per_window(small_scene, cams64):
    cfg = LuminaConfig(capacity=256, window=3, use_rc=False)
    sys_ = LuminSys(small_scene, cfg, cams64[0])
    flags = [float(sys_.step(cam)[1].sorted_this_frame) for cam in cams64]
    assert flags == [1.0, 0.0, 0.0, 1.0, 0.0, 0.0]


def test_hwmodel_orderings(small_scene, cams64):
    """The qualitative claims of Fig. 22 hold on measured stats:
    Lumina fastest; NRU >= GPU; RC-GPU does not beat plain GPU much;
    all accelerator variants cut energy."""
    cfg = LuminaConfig(capacity=256, window=6)
    sys_ = LuminSys(small_scene, cfg, cams64[0])
    stats = []
    for cam in cams64:
        _, st = sys_.step(cam)
        _, colors, aux, lists = render_frame_baseline(small_scene, cam, cfg)
        stats.append(hwmodel.measure_frame(
            lists, aux, hit_rate=float(st.hit_rate),
            sorted_this_frame=1.0 / cfg.window))
    table = hwmodel.evaluate_variants(stats)
    sp = {v: m['speedup'] for v, m in table.items()}
    en = {v: m['norm_energy'] for v, m in table.items()}
    assert sp['Lumina'] >= sp['S2-Acc'] >= sp['NRU+GPU'] > 1.0
    assert sp['Lumina'] > sp['GPU'] == 1.0
    assert sp['RC-GPU'] < sp['NRU+GPU']    # GPU can't harvest RC sparsity
    assert en['Lumina'] < en['NRU+GPU'] < 1.0
    assert 0 < sp['GSCore'] < sp['Lumina']


def test_masked_fraction_matches_paper_ballpark(small_scene, cams64):
    """Sec. 2.2: threads masked most of the time; sig fraction ~10%."""
    cfg = LuminaConfig(capacity=256)
    _, colors, aux, lists = render_frame_baseline(small_scene, cams64[0], cfg)
    s = hwmodel.measure_frame(lists, aux)
    assert 0.5 < s.masked_fraction < 0.99
    assert 0.02 < s.sig_fraction < 0.5


def test_train_driver_loss_decreases(tmp_path):
    from repro.launch.train import train
    # warmup sized to the run: the default (20) would leave the effective lr
    # near zero for all 8 steps and the loss in the noise
    _, _, hist = train('smollm-360m', steps=8, batch=2, seq=64,
                       lr=3e-3, warmup=2, log_every=0, print_fn=lambda *a: None)
    assert hist[-1] < hist[0]


def test_serve_driver_drains():
    from repro.launch.serve import run
    stats = run('smollm-360m', slots=2, n_requests=3, prompt_len=4,
                max_new=4, max_seq=32, print_fn=lambda *a: None)
    assert stats['requests'] == 3 and stats['ticks'] > 0


def test_grad_compression_in_training_step():
    """int8 error-feedback compression keeps a toy model training."""
    from repro.optim import adam, compression
    key = jax.random.PRNGKey(0)
    w = {'w': jax.random.normal(key, (16, 16)) * 0.1}
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    y = x @ jax.random.normal(jax.random.PRNGKey(2), (16, 16))

    cfg = adam.AdamConfig(lr=1e-2)
    state = adam.init(w, cfg)
    residual = compression.init_residuals(w)
    losses = []
    for _ in range(60):
        loss, g = jax.value_and_grad(
            lambda p: jnp.mean((x @ p['w'] - y) ** 2))(w)
        comp, residual = compression.compress_tree(g, residual)
        g = compression.decompress_tree(comp)
        w, state, _ = adam.step(w, g, state, cfg)
        losses.append(float(loss))
    assert losses[-1] < 0.6 * losses[0], (losses[0], losses[-1])


def test_roofline_row_roundtrip():
    from repro.analysis import roofline as rl
    r = rl.Roofline(arch='x', shape='train_4k', mesh='single', chips=256,
                    flops_per_chip=1e12, bytes_per_chip=1e9,
                    coll_bytes_per_chip=1e8,
                    coll_bytes_crosspod_per_chip=0.0,
                    collective_counts={'all-reduce': 3},
                    model_flops=2e14).finalize()
    assert r.bottleneck == 'compute'
    row = r.row()
    assert 0 < row['roofline_fraction'] <= 1.0
    assert row['useful_ratio'] == pytest.approx(2e14 / (1e12 * 256))
    print(rl.fmt_table([row]))
