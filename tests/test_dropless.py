"""Dropless slot allocation: power-of-two capacity buckets, slot
oversubscription, and the invariants that make them safe.

The dropless refactor (PR 9) replaces the static worst-case per-scene sort
pools (``pool_size = V`` entries, mostly dead) with power-of-two capacity
buckets recomputed from live refcounts, and lets paced viewers whose render
ticks provably never collide interleave through one physical slot.  These
tests pin the contract:

* ``pow2_bucket`` — the bucket helper's edge cases;
* **bit identity** — a dynamically-bucketed run renders the exact same
  per-viewer images, cache tags, LRU ages and sort cadence as the static
  worst-case pool (capacity is an allocation concern, never a semantic
  one), while allocating strictly less;
* **reclamation** — evicting the last viewer of a scene frees its pool
  entries: capacity shrinks back once the refcount drops and the freshness
  window expires;
* **oversubscription** — co-residents admitted under the CRT
  non-collision check all finish, on both host drivers, and quarantining
  a poisoned physical slot forces every stashed co-resident through a
  fresh sort on return;
* **crash consistency** — a snapshot taken at a grown capacity (with
  stashed co-residents) restores into a freshly built stepper whose pool
  is still at its initial capacity, bit-identically;
* a property sweep: any admit/release/step schedule leaves every active
  viewer's pool entry in bounds and referenced (grow/shrink never orphans
  a lane).

Under the real ``hypothesis`` package (CI) the sweep explores the strategy
space; under the conftest shim it runs deterministic examples and reports
as skipped.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.buckets import pow2_bucket
from repro.core.pipeline import LuminaConfig
from repro.checkpoint.manager import CheckpointManager
from repro.data.trajectory import orbit_trajectory
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper

CFG = LuminaConfig(capacity=256, window=3)


def _trajs(n, frames, width=48, spread=85.0):
    # distinct start angles -> distinct pose cells -> distinct pool entries
    return [orbit_trajectory(frames, width=width, height_px=width,
                             start_deg=spread * i + 7.0) for i in range(n)]


# ------------------------------------------------------ pow2 buckets ----

def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (0, 1, 2, 3, 4, 5, 8, 9)] == \
        [1, 1, 2, 4, 4, 8, 8, 16]
    assert pow2_bucket(-3) == 1
    # cap clamps (and need not itself be a power of two)
    assert pow2_bucket(9, cap=8) == 8
    assert pow2_bucket(2, cap=8) == 2
    assert pow2_bucket(5, cap=6) == 6
    with pytest.raises(ValueError):
        pow2_bucket(1, cap=0)


# ---------------------------------------- dynamic == static, cheaper ----

def _paced_stepper_run(stepper, trajs, ticks):
    """Drive a pace-2 interleave directly: even ticks render the first
    half of the slots, odd ticks the second half — the paced workload the
    capacity buckets are sized by.  Returns per-tick outputs."""
    half = len(trajs) // 2
    for slot in range(len(trajs)):
        stepper.admit(slot)
    outs = []
    for t in range(ticks):
        slots = range(0, half) if t % 2 == 0 else range(half, len(trajs))
        cams = {s: trajs[s][t // 2] for s in slots}
        outs.append(stepper.step(cams))
    return outs


@pytest.mark.parametrize('backend,viewers,frames',
                         [('reference', 4, 4), ('pallas', 2, 3)])
def test_dynamic_pool_bit_identical_to_static(small_scene, backend,
                                              viewers, frames):
    cfg = LuminaConfig(capacity=256, window=3, backend=backend)
    trajs = _trajs(viewers, frames, width=32 if backend == 'pallas' else 48)
    cam0 = trajs[0][0]
    static = BatchedStepper(small_scene, cfg, cam0, viewers,
                            viewers_per_scene=viewers, pool_size=viewers)
    dynamic = BatchedStepper(small_scene, cfg, cam0, viewers,
                             viewers_per_scene=viewers)
    assert static.pool_cap == viewers and dynamic.pool_cap == 1
    out_s = _paced_stepper_run(static, trajs, 2 * frames)
    out_d = _paced_stepper_run(dynamic, trajs, 2 * frames)
    for tick, (os_, od) in enumerate(zip(out_s, out_d)):
        assert os_.keys() == od.keys()
        for slot in os_:
            img_s, st_s, _ = os_[slot]
            img_d, st_d, _ = od[slot]
            np.testing.assert_array_equal(
                np.asarray(img_s), np.asarray(img_d),
                err_msg=f'{backend}: slot {slot} tick {tick}')
            assert float(st_s.hit_rate) == float(st_d.hit_rate)
    # sort cadence and cache decisions are bit-unchanged too
    assert static.sort_log == dynamic.sort_log
    for field in ('tags', 'age', 'clock'):
        np.testing.assert_array_equal(
            np.asarray(getattr(static.shared.cache, field)),
            np.asarray(getattr(dynamic.shared.cache, field)),
            err_msg=f'{backend}: cache {field}')
    # ... while the buckets allocate strictly less than the reservation
    # would (distinct cells per viewer -> the pool did have to grow)
    assert dynamic.pool_cap > 1
    sm_d, sm_s = dynamic.state_metrics(), static.state_metrics()
    assert sm_d['state_reserved_bytes'] == sm_s['state_alloc_bytes']
    if dynamic.pool_cap < viewers:
        assert sm_d['state_alloc_bytes'] < sm_d['state_reserved_bytes']


def test_evict_last_viewer_frees_entries(small_scene):
    """Releasing a scene's viewers drops their entries' refcounts; once the
    freshness window expires the pool compacts back down."""
    trajs = _trajs(4, 6)
    stepper = BatchedStepper(small_scene, CFG, trajs[0][0], 4,
                             viewers_per_scene=4)
    for slot in range(4):
        stepper.admit(slot)
    for f in range(2):
        stepper.step({s: trajs[s][f] for s in range(4)})
    grown = stepper.pool_cap
    assert grown >= 4, 'distinct cells must each hold an entry'
    alloc_grown = stepper.state_metrics()['state_alloc_bytes']
    # viewers 1..3 leave; only slot 0 keeps rendering
    for slot in (1, 2, 3):
        stepper.release(slot)
    for f in range(2, 2 + CFG.window + 1):
        stepper.step({0: trajs[0][f]})
    assert stepper.pool_cap == 1, (
        f'pool stuck at {stepper.pool_cap} entries after the last '
        f'co-viewers left')
    assert stepper.state_metrics()['state_alloc_bytes'] < alloc_grown
    # the surviving viewer still references a live in-bounds entry
    entry = int(stepper._slot_pool[0])
    assert 0 <= entry < stepper.pool_cap
    assert stepper._refs[0, entry] > 0


# ------------------------------------------------- oversubscription -----

def _oversub_manager(scene, frames, viewers=4, slots=2):
    trajs = _trajs(viewers, frames)
    stepper = BatchedStepper(scene, CFG, trajs[0][0], slots,
                             viewers_per_scene=slots)
    mgr = SessionManager(stepper, slots, oversubscribe=True)
    sessions = [ViewerSession(sid=i, cams=trajs[i], pace=2)
                for i in range(viewers)]
    return mgr, stepper, sessions


@pytest.mark.parametrize('driver', ['sync', 'threaded'])
def test_oversubscription_serves_double_population(small_scene, driver):
    """4 pace-2 viewers on 2 physical slots: the CRT admission check pins
    co-residents to disjoint residue classes, every session finishes with
    its full trajectory, and the slots really were shared."""
    frames = 5
    mgr, stepper, sessions = _oversub_manager(small_scene, frames)
    for s in sessions:
        mgr.submit(s)
    finished = mgr.run(driver=driver)
    assert sorted(s.sid for s in finished) == [0, 1, 2, 3]
    assert all(s.telemetry.frames == frames for s in finished)
    assert mgr.metrics['serve.oversubscribed'].value >= 2
    # 4 viewers finished on 2 slots in about pace * frames ticks — far
    # under the >= 2x ticks a non-oversubscribed 2-slot run would need
    assert mgr.tick <= 2 * frames + 4


def test_quarantine_invalidates_stashed_coresidents(small_scene):
    """A poisoned physical slot's stashed co-residents may reference an
    invalidated pool entry: quarantine must force them through a fresh
    sort on their next turn (and the run must still drain)."""
    mgr, stepper, sessions = _oversub_manager(small_scene, frames=6)
    for s in sessions:
        mgr.submit(s)
    for _ in range(4):   # far enough in for stashes to exist
        mgr.run_tick()
        mgr.evict_finished()
    assert stepper._stash, 'no stashed co-residents to quarantine'
    key, ctx = next(iter(stepper._stash.items()))
    ctx['pending_sort'] = False   # pretend its entry was adopted fresh
    stepper.quarantine(ctx['slot'])
    assert all(c['pending_sort'] for c in stepper._stash.values()
               if c['slot'] == ctx['slot']), (
        'quarantine left a stashed co-resident trusting a dead entry')
    finished = mgr.run()
    assert sorted(s.sid for s in finished) == [0, 1, 2, 3]


def test_checkpoint_roundtrip_at_grown_capacity(small_scene, tmp_path):
    """Kill/restore with the pool grown past its initial bucket and lanes
    stashed: the manifest's geometry builds the shape template, and the
    continuation is bit-identical to the uninterrupted run."""
    frames = 8

    def build():
        return _oversub_manager(small_scene, frames)

    # golden: uninterrupted run
    mgr, stepper, sessions = build()
    for s in sessions:
        mgr.submit(s)
    mgr.run()
    golden = {f: np.asarray(getattr(stepper.shared.cache, f))
              for f in ('tags', 'age', 'clock')}
    golden_ticks = mgr.tick

    # victim: checkpoint every 3 ticks, die mid-run
    mgr, stepper, sessions = build()
    mgr.enable_checkpoints(CheckpointManager(tmp_path, keep=5), every=3)
    for s in sessions:
        mgr.submit(s)
    while not mgr.drained() and mgr.tick < 7:
        mgr.run_tick()
        mgr.evict_finished()
        mgr.maybe_checkpoint()
    assert not mgr.drained(), 'kill point must land mid-run'
    mgr._ckpt.wait()
    assert stepper.pool_cap > 1, 'snapshot must capture a grown pool'

    # survivor: fresh stepper (pool back at capacity 1), restore, finish
    mgr2, stepper2, _ = build()
    restored = mgr2.restore_serving(CheckpointManager(tmp_path),
                                    [ViewerSession(sid=s.sid, cams=s.cams,
                                                   pace=2)
                                     for s in sessions])
    assert restored == 6
    assert stepper2.pool_cap > 1, 'restore must adopt the snapshot geometry'
    finished = mgr2.run()
    assert sorted(s.sid for s in finished) == [0, 1, 2, 3]
    assert mgr2.tick == golden_ticks
    for f, want in golden.items():
        np.testing.assert_array_equal(
            np.asarray(getattr(stepper2.shared.cache, f)), want,
            err_msg=f'cache {f} diverged after restore')


# ------------------------------------------------------ property sweep --

_OPS = st.lists(
    st.tuples(st.sampled_from(('admit', 'release', 'step', 'step')),
              st.integers(min_value=0, max_value=3),
              st.integers(min_value=0, max_value=9)),
    min_size=4, max_size=14)


@settings(max_examples=8, deadline=None)
@given(_OPS)
def test_route_grow_shrink_never_orphans(ops):
    """Any admit/release/step schedule: after every tick, each active
    viewer's pool entry is in bounds and referenced — growth, shrink and
    compaction never strand a lane pointing at freed storage.

    Fixture-free (the scene builds lazily in the shared-stepper cache):
    the conftest hypothesis shim does not preserve signatures, so pytest
    cannot inject fixtures into ``@given``-wrapped tests."""
    trajs = _trajs(4, 10, width=32)
    stepper = _orphan_stepper(trajs[0][0])
    stepper.reset()
    active: set = set()
    cursor = {s: 0 for s in range(4)}
    for kind, slot, jitter in ops:
        if kind == 'admit':
            stepper.admit(slot)
            active.add(slot)
            cursor[slot] = jitter % 5
        elif kind == 'release':
            stepper.release(slot)
            active.discard(slot)
        elif active:
            cams = {s: trajs[s][(cursor[s] + jitter) % 10]
                    for s in sorted(active)}
            stepper.step(cams)
            for s in active:
                cursor[s] += 1
            for s in active:
                entry = int(stepper._slot_pool[s])
                scene_i = int(stepper._scene_of[s])
                assert 0 <= entry < stepper.pool_cap, (
                    f'slot {s} points past capacity: entry {entry} of '
                    f'{stepper.pool_cap}')
                assert stepper._refs[scene_i, entry] > 0, (
                    f'slot {s} references freed entry {entry}')
                cell = stepper._pool_cell[scene_i, entry]
                assert cell != -1, (
                    f'slot {s} references an unkeyed entry {entry}')


_ORPHAN_STEPPER = {}


def _orphan_stepper(cam0):
    """One compiled stepper shared by every hypothesis example (reset per
    example): construction + jit dominate; examples only pay the steps."""
    if 'stepper' not in _ORPHAN_STEPPER:
        import jax
        from repro.data.scenes import structured_scene
        scene = structured_scene(jax.random.PRNGKey(0), 400)
        _ORPHAN_STEPPER['stepper'] = BatchedStepper(
            scene, CFG, cam0, 4, viewers_per_scene=4)
    return _ORPHAN_STEPPER['stepper']
