"""Straggler detection, elastic re-meshing, pipeline parallelism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.runtime.elastic import ElasticRunner, plan_remesh
from repro.runtime.straggler import StragglerDetector


def test_straggler_flags_persistent_slow_host():
    events = []
    det = StragglerDetector(8, threshold=1.25, patience=3,
                            on_straggler=lambda h, e, m: events.append(h))
    for step in range(10):
        timings = {h: 1.0 for h in range(8)}
        timings[3] = 2.0   # persistently 2x slower
        det.observe_step(timings)
    assert 3 in det.flagged and events and events[0] == 3
    assert det.healthy_hosts() == [0, 1, 2, 4, 5, 6, 7]


def test_straggler_ignores_transient_blip():
    det = StragglerDetector(4, patience=3)
    for step in range(10):
        timings = {h: 1.0 for h in range(4)}
        if step == 4:
            timings[1] = 5.0   # one-off GC pause
        det.observe_step(timings)
    assert not det.flagged


def test_straggler_recovers():
    det = StragglerDetector(4, patience=2, alpha=0.9)
    for _ in range(5):
        det.observe_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 3.0})
    assert 3 in det.flagged
    for _ in range(10):
        det.observe_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0})
    assert 3 not in det.flagged


def test_plan_remesh_basic():
    plan = plan_remesh(256, 16, model=16)
    assert plan.shape == (8, 16)      # 240 survivors -> largest divisor data'
    assert plan.grad_accum_factor == 2
    assert plan.devices_used == 128


def test_plan_remesh_keeps_model_axis():
    with pytest.raises(ValueError):
        plan_remesh(16, 8, model=16)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 32).map(lambda x: 16 * x), st.integers(0, 200))
def test_plan_remesh_properties(total, failed):
    model = 16
    if total - failed < model:
        with pytest.raises(ValueError):
            plan_remesh(total, failed, model=model)
        return
    plan = plan_remesh(total, failed, model=model)
    old_data = total // model
    new_data = plan.shape[0]
    # invariants: fits survivors, model preserved, global batch divides
    assert plan.devices_used <= total - failed
    assert plan.shape[1] == model
    assert old_data % new_data == 0
    assert plan.grad_accum_factor * new_data == old_data


def test_elastic_runner_fail_recover():
    r = ElasticRunner(256, 16)
    p1 = r.step_failure([3, 7])
    assert p1.shape[0] < 16
    p2 = r.step_recovery([3, 7])
    assert p2.shape == (16, 16)


def test_elastic_reshard_roundtrip():
    """Host-restored state re-placed on a smaller mesh keeps its values."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.runtime.elastic import build_mesh, reshard_tree
    plan = plan_remesh(1, 0, model=1, axes=('data', 'model'))
    mesh = build_mesh(plan)
    tree = {'w': np.arange(8.0).reshape(4, 2)}
    out = reshard_tree(tree, {'w': P('data', None)}, mesh)
    np.testing.assert_allclose(np.asarray(out['w']), tree['w'])


def test_checkpoint_plus_remesh_recovery(tmp_path):
    """The full recovery flow at test scale: save -> 'fail' -> restore."""
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_path)
    state = {'w': jnp.arange(16.0).reshape(4, 4), 'step': jnp.int32(5)}
    mgr.save(state, step=5, blocking=True)
    # failure: rebuild (trivial 1-device) mesh, restore, verify
    restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, state))
    assert restored is not None
    tree, step, _ = restored
    assert step == 5
    np.testing.assert_allclose(np.asarray(tree['w']),
                               np.asarray(state['w']))
