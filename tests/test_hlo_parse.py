"""Optimized-HLO analyzer: exact dot FLOPs, trip counts, collectives."""
import numpy as np

from repro.analysis import hlo_parse as hp

MODULE = '''
HloModule test

%inner (p0: f32[8,16], p1: f32[16,4]) -> f32[8,4] {
  %p0 = f32[8,16] parameter(0)
  %p1 = f32[16,4] parameter(1)
  ROOT %d = f32[8,4] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%body (c: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %c = (s32[], f32[8,4]) parameter(0)
  %i = s32[] get-tuple-element(%c), index=0
  %x = f32[8,4] get-tuple-element(%c), index=1
  %ag = f32[16,4] all-gather(%x), replica_groups={{0,1}}, dimensions={0}
  %sl = f32[8,4] slice(%ag), slice={[0:8], [0:4]}
  %add = f32[8,4] add(%x, %sl)
  ROOT %t = (s32[], f32[8,4]) tuple(%i, %add)
}

%cond (c: (s32[], f32[8,4])) -> pred[] {
  %c = (s32[], f32[8,4]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[8,16], b: f32[16,4]) -> f32[8,4] {
  %a = f32[8,16] parameter(0)
  %b = f32[16,4] parameter(1)
  %mm = f32[8,4] call(%a, %b), to_apply=%inner
  %init = (s32[], f32[8,4]) tuple(%mm)
  %w = (s32[], f32[8,4]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ar = f32[8,4] all-reduce(%mm), replica_groups={{0,1,2,3}}
  ROOT %out = f32[8,4] get-tuple-element(%w), index=1
}
'''


def test_dot_flops_exact():
    agg = hp.analyze_text(MODULE)
    # dot: 2 * 8*4 * 16 = 1024 flops; add inside while: 32 elems x 5 trips
    assert agg['flops'] == 1024 + 32 * 5


def test_trip_count_applied_to_collectives():
    agg = hp.analyze_text(MODULE)
    # all-gather result 16*4*4B = 256B x 5 trips + all-reduce 8*4*4 = 128B
    assert agg['collective_bytes'] == 256 * 5 + 128
    assert agg['collective_counts']['all-gather'] == 5
    assert agg['collective_counts']['all-reduce'] == 1


def test_crosspod_split():
    agg = hp.analyze_text(MODULE, pod_size=2)
    # the all-reduce group {0,1,2,3} crosses pods of size 2; all-gather {0,1} doesn't
    assert agg['collective_bytes_crosspod'] == 128


def test_bytes_model_counts_moves_and_dots_only():
    agg = hp.analyze_text(MODULE)
    # dot operands+result: (8*16 + 16*4)*4 + 128 = 896; slice result 128B x5;
    # all-gather 256 x5 + all-reduce 128; adds are fused (0 bytes)
    expect = (8 * 16 + 16 * 4) * 4 + 128 + 5 * 128 + 5 * 256 + 128
    assert agg['bytes'] == expect


def test_entry_detection():
    agg = hp.analyze_text(MODULE)
    assert 'main' in agg['entry']
