"""Scene-centric serving: shared radiance caches, the pose-cell sort pool,
and cross-viewer determinism.

Contracts under test:

* the multi-viewer cache forms (``lookup_all_groups_multi`` /
  ``insert_all_groups_multi``) evolve one shared cache in deterministic
  (slot, pixel) order — independent of host-side presentation order, with
  cross-viewer conflicts won by the lowest slot and duplicate tags landing
  once — and reduce bit-identically to the private per-viewer functions at
  V == 1 (tags, values, LRU ages, clock);
* pose-cell keys quantize deterministically (co-located cameras share a
  cell, distant ones do not);
* the scene-shared ``BatchedStepper``: co-located viewers collapse to ONE
  live sort buffer and one speculative sort per window; a shared scene
  cache yields a hit rate at least as high as private caches for staggered
  arrivals; final shared-cache tags are invariant to session submission
  order.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import posecell
from repro.core import radiance_cache as rc
from repro.core.pipeline import LuminaConfig, init_fleet
from repro.data.trajectory import orbit_trajectory
from repro.serve.render import build_sessions
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper
from repro.serve.telemetry import tick_rollup

CFG = rc.CacheConfig(n_sets=16, n_ways=2, k=3)


def _records(key, v, g, b, k, lo=0, hi=400):
    return jax.random.randint(jax.random.PRNGKey(key), (v, g, b, k), lo, hi,
                              dtype=jnp.int32)


def _rgb_like(ids):
    v, g, b, _ = ids.shape
    base = jnp.arange(v * g * b, dtype=jnp.float32).reshape(v, g, b, 1)
    return jnp.concatenate([base, base + 0.25, base + 0.5], axis=-1)


# ---------------------------------------------------------------------------
# radiance_cache multi-viewer forms
# ---------------------------------------------------------------------------

def test_multi_v1_bitwise_matches_private_forms():
    """V == 1 shared-cache ops ARE the private ops: tags, values, LRU ages
    and clock bitwise — the parity anchor for single-viewer serving."""
    ids = _records(0, 1, 2, 8, CFG.k)
    rgb = _rgb_like(ids)
    do = jnp.ones(ids.shape[:3], bool)

    c_priv = rc.init_cache(2, CFG)
    c_multi = rc.init_cache(2, CFG)
    c_priv = rc.insert_all_groups(c_priv, ids[0], rgb[0], do[0], CFG)
    c_multi = rc.insert_all_groups_multi(c_multi, ids, rgb, do, CFG)
    for field in ('tags', 'values', 'age', 'clock'):
        np.testing.assert_array_equal(
            np.asarray(getattr(c_multi, field)),
            np.asarray(getattr(c_priv, field)), err_msg=field)

    hit_p, val_p, _, _, c_priv = rc.lookup_all_groups(c_priv, ids[0], CFG)
    hit_m, val_m, _, _, c_multi = rc.lookup_all_groups_multi(
        c_multi, ids, CFG, live=jnp.ones((1,), bool))
    np.testing.assert_array_equal(np.asarray(hit_m[0]), np.asarray(hit_p))
    np.testing.assert_array_equal(np.asarray(val_m[0]), np.asarray(val_p))
    for field in ('tags', 'values', 'age', 'clock'):
        np.testing.assert_array_equal(
            np.asarray(getattr(c_multi, field)),
            np.asarray(getattr(c_priv, field)), err_msg=f'post-touch {field}')


def test_multi_insert_conflict_lowest_slot_wins():
    """Cross-viewer conflicts resolve by (slot, pixel) order: when two
    viewers' different records map to the same victim way, the lower slot's
    record lands — the multi-viewer extension of lowest-pixel-wins."""
    cfg = rc.CacheConfig(n_sets=1, n_ways=1, k=2, insert_rounds=1)
    cache = rc.init_cache(1, cfg)
    ids = jnp.asarray([[[[5, 5]]], [[[6, 6]]]], jnp.int32)   # [V=2,G=1,B=1,k]
    rgb = _rgb_like(ids)
    cache = rc.insert_all_groups_multi(cache, ids, rgb,
                                       jnp.ones((2, 1, 1), bool), cfg)
    hit, _, _, _, _ = rc.lookup_all_groups_multi(cache, ids, cfg)
    assert bool(hit[0, 0, 0]) and not bool(hit[1, 0, 0])


def test_multi_insert_duplicate_tags_land_once():
    """Co-located viewers emit identical records; the shared cache stores
    one entry (insert-round re-probe dedupe), not one per viewer."""
    cache = rc.init_cache(1, CFG)
    row = jnp.asarray([[[9, 9, 9]]], jnp.int32)              # [G=1,B=1,k]
    ids = jnp.stack([row, row, row])                         # [V=3,...]
    cache = rc.insert_all_groups_multi(cache, ids, _rgb_like(ids),
                                       jnp.ones((3, 1, 1), bool), CFG)
    tags = np.asarray(cache.tags[0])
    n_present = (np.all(tags == np.asarray([9, 9, 9]), axis=-1)).sum()
    assert n_present == 1
    # and the stored value is slot 0's (the (slot, pixel)-order winner)
    hit, val, _, _, _ = rc.lookup_all_groups_multi(cache, ids, CFG)
    assert bool(np.asarray(hit).all())
    np.testing.assert_array_equal(np.asarray(val[1, 0, 0]),
                                  np.asarray(_rgb_like(ids)[0, 0, 0]))


def test_multi_insert_deterministic_vs_presentation_order():
    """The shared-cache result depends only on the slot -> records mapping:
    feeding the slot-major flattened batch through the plain insert (the
    documented serial semantics) reproduces the multi form exactly, and
    repeated evaluation is stable."""
    ids = _records(7, 3, 2, 8, CFG.k)
    rgb = _rgb_like(ids)
    do = jnp.ones(ids.shape[:3], bool)
    a = rc.insert_all_groups_multi(rc.init_cache(2, CFG), ids, rgb, do, CFG)
    b = rc.insert_all_groups(rc.init_cache(2, CFG), rc.slot_major(ids),
                             rc.slot_major(rgb), rc.slot_major(do), CFG)
    c = rc.insert_all_groups_multi(rc.init_cache(2, CFG), ids, rgb, do, CFG)
    for field in ('tags', 'values', 'age', 'clock'):
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)),
                                      err_msg=field)
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(c, field)),
                                      err_msg=f'restability {field}')


def test_multi_lookup_dead_viewer_probes_without_touching():
    """A dead viewer (idle slot) still reports hits but must not age-bump
    shared entries — its LRU influence would survive its eviction."""
    ids = _records(3, 2, 1, 4, CFG.k)
    rgb = _rgb_like(ids)
    cache = rc.insert_all_groups_multi(rc.init_cache(1, CFG), ids, rgb,
                                       jnp.ones(ids.shape[:3], bool), CFG)
    live = jnp.asarray([True, False])
    hit_l, _, _, _, c_live = rc.lookup_all_groups_multi(cache, ids, CFG,
                                                        live=live)
    hit_a, _, _, _, c_all = rc.lookup_all_groups_multi(cache, ids, CFG)
    np.testing.assert_array_equal(np.asarray(hit_l), np.asarray(hit_a))
    # the dead viewer's touches are the only difference
    assert bool(hit_a[1].any())
    assert not np.array_equal(np.asarray(c_live.age), np.asarray(c_all.age))
    # clocks advance identically (age sequence independent of liveness)
    np.testing.assert_array_equal(np.asarray(c_live.clock),
                                  np.asarray(c_all.clock))


# ---------------------------------------------------------------------------
# pose cells
# ---------------------------------------------------------------------------

def test_pose_cell_key_quantizes():
    traj = orbit_trajectory(2, width=64, height_px=64)
    far = orbit_trajectory(1, width=64, height_px=64, start_deg=120.0)[0]
    k0 = posecell.pose_cell_key(traj[0])
    assert k0 == posecell.pose_cell_key(traj[0])          # deterministic
    assert 0 <= k0 < 2 ** 31
    # a sub-cell positive position jitter stays in the cell (keys quantize,
    # they don't hash raw floats); a 120-degree-away viewer never shares one
    near = dataclasses.replace(traj[0],
                               position=traj[0].position + 1e-6)
    assert posecell.pose_cell_key(near) == k0
    assert posecell.pose_cell_key(far) != k0
    # widening the quantum merges consecutive VR-rate frames into one cell
    assert posecell.pose_cell_key(traj[1], cell_size=1.0, ang_bins=16) == \
        posecell.pose_cell_key(traj[0], cell_size=1.0, ang_bins=16)


def test_pose_cell_poles_do_not_wrap():
    """Elevation is not periodic: straight-up and straight-down cameras at
    one position must land in different cells (a modulo wrap would hand one
    the other's sort — a 180-degree orientation error no margin absorbs)."""
    from repro.core.camera import look_at, make_camera
    pos = (0.0, 0.0, 0.0)
    p_up, q_up = look_at(pos, (0.0, 1.0, 0.0), up=(0.0, 0.0, 1.0))
    p_dn, q_dn = look_at(pos, (0.0, -1.0, 0.0), up=(0.0, 0.0, 1.0))
    up = make_camera(p_up, q_up, 60.0, 64, 64)
    down = make_camera(p_dn, q_dn, 60.0, 64, 64)
    assert posecell.pose_cell_key(up) != posecell.pose_cell_key(down)


# ---------------------------------------------------------------------------
# the scene-shared serving engine
# ---------------------------------------------------------------------------

def _run_manager(scene, cfg, sessions, slots, vps):
    stepper = BatchedStepper(scene, cfg, sessions[0].cams[0], slots,
                             viewers_per_scene=vps)
    mgr = SessionManager(stepper, slots)
    for s in sessions:
        mgr.submit(s)
    finished = mgr.run()
    return stepper, mgr, finished


def test_colocated_viewers_share_one_sort_buffer(small_scene):
    """Four co-located viewers of one scene: ONE live SortShared entry at
    every tick (vs four under private state), at most one speculative sort
    per window after warmup, and everyone still renders every frame."""
    s, frames = 4, 9
    cfg = LuminaConfig(capacity=256, window=3)
    sessions = build_sessions(s, frames, width=64, stagger=0,
                              viewers_per_scene=s)
    stepper, mgr, finished = _run_manager(small_scene, cfg, sessions, s, s)
    assert sorted(f.sid for f in finished) == list(range(s))
    assert all(f.telemetry.frames == frames for f in finished)
    lives = [t['sort_pool_live'] for t in mgr.tick_log]
    assert max(lives) == 1, lives
    # one sort per window for the whole fleet (the sharing win: the private
    # cohort scheduler would run ceil(S/window) + admit sorts)
    executed = [e['scheduled'] + e['admit'] for e in stepper.sort_log]
    assert executed[0] == 1                       # one admit sort for all 4
    assert sum(executed) <= 1 + (frames // cfg.window) + 1
    assert max(executed) <= 1
    joined = sum(e['joined'] for e in stepper.sort_log)
    assert joined > 0
    roll = tick_rollup(mgr.tick_log, warmup_ticks=1)
    assert roll['max_sort_pool_live'] == 1
    assert roll['state_bytes'] == (roll['cache_bytes']
                                   + roll['sort_pool_bytes'])


def test_shared_cache_hit_rate_beats_private_on_staggered_arrivals(
        small_scene):
    """A viewer admitted into a warm scene cache hits immediately; under
    private state it pays a cold start.  Same workload, same engine, only
    viewers_per_scene differs."""
    viewers, frames, stagger = 3, 6, 2
    cfg = LuminaConfig(capacity=256, window=3)

    def mean_hit(vps):
        sessions = []
        for sid in range(viewers):
            cams = orbit_trajectory(frames, width=64, height_px=64)
            sessions.append(ViewerSession(sid=sid, cams=cams,
                                          arrival_tick=sid * stagger,
                                          scene_id=0))
        _, _, finished = _run_manager(small_scene, cfg, sessions, viewers,
                                      vps)
        return np.mean([f.telemetry.summary()['hit_rate'] for f in finished])

    assert mean_hit(viewers) > mean_hit(1) + 0.05


def test_shared_cache_tags_invariant_to_submission_order(small_scene):
    """Cross-viewer determinism at the engine level: permuting the order
    co-located sessions are submitted (hence which slots they land in)
    leaves the final shared-cache tags and values bitwise identical —
    the (slot, pixel) insert order plus duplicate dedupe make the cache a
    function of the rendered content, not the admission history."""
    s, frames = 3, 5
    cfg = LuminaConfig(capacity=256, window=3)

    def final_cache(order):
        cams = orbit_trajectory(frames, width=64, height_px=64)
        sessions = [ViewerSession(sid=sid, cams=list(cams), scene_id=0)
                    for sid in order]
        stepper, _, _ = _run_manager(small_scene, cfg, sessions, s, s)
        return stepper.shared.cache

    a = final_cache([0, 1, 2])
    b = final_cache([2, 0, 1])
    np.testing.assert_array_equal(np.asarray(a.tags), np.asarray(b.tags))
    np.testing.assert_array_equal(np.asarray(a.values),
                                  np.asarray(b.values))


def test_shared_mode_admit_preserves_scene_cache(small_scene):
    """Shared-mode slot reuse: a new viewer admitted into a warm scene
    keeps the scene cache (that is the feature); its private state still
    cold-starts (fresh frame counter -> sort-on-admit)."""
    cfg = LuminaConfig(capacity=256, window=3)
    traj = orbit_trajectory(6, width=64, height_px=64)
    stepper = BatchedStepper(small_scene, cfg, traj[0], slots=2,
                             viewers_per_scene=2)
    stepper.admit(0)
    stepper.admit(1)
    for f in range(3):
        stepper.step({0: traj[f], 1: traj[f]})
    occ_before = float(jax.jit(rc.occupancy)(stepper.shared.cache))
    assert occ_before > 0.0
    stepper.admit(0)          # slot reuse mid-flight
    out = stepper.step({0: traj[0], 1: traj[3]})
    _, st0, _ = out[0]
    assert float(st0.sorted_this_frame) == 1.0      # sort-on-admit ran
    assert float(st0.hit_rate) > 0.5                # warm cache served it
    occ_after = float(jax.jit(rc.occupancy)(stepper.shared.cache))
    assert occ_after >= occ_before - 1e-6


def test_scene_blocked_admission(small_scene):
    """Sessions land only in their scene's slot block; a full block queues
    its sessions without blocking other scenes' admissions."""
    cfg = LuminaConfig(capacity=256, window=3)
    cams = orbit_trajectory(4, width=64, height_px=64)
    # scene 0: three sessions for a two-slot block; scene 1: one session
    sessions = [ViewerSession(sid=i, cams=list(cams), scene_id=0)
                for i in range(3)]
    sessions.append(ViewerSession(sid=3, cams=list(cams), scene_id=1))
    stepper = BatchedStepper(small_scene, cfg, cams[0], slots=4,
                             viewers_per_scene=2)
    mgr = SessionManager(stepper, 4)
    for s in sessions:
        mgr.submit(s)
    mgr.admit_ready()
    by_slot = {i: s.sid for i, s in enumerate(mgr.slot_session)
               if s is not None}
    assert by_slot == {0: 0, 1: 1, 2: 3}     # sid 2 waits for block 0
    assert [s.sid for s in mgr.pending] == [2]
    finished = mgr.run()
    assert sorted(f.sid for f in finished) == [0, 1, 2, 3]
    assert all(f.telemetry.frames == 4 for f in finished)


def test_fleet_rejects_ragged_blocks(small_scene):
    cams = orbit_trajectory(1, width=64, height_px=64)
    with pytest.raises(ValueError):
        BatchedStepper(small_scene, LuminaConfig(), cams[0], slots=3,
                       viewers_per_scene=2)


def test_plan_groups_never_doubles_up_pool_entries(small_scene):
    """Two sorting groups of one scene must land in distinct pool entries
    even when a stale held entry (owner evicted, zero refs) is grabbed as
    free by an earlier group: the later group whose cell the entry still
    tags must NOT reuse it — two sorts scattered into one slot would leave
    one group rendering the other cell's tiles."""
    cams = orbit_trajectory(1, width=64, height_px=64)
    stepper = BatchedStepper(small_scene, LuminaConfig(window=4), cams[0],
                             slots=2, viewers_per_scene=2)
    cell_x, cell_y = 111, 222
    # entry 0 still tags cell X from an evicted owner; both slots are due:
    # slot 0 now in cell Y (processed first, lower leader), slot 1 back in X
    stepper._pool_cell[0, 0] = cell_x
    stepper._pool_tick[0, 0] = 0
    stepper._pool_owner[0, 0] = -1
    stepper.global_tick = 4
    groups = stepper._plan_groups(due=[0, 1], active={0, 1},
                                  cells={0: cell_y, 1: cell_x})
    assert len(groups) == 2 and all(g.sorts for g in groups)
    entries = [(g.scene, g.entry) for g in groups]
    assert len(set(entries)) == 2, entries
