"""Open-loop serving traffic: arrival traces and per-viewer frame pacing.

A **traffic trace** is the host-loop's replayable input: per viewer, the
tick it arrives on and the pace at which it consumes frames (a pace-``p``
viewer renders one frame every ``p`` ticks — a 30 fps client against a
90 Hz tick, say).  Traces are plain integers, generated from a seeded RNG,
and round-trip through ``to_dict``/``from_dict`` — so any observed workload
can be recorded once and replayed bit-identically through the virtual-clock
driver (``repro.serve.events.SyncDriver``), which is what the conformance
tests in ``tests/test_serve_async.py`` do.

Three arrival processes:

  * ``stagger`` — one viewer every ``stagger`` ticks (the legacy layout);
  * ``poisson`` — open-loop Poisson arrivals at ``rate`` viewers/tick
    (exponential inter-arrival gaps, floored to ticks): the
    "millions of independent users" model;
  * ``bursty``  — ``burst`` viewers land together every ``gap`` ticks, each
    burst jittered by up to ``jitter`` ticks: the flash-crowd /
    broadcast-start model that stresses admission and sort-on-admit.
"""
from __future__ import annotations

import dataclasses

import numpy as np

KINDS = ('stagger', 'poisson', 'bursty')


@dataclasses.dataclass(frozen=True)
class TrafficTrace:
    """A replayable arrival/pacing trace for ``viewers`` sessions.

    ``arrivals[i]`` is viewer ``i``'s arrival tick (non-decreasing),
    ``paces[i]`` its frame pace in ticks (>= 1).
    """

    kind: str
    seed: int
    arrivals: tuple
    paces: tuple

    @property
    def viewers(self) -> int:
        return len(self.arrivals)

    def to_dict(self) -> dict:
        return {'kind': self.kind, 'seed': self.seed,
                'arrivals': list(self.arrivals), 'paces': list(self.paces)}

    @classmethod
    def from_dict(cls, d: dict) -> 'TrafficTrace':
        return cls(kind=d['kind'], seed=int(d['seed']),
                   arrivals=tuple(int(a) for a in d['arrivals']),
                   paces=tuple(int(p) for p in d['paces']))


def _stagger_arrivals(viewers: int, stagger: int) -> list:
    return [i * stagger for i in range(viewers)]


def _poisson_arrivals(viewers: int, rate: float,
                      rng: np.random.Generator) -> list:
    if rate <= 0:
        raise ValueError(f'poisson arrivals need rate > 0, got {rate}')
    gaps = rng.exponential(1.0 / rate, size=viewers)
    return np.floor(np.cumsum(gaps)).astype(int).tolist()


def _bursty_arrivals(viewers: int, burst: int, gap: int, jitter: int,
                     rng: np.random.Generator) -> list:
    if burst < 1 or gap < 1:
        raise ValueError(f'bursty arrivals need burst/gap >= 1, got '
                         f'{burst}/{gap}')
    arrivals = []
    for b in range(-(-viewers // burst)):
        base = b * gap + (int(rng.integers(0, jitter + 1)) if jitter else 0)
        arrivals.extend([base] * min(burst, viewers - len(arrivals)))
    return sorted(arrivals)


def make_trace(kind: str, viewers: int, *, seed: int = 0, rate: float = 0.5,
               burst: int = 4, gap: int = 8, jitter: int = 0,
               stagger: int = 2, pace: int = 1,
               pace_jitter: int = 0) -> TrafficTrace:
    """Generate a deterministic arrival/pacing trace.

    ``pace_jitter`` > 0 mixes client rates: viewer ``i`` gets a pace drawn
    uniformly from ``[pace, pace + pace_jitter]``, so the fleet carries
    fast and slow consumers on one tick clock.  Everything is drawn from
    ``np.random.default_rng(seed)`` — same arguments, same trace, always.
    """
    if kind not in KINDS:
        raise ValueError(f'unknown traffic kind {kind!r} '
                         f'(expected one of {KINDS})')
    if viewers < 1:
        raise ValueError('viewers must be >= 1')
    if pace < 1:
        raise ValueError('pace must be >= 1')
    rng = np.random.default_rng(seed)
    if kind == 'stagger':
        arrivals = _stagger_arrivals(viewers, stagger)
    elif kind == 'poisson':
        arrivals = _poisson_arrivals(viewers, rate, rng)
    else:
        arrivals = _bursty_arrivals(viewers, burst, gap, jitter, rng)
    if pace_jitter:
        paces = [pace + int(p)
                 for p in rng.integers(0, pace_jitter + 1, size=viewers)]
    else:
        paces = [pace] * viewers
    return TrafficTrace(kind=kind, seed=seed, arrivals=tuple(arrivals),
                        paces=tuple(paces))
