"""Multi-viewer render-serving entrypoint.

Serves N concurrent camera streams (staggered arrivals, per-viewer orbit
trajectories) over one shared Gaussian scene with a fixed number of render
slots, then prints per-session telemetry:

    PYTHONPATH=src python -m repro.serve.render --viewers 4 --frames 24

Each viewer orbits the scene from its own start angle, so their radiance
caches evolve independently while the batched stepper advances all of them
through one vmapped shade_phase per tick; speculative sorts run only for the
tick's due cohort (staggered across slots, at most ceil(S/window) per tick,
plus sort-on-admit) — see repro.serve.stepper for the cadence-shift caveat.
"""
from __future__ import annotations

import argparse

import jax

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.core.pipeline import LuminaConfig
from repro.data.scenes import structured_scene
from repro.data.trajectory import orbit_trajectory
from repro.serve import faults as serve_faults
from repro.serve import traffic
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper, SequentialStepper
from repro.serve.telemetry import aggregate, format_table, tick_rollup


def build_sessions(viewers: int, frames: int, *, width: int = 96,
                   stagger: int = 2, fps: float = 90.0,
                   viewers_per_scene: int = 1,
                   arrivals=None, paces=None) -> list[ViewerSession]:
    """One session per viewer, grouped into scenes of ``viewers_per_scene``.

    Scenes get distinct orbit start angles; viewers of one scene ride the
    *same* trajectory (the co-watching scenario — broadcast spectators at
    near-identical poses), so they land in one pose cell and exercise the
    scene-shared sort pool and radiance cache.  With one viewer per scene
    this reduces to the original one-orbit-per-viewer layout.

    ``arrivals``/``paces`` override the default ``sid * stagger`` arrival
    ticks and every-tick pacing — pass a ``repro.serve.traffic`` trace's
    fields to serve an open-loop workload.
    """
    sessions = []
    n_scenes = -(-viewers // viewers_per_scene)
    for sid in range(viewers):
        scene_id = sid // viewers_per_scene
        cams = orbit_trajectory(frames, fps=fps, width=width, height_px=width,
                                start_deg=360.0 * scene_id / max(n_scenes, 1))
        sessions.append(ViewerSession(
            sid=sid, cams=cams,
            arrival_tick=(sid * stagger if arrivals is None
                          else int(arrivals[sid])),
            scene_id=scene_id,
            pace=1 if paces is None else int(paces[sid])))
    return sessions


def serve(viewers: int, frames: int, *, slots: int = 0, width: int = 96,
          gaussians: int = 1500, window: int = 6, capacity: int = 192,
          stagger: int = 2, sequential: bool = False, seed: int = 0,
          backend: str = 'reference', profile_every: int = 0,
          viewers_per_scene: int = 1, arrivals: str = 'stagger',
          rate: float = 0.5, burst: int = 4, gap: int = 8, jitter: int = 0,
          pace: int = 1, pace_jitter: int = 0, oversubscribe: bool = False,
          driver: str = 'sync', trace_out: str | None = None,
          metrics_out: str | None = None,
          faults: str = '', fault_rate: float = 0.05, fault_seed: int = 0,
          watchdog: float | None = None, max_pending: int | None = None,
          checkpoint_dir: str | None = None, checkpoint_every: int = 0,
          restore: bool = False, devices: int = 1,
          stream: bool = False, stream_budget: int = 0,
          stream_near: int = 2, stream_lod: int = 4,
          stream_lod_frac: float = 0.5, stream_cell: float = 0.4,
          stream_chunk: int = 64, stream_max_loads: int = 0,
          print_fn=print) -> dict:
    """Run the serving loop to completion; returns the aggregate rollup.

    ``backend`` selects the shade implementation ('reference' | 'pallas');
    ``profile_every`` > 0 samples a per-kernel shade latency breakdown every
    N ticks (pallas backend, batched engine); ``viewers_per_scene`` > 1
    groups that many slots per scene so co-scene viewers share one radiance
    cache and pose-cell sort pool (batched engine only).  ``arrivals``
    selects the traffic trace ('stagger' | 'poisson' | 'bursty', seeded by
    ``seed`` — see ``repro.serve.traffic``) and ``driver`` the host loop:
    'sync' (virtual clock, deterministic replay) or 'threaded' (host
    admission/planning double-buffered against the device step).
    ``oversubscribe`` lets paced viewers whose render ticks provably never
    collide share one physical slot (dropless allocation; batched engine
    with ``viewers_per_scene`` >= 2 and ``pace`` >= 2 only).

    ``trace_out`` writes the run's span trace as Chrome trace-event JSON
    (open in https://ui.perfetto.dev — host / host-worker / device tracks);
    ``metrics_out`` dumps the typed metrics registry snapshot
    (``repro.obs``).

    ``faults`` turns on deterministic fault injection
    (``repro.serve.faults``): a comma list of fault kinds or ``'all'``,
    scheduled per tick at ``fault_rate`` from ``fault_seed`` — same
    arguments, same failure schedule, always.  ``watchdog`` bounds the
    device/planner waits (seconds) and ``max_pending`` bounds the admission
    backlog (overflow arrivals are load-shed).  ``checkpoint_dir`` +
    ``checkpoint_every`` snapshot the full serving state every N ticks
    (atomic, crash-consistent — ``repro.checkpoint``); ``restore`` resumes
    from the newest complete snapshot instead of starting cold.

    ``stream`` turns on pose-cell scene residency (``repro.serve
    .streaming``): the scene is partitioned into pose-cell-keyed chunks
    (``stream_cell`` cell size, ``stream_chunk`` Gaussians per chunk) and
    only the live cells' chunks stay device-resident — FULL detail within
    ``stream_near`` cells of a camera, a significance-prefix LOD subset
    (``stream_lod_frac`` of each chunk) out to ``stream_lod`` cells.
    ``stream_budget`` bounds the device arena in bytes (0 = one frame per
    chunk) and ``stream_max_loads`` bounds chunk uploads per tick (0 =
    unbounded; misses beyond it stall only the missing viewer's slot).

    ``devices`` > 1 serves through the elastic multi-device fleet
    (``repro.serve.fleet``): ``slots`` render slots *per device*, a shared
    bounded admission queue with deterministic routing, and device-loss
    recovery (inject it with ``--faults device_loss``; checkpointing makes
    the recovery a whole-fleet rollback with slot-aligned bit-identical
    continuation).  On CPU, launch with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for distinct
    devices; otherwise workers oversubscribe the one device.
    """
    if viewers < 1 or frames < 1:
        raise SystemExit('--viewers and --frames must be >= 1')
    if viewers_per_scene < 1:
        raise SystemExit('--viewers-per-scene must be >= 1')
    if sequential and viewers_per_scene > 1:
        raise SystemExit('--viewers-per-scene > 1 needs the batched engine '
                         '(the sequential baseline is fully private state)')
    if oversubscribe and (sequential or viewers_per_scene < 2):
        raise SystemExit('--oversubscribe needs the batched engine with '
                         '--viewers-per-scene >= 2 (co-residents interleave '
                         'through a shared scene block)')
    if oversubscribe and pace < 2:
        raise SystemExit('--oversubscribe needs --pace >= 2: only paced '
                         'viewers have the off ticks co-residents render in')
    if stream and sequential:
        raise SystemExit('--stream needs the batched engine (residency is '
                         'a property of the shared scene arena)')
    if stream and devices > 1:
        raise SystemExit('--stream is a single-device feature for now '
                         '(fleet workers hold fully-resident scene copies)')
    slots = slots or min(viewers, 8)
    # scene blocks are static: round slots up to whole blocks
    slots = -(-slots // viewers_per_scene) * viewers_per_scene
    scene = structured_scene(jax.random.PRNGKey(seed), gaussians)
    cfg = LuminaConfig(capacity=capacity, window=window, backend=backend)
    trace = traffic.make_trace(arrivals, viewers, seed=seed, rate=rate,
                               burst=burst, gap=gap, jitter=jitter,
                               stagger=stagger, pace=pace,
                               pace_jitter=pace_jitter)
    sessions = build_sessions(viewers, frames, width=width, stagger=stagger,
                              viewers_per_scene=viewers_per_scene,
                              arrivals=trace.arrivals, paces=trace.paces)
    cam0 = sessions[0].cams[0]

    injector = serve_faults.NULL
    fault_trace = None
    if faults:
        kinds = serve_faults.KINDS if faults == 'all' else tuple(
            k.strip() for k in faults.split(',') if k.strip())
        # arm events across the expected run: last arrival + slowest
        # viewer's frames, plus slack for degraded/shed ticks
        horizon = int(max(trace.arrivals)) + frames * int(max(trace.paces)) + 4
        fault_trace = serve_faults.make_trace(kinds, horizon, seed=fault_seed,
                                              rate=fault_rate, slots=slots)
        injector = serve_faults.FaultInjector(fault_trace)

    if devices > 1:
        if sequential:
            raise SystemExit('--devices > 1 needs the batched engine')
        if oversubscribe:
            raise SystemExit('--oversubscribe is a single-device feature '
                             '(fleet workers place one viewer per slot)')
        return _serve_fleet_path(
            scene, cfg, cam0, sessions, devices=devices, slots=slots,
            driver=driver, viewers_per_scene=viewers_per_scene,
            profile_every=profile_every, injector=injector,
            fault_trace=fault_trace, fault_rate=fault_rate,
            fault_seed=fault_seed, max_pending=max_pending,
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every, restore=restore,
            backend=backend, arrivals=arrivals, trace_out=trace_out,
            metrics_out=metrics_out, print_fn=print_fn)

    if sequential:
        stepper = SequentialStepper(scene, cfg, cam0, slots,
                                    profile_every=profile_every)
    else:
        streaming = None
        if stream:
            from repro.data.scenes import partition_scene
            from repro.serve.streaming import ResidencyManager
            chunked = partition_scene(scene, cell_size=stream_cell,
                                      chunk_cap=stream_chunk)
            streaming = ResidencyManager(
                chunked, near_radius=stream_near, lod_radius=stream_lod,
                lod_frac=stream_lod_frac,
                budget_bytes=stream_budget or None,
                max_loads_per_tick=stream_max_loads or None)
        stepper = BatchedStepper(scene, cfg, cam0, slots,
                                 profile_every=profile_every,
                                 viewers_per_scene=viewers_per_scene,
                                 streaming=streaming)

    tracer = obs.Tracer() if trace_out else None
    mgr = SessionManager(stepper, slots, tracer=tracer, injector=injector,
                         watchdog_s=watchdog, max_pending=max_pending,
                         oversubscribe=oversubscribe)

    ckpt = None
    restored = None
    if checkpoint_dir:
        ckpt = CheckpointManager(checkpoint_dir, metrics=mgr.metrics)
        if checkpoint_every:
            mgr.enable_checkpoints(ckpt, checkpoint_every,
                                   extra={'traffic': trace.to_dict()})
        if restore:
            restored = mgr.restore_serving(ckpt, sessions)
            if restored is not None:
                print_fn(f'-- restored serving state from tick {restored} '
                         f'({checkpoint_dir})')
    if restored is None:
        for sess in sessions:
            mgr.submit(sess)
    finished = mgr.run(driver=driver)
    if ckpt is not None:
        ckpt.wait()   # flush any in-flight background save
    if injector.enabled:
        serve_faults.account_unfired(injector, mgr.metrics)
    if trace_out:
        obs.write_trace(trace_out, tracer)
        print_fn(f'-- trace: {len(tracer.events)} events -> {trace_out} '
                 f'(load in https://ui.perfetto.dev)')
    if metrics_out:
        with open(metrics_out, 'w') as f:
            f.write(mgr.metrics.to_json(indent=1))
        print_fn(f'-- metrics: {len(mgr.metrics.names())} instruments -> '
                 f'{metrics_out}')

    summaries = [s.telemetry.summary() for s in
                 sorted(finished, key=lambda s: s.sid)]
    agg = aggregate(summaries)
    agg['ticks'] = mgr.tick
    agg['mode'] = 'sequential' if sequential else 'batched'
    # Tick-level rollup keys get a tick_ prefix: aggregate()'s
    # mean_sort_ms/mean_shade_ms are session-level means (matching the table
    # above) and sessions ride different subsets of ticks, so the two
    # statistics legitimately differ.
    roll = tick_rollup(mgr.tick_log, warmup_ticks=1)
    agg['backend'] = backend
    agg['viewers_per_scene'] = viewers_per_scene
    agg['driver'] = driver
    agg['arrivals'] = arrivals

    def _counter(name: str) -> int:
        return mgr.metrics[name].value if name in mgr.metrics else 0

    agg['fault_rate'] = fault_rate if faults else 0.0
    agg['faults_injected'] = sum(injector.fired_counts().values())
    agg['degraded_ticks'] = _counter('serve.degraded_ticks')
    agg['retries'] = _counter('serve.retries')
    agg['oversubscribed'] = _counter('serve.oversubscribed')
    agg['pool_resizes'] = _counter('pool.resizes')
    agg['mean_sorts_per_tick'] = roll['mean_sorts_per_tick']
    agg['max_sorts_per_tick'] = roll['max_sorts_per_tick']
    agg['tick_sort_ms'] = roll['mean_sort_ms']
    agg['tick_shade_ms'] = roll['mean_shade_ms']
    agg['kernel_ms'] = roll['kernel_ms']
    for key in ('last_occupancy', 'max_sort_pool_live', 'sort_pool_bytes',
                'sort_pool_alloc_bytes', 'sort_pool_reserved_bytes',
                'cache_bytes', 'state_bytes', 'state_alloc_bytes',
                'state_reserved_bytes', 'p50_frame_ms', 'p95_frame_ms',
                'host_ms', 'host_overlap', 'stream_resident_bytes',
                'stream_arena_bytes', 'stream_full_bytes', 'stream_stalls',
                'stream_stalls_tail', 'stream_loads',
                'stream_prefetch_hits', 'stream_evictions'):
        if key in roll:
            agg[key] = roll[key]
    agg['stream_budget'] = stream_budget if stream else 0
    print_fn(format_table(summaries))
    print_fn(f"-- {agg['mode']} ({backend}): {agg['sessions']} sessions, "
             f"{agg['frames']} frames in {agg['ticks']} ticks, "
             f"fleet {agg['fleet_fps']:.2f} fps/viewer (frame-weighted), "
             f"mean hit rate {agg['mean_hit_rate']:.2f}, "
             f"worst p99 {agg['worst_p99_ms']:.0f} ms, "
             f"sort/shade {agg['mean_sort_ms']:.1f}/"
             f"{agg['mean_shade_ms']:.1f} ms, "
             f"max {agg['max_sorts_per_tick']} sorts/tick")
    if 'max_sort_pool_live' in agg:
        occ = agg.get('last_occupancy')
        occ_s = f", cache occupancy {occ:.2f}" if occ is not None else ''
        print_fn(f"-- state ({viewers_per_scene} viewers/scene): "
                 f"{agg['max_sort_pool_live']} live sort buffers peak, "
                 f"{agg['state_bytes'] / 1e6:.1f} MB live state "
                 f"(cache {agg['cache_bytes'] / 1e6:.1f} MB + sort pool "
                 f"{agg['sort_pool_bytes'] / 1e6:.1f} MB; "
                 f"{agg['state_alloc_bytes'] / 1e6:.1f} MB allocated, "
                 f"{agg.get('state_reserved_bytes', 0) / 1e6:.1f} MB static "
                 f"reservation)"
                 f"{occ_s}")
    if stream and 'stream_resident_bytes' in agg:
        print_fn(f"-- streaming: "
                 f"{agg['stream_resident_bytes'] / 1e6:.2f} MB resident "
                 f"peak of {agg['stream_full_bytes'] / 1e6:.2f} MB scene "
                 f"(arena {agg['stream_arena_bytes'] / 1e6:.2f} MB, budget "
                 f"{stream_budget or 'unbounded'}); "
                 f"{agg['stream_loads']} loads, "
                 f"{agg['stream_prefetch_hits']} prefetch hits, "
                 f"{agg['stream_evictions']} evictions, "
                 f"{agg['stream_stalls']} stalls "
                 f"({agg.get('stream_stalls_tail', 0)} post-warmup)")
    if roll['kernel_ms']:
        parts = '  '.join(f'{k} {v:.1f}' for k, v in roll['kernel_ms'].items())
        print_fn(f"-- shade kernels (ms/tick, sampled): {parts}")
    if 'host_ms' in agg:
        print_fn(f"-- host pipeline ({driver}, {arrivals} arrivals): "
                 f"plan {agg['host_ms']:.2f} ms/tick, "
                 f"overlap {agg.get('host_overlap', 0.0):.0%}, "
                 f"frame p50/p95 {agg.get('p50_frame_ms', 0.0):.1f}/"
                 f"{agg.get('p95_frame_ms', 0.0):.1f} ms")
    if injector.enabled:
        fired = injector.fired_counts()
        fired_s = ' '.join(f'{k}={v}' for k, v in sorted(fired.items())) \
            or 'none'
        out = injector.outstanding()
        out_s = (' (unfired: '
                 + ' '.join(f'{k}={v}' for k, v in sorted(out.items()))
                 + ' — counted in serve.faults_unfired)') if out else ''
        unfired = sum(out.values())
        print_fn(f"-- faults (seed {fault_seed}, rate {fault_rate}, "
                 f"{len(fault_trace.events)} scheduled): fired {fired_s}"
                 f"{out_s}; unfired {unfired}, retries {agg['retries']}, "
                 f"degraded ticks {agg['degraded_ticks']}, "
                 f"quarantined {_counter('serve.quarantined')}, "
                 f"shed arrivals {_counter('serve.shed')}")
    return agg


def _serve_fleet_path(scene, cfg, cam0, sessions, *, devices, slots, driver,
                      viewers_per_scene, profile_every, injector,
                      fault_trace, fault_rate, fault_seed, max_pending,
                      checkpoint_dir, checkpoint_every, restore, backend,
                      arrivals, trace_out, metrics_out, print_fn) -> dict:
    """The ``--devices N`` serving path: the elastic multi-device fleet
    (``repro.serve.fleet``) with ``slots`` render slots per device.
    ``restore`` resumes from the per-device lockstep checkpoints under
    ``checkpoint_dir`` (fail-fast ``SystemExit`` when absent — see
    ``serve_fleet``)."""
    from repro.serve.fleet import serve_fleet
    tracer = obs.Tracer() if trace_out else None
    fleet, finished = serve_fleet(
        scene, cfg, cam0, sessions, num_devices=devices,
        slots_per_device=slots, driver=driver,
        viewers_per_scene=viewers_per_scene, profile_every=profile_every,
        ckpt_root=checkpoint_dir, ckpt_every=checkpoint_every,
        restore=restore, max_pending=max_pending,
        injector=injector, tracer=tracer)
    if fleet.restored_tick is not None:
        print_fn(f'-- restored serving state from tick '
                 f'{fleet.restored_tick} ({checkpoint_dir}, '
                 f'{devices} devices)')
    if trace_out:
        obs.write_trace(trace_out, tracer)
        print_fn(f'-- trace: {len(tracer.events)} events -> {trace_out} '
                 f'(load in https://ui.perfetto.dev)')
    if metrics_out:
        with open(metrics_out, 'w') as f:
            f.write(fleet.metrics.to_json(indent=1))
        print_fn(f'-- metrics: {len(fleet.metrics.names())} instruments -> '
                 f'{metrics_out}')
    summaries = [s.telemetry.summary() for s in finished]
    agg = fleet.aggregate()
    agg['ticks'] = fleet.tick
    agg['mode'] = 'fleet'
    agg['backend'] = backend
    agg['viewers_per_scene'] = viewers_per_scene
    agg['driver'] = driver
    agg['arrivals'] = arrivals
    agg['fault_rate'] = fault_rate if fault_trace is not None else 0.0
    agg['faults_injected'] = sum(injector.fired_counts().values())
    roll = tick_rollup(fleet.merged_tick_log(), warmup_ticks=1)
    for key in ('p50_frame_ms', 'p95_frame_ms', 'host_ms', 'host_overlap'):
        if key in roll:
            agg[key] = roll[key]
    print_fn(format_table(summaries))

    def _counter(name: str) -> int:
        # labelled counters register as 'name{k=v,...}': sum all series
        return sum(fleet.metrics[key].value for key in fleet.metrics.names()
                   if key == name or key.startswith(name + '{'))

    print_fn(f"-- fleet ({backend}, {driver}): "
             f"{agg['devices']} devices ({agg['alive_devices']} alive), "
             f"{agg['sessions']} sessions, {agg['frames']} frames in "
             f"{agg['ticks']} ticks, "
             f"fleet {agg['fleet_fps']:.2f} fps/viewer (frame-weighted), "
             f"mean hit rate {agg['mean_hit_rate']:.2f}, "
             f"worst p99 {agg['worst_p99_ms']:.0f} ms, "
             f"shed arrivals {agg['shed']}")
    if injector.enabled:
        fired = injector.fired_counts()
        fired_s = ' '.join(f'{k}={v}' for k, v in sorted(fired.items())) \
            or 'none'
        out = injector.outstanding()
        out_s = (' (unfired: '
                 + ' '.join(f'{k}={v}' for k, v in sorted(out.items()))
                 + ' — counted in serve.faults_unfired)') if out else ''
        print_fn(f"-- faults (seed {fault_seed}, rate {fault_rate}, "
                 f"{len(fault_trace.events)} scheduled): fired {fired_s}"
                 f"{out_s}; unfired {sum(out.values())}, "
                 f"devices lost {_counter('fleet.device_lost')}, "
                 f"re-queued {_counter('fleet.requeued')}")
    return agg


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument('--viewers', type=int, default=4)
    ap.add_argument('--frames', type=int, default=24)
    ap.add_argument('--slots', type=int, default=0,
                    help='render slots (default min(viewers, 8))')
    ap.add_argument('--width', type=int, default=96,
                    help='square image size in pixels')
    ap.add_argument('--gaussians', type=int, default=1500)
    ap.add_argument('--window', type=int, default=6)
    ap.add_argument('--capacity', type=int, default=192)
    ap.add_argument('--stagger', type=int, default=2,
                    help='ticks between viewer arrivals')
    ap.add_argument('--sequential', action='store_true',
                    help='per-slot stepping instead of one vmapped call')
    ap.add_argument('--backend', choices=('reference', 'pallas'),
                    default='reference',
                    help='shade implementation: pure-JAX reference or the '
                         'chunked Pallas kernel path')
    ap.add_argument('--profile-every', type=int, default=0,
                    help='sample a per-kernel shade latency breakdown every '
                         'N ticks (pallas backend, batched engine)')
    ap.add_argument('--viewers-per-scene', type=int, default=1,
                    help='slots per scene block: viewers of one scene share '
                         'its radiance cache and pose-cell sort pool '
                         '(batched engine only)')
    ap.add_argument('--arrivals', choices=traffic.KINDS, default='stagger',
                    help='arrival trace: fixed stagger, open-loop poisson '
                         '(--rate viewers/tick, seeded by --seed) or bursty '
                         'flash crowds (--burst/--gap, seeded only when '
                         '--jitter > 0; repro.serve.traffic)')
    ap.add_argument('--rate', type=float, default=0.5,
                    help='poisson arrival rate in viewers per tick')
    ap.add_argument('--burst', type=int, default=4,
                    help='bursty arrivals: viewers landing together')
    ap.add_argument('--gap', type=int, default=8,
                    help='bursty arrivals: ticks between bursts')
    ap.add_argument('--jitter', type=int, default=0,
                    help='bursty arrivals: max seeded jitter per burst '
                         '(ticks)')
    ap.add_argument('--pace', type=int, default=1,
                    help='viewer frame interval in ticks (1 = every tick)')
    ap.add_argument('--pace-jitter', type=int, default=0,
                    help='mix client rates: pace drawn from '
                         '[pace, pace + jitter] per viewer')
    ap.add_argument('--oversubscribe', action='store_true',
                    help='interleave paced viewers whose render ticks '
                         'provably never collide through one physical slot '
                         '(needs --viewers-per-scene >= 2 and --pace >= 2)')
    ap.add_argument('--driver', choices=('sync', 'threaded'), default='sync',
                    help='host loop: sync virtual clock (deterministic '
                         'replay) or threaded (admission/eviction/pose-cell '
                         'planning overlapped with the device step)')
    ap.add_argument('--trace-out', default=None, metavar='PATH',
                    help='write the span trace as Chrome trace-event JSON '
                         '(Perfetto / chrome://tracing; host, host-worker '
                         'and device tracks)')
    ap.add_argument('--metrics-out', default=None, metavar='PATH',
                    help='dump the typed metrics registry snapshot as JSON '
                         '(repro.obs.metrics)')
    ap.add_argument('--faults', default='', metavar='KINDS',
                    help="deterministic fault injection: comma list of "
                         f"kinds from {serve_faults.KINDS} or 'all' "
                         "(repro.serve.faults; seeded by --fault-seed)")
    ap.add_argument('--fault-rate', type=float, default=0.05,
                    help='per-tick per-kind Bernoulli fault probability')
    ap.add_argument('--fault-seed', type=int, default=0,
                    help='fault trace seed (independent of --seed)')
    ap.add_argument('--watchdog', type=float, default=None, metavar='SECONDS',
                    help='bound device-finish / planner-completion waits '
                         '(default: unbounded unless faults are injected)')
    ap.add_argument('--max-pending', type=int, default=None, metavar='N',
                    help='admission backlog bound: arrivals past N pending '
                         'sessions are load-shed instead of queued')
    ap.add_argument('--checkpoint-dir', default=None, metavar='DIR',
                    help='snapshot serving state to this directory '
                         '(atomic, crash-consistent; repro.checkpoint)')
    ap.add_argument('--checkpoint-every', type=int, default=0, metavar='N',
                    help='checkpoint cadence in ticks (0 = never)')
    ap.add_argument('--restore', action='store_true',
                    help='resume from the newest complete checkpoint in '
                         '--checkpoint-dir instead of starting cold')
    ap.add_argument('--devices', type=int, default=1, metavar='N',
                    help='serve through the elastic multi-device fleet: N '
                         'scene-sharded workers with --slots slots each, a '
                         'shared bounded admission queue and device-loss '
                         'recovery (repro.serve.fleet; on CPU launch with '
                         'XLA_FLAGS=--xla_force_host_platform_device_count'
                         '=N for distinct devices)')
    ap.add_argument('--stream', action='store_true',
                    help='pose-cell scene residency: only live cells\' '
                         'chunks stay device-resident, neighbors prefetch, '
                         'far cells stream a coarser LOD subset '
                         '(repro.serve.streaming; batched single-device)')
    ap.add_argument('--stream-budget', type=int, default=0, metavar='BYTES',
                    help='device arena byte budget for streamed chunks '
                         '(0 = one arena frame per chunk)')
    ap.add_argument('--stream-near', type=int, default=2, metavar='CELLS',
                    help='full-detail radius in pose cells (Chebyshev)')
    ap.add_argument('--stream-lod', type=int, default=4, metavar='CELLS',
                    help='LOD radius in pose cells: cells between near and '
                         'lod stream a significance-prefix subset')
    ap.add_argument('--stream-lod-frac', type=float, default=0.5,
                    help='fraction of each chunk kept at LOD detail')
    ap.add_argument('--stream-cell', type=float, default=0.4,
                    help='pose-cell edge length for the chunk partition')
    ap.add_argument('--stream-chunk', type=int, default=64,
                    help='Gaussians per chunk (the streaming granule)')
    ap.add_argument('--stream-max-loads', type=int, default=0, metavar='N',
                    help='chunk uploads per tick (0 = unbounded; misses '
                         'beyond it stall only the missing viewer)')
    ap.add_argument('--seed', type=int, default=0)
    args = ap.parse_args(argv)
    serve(args.viewers, args.frames, slots=args.slots, width=args.width,
          gaussians=args.gaussians, window=args.window,
          capacity=args.capacity, stagger=args.stagger,
          sequential=args.sequential, seed=args.seed,
          backend=args.backend, profile_every=args.profile_every,
          viewers_per_scene=args.viewers_per_scene,
          arrivals=args.arrivals, rate=args.rate, burst=args.burst,
          gap=args.gap, jitter=args.jitter, pace=args.pace,
          pace_jitter=args.pace_jitter, oversubscribe=args.oversubscribe,
          driver=args.driver,
          trace_out=args.trace_out, metrics_out=args.metrics_out,
          faults=args.faults, fault_rate=args.fault_rate,
          fault_seed=args.fault_seed, watchdog=args.watchdog,
          max_pending=args.max_pending,
          checkpoint_dir=args.checkpoint_dir,
          checkpoint_every=args.checkpoint_every, restore=args.restore,
          devices=args.devices, stream=args.stream,
          stream_budget=args.stream_budget, stream_near=args.stream_near,
          stream_lod=args.stream_lod,
          stream_lod_frac=args.stream_lod_frac,
          stream_cell=args.stream_cell, stream_chunk=args.stream_chunk,
          stream_max_loads=args.stream_max_loads)


if __name__ == '__main__':
    main()
