"""The serving host pipeline's event seam: tick plans and drivers.

The ``SessionManager`` tick decomposes into three operations (see
``repro.serve.session``):

  * ``plan_tick``    — pure host planning: which slots evict, which pending
    sessions admit where, which slots render which cameras, plus the
    stepper's pose-cell sort plan.  Numpy/python only — safe to run off the
    main thread;
  * ``apply_plan``   — atomic commit of the plan's admissions/evictions
    (holds the manager lock, so no observer ever sees a half-admitted tick);
  * ``observe_tick`` — per-frame telemetry + cursor advance once the device
    outputs land.

This module provides the two drivers that sequence those operations through
an explicit command/completion queue:

  * ``SyncDriver``     — the **virtual-clock** driver: processes the command
    protocol inline, one tick at a time, on a tick counter that IS the
    clock.  It replays any arrival/departure trace (sessions with
    ``arrival_tick``/trajectory lengths, e.g. from ``repro.serve.traffic``)
    deterministically and is bit-identical to the pre-pipeline synchronous
    engine — the parity oracle every async test leans on
    (``tests/test_serve_async.py``).
  * ``ThreadedDriver`` — the **real-time** driver: a host worker thread
    computes tick ``t+1``'s plan behind the command queue while the device
    executes tick ``t`` (the stepper's ``step_dispatch`` returns as soon as
    the jitted shade is dispatched; ``step_finish`` blocks).  Host admission
    /eviction/pose-cell planning thus overlaps device work instead of
    serializing into the render tick.  Control flow is identical to the
    sync driver — the plan for ``t+1`` is a pure function of post-dispatch
    host state plus the deterministic "active slots advanced one frame"
    adjustment — so images, cache tags and sort cadence stay bit-identical;
    only wall-clock telemetry (and the new ``host_ms``/``overlap_ms``
    attribution) differs.

Worker-thread safety contract: ``plan_tick`` touches manager state (pending
queue, slot sessions, cursors) and the stepper's host-side scheduler mirrors
(pose-cell pool bookkeeping, ``_pending_sort``), never device arrays.  The
threaded driver only requests a plan AFTER ``step_dispatch`` returns (all of
the stepper's host mutations for tick ``t`` are complete by then) and only
observes/applies AFTER the plan completion arrives — so the worker always
reads quiescent state; the queue pair is the synchronization.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import warnings
from typing import Optional


@dataclasses.dataclass(frozen=True)
class TickPlan:
    """One tick's host decisions, computed ahead of (and apart from) the
    device step.

    evict : slots whose (finished) sessions leave before this tick
    admit : ``(slot, sid)`` placements, in the order the pending queue
            releases them
    cams  : ``{slot: Camera}`` for the slots that render this tick (a paced
            session skips ticks between its due frames; its slot stays
            occupied but renders nothing)
    sort_plan : the stepper's precomputed pose-cell sort plan
            (``BatchedStepper.plan_step``), or None for steppers without a
            host planning phase
    switches : ``(slot, sid)`` lane swaps for oversubscribed slots — the
            named (stashed) co-resident session becomes the slot's lane
            occupant before this tick renders; the outgoing occupant is
            stashed, or retired if it already finished
    """

    tick: int
    evict: tuple
    admit: tuple
    cams: dict
    sort_plan: object = None
    switches: tuple = ()


@dataclasses.dataclass(frozen=True)
class HostTiming:
    """Host-side cost attribution for one tick.

    host_ms    : wall-clock of the tick's host planning work
    overlap_ms : portion of ``host_ms`` that ran while the device window of
                 the concurrent tick was open (dispatch -> outputs ready).
                 Zero by construction in the sync driver — planning
                 serializes into the tick there, which is exactly what the
                 threaded driver exists to hide.
    """

    host_ms: float = 0.0
    overlap_ms: float = 0.0


def _step_split(stepper):
    """The stepper's (dispatch, finish) pair; monolithic steppers fall back
    to doing all work in dispatch (their finish is a no-op), which keeps the
    protocol uniform at zero overlap."""
    dispatch = getattr(stepper, 'step_dispatch', None)
    finish = getattr(stepper, 'step_finish', None)
    if dispatch is not None and finish is not None:
        return dispatch, finish
    return (lambda cams, plan=None: stepper.step(cams)), (lambda out: out)


class SyncDriver:
    """Virtual-clock driver: the command/completion protocol executed inline.

    ``run`` drives plan -> apply -> step -> observe on a pure tick counter
    until every submitted session has completed.  Replaying the same
    arrival/departure trace (same sessions, same arrival ticks, same
    trajectories) reproduces the same images, cache tags, LRU ages and sort
    cadence bit-for-bit — there is no wall clock anywhere in the control
    path.
    """

    def __init__(self, mgr):
        self.mgr = mgr

    def run_tick(self) -> int:
        return self.mgr.run_tick()

    def run(self, max_ticks: int = 100_000):
        mgr = self.mgr
        while not mgr.drained():
            self.run_tick()
            mgr.evict_finished()
            mgr.maybe_checkpoint()
            if mgr.tick >= max_ticks:
                raise RuntimeError('serve loop did not drain')
        return mgr.finished


class ThreadedDriver:
    """Real-time driver: host planning double-buffered against device steps.

    Main-thread loop per tick ``t``::

        apply_plan(plan_t)                  # atomic admissions/evictions
        inflight = step_dispatch(cams_t)    # host scheduling + async dispatch
        cmd_q.put(plan request for t+1)     # worker plans while device runs
        outputs = step_finish(inflight)     # blocks on the device
        plan_{t+1} = out_q.get()            # completion (usually ready)
        observe_tick(plan_t, outputs)       # telemetry + cursor advance

    The worker's planning interval is intersected with the tick's device
    window ``[dispatch_start, outputs_ready]`` to report ``overlap_ms`` —
    the host work genuinely hidden behind the device step.

    **Hardening** (``repro.serve.faults``; every recovery path emits
    ``serve.faults{kind=...}`` / ``serve.degraded_ticks`` through the
    manager):

    * the completion wait is **bounded** (``mgr.watchdog_s``, default
      ``mgr.default_watchdog_s``): a worker that dies without posting no
      longer blocks the fleet forever — the loop warns, plans the tick
      inline (degraded mode), restarts the worker on a fresh queue pair and
      keeps serving;
    * a worker ``plan_tick`` **exception** no longer kills every viewer: the
      error is contained, the plan recomputed inline (a deterministic
      planner bug still surfaces — the inline replan re-raises it);
    * when containment drops a **poisoned frame** or a dispatch is **shed**,
      the worker's speculative plan (computed under the all-cursors-advance
      assumption) is discarded and the tick replanned inline after
      ``observe_tick`` — planning is pure, so the inline plan equals what
      the worker would have produced with the corrected cursor state;
    * at shutdown a worker that outlives ``join(timeout)`` is surfaced as a
      ``RuntimeWarning`` + ``serve.thread_leaks`` counter + obs instant
      instead of leaking silently.
    """

    JOIN_TIMEOUT_S = 5.0

    def __init__(self, mgr):
        self.mgr = mgr
        self._cmd_q: Optional[queue.Queue] = None
        self._out_q: Optional[queue.Queue] = None
        self._th: Optional[threading.Thread] = None

    # -- worker lifecycle --------------------------------------------------

    def _start_worker(self) -> None:
        mgr = self.mgr
        cmd_q: queue.Queue = queue.Queue()
        out_q: queue.Queue = queue.Queue()

        def worker():
            inj = mgr.injector
            while True:
                msg = cmd_q.get()
                if msg is None:
                    return
                tick, advanced = msg
                if inj.enabled \
                        and inj.take('worker_death', tick) is not None:
                    # simulated host-worker death: vanish without posting.
                    # The main loop's bounded get times out, degrades to
                    # inline planning and restarts the worker.  Counted
                    # here — the main thread cannot tell death from
                    # slowness, only this thread knows the event fired.
                    mgr.count_fault('worker_death', tick)
                    return
                t0 = time.perf_counter()
                try:
                    plan = mgr.plan_tick(tick, advanced=advanced)
                    out_q.put(('plan', plan, t0, time.perf_counter()))
                except BaseException as exc:  # contained on the main thread
                    out_q.put(('error', exc, t0, time.perf_counter()))

        th = threading.Thread(target=worker, name='serve-host-planner',
                              daemon=True)
        th.start()
        self._cmd_q, self._out_q, self._th = cmd_q, out_q, th

    def _restart_worker(self) -> None:
        """Replace a dead/hung worker.  Fresh queues isolate the old
        incarnation completely: if it ever wakes it sees the poison pill on
        its (orphaned) command queue and exits; a late completion it posts
        lands on a queue nobody reads."""
        if self._cmd_q is not None:
            self._cmd_q.put(None)
        self._start_worker()

    def _stop_worker(self) -> None:
        mgr = self.mgr
        if self._cmd_q is not None:
            self._cmd_q.put(None)
        if self._th is not None:
            self._th.join(timeout=self.JOIN_TIMEOUT_S)
            if self._th.is_alive():
                mgr.metrics.counter(
                    'serve.thread_leaks',
                    'planner threads alive past their join deadline').inc()
                mgr.tracer.instant('thread_leak', thread=self._th.name)
                warnings.warn(
                    f'{self._th.name} thread did not exit within '
                    f'{self.JOIN_TIMEOUT_S}s; daemon thread leaked',
                    RuntimeWarning, stacklevel=2)
        self._cmd_q = self._out_q = self._th = None

    # -- plan collection ---------------------------------------------------

    def _collect_plan(self, want_tick: int):
        """Bounded wait for the worker's plan for ``want_tick``.  Returns
        ``(plan, p0, p1)`` or ``(None, 0, 0)`` when the tick must be planned
        inline: the worker died (timeout -> warn + restart) or its
        ``plan_tick`` raised (fault counted; a real deterministic bug
        re-raises from the caller's inline replan)."""
        mgr = self.mgr
        deadline = mgr.watchdog_s if mgr.watchdog_s is not None \
            else mgr.default_watchdog_s
        try:
            kind, payload, p0, p1 = self._out_q.get(timeout=deadline)
        except queue.Empty:
            mgr.metrics.counter(
                'serve.watchdog',
                'finish/plan watchdog deadline expiries').inc()
            mgr.tracer.instant('watchdog', what='planner', tick=want_tick)
            warnings.warn(
                f'serve watchdog: no plan for tick {want_tick} within '
                f'{deadline}s (worker dead?); replanning inline and '
                f'restarting the worker', RuntimeWarning, stacklevel=2)
            self._restart_worker()
            return None, 0.0, 0.0
        if kind == 'error':
            from repro.serve import faults as serve_faults
            if not isinstance(payload, serve_faults.InjectedFault):
                # a real planner error: contained (the fleet keeps serving)
                # but never silent
                warnings.warn(f'planner worker raised {payload!r}; '
                              f'replanning tick {want_tick} inline',
                              RuntimeWarning, stacklevel=2)
            mgr.count_fault('plan_exc', want_tick)
            return None, 0.0, 0.0
        return payload, p0, p1

    # -- the loop ----------------------------------------------------------

    def run(self, max_ticks: int = 100_000):
        mgr = self.mgr
        dispatch, finish = _step_split(mgr.stepper)
        self._start_worker()

        def inline_plan():
            t0 = time.perf_counter()
            plan = mgr.plan_tick_hardened()
            return plan, HostTiming(
                host_ms=(time.perf_counter() - t0) * 1e3)

        try:
            plan, host0 = inline_plan()
            while True:
                # the tick span lives on the 'host' track; the worker's
                # plan_tick span for t+1 lands on 'host-worker' and the
                # stepper's shade window on 'device' — the three-lane
                # overlap picture Perfetto renders (repro.obs)
                with mgr.tracer.span('tick', tick=plan.tick):
                    mgr.apply_plan(plan)
                    if mgr.drained():
                        break
                    t_disp = time.perf_counter()
                    inflight, ok = mgr.dispatch_hardened(dispatch, plan.cams,
                                                         plan)
                    if not ok:
                        # shed tick: nothing in flight and the worker was
                        # never asked — observe the empty tick (cursors
                        # stay put, frames retry) and plan inline
                        mgr.observe_tick(plan, {}, host=host0)
                        mgr.maybe_checkpoint()
                        plan, host0 = inline_plan()
                        continue
                    # all host mutations for tick t are committed by now;
                    # hand the worker tick t+1 while the device crunches
                    # tick t
                    self._cmd_q.put((plan.tick + 1, frozenset(plan.cams)))
                    outputs = mgr.finish_hardened(finish, inflight,
                                                  plan.tick)
                    t_ready = time.perf_counter()
                    outputs = mgr.poison_outputs(outputs, plan.tick)
                    outputs, poisoned = mgr.contain_outputs(outputs,
                                                            plan.tick)
                    nxt, p0, p1 = self._collect_plan(plan.tick + 1)
                    mgr.observe_tick(plan, outputs, host=host0)
                    mgr.maybe_checkpoint()
                    if nxt is None or poisoned:
                        # degraded tick: the worker's plan is missing, or
                        # it assumed a cursor advance containment rolled
                        # back — replan inline on post-observe state
                        # (planning is pure: this equals the pre-observe
                        # plan with the corrected `advanced` set)
                        mgr.count_degraded(plan.tick + 1)
                        plan, host0 = inline_plan()
                    else:
                        overlap_s = max(0.0, min(p1, t_ready)
                                        - max(p0, t_disp))
                        host0 = HostTiming(host_ms=(p1 - p0) * 1e3,
                                           overlap_ms=overlap_s * 1e3)
                        plan = nxt
                if mgr.tick >= max_ticks:
                    raise RuntimeError('serve loop did not drain')
        finally:
            self._stop_worker()
        return mgr.finished


DRIVERS = {'sync': SyncDriver, 'threaded': ThreadedDriver}


def get_driver(name: str, mgr):
    try:
        return DRIVERS[name](mgr)
    except KeyError:
        raise ValueError(f'unknown serve driver {name!r} '
                         f'(expected one of {sorted(DRIVERS)})') from None
