"""Multi-viewer batched render serving over one shared GaussianScene.

Layers (bottom-up):
  * ``repro.core.pipeline.sort_phase`` / ``shade_phase`` — the pure two-phase
    frame over ``SceneShared``/``ViewerPrivate`` state (lives in core; the
    serving path schedules the phases itself instead of using
    ``render_step``'s per-viewer ``lax.cond``);
  * ``stepper``   — Batched (pose-cell sort scheduler + one scene-major
    shade per tick, scene-shared caches, state buffers donated) /
    Sequential engines, each split into ``plan_step`` / ``step_dispatch`` /
    ``step_finish`` for the async host loop;
  * ``session``   — viewer sessions (with ``scene_id`` and frame ``pace``)
    + slot-based admit/evict manager whose tick decomposes into
    ``plan_tick`` / ``apply_plan`` / ``observe_tick`` (keeps the per-tick
    ``tick_log`` of sort/shade/host attribution + state metrics);
  * ``events``    — the host-pipeline seam: ``TickPlan`` and the two
    drivers — ``SyncDriver`` (virtual clock, deterministic replay, the
    parity oracle) and ``ThreadedDriver`` (host planning double-buffered
    against the device step behind a command/completion queue);
  * ``traffic``   — replayable open-loop arrival traces (stagger / poisson
    / bursty) with per-viewer frame pacing;
  * ``telemetry`` — per-session FPS / hit-rate / latency percentiles /
    per-phase ``sort_ms``+``shade_ms``, fleet ``tick_rollup`` (now with
    per-frame p50/p95 latency and the host-overlap fraction);
  * ``render``    — the CLI entrypoint (``python -m repro.serve.render``).

Cross-cutting: every layer publishes spans/instants into a ``repro.obs``
tracer and typed metrics into a ``repro.obs.metrics.Registry`` (both
injected via ``SessionManager``; no-ops by default) — see the README's
"Observability" section and ``--trace-out`` / ``--metrics-out`` on the CLI.
"""
from repro.serve.events import (HostTiming, SyncDriver, ThreadedDriver,
                                TickPlan)
from repro.serve.fleet import (FleetManager, SyncFleetDriver,
                               ThreadedFleetDriver, serve_fleet)
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper, SequentialStepper, TickTiming
from repro.serve.telemetry import (SessionTelemetry, aggregate, format_table,
                                   tick_rollup)
from repro.serve.traffic import TrafficTrace, make_trace

__all__ = [
    'BatchedStepper', 'SequentialStepper', 'SessionManager', 'TickTiming',
    'ViewerSession', 'SessionTelemetry', 'aggregate', 'format_table',
    'tick_rollup', 'TickPlan', 'HostTiming', 'SyncDriver', 'ThreadedDriver',
    'FleetManager', 'SyncFleetDriver', 'ThreadedFleetDriver', 'serve_fleet',
    'TrafficTrace', 'make_trace',
]
