"""Multi-viewer batched render serving over one shared GaussianScene.

Layers (bottom-up):
  * ``repro.core.pipeline.sort_phase`` / ``shade_phase`` — the pure two-phase
    frame over ``SceneShared``/``ViewerPrivate`` state (lives in core; the
    serving path schedules the phases itself instead of using
    ``render_step``'s per-viewer ``lax.cond``);
  * ``stepper``   — Batched (pose-cell sort scheduler + one scene-major
    shade per tick, scene-shared caches, state buffers donated) /
    Sequential engines;
  * ``session``   — viewer sessions (with ``scene_id``) + slot-based
    admit/evict manager routing sessions to scene blocks (keeps the
    per-tick ``tick_log`` of sort/shade attribution + state metrics);
  * ``telemetry`` — per-session FPS / hit-rate / latency percentiles /
    per-phase ``sort_ms``+``shade_ms``, fleet ``tick_rollup``;
  * ``render``    — the CLI entrypoint (``python -m repro.serve.render``).
"""
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper, SequentialStepper, TickTiming
from repro.serve.telemetry import (SessionTelemetry, aggregate, format_table,
                                   tick_rollup)

__all__ = [
    'BatchedStepper', 'SequentialStepper', 'SessionManager', 'TickTiming',
    'ViewerSession', 'SessionTelemetry', 'aggregate', 'format_table',
    'tick_rollup',
]
