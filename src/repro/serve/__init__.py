"""Multi-viewer batched render serving over one shared GaussianScene.

Layers (bottom-up):
  * ``repro.core.pipeline.render_step`` — the pure per-viewer frame function
    (lives in core; vmapped here for the batched path);
  * ``stepper``   — Batched (one vmapped call per tick) / Sequential engines;
  * ``session``   — viewer sessions + slot-based admit/evict manager;
  * ``telemetry`` — per-session FPS / hit-rate / latency percentiles;
  * ``render``    — the CLI entrypoint (``python -m repro.serve.render``).
"""
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper, SequentialStepper
from repro.serve.telemetry import (SessionTelemetry, aggregate, format_table)

__all__ = [
    'BatchedStepper', 'SequentialStepper', 'SessionManager', 'ViewerSession',
    'SessionTelemetry', 'aggregate', 'format_table',
]
