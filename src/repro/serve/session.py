"""Viewer sessions and the slot-based session manager.

The manager mirrors the continuous-batching LM server
(``repro.launch.serve``): a fixed number of slots, a queue of pending
viewers with arrival times, admit-on-free-slot, evict-on-completion.  A
viewer session is a camera trajectory (one camera per frame) plus its
telemetry; slots hold whichever sessions are currently live, and the
stepper advances every live slot one frame per tick.

Scene-centric serving: sessions carry a ``scene_id`` and the manager groups
slots by scene — when the stepper serves ``viewers_per_scene > 1`` slots per
scene block, a session is only admitted into a free slot of *its* scene's
block, so co-scene viewers land on the block whose ``SceneShared`` (radiance
cache + sort pool) they are meant to share.  With one viewer per scene (the
default) scene identity does not constrain placement and admission is plain
FIFO over all free slots, exactly the pre-split behavior.

**Host pipeline**: a tick decomposes into three explicit operations —

  * ``plan_tick``    — pure planning (evictions, admissions, due cameras,
    the stepper's pose-cell sort plan); numpy/python only, safe off-thread;
  * ``apply_plan``   — atomic commit of the plan under the manager lock
    (no observer ever sees a half-admitted tick);
  * ``observe_tick`` — telemetry + cursor advance once device outputs land.

``run_tick`` is their inline composition (identical to the pre-pipeline
synchronous engine); ``run(driver=...)`` hands the sequencing to a driver
from ``repro.serve.events`` — ``'sync'`` (virtual clock, deterministic
replay) or ``'threaded'`` (host planning double-buffered against the
device step).

**Frame pacing**: a session with ``pace = p`` consumes one frame every
``p`` ticks (open-loop clients slower than the tick clock, see
``repro.serve.traffic``); its slot stays occupied on off ticks but renders
nothing.  ``pace = 1`` (the default) is the legacy every-tick behavior.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Optional

from repro.core.camera import Camera
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.events import HostTiming, TickPlan, get_driver
from repro.serve.telemetry import SessionTelemetry


@dataclasses.dataclass
class ViewerSession:
    """One viewer's camera stream: frames are consumed front-to-back.

    ``scene_id`` names the scene this viewer watches; viewers sharing it are
    eligible to share that scene's radiance cache and speculative sorts.
    ``pace`` is the session's frame interval in ticks (>= 1): a pace-``p``
    viewer renders on ticks ``admitted_tick + k * p`` only.
    """

    sid: int
    cams: list          # list[Camera], one per frame
    arrival_tick: int = 0
    cursor: int = 0
    scene_id: int = 0
    pace: int = 1
    telemetry: Optional[SessionTelemetry] = None

    def __post_init__(self):
        if self.pace < 1:
            raise ValueError(f'session pace must be >= 1, got {self.pace}')
        if self.telemetry is None:
            self.telemetry = SessionTelemetry(sid=self.sid,
                                              arrival_tick=self.arrival_tick)

    @property
    def done(self) -> bool:
        return self.cursor >= len(self.cams)

    def current_cam(self) -> Camera:
        return self.cams[self.cursor]


class SessionManager:
    """Admit/evict viewers over a fixed set of render slots.

    ``stepper`` is any object with the ``admit(slot)`` / ``step({slot: cam})``
    interface of ``repro.serve.stepper``; the manager owns which sessions sit
    in which slots and feeds their per-frame stats into telemetry.  When the
    stepper exposes ``viewers_per_scene > 1``, slots are grouped into scene
    blocks and sessions are placed by ``scene_id`` (see module docstring).

    All session-placement mutations (``apply_plan``/``observe_tick`` and the
    legacy ``admit_ready``/``evict_finished``) hold ``self._lock``;
    ``snapshot()`` reads under the same lock, so concurrent observers (the
    threaded driver's telemetry consumers, tests) always see a consistent
    admission state.
    """

    def __init__(self, stepper, slots: int, tracer=None,
                 metrics: Optional[obs_metrics.Registry] = None):
        self.stepper = stepper
        self.slots = slots
        # Observability (repro.obs): a span tracer (NULL no-op by default)
        # and a typed metrics registry, shared with the stepper so sort
        # scheduling / kernel-stage events land in the same trace.
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        self.metrics = metrics if metrics is not None else \
            obs_metrics.Registry()
        stepper.tracer = self.tracer
        stepper.metrics = self.metrics
        self.viewers_per_scene = getattr(stepper, 'viewers_per_scene', 1)
        self.num_scenes = max(1, slots // self.viewers_per_scene)
        self.slot_session: list[Optional[ViewerSession]] = [None] * slots
        self.pending: deque[ViewerSession] = deque()
        self.finished: list[ViewerSession] = []
        self.tick = 0
        self._lock = threading.Lock()
        # host planning spent on zero-frame ticks (arrival gaps, paced
        # idle ticks) carries into the next logged entry, so host_ms /
        # host_overlap stay honest for open-loop workloads
        self._carry_host_ms = 0.0
        self._carry_overlap_ms = 0.0
        # Per-tick phase attribution: {'tick', 'frames', 'sorted_slots',
        # 'sort_ms', 'shade_ms', 'latency_ms', 'host_ms', 'overlap_ms',
        # 'kernel_ms'} per rendered tick (empty ticks are skipped; kernel_ms
        # is None except on profiled pallas ticks), plus the stepper's state
        # metrics (cache occupancy, live sort-pool entries, state bytes)
        # when it exposes ``state_metrics()``.
        self.tick_log: list[dict] = []

    # -- lifecycle ---------------------------------------------------------

    def submit(self, session: ViewerSession) -> None:
        """Queue a session for admission.  Lock-safe against a concurrent
        threaded run: a session submitted mid-run is simply picked up by
        the next tick's plan."""
        with self._lock:
            self.pending.append(session)
        self.tracer.instant('arrival', sid=session.sid,
                            arrival_tick=session.arrival_tick)

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slot_session) if s is None]

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slot_session) if s is not None]

    def _scene_block(self, scene_id: int) -> range:
        """Slot range of a session's scene block (scene ids beyond the
        stepper's scene count wrap — the block is a cache domain, not a
        registry of world scenes)."""
        c = scene_id % self.num_scenes
        v = self.viewers_per_scene
        return range(c * v, (c + 1) * v)

    def _admit_into(self, slot: int, sess: ViewerSession) -> None:
        sess.telemetry.admitted_tick = self.tick
        self.slot_session[slot] = sess
        self.stepper.admit(slot)

    def admit_ready(self) -> list[int]:
        """Admit arrived pending sessions into free slots (FIFO; with scene
        blocks, FIFO per admissible session — a session whose block is full
        waits without blocking later sessions bound for other scenes)."""
        with self._lock:
            return self._admit_ready_locked()

    def _admit_ready_locked(self) -> list[int]:
        admitted = []
        if self.viewers_per_scene == 1:
            for slot in self.free_slots():
                if not self.pending or self.pending[0].arrival_tick > self.tick:
                    break
                self._admit_into(slot, self.pending.popleft())
                admitted.append(slot)
            return admitted
        waiting = deque()
        while self.pending:
            sess = self.pending.popleft()
            if sess.arrival_tick > self.tick:
                waiting.append(sess)
                continue
            free = [i for i in self._scene_block(sess.scene_id)
                    if self.slot_session[i] is None]
            if free:
                self._admit_into(free[0], sess)
                admitted.append(free[0])
            else:
                waiting.append(sess)
        self.pending = waiting
        return admitted

    def evict_finished(self) -> list[int]:
        with self._lock:
            return self._evict_finished_locked()

    def _evict_finished_locked(self) -> list[int]:
        evicted = []
        for slot, sess in enumerate(self.slot_session):
            if sess is not None and sess.done:
                sess.telemetry.finished_tick = self.tick
                self.finished.append(sess)
                self.slot_session[slot] = None
                evicted.append(slot)
        return evicted

    # -- the host pipeline: plan / apply / observe -------------------------

    def _frame_due(self, sess: ViewerSession, tick: int) -> bool:
        """Does this (already-admitted) session consume a frame on
        ``tick``?  Paced sessions render every ``pace`` ticks counted from
        admission; sessions admitted this very tick don't come through
        here — ``plan_tick`` assigns their first frame directly."""
        return (tick - sess.telemetry.admitted_tick) % sess.pace == 0

    def plan_tick(self, tick: Optional[int] = None,
                  advanced=()) -> TickPlan:
        """Compute the next tick's host decisions without mutating anything.

        ``advanced`` names the slots of an in-flight, not-yet-observed tick:
        their sessions are treated as one frame further along (the threaded
        driver's double-buffer adjustment — eviction/camera choices for tick
        ``t+1`` are a pure function of tick ``t``'s inputs, never its device
        outputs).  With no tick in flight (the sync path) it is empty and
        this reads the literal manager state.

        The returned plan also carries the stepper's pose-cell sort plan
        (``plan_step``) when the stepper has a host planning phase, computed
        against the post-admission active set — the piece of per-tick host
        work the async pipeline exists to overlap.
        """
        tick = self.tick if tick is None else tick
        with self.tracer.span('plan_tick', tick=tick):
            return self._plan_tick(tick, advanced)

    def _plan_tick(self, tick: int, advanced=()) -> TickPlan:
        adv = frozenset(advanced)

        def cursor_of(slot: int, sess: ViewerSession) -> int:
            return sess.cursor + (1 if slot in adv else 0)

        evict = tuple(
            slot for slot, sess in enumerate(self.slot_session)
            if sess is not None and cursor_of(slot, sess) >= len(sess.cams))
        free = sorted(set(self.free_slots()) | set(evict))
        placements = self._plan_admissions(free, tick)
        admit = tuple((slot, sess.sid) for slot, sess in placements)
        admitted_slots = {slot for slot, _ in admit}

        cams: dict[int, Camera] = {}
        for slot, sess in enumerate(self.slot_session):
            if sess is None or slot in evict or slot in admitted_slots:
                continue
            if self._frame_due(sess, tick):
                cams[slot] = sess.cams[cursor_of(slot, sess)]
        for slot, sess in placements:
            cams[slot] = sess.cams[0]

        sort_plan = None
        plan_step = getattr(self.stepper, 'plan_step', None)
        if plan_step is not None:
            sort_plan = plan_step(cams, pending_admits=admitted_slots)
        return TickPlan(tick=tick, evict=evict, admit=admit, cams=cams,
                        sort_plan=sort_plan)

    def _plan_admissions(self, free: list, tick: int) -> list:
        """Pure mirror of ``admit_ready`` over a hypothetical free-slot list:
        returns ``(slot, session)`` placements in pending-queue order
        without popping anything.  The pending snapshot is taken under the
        lock (this runs on the planner worker; ``submit`` may race), and in
        FIFO mode only the first ``len(free)`` entries are materialized —
        a deep open-loop backlog must not cost O(queue) host work per tick.
        """
        with self._lock:
            if self.viewers_per_scene == 1:
                pending = list(itertools.islice(self.pending, len(free)))
            else:
                pending = list(self.pending)
        placements = []
        if self.viewers_per_scene == 1:
            k = 0
            for slot in free:
                if k >= len(pending) or pending[k].arrival_tick > tick:
                    break
                placements.append((slot, pending[k]))
                k += 1
            return placements
        remaining = set(free)
        for sess in pending:
            if sess.arrival_tick > tick:
                continue
            block = [i for i in self._scene_block(sess.scene_id)
                     if i in remaining]
            if block:
                placements.append((block[0], sess))
                remaining.discard(block[0])
        return placements

    def apply_plan(self, plan: TickPlan) -> None:
        """Atomically commit a plan's evictions and admissions.  Holding the
        lock across the whole commit is the no-partial-admission guarantee:
        a session is either fully pending or fully admitted (placed, stepper
        slot reset, ``admitted_tick`` stamped) in any concurrent view."""
        with self.tracer.span('apply_plan', tick=plan.tick,
                              admits=len(plan.admit),
                              evicts=len(plan.evict)), self._lock:
            if plan.tick != self.tick:
                raise RuntimeError(f'stale plan: tick {plan.tick} applied at '
                                   f'manager tick {self.tick}')
            for slot in plan.evict:
                sess = self.slot_session[slot]
                if sess is None or not sess.done:
                    raise RuntimeError(f'plan evicts slot {slot} whose '
                                       f'session is not finished')
                sess.telemetry.finished_tick = plan.tick
                self.finished.append(sess)
                self.slot_session[slot] = None
                self.tracer.instant('evict', slot=slot, sid=sess.sid,
                                    tick=plan.tick)
            self.metrics.counter(
                'serve.evicted', 'sessions leaving their slot').inc(
                    len(plan.evict))
            for slot, sid in plan.admit:
                if self.slot_session[slot] is not None:
                    raise RuntimeError(f'plan admits into occupied slot '
                                       f'{slot}')
                sess = next((s for s in self.pending if s.sid == sid), None)
                if sess is None:
                    raise RuntimeError(f'planned session {sid} not pending')
                self.pending.remove(sess)
                self._admit_into(slot, sess)
                self.tracer.instant('admit', slot=slot, sid=sid,
                                    tick=plan.tick)
            self.metrics.counter(
                'serve.admitted', 'sessions placed into a slot').inc(
                    len(plan.admit))
            self.metrics.gauge(
                'serve.queue_depth', 'pending sessions after admission').set(
                    len(self.pending))

    def observe_tick(self, plan: TickPlan, outputs: dict,
                     host: Optional[HostTiming] = None) -> int:
        """Record a completed tick: per-frame telemetry, cursor advance, the
        tick log entry (mirrored into the metrics registry's ``tick.*``
        series), and the clock advance to ``plan.tick + 1``."""
        with self.tracer.span('observe_tick', tick=plan.tick,
                              frames=len(outputs)), self._lock:
            for slot, (_image, stats, timing) in outputs.items():
                sess = self.slot_session[slot]
                hit_rate = float(stats.hit_rate)
                saved_frac = float(stats.saved_frac)
                sess.telemetry.observe_frame(
                    latency_s=timing.latency_s,
                    hit_rate=hit_rate,
                    saved_frac=saved_frac,
                    sorted_flag=float(stats.sorted_this_frame),
                    sort_ms=timing.sort_ms,
                    shade_ms=timing.shade_ms)
                sess.cursor += 1
                self.metrics.histogram(
                    'cache.hit_rate', 'per-frame RC hit rate',
                    scene=sess.scene_id).observe(hit_rate)
                self.metrics.histogram(
                    'rc.saved_frac', 'integration skipped via RC',
                    scene=sess.scene_id).observe(saved_frac)
            # paced-idle accounting: occupied slots that rendered nothing
            # this tick (pace gaps; a done session awaiting eviction also
            # counts — its slot is held either way)
            idle = sum(1 for s in self.slot_session
                       if s is not None) - len(outputs)
            if idle > 0:
                self.metrics.counter(
                    'serve.paced_idle',
                    'occupied slot-ticks that rendered no frame').inc(idle)
                self.tracer.instant('pace', tick=plan.tick, idle_slots=idle)
            self.metrics.counter('serve.frames',
                                 'frames rendered').inc(len(outputs))
            if outputs:
                tick_timing = self.stepper.last_timing
                entry = {
                    'tick': plan.tick,
                    'frames': len(outputs),
                    'sorted_slots': tick_timing.sorted_slots,
                    'sort_ms': tick_timing.sort_ms,
                    'shade_ms': tick_timing.shade_ms,
                    'latency_ms': tick_timing.latency_s * 1e3,
                    'host_ms': self._carry_host_ms
                               + (host.host_ms if host else 0.0),
                    'overlap_ms': self._carry_overlap_ms
                                  + (host.overlap_ms if host else 0.0),
                    'kernel_ms': getattr(tick_timing, 'kernel_ms', None),
                }
                self._carry_host_ms = self._carry_overlap_ms = 0.0
                metrics = getattr(self.stepper, 'state_metrics', None)
                if metrics is not None:
                    entry.update(metrics())
                self.tick_log.append(entry)
                obs_metrics.publish_tick(self.metrics, entry)
                self.metrics.histogram(
                    'serve.tick_latency_ms',
                    'wall latency of rendered ticks').observe(
                        entry['latency_ms'])
            elif host is not None:
                self._carry_host_ms += host.host_ms
                self._carry_overlap_ms += host.overlap_ms
            self.tick = plan.tick + 1
            return len(outputs)

    def snapshot(self) -> dict:
        """A consistent view of session placement for concurrent observers:
        pending sids, ``(slot, sid, admitted_tick)`` for occupied slots,
        finished sids, and the tick — all read under the manager lock."""
        with self._lock:
            return {
                'tick': self.tick,
                'pending': tuple(s.sid for s in self.pending),
                'slotted': tuple(
                    (slot, s.sid, s.telemetry.admitted_tick)
                    for slot, s in enumerate(self.slot_session)
                    if s is not None),
                'finished': tuple(s.sid for s in self.finished),
            }

    # -- the serving loop --------------------------------------------------

    def run_tick(self) -> int:
        """One scheduler tick: evict, admit, render every due slot one frame
        (plan -> apply -> step -> observe, inline).

        Returns the number of frames rendered this tick.
        """
        with self.tracer.span('tick', tick=self.tick):
            t0 = time.perf_counter()
            plan = self.plan_tick()
            host = HostTiming(host_ms=(time.perf_counter() - t0) * 1e3)
            self.apply_plan(plan)
            outputs = self.stepper.step(plan.cams, plan=plan.sort_plan)
            return self.observe_tick(plan, outputs, host=host)

    def drained(self) -> bool:
        return not self.pending and not self.active_slots()

    def run(self, max_ticks: int = 100_000,
            driver: str = 'sync') -> list[ViewerSession]:
        """Drive ticks until every submitted session has completed.

        ``driver='sync'`` is the virtual-clock host loop (deterministic,
        bit-identical replay); ``driver='threaded'`` double-buffers host
        planning against the device step (``repro.serve.events``).
        """
        return get_driver(driver, self).run(max_ticks)
