"""Viewer sessions and the slot-based session manager.

The manager mirrors the continuous-batching LM server
(``repro.launch.serve``): a fixed number of slots, a queue of pending
viewers with arrival times, admit-on-free-slot, evict-on-completion.  A
viewer session is a camera trajectory (one camera per frame) plus its
telemetry; slots hold whichever sessions are currently live, and the
stepper advances every live slot one frame per tick.

Scene-centric serving: sessions carry a ``scene_id`` and the manager groups
slots by scene — when the stepper serves ``viewers_per_scene > 1`` slots per
scene block, a session is only admitted into a free slot of *its* scene's
block, so co-scene viewers land on the block whose ``SceneShared`` (radiance
cache + sort pool) they are meant to share.  With one viewer per scene (the
default) scene identity does not constrain placement and admission is plain
FIFO over all free slots, exactly the pre-split behavior.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

from repro.core.camera import Camera
from repro.serve.telemetry import SessionTelemetry


@dataclasses.dataclass
class ViewerSession:
    """One viewer's camera stream: frames are consumed front-to-back.

    ``scene_id`` names the scene this viewer watches; viewers sharing it are
    eligible to share that scene's radiance cache and speculative sorts.
    """

    sid: int
    cams: list          # list[Camera], one per frame
    arrival_tick: int = 0
    cursor: int = 0
    scene_id: int = 0
    telemetry: Optional[SessionTelemetry] = None

    def __post_init__(self):
        if self.telemetry is None:
            self.telemetry = SessionTelemetry(sid=self.sid,
                                              arrival_tick=self.arrival_tick)

    @property
    def done(self) -> bool:
        return self.cursor >= len(self.cams)

    def current_cam(self) -> Camera:
        return self.cams[self.cursor]


class SessionManager:
    """Admit/evict viewers over a fixed set of render slots.

    ``stepper`` is any object with the ``admit(slot)`` / ``step({slot: cam})``
    interface of ``repro.serve.stepper``; the manager owns which sessions sit
    in which slots and feeds their per-frame stats into telemetry.  When the
    stepper exposes ``viewers_per_scene > 1``, slots are grouped into scene
    blocks and sessions are placed by ``scene_id`` (see module docstring).
    """

    def __init__(self, stepper, slots: int):
        self.stepper = stepper
        self.slots = slots
        self.viewers_per_scene = getattr(stepper, 'viewers_per_scene', 1)
        self.num_scenes = max(1, slots // self.viewers_per_scene)
        self.slot_session: list[Optional[ViewerSession]] = [None] * slots
        self.pending: deque[ViewerSession] = deque()
        self.finished: list[ViewerSession] = []
        self.tick = 0
        # Per-tick phase attribution: {'tick', 'frames', 'sorted_slots',
        # 'sort_ms', 'shade_ms', 'kernel_ms'} per rendered tick (empty ticks
        # are skipped; kernel_ms is None except on profiled pallas ticks),
        # plus the stepper's state metrics (cache occupancy, live sort-pool
        # entries, state bytes) when it exposes ``state_metrics()``.
        self.tick_log: list[dict] = []

    # -- lifecycle ---------------------------------------------------------

    def submit(self, session: ViewerSession) -> None:
        self.pending.append(session)

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slot_session) if s is None]

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slot_session) if s is not None]

    def _scene_block(self, scene_id: int) -> range:
        """Slot range of a session's scene block (scene ids beyond the
        stepper's scene count wrap — the block is a cache domain, not a
        registry of world scenes)."""
        c = scene_id % self.num_scenes
        v = self.viewers_per_scene
        return range(c * v, (c + 1) * v)

    def _admit_into(self, slot: int, sess: ViewerSession) -> None:
        sess.telemetry.admitted_tick = self.tick
        self.slot_session[slot] = sess
        self.stepper.admit(slot)

    def admit_ready(self) -> list[int]:
        """Admit arrived pending sessions into free slots (FIFO; with scene
        blocks, FIFO per admissible session — a session whose block is full
        waits without blocking later sessions bound for other scenes)."""
        admitted = []
        if self.viewers_per_scene == 1:
            for slot in self.free_slots():
                if not self.pending or self.pending[0].arrival_tick > self.tick:
                    break
                self._admit_into(slot, self.pending.popleft())
                admitted.append(slot)
            return admitted
        waiting = deque()
        while self.pending:
            sess = self.pending.popleft()
            if sess.arrival_tick > self.tick:
                waiting.append(sess)
                continue
            free = [i for i in self._scene_block(sess.scene_id)
                    if self.slot_session[i] is None]
            if free:
                self._admit_into(free[0], sess)
                admitted.append(free[0])
            else:
                waiting.append(sess)
        self.pending = waiting
        return admitted

    def evict_finished(self) -> list[int]:
        evicted = []
        for slot, sess in enumerate(self.slot_session):
            if sess is not None and sess.done:
                sess.telemetry.finished_tick = self.tick
                self.finished.append(sess)
                self.slot_session[slot] = None
                evicted.append(slot)
        return evicted

    # -- the serving loop --------------------------------------------------

    def run_tick(self) -> int:
        """One scheduler tick: evict, admit, render every live slot one frame.

        Returns the number of frames rendered this tick.
        """
        self.evict_finished()
        self.admit_ready()
        cams = {slot: self.slot_session[slot].current_cam()
                for slot in self.active_slots()}
        outputs = self.stepper.step(cams)
        for slot, (_image, stats, timing) in outputs.items():
            sess = self.slot_session[slot]
            sess.telemetry.observe_frame(
                latency_s=timing.latency_s,
                hit_rate=float(stats.hit_rate),
                saved_frac=float(stats.saved_frac),
                sorted_flag=float(stats.sorted_this_frame),
                sort_ms=timing.sort_ms,
                shade_ms=timing.shade_ms)
            sess.cursor += 1
        if outputs:
            tick_timing = self.stepper.last_timing
            entry = {
                'tick': self.tick,
                'frames': len(outputs),
                'sorted_slots': tick_timing.sorted_slots,
                'sort_ms': tick_timing.sort_ms,
                'shade_ms': tick_timing.shade_ms,
                'kernel_ms': getattr(tick_timing, 'kernel_ms', None),
            }
            metrics = getattr(self.stepper, 'state_metrics', None)
            if metrics is not None:
                entry.update(metrics())
            self.tick_log.append(entry)
        self.tick += 1
        return len(outputs)

    def drained(self) -> bool:
        return not self.pending and not self.active_slots()

    def run(self, max_ticks: int = 100_000) -> list[ViewerSession]:
        """Drive ticks until every submitted session has completed."""
        while not self.drained():
            self.run_tick()
            self.evict_finished()
            if self.tick >= max_ticks:
                raise RuntimeError('serve loop did not drain')
        return self.finished
