"""Viewer sessions and the slot-based session manager.

The manager mirrors the continuous-batching LM server
(``repro.launch.serve``): a fixed number of slots, a queue of pending
viewers with arrival times, admit-on-free-slot, evict-on-completion.  A
viewer session is a camera trajectory (one camera per frame) plus its
telemetry; slots hold whichever sessions are currently live, and the
stepper advances every live slot one frame per tick.

Scene-centric serving: sessions carry a ``scene_id`` and the manager groups
slots by scene — when the stepper serves ``viewers_per_scene > 1`` slots per
scene block, a session is only admitted into a free slot of *its* scene's
block, so co-scene viewers land on the block whose ``SceneShared`` (radiance
cache + sort pool) they are meant to share.  With one viewer per scene (the
default) scene identity does not constrain placement and admission is plain
FIFO over all free slots, exactly the pre-split behavior.

**Host pipeline**: a tick decomposes into three explicit operations —

  * ``plan_tick``    — pure planning (evictions, admissions, due cameras,
    the stepper's pose-cell sort plan); numpy/python only, safe off-thread;
  * ``apply_plan``   — atomic commit of the plan under the manager lock
    (no observer ever sees a half-admitted tick);
  * ``observe_tick`` — telemetry + cursor advance once device outputs land.

``run_tick`` is their inline composition (identical to the pre-pipeline
synchronous engine); ``run(driver=...)`` hands the sequencing to a driver
from ``repro.serve.events`` — ``'sync'`` (virtual clock, deterministic
replay) or ``'threaded'`` (host planning double-buffered against the
device step).

**Frame pacing**: a session with ``pace = p`` consumes one frame every
``p`` ticks (open-loop clients slower than the tick clock, see
``repro.serve.traffic``); its slot stays occupied on off ticks but renders
nothing.  ``pace = 1`` (the default) is the legacy every-tick behavior.

**Slot oversubscription** (``oversubscribe=True``, shared-scene steppers
only): paced sessions whose render ticks provably never collide — admission
requires ``(tick - admitted_tick_r) % gcd(pace_r, pace_new) != 0`` against
every current resident, which pins the newcomer to a disjoint residue class
forever — interleave in ONE physical slot.  The lane's occupant renders;
co-residents are parked in the stepper's stash (``stash_lane``) and swapped
in on their due ticks (``TickPlan.switches``).  A half-rate pace-2 pair
thus serves two viewers from one slot's worth of device state.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import threading
import time
import warnings
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.camera import Camera
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import faults as serve_faults
from repro.serve.events import HostTiming, TickPlan, _step_split, get_driver
from repro.serve.telemetry import SessionTelemetry


@dataclasses.dataclass
class ViewerSession:
    """One viewer's camera stream: frames are consumed front-to-back.

    ``scene_id`` names the scene this viewer watches; viewers sharing it are
    eligible to share that scene's radiance cache and speculative sorts.
    ``pace`` is the session's frame interval in ticks (>= 1): a pace-``p``
    viewer renders on ticks ``admitted_tick + k * p`` only.
    """

    sid: int
    cams: list          # list[Camera], one per frame
    arrival_tick: int = 0
    cursor: int = 0
    scene_id: int = 0
    pace: int = 1
    telemetry: Optional[SessionTelemetry] = None

    def __post_init__(self):
        if self.pace < 1:
            raise ValueError(f'session pace must be >= 1, got {self.pace}')
        if self.telemetry is None:
            self.telemetry = SessionTelemetry(sid=self.sid,
                                              arrival_tick=self.arrival_tick)

    @property
    def done(self) -> bool:
        return self.cursor >= len(self.cams)

    def current_cam(self) -> Camera:
        return self.cams[self.cursor]


class SessionManager:
    """Admit/evict viewers over a fixed set of render slots.

    ``stepper`` is any object with the ``admit(slot)`` / ``step({slot: cam})``
    interface of ``repro.serve.stepper``; the manager owns which sessions sit
    in which slots and feeds their per-frame stats into telemetry.  When the
    stepper exposes ``viewers_per_scene > 1``, slots are grouped into scene
    blocks and sessions are placed by ``scene_id`` (see module docstring).

    All session-placement mutations (``apply_plan``/``observe_tick`` and the
    legacy ``admit_ready``/``evict_finished``) hold ``self._lock``;
    ``snapshot()`` reads under the same lock, so concurrent observers (the
    threaded driver's telemetry consumers, tests) always see a consistent
    admission state.
    """

    #: dispatch retry policy for injected/transient device failures
    max_retries = 3
    backoff_s = 0.002
    #: default bound on the threaded driver's completion-queue wait (s)
    default_watchdog_s = 30.0

    def __init__(self, stepper, slots: int, tracer=None,
                 metrics: Optional[obs_metrics.Registry] = None,
                 injector=None, watchdog_s: Optional[float] = None,
                 max_pending: Optional[int] = None,
                 oversubscribe: bool = False):
        self.stepper = stepper
        self.slots = slots
        # Observability (repro.obs): a span tracer (NULL no-op by default)
        # and a typed metrics registry, shared with the stepper so sort
        # scheduling / kernel-stage events land in the same trace.
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        self.metrics = metrics if metrics is not None else \
            obs_metrics.Registry()
        stepper.tracer = self.tracer
        stepper.metrics = self.metrics
        # Fault layer (repro.serve.faults): a NULL injector by default —
        # the same seam pattern as the NULL tracer, so the unfaulted hot
        # path is untouched and every conformance test exercises the fault
        # layer disabled.  ``watchdog_s`` bounds the threaded driver's
        # completion wait (``default_watchdog_s`` when unset) and, when set
        # explicitly (or when faults are injected), arms a per-tick finish
        # watchdog timer around ``step_finish``.
        self.injector = injector if injector is not None else \
            serve_faults.NULL
        self.watchdog_s = watchdog_s
        self.max_pending = max_pending
        self.shed: list[ViewerSession] = []
        # crash-consistent checkpointing (wired via enable_checkpoints)
        self._ckpt = None
        self._ckpt_every = 0
        self._ckpt_extra: Optional[dict] = None
        self.viewers_per_scene = getattr(stepper, 'viewers_per_scene', 1)
        self.num_scenes = max(1, slots // self.viewers_per_scene)
        # Slot oversubscription needs the stepper's lane stash AND a shared
        # scene block (a private-mode scene is one pool-of-one per slot —
        # interleaving two viewers through it would thrash the cache the
        # block exists to keep warm).
        self.oversubscribe = bool(
            oversubscribe and hasattr(stepper, 'stash_lane')
            and self.viewers_per_scene > 1)
        if oversubscribe and not self.oversubscribe:
            raise ValueError('oversubscribe requires a shared-scene stepper '
                             '(viewers_per_scene > 1) with a lane stash')
        # stashed co-resident sessions per slot (the lane's occupant stays
        # in slot_session; everyone else parks here + in the stepper stash)
        self._coresidents: dict[int, list[ViewerSession]] = {}
        self.slot_session: list[Optional[ViewerSession]] = [None] * slots
        self.pending: deque[ViewerSession] = deque()
        self.finished: list[ViewerSession] = []
        self.tick = 0
        self._lock = threading.Lock()
        # host planning spent on zero-frame ticks (arrival gaps, paced
        # idle ticks) carries into the next logged entry, so host_ms /
        # host_overlap stay honest for open-loop workloads
        self._carry_host_ms = 0.0
        self._carry_overlap_ms = 0.0
        # Per-tick phase attribution: {'tick', 'frames', 'sorted_slots',
        # 'sort_ms', 'shade_ms', 'latency_ms', 'host_ms', 'overlap_ms',
        # 'kernel_ms'} per rendered tick (empty ticks are skipped; kernel_ms
        # is None except on profiled pallas ticks), plus the stepper's state
        # metrics (cache occupancy, live sort-pool entries, state bytes)
        # when it exposes ``state_metrics()``.
        self.tick_log: list[dict] = []

    # -- lifecycle ---------------------------------------------------------

    def submit(self, session: ViewerSession) -> bool:
        """Queue a session for admission.  Lock-safe against a concurrent
        threaded run: a session submitted mid-run is simply picked up by
        the next tick's plan.

        With ``max_pending`` set, a full backlog load-sheds: the session is
        rejected up front (recorded in ``self.shed`` + the ``serve.shed``
        counter) instead of queueing unboundedly — admission collapse under
        a flash crowd is an explicit, observable decision.  Returns whether
        the session was accepted."""
        with self._lock:
            if self.max_pending is not None \
                    and len(self.pending) >= self.max_pending:
                self.shed.append(session)
                accepted = False
            else:
                self.pending.append(session)
                accepted = True
        if not accepted:
            self.metrics.counter(
                'serve.shed',
                'sessions rejected by the admission backlog bound').inc()
            self.tracer.instant('shed', sid=session.sid,
                                arrival_tick=session.arrival_tick)
            return False
        self.tracer.instant('arrival', sid=session.sid,
                            arrival_tick=session.arrival_tick)
        return True

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slot_session) if s is None]

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slot_session) if s is not None]

    def resident_count(self) -> int:
        """Sessions currently holding serving state: lane occupants plus
        stashed co-residents.  The fleet's load figure — an oversubscribed
        worker is carrying more viewers than its occupied slot count."""
        return (sum(1 for s in self.slot_session if s is not None)
                + sum(len(v) for v in self._coresidents.values()))

    def _scene_block(self, scene_id: int) -> range:
        """Slot range of a session's scene block (scene ids beyond the
        stepper's scene count wrap — the block is a cache domain, not a
        registry of world scenes)."""
        c = scene_id % self.num_scenes
        v = self.viewers_per_scene
        return range(c * v, (c + 1) * v)

    def _admit_into(self, slot: int, sess: ViewerSession) -> None:
        sess.telemetry.admitted_tick = self.tick
        self.slot_session[slot] = sess
        self.stepper.admit(slot)

    def admit_ready(self) -> list[int]:
        """Admit arrived pending sessions into free slots (FIFO; with scene
        blocks, FIFO per admissible session — a session whose block is full
        waits without blocking later sessions bound for other scenes)."""
        with self._lock:
            return self._admit_ready_locked()

    def _admit_ready_locked(self) -> list[int]:
        admitted = []
        if self.viewers_per_scene == 1:
            for slot in self.free_slots():
                if not self.pending or self.pending[0].arrival_tick > self.tick:
                    break
                self._admit_into(slot, self.pending.popleft())
                admitted.append(slot)
            return admitted
        waiting = deque()
        while self.pending:
            sess = self.pending.popleft()
            if sess.arrival_tick > self.tick:
                waiting.append(sess)
                continue
            free = [i for i in self._scene_block(sess.scene_id)
                    if self.slot_session[i] is None]
            if free:
                self._admit_into(free[0], sess)
                admitted.append(free[0])
            else:
                waiting.append(sess)
        self.pending = waiting
        return admitted

    def vacate(self, slot: int) -> ViewerSession:
        """Remove the session occupying ``slot`` WITHOUT marking it finished
        — the fleet's migration seam (the viewer continues on another
        device).  The slot's device state is left as-is; the next admit
        into it cold-starts it."""
        with self._lock:
            sess = self.slot_session[slot]
            if sess is None:
                raise RuntimeError(f'vacate: slot {slot} is empty')
            if self._coresidents.get(slot):
                raise RuntimeError(f'vacate: slot {slot} has stashed '
                                   'co-residents (drain them first)')
            self.slot_session[slot] = None
            self._release_slot(slot)
            return sess

    def place(self, slot: int, sess: ViewerSession,
              payload: Optional[dict] = None,
              admitted_tick: Optional[int] = None) -> None:
        """Direct placement into a free slot, bypassing the FIFO queue —
        the fleet's migration / device-loss recovery seam.  With
        ``payload`` the stepper restores an extracted viewer lane
        (warm scene-carry or cold, per the payload — see
        ``BatchedStepper.extract_viewer``); without one, a plain cold
        admit.  ``admitted_tick`` preserves the original admission tick so
        a paced session keeps its frame cadence across the move (defaults
        to the current tick, matching a fresh admit)."""
        with self._lock:
            occupant = self.slot_session[slot]
            if occupant is not None:
                raise RuntimeError(f'place: slot {slot} occupied by sid '
                                   f'{occupant.sid}')
            sess.telemetry.admitted_tick = (
                self.tick if admitted_tick is None else int(admitted_tick))
            self.slot_session[slot] = sess
            if payload is None:
                self.stepper.admit(slot)
            else:
                self.stepper.restore_viewer(slot, payload)

    def evict_finished(self) -> list[int]:
        with self._lock:
            return self._evict_finished_locked()

    def _release_slot(self, slot: int) -> None:
        """Tell the stepper the slot no longer hosts a viewer, so a dynamic
        pool can stop protecting (and eventually reclaim) its sort entry."""
        release = getattr(self.stepper, 'release', None)
        if release is not None:
            release(slot)

    def _evict_finished_locked(self) -> list[int]:
        evicted = []
        for slot, sess in enumerate(self.slot_session):
            if sess is not None and sess.done:
                co = self._coresidents.get(slot)
                if co:
                    # promote a stashed co-resident instead of freeing the
                    # slot (cursors only advance while active, so stashed
                    # sessions are never done themselves)
                    succ = min(co, key=lambda c: c.telemetry.admitted_tick)
                    co.remove(succ)
                    sess.telemetry.finished_tick = self.tick
                    self.finished.append(sess)
                    self.slot_session[slot] = succ
                    self.stepper.unstash_lane(slot, str(succ.sid))
                    evicted.append(slot)
                    continue
                sess.telemetry.finished_tick = self.tick
                self.finished.append(sess)
                self.slot_session[slot] = None
                self._release_slot(slot)
                evicted.append(slot)
        return evicted

    # -- the host pipeline: plan / apply / observe -------------------------

    def _frame_due(self, sess: ViewerSession, tick: int) -> bool:
        """Does this (already-admitted) session consume a frame on
        ``tick``?  Paced sessions render every ``pace`` ticks counted from
        admission; sessions admitted this very tick don't come through
        here — ``plan_tick`` assigns their first frame directly."""
        return (tick - sess.telemetry.admitted_tick) % sess.pace == 0

    def plan_tick(self, tick: Optional[int] = None,
                  advanced=()) -> TickPlan:
        """Compute the next tick's host decisions without mutating anything.

        ``advanced`` names the slots of an in-flight, not-yet-observed tick:
        their sessions are treated as one frame further along (the threaded
        driver's double-buffer adjustment — eviction/camera choices for tick
        ``t+1`` are a pure function of tick ``t``'s inputs, never its device
        outputs).  With no tick in flight (the sync path) it is empty and
        this reads the literal manager state.

        The returned plan also carries the stepper's pose-cell sort plan
        (``plan_step``) when the stepper has a host planning phase, computed
        against the post-admission active set — the piece of per-tick host
        work the async pipeline exists to overlap.
        """
        tick = self.tick if tick is None else tick
        if self.injector.enabled \
                and self.injector.take('plan_exc', tick) is not None:
            # injected BEFORE any planning work: plan_tick is pure, so the
            # recovery replan (inline, degraded) sees identical inputs
            raise serve_faults.InjectedPlanError(
                f'injected plan_tick fault at tick {tick}')
        with self.tracer.span('plan_tick', tick=tick):
            return self._plan_tick(tick, advanced)

    def _plan_tick(self, tick: int, advanced=()) -> TickPlan:
        adv = frozenset(advanced)

        def cursor_of(slot: int, sess: ViewerSession) -> int:
            # the in-flight frame (if any) belongs to the slot's current
            # lane occupant; stashed co-residents never render in flight,
            # so their cursors read literally
            return sess.cursor + (1 if slot in adv else 0)

        cor_slots = {slot for slot, lst in self._coresidents.items() if lst}
        evict = tuple(
            slot for slot, sess in enumerate(self.slot_session)
            if sess is not None and slot not in cor_slots
            and cursor_of(slot, sess) >= len(sess.cams))
        free = sorted(set(self.free_slots()) | set(evict))
        placements = self._plan_admissions(free, tick)
        admit = tuple((slot, sess.sid) for slot, sess in placements)
        admitted_slots = {slot for slot, _ in admit}

        # Oversubscribed lanes: at most one resident (occupant or stashed
        # co-resident) is due per tick — the admission-time residue check
        # guarantees it.  A due co-resident swaps in; a finished occupant
        # retires into the swap (its lane needs no stashing).
        cams: dict[int, Camera] = {}
        switches = []
        for slot in sorted(cor_slots):
            sess = self.slot_session[slot]
            occupant_done = cursor_of(slot, sess) >= len(sess.cams)
            due_co = [c for c in self._coresidents[slot] if not c.done
                      and (tick - c.telemetry.admitted_tick) % c.pace == 0]
            if due_co:
                inc = due_co[0]
                switches.append((slot, inc.sid))
                cams[slot] = inc.cams[inc.cursor]
            elif occupant_done:
                inc = min(self._coresidents[slot],
                          key=lambda c: c.telemetry.admitted_tick)
                switches.append((slot, inc.sid))
            elif self._frame_due(sess, tick):
                cams[slot] = sess.cams[cursor_of(slot, sess)]

        for slot, sess in enumerate(self.slot_session):
            if sess is None or slot in evict or slot in admitted_slots \
                    or slot in cor_slots:
                continue
            if self._frame_due(sess, tick):
                cams[slot] = sess.cams[cursor_of(slot, sess)]
        for slot, sess in placements:
            cams[slot] = sess.cams[0]

        sort_plan = None
        plan_step = getattr(self.stepper, 'plan_step', None)
        if plan_step is not None:
            if switches:
                sort_plan = plan_step(
                    cams, pending_admits=admitted_slots,
                    lane_swaps={slot: str(sid) for slot, sid in switches})
            else:
                sort_plan = plan_step(cams, pending_admits=admitted_slots)
        return TickPlan(tick=tick, evict=evict, admit=admit, cams=cams,
                        sort_plan=sort_plan, switches=tuple(switches))

    def _plan_admissions(self, free: list, tick: int) -> list:
        """Pure mirror of ``admit_ready`` over a hypothetical free-slot list:
        returns ``(slot, session)`` placements in pending-queue order
        without popping anything.  The pending snapshot is taken under the
        lock (this runs on the planner worker; ``submit`` may race), and in
        FIFO mode only the first ``len(free)`` entries are materialized —
        a deep open-loop backlog must not cost O(queue) host work per tick.
        """
        with self._lock:
            if self.viewers_per_scene == 1:
                pending = list(itertools.islice(self.pending, len(free)))
            else:
                pending = list(self.pending)
        placements = []
        if self.viewers_per_scene == 1:
            k = 0
            for slot in free:
                if k >= len(pending) or pending[k].arrival_tick > tick:
                    break
                placements.append((slot, pending[k]))
                k += 1
            return placements
        remaining = set(free)
        co_placed: set[int] = set()
        for sess in pending:
            if sess.arrival_tick > tick:
                continue
            block = [i for i in self._scene_block(sess.scene_id)
                     if i in remaining]
            if block:
                placements.append((block[0], sess))
                remaining.discard(block[0])
                continue
            if not self.oversubscribe or sess.pace < 2:
                continue
            # Block full: co-place onto an occupied slot whose residents'
            # render ticks are residue-disjoint from the newcomer's.  The
            # newcomer renders on ticks ≡ tick (mod pace); resident r on
            # ticks ≡ admitted_r (mod pace_r) — they never collide iff
            # tick ≢ admitted_r (mod gcd(pace_r, pace)), and that residue
            # relation is permanent, so one admission-time check covers
            # the whole co-residency.  One co-placement per slot per tick
            # (two same-tick admits would share a residue by definition).
            for slot in self._scene_block(sess.scene_id):
                occ = self.slot_session[slot]
                if occ is None or slot in co_placed or slot in remaining:
                    continue
                residents = [occ] + self._coresidents.get(slot, [])
                if any(r.pace < 2 for r in residents):
                    continue
                if all((tick - r.telemetry.admitted_tick)
                       % math.gcd(r.pace, sess.pace) != 0
                       for r in residents):
                    placements.append((slot, sess))
                    co_placed.add(slot)
                    break
        return placements

    def apply_plan(self, plan: TickPlan) -> None:
        """Atomically commit a plan's evictions and admissions.  Holding the
        lock across the whole commit is the no-partial-admission guarantee:
        a session is either fully pending or fully admitted (placed, stepper
        slot reset, ``admitted_tick`` stamped) in any concurrent view."""
        with self.tracer.span('apply_plan', tick=plan.tick,
                              admits=len(plan.admit),
                              evicts=len(plan.evict)), self._lock:
            if plan.tick != self.tick:
                raise RuntimeError(f'stale plan: tick {plan.tick} applied at '
                                   f'manager tick {self.tick}')
            retired = 0
            for slot in plan.evict:
                sess = self.slot_session[slot]
                if sess is None or not sess.done:
                    raise RuntimeError(f'plan evicts slot {slot} whose '
                                       f'session is not finished')
                sess.telemetry.finished_tick = plan.tick
                self.finished.append(sess)
                self.slot_session[slot] = None
                self._release_slot(slot)
                self.tracer.instant('evict', slot=slot, sid=sess.sid,
                                    tick=plan.tick)
            for slot, sid in getattr(plan, 'switches', ()):
                sess = self.slot_session[slot]
                co = self._coresidents.get(slot, [])
                inc = next((c for c in co if c.sid == sid), None)
                if inc is None:
                    raise RuntimeError(f'planned switch-in {sid} is not a '
                                       f'co-resident of slot {slot}')
                co.remove(inc)
                if sess.done:
                    # the outgoing occupant retires through the swap — its
                    # lane state needs no stashing
                    sess.telemetry.finished_tick = plan.tick
                    self.finished.append(sess)
                    retired += 1
                    self.tracer.instant('evict', slot=slot, sid=sess.sid,
                                        tick=plan.tick)
                else:
                    self.stepper.stash_lane(slot, str(sess.sid))
                    co.append(sess)
                self.slot_session[slot] = inc
                self.stepper.unstash_lane(slot, str(inc.sid))
                self.tracer.instant('switch', slot=slot, sid=inc.sid,
                                    tick=plan.tick)
            self.metrics.counter(
                'serve.evicted', 'sessions leaving their slot').inc(
                    len(plan.evict) + retired)
            for slot, sid in plan.admit:
                occupant = self.slot_session[slot]
                sess = next((s for s in self.pending if s.sid == sid), None)
                if sess is None:
                    raise RuntimeError(f'planned session {sid} not pending')
                if occupant is not None:
                    if not self.oversubscribe:
                        raise RuntimeError(f'plan admits into occupied slot '
                                           f'{slot}')
                    # co-placement: park the lane's occupant, cold-start the
                    # newcomer into the lane (the scene cache persists — the
                    # sharing the block exists for)
                    self.stepper.stash_lane(slot, str(occupant.sid))
                    self._coresidents.setdefault(slot, []).append(occupant)
                    self.metrics.counter(
                        'serve.oversubscribed',
                        'sessions co-placed onto an occupied slot').inc()
                self.pending.remove(sess)
                self._admit_into(slot, sess)
                self.tracer.instant('admit', slot=slot, sid=sid,
                                    tick=plan.tick)
            self.metrics.counter(
                'serve.admitted', 'sessions placed into a slot').inc(
                    len(plan.admit))
            self.metrics.gauge(
                'serve.queue_depth', 'pending sessions after admission').set(
                    len(self.pending))

    def observe_tick(self, plan: TickPlan, outputs: dict,
                     host: Optional[HostTiming] = None) -> int:
        """Record a completed tick: per-frame telemetry, cursor advance, the
        tick log entry (mirrored into the metrics registry's ``tick.*``
        series), and the clock advance to ``plan.tick + 1``."""
        with self.tracer.span('observe_tick', tick=plan.tick,
                              frames=len(outputs)), self._lock:
            for slot, (_image, stats, timing) in outputs.items():
                sess = self.slot_session[slot]
                hit_rate = float(stats.hit_rate)
                saved_frac = float(stats.saved_frac)
                sess.telemetry.observe_frame(
                    latency_s=timing.latency_s,
                    hit_rate=hit_rate,
                    saved_frac=saved_frac,
                    sorted_flag=float(stats.sorted_this_frame),
                    sort_ms=timing.sort_ms,
                    shade_ms=timing.shade_ms)
                sess.cursor += 1
                self.metrics.histogram(
                    'cache.hit_rate', 'per-frame RC hit rate',
                    scene=sess.scene_id).observe(hit_rate)
                self.metrics.histogram(
                    'rc.saved_frac', 'integration skipped via RC',
                    scene=sess.scene_id).observe(saved_frac)
            # paced-idle accounting: resident sessions that rendered nothing
            # this tick (pace gaps; a done session awaiting eviction also
            # counts — its slot is held either way).  Stashed co-residents
            # are idle residents too: oversubscription converts their idle
            # slot-ticks into another viewer's frames, and this counter is
            # the denominator that shows it.
            idle = (sum(1 for s in self.slot_session if s is not None)
                    + sum(len(v) for v in self._coresidents.values())
                    - len(outputs))
            if idle > 0:
                self.metrics.counter(
                    'serve.paced_idle',
                    'occupied slot-ticks that rendered no frame').inc(idle)
                self.tracer.instant('pace', tick=plan.tick, idle_slots=idle)
            self.metrics.counter('serve.frames',
                                 'frames rendered').inc(len(outputs))
            if outputs:
                tick_timing = self.stepper.last_timing
                entry = {
                    'tick': plan.tick,
                    'frames': len(outputs),
                    'sorted_slots': tick_timing.sorted_slots,
                    'sort_ms': tick_timing.sort_ms,
                    'shade_ms': tick_timing.shade_ms,
                    'latency_ms': tick_timing.latency_s * 1e3,
                    'host_ms': self._carry_host_ms
                               + (host.host_ms if host else 0.0),
                    'overlap_ms': self._carry_overlap_ms
                                  + (host.overlap_ms if host else 0.0),
                    'kernel_ms': getattr(tick_timing, 'kernel_ms', None),
                }
                self._carry_host_ms = self._carry_overlap_ms = 0.0
                metrics = getattr(self.stepper, 'state_metrics', None)
                if metrics is not None:
                    entry.update(metrics())
                self.tick_log.append(entry)
                obs_metrics.publish_tick(self.metrics, entry)
                self.metrics.histogram(
                    'serve.tick_latency_ms',
                    'wall latency of rendered ticks').observe(
                        entry['latency_ms'])
            elif host is not None:
                self._carry_host_ms += host.host_ms
                self._carry_overlap_ms += host.overlap_ms
            self.tick = plan.tick + 1
            return len(outputs)

    def snapshot(self) -> dict:
        """A consistent view of session placement for concurrent observers:
        pending sids, ``(slot, sid, admitted_tick)`` for occupied slots,
        finished sids, and the tick — all read under the manager lock."""
        with self._lock:
            return {
                'tick': self.tick,
                'pending': tuple(s.sid for s in self.pending),
                'slotted': tuple(
                    (slot, s.sid, s.telemetry.admitted_tick)
                    for slot, s in enumerate(self.slot_session)
                    if s is not None),
                'finished': tuple(s.sid for s in self.finished),
            }

    # -- fault handling (shared by both drivers) ---------------------------
    #
    # Each helper reduces exactly to the pre-hardening path under the NULL
    # injector: one attribute test, no wrapping, no extra work — so the
    # unfaulted golden traces stay bit-identical with the fault layer
    # present but disabled.

    def count_fault(self, kind: str, tick: int) -> None:
        """One observed fault event (injected or real-but-contained)."""
        self.metrics.counter('serve.faults',
                             'fault events observed by the host loop',
                             kind=kind).inc()
        self.tracer.instant('fault', kind=kind, tick=tick)

    def count_degraded(self, tick: int) -> None:
        """One tick the host loop fell back from its pipelined fast path
        (inline replan, shed dispatch, worker restart)."""
        self.metrics.counter(
            'serve.degraded_ticks',
            'ticks served in degraded (inline/shed) mode').inc()
        self.tracer.instant('degraded', tick=tick)

    def plan_tick_hardened(self, tick: Optional[int] = None,
                           advanced=()) -> TickPlan:
        """``plan_tick`` surviving an injected planner exception: the fault
        fires before any planning work and planning is pure, so the inline
        retry sees identical inputs (the sync-driver arm of the recovery
        the threaded driver gets from its worker-error fallback)."""
        try:
            return self.plan_tick(tick, advanced)
        except serve_faults.InjectedPlanError:
            t = self.tick if tick is None else tick
            self.count_fault('plan_exc', t)
            self.count_degraded(t)
            return self.plan_tick(tick, advanced)

    def poison_outputs(self, outputs: dict, tick: int) -> dict:
        """Apply a pending ``nan_poison`` event: one slot's finished shade
        output is replaced with NaNs — the corrupted-device-result scenario
        (a NaN camera demonstrably does NOT reproduce it: non-finite pose
        comparisons all fail, nothing rasterizes, and the image comes back
        finite background).  Injection happens here, *detection* is
        ``contain_outputs``'s independent finite scan — the containment
        path never peeks at the injector's choice.  The scene cache is
        threatened separately: ``insert_all_groups`` carries the
        ``jnp.isfinite`` gate that keeps non-finite rgb out of
        ``SceneShared`` no matter how the corruption arose.  With no output
        this tick the event stays armed.  Returns the (possibly
        substituted) outputs dict."""
        inj = self.injector
        if not inj.enabled or not outputs \
                or not inj.peek('nan_poison', tick):
            return outputs
        ev = inj.take('nan_poison', tick)
        slot = inj.poison_slot(ev, sorted(outputs))
        self.count_fault('nan_poison', tick)
        self.tracer.instant('poison', slot=slot, tick=tick)
        img, stats, timing = outputs[slot]
        outputs = dict(outputs)
        outputs[slot] = (jnp.full_like(img, jnp.nan), stats, timing)
        return outputs

    def dispatch_hardened(self, dispatch, cams: dict, plan: TickPlan):
        """Dispatch with retry-with-backoff.  Injected dispatch faults fire
        *before* the real dispatch mutates any host state or donates any
        buffer, so re-attempting is trivially safe.  A transient event
        costs ``count`` backed-off retries and then succeeds; a persistent
        event exhausts the retry budget and **sheds the tick** — returns
        ``(None, False)``, no cursor advances, and every due frame is
        replanned next tick (by which time the one-shot event is consumed).
        """
        inj = self.injector
        if not inj.enabled:
            return dispatch(cams, plan=plan.sort_plan), True
        retries = self.metrics.counter('serve.retries',
                                       'dispatch retry attempts')
        ev = inj.take('dispatch_persistent', plan.tick)
        if ev is not None:
            self.count_fault('dispatch_persistent', plan.tick)
            with self.tracer.span('dispatch_retry', tick=plan.tick,
                                  outcome='shed'):
                for attempt in range(self.max_retries):
                    retries.inc()
                    time.sleep(self.backoff_s * (2 ** attempt))
            self.count_degraded(plan.tick)
            self.tracer.instant('tick_shed', tick=plan.tick,
                                frames=len(cams))
            return None, False
        ev = inj.take('dispatch_transient', plan.tick)
        if ev is not None:
            self.count_fault('dispatch_transient', plan.tick)
            with self.tracer.span('dispatch_retry', tick=plan.tick,
                                  outcome='recovered', failures=ev.count):
                for attempt in range(min(ev.count, self.max_retries)):
                    retries.inc()
                    time.sleep(self.backoff_s * (2 ** attempt))
        return dispatch(cams, plan=plan.sort_plan), True

    def finish_hardened(self, finish, inflight, tick: int) -> dict:
        """``step_finish`` under a stall watchdog.  An injected ``stall``
        delays completion inside the watchdog window; a deadline expiry
        (armed when ``watchdog_s`` is set explicitly or faults are being
        injected — never on the plain hot path) emits a ``RuntimeWarning``
        + ``serve.watchdog`` counter but keeps waiting: surfacing a hung
        device is the watchdog's job, abandoning in-flight donated buffers
        would corrupt state."""
        inj = self.injector
        deadline = self.watchdog_s
        if deadline is None and inj.enabled:
            deadline = self.default_watchdog_s
        timer = None
        if deadline is not None:
            def expired():
                self.metrics.counter(
                    'serve.watchdog',
                    'finish/plan watchdog deadline expiries').inc()
                self.tracer.instant('watchdog', what='step_finish',
                                    tick=tick)
                warnings.warn(
                    f'serve watchdog: step_finish exceeded {deadline}s at '
                    f'tick {tick} (device stalled?)', RuntimeWarning,
                    stacklevel=2)
            timer = threading.Timer(deadline, expired)
            timer.daemon = True
            timer.start()
        try:
            ev = inj.take('stall', tick) if inj.enabled else None
            if ev is not None:
                self.count_fault('stall', tick)
                with self.tracer.span('device_stall', tick=tick,
                                      delay_s=ev.delay_s):
                    time.sleep(ev.delay_s)
            return finish(inflight)
        finally:
            if timer is not None:
                timer.cancel()

    def contain_outputs(self, outputs: dict, tick: int) -> tuple:
        """Per-viewer blast-radius containment: any output whose image is
        non-finite is dropped (never reaches telemetry or the viewer — its
        cursor does not advance, the frame retries after recovery) and its
        slot is quarantined (``stepper.quarantine``: private state reset,
        owned pool entry invalidated; the ``jnp.isfinite`` insert gate
        already kept its values out of the scene cache).  Returns
        ``(clean_outputs, poisoned_slots)``.  Only scans when faults are
        being injected — the host must not sync-and-scan every healthy
        frame."""
        if not self.injector.enabled or not outputs:
            return outputs, ()
        poisoned = tuple(
            slot for slot, (img, _stats, _timing) in outputs.items()
            if not bool(np.isfinite(np.asarray(img)).all()))
        if not poisoned:
            return outputs, ()
        quarantine = getattr(self.stepper, 'quarantine', self.stepper.admit)
        for slot in poisoned:
            self.tracer.instant('quarantine', slot=slot, tick=tick)
            quarantine(slot)
        self.metrics.counter(
            'serve.quarantined',
            'poisoned frames dropped and their slots reset').inc(
                len(poisoned))
        clean = {s: o for s, o in outputs.items() if s not in poisoned}
        return clean, poisoned

    def step_hardened(self, plan: TickPlan) -> tuple:
        """The full hardened device leg of one tick (dispatch with retry ->
        finish under watchdog -> poison -> containment), shared by the
        sync driver's ``run_tick`` and usable standalone.  Returns
        ``(outputs, poisoned_slots)``."""
        dispatch, finish = _step_split(self.stepper)
        inflight, ok = self.dispatch_hardened(dispatch, plan.cams, plan)
        if not ok:
            return {}, ()
        outputs = self.finish_hardened(finish, inflight, plan.tick)
        outputs = self.poison_outputs(outputs, plan.tick)
        return self.contain_outputs(outputs, plan.tick)

    # -- crash-consistent checkpoint/restore -------------------------------

    def enable_checkpoints(self, manager, every: int,
                           extra: Optional[dict] = None) -> None:
        """Snapshot serving state through a ``repro.checkpoint``
        ``CheckpointManager`` every ``every`` ticks (``maybe_checkpoint`` is
        called by both drivers at each tick boundary).  ``extra`` is
        JSON-able context stored alongside (e.g. the traffic trace), so a
        snapshot is self-describing for the multi-device migration path."""
        self._ckpt = manager
        self._ckpt_every = int(every)
        self._ckpt_extra = extra

    def maybe_checkpoint(self) -> bool:
        if self._ckpt is None or self._ckpt_every <= 0:
            return False
        if self.tick == 0 or self.tick % self._ckpt_every:
            return False
        self.checkpoint_now()
        return True

    def checkpoint_now(self, blocking: bool = False) -> None:
        """Snapshot at the current tick boundary.  Must run with no tick in
        flight: the stepper's buffers are donated into the next dispatch,
        and ``CheckpointManager.save`` device_gets them synchronously before
        returning — after that the background serialization races nothing.
        (The threaded driver's concurrent ``plan_tick`` only *reads* host
        state, so planning t+1 may overlap the snapshot safely.)"""
        with self.tracer.span('checkpoint', tick=self.tick):
            arrays, stepper_meta = self.stepper.state_dict()
            with self._lock:
                meta = {
                    'tick': self.tick,
                    'stepper': stepper_meta,
                    'slots': [
                        None if s is None else {
                            'sid': s.sid, 'cursor': s.cursor,
                            'admitted_tick': s.telemetry.admitted_tick}
                        for s in self.slot_session],
                    'coresidents': {
                        str(slot): [{'sid': c.sid, 'cursor': c.cursor,
                                     'admitted_tick':
                                         c.telemetry.admitted_tick}
                                    for c in lst]
                        for slot, lst in self._coresidents.items() if lst},
                    'pending': [s.sid for s in self.pending],
                    'finished': [s.sid for s in self.finished],
                    'shed': [s.sid for s in self.shed],
                }
            if self._ckpt_extra:
                meta['extra'] = self._ckpt_extra
            self._ckpt.save(arrays, step=self.tick, extra=meta,
                            blocking=blocking)

    def restore_serving(self, ckpt, sessions,
                        max_step: Optional[int] = None) -> Optional[int]:
        """Restore the newest complete checkpoint into this manager.

        ``sessions`` must be the same session list (sids + trajectories)
        the checkpointed run was built from — the snapshot stores cursors
        and placement, not camera data.  Stepper state, host scheduler
        mirrors, per-slot placement, pending order and the manager tick all
        restore; a subsequent run continues bit-identically to the
        uninterrupted one (the kill-and-restore oracle in
        ``tests/test_chaos.py``).  Returns the restored tick, or None when
        no usable checkpoint exists (caller falls back to a fresh run).

        The shape template is built per checkpoint step: a snapshot's pool
        capacity (and stash population) is part of its geometry, so the
        manifest's ``extra`` is peeked first and handed to the stepper's
        ``state_template`` — a freshly constructed stepper's own
        ``state_dict`` only matches snapshots taken at its initial
        capacity.

        ``max_step`` caps the restore at a given checkpoint step — the
        fleet restores every worker to its newest *common* step so a kill
        landing mid-save on one device cannot leave the workers on
        different ticks."""
        out = self._restore_arrays(ckpt, max_step=max_step)
        if out is None:
            return None
        arrays, step, meta = out
        self.stepper.load_state(arrays, meta['stepper'])
        by_sid = {s.sid: s for s in sessions}
        with self._lock:
            self.tick = int(meta['tick'])
            self.slot_session = []
            for m in meta['slots']:
                if m is None:
                    self.slot_session.append(None)
                    continue
                sess = by_sid.pop(m['sid'])
                sess.cursor = int(m['cursor'])
                sess.telemetry.admitted_tick = int(m['admitted_tick'])
                self.slot_session.append(sess)
            self._coresidents = {}
            for slot_s, lst in meta.get('coresidents', {}).items():
                co = []
                for m in lst:
                    sess = by_sid.pop(m['sid'])
                    sess.cursor = int(m['cursor'])
                    sess.telemetry.admitted_tick = int(m['admitted_tick'])
                    co.append(sess)
                self._coresidents[int(slot_s)] = co
            self.finished = []
            for sid in meta['finished']:
                sess = by_sid.pop(sid)
                sess.cursor = len(sess.cams)
                self.finished.append(sess)
            self.shed = [by_sid.pop(sid) for sid in meta.get('shed', ())]
            self.pending = deque(by_sid.pop(sid)
                                 for sid in meta['pending'])
        self.tracer.instant('restore', tick=self.tick, step=step)
        self.metrics.counter('serve.restores',
                             'runs resumed from a checkpoint').inc()
        return int(step)

    def _restore_arrays(self, ckpt, max_step=None) -> Optional[tuple]:
        """Newest loadable checkpoint as ``(arrays, step, meta)``, building
        the shape template per step from the manifest's stepper geometry.
        ``max_step`` skips snapshots newer than the given step (fleet
        common-step restore).  Falls back to the plain ``restore_latest``
        protocol for steppers without ``state_template`` (or checkpoint
        stores without manifest peeking), and one step back on any
        unreadable snapshot — the same fallback ladder
        ``CheckpointManager.restore_latest`` walks."""
        state_template = getattr(self.stepper, 'state_template', None)
        manifest_extra = getattr(ckpt, 'manifest_extra', None)
        if state_template is None or manifest_extra is None:
            if max_step is not None:
                raise ValueError('max_step needs the manifest-template '
                                 'restore path')
            template, _ = self.stepper.state_dict()
            return ckpt.restore_latest(template)
        from repro.checkpoint.manager import load_checkpoint
        ckpt.wait()
        steps = [s for s in ckpt.all_steps()
                 if max_step is None or s <= max_step]
        for step in reversed(steps):
            try:
                extra = manifest_extra(step)
                if extra is None:
                    raise ValueError('manifest unreadable')
                template = state_template(extra.get('stepper', {}))
                arrays, meta = load_checkpoint(ckpt.dir, template, step=step)
                return arrays, step, meta
            except Exception as e:   # corrupt / partial: fall back one step
                ckpt.metrics.counter(
                    'ckpt.restore_fallback',
                    'checkpoints skipped as unreadable at restore').inc()
                warnings.warn(f'checkpoint step {step} unreadable ({e}); '
                              'falling back to previous',
                              RuntimeWarning, stacklevel=2)
        return None

    # -- the serving loop --------------------------------------------------

    def run_tick(self) -> int:
        """One scheduler tick: evict, admit, render every due slot one frame
        (plan -> apply -> step -> observe, inline).

        Returns the number of frames rendered this tick.

        The device leg runs through the hardened helpers (poison/retry/
        watchdog/containment) — each a no-op reducing to the pre-hardening
        ``stepper.step`` composition under the NULL injector.
        """
        with self.tracer.span('tick', tick=self.tick):
            t0 = time.perf_counter()
            plan = self.plan_tick_hardened()
            host = HostTiming(host_ms=(time.perf_counter() - t0) * 1e3)
            self.apply_plan(plan)
            outputs, _poisoned = self.step_hardened(plan)
            return self.observe_tick(plan, outputs, host=host)

    def drained(self) -> bool:
        return not self.pending and not self.active_slots()

    def run(self, max_ticks: int = 100_000,
            driver: str = 'sync') -> list[ViewerSession]:
        """Drive ticks until every submitted session has completed.

        ``driver='sync'`` is the virtual-clock host loop (deterministic,
        bit-identical replay); ``driver='threaded'`` double-buffers host
        planning against the device step (``repro.serve.events``).
        """
        return get_driver(driver, self).run(max_ticks)
