"""Slot steppers: how a set of viewer slots advances one frame.

Two interchangeable engines behind one interface:

* ``BatchedStepper``    — the serving fast path over **scene-centric**
  state: slots are partitioned into scenes (``viewers_per_scene`` slots per
  scene, a static block layout), each scene holding ONE shared radiance
  cache and a pose-cell-keyed pool of speculative-sort entries
  (``SceneShared``), while per-slot state shrinks to a ``ViewerPrivate``.
  A **pose-cell sort scheduler** generalizes the PR-2 cohort scheduler:
  slot ``i`` comes due when ``global_tick % window == i % window`` (plus
  sort-on-admit outside the tick), due slots are grouped by (scene,
  pose cell), and each group elects one **leader** (lowest slot) to run the
  speculative sort — co-located viewers share one ``SortShared`` buffer, so
  the pool holds O(distinct cells) live entries instead of one per slot.
  A due slot whose cell already has a *fresh* entry (sorted within the
  window, by a still-active owner still in that cell) adopts it without
  sorting at all.  Each tick then advances all live slots through one
  ``batched_shade_phase``, whose cache stages run scene-major: every
  viewer of a scene probes and fills the scene's single cache, conflicts
  resolving in deterministic (slot, pixel) order.
* ``SequentialStepper`` — each active slot advances through its own
  single-viewer jitted ``render_step`` (the reference/baseline the benchmark
  compares against; per-viewer sort cadence, exact ``LuminSys`` semantics,
  fully private state).

With ``viewers_per_scene == 1`` (the default) every slot is its own scene:
private cache, singleton pose-cell groups, the exact PR-2 cohort cadence —
single-viewer behavior is bit-identical to the pre-split engine, preserved
by the parity oracles in ``tests/test_serve.py``.

Cadence caveats: the scheduler shifts *when* each slot sorts relative to an
independent per-viewer run (every frame still renders from a sort no older
than ``window`` frames in private mode; a shared entry adopted from another
viewer's leader can be up to ``2*window - 1`` ticks old across an ownership
handoff).  For a single viewer in slot 0 admitted at tick 0 the cadences
coincide and the engines agree on every integer cache decision.

Both engines **donate** their state buffers into the jitted calls (the
previous tick's state is dead the instant the step returns), so XLA updates
the O(S*N) state in place instead of round-tripping a copy every tick.

**Idle-lane compaction**: when whole scenes are idle, the batched engine
gathers the active scene blocks into a dense prefix (padded to a
power-of-two bucket so at most log2(C) shade widths ever compile), shades
only that sub-batch, and scatters results back — idle scenes are not shaded
at all and their state is left untouched.  Idle slots *within* an active
scene ride the shade with ``active=False``: they contribute nothing, touch
no LRU state and insert nothing into the shared cache.  With one slot per
scene this reduces exactly to the PR-3 per-slot compaction.

**Per-kernel latency attribution**: with ``profile_every=N`` (and the
``pallas`` backend), every Nth tick re-runs the shade decomposed into its
kernel stages — prep (S^2 feature refresh), prefix (RC phase A), lookup
(scene-major LuminCache probe), resume (miss-compacted phase B), insert —
on a copy of the pre-shade state, timing each stage with a device sync.
The breakdown lands in ``TickTiming.kernel_ms`` / ``SessionManager.
tick_log`` and is rolled up by ``telemetry.tick_rollup``.

Interface::

    stepper.admit(slot)                  # reset a slot to cold-start state
    out = stepper.step({slot: cam, ..})  # advance the given slots one frame
    # out: {slot: (image, FrameStats, TickTiming)}
    stepper.sort_log                     # per-step {'scheduled','admit',
                                         #           'joined'} counts
    stepper.last_timing                  # tick-level TickTiming of the last
                                         # non-empty step
    stepper.state_metrics()              # occupancy + state-memory bytes

Async host-loop seam (``repro.serve.events``)::

    plan = stepper.plan_step(cams)       # pure host planning (worker-safe)
    infl = stepper.step_dispatch(cams, plan)  # host mutations + async
                                              # device dispatch
    out = stepper.step_finish(infl)      # block on the device, assemble
    # step(cams, plan) == step_finish(step_dispatch(cams, plan))
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posecell
from repro.core import radiance_cache as rc
from repro.core.buckets import pow2_bucket
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.core.camera import Camera, stack_cameras
from repro.core.gaussians import GaussianScene
from repro.core.groups import regroup, ungroup
from repro.core.pipeline import (LuminaConfig, SceneShared, ViewerPrivate,
                                 ViewerState, batched_prep_features,
                                 batched_shade_phase, batched_sort_phase,
                                 copy_pytree, init_fleet, init_scene_shared,
                                 init_viewer_private, init_viewer_state,
                                 pytree_nbytes, render_step,
                                 trim_features_slots)
from repro.core.tiling import tile_grid


class TickTiming(NamedTuple):
    """Per-phase latency attribution for the tick a frame rode in."""

    latency_s: float     # wall-clock of the whole tick (sort + shade)
    sort_ms: float       # wall-clock of the tick's sort-phase calls
    shade_ms: float      # wall-clock of the tick's shade-phase call
    sorted_slots: int    # speculative sorts executed this tick (incl. admits)
    kernel_ms: Optional[dict] = None  # per-kernel shade breakdown (profiled
                                      # ticks on the pallas backend)


class _SortGroup(NamedTuple):
    """One due (scene, cell) group resolved by the pose-cell scheduler."""

    scene: int
    cell: int
    leader: int          # lowest due slot; runs the sort if one is needed
    members: tuple       # all due slots adopting the entry
    riders: tuple        # non-due co-located slots consolidated onto it
    entry: int           # pool index the group lands in
    sorts: bool          # False = adopted a fresh entry, no sort executed


class _StepPlan(NamedTuple):
    """Precomputed host scheduling for one ``step(cams)`` call (see
    ``BatchedStepper.plan_step``): the pure planning output the async host
    loop computes off-thread while the device executes the previous tick."""

    active: frozenset    # slots rendering this step (stalled slots removed)
    admits: tuple        # slots sorting on admit (outside the cohort)
    due: tuple           # all slots consuming a sort refresh this step
    groups: tuple        # _SortGroup plan from the pose-cell scheduler
    stream: object = None  # StreamPlan when scene residency is streamed


class _InFlight(NamedTuple):
    """A dispatched-but-unfinished batched step: everything ``step_finish``
    needs to block, attribute timing and assemble per-slot outputs."""

    cams: dict           # the step's {slot: cam} request
    images: object       # dispatched (not yet synced) device arrays
    stats: object
    pos: dict            # slot -> lane in images/stats
    t0: float            # perf_counter at step start
    t1: float            # perf_counter at shade dispatch
    sort_s: float        # host+device seconds of the sort phase
    n_sched: int
    n_admit: int
    profile: object      # (prof_shared, prof_priv, cam_b, mask) or None
    tick: int = 0        # global_tick the step ran at (trace span args)


class BatchedStepper:
    """All live slots advance in one scene-major ``batched_shade_phase``
    call per tick (gathered to a dense scene prefix when some scenes are
    idle); speculative sorts run once per due (scene, pose-cell) group."""

    def __init__(self, scene: GaussianScene, cfg: LuminaConfig,
                 cam0: Camera, slots: int, profile_every: int = 0,
                 viewers_per_scene: int = 1, pool_size: int | None = None,
                 cell_size: float = posecell.CELL_SIZE,
                 cell_ang_bins: int = posecell.ANG_BINS,
                 streaming=None):
        if slots % viewers_per_scene:
            raise ValueError(f'slots ({slots}) must be a multiple of '
                             f'viewers_per_scene ({viewers_per_scene})')
        # Streaming residency (repro.serve.streaming.ResidencyManager): the
        # effective scene is the manager's masked arena view — same shape
        # every tick, so a residency change swaps ``self.scene`` without
        # recompiling anything (the scene is an argument to every jitted
        # call, never a closure capture).
        self._streaming = streaming
        if streaming is not None:
            if streaming.grace_ticks is None:
                # eviction grace must outlive any stale sorted tile list:
                # one full sort window plus dispatch slack
                streaming.grace_ticks = (max(1, cfg.window)
                                         if cfg.use_s2 else 1) + 2
            scene = streaming.scene()
        self.scene = scene
        self.cfg = cfg
        self.slots = slots
        self.viewers_per_scene = viewers_per_scene
        self.num_scenes = slots // viewers_per_scene
        self.pool_size = (viewers_per_scene if pool_size is None
                          else pool_size)
        # Dropless allocation: in shared mode the pool no longer reserves
        # the every-viewer-its-own-cell worst case (``pool_size`` entries
        # per scene) up front.  Capacity starts at one entry and
        # grows/shrinks with the live pose-cell count in power-of-two
        # buckets (``_resize_pool``), the same capacity-bucket routing a
        # dropless-MoE router applies to token -> expert dispatch.  An
        # explicit ``pool_size`` pins the static worst-case layout (the
        # baseline the benchmark compares against); private mode (one
        # viewer per scene) is already a pool-of-one.
        self.dynamic_pool = pool_size is None and viewers_per_scene > 1
        self.pool_cap = 1 if self.dynamic_pool else self.pool_size
        self.cell_size = cell_size
        self.cell_ang_bins = cell_ang_bins
        self.window = max(1, cfg.window) if cfg.use_s2 else 1
        # Fixed sort-call width: at most ceil(S/window) groups are due per
        # scheduled tick, so the gather/sort/scatter call jits once for the
        # worst-case cohort (admit bursts are chunked to the same width).
        self.cohort = -(-slots // self.window)
        self.global_tick = 0
        self.profile_every = profile_every
        self.tiles_x, self.tiles_y = tile_grid(cam0.width, cam0.height)

        self.shared: SceneShared
        self.priv: ViewerPrivate
        self.shared, self.priv = init_fleet(
            scene, cfg, cam0, slots, viewers_per_scene=viewers_per_scene,
            pool_size=self.pool_cap)
        self._fresh_shared = init_scene_shared(scene, cfg, cam0,
                                               pool_size=self.pool_cap)
        self._fresh_priv = init_viewer_private(cam0)

        # slot -> scene (static block layout) and host-side scheduler
        # mirrors of the device pool bookkeeping
        self._scene_of = np.arange(slots) // viewers_per_scene
        self._pool_cell = np.full((self.num_scenes, self.pool_cap), -1,
                                  np.int64)
        self._pool_tick = np.full((self.num_scenes, self.pool_cap),
                                  -self.window, np.int64)
        self._pool_owner = np.full((self.num_scenes, self.pool_cap), -1,
                                   np.int64)
        self._slot_pool = np.zeros((slots,), np.int64)
        self._refs = np.zeros((self.num_scenes, self.pool_cap), np.int64)
        # occupied slots (admit .. release) and stashed co-resident viewer
        # contexts (slot oversubscription): both hold pool references, so
        # a paced-idle or stashed viewer's sort entry is never reclaimed
        self._resident: set[int] = set()
        self._stash: dict[str, dict] = {}

        # observability: the SessionManager shares its tracer/registry with
        # the stepper; standalone steppers default to no-op/private ones
        self.tracer = obs_trace.NULL
        self.metrics = obs_metrics.Registry()

        self._slot_cams: list[Camera] = [cam0] * slots
        # frames each slot rendered since it last consumed a sort refresh
        # (drives the paced-slot staleness catch-up in _due_scheduled)
        self._frames_since_due = np.zeros((slots,), np.int64)
        self._pending_sort: set[int] = set()   # admitted, not yet sorted
        self.sort_log: list[dict] = []         # per-step sort accounting
        self.last_timing: TickTiming | None = None
        self.profile_s = 0.0   # cumulative profiling overhead (state copy +
                               # decomposed stage runs) — callers timing the
                               # serving loop subtract it for honest fps

        self._shade = jax.jit(
            functools.partial(batched_shade_phase, cfg=cfg,
                              viewers_per_scene=viewers_per_scene),
            donate_argnums=(1, 2))
        # scene-block shade jits per within-scene lane width (lane
        # compaction; the full-width instance is the legacy _shade_sub)
        self._lane_jits: dict[int, object] = {}
        self._shade_sub = self._get_lane_jit(viewers_per_scene)
        self._sort_pool = jax.jit(self._sort_pool_fn, donate_argnums=(1,))
        self._resize = jax.jit(self._resize_pool_fn, donate_argnums=(0, 2))
        self._admit_scene = jax.jit(self._admit_scene_fn,
                                    donate_argnums=(0, 1))
        self._admit_priv = jax.jit(self._admit_priv_fn, donate_argnums=(0,))
        self._occupancy = jax.jit(rc.occupancy)
        self._build_kernel_stages()
        # static byte accounting for state_metrics()
        self._pool_entry_bytes = (pytree_nbytes(self.shared.pool)
                                  // (self.num_scenes * self.pool_cap))
        self._cache_bytes = pytree_nbytes(self.shared.cache)

    # -- jitted bodies ------------------------------------------------------

    def _sort_pool_fn(self, scene, shared, priv, cams, slot_idx, scene_tgt,
                      pool_tgt, cells, tick):
        """Run the elected leaders' sorts and scatter the entries into the
        scene pools.

        ``slot_idx`` [W] int32 leader slots (padded with duplicates of a
        real slot); ``scene_tgt``/``pool_tgt`` [W] int32 scatter targets —
        ``num_scenes`` (out of bounds, dropped) for padding lanes.  Shared
        state is donated: all leaves except the updated pool alias straight
        through; privates are read-only (pose prediction inputs).
        """
        sub_priv = jax.tree.map(lambda x: x[slot_idx], priv)
        sub_cams = jax.tree.map(lambda x: x[slot_idx], cams)
        entries = batched_sort_phase(scene, sub_priv, sub_cams, self.cfg)
        pool = jax.tree.map(
            lambda full, upd: full.at[scene_tgt, pool_tgt].set(upd,
                                                               mode='drop'),
            shared.pool, entries)
        return dataclasses.replace(
            shared, pool=pool,
            pool_cell=shared.pool_cell.at[scene_tgt, pool_tgt].set(
                cells, mode='drop'),
            pool_tick=shared.pool_tick.at[scene_tgt, pool_tgt].set(
                tick, mode='drop'))

    def _shade_sub_fn(self, scene, shared, priv, cams, sorted_mask,
                      scene_idx, scene_tgt, slot_idx, slot_tgt, act_sub,
                      lanes=None):
        """Active-scene-prefix shade: gather the ``scene_idx`` scene blocks
        (and their ``slot_idx`` slots), shade only them, scatter the
        advanced state back.  ``scene_tgt``/``slot_tgt`` use
        ``num_scenes``/``slots`` (= dropped) for padding lanes; ``act_sub``
        [B*L] bool is False for padding and for idle slots inside active
        scenes.  ``lanes`` is the within-scene lane width L of the gathered
        sub-batch: the full ``viewers_per_scene`` on the legacy scene-block
        path, or a smaller power-of-two bucket when lane compaction gathers
        only each scene's live lanes.  Untouched scenes' state — and, under
        lane compaction, the idle lanes of shaded scenes — pass through
        unchanged."""
        lanes = self.viewers_per_scene if lanes is None else lanes
        sub_shared = jax.tree.map(lambda x: x[scene_idx], shared)
        sub_priv = jax.tree.map(lambda x: x[slot_idx], priv)
        sub_cams = jax.tree.map(lambda x: x[slot_idx], cams)
        new_sh, new_pr, images, stats = batched_shade_phase(
            scene, sub_shared, sub_priv, sub_cams, sorted_mask[slot_idx],
            act_sub, self.cfg, lanes)
        shared2 = jax.tree.map(
            lambda full, upd: full.at[scene_tgt].set(upd, mode='drop'),
            shared, new_sh)
        priv2 = jax.tree.map(
            lambda full, upd: full.at[slot_tgt].set(upd, mode='drop'),
            priv, new_pr)
        return shared2, priv2, images, stats

    def _get_lane_jit(self, lanes: int):
        """Jitted scene-block shade at within-scene lane width ``lanes``
        (one compile per power-of-two width, so at most log2(V) variants
        ever build — the same bound the scene-bucket compaction holds)."""
        fn = self._lane_jits.get(lanes)
        if fn is None:
            fn = jax.jit(functools.partial(self._shade_sub_fn, lanes=lanes),
                         donate_argnums=(1, 2))
            self._lane_jits[lanes] = fn
        return fn

    def _resize_pool_fn(self, cache, pool, priv, perm, remap, cell, tick,
                        refs):
        """Device half of a pool-capacity resize: gather the kept entries
        into the new layout (``perm`` [C, new_cap] old entry index per
        scene) and remap every viewer's ``pool_idx`` (``remap`` [C,
        old_cap] new index per old entry).  Entry payloads move bit-intact
        and every referencing lane follows its entry, so per-viewer output
        is unchanged by construction.  The pool is passed (and returned)
        separately from the rest of ``SceneShared``: its leaves change
        shape across the call, so only the shape-stable cache/priv buffers
        are donated."""
        c_idx = jnp.arange(self.num_scenes, dtype=jnp.int32)[:, None]
        new_pool = jax.tree.map(lambda x: x[c_idx, perm], pool)
        scene_of = jnp.asarray(self._scene_of, jnp.int32)
        new_idx = remap[scene_of, priv.pool_idx]
        shared = SceneShared(cache=cache, pool=new_pool, pool_cell=cell,
                             pool_tick=tick, pool_refs=refs)
        priv = dataclasses.replace(priv, pool_idx=new_idx)
        return shared, priv

    @staticmethod
    def _admit_scene_fn(shared, priv, fresh_shared, fresh_priv, scene_i,
                        slot):
        """Private-mode admit: cold-start the slot's whole scene (cache +
        pool) and its private state — exactly the pre-split semantics."""
        shared = jax.tree.map(
            lambda full, one: full.at[scene_i].set(one), shared, fresh_shared)
        priv = jax.tree.map(
            lambda full, one: full.at[slot].set(one), priv, fresh_priv)
        return shared, priv

    @staticmethod
    def _admit_priv_fn(priv, fresh_priv, slot):
        """Shared-mode admit: only the viewer's private state resets; the
        scene's cache (and any live pool entries) persist — that is the
        cross-viewer reuse this engine exists for."""
        return jax.tree.map(lambda full, one: full.at[slot].set(one),
                            priv, fresh_priv)

    # -- dropless pool capacity ---------------------------------------------

    def _resize_pool(self, new_cap: int,
                     keep: Optional[list] = None) -> None:
        """Resize the per-scene pool to ``new_cap`` entries.

        ``keep`` (shrink only) lists the entry indices each scene must
        preserve; they compact to a dense prefix in index order.  Growth
        passes ``keep=None`` and pads: old entries keep their indices, new
        entries start free (cell -1, aged tick, zero refs — their gathered
        payload is whatever entry 0 held, which nothing ever reads before a
        sort overwrites it).  Host mirrors, ``_slot_pool``, stashed lane
        contexts and the device state all move through the same mapping.
        """
        old = self.pool_cap
        c = self.num_scenes
        perm = np.zeros((c, new_cap), np.int64)
        remap = np.zeros((c, old), np.int64)
        cell = np.full((c, new_cap), -1, np.int64)
        tick = np.full((c, new_cap), -self.window, np.int64)
        owner = np.full((c, new_cap), -1, np.int64)
        refs = np.zeros((c, new_cap), np.int64)
        for ci in range(c):
            kept = (sorted(keep[ci]) if keep is not None
                    else list(range(min(old, new_cap))))
            for j, p in enumerate(kept):
                perm[ci, j] = p
                remap[ci, p] = j
                cell[ci, j] = self._pool_cell[ci, p]
                tick[ci, j] = self._pool_tick[ci, p]
                owner[ci, j] = self._pool_owner[ci, p]
                refs[ci, j] = self._refs[ci, p]
        self.shared, self.priv = self._resize(
            self.shared.cache, self.shared.pool, self.priv,
            jnp.asarray(perm, jnp.int32),
            jnp.asarray(remap, jnp.int32), jnp.asarray(cell, jnp.int32),
            jnp.asarray(tick, jnp.int32), jnp.asarray(refs, jnp.int32))
        self._pool_cell, self._pool_tick = cell, tick
        self._pool_owner, self._refs = owner, refs
        self._slot_pool = remap[self._scene_of, self._slot_pool]
        for ctx in self._stash.values():
            ctx['slot_pool'] = int(
                remap[int(self._scene_of[ctx['slot']]), ctx['slot_pool']])
        self.pool_cap = new_cap
        self.metrics.counter('pool.resizes',
                             'sort-pool capacity resizes').inc()
        self.metrics.gauge('pool.capacity',
                           'allocated sort-pool entries per scene'
                           ).set(new_cap)

    def _grow_pool_for(self, groups) -> None:
        """Grow capacity to cover the plan's highest entry index (the
        planner allocates virtual indices past ``pool_cap`` when no free
        entry exists — the dropless contract: route every live pose cell,
        never drop one)."""
        need = 1 + max((g.entry for g in groups), default=-1)
        if need > self.pool_cap:
            self._resize_pool(pow2_bucket(need))

    def _keep_entries(self) -> list:
        """Entries a shrink must preserve, per scene: referenced by any
        resident lane (active, paced-idle or stashed), plus entries still
        adoptable (sorted within the window by a still-resident owner) —
        dropping those would turn a would-be adoption into a re-sort and
        change per-viewer output vs the static pool."""
        keep = [set() for _ in range(self.num_scenes)]
        for ci in range(self.num_scenes):
            for p in range(self.pool_cap):
                if self._refs[ci, p] > 0:
                    keep[ci].add(p)
                elif (int(self._pool_owner[ci, p]) in self._resident
                      and self.global_tick - self._pool_tick[ci, p]
                      < self.window):
                    keep[ci].add(p)
        return keep

    def _maybe_shrink_pool(self) -> None:
        keep = self._keep_entries()
        used = max((len(k) for k in keep), default=0)
        target = pow2_bucket(used)
        if target < self.pool_cap:
            self._resize_pool(target, keep=keep)

    # -- slot residency / oversubscription ----------------------------------

    def release(self, slot: int) -> None:
        """The manager vacated ``slot``: drop it from the resident set so
        its pool entry no longer counts as referenced and the bucketed
        pool may reclaim the capacity."""
        self._resident.discard(slot)
        self._pending_sort.discard(slot)

    def stash_lane(self, slot: int, key: str) -> None:
        """Park the slot's current viewer context under ``key`` so a
        co-resident viewer can interleave into the same physical lane
        (slot oversubscription).  The parked context keeps its pool
        reference — a stashed viewer's sort entry is never reclaimed."""
        self._stash[key] = {
            'slot': int(slot),
            'priv': jax.tree.map(lambda x: np.asarray(x[slot]), self.priv),
            'cam': jax.tree.map(np.asarray, self._slot_cams[slot]),
            'frames_since_due': int(self._frames_since_due[slot]),
            'pending_sort': slot in self._pending_sort,
            'slot_pool': int(self._slot_pool[slot]),
        }
        self._pending_sort.discard(slot)

    def unstash_lane(self, slot: int, key: str) -> None:
        """Swap a parked viewer context back into its physical lane (the
        jitted admit scatter — lane shapes always match, no recompile)."""
        ctx = self._stash.pop(key)
        if ctx['slot'] != slot:
            raise ValueError(f'stash {key!r} belongs to slot '
                             f'{ctx["slot"]}, not {slot}')
        priv_lane = jax.tree.map(jnp.asarray, ctx['priv'])
        self.priv = self._admit_priv(self.priv, priv_lane, jnp.int32(slot))
        self._slot_cams[slot] = jax.tree.map(jnp.asarray, ctx['cam'])
        self._frames_since_due[slot] = ctx['frames_since_due']
        self._slot_pool[slot] = ctx['slot_pool']
        if ctx['pending_sort']:
            self._pending_sort.add(slot)
        else:
            self._pending_sort.discard(slot)

    def drop_stash(self, key: str) -> None:
        """A stashed viewer was evicted: its parked context (and pool
        reference) goes away."""
        self._stash.pop(key, None)

    # -- per-kernel profiling ----------------------------------------------

    def _build_kernel_stages(self) -> None:
        """Jitted stage functions decomposing the slot-batched pallas shade
        path for latency attribution (see module docstring).  Each stage is
        the same function the fused ``batched_shade_phase`` composes, so the
        split is faithful modulo XLA fusion across stage boundaries."""
        if self.cfg.backend != 'pallas' or not self.cfg.use_rc:
            return
        from repro.kernels import ops
        cfg = self.cfg
        tx, ty = self.tiles_x, self.tiles_y
        chunk = cfg.shade_chunk
        v = self.viewers_per_scene
        c = self.num_scenes

        # gauss is an argument (not a closure capture) so a streamed scene
        # swap never invalidates the profiling stages
        def prep(gauss, shared, priv, cams):
            feats_b = batched_prep_features(gauss, shared, priv, cams, cfg, v)
            feats_b = trim_features_slots(feats_b, tx)
            return ops.pad_features_slots(feats_b, chunk)

        def probe(caches, st_a, live):
            ids_g = jax.vmap(
                lambda r: regroup(r, tx, ty, cfg.group_tiles))(st_a.record)
            ids_cv = ids_g.reshape(c, v, *ids_g.shape[1:])
            live_cv = live.reshape(c, v)
            hit_cv, _, _, _ = jax.vmap(
                lambda cc, ii, lv: ops.rc_probe_multi(cc, ii, cfg.cache,
                                                      live=lv)
            )(caches, ids_cv, live_cv)
            hit = jax.vmap(
                lambda h: ungroup(h[..., None], tx, ty,
                                  cfg.group_tiles)[..., 0]
            )(hit_cv.reshape(len(live), *hit_cv.shape[2:]))
            return hit, ids_cv, hit_cv, live_cv

        def resume(feats_b, st_a, miss):
            t = feats_b.ids.shape[1]
            return ops.rasterize_resume_compacted_slots(
                feats_b, tx, st_a, miss, t_img=t, k_record=cfg.k_record,
                chunk=chunk, bg=cfg.bg)

        def insert(caches, ids_cv, colors, hit_cv, live_cv):
            raw_g = jax.vmap(
                lambda cl: regroup(cl, tx, ty, cfg.group_tiles))(colors)
            raw_cv = raw_g.reshape(c, v, *raw_g.shape[1:])
            return jax.vmap(
                lambda cc, ii, rr, dd: rc.insert_all_groups_multi(
                    cc, ii, rr, dd, cfg.cache)
            )(caches, ids_cv, raw_cv, ~hit_cv & live_cv[:, :, None, None])

        self._k_prep = jax.jit(prep)
        self._k_prefix = jax.jit(
            lambda f, a: ops.rasterize_prefix_slots(
                f, tx, k_record=cfg.k_record, chunk=chunk, live=a))
        self._k_lookup = jax.jit(probe)
        self._k_resume = jax.jit(resume)
        self._k_insert = jax.jit(insert)

    def _profile_kernels(self, shared: SceneShared, priv: ViewerPrivate,
                         cams: Camera, active_mask: jax.Array) -> dict:
        """Time the decomposed shade stages on a pre-shade state copy.

        Each stage lands in the trace as a device-track span nested under
        one ``shade.profile`` parent — the kernel breakdown Perfetto shows
        alongside the fused-shade spans it decomposes."""
        ms = {}
        stages = []

        def timed(name, f, *args):
            t0 = time.perf_counter()
            out = f(*args)
            jax.block_until_ready(out)
            t1 = time.perf_counter()
            ms[name] = (t1 - t0) * 1e3
            stages.append((name, t0, t1))
            return out

        feats_b = timed('prep', self._k_prep, self.scene, shared, priv, cams)
        st_a = timed('prefix', self._k_prefix, feats_b, active_mask)
        hit, ids_cv, hit_cv, live_cv = timed('lookup', self._k_lookup,
                                             shared.cache, st_a, active_mask)
        miss = ~hit & active_mask[:, None, None]
        colors, _, _ = timed('resume', self._k_resume, feats_b, st_a, miss)
        timed('insert', self._k_insert, shared.cache, ids_cv, colors,
              hit_cv, live_cv)
        self.tracer.complete('shade.profile', stages[0][1], stages[-1][2])
        for name, t0, t1 in stages:
            self.tracer.complete(f'kernel.{name}', t0, t1, depth=1)
        return ms

    # -- scheduling ---------------------------------------------------------

    def reset(self) -> None:
        """Cold-start every scene and viewer WITHOUT recompiling: fresh
        fleet state, pool bookkeeping and tick counter on the already-jitted
        callables.  Benchmarks use this between repetitions — in shared mode
        ``admit`` deliberately keeps scene caches warm, so only a reset
        separates repetitions honestly."""
        if self._streaming is not None:
            self._streaming.reset()
            self.scene = self._streaming.scene()
        self.pool_cap = 1 if self.dynamic_pool else self.pool_size
        self.shared, self.priv = init_fleet(
            self.scene, self.cfg, self._fresh_priv.prev_cam, self.slots,
            viewers_per_scene=self.viewers_per_scene,
            pool_size=self.pool_cap)
        c = self.num_scenes
        self._pool_cell = np.full((c, self.pool_cap), -1, np.int64)
        self._pool_tick = np.full((c, self.pool_cap), -self.window, np.int64)
        self._pool_owner = np.full((c, self.pool_cap), -1, np.int64)
        self._slot_pool = np.zeros((self.slots,), np.int64)
        self._refs = np.zeros((c, self.pool_cap), np.int64)
        self._frames_since_due[:] = 0
        self._pending_sort.clear()
        self._resident.clear()
        self._stash.clear()
        self.global_tick = 0
        self.sort_log = []
        self.last_timing = None

    def admit(self, slot: int) -> None:
        # fresh templates are read (not donated) by the admit scatters, so
        # they stay valid across admits without copies
        if self.viewers_per_scene == 1:
            scene_i = int(self._scene_of[slot])
            self.shared, self.priv = self._admit_scene(
                self.shared, self.priv, self._fresh_shared,
                self._fresh_priv, jnp.int32(scene_i), jnp.int32(slot))
            self._pool_cell[scene_i] = -1
            self._pool_tick[scene_i] = -self.window
            self._pool_owner[scene_i] = -1
        else:
            self.priv = self._admit_priv(self.priv, self._fresh_priv,
                                         jnp.int32(slot))
        self._slot_pool[slot] = 0
        self._frames_since_due[slot] = 0
        self._resident.add(slot)
        # The slot's camera is only known at the next step(): run its
        # sort-on-admit there, outside the scheduled per-tick cohort.
        self._pending_sort.add(slot)

    def quarantine(self, slot: int) -> None:
        """Blast-radius containment for a poisoned slot: its private state
        (the corrupt ``prev_cam`` rides there) resets to the cold-start
        template, any pool entry it *owns* is marked stale (owner cleared,
        tick aged out of the window) so no co-located viewer adopts it as
        fresh, and the slot re-sorts on its next frame.  In private mode
        this is a full scene cold-start; in shared mode the scene's cache
        persists — the ``jnp.isfinite`` insert gate already kept the
        poisoned values out of it."""
        scene_i = int(self._scene_of[slot])
        if self.viewers_per_scene > 1:
            owned = np.flatnonzero(self._pool_owner[scene_i] == slot)
            self._pool_owner[scene_i, owned] = -1
            self._pool_tick[scene_i, owned] = -self.window
        # co-residents stashed on this physical lane may reference an
        # invalidated entry: force them through a fresh sort on return
        for ctx in self._stash.values():
            if ctx['slot'] == slot:
                ctx['pending_sort'] = True
        self.admit(slot)
        # the stacked camera batch reads _slot_cams every dispatch — a NaN
        # lane must not linger past containment
        self._slot_cams[slot] = self._fresh_priv.prev_cam

    def _due_scheduled(self, active: set, exclude: set,
                       fsd=None) -> list[int]:
        """Slots due for a scheduled sort refresh this tick: the cohort
        residue leg (``global_tick % window == slot % window``) plus a
        staleness catch-up for frame-paced viewers.

        The residue leg assumes a slot renders every tick; a paced slot
        (``ViewerSession.pace`` > 1) renders only every ``pace`` ticks, and
        when its render ticks never align with its residue (e.g. ``pace %
        window == 0`` off-phase) it would ride its admission sort forever
        while faster co-resident viewers keep ``global_tick`` advancing.
        The catch-up leg marks a slot due when the frame it is about to
        render would otherwise be its ``window``-th since the last refresh
        (``frames_since_due`` counts the rendered-unrefreshed frames, so
        the trigger is ``>= window - 1``) — restoring the documented "no
        frame renders from a sort older than ``window`` *frames*" bound on
        the slot's own frame clock, at exactly the legacy refresh spacing.
        For always-active (pace-1) slots the residue leg fires no later
        than the catch-up could (a refresh every ``window`` ticks ==
        ``window`` frames), so the legacy cohort cadence — and its
        bit-parity oracles — are untouched.
        """
        fsd = self._frames_since_due if fsd is None else fsd
        r = self.global_tick % self.window
        return [i for i in range(self.slots)
                if i in active and i not in exclude
                and (i % self.window == r
                     or fsd[i] >= self.window - 1)]

    def _plan_groups(self, due: list[int], active: set,
                     cells: dict[int, int], slot_pool=None,
                     protect=()) -> list[_SortGroup]:
        """Group the due slots by (scene, pose cell), elect leaders, pick
        pool entries, and decide which groups actually sort.

        Deterministic given (slot -> cell, pool bookkeeping): groups are
        processed in (scene, leader) order, entry allocation prefers the
        entry already holding the cell, then the lowest-index free entry
        (refs counted over active non-due slots plus earlier groups).  A
        group *adopts* without sorting iff its cell's entry is fresh
        (sorted within the window) and owned by a still-active slot outside
        the group that is still in that cell — so a lone viewer (or any
        private-mode slot) always sorts on its own cadence, bit-identical
        to the cohort scheduler.

        Non-due active slots of the same scene whose *current* cell matches
        a group's ride along onto its entry ("riders"): they were going to
        render this cell from an older buffer of their own; consolidating
        them onto the freshly sorted (strictly fresher, same-cell, so
        margin-equivalent) entry keeps co-located fleets at one live buffer
        per cell instead of one per cadence phase.  Riders do not count as
        sorted — their cadence is untouched.

        With the bucketed pool, entries referenced by paced-idle residents
        and by stashed (oversubscribed) viewer contexts are seeded into the
        refcounts too, so a viewer idling this tick never has its entry
        stolen.  When every in-capacity entry is referenced, the dynamic
        pool allocates *virtual* entry indices past ``pool_cap`` — the
        dropless contract: ``_grow_pool_for`` resizes before the sorts
        scatter, so no pose cell is ever dropped.  ``slot_pool``/``protect``
        let ``plan_step`` substitute post-lane-swap entry assignments.
        """
        sp = self._slot_pool if slot_pool is None else slot_pool
        groups: dict[tuple[int, int], list[int]] = {}
        for i in due:
            groups.setdefault((int(self._scene_of[i]), cells[i]),
                              []).append(i)
        rider_pool: dict[tuple[int, int], list[int]] = {}
        for i in sorted(active):
            key = (int(self._scene_of[i]), cells[i])
            if i not in due and key in groups:
                rider_pool.setdefault(key, []).append(i)

        refs = np.zeros((self.num_scenes, self.pool_cap), np.int64)
        for i in active:
            if i not in due and (int(self._scene_of[i]), cells[i]) \
                    not in groups:
                refs[self._scene_of[i], sp[i]] += 1
        for i in self._resident:
            if i not in active and i not in self._pending_sort:
                refs[self._scene_of[i], sp[i]] += 1
        for ctx in self._stash.values():
            if not ctx['pending_sort']:
                refs[self._scene_of[ctx['slot']], ctx['slot_pool']] += 1
        for scene_i, p in protect:
            refs[scene_i, p] += 1
        claimed: set[tuple[int, int]] = set()
        next_new: dict[int, int] = {}
        planned = []
        for (scene_i, cell), members in sorted(groups.items(),
                                               key=lambda kv: min(kv[1])):
            leader = min(members)
            riders = tuple(rider_pool.get((scene_i, cell), ()))
            # an entry still tagged with this cell is only reusable if no
            # earlier group claimed it this tick (a stale held entry with
            # zero refs is fair game for another group's free-entry search;
            # reusing it anyway would scatter two sorts into one slot)
            held = [int(p)
                    for p in np.flatnonzero(self._pool_cell[scene_i] == cell)
                    if (scene_i, int(p)) not in claimed]
            entry = held[0] if held else -1
            if entry >= 0:
                owner = int(self._pool_owner[scene_i, entry])
                fresh = (self.global_tick - self._pool_tick[scene_i, entry]
                         < self.window)
                owner_ok = (owner in active and owner not in members
                            and cells.get(owner) == cell)
                if fresh and owner_ok:
                    planned.append(_SortGroup(scene_i, cell, leader,
                                              tuple(members), riders,
                                              entry, False))
                    claimed.add((scene_i, entry))
                    refs[scene_i, entry] += len(members) + len(riders)
                    continue
            if entry < 0:
                free = [p for p in range(self.pool_cap)
                        if refs[scene_i, p] == 0
                        and (scene_i, p) not in claimed]
                if free:
                    entry = free[0]
                elif self.dynamic_pool:
                    # every in-capacity entry is referenced: allocate a
                    # virtual index past pool_cap; _grow_pool_for resizes
                    # before the sorts scatter (dropless)
                    entry = next_new.get(scene_i, self.pool_cap)
                    next_new[scene_i] = entry + 1
                else:
                    # static pool: a free entry always exists (each slot
                    # references at most one entry and the pool holds one
                    # per slot); fall back to overwriting the leader's
                    # current entry defensively
                    entry = int(self._slot_pool[leader])
            planned.append(_SortGroup(scene_i, cell, leader, tuple(members),
                                      riders, entry, True))
            claimed.add((scene_i, entry))
            if entry < self.pool_cap:
                refs[scene_i, entry] += len(members) + len(riders)
        return planned

    def _run_sorts(self, cam_b: Camera, groups: list[_SortGroup]) -> None:
        """Execute the sorting groups' leader sorts, ``cohort`` at a time."""
        tick = jnp.int32(self.global_tick)
        for i in range(0, len(groups), self.cohort):
            batch = groups[i:i + self.cohort]
            pad = self.cohort - len(batch)
            slot_idx = jnp.asarray([g.leader for g in batch]
                                   + [batch[0].leader] * pad, jnp.int32)
            scene_tgt = jnp.asarray([g.scene for g in batch]
                                    + [self.num_scenes] * pad, jnp.int32)
            pool_tgt = jnp.asarray([g.entry for g in batch] + [0] * pad,
                                   jnp.int32)
            cell_keys = jnp.asarray([g.cell for g in batch] + [0] * pad,
                                    jnp.int32)
            self.shared = self._sort_pool(self.scene, self.shared, self.priv,
                                          cam_b, slot_idx, scene_tgt,
                                          pool_tgt, cell_keys, tick)
        for g in groups:
            self._pool_cell[g.scene, g.entry] = g.cell
            self._pool_tick[g.scene, g.entry] = self.global_tick
            self._pool_owner[g.scene, g.entry] = g.leader

    def _apply_assignments(self, groups: list[_SortGroup],
                           active: set) -> None:
        """Point every group member at its entry (host mirrors + device
        ``ViewerPrivate``) and refresh the pool refcounts."""
        slots, pools, cellv = [], [], []
        for g in groups:
            for m in g.members + g.riders:
                self._slot_pool[m] = g.entry
                slots.append(m)
                pools.append(g.entry)
                cellv.append(g.cell)
        if slots:
            idx = jnp.asarray(slots, jnp.int32)
            self.priv = dataclasses.replace(
                self.priv,
                pool_idx=self.priv.pool_idx.at[idx].set(
                    jnp.asarray(pools, jnp.int32)),
                cell_id=self.priv.cell_id.at[idx].set(
                    jnp.asarray(cellv, jnp.int32)))
        refs = np.zeros((self.num_scenes, self.pool_cap), np.int64)
        for i in active:
            refs[self._scene_of[i], self._slot_pool[i]] += 1
        # paced-idle residents and stashed co-resident contexts hold their
        # entries across idle ticks (not a steal candidate, not shrinkable)
        for i in self._resident:
            if i not in active and i not in self._pending_sort:
                refs[self._scene_of[i], self._slot_pool[i]] += 1
        for ctx in self._stash.values():
            if not ctx['pending_sort']:
                refs[self._scene_of[ctx['slot']], ctx['slot_pool']] += 1
        self._refs = refs
        self.shared = dataclasses.replace(
            self.shared, pool_refs=jnp.asarray(refs, jnp.int32))

    def _slot_cell_key(self, slot: int, cam: Camera) -> int:
        """Pose-cell key for a slot rendering ``cam``.  In private mode
        (one viewer per scene) cells are moot — the slot id keys its own
        singleton group, sparing the quantization work."""
        if self.viewers_per_scene == 1:
            return slot
        return posecell.pose_cell_key(cam, cell_size=self.cell_size,
                                      ang_bins=self.cell_ang_bins)

    def plan_step(self, cams: dict[int, Camera], pending_admits=(),
                  lane_swaps=None) -> _StepPlan:
        """Pure host planning for a coming ``step(cams)`` call: pose-cell
        quantization, the sort-on-admit set, the due cohort and the sort
        groups.  Reads only the host-side scheduler mirrors (never device
        arrays) and mutates nothing — the async host loop runs this on a
        worker thread while the device executes the previous tick.  The
        caller must sequence it after the previous ``step_dispatch`` has
        returned (that dispatch's host bookkeeping is this plan's input).

        ``pending_admits`` names slots whose ``admit()`` is planned but not
        yet applied — the manager plans ahead of admission, so those slots'
        sort-on-admit must be scheduled here even though ``_pending_sort``
        does not contain them yet.

        ``lane_swaps`` maps slot -> stash key for oversubscribed lanes the
        manager will swap before dispatch: the plan substitutes the
        incoming context's pending/cadence/entry bookkeeping for the
        slot's, and protects the outgoing occupant's entry (it is stashed,
        not released) from the free-entry search.
        """
        stream = None
        if self._streaming is not None and cams:
            # residency first: slots stalled on a missing chunk drop out of
            # this tick entirely (no render, no sort, cursor retried), so
            # the scheduling below sees only the slots that will run.
            # Pending admits are named so their cold-start loads are exempt
            # from the per-tick load budget.
            admit_guess = ((set(self._pending_sort) | set(pending_admits))
                           & set(cams))
            stream = self._streaming.plan(self.global_tick, cams,
                                          admit_guess)
            if stream.stalled:
                cams = {s: c for s, c in cams.items()
                        if s not in stream.stalled}
        active = set(cams)
        if not cams or not self.cfg.use_s2:
            return _StepPlan(frozenset(active), (), (), (), stream)
        swaps = dict(lane_swaps or {})
        cells = {i: self._slot_cell_key(i, cams[i]) for i in active}
        pending = set(self._pending_sort)
        slot_pool = self._slot_pool
        fsd = self._frames_since_due
        protect = []
        if swaps:
            slot_pool = slot_pool.copy()
            fsd = fsd.copy()
            for slot, key in swaps.items():
                ctx = self._stash[key]
                if slot not in self._pending_sort:
                    protect.append((int(self._scene_of[slot]),
                                    int(self._slot_pool[slot])))
                pending.discard(slot)
                if ctx['pending_sort']:
                    pending.add(slot)
                slot_pool[slot] = ctx['slot_pool']
                fsd[slot] = ctx['frames_since_due']
        # Sort-on-admit outside the tick's scheduled cohort: newly
        # admitted slots must not render a stale or zero-filled entry.
        admits = sorted((pending | set(pending_admits)) & active)
        sched = self._due_scheduled(active, exclude=set(admits), fsd=fsd)
        due = sorted(set(admits) | set(sched))
        groups = self._plan_groups(due, active, cells, slot_pool=slot_pool,
                                   protect=protect)
        return _StepPlan(active=frozenset(active), admits=tuple(admits),
                         due=tuple(due), groups=tuple(groups),
                         stream=stream)

    def _apply_stream(self, stream) -> None:
        """Execute a residency plan (evictions, loads, LOD render masks)
        and swap the streamed scene view in for this tick's shade.  The
        manager publishes through this stepper's registry/tracer so the
        ``stream.*`` series land where the session rolls tick metrics up;
        they are re-pointed every call because the session installs its
        tracer after construction."""
        mgr = self._streaming
        mgr.metrics = self.metrics
        mgr.tracer = self.tracer
        mgr.apply(stream)
        if mgr.dirty:
            # scene is an argument to every jitted callable (same shapes:
            # the arena is fixed-size), so the swap never recompiles
            self.scene = mgr.scene()

    def step_dispatch(self, cams: dict[int, Camera],
                      plan: Optional[_StepPlan] = None):
        """Host scheduling + async device dispatch for one step.  Returns an
        ``_InFlight`` handle; all host-side mutations (sort bookkeeping,
        ``global_tick``, ``sort_log``) are complete when this returns — only
        the device shade is still executing.  ``step_finish`` blocks on it.
        """
        if not cams:
            return None
        with self.tracer.span('step_dispatch', tick=self.global_tick,
                              slots=len(cams)):
            return self._dispatch(cams, plan)

    def _dispatch(self, cams: dict[int, Camera],
                  plan: Optional[_StepPlan]):
        if plan is None:
            plan = self.plan_step(cams)
        if plan.stream is not None:
            self._apply_stream(plan.stream)
            if plan.stream.stalled:
                # a stalled slot renders nothing this tick: its cursor is
                # never advanced (no output), so the same frame retries
                # next tick against the freshly loaded chunks
                cams = {s: c for s, c in cams.items()
                        if s not in plan.stream.stalled}
            if not cams:
                # every requested slot stalled — the loads above still ran,
                # so the retried tick can make progress
                self.global_tick += 1
                self.sort_log.append({'scheduled': 0, 'admit': 0,
                                      'joined': 0})
                return None
        for slot, cam in cams.items():
            self._slot_cams[slot] = cam
        cam_b = stack_cameras(self._slot_cams)
        active = set(cams)

        t0 = time.perf_counter()
        n_admit = n_sched = n_joined = 0
        if self.cfg.use_s2:
            groups = list(plan.groups)
            sorting = [g for g in groups if g.sorts]
            if self.dynamic_pool:
                # grow BEFORE the sorts scatter: the planner's virtual
                # entry indices must be in capacity or the mode='drop'
                # scatter would silently discard the sort
                self._grow_pool_for(groups)
            if sorting:
                self._run_sorts(cam_b, sorting)
            self._apply_assignments(groups, active)
            self._pending_sort -= active
            if self.dynamic_pool:
                # shrink AFTER assignments refreshed the refcounts, so
                # capacity tracks the live pose-cell count this tick
                self._maybe_shrink_pool()
            admit_set = set(plan.admits)
            n_admit = sum(1 for g in sorting if g.leader in admit_set)
            n_sched = len(sorting) - n_admit
            n_joined = (sum(len(g.members) for g in groups if not g.sorts)
                        + sum(len(g.riders) for g in groups))
            # executions vs adoptions, attributed per (scene, pose cell):
            # the redundancy ledger the pose-cell scheduler is judged by
            for g in groups:
                adopted = len(g.members) - (1 if g.sorts else 0)
                if g.sorts:
                    self.metrics.counter(
                        'sort.executed', 'speculative sorts run',
                        scene=g.scene, cell=g.cell).inc()
                if adopted:
                    self.metrics.counter(
                        'sort.adopted', 'due slots adopting a leader sort',
                        scene=g.scene, cell=g.cell).inc(adopted)
                if g.riders:
                    self.metrics.counter(
                        'sort.riders',
                        'non-due slots consolidated onto a fresh entry',
                        scene=g.scene, cell=g.cell).inc(len(g.riders))
            # Two deliberately different telemetry views of "sorted":
            # per-session ``sorted_this_frame`` flags every DUE slot — it
            # reached its cadence point and renders from a sort refreshed
            # for its cell this window (executed by it or adopted from the
            # group leader), so per-viewer sorts_per_frame stays ~1/window.
            # Tick-level ``sorted_slots``/sort_log count only EXECUTED
            # sorts — the fleet's cost.  Their ratio IS the sharing win.
            # (Riders are not due and not flagged: cadence untouched.)
            sorted_set = set(plan.due)
            for i in active:
                self._frames_since_due[i] = (0 if i in sorted_set
                                             else self._frames_since_due[i]
                                             + 1)
            if sorting:
                jax.block_until_ready(self.shared.pool.lists.indices)
        else:
            # Baseline mode runs Projection+Sorting for every active lane
            # every frame (inside shade_phase, so its cost lands in
            # shade_ms): count those sorts so tick_rollup/sort_log never
            # report an amortization this mode doesn't have.
            self._pending_sort -= active
            sorted_set = active
            n_sched = len(sorted_set)
            self.metrics.counter(
                'sort.executed',
                'per-lane sorts (no-S2 baseline)').inc(n_sched)
        sort_s = time.perf_counter() - t0
        if n_sched + n_admit:
            # the sort window on the device lane (the leader sorts block
            # inside dispatch, so begin/end are explicit)
            self.tracer.complete('sort', t0, t0 + sort_s,
                                 tick=self.global_tick,
                                 executed=n_sched + n_admit)

        sorted_mask = jnp.asarray(
            [1.0 if i in sorted_set else 0.0 for i in range(self.slots)],
            jnp.float32)

        do_profile = (self.profile_every > 0
                      and self.cfg.backend == 'pallas' and self.cfg.use_rc
                      and self.global_tick % self.profile_every == 0)
        profile = None
        if do_profile:
            # the shade call donates the state — keep a copy to profile
            t_prof = time.perf_counter()
            prof_shared = copy_pytree(self.shared)
            prof_priv = copy_pytree(self.priv)
            jax.block_until_ready(prof_shared.cache.tags)
            self.profile_s += time.perf_counter() - t_prof
            active_mask_full = jnp.asarray(
                [i in active for i in range(self.slots)], bool)
            profile = (prof_shared, prof_priv, cam_b, active_mask_full)

        v = self.viewers_per_scene
        active_scenes = sorted({int(self._scene_of[i]) for i in active})
        per_scene = {c: [i for i in range(c * v, (c + 1) * v) if i in active]
                     for c in active_scenes}
        # within-scene lane width: the pow2 bucket of the busiest active
        # scene's live lane count (lane compaction); v itself when every
        # lane bucket rounds up to full width
        lanes = (pow2_bucket(max(len(s) for s in per_scene.values()), cap=v)
                 if v > 1 else 1)
        t1 = time.perf_counter()
        if lanes == v and len(active_scenes) == self.num_scenes:
            # every scene live at full lane width: full shade, no
            # gather/scatter (idle slots inside a scene still pass
            # active=False)
            active_mask = jnp.asarray([i in active
                                       for i in range(self.slots)], bool)
            self.shared, self.priv, images, stats = self._shade(
                self.scene, self.shared, self.priv, cam_b, sorted_mask,
                active_mask)
            pos = {slot: slot for slot in active}
        elif lanes == v:
            # idle-scene compaction: shade only the active scene blocks,
            # padded to a power-of-two bucket so shade widths compile at
            # most log2(C) times; idle scenes are untouched
            bucket = pow2_bucket(len(active_scenes), cap=self.num_scenes)
            pad = bucket - len(active_scenes)
            scenes_g = active_scenes + [active_scenes[0]] * pad
            slots_g = [c * v + j for c in scenes_g for j in range(v)]
            scene_idx = jnp.asarray(scenes_g, jnp.int32)
            scene_tgt = jnp.asarray(active_scenes + [self.num_scenes] * pad,
                                    jnp.int32)
            slot_idx = jnp.asarray(slots_g, jnp.int32)
            slot_tgt = jnp.asarray(
                [c * v + j for c in active_scenes for j in range(v)]
                + [self.slots] * (pad * v), jnp.int32)
            act_sub = jnp.asarray(
                [i < len(active_scenes) * v and slots_g[i] in active
                 for i in range(bucket * v)])
            self.shared, self.priv, images, stats = self._shade_sub(
                self.scene, self.shared, self.priv, cam_b, sorted_mask,
                scene_idx, scene_tgt, slot_idx, slot_tgt, act_sub)
            pos = {slot: j for j, slot in enumerate(slots_g[:len(
                active_scenes) * v]) if slot in active}
        else:
            # within-scene lane compaction: gather each active scene's
            # LIVE lanes (padded to the common ``lanes`` bucket with inert
            # duplicates), shade the dense sub-batch, scatter only the
            # live lanes back.  Idle lanes of active scenes are untouched
            # — in particular never shaded and never charged a lane of
            # shade width.  Bit-identical per-viewer output: inactive
            # lanes contribute nothing to the shared cache/LRU, and the
            # skipped idle-lane private update only bumps ``frame_idx``
            # (read solely as ``frame_idx == 0``) and rewrites
            # ``prev_cam`` with the value it already holds.
            bucket = pow2_bucket(len(active_scenes), cap=self.num_scenes)
            pad = bucket - len(active_scenes)
            scenes_g = active_scenes + [active_scenes[0]] * pad
            slots_g: list[int] = []
            slot_tgt_l: list[int] = []
            for c in active_scenes:
                live = per_scene[c]
                fill = lanes - len(live)
                slots_g += live + [live[0]] * fill
                slot_tgt_l += live + [self.slots] * fill
            for _ in range(pad):
                slots_g += [slots_g[0]] * lanes
                slot_tgt_l += [self.slots] * lanes
            scene_idx = jnp.asarray(scenes_g, jnp.int32)
            scene_tgt = jnp.asarray(active_scenes + [self.num_scenes] * pad,
                                    jnp.int32)
            slot_idx = jnp.asarray(slots_g, jnp.int32)
            slot_tgt = jnp.asarray(slot_tgt_l, jnp.int32)
            act_sub = jnp.asarray([t < self.slots for t in slot_tgt_l])
            shade = self._get_lane_jit(lanes)
            self.shared, self.priv, images, stats = shade(
                self.scene, self.shared, self.priv, cam_b, sorted_mask,
                scene_idx, scene_tgt, slot_idx, slot_tgt, act_sub)
            pos = {s: j for j, (s, t) in enumerate(zip(slots_g, slot_tgt_l))
                   if t < self.slots}

        self.global_tick += 1
        self.sort_log.append({'scheduled': n_sched, 'admit': n_admit,
                              'joined': n_joined})
        return _InFlight(cams=cams, images=images, stats=stats, pos=pos,
                         t0=t0, t1=t1, sort_s=sort_s, n_sched=n_sched,
                         n_admit=n_admit, profile=profile,
                         tick=self.global_tick - 1)

    def step_finish(self, infl) -> dict:
        """Block on a dispatched step's device work and assemble the per-slot
        outputs + tick timing."""
        if infl is None:
            return {}
        jax.block_until_ready(infl.images)
        t2 = time.perf_counter()
        # the async device window: dispatch -> outputs ready.  This is the
        # span the threaded driver's worker plan(t+1) should sit under.
        self.tracer.complete('shade', infl.t1, t2, tick=infl.tick,
                             slots=len(infl.cams))

        kernel_ms = None
        if infl.profile is not None:
            t_prof = time.perf_counter()
            prof_shared, prof_priv, cam_b, active_mask_full = infl.profile
            kernel_ms = self._profile_kernels(prof_shared, prof_priv, cam_b,
                                              active_mask_full)
            self.profile_s += time.perf_counter() - t_prof

        timing = TickTiming(latency_s=t2 - infl.t0,
                            sort_ms=infl.sort_s * 1e3,
                            shade_ms=(t2 - infl.t1) * 1e3,
                            sorted_slots=infl.n_sched + infl.n_admit,
                            kernel_ms=kernel_ms)
        self.last_timing = timing
        # every rider of the batch waited for the whole tick
        return {slot: (infl.images[infl.pos[slot]],
                       jax.tree.map(lambda x: x[infl.pos[slot]], infl.stats),
                       timing)
                for slot in infl.cams}

    def step(self, cams: dict[int, Camera],
             plan: Optional[_StepPlan] = None) -> dict:
        return self.step_finish(self.step_dispatch(cams, plan))

    # -- telemetry ----------------------------------------------------------

    def state_metrics(self) -> dict:
        """Occupancy and state-memory footprint of the shared state.

        Three tiers, finest to coarsest: ``*_bytes`` charge only entries
        with live referencing viewers (the number of distinct (scene,
        pose-cell) sorts actually held); ``*_alloc_bytes`` report what the
        device currently allocates — under the dropless bucketed pool that
        is ``pool_cap`` entries per scene, tracking live work instead of
        the worst case; ``*_reserved_bytes`` report the static worst case
        (``pool_size`` entries per scene, the every-viewer-its-own-cell
        layout) the dynamic pool replaces — alloc == reserved when a
        pinned ``pool_size`` disables bucketing."""
        live = int((self._refs > 0).sum())
        pool_bytes = live * self._pool_entry_bytes
        pool_alloc = (self.num_scenes * self.pool_cap
                      * self._pool_entry_bytes)
        pool_reserved = (self.num_scenes * self.pool_size
                         * self._pool_entry_bytes)
        m = {
            # dispatched async, NOT synced here: the serving tick must not
            # block on a telemetry reduction (tick_rollup converts to float
            # after the timed loop)
            'occupancy': self._occupancy(self.shared.cache),
            'sort_pool_live': live,
            'sort_pool_total': self.num_scenes * self.pool_cap,
            'sort_pool_bytes': pool_bytes,
            'sort_pool_alloc_bytes': pool_alloc,
            'sort_pool_reserved_bytes': pool_reserved,
            'cache_bytes': self._cache_bytes,
            'state_bytes': pool_bytes + self._cache_bytes,
            'state_alloc_bytes': pool_alloc + self._cache_bytes,
            'state_reserved_bytes': pool_reserved + self._cache_bytes,
        }
        if self._streaming is not None:
            mgr = self._streaming
            cnt = mgr.counters()
            m.update({
                'stream_resident_bytes': mgr.resident_bytes,
                'stream_arena_bytes': mgr.arena_bytes,
                'stream_full_bytes': mgr.chunked.scene_bytes,
                'stream_stalls': cnt['stalls'],
                'stream_loads': cnt['loads'],
                'stream_prefetch_hits': cnt['prefetch_hits'],
                'stream_evictions': cnt['evictions'],
            })
        self.metrics.gauge(
            'state.alloc_bytes',
            'device bytes backing live serving state').set(
                float(m['state_alloc_bytes']))
        self.metrics.gauge(
            'state.reserved_bytes',
            'worst-case static-pool serving state bytes').set(
                float(m['state_reserved_bytes']))
        return m

    # -- checkpoint/restore --------------------------------------------------

    def state_dict(self) -> tuple:
        """``(arrays, meta)`` snapshot of everything a bit-identical resume
        needs: the device pytrees (``SceneShared``/``ViewerPrivate`` plus the
        stacked per-slot cameras — a restored dispatch re-stacks the same
        batch) and the host-side scheduler mirrors as plain JSON-able meta.
        The arrays pytree is what ``repro.checkpoint`` serializes; callers
        must snapshot at a tick boundary (nothing in flight — the shade
        donates these buffers)."""
        arrays = {'shared': self.shared, 'priv': self.priv,
                  'slot_cams': stack_cameras(self._slot_cams)}
        if self._stash:
            arrays['stash'] = {k: {'priv': ctx['priv'], 'cam': ctx['cam']}
                               for k, ctx in self._stash.items()}
        stream_meta = None
        if self._streaming is not None:
            stream_arrays, stream_meta = self._streaming.state_dict()
            arrays['stream'] = stream_arrays
        meta = {
            'global_tick': int(self.global_tick),
            'pool_cap': int(self.pool_cap),
            'pool_cell': self._pool_cell.tolist(),
            'pool_tick': self._pool_tick.tolist(),
            'pool_owner': self._pool_owner.tolist(),
            'slot_pool': self._slot_pool.tolist(),
            'refs': self._refs.tolist(),
            'frames_since_due': self._frames_since_due.tolist(),
            'pending_sort': sorted(int(i) for i in self._pending_sort),
            'resident': sorted(int(i) for i in self._resident),
            'stash': {k: {'slot': int(ctx['slot']),
                          'frames_since_due': int(ctx['frames_since_due']),
                          'pending_sort': bool(ctx['pending_sort']),
                          'slot_pool': int(ctx['slot_pool'])}
                      for k, ctx in self._stash.items()},
        }
        if stream_meta is not None:
            meta['stream'] = stream_meta
        return arrays, meta

    def load_state(self, arrays, meta: dict) -> None:
        """Restore a ``state_dict`` snapshot onto the already-compiled
        callables.  Shapes must match the snapshot (the checkpoint loader
        verifies them against a ``state_template`` built for the saved
        geometry); a snapshot taken at a different ``pool_cap`` than the
        live stepper holds simply retraces the affected jits on the next
        step — capacity is part of the crash-consistent state.
        ``jnp.asarray`` materializes fresh device buffers, so the next
        step's donation never aliases the caller's numpy copies."""
        self.shared = jax.tree.map(jnp.asarray, arrays['shared'])
        self.priv = jax.tree.map(jnp.asarray, arrays['priv'])
        cam_b = arrays['slot_cams']
        self._slot_cams = [
            jax.tree.map(lambda x, i=i: jnp.asarray(x)[i], cam_b)
            for i in range(self.slots)]
        self.global_tick = int(meta['global_tick'])
        self.pool_cap = int(meta.get('pool_cap', self.pool_size))
        self._pool_cell = np.asarray(meta['pool_cell'], np.int64)
        self._pool_tick = np.asarray(meta['pool_tick'], np.int64)
        self._pool_owner = np.asarray(meta['pool_owner'], np.int64)
        self._slot_pool = np.asarray(meta['slot_pool'], np.int64)
        self._refs = np.asarray(meta['refs'], np.int64)
        self._frames_since_due = np.asarray(meta['frames_since_due'],
                                            np.int64)
        self._pending_sort = set(int(i) for i in meta['pending_sort'])
        # legacy snapshots (pre-oversubscription) default every slot
        # resident — conservative: entries stay protected until the
        # manager's occupancy catches up
        self._resident = set(int(i) for i in
                             meta.get('resident', range(self.slots)))
        stash_arrays = arrays.get('stash', {})
        self._stash = {}
        for k, sm in meta.get('stash', {}).items():
            sa = stash_arrays[k]
            self._stash[k] = {
                'slot': int(sm['slot']),
                'priv': jax.tree.map(np.asarray, sa['priv']),
                'cam': jax.tree.map(np.asarray, sa['cam']),
                'frames_since_due': int(sm['frames_since_due']),
                'pending_sort': bool(sm['pending_sort']),
                'slot_pool': int(sm['slot_pool']),
            }
        if self._streaming is not None and 'stream' in meta:
            self._streaming.load_state(arrays['stream'], meta['stream'])
            self.scene = self._streaming.scene()

    def state_template(self, meta: dict):
        """Arrays pytree matching a snapshot's geometry WITHOUT mutating
        the live state: the checkpoint loader needs a shape template
        before deserializing, and a crashed run may have saved at a
        different pool capacity (or with stashed lanes) than a freshly
        constructed stepper holds.  ``meta`` is the snapshot's manifest
        extra (``state_dict()[1]``); only shapes matter — leaf values are
        never read."""
        shared = self.shared
        cap = int(meta.get('pool_cap', self.pool_cap))
        if cap != self.pool_cap:
            c = self.num_scenes
            shared = dataclasses.replace(
                shared,
                pool=jax.tree.map(
                    lambda x: np.zeros((c, cap) + x.shape[2:], x.dtype),
                    shared.pool),
                pool_cell=np.zeros((c, cap), np.int32),
                pool_tick=np.zeros((c, cap), np.int32),
                pool_refs=np.zeros((c, cap), np.int32))
        arrays = {'shared': shared, 'priv': self.priv,
                  'slot_cams': stack_cameras(self._slot_cams)}
        stash_meta = meta.get('stash', {})
        if stash_meta:
            lane = jax.tree.map(lambda x: np.asarray(x[0]), self.priv)
            cam = jax.tree.map(np.asarray, self._slot_cams[0])
            arrays['stash'] = {k: {'priv': lane, 'cam': cam}
                               for k in stash_meta}
        if self._streaming is not None and 'stream' in meta:
            arrays['stream'] = self._streaming.state_template()
        return arrays

    # -- viewer extraction / injection (fleet migration) ---------------------

    def extract_viewer(self, slot: int, with_scene: bool = False) -> dict:
        """Snapshot one viewer's lane for re-admission on another stepper.

        The payload always carries the ``ViewerPrivate`` lane and the slot's
        last camera (pose-prediction continuity across the move).  With
        ``with_scene`` (private mode only) it additionally carries the slot's
        whole ``SceneShared`` block plus the host pool mirrors for it — a
        *scene-carry* move that keeps the radiance cache warm.  Scene-carry
        payloads are only valid for an **aligned** restore (same slot index
        on a stepper at the same ``global_tick``): ``pool_owner`` stores slot
        ids and ``pool_tick`` stores absolute ticks, and neither is
        re-encoded here.  Cross-slot moves must restore cold
        (``shared=None``) and eat the documented sort-on-admit staleness."""
        scene_i = int(self._scene_of[slot])
        payload = {
            'priv': jax.tree.map(lambda x: np.asarray(x[slot]), self.priv),
            'cam': jax.tree.map(np.asarray, self._slot_cams[slot]),
            'frames_since_due': int(self._frames_since_due[slot]),
            'pending_sort': slot in self._pending_sort,
            'shared': None,
            'pool_rows': None,
        }
        if with_scene:
            if self.viewers_per_scene != 1:
                raise ValueError('scene-carry extraction needs a private '
                                 'scene block (viewers_per_scene == 1)')
            payload['shared'] = jax.tree.map(
                lambda x: np.asarray(x[scene_i]), self.shared)
            payload['pool_rows'] = {
                'pool_cell': self._pool_cell[scene_i].copy(),
                'pool_tick': self._pool_tick[scene_i].copy(),
                'pool_owner': self._pool_owner[scene_i].copy(),
                'slot_pool': int(self._slot_pool[slot]),
                'refs': self._refs[scene_i].copy(),
            }
        return payload

    def restore_viewer(self, slot: int, payload: dict) -> None:
        """Re-admit an ``extract_viewer`` payload into ``slot``.

        Scene-carry payloads reuse the jitted private-mode admit scatter
        (lane shapes match the cold templates, so no recompilation) and
        restore the pool mirrors — bit-identical continuation when the
        alignment contract above holds.  Cold payloads go through the normal
        ``admit`` (fresh scene, sort-on-admit queued) and then overwrite
        just the private lane, so the migrated viewer resumes its pose
        trajectory against a cold cache: at most one sort-window of sharing
        staleness, never a wrong image."""
        scene_i = int(self._scene_of[slot])
        priv_lane = jax.tree.map(jnp.asarray, payload['priv'])
        if payload.get('shared') is not None:
            if self.viewers_per_scene != 1:
                raise ValueError('scene-carry restore needs a private '
                                 'scene block (viewers_per_scene == 1)')
            shared_lane = jax.tree.map(jnp.asarray, payload['shared'])
            self.shared, self.priv = self._admit_scene(
                self.shared, self.priv, shared_lane, priv_lane,
                jnp.int32(scene_i), jnp.int32(slot))
            rows = payload['pool_rows']
            self._pool_cell[scene_i] = np.asarray(rows['pool_cell'],
                                                  np.int64)
            self._pool_tick[scene_i] = np.asarray(rows['pool_tick'],
                                                  np.int64)
            self._pool_owner[scene_i] = np.asarray(rows['pool_owner'],
                                                   np.int64)
            self._slot_pool[slot] = int(rows['slot_pool'])
            self._refs[scene_i] = np.asarray(rows['refs'], np.int64)
            self._frames_since_due[slot] = int(payload['frames_since_due'])
            if payload['pending_sort']:
                self._pending_sort.add(slot)
            else:
                self._pending_sort.discard(slot)
        else:
            self.admit(slot)
            self.priv = self._admit_priv(self.priv, priv_lane,
                                         jnp.int32(slot))
        self._slot_cams[slot] = jax.tree.map(jnp.asarray, payload['cam'])


class SequentialStepper:
    """Reference engine: one single-viewer jitted step per active slot,
    per-viewer sort cadence (``frame_idx % window``), fully private state
    (each slot carries its own scene: cache + pool-of-one)."""

    viewers_per_scene = 1

    def __init__(self, scene: GaussianScene, cfg: LuminaConfig,
                 cam0: Camera, slots: int, profile_every: int = 0):
        del profile_every   # per-kernel attribution is a batched-engine tool
        self.scene = scene
        self.cfg = cfg
        self.slots = slots
        self._fresh = init_viewer_state(scene, cfg, cam0)
        # Per-slot copies: the step donates its state, so slots must never
        # share buffers with each other or with the cold-start template.
        self._states: list[ViewerState] = [copy_pytree(self._fresh)
                                           for _ in range(slots)]
        self._step = jax.jit(functools.partial(render_step, cfg=cfg),
                             donate_argnums=(1,))
        self.tracer = obs_trace.NULL
        self.metrics = obs_metrics.Registry()
        self.sort_log: list[dict] = []
        self.last_timing: TickTiming | None = None
        self.profile_s = 0.0
        self._last_active = 0
        self._pool_entry_bytes = pytree_nbytes(self._fresh.scene_shared.pool)
        self._cache_bytes = pytree_nbytes(self._fresh.scene_shared.cache)

    def admit(self, slot: int) -> None:
        self._states[slot] = copy_pytree(self._fresh)

    def release(self, slot: int) -> None:
        """No dynamic capacity to reclaim on the static engine."""

    def quarantine(self, slot: int) -> None:
        """Containment on the private engine is a full cold-start: every
        piece of the slot's state (cache included) is its own."""
        self.admit(slot)

    def reset(self) -> None:
        """Cold-start every slot (see ``BatchedStepper.reset``)."""
        self._states = [copy_pytree(self._fresh) for _ in range(self.slots)]
        self.sort_log = []
        self.last_timing = None
        self._last_active = 0

    def state_dict(self) -> tuple:
        """``(arrays, meta)`` snapshot (see ``BatchedStepper.state_dict``):
        per-slot ``ViewerState`` pytrees, no host mirrors to carry."""
        return {f'slot{i}': st for i, st in enumerate(self._states)}, {}

    def load_state(self, arrays, meta: dict) -> None:
        del meta
        self._states = [jax.tree.map(jnp.asarray, arrays[f'slot{i}'])
                        for i in range(self.slots)]

    def step_dispatch(self, cams: dict[int, Camera], plan=None):
        """Nothing dispatches ahead on the sequential engine: each slot's
        step blocks for its per-slot latency attribution, so the whole tick
        executes inside ``step_finish``.  The threaded host loop still
        overlaps its planning with that execution (the jitted per-slot
        steps release the GIL) — the uniform protocol at the baseline's
        pipelining depth."""
        del plan
        return cams

    def step_finish(self, cams) -> dict:
        return self.step(cams) if cams else {}

    def step(self, cams: dict[int, Camera], plan=None) -> dict:
        del plan   # host sort planning is a batched-engine concept
        out = {}
        sorts = 0
        t_start = time.perf_counter()
        for slot, cam in cams.items():
            t0 = time.perf_counter()
            self._states[slot], image, stats = self._step(
                self.scene, self._states[slot], cam)
            jax.block_until_ready(image)
            t_done = time.perf_counter()
            dt = t_done - t0
            self.tracer.complete('render_step', t0, t_done, slot=slot)
            sorted_flag = int(float(stats.sorted_this_frame))
            sorts += sorted_flag
            # The monolithic reference step fuses the phases; its whole
            # latency is attributed to shade (sort_ms stays 0) — the split
            # attribution is what the batched engine exists to provide.
            out[slot] = (image, stats,
                         TickTiming(latency_s=dt, sort_ms=0.0,
                                    shade_ms=dt * 1e3,
                                    sorted_slots=sorted_flag))
        self.sort_log.append({'scheduled': sorts, 'admit': 0, 'joined': 0})
        if sorts:
            self.metrics.counter('sort.executed',
                                 'per-viewer cadence sorts').inc(sorts)
        self.last_timing = TickTiming(
            latency_s=time.perf_counter() - t_start, sort_ms=0.0,
            shade_ms=(time.perf_counter() - t_start) * 1e3,
            sorted_slots=sorts)
        self._last_active = len(cams)
        return out

    def state_metrics(self) -> dict:
        """Private-state footprint: every occupied slot holds a full sort
        buffer and a full cache — the O(S) memory the scene-shared engine
        exists to collapse; the engine allocates all ``slots`` copies up
        front (``*_alloc_bytes``).  (No occupancy scan: S separate device
        reductions per tick would tax the baseline's own timing.)"""
        live = self._last_active
        pool_bytes = live * self._pool_entry_bytes
        per_slot = self._pool_entry_bytes + self._cache_bytes
        return {
            'sort_pool_live': live,
            'sort_pool_total': self.slots,
            'sort_pool_bytes': pool_bytes,
            'sort_pool_alloc_bytes': self._pool_entry_bytes * self.slots,
            'sort_pool_reserved_bytes': self._pool_entry_bytes * self.slots,
            'cache_bytes': self._cache_bytes * live,
            'state_bytes': pool_bytes + self._cache_bytes * live,
            'state_alloc_bytes': per_slot * self.slots,
            'state_reserved_bytes': per_slot * self.slots,
        }
