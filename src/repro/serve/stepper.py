"""Slot steppers: how a set of viewer slots advances one frame.

Two interchangeable engines behind one interface:

* ``BatchedStepper``    — the serving fast path.  A **cohort sort scheduler**
  staggers speculative sorts across slots (slot ``i`` sorts when
  ``global_tick % window == i % window``, plus sort-on-admit outside the
  tick): each tick gathers only the due cohort (<= ceil(S/window) slots),
  runs one small vmapped/jitted ``sort_phase`` over it, scatters the
  resulting ``SortShared`` leaves back into the batched ``ViewerState``, then
  advances the live slots through a vmapped ``shade_phase`` whose no-sort
  path is scalar and sort-free.  This restores the paper's 1-in-window sort
  amortization that a per-lane ``lax.cond`` (lowered to a select under vmap)
  destroys.
* ``SequentialStepper`` — each active slot advances through its own
  single-viewer jitted ``render_step`` (the reference/baseline the benchmark
  compares against; per-viewer sort cadence, exact ``LuminSys`` semantics).

Cadence-shift caveat: the cohort scheduler intentionally shifts *when* each
slot sorts relative to an independent per-viewer run (cadence-shift, not
result-change — every frame still renders from a sort no older than
``window`` frames, and a slot admitted mid-window sorts immediately).  For a
single viewer in slot 0 admitted at tick 0 the cadences coincide and the two
engines agree on every integer cache decision.

Both engines **donate** their ``ViewerState`` buffers into the jitted calls
(the previous tick's state is dead the instant the step returns), so XLA
updates the O(S*N) state in place instead of round-tripping a copy every
tick.

**Idle-lane compaction**: when some slots are idle, the batched engine
gathers the active slots into a dense prefix (padded to a power-of-two
bucket so at most log2(S) shade widths ever compile), shades only that
sub-batch, and scatters results back — idle lanes are not shaded at all, on
either backend, and their state (cache, frame counter) is left untouched
instead of advancing with garbage.  Under ``vmap`` this is the only way to
stop paying for dead lanes: a per-lane ``live=False`` mask zeroes their
*contribution*, but XLA still executes the batch-wide max trip count.  When
every slot is active the engine takes the full-width path unchanged.

**Per-kernel latency attribution**: with ``profile_every=N`` (and the
``pallas`` backend), every Nth tick re-runs the shade decomposed into its
kernel stages — prep (S^2 feature refresh), prefix (RC phase A), lookup
(LuminCache probe), resume (miss-compacted phase B), insert — on a copy of
the pre-shade state, timing each stage with a device sync.  The breakdown
lands in ``TickTiming.kernel_ms`` / ``SessionManager.tick_log`` and is
rolled up by ``telemetry.tick_rollup``.  The decomposed stages are the same
functions the fused shade composes, so the split is faithful modulo XLA
fusion across stage boundaries; profiling runs outside the timed section
(``sort_ms``/``shade_ms`` are unaffected; wall-clock of profiled runs is
slightly conservative).

Interface::

    stepper.admit(slot)                  # reset a slot to cold-start state
    out = stepper.step({slot: cam, ..})  # advance the given slots one frame
    # out: {slot: (image, FrameStats, TickTiming)}
    stepper.sort_log                     # per-step {'scheduled','admit'} counts
    stepper.last_timing                  # tick-level TickTiming of the last
                                         # non-empty step (SessionManager
                                         # reads it for its tick_log)
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import radiance_cache as rc
from repro.core.camera import Camera, stack_cameras
from repro.core.gaussians import GaussianScene
from repro.core.groups import regroup, ungroup
from repro.core.pipeline import (LuminaConfig, ViewerState,
                                 batched_shade_phase, batched_sort_phase,
                                 copy_pytree, init_viewer_state, render_step)
from repro.core.tiling import tile_grid


class TickTiming(NamedTuple):
    """Per-phase latency attribution for the tick a frame rode in."""

    latency_s: float     # wall-clock of the whole tick (sort + shade)
    sort_ms: float       # wall-clock of the tick's sort-phase calls
    shade_ms: float      # wall-clock of the tick's shade-phase call
    sorted_slots: int    # speculative sorts executed this tick (incl. admits)
    kernel_ms: Optional[dict] = None  # per-kernel shade breakdown (profiled
                                      # ticks on the pallas backend)


class BatchedStepper:
    """All live slots advance in one vmapped ``shade_phase`` call per tick
    (gathered to a dense prefix when some slots are idle); only the due
    cohort runs ``sort_phase``."""

    def __init__(self, scene: GaussianScene, cfg: LuminaConfig,
                 cam0: Camera, slots: int, profile_every: int = 0):
        self.scene = scene
        self.cfg = cfg
        self.slots = slots
        self.window = max(1, cfg.window) if cfg.use_s2 else 1
        # Fixed cohort width: ceil(S/window) slots share each sort tick, so
        # the gather/sort/scatter call jits once for the worst-case cohort.
        self.cohort = -(-slots // self.window)
        self.global_tick = 0
        self.profile_every = profile_every
        self.tiles_x, self.tiles_y = tile_grid(cam0.width, cam0.height)
        self._fresh = init_viewer_state(scene, cfg, cam0)
        self.states: ViewerState = jax.tree.map(
            lambda x: jnp.stack([x] * slots), self._fresh)
        self._slot_cams: list[Camera] = [cam0] * slots
        self._pending_sort: set[int] = set()   # admitted, not yet sorted
        self.sort_log: list[dict] = []         # per-step sort accounting
        self.last_timing: TickTiming | None = None
        self.profile_s = 0.0   # cumulative profiling overhead (state copy +
                               # decomposed stage runs) — callers timing the
                               # serving loop subtract it for honest fps

        self._shade = jax.jit(
            functools.partial(batched_shade_phase, cfg=cfg),
            donate_argnums=(1,))
        self._shade_sub = jax.jit(self._shade_sub_fn, donate_argnums=(1,))
        self._sort_cohort = jax.jit(self._sort_cohort_fn,
                                    donate_argnums=(1,))
        self._admit_one = jax.jit(self._admit_fn, donate_argnums=(0,))
        self._build_kernel_stages()

    # -- jitted bodies ------------------------------------------------------

    def _sort_cohort_fn(self, scene, states, cams, idx, tgt):
        """Gather the due cohort, sort it, scatter the SortShared back.

        ``idx`` [C] int32 source slots (padded with duplicates of a real
        slot); ``tgt`` [C] int32 scatter targets — ``self.slots`` (out of
        bounds, dropped) for padding lanes.  States are donated: all leaves
        except the updated ``shared`` alias straight through.
        """
        sub_states = jax.tree.map(lambda x: x[idx], states)
        sub_cams = jax.tree.map(lambda x: x[idx], cams)
        shared = batched_sort_phase(scene, sub_states, sub_cams, self.cfg)
        new_shared = jax.tree.map(
            lambda full, upd: full.at[tgt].set(upd, mode='drop'),
            states.shared, shared)
        return dataclasses.replace(states, shared=new_shared)

    def _shade_sub_fn(self, scene, states, cams, sorted_mask, idx, tgt,
                      act_sub):
        """Active-prefix shade: gather the ``idx`` slots, shade only them,
        scatter the advanced states back.  ``idx`` [B] source slots (padded
        with duplicates), ``tgt`` [B] scatter targets (``self.slots`` =
        dropped, for padding lanes), ``act_sub`` [B] bool (False for padding,
        which therefore contributes nothing and is dropped on scatter).
        Idle slots' states pass through untouched.
        """
        sub_states = jax.tree.map(lambda x: x[idx], states)
        sub_cams = jax.tree.map(lambda x: x[idx], cams)
        new_sub, images, stats = batched_shade_phase(
            scene, sub_states, sub_cams, sorted_mask[idx], act_sub, self.cfg)
        new_states = jax.tree.map(
            lambda full, upd: full.at[tgt].set(upd, mode='drop'),
            states, new_sub)
        return new_states, images, stats

    @staticmethod
    def _admit_fn(states, fresh, slot):
        return jax.tree.map(lambda full, one: full.at[slot].set(one),
                            states, fresh)

    # -- per-kernel profiling ----------------------------------------------

    def _build_kernel_stages(self) -> None:
        """Jitted stage functions decomposing the slot-batched pallas shade
        path for latency attribution (see module docstring).  Each stage is
        the same function the fused ``batched_shade_phase`` composes, so the
        split is faithful modulo XLA fusion across stage boundaries."""
        if self.cfg.backend != 'pallas' or not self.cfg.use_rc:
            return
        from repro.core.pipeline import (batched_prep_features,
                                         trim_features_slots)
        from repro.kernels import ops
        cfg, scene = self.cfg, self.scene
        tx, ty = self.tiles_x, self.tiles_y
        chunk = cfg.shade_chunk

        def prep(states, cams):
            feats_b = batched_prep_features(scene, states, cams, cfg)
            feats_b = trim_features_slots(feats_b, tx)
            return ops.pad_features_slots(feats_b, chunk)

        def probe(caches, st_a):
            ids_g = jax.vmap(
                lambda r: regroup(r, tx, ty, cfg.group_tiles))(st_a.record)
            hit_g, _, _, _ = jax.vmap(
                lambda c, i: ops.rc_probe(c, i, cfg.cache))(caches, ids_g)
            hit = jax.vmap(
                lambda h: ungroup(h[..., None], tx, ty,
                                  cfg.group_tiles)[..., 0])(hit_g)
            return hit, ids_g, hit_g

        def resume(feats_b, st_a, miss):
            t = feats_b.ids.shape[1]
            return ops.rasterize_resume_compacted_slots(
                feats_b, tx, st_a, miss, t_img=t, k_record=cfg.k_record,
                chunk=chunk, bg=cfg.bg)

        def insert(caches, ids_g, colors, hit_g):
            raw_g = jax.vmap(
                lambda c: regroup(c, tx, ty, cfg.group_tiles))(colors)
            return jax.vmap(
                lambda c, i, r, h: rc.insert_all_groups(c, i, r, ~h,
                                                        cfg.cache)
            )(caches, ids_g, raw_g, hit_g)

        self._k_prep = jax.jit(prep)
        self._k_prefix = jax.jit(
            lambda f, a: ops.rasterize_prefix_slots(
                f, tx, k_record=cfg.k_record, chunk=chunk, live=a))
        self._k_lookup = jax.jit(probe)
        self._k_resume = jax.jit(resume)
        self._k_insert = jax.jit(insert)

    def _profile_kernels(self, states: ViewerState, cams: Camera,
                         active_mask: jax.Array) -> dict:
        """Time the decomposed shade stages on a pre-shade state copy."""
        ms = {}

        def timed(name, f, *args):
            t0 = time.perf_counter()
            out = f(*args)
            jax.block_until_ready(out)
            ms[name] = (time.perf_counter() - t0) * 1e3
            return out

        feats_b = timed('prep', self._k_prep, states, cams)
        st_a = timed('prefix', self._k_prefix, feats_b, active_mask)
        hit, ids_g, hit_g = timed('lookup', self._k_lookup,
                                  states.cache, st_a)
        miss = ~hit & active_mask[:, None, None]
        colors, _, _ = timed('resume', self._k_resume, feats_b, st_a, miss)
        timed('insert', self._k_insert, states.cache, ids_g, colors, hit_g)
        return ms

    # -- scheduling ---------------------------------------------------------

    def admit(self, slot: int) -> None:
        self.states = self._admit_one(self.states, self._fresh,
                                      jnp.int32(slot))
        # The slot's camera is only known at the next step(): run its
        # sort-on-admit there, outside the scheduled per-tick cohort.
        self._pending_sort.add(slot)

    def _due_cohort(self, active: set, exclude: set) -> list[int]:
        r = self.global_tick % self.window
        return [i for i in range(self.slots)
                if i % self.window == r and i in active
                and i not in exclude]

    def _run_sort(self, cams_b: Camera, due: list[int]) -> None:
        pad = self.cohort - len(due)
        idx = jnp.asarray(due + [due[0]] * pad, jnp.int32)
        tgt = jnp.asarray(due + [self.slots] * pad, jnp.int32)
        self.states = self._sort_cohort(self.scene, self.states, cams_b,
                                        idx, tgt)

    def step(self, cams: dict[int, Camera]) -> dict:
        if not cams:
            return {}
        for slot, cam in cams.items():
            self._slot_cams[slot] = cam
        cam_b = stack_cameras(self._slot_cams)
        active = set(cams)

        t0 = time.perf_counter()
        n_admit = n_sched = 0
        if self.cfg.use_s2:
            # Sort-on-admit, outside the tick's scheduled cohort: newly
            # admitted slots must not render the zero-filled SortShared.
            admits = sorted(self._pending_sort & active)
            for i in range(0, len(admits), self.cohort):
                self._run_sort(cam_b, admits[i:i + self.cohort])
            self._pending_sort -= active
            n_admit = len(admits)
            # The scheduled cohort: slot i sorts when tick % window == i %
            # window — at most ceil(S/window) slots, one small jitted call.
            # Slots that just sorted on admit skip their scheduled turn.
            due = self._due_cohort(active, exclude=set(admits))
            if due:
                self._run_sort(cam_b, due)
            n_sched = len(due)
            sorted_set = set(admits) | set(due)
            if sorted_set:
                jax.block_until_ready(self.states.shared.lists.indices)
        else:
            # Baseline mode runs Projection+Sorting for every active lane
            # every frame (inside shade_phase, so its cost lands in
            # shade_ms): count those sorts so tick_rollup/sort_log never
            # report an amortization this mode doesn't have.
            self._pending_sort -= active
            sorted_set = active
            n_sched = len(sorted_set)
        sort_s = time.perf_counter() - t0

        sorted_mask = jnp.asarray(
            [1.0 if i in sorted_set else 0.0 for i in range(self.slots)],
            jnp.float32)

        do_profile = (self.profile_every > 0
                      and self.cfg.backend == 'pallas' and self.cfg.use_rc
                      and self.global_tick % self.profile_every == 0)
        if do_profile:
            # the shade call donates self.states — keep a copy to profile
            t_prof = time.perf_counter()
            prof_states = copy_pytree(self.states)
            jax.block_until_ready(prof_states.cache.tags)
            self.profile_s += time.perf_counter() - t_prof

        active_list = sorted(active)
        t1 = time.perf_counter()
        if len(active_list) == self.slots:
            # every slot live: full-width shade, no gather/scatter
            active_mask = jnp.ones((self.slots,), bool)
            self.states, images, stats = self._shade(
                self.scene, self.states, cam_b, sorted_mask, active_mask)
            pos = {slot: slot for slot in active_list}
        else:
            # idle-lane compaction: shade only the active prefix, padded to
            # a power-of-two bucket so shade widths compile at most log2(S)
            # times; idle slots are untouched (no work, no state advance)
            bucket = 1
            while bucket < len(active_list):
                bucket *= 2
            bucket = min(bucket, self.slots)
            pad = bucket - len(active_list)
            idx = jnp.asarray(active_list + [active_list[0]] * pad,
                              jnp.int32)
            tgt = jnp.asarray(active_list + [self.slots] * pad, jnp.int32)
            act_sub = jnp.asarray([True] * len(active_list) + [False] * pad)
            self.states, images, stats = self._shade_sub(
                self.scene, self.states, cam_b, sorted_mask, idx, tgt,
                act_sub)
            pos = {slot: j for j, slot in enumerate(active_list)}
        jax.block_until_ready(images)
        t2 = time.perf_counter()

        kernel_ms = None
        if do_profile:
            t_prof = time.perf_counter()
            active_mask_full = jnp.asarray(
                [i in active for i in range(self.slots)], bool)
            kernel_ms = self._profile_kernels(prof_states, cam_b,
                                              active_mask_full)
            self.profile_s += time.perf_counter() - t_prof

        self.global_tick += 1
        self.sort_log.append({'scheduled': n_sched, 'admit': n_admit})
        timing = TickTiming(latency_s=t2 - t0, sort_ms=sort_s * 1e3,
                            shade_ms=(t2 - t1) * 1e3,
                            sorted_slots=n_sched + n_admit,
                            kernel_ms=kernel_ms)
        self.last_timing = timing
        # every rider of the batch waited for the whole tick
        return {slot: (images[pos[slot]],
                       jax.tree.map(lambda x: x[pos[slot]], stats),
                       timing)
                for slot in cams}


class SequentialStepper:
    """Reference engine: one single-viewer jitted step per active slot,
    per-viewer sort cadence (``frame_idx % window``)."""

    def __init__(self, scene: GaussianScene, cfg: LuminaConfig,
                 cam0: Camera, slots: int, profile_every: int = 0):
        del profile_every   # per-kernel attribution is a batched-engine tool
        self.scene = scene
        self.cfg = cfg
        self.slots = slots
        self._fresh = init_viewer_state(scene, cfg, cam0)
        # Per-slot copies: the step donates its state, so slots must never
        # share buffers with each other or with the cold-start template.
        self._states: list[ViewerState] = [copy_pytree(self._fresh)
                                           for _ in range(slots)]
        self._step = jax.jit(functools.partial(render_step, cfg=cfg),
                             donate_argnums=(1,))
        self.sort_log: list[dict] = []
        self.last_timing: TickTiming | None = None
        self.profile_s = 0.0

    def admit(self, slot: int) -> None:
        self._states[slot] = copy_pytree(self._fresh)

    def step(self, cams: dict[int, Camera]) -> dict:
        out = {}
        sorts = 0
        t_start = time.perf_counter()
        for slot, cam in cams.items():
            t0 = time.perf_counter()
            self._states[slot], image, stats = self._step(
                self.scene, self._states[slot], cam)
            jax.block_until_ready(image)
            dt = time.perf_counter() - t0
            sorted_flag = int(float(stats.sorted_this_frame))
            sorts += sorted_flag
            # The monolithic reference step fuses the phases; its whole
            # latency is attributed to shade (sort_ms stays 0) — the split
            # attribution is what the batched engine exists to provide.
            out[slot] = (image, stats,
                         TickTiming(latency_s=dt, sort_ms=0.0,
                                    shade_ms=dt * 1e3,
                                    sorted_slots=sorted_flag))
        self.sort_log.append({'scheduled': sorts, 'admit': 0})
        self.last_timing = TickTiming(
            latency_s=time.perf_counter() - t_start, sort_ms=0.0,
            shade_ms=(time.perf_counter() - t_start) * 1e3,
            sorted_slots=sorts)
        return out
