"""Slot steppers: how a set of viewer slots advances one frame.

Two interchangeable engines behind one interface:

* ``BatchedStepper``    — all slots advance in ONE vmapped, jitted
  ``render_step`` call over stacked ``ViewerState``/``Camera`` pytrees
  (continuous batching for frames: this is the serving fast path);
* ``SequentialStepper`` — each active slot advances through its own
  single-viewer jitted step (the reference/baseline the benchmark
  compares against).

Interface::

    stepper.admit(slot)                  # reset a slot to cold-start state
    out = stepper.step({slot: cam, ..})  # advance the given slots one frame
    # out: {slot: (image, FrameStats, latency_s)}

Inactive slots in the batched engine still execute (their lanes render at
their last camera) — their outputs and state are garbage-by-construction and
are fully overwritten by ``admit`` before the slot is read again, exactly
like a freed KV-cache slot in the LM server.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core.camera import Camera, stack_cameras
from repro.core.gaussians import GaussianScene
from repro.core.pipeline import (LuminaConfig, ViewerState,
                                 batched_render_step, init_viewer_state,
                                 render_step)


class BatchedStepper:
    """All slots advance in one vmapped ``render_step`` call."""

    def __init__(self, scene: GaussianScene, cfg: LuminaConfig,
                 cam0: Camera, slots: int):
        self.scene = scene
        self.cfg = cfg
        self.slots = slots
        self._fresh = init_viewer_state(scene, cfg, cam0)
        self.states: ViewerState = jax.tree.map(
            lambda x: jnp.stack([x] * slots), self._fresh)
        self._slot_cams: list[Camera] = [cam0] * slots
        self._step = jax.jit(functools.partial(batched_render_step, cfg=cfg))

    def admit(self, slot: int) -> None:
        self.states = jax.tree.map(lambda full, one: full.at[slot].set(one),
                                   self.states, self._fresh)

    def step(self, cams: dict[int, Camera]) -> dict:
        if not cams:
            return {}
        for slot, cam in cams.items():
            self._slot_cams[slot] = cam
        cam_b = stack_cameras(self._slot_cams)
        t0 = time.perf_counter()
        self.states, images, stats = self._step(self.scene, self.states, cam_b)
        jax.block_until_ready(images)
        latency = time.perf_counter() - t0
        # every rider of the batch waited for the whole tick
        return {slot: (images[slot],
                       jax.tree.map(lambda x: x[slot], stats),
                       latency)
                for slot in cams}


class SequentialStepper:
    """Reference engine: one single-viewer jitted step per active slot."""

    def __init__(self, scene: GaussianScene, cfg: LuminaConfig,
                 cam0: Camera, slots: int):
        self.scene = scene
        self.cfg = cfg
        self.slots = slots
        self._fresh = init_viewer_state(scene, cfg, cam0)
        self._states: list[ViewerState] = [self._fresh] * slots
        self._step = jax.jit(functools.partial(render_step, cfg=cfg))

    def admit(self, slot: int) -> None:
        self._states[slot] = self._fresh

    def step(self, cams: dict[int, Camera]) -> dict:
        out = {}
        for slot, cam in cams.items():
            t0 = time.perf_counter()
            self._states[slot], image, stats = self._step(
                self.scene, self._states[slot], cam)
            jax.block_until_ready(image)
            out[slot] = (image, stats, time.perf_counter() - t0)
        return out
