"""Elastic multi-device serving fleet: scene-sharded workers, live session
migration, and device-loss recovery.

Scene blocks shard across a 1-D ``devices`` mesh axis (``launch.mesh
.make_serve_mesh`` / ``repro.runtime.sharding.DEVICES_AXIS``): one host
worker per device, each a full single-device serving stack — a
``BatchedStepper`` whose arrays live on that device plus a
``SessionManager`` driving the plan/apply/observe seam
(``repro.serve.events``).  On top sits a shared admission queue and a
deterministic placement layer:

  * ``plan_route``      — FIFO routing of arrived sessions onto the
    least-loaded alive device (sticky per-scene when viewers share scene
    caches), pure numpy/python like ``plan_tick``;
  * ``plan_rebalance``  — greedy max->min moves of *queued* sessions until
    the load spread is within ``slack``; deterministic, a no-op when
    already balanced, never targets a dead device;
  * ``plan_shrink``     — device-loss placement: the lost device's slotted
    viewers map onto survivors' free slots **at the same slot index**
    wherever possible (``aligned`` — bit-identical continuation, see
    below), the rest ``spill`` back to the admission queue.

**Lockstep clock.** Every alive worker runs exactly one manager tick per
fleet tick, and idle ticks advance the stepper's ``global_tick`` too, so
all steppers share one sort-cadence clock (``global_tick == fleet tick``).
That invariant is what makes cross-device moves exact: a viewer restored
at the same slot index on a stepper at the same ``global_tick`` sees the
same cadence residue, the same pool-freshness windows and the same lane
state — its continuation is bit-identical to never having moved.

**Drivers.** ``SyncFleetDriver`` is the virtual N-device oracle: workers
tick sequentially in device order on a pure tick counter — replaying a
traffic trace reproduces images, cache tags, LRU ages and sort cadence
bit-for-bit.  ``ThreadedFleetDriver`` runs one persistent thread per
worker (the real-time shape: devices crunch their ticks concurrently,
barrier at the tick boundary).  Workers touch disjoint state and run the
same ``run_tick`` code, and all fleet-level decisions (routing, loss
handling) happen on the main thread between barriers — so the threaded
fleet is structurally bit-identical to the sync oracle (the conformance
suite in ``tests/test_fleet.py`` asserts it on both backends).  Per-worker
wall times feed a ``repro.runtime.straggler.StragglerDetector``;
``exclude_stragglers=True`` turns a persistent straggler into a
``lose_device`` shrink at the tick boundary (wall-clock-driven, so it is
off by default to preserve bit-identity).

**Live migration** (``FleetManager.migrate``) moves one viewer between
devices at a tick boundary via ``BatchedStepper.extract_viewer`` /
``restore_viewer`` payloads — the per-viewer slice of the PR-7 snapshot
format (``ViewerPrivate`` lane + camera, plus the ``SceneShared`` block
and pool bookkeeping when the move is slot-aligned).  Aligned moves are
bit-identical; unaligned moves restore cold and re-sort on admission, so
the viewer observes at most one sort-window of sharing staleness — the
same bound every freshly admitted viewer already lives under.

**Device loss.** A ``device_loss`` fault event (``repro.serve.faults``) or
a straggler exclusion marks a device dead at a tick boundary.  With
checkpointing enabled (all workers snapshot at the same tick multiples,
so the per-device checkpoints form one crash-consistent fleet snapshot)
recovery is a whole-fleet rollback — synchronous elastic-training
semantics, like ``repro.runtime.elastic`` shrinking a training mesh:

  1. every survivor restores its own checkpoint (bit-identical per-worker
     resume — the PR-7 kill-and-restore oracle);
  2. the victim's checkpoint is read host-side; its slotted viewers are
     placed onto survivors by ``plan_shrink`` — aligned ones restore their
     exact lane (bit-identical continuation vs the unfaulted golden run),
     spilled ones re-queue with their checkpoint cursor;
  3. per-session telemetry rolls back to the restored cursors
     (``SessionTelemetry.rollback``) so replayed frames are not
     double-counted; delivery is at-least-once;
  4. anything admitted after the snapshot re-queues from the start.

Without checkpoints the recovery is cold: host-side cursors are
crash-consistent in-process, so victims re-queue at their current frame
and re-admit cold on survivors — zero dropped viewers either way.  While
capacity is degraded the bounded fleet admission queue (``max_pending``)
sheds *new* load instead of collapsing: accepted viewers always drain.

Fault scope: the fleet consumes only ``device_loss`` from its injector;
per-worker host-loop faults (plan_exc, nan_poison, ...) belong to the
single-device drivers and keep their existing seams there.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import warnings
import time
from collections import deque
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from repro.launch.mesh import serve_devices
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime.straggler import StragglerDetector
from repro.serve import faults as serve_faults
from repro.serve import telemetry as serve_telemetry
from repro.serve.session import SessionManager, ViewerSession
from repro.serve.stepper import BatchedStepper


# -- pure placement planners (numpy/python only, no device state) -----------

def plan_route(pending, loads, alive, scene_home=None):
    """Route arrived sessions onto devices: ``((sid, device), ...)``.

    ``pending`` is ``((sid, scene_id), ...)`` in FIFO order; ``loads`` maps
    device -> current load (active + queued); ``alive`` is the live device
    set.  A scene already homed on an alive device keeps attracting its
    viewers (``scene_home``: scene_id -> device; cache sharing only pays
    on-device); everything else goes to the least-loaded alive device,
    lowest id breaking ties.  Pure and deterministic — same inputs, same
    routing, on any host."""
    alive_l = sorted(alive)
    if not alive_l:
        raise ValueError('plan_route: no alive devices')
    loads = {d: int(loads.get(d, 0)) for d in alive_l}
    out = []
    for sid, scene_id in pending:
        dev = None
        if scene_home:
            home = scene_home.get(scene_id)
            if home in loads:
                dev = home
        if dev is None:
            dev = min(alive_l, key=lambda d: (loads[d], d))
        out.append((sid, dev))
        loads[dev] += 1
    return tuple(out)


def plan_rebalance(assignments, alive, *, slack=1, fixed=None):
    """Even out *movable* load: ``((sid, src, dst), ...)`` moves.

    ``assignments`` maps device -> tuple of movable sids (queue order);
    ``fixed`` maps device -> immovable load (slotted viewers — migrating
    those costs state, queued ones are free to move).  Movable sids
    stranded on dead devices evacuate first; then greedy max->min moves
    run until the load spread is within ``slack`` (>= 1 — a spread of one
    is already balanced for integer loads).  Deterministic (sorted device
    order, LIFO pops), a no-op when balanced, and never targets a device
    outside ``alive``."""
    alive_l = sorted(alive)
    if not alive_l:
        raise ValueError('plan_rebalance: no alive devices')
    slack = max(1, int(slack))
    fixed = {d: int((fixed or {}).get(d, 0)) for d in alive_l}
    movable = {d: list(assignments.get(d, ())) for d in alive_l}
    moves = []

    def load(d):
        return fixed[d] + len(movable[d])

    for dead in sorted(assignments):
        if dead in movable:
            continue
        for sid in assignments[dead]:
            dst = min(alive_l, key=lambda d: (load(d), d))
            movable[dst].append(sid)
            moves.append((sid, dead, dst))
    while True:
        candidates = [d for d in alive_l if movable[d]]
        if not candidates:
            break
        src = max(candidates, key=lambda d: (load(d), -d))
        dst = min(alive_l, key=lambda d: (load(d), d))
        if load(src) - load(dst) <= slack:
            break
        sid = movable[src].pop()
        movable[dst].append(sid)
        moves.append((sid, src, dst))
    return tuple(moves)


def plan_shrink(victims, free, alive):
    """Device-loss placement: ``(aligned, spilled)``.

    ``victims`` is ``((sid, slot), ...)`` from the lost device's checkpoint;
    ``free`` maps alive device -> iterable of free slot indices.  Each
    victim lands on the lowest-id alive device with **the same slot index**
    free (``aligned`` — the only placement whose restored lane replays
    bit-identically: pool ownership and sort-cadence residue are keyed by
    slot index); the rest return as ``spilled`` sids for cold
    re-admission.  Pure and deterministic."""
    alive_l = sorted(alive)
    free = {d: set(free.get(d, ())) for d in alive_l}
    aligned, spilled = [], []
    for sid, slot in victims:
        target = next((d for d in alive_l if slot in free[d]), None)
        if target is None:
            spilled.append(sid)
        else:
            free[target].discard(slot)
            aligned.append((sid, target, slot))
    return tuple(aligned), tuple(spilled)


def viewer_payload_from_state(arrays, meta, slot, viewers_per_scene=1):
    """Build an ``extract_viewer``-format payload for ``slot`` out of a
    checkpointed ``BatchedStepper.state_dict`` — the device is gone, so its
    last crash-consistent snapshot is the source of truth.  Valid for an
    aligned restore only (same slot index, same ``global_tick``; see
    ``BatchedStepper.extract_viewer``)."""
    scene_i = slot // viewers_per_scene
    payload = {
        'priv': jax.tree.map(lambda x: np.asarray(x)[slot], arrays['priv']),
        'cam': jax.tree.map(lambda x: np.asarray(x)[slot],
                            arrays['slot_cams']),
        'frames_since_due': int(meta['frames_since_due'][slot]),
        'pending_sort': slot in set(meta['pending_sort']),
        'shared': None,
        'pool_rows': None,
    }
    if viewers_per_scene == 1:
        payload['shared'] = jax.tree.map(
            lambda x: np.asarray(x)[scene_i], arrays['shared'])
        payload['pool_rows'] = {
            'pool_cell': np.asarray(meta['pool_cell'][scene_i], np.int64),
            'pool_tick': np.asarray(meta['pool_tick'][scene_i], np.int64),
            'pool_owner': np.asarray(meta['pool_owner'][scene_i], np.int64),
            'slot_pool': int(meta['slot_pool'][slot]),
            'refs': np.asarray(meta['refs'][scene_i], np.int64),
        }
    return payload


# -- the fleet ---------------------------------------------------------------

@dataclasses.dataclass
class FleetWorker:
    """One device's serving stack: its own stepper (arrays committed to
    ``device``), its own ``SessionManager`` with a private metrics registry
    (``tick.*`` series are per-manager — sharing one registry across
    workers would interleave their tick streams), and optionally its own
    checkpoint directory."""

    device_id: int
    device: object
    mgr: SessionManager
    ckpt: object = None


class FleetManager:
    """Scene-sharded serving across N device workers (see module docs).

    All mutations happen on the driver's main thread at tick boundaries;
    worker ``run_tick`` legs touch only their own worker's state, which is
    what lets ``ThreadedFleetDriver`` run them concurrently without locks
    or divergence from the sync oracle.
    """

    def __init__(self, workers, *, tracer=None, metrics=None, injector=None,
                 max_pending: Optional[int] = None):
        self.workers = list(workers)
        if not self.workers:
            raise ValueError('fleet needs at least one worker')
        self.tracer = tracer if tracer is not None else obs_trace.NULL
        self.metrics = metrics if metrics is not None else \
            obs_metrics.Registry()
        self.injector = injector if injector is not None else \
            serve_faults.NULL
        self.max_pending = max_pending
        self.alive = {w.device_id for w in self.workers}
        self.tick = 0
        self.pending: deque[ViewerSession] = deque()
        self.shed: list[ViewerSession] = []
        self.sessions: dict[int, ViewerSession] = {}
        self.home: dict[int, int] = {}          # sid -> device
        self.scene_home: dict[int, int] = {}    # scene_id -> device (vps>1)
        #: finished sessions recovered from a lost device's checkpoint meta
        #: (their worker is dead; they are done and must still be counted)
        self.orphan_finished: list[ViewerSession] = []
        self._gauge_alive()

    # -- construction ------------------------------------------------------

    @classmethod
    def build(cls, scene, cfg, cam0, *, num_devices: int,
              slots_per_device: int, viewers_per_scene: int = 1,
              profile_every: int = 0, ckpt_root=None, ckpt_every: int = 0,
              max_pending: Optional[int] = None, injector=None,
              tracer=None, metrics=None, stepper_cls=BatchedStepper):
        """One worker per device (``launch.mesh.serve_devices`` — distinct
        devices when available, oversubscribed on single-device CI).  Each
        stepper is constructed under ``jax.default_device`` so its arrays
        commit to its worker's device."""
        from repro.checkpoint.manager import CheckpointManager
        devices = serve_devices(num_devices)
        workers = []
        for d, dev in enumerate(devices):
            with jax.default_device(dev):
                stepper = stepper_cls(
                    scene, cfg, cam0, slots_per_device,
                    profile_every=profile_every,
                    viewers_per_scene=viewers_per_scene)
            mgr = SessionManager(stepper, slots_per_device,
                                 metrics=obs_metrics.Registry())
            ckpt = None
            if ckpt_root is not None:
                # the manager exists whenever a checkpoint root is named —
                # a restore-only launch (ckpt_every == 0) must still be
                # able to read the previous run's snapshots
                ckpt = CheckpointManager(Path(ckpt_root) / f'device{d}',
                                         metrics=mgr.metrics)
                if ckpt_every > 0:
                    mgr.enable_checkpoints(ckpt, ckpt_every)
            workers.append(FleetWorker(d, dev, mgr, ckpt))
        return cls(workers, tracer=tracer, metrics=metrics,
                   injector=injector, max_pending=max_pending)

    # -- restore at launch -------------------------------------------------

    def restore_at_launch(self, sessions) -> Optional[int]:
        """Restore the whole fleet from its newest *common* snapshot step.

        Lockstep checkpointing normally leaves every worker with the same
        step set, but a kill can land mid-save on one device — so the
        fleet restores to the newest step EVERY worker holds (``max_step``
        threads through ``SessionManager.restore_serving``), keeping the
        resumed state crash-consistent fleet-wide.  Fleet-level placement
        (``home``/``scene_home``) rebuilds from the restored workers;
        sessions absent from every snapshot (accepted after it, or never
        routed) re-queue from frame 0.  Returns the restored fleet tick,
        or None when any worker lacks a usable snapshot (caller decides
        whether that is fatal)."""
        steps = []
        for w in self.workers:
            if w.ckpt is None:
                return None
            w.ckpt.wait()
            steps.append(set(w.ckpt.all_steps()))
        common = set.intersection(*steps)
        if not common:
            return None
        step = max(common)
        self.sessions = {s.sid: s for s in sessions}
        for w in self.workers:
            if w.mgr.restore_serving(w.ckpt, sessions,
                                     max_step=step) is None:
                return None
        ticks = {w.mgr.tick for w in self.workers}
        if len(ticks) != 1:
            raise RuntimeError(f'fleet checkpoints out of sync at restore: '
                               f'ticks {sorted(ticks)}')
        self.tick = ticks.pop()
        vps = max(getattr(w.mgr.stepper, 'viewers_per_scene', 1)
                  for w in self.workers)
        self.home = {}
        self.scene_home = {}
        placed = set()
        for w in self.workers:
            for sess in w.mgr.slot_session:
                if sess is None:
                    continue
                self.home[sess.sid] = w.device_id
                placed.add(sess.sid)
                if vps > 1:
                    self.scene_home.setdefault(sess.scene_id, w.device_id)
            for lst in w.mgr._coresidents.values():
                for sess in lst:
                    self.home[sess.sid] = w.device_id
                    placed.add(sess.sid)
            for sess in w.mgr.pending:
                self.home[sess.sid] = w.device_id
                placed.add(sess.sid)
            placed |= {s.sid for s in w.mgr.finished}
            placed |= {s.sid for s in w.mgr.shed}
        requeue = [self.sessions[sid] for sid in sorted(self.sessions)
                   if sid not in placed]
        for sess in requeue:
            sess.cursor = 0
            sess.telemetry.rollback(0)
            sess.telemetry.admitted_tick = -1
        self.pending = deque(sorted(requeue,
                                    key=lambda s: (s.arrival_tick, s.sid)))
        self.metrics.counter('fleet.restores',
                             'fleet runs resumed from checkpoints').inc()
        self.tracer.instant('fleet_restore', tick=self.tick, step=step)
        return self.tick

    # -- admission ---------------------------------------------------------

    def submit(self, session: ViewerSession) -> bool:
        """Bounded fleet-level admission: beyond ``max_pending`` queued
        sessions the arrival is shed (recorded + counted), never silently
        dropped — degraded capacity sheds NEW load; accepted viewers always
        drain."""
        if self.max_pending is not None \
                and len(self.pending) >= self.max_pending:
            self.shed.append(session)
            self.metrics.counter(
                'fleet.shed',
                'arrivals rejected by the bounded fleet queue').inc()
            return False
        self.pending.append(session)
        self.sessions[session.sid] = session
        self.metrics.gauge('fleet.pending_depth',
                           'fleet admission queue depth').set(
                               len(self.pending))
        return True

    # -- tick legs (shared by both fleet drivers) --------------------------

    def alive_workers(self) -> list[FleetWorker]:
        return [w for w in self.workers if w.device_id in self.alive]

    def _check_device_loss(self) -> None:
        """Consume a pending ``device_loss`` event at the tick boundary."""
        if not self.injector.enabled:
            return
        ev = self.injector.take('device_loss', self.tick)
        if ev is None:
            return
        victim = ev.slot if ev.slot in self.alive else max(self.alive)
        if len(self.alive) <= 1:
            warnings.warn(
                f'device_loss at tick {self.tick} ignored: device '
                f'{victim} is the last alive device (a real loss here is '
                f'a total outage, not a shrink)', RuntimeWarning,
                stacklevel=2)
            self.metrics.counter(
                'fleet.device_loss_ignored',
                'loss events on the last alive device').inc()
            return
        self.lose_device(victim)

    def _route_tick(self) -> None:
        """Route arrived queued sessions onto alive workers."""
        arrived = [s for s in self.pending if s.arrival_tick <= self.tick]
        if not arrived:
            return
        vps = max(getattr(w.mgr.stepper, 'viewers_per_scene', 1)
                  for w in self.workers)
        # resident_count (not occupied-slot count): an oversubscribed slot
        # carries several paced viewers and weighs as all of them
        loads = {w.device_id: w.mgr.resident_count() + len(w.mgr.pending)
                 for w in self.alive_workers()}
        routes = plan_route(
            tuple((s.sid, s.scene_id) for s in arrived), loads, self.alive,
            scene_home=self.scene_home if vps > 1 else None)
        by_sid = {s.sid: s for s in arrived}
        for sid, dev in routes:
            sess = by_sid[sid]
            self.pending.remove(sess)
            self.workers[dev].mgr.submit(sess)
            self.home[sid] = dev
            if vps > 1:
                self.scene_home.setdefault(sess.scene_id, dev)
            self.metrics.counter('fleet.routed',
                                 'sessions routed to a device worker',
                                 device=dev).inc()
        self.metrics.gauge('fleet.pending_depth',
                           'fleet admission queue depth').set(
                               len(self.pending))

    def _worker_tick(self, w: FleetWorker) -> int:
        """One worker's tick leg: run, evict, and keep the stepper clock in
        lockstep (idle ticks advance ``global_tick`` too — the fleet-wide
        shared sort-cadence clock that slot-aligned moves rely on)."""
        frames = w.mgr.run_tick()
        stepper = w.mgr.stepper
        if getattr(stepper, 'global_tick', w.mgr.tick) < w.mgr.tick:
            stepper.global_tick = w.mgr.tick
        w.mgr.evict_finished()
        return frames

    def _after_tick(self) -> None:
        self.tick += 1
        for w in self.alive_workers():
            w.mgr.maybe_checkpoint()

    def run_tick(self) -> int:
        """One synchronous fleet tick (the virtual N-device oracle leg)."""
        self._check_device_loss()
        self._route_tick()
        frames = 0
        for w in self.alive_workers():
            frames += self._worker_tick(w)
        self._after_tick()
        return frames

    # -- live migration ----------------------------------------------------

    def migrate(self, sid: int, dst: int) -> Optional[int]:
        """Move one slotted viewer to device ``dst`` at a tick boundary.

        Slot-aligned moves (the same slot index is free on ``dst``, private
        scene blocks) carry the whole scene lane — bit-identical
        continuation.  Otherwise the viewer restores cold into the lowest
        free slot and re-sorts on admission (at most one sort-window of
        staleness).  With no free slot on ``dst`` the viewer re-queues on
        the fleet with its cursor preserved.  Returns the destination slot,
        or None when re-queued."""
        if dst not in self.alive:
            raise ValueError(f'migrate: device {dst} is not alive')
        src = self.home.get(sid)
        if src is None or src not in self.alive:
            raise ValueError(f'migrate: sid {sid} has no alive home device')
        if src == dst:
            raise ValueError(f'migrate: sid {sid} already on device {dst}')
        sw, dw = self.workers[src], self.workers[dst]
        slot = next((i for i, s in enumerate(sw.mgr.slot_session)
                     if s is not None and s.sid == sid), None)
        if slot is None:
            raise ValueError(f'migrate: sid {sid} is not slotted on '
                             f'device {src}')
        if getattr(sw.mgr, '_coresidents', {}).get(slot):
            raise ValueError(
                f'migrate: slot {slot} on device {src} is oversubscribed — '
                f'stashed co-residents cannot follow a single-viewer move')
        free = dw.mgr.free_slots()
        if not free:
            sess = sw.mgr.vacate(slot)
            sess.telemetry.admitted_tick = -1
            self.pending.append(sess)
            self.home.pop(sid, None)
            self.metrics.counter('fleet.migrations',
                                 'viewer moves between devices',
                                 kind='requeued').inc()
            return None
        vps1 = getattr(sw.mgr.stepper, 'viewers_per_scene', 1) == 1
        aligned = vps1 and slot in free
        payload = sw.mgr.stepper.extract_viewer(slot, with_scene=aligned)
        sess = sw.mgr.vacate(slot)
        target = slot if aligned else free[0]
        dw.mgr.place(target, sess, payload=payload,
                     admitted_tick=sess.telemetry.admitted_tick)
        self.home[sid] = dst
        self.metrics.counter('fleet.migrations',
                             'viewer moves between devices',
                             kind='aligned' if aligned else 'cold').inc()
        return target

    # -- device loss -------------------------------------------------------

    def lose_device(self, victim: int) -> None:
        """Shrink the fleet: mark ``victim`` dead and migrate every session
        off it (checkpoint rollback when available, cold re-queue
        otherwise).  Zero dropped viewers either way."""
        if victim not in self.alive:
            raise ValueError(f'device {victim} is not alive')
        if len(self.alive) <= 1:
            raise ValueError('cannot lose the last alive device')
        vw = self.workers[victim]
        self.alive.discard(victim)
        self.metrics.counter('fleet.device_lost',
                             'devices dropped from the fleet',
                             device=victim).inc()
        self.tracer.instant('device_loss', device=victim, tick=self.tick)
        with self.tracer.span('device_recovery', device=victim,
                              tick=self.tick):
            if vw.ckpt is not None and vw.ckpt.latest() is not None:
                self._recover_from_checkpoint(vw)
            else:
                self._recover_cold(vw)
        self._gauge_alive()

    def _gauge_alive(self) -> None:
        self.metrics.gauge('fleet.alive_devices',
                           'devices currently serving').set(len(self.alive))

    def _recover_cold(self, vw: FleetWorker) -> None:
        """No checkpoint: host-side cursors are crash-consistent in-process
        (every delivered frame advanced them before the loss), so victims
        re-queue at their current frame and re-admit cold on survivors.
        Rendered frames are never re-rendered; the viewers just lose their
        warm caches."""
        mgr = vw.mgr
        victims = [mgr.vacate(slot) for slot in mgr.active_slots()]
        victims.extend(mgr.pending)
        mgr.pending.clear()
        self.orphan_finished.extend(mgr.finished)
        mgr.finished = []
        for sess in sorted(victims, key=lambda s: (s.arrival_tick, s.sid)):
            sess.telemetry.admitted_tick = -1
            self.home.pop(sess.sid, None)
            self.pending.append(sess)
        self.scene_home = {sc: d for sc, d in self.scene_home.items()
                           if d != vw.device_id}
        self.metrics.counter('fleet.requeued',
                             'sessions re-queued off a lost device').inc(
                                 len(victims))

    def _recover_from_checkpoint(self, vw: FleetWorker) -> None:
        """Whole-fleet rollback to the last crash-consistent snapshot.

        All workers checkpoint at the same tick multiples under the
        lockstep clock, so the newest per-device checkpoints form one
        consistent fleet state.  Survivors restore their own snapshots
        (bit-identical per-worker resume); the victim's snapshot is read
        host-side and its viewers shrink onto survivors via
        ``plan_shrink``.  Replay from the snapshot is at-least-once
        delivery — telemetry rolls back so nothing double-counts."""
        for w in self.workers:
            if w.ckpt is not None:
                w.ckpt.wait()
        all_sessions = list(self.sessions.values())
        survivors = self.alive_workers()
        ticks = set()
        for w in survivors:
            step = w.mgr.restore_serving(w.ckpt, all_sessions)
            if step is None:
                raise RuntimeError(
                    f'device {w.device_id} has no usable checkpoint — '
                    f'fleet snapshots are taken in lockstep, so this is '
                    f'checkpoint corruption, not a race')
            ticks.add(w.mgr.tick)
        if len(ticks) != 1:
            raise RuntimeError(f'fleet checkpoints out of sync: restored '
                               f'ticks {sorted(ticks)}')
        restore_tick = ticks.pop()
        for w in survivors:
            # rolled-back frames will replay: truncate per-session frame
            # telemetry to the restored cursors and drop post-snapshot tick
            # log entries (restore_serving leaves pending cursors alone —
            # a PR-7 fresh-process restore never needed the fix-up, an
            # in-process rollback does)
            for sess in w.mgr.slot_session:
                if sess is not None:
                    sess.telemetry.rollback(sess.cursor)
            for lst in w.mgr._coresidents.values():
                for sess in lst:
                    sess.telemetry.rollback(sess.cursor)
            for sess in w.mgr.pending:
                sess.cursor = 0
                sess.telemetry.rollback(0)
                sess.telemetry.admitted_tick = -1
            w.mgr.tick_log = [t for t in w.mgr.tick_log
                              if t['tick'] < restore_tick]

        # the victim's snapshot, read host-side (per-step shape template:
        # the snapshot's pool capacity is part of its geometry)
        out = vw.mgr._restore_arrays(vw.ckpt)
        if out is None:
            raise RuntimeError(f'device {vw.device_id}: checkpoint '
                               f'vanished between latest() and restore')
        arrays, _step, meta = out
        if int(meta['tick']) != restore_tick:
            raise RuntimeError(
                f'victim checkpoint tick {meta["tick"]} != fleet restore '
                f'tick {restore_tick}')
        vps = getattr(vw.mgr.stepper, 'viewers_per_scene', 1)
        slotted = [(m['sid'], slot, int(m['cursor']),
                    int(m['admitted_tick']))
                   for slot, m in enumerate(meta['slots']) if m is not None]
        info = {sid: (cursor, adm) for sid, _, cursor, adm in slotted}
        free = {w.device_id: tuple(w.mgr.free_slots()) for w in survivors}
        aligned, spilled = plan_shrink(
            tuple((sid, slot) for sid, slot, _, _ in slotted), free,
            self.alive)
        for sid, dev, slot in aligned:
            sess = self.sessions[sid]
            cursor, adm = info[sid]
            sess.cursor = cursor
            sess.telemetry.rollback(cursor)
            payload = viewer_payload_from_state(
                arrays, meta['stepper'], slot, viewers_per_scene=vps)
            self.workers[dev].mgr.place(slot, sess, payload=payload,
                                        admitted_tick=adm)
            self.home[sid] = dev
            self.metrics.counter('fleet.migrations',
                                 'viewer moves between devices',
                                 kind='loss_aligned').inc()
        requeue = []
        for sid in spilled:
            sess = self.sessions[sid]
            cursor, _adm = info[sid]
            sess.cursor = cursor
            sess.telemetry.rollback(cursor)
            sess.telemetry.admitted_tick = -1
            self.home.pop(sid, None)
            requeue.append(sess)
            self.metrics.counter('fleet.migrations',
                                 'viewer moves between devices',
                                 kind='loss_spilled').inc()
        # stashed co-residents of the victim's oversubscribed slots restore
        # cold onto the fleet queue with their cursors preserved — their
        # lane context died with the device, but not their progress
        for lst in meta.get('coresidents', {}).values():
            for m in lst:
                sess = self.sessions[m['sid']]
                sess.cursor = int(m['cursor'])
                sess.telemetry.rollback(sess.cursor)
                sess.telemetry.admitted_tick = -1
                self.home.pop(m['sid'], None)
                requeue.append(sess)
                self.metrics.counter('fleet.migrations',
                                     'viewer moves between devices',
                                     kind='loss_spilled').inc()
        for sid in meta['pending']:
            sess = self.sessions[sid]
            sess.cursor = 0
            sess.telemetry.rollback(0)
            sess.telemetry.admitted_tick = -1
            self.home.pop(sid, None)
            requeue.append(sess)
        for sid in meta['finished']:
            sess = self.sessions[sid]
            sess.cursor = len(sess.cams)
            self.orphan_finished.append(sess)
        # the victim's live (post-snapshot) state is dead with the device
        vw.mgr.slot_session = [None] * vw.mgr.slots
        vw.mgr._coresidents = {}
        vw.mgr.pending.clear()
        vw.mgr.finished = []
        vw.mgr.tick_log = [t for t in vw.mgr.tick_log
                           if t['tick'] < restore_tick]
        self.scene_home = {sc: d for sc, d in self.scene_home.items()
                           if d != vw.device_id}

        # reconcile: sessions accepted after the snapshot are nowhere in
        # the restored state — they restart from frame 0
        placed = {s.sid for s in self.orphan_finished}
        placed |= {s.sid for s in requeue}
        placed |= {s.sid for s in self.pending}
        placed |= {s.sid for s in self.shed}
        for w in survivors:
            placed |= {s.sid for s in w.mgr.slot_session if s is not None}
            placed |= {s.sid for lst in w.mgr._coresidents.values()
                       for s in lst}
            placed |= {s.sid for s in w.mgr.pending}
            placed |= {s.sid for s in w.mgr.finished}
        for sid in sorted(self.sessions):
            if sid in placed:
                continue
            sess = self.sessions[sid]
            sess.cursor = 0
            sess.telemetry.rollback(0)
            sess.telemetry.admitted_tick = -1
            self.home.pop(sid, None)
            requeue.append(sess)
        merged = list(self.pending) + requeue
        self.pending = deque(sorted(merged,
                                    key=lambda s: (s.arrival_tick, s.sid)))
        self.metrics.counter('fleet.requeued',
                             'sessions re-queued off a lost device').inc(
                                 len(requeue))
        self.tick = restore_tick

    # -- draining / results ------------------------------------------------

    def drained(self) -> bool:
        return (not self.pending
                and all(w.mgr.drained() for w in self.alive_workers()))

    def finished_sessions(self) -> list[ViewerSession]:
        out = list(self.orphan_finished)
        for w in self.workers:
            out.extend(w.mgr.finished)
        return sorted(out, key=lambda s: s.sid)

    def summaries(self) -> list[dict]:
        return [s.telemetry.summary() for s in self.finished_sessions()]

    def aggregate(self) -> dict:
        agg = serve_telemetry.aggregate(self.summaries())
        agg['devices'] = len(self.workers)
        agg['alive_devices'] = len(self.alive)
        agg['shed'] = len(self.shed)
        return agg

    def merged_tick_log(self) -> list[dict]:
        """All workers' tick logs in tick order (ticks repeat across
        workers — and, after a rollback, replayed ranges repeat in time;
        per-frame percentiles over the merged log are at-least-once
        accounting, consistent with the replayed frames)."""
        log = []
        for w in self.workers:
            log.extend(w.mgr.tick_log)
        return sorted(log, key=lambda t: t['tick'])


# -- fleet drivers -----------------------------------------------------------

class SyncFleetDriver:
    """The virtual N-device oracle: workers tick sequentially in device
    order on a pure tick counter.  Bit-identical trace replay — the
    conformance baseline ``ThreadedFleetDriver`` is judged against."""

    def __init__(self, fleet: FleetManager):
        self.fleet = fleet

    def run_tick(self) -> int:
        return self.fleet.run_tick()

    def run(self, max_ticks: int = 100_000) -> list[ViewerSession]:
        fleet = self.fleet
        while not fleet.drained():
            self.run_tick()
            if fleet.tick >= max_ticks:
                raise RuntimeError('fleet serve loop did not drain')
        return fleet.finished_sessions()


class ThreadedFleetDriver:
    """Real-time fleet driver: one persistent thread per worker, barrier at
    every tick boundary.

    Main-thread loop per fleet tick::

        _check_device_loss()        # consume device_loss, maybe shrink
        _route_tick()               # fleet queue -> worker queues
        cmd[w].put(tick)            # alive workers tick concurrently
        barrier: done[w].get()      # collect frames + wall time per worker
        straggler.observe_step(...) # EWMA per device; optional exclusion
        _after_tick()               # clock + lockstep checkpoints

    Workers touch disjoint state and run the same ``run_tick`` code as the
    sync oracle, and every fleet-level decision happens between barriers on
    the main thread — so control flow (and therefore images, cache tags,
    sort cadence) is bit-identical to ``SyncFleetDriver``; only wall-clock
    telemetry differs.  ``exclude_stragglers=True`` trades that determinism
    for availability: a device flagged by the ``StragglerDetector``
    (threshold x fleet-median EWMA, ``patience`` consecutive slow ticks)
    is dropped via ``lose_device`` at the next boundary."""

    JOIN_TIMEOUT_S = 5.0

    def __init__(self, fleet: FleetManager, *,
                 exclude_stragglers: bool = False,
                 straggler_threshold: float = 1.25,
                 straggler_patience: int = 3,
                 watchdog_s: Optional[float] = None):
        self.fleet = fleet
        self.exclude_stragglers = exclude_stragglers
        self.detector = StragglerDetector(
            len(fleet.workers), threshold=straggler_threshold,
            patience=straggler_patience, metrics=fleet.metrics)
        self.watchdog_s = watchdog_s if watchdog_s is not None \
            else SessionManager.default_watchdog_s
        self._cmd: dict[int, queue.Queue] = {}
        self._done: dict[int, queue.Queue] = {}
        self._threads: dict[int, threading.Thread] = {}

    # -- worker lifecycle --------------------------------------------------

    def _start(self) -> None:
        for w in self.fleet.workers:
            cmd: queue.Queue = queue.Queue()
            done: queue.Queue = queue.Queue()

            def loop(w=w, cmd=cmd, done=done):
                while True:
                    msg = cmd.get()
                    if msg is None:
                        return
                    t0 = time.perf_counter()
                    try:
                        frames = self.fleet._worker_tick(w)
                        done.put(('ok', frames,
                                  time.perf_counter() - t0))
                    except BaseException as exc:
                        done.put(('error', exc,
                                  time.perf_counter() - t0))

            th = threading.Thread(
                target=loop, name=f'fleet-worker-{w.device_id}',
                daemon=True)
            th.start()
            self._cmd[w.device_id] = cmd
            self._done[w.device_id] = done
            self._threads[w.device_id] = th

    def _stop(self) -> None:
        for d, cmd in self._cmd.items():
            cmd.put(None)
        for d, th in self._threads.items():
            th.join(timeout=self.JOIN_TIMEOUT_S)
            if th.is_alive():
                self.fleet.metrics.counter(
                    'serve.thread_leaks',
                    'planner threads alive past their join deadline').inc()
                warnings.warn(f'{th.name} did not exit within '
                              f'{self.JOIN_TIMEOUT_S}s; daemon thread '
                              f'leaked', RuntimeWarning, stacklevel=2)
        self._cmd, self._done, self._threads = {}, {}, {}

    # -- the loop ----------------------------------------------------------

    def run_tick(self) -> int:
        fleet = self.fleet
        fleet._check_device_loss()
        fleet._route_tick()
        alive = fleet.alive_workers()
        for w in alive:
            self._cmd[w.device_id].put(fleet.tick)
        frames = 0
        timings: dict[int, float] = {}
        failures = []
        for w in alive:
            try:
                kind, payload, dt = self._done[w.device_id].get(
                    timeout=self.watchdog_s)
            except queue.Empty:
                raise RuntimeError(
                    f'fleet watchdog: device {w.device_id} posted no tick '
                    f'completion within {self.watchdog_s}s') from None
            if kind == 'error':
                failures.append((w.device_id, payload))
                continue
            frames += payload
            timings[w.device_id] = dt
        if failures:
            dev, exc = failures[0]
            raise RuntimeError(
                f'fleet worker {dev} failed at tick {fleet.tick}') from exc
        flagged = self.detector.observe_step(timings)
        if self.exclude_stragglers:
            for dev in sorted(flagged):
                if dev in fleet.alive and len(fleet.alive) > 1:
                    warnings.warn(
                        f'excluding straggler device {dev} at tick '
                        f'{fleet.tick}', RuntimeWarning, stacklevel=2)
                    fleet.lose_device(dev)
        fleet._after_tick()
        return frames

    def run(self, max_ticks: int = 100_000) -> list[ViewerSession]:
        fleet = self.fleet
        self._start()
        try:
            while not fleet.drained():
                self.run_tick()
                if fleet.tick >= max_ticks:
                    raise RuntimeError('fleet serve loop did not drain')
        finally:
            self._stop()
        return fleet.finished_sessions()


FLEET_DRIVERS = {'sync': SyncFleetDriver, 'threaded': ThreadedFleetDriver}


def get_fleet_driver(name: str, fleet: FleetManager, **kw):
    try:
        return FLEET_DRIVERS[name](fleet, **kw)
    except KeyError:
        raise ValueError(f'unknown fleet driver {name!r} '
                         f'(expected one of {sorted(FLEET_DRIVERS)})') \
            from None


def serve_fleet(scene, cfg, cam0, sessions, *, num_devices: int,
                slots_per_device: int, driver: str = 'sync',
                viewers_per_scene: int = 1, profile_every: int = 0,
                ckpt_root=None, ckpt_every: int = 0, restore: bool = False,
                max_pending: Optional[int] = None, injector=None,
                tracer=None, max_ticks: int = 100_000,
                **driver_kw) -> tuple:
    """Build a fleet, submit ``sessions``, drive it to drain.

    ``restore=True`` resumes from the newest fleet-consistent snapshot
    under ``ckpt_root`` (``FleetManager.restore_at_launch``) instead of
    starting cold — and fails fast with ``SystemExit`` when no usable
    snapshot exists, because silently starting over is exactly the bug
    this flag guards against.  The restored tick lands on
    ``fleet.restored_tick`` (None for a cold start).

    Returns ``(fleet, finished_sessions)``; end-of-run fault accounting
    (``serve.faults_unfired``) runs against the fleet registry."""
    fleet = FleetManager.build(
        scene, cfg, cam0, num_devices=num_devices,
        slots_per_device=slots_per_device,
        viewers_per_scene=viewers_per_scene, profile_every=profile_every,
        ckpt_root=ckpt_root, ckpt_every=ckpt_every,
        max_pending=max_pending, injector=injector, tracer=tracer)
    fleet.restored_tick = None
    if restore:
        if ckpt_root is None:
            raise SystemExit('--restore with --devices > 1 needs '
                             '--checkpoint-dir (the fleet restores from '
                             'per-device lockstep snapshots)')
        restored = fleet.restore_at_launch(sessions)
        if restored is None:
            raise SystemExit(
                f'--restore: no usable fleet checkpoint under {ckpt_root} '
                f'(every device worker needs a complete snapshot at a '
                f'common step)')
        fleet.restored_tick = restored
    else:
        for sess in sessions:
            fleet.submit(sess)
    drv = get_fleet_driver(driver, fleet, **driver_kw)
    finished = drv.run(max_ticks)
    for w in fleet.workers:
        if w.ckpt is not None:
            w.ckpt.wait()
    if fleet.injector.enabled:
        serve_faults.account_unfired(fleet.injector, fleet.metrics)
    return fleet, finished
