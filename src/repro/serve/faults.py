"""Deterministic fault injection for the serving host loop.

A **fault trace** is the chaos-engineering twin of a traffic trace
(``repro.serve.traffic``): a seeded, replayable schedule of failures the
host loop must survive, expressed as plain integers/floats that round-trip
through ``to_dict``/``from_dict`` — record one observed incident, replay it
bit-identically through either driver, and regression-test the recovery
path forever.

Fault kinds (the taxonomy README's "Failure model & recovery" documents):

  * ``plan_exc``            — ``plan_tick`` raises (a planner bug / transient
    host error).  On the threaded driver this lands on the worker thread —
    the pre-hardening behavior was to re-raise on the main thread and kill
    every viewer.
  * ``dispatch_transient``  — the device dispatch fails ``count`` times
    before succeeding (driver reset, transient allocator failure);
    recovered by retry-with-backoff.
  * ``dispatch_persistent`` — the dispatch keeps failing past the retry
    budget; the tick is shed (no cursor advances, so every due frame is
    replanned next tick) and the loop keeps serving.
  * ``stall``               — the device hangs for ``delay_s`` inside
    ``step_finish``; the finish watchdog surfaces it.
  * ``nan_poison``          — one slot's finished shade output is replaced
    with NaNs (the corrupted-device-result scenario).  Containment is a
    separate, independent mechanism: the host's finite scan drops the frame
    and quarantines the slot, and the ``jnp.isfinite`` insert gate
    (``repro.core.radiance_cache``) keeps non-finite rgb out of the shared
    scene cache no matter how corruption arises.
  * ``worker_death``        — the threaded driver's planner worker dies
    without posting a completion; the main loop's bounded queue get times
    out, plans inline (degraded mode) and restarts the worker.
  * ``device_loss``         — an entire device drops out of the serving
    fleet (``ev.slot`` is the device index; -1 = the highest-numbered
    alive device).  Only the fleet drivers (``repro.serve.fleet``) consume
    it: the lost device's scene blocks are migrated onto survivors from
    the last crash-consistent checkpoint and admission stays bounded while
    capacity is degraded.  Single-device drivers leave it outstanding.

The **injector** follows the NULL-object seam of ``repro.obs.trace``: the
manager holds ``faults.NULL`` by default — every check is a cheap attribute
test + no-op, the unfaulted hot path is untouched, and the fault layer is
exercised (disabled) by every existing conformance test.  Events are
consumed **one-shot** (``take``) and recorded in ``fired``, so a test can
assert the emitted ``serve.faults{kind=...}`` counters match the injected
trace exactly.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

KINDS = ('plan_exc', 'dispatch_transient', 'dispatch_persistent', 'stall',
         'nan_poison', 'worker_death', 'device_loss')


class InjectedFault(RuntimeError):
    """Base class of all injected failures (never raised by real code)."""


class InjectedPlanError(InjectedFault):
    """An injected ``plan_tick`` exception."""


class InjectedDispatchError(InjectedFault):
    """An injected device-dispatch failure (one attempt)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled failure.

    ``tick``    : manager tick the event arms at (it fires at the first
                  opportunity at or after this tick — a dispatch fault on an
                  idle tick waits for the next dispatch)
    ``kind``    : one of ``KINDS``
    ``slot``    : preferred target slot for ``nan_poison`` (-1 = lowest
                  slot rendering that tick — see
                  ``FaultInjector.poison_slot``)
    ``count``   : failed attempts for ``dispatch_transient``
    ``delay_s`` : injected device delay for ``stall``
    """

    tick: int
    kind: str
    slot: int = -1
    count: int = 1
    delay_s: float = 0.05

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f'unknown fault kind {self.kind!r} '
                             f'(expected one of {KINDS})')

    def to_dict(self) -> dict:
        return {'tick': self.tick, 'kind': self.kind, 'slot': self.slot,
                'count': self.count, 'delay_s': self.delay_s}

    @classmethod
    def from_dict(cls, d: dict) -> 'FaultEvent':
        return cls(tick=int(d['tick']), kind=str(d['kind']),
                   slot=int(d.get('slot', -1)), count=int(d.get('count', 1)),
                   delay_s=float(d.get('delay_s', 0.05)))


@dataclasses.dataclass(frozen=True)
class FaultTrace:
    """A replayable failure schedule: ``events`` sorted by (tick, kind)."""

    seed: int
    events: tuple

    def to_dict(self) -> dict:
        return {'seed': self.seed,
                'events': [e.to_dict() for e in self.events]}

    @classmethod
    def from_dict(cls, d: dict) -> 'FaultTrace':
        return cls(seed=int(d['seed']),
                   events=tuple(FaultEvent.from_dict(e)
                                for e in d['events']))

    def counts(self) -> dict:
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out


def make_trace(kinds, ticks: int, *, seed: int = 0, rate: float = 0.05,
               slots: int = 1, stall_s: float = 0.05,
               transient_count: int = 1) -> FaultTrace:
    """Generate a deterministic fault trace: per tick and per kind an
    independent Bernoulli(``rate``) draw, everything from
    ``np.random.default_rng(seed)`` — same arguments, same trace, always.
    """
    kinds = tuple(kinds)
    for k in kinds:
        if k not in KINDS:
            raise ValueError(f'unknown fault kind {k!r} '
                             f'(expected one of {KINDS})')
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f'fault rate must be in [0, 1], got {rate}')
    rng = np.random.default_rng(seed)
    events = []
    for tick in range(ticks):
        for kind in kinds:
            if rng.random() >= rate:
                continue
            events.append(FaultEvent(
                tick=tick, kind=kind,
                slot=int(rng.integers(0, max(1, slots))),
                count=transient_count, delay_s=stall_s))
    return FaultTrace(seed=seed, events=tuple(events))


class FaultInjector:
    """Consumes a ``FaultTrace`` against the live host loop.

    Events of each kind queue in tick order; ``take(kind, tick)`` pops the
    next one armed at or before ``tick`` (one-shot — a consumed event never
    fires again) and appends it to ``fired``.  Deferred firing is the
    contract: a dispatch fault armed on an idle tick fires at the next
    dispatch, a poison event with no eligible (non-leader) slot waits for
    the next tick with one — so ``fired`` converges on the full trace for
    any run long enough, and counters can be matched exactly.
    """

    enabled = True

    def __init__(self, trace: FaultTrace):
        self.trace = trace
        self._pending: dict[str, deque] = {k: deque() for k in KINDS}
        for ev in sorted(trace.events, key=lambda e: e.tick):
            self._pending[ev.kind].append(ev)
        self.fired: list[FaultEvent] = []

    def take(self, kind: str, tick: int):
        """Pop (and record) the next ``kind`` event armed at or before
        ``tick``, or None."""
        q = self._pending[kind]
        if q and q[0].tick <= tick:
            ev = q.popleft()
            self.fired.append(ev)
            return ev
        return None

    def peek(self, kind: str, tick: int) -> bool:
        q = self._pending[kind]
        return bool(q) and q[0].tick <= tick

    def fired_counts(self) -> dict:
        out: dict[str, int] = {}
        for e in self.fired:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def outstanding(self) -> dict:
        """Armed-but-unfired events per kind (drivers that never reach an
        event's seam — e.g. ``worker_death`` on the sync driver — leave it
        outstanding; tests account for these explicitly)."""
        return {k: len(q) for k, q in self._pending.items() if q}

    @staticmethod
    def poison_slot(ev: FaultEvent, eligible) -> int:
        """The slot a poison event lands on: its preferred ``slot`` if
        eligible, else the lowest eligible slot (callers pass the slots
        that actually produced an output this tick)."""
        eligible = sorted(eligible)
        return ev.slot if ev.slot in eligible else eligible[0]


def poison_camera(cam):
    """A copy of ``cam`` with every floating leaf replaced by NaN.  Not
    used for ``nan_poison`` injection — a NaN pose demonstrably yields a
    *finite* background image (every NaN comparison fails, nothing
    rasterizes) — but kept as a test utility: it drives NaN through the
    real jitted shade to pin down that the ``jnp.isfinite`` insert gate
    holds on the genuine render path.  Static fields (width/height) are
    part of the treedef and untouched."""
    def leaf(x):
        x = jnp.asarray(x)
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.full_like(x, jnp.nan)
        return x
    return jax.tree.map(leaf, cam)


def account_unfired(injector, metrics=None) -> dict:
    """End-of-run accounting for events that never fired.

    An armed-but-unfired event usually means the run ended before the
    event's seam was reached (a short trace), the driver has no such seam
    (``worker_death`` on the sync driver), or — the case worth an alarm —
    the injection wiring silently rotted.  Surface the residue instead of
    dropping it: one ``RuntimeWarning`` summarising the counts and a
    ``serve.faults_unfired{kind=...}`` counter per kind on ``metrics``
    (a ``repro.obs.metrics.Registry``; None skips the counters).

    Returns the ``outstanding()`` dict so CLI summaries can print it.
    """
    left = injector.outstanding()
    if left:
        detail = ', '.join(f'{k}={n}' for k, n in sorted(left.items()))
        warnings.warn(
            f'fault trace finished with unfired events: {detail} '
            f'(driver never reached their seam — see FaultInjector docs)',
            RuntimeWarning, stacklevel=2)
        if metrics is not None:
            for kind, n in sorted(left.items()):
                metrics.counter('serve.faults_unfired', kind=kind).inc(n)
    return left


class _NullInjector:
    """No-op injector (the default): ``enabled`` is False and every check
    short-circuits, so the unfaulted hot path never pays for the fault
    layer — the same seam pattern as ``repro.obs.trace.NULL``."""

    enabled = False
    fired = ()

    def take(self, kind, tick):
        return None

    def peek(self, kind, tick):
        return False

    def fired_counts(self):
        return {}

    def outstanding(self):
        return {}


NULL = _NullInjector()
