"""Per-session render telemetry for the multi-viewer server.

Each viewer session accumulates per-frame observations (wall-clock latency of
the batched tick it rode in, split into the tick's **sort-phase** and
**shade-phase** wall time, radiance-cache hit rate, whether its slot ran a
speculative sort) and summarises them into the numbers an operator watches:
frames/sec, mean hit rate, p50/p99 frame latency, the realised sort cadence
(sorts per frame; 1/window when S^2 is keeping up — this counts sort
*refreshes the viewer consumed*, scheduled or adopted from a pose-cell
leader, so it stays ~1/window even when scene-sharing means far fewer
sorts *executed*; the executed count lives in the tick rollup) and mean
per-phase cost.
The per-tick sorted-slot counts live on ``SessionManager.tick_log`` — see
``tick_rollup`` for the fleet-level view the cohort scheduler is judged by
(max sorted slots per tick <= ceil(S/window) after warmup).
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np


@dataclasses.dataclass
class SessionTelemetry:
    """Accumulated per-frame observations for one viewer session."""

    sid: int
    arrival_tick: int = 0
    admitted_tick: int = -1
    finished_tick: int = -1
    latencies_s: list = dataclasses.field(default_factory=list)
    hit_rates: list = dataclasses.field(default_factory=list)
    saved_fracs: list = dataclasses.field(default_factory=list)
    sorted_flags: list = dataclasses.field(default_factory=list)
    sort_mss: list = dataclasses.field(default_factory=list)
    shade_mss: list = dataclasses.field(default_factory=list)

    def observe_frame(self, latency_s: float, hit_rate: float,
                      saved_frac: float, sorted_flag: float,
                      sort_ms: float = 0.0,
                      shade_ms: float | None = None) -> None:
        """``sort_ms``/``shade_ms`` attribute the tick's latency to its two
        phases; ``shade_ms`` defaults to the whole tick when the engine
        cannot split (the monolithic sequential reference)."""
        self.latencies_s.append(float(latency_s))
        self.hit_rates.append(float(hit_rate))
        self.saved_fracs.append(float(saved_frac))
        self.sorted_flags.append(float(sorted_flag))
        self.sort_mss.append(float(sort_ms))
        self.shade_mss.append(float(latency_s * 1e3 if shade_ms is None
                                    else shade_ms))

    @property
    def frames(self) -> int:
        return len(self.latencies_s)

    def rollback(self, frames: int) -> None:
        """Truncate to the first ``frames`` observations — the fleet's
        device-loss recovery rolls sessions back to a checkpoint cursor and
        *replays* the tail, so without truncation every replayed frame
        would be double-counted.  Also clears ``finished_tick``: a rolled-
        back session is live again."""
        frames = max(0, int(frames))
        for name in ('latencies_s', 'hit_rates', 'saved_fracs',
                     'sorted_flags', 'sort_mss', 'shade_mss'):
            del getattr(self, name)[frames:]
        self.finished_tick = -1

    def summary(self) -> dict:
        lat = np.asarray(self.latencies_s, np.float64)
        wall = float(lat.sum())
        queue_ticks = (self.admitted_tick - self.arrival_tick
                       if self.admitted_tick >= 0 else -1)
        return {
            'sid': self.sid,
            'frames': self.frames,
            'queue_ticks': queue_ticks,
            'fps': self.frames / wall if wall > 0 else float('inf'),
            'hit_rate': float(np.mean(self.hit_rates)) if self.hit_rates else 0.0,
            'saved_frac': (float(np.mean(self.saved_fracs))
                           if self.saved_fracs else 0.0),
            'p50_ms': float(np.percentile(lat, 50) * 1e3) if self.frames else 0.0,
            'p99_ms': float(np.percentile(lat, 99) * 1e3) if self.frames else 0.0,
            'sorts_per_frame': (float(np.mean(self.sorted_flags))
                                if self.sorted_flags else 0.0),
            'sort_ms': (float(np.mean(self.sort_mss))
                        if self.sort_mss else 0.0),
            'shade_ms': (float(np.mean(self.shade_mss))
                         if self.shade_mss else 0.0),
        }


def format_table(summaries: list[dict]) -> str:
    """Render session summaries as an aligned text table.

    Summaries may be heterogeneous — sessions admitted under different
    drivers/backends carry different keys; the table shows the union of
    columns (first-seen order) with missing cells left blank."""
    if not summaries:
        return '(no sessions)'
    cols = list(dict.fromkeys(c for s in summaries for c in s))
    missing = object()

    def fmt(v):
        if v is missing:
            return ''
        return f'{v:.3g}' if isinstance(v, float) else str(v)

    width = {c: max(len(c), max(len(fmt(s.get(c, missing)))
                                for s in summaries))
             for c in cols}
    lines = ['  '.join(c.rjust(width[c]) for c in cols)]
    for s in summaries:
        lines.append('  '.join(fmt(s.get(c, missing)).rjust(width[c])
                               for c in cols))
    return '\n'.join(lines)


def aggregate(summaries: list[dict]) -> dict:
    """Fleet-level rollup across sessions.

    ``fleet_fps`` is the frame-weighted per-viewer rate (each session's fps
    weighted by the frames it rendered — a 2-frame session no longer counts
    as much as a 200-frame one).  The legacy unweighted ``mean_fps`` field
    is gone; ``fleet_fps`` is the standard.
    """
    if not summaries:
        return {'sessions': 0, 'frames': 0}
    frames = sum(s['frames'] for s in summaries)
    fps = np.asarray([s['fps'] for s in summaries], np.float64)
    weights = np.asarray([s['frames'] for s in summaries], np.float64)
    finite = np.isfinite(fps) & (weights > 0)
    fleet_fps = (float(np.average(fps[finite], weights=weights[finite]))
                 if finite.any() else 0.0)
    return {
        'sessions': len(summaries),
        'frames': frames,
        'fleet_fps': fleet_fps,
        'mean_hit_rate': float(np.mean([s['hit_rate'] for s in summaries])),
        'worst_p99_ms': float(max(s['p99_ms'] for s in summaries)),
        'mean_sort_ms': float(np.mean([s.get('sort_ms', 0.0)
                                       for s in summaries])),
        'mean_shade_ms': float(np.mean([s.get('shade_ms', 0.0)
                                        for s in summaries])),
    }


def tick_rollup(tick_log: list[dict], warmup_ticks: int = 0) -> dict:
    """Fleet-level per-tick view of the cohort scheduler's sort activity.

    ``tick_log`` is ``SessionManager.tick_log``; ``warmup_ticks`` drops the
    leading ticks (compile + sort-on-admit bursts sit outside the scheduled
    per-tick cohort bound).

    When any tick carries a per-kernel shade breakdown (``kernel_ms``, from
    the batched stepper's sampled profiling on the pallas backend) the
    rollup's ``kernel_ms`` maps each kernel stage — prep / prefix / lookup /
    resume / insert — to its mean milliseconds over the profiled ticks, so
    the operator sees *where* shade time goes, not just its total.

    When ticks carry the stepper's state metrics (scene-shared serving) the
    rollup adds the radiance-cache warm-up view (``mean_occupancy`` /
    ``last_occupancy``) and the state-memory footprint: the peak number of
    live sort-pool entries (``max_sort_pool_live`` — the O(distinct pose
    cells) figure the scene-shared pool exists to shrink below O(S)) and
    the final cache/sort-pool byte split.

    When ticks carry the host-pipeline attribution (``latency_ms`` /
    ``host_ms`` / ``overlap_ms``, from the plan/apply/observe decomposition
    in ``repro.serve.session``) the rollup adds:

    * ``p50_frame_ms`` / ``p95_frame_ms`` — per-frame latency percentiles
      (each tick's latency weighted by the frames that rode it — the number
      an open-loop client actually experiences);
    * ``host_ms`` — mean host planning (admission/eviction/pose-cell) time
      per tick;
    * ``host_overlap`` — the fraction of total host planning time that ran
      while the device window of a concurrent tick was open.  0.0 under the
      synchronous virtual-clock driver by construction; > 0 is the threaded
      driver's whole point (host work hidden behind the device step).
    """
    log = [t for t in tick_log if t['tick'] >= warmup_ticks]
    if not log:
        return {'ticks': 0, 'mean_sorts_per_tick': 0.0,
                'max_sorts_per_tick': 0, 'mean_sort_ms': 0.0,
                'mean_shade_ms': 0.0, 'kernel_ms': {}}
    sorts = [t['sorted_slots'] for t in log]
    profiled = [t['kernel_ms'] for t in log if t.get('kernel_ms')]
    kernel_ms = {}
    if profiled:
        for key in profiled[0]:
            kernel_ms[key] = float(np.mean([p[key] for p in profiled]))
    roll = {
        'ticks': len(log),
        'mean_sorts_per_tick': float(np.mean(sorts)),
        'max_sorts_per_tick': int(max(sorts)),
        'mean_sort_ms': float(np.mean([t['sort_ms'] for t in log])),
        'mean_shade_ms': float(np.mean([t['shade_ms'] for t in log])),
        'kernel_ms': kernel_ms,
    }
    # per-frame latency percentiles: each tick's latency, weighted by the
    # frames that rode it (legacy logs without latency_ms just omit these)
    lat = np.repeat([t['latency_ms'] for t in log if 'latency_ms' in t],
                    [t['frames'] for t in log if 'latency_ms' in t])
    if lat.size:
        roll['p50_frame_ms'] = float(np.percentile(lat, 50))
        roll['p95_frame_ms'] = float(np.percentile(lat, 95))
    host = [t for t in log if 'host_ms' in t]
    if host:
        total_host = float(np.sum([t['host_ms'] for t in host]))
        total_overlap = float(np.sum([t.get('overlap_ms', 0.0)
                                      for t in host]))
        roll['host_ms'] = float(np.mean([t['host_ms'] for t in host]))
        # overlap is a subset of host planning time, so the ratio cannot
        # legitimately exceed 1.0 — report it UNclamped and warn instead of
        # silently masking the accounting bug a clamp would hide (a driver
        # intersecting the wrong interval, double-counted carry, ...)
        overlap = total_overlap / total_host if total_host > 0 else 0.0
        if overlap > 1.0:
            warnings.warn(
                f'host_overlap accounting bug: overlap {total_overlap:.3f} '
                f'ms exceeds host planning time {total_host:.3f} ms '
                f'(ratio {overlap:.3f})', RuntimeWarning, stacklevel=2)
        roll['host_overlap'] = overlap
    # occupancy values may still be unsynced device scalars (the stepper
    # defers the host transfer out of the timed serving loop) — float()
    # here is where they land
    occ = [float(t['occupancy']) for t in log if 'occupancy' in t]
    if occ:
        roll['mean_occupancy'] = float(np.mean(occ))
        roll['last_occupancy'] = occ[-1]
    pool = [t['sort_pool_live'] for t in log if 'sort_pool_live' in t]
    if pool:
        roll['max_sort_pool_live'] = int(max(pool))
    # byte figures are PEAKS over the run (staggered workloads drain toward
    # the end; the final-tick snapshot would understate the footprint)
    for key in ('sort_pool_bytes', 'sort_pool_alloc_bytes',
                'sort_pool_reserved_bytes', 'cache_bytes', 'state_bytes',
                'state_alloc_bytes', 'state_reserved_bytes',
                'stream_resident_bytes', 'stream_arena_bytes',
                'stream_full_bytes'):
        vals = [t[key] for t in log if key in t]
        if vals:
            roll[key] = int(max(vals))
    # streaming counters are cumulative over the run — the last snapshot is
    # the total; ``stream_stalls_tail`` isolates the post-warmup window the
    # steady-state gate (CI: stalls == 0 after warmup) reads
    for key in ('stream_stalls', 'stream_loads', 'stream_prefetch_hits',
                'stream_evictions'):
        vals = [t[key] for t in log if key in t]
        if vals:
            roll[key] = int(vals[-1])
    stall_vals = [t['stream_stalls'] for t in tick_log
                  if 'stream_stalls' in t]
    if stall_vals:
        warm = (stall_vals[min(warmup_ticks, len(stall_vals)) - 1]
                if warmup_ticks else 0)
        roll['stream_stalls_tail'] = int(stall_vals[-1] - warm)
    return roll
