"""Per-session render telemetry for the multi-viewer server.

Each viewer session accumulates per-frame observations (wall-clock latency of
the batched tick it rode in, radiance-cache hit rate, whether its slot ran a
speculative sort) and summarises them into the numbers an operator watches:
frames/sec, mean hit rate, p50/p99 frame latency and the realised sort
cadence (sorts per frame; 1/window when S^2 is keeping up).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SessionTelemetry:
    """Accumulated per-frame observations for one viewer session."""

    sid: int
    arrival_tick: int = 0
    admitted_tick: int = -1
    finished_tick: int = -1
    latencies_s: list = dataclasses.field(default_factory=list)
    hit_rates: list = dataclasses.field(default_factory=list)
    saved_fracs: list = dataclasses.field(default_factory=list)
    sorted_flags: list = dataclasses.field(default_factory=list)

    def observe_frame(self, latency_s: float, hit_rate: float,
                      saved_frac: float, sorted_flag: float) -> None:
        self.latencies_s.append(float(latency_s))
        self.hit_rates.append(float(hit_rate))
        self.saved_fracs.append(float(saved_frac))
        self.sorted_flags.append(float(sorted_flag))

    @property
    def frames(self) -> int:
        return len(self.latencies_s)

    def summary(self) -> dict:
        lat = np.asarray(self.latencies_s, np.float64)
        wall = float(lat.sum())
        queue_ticks = (self.admitted_tick - self.arrival_tick
                       if self.admitted_tick >= 0 else -1)
        return {
            'sid': self.sid,
            'frames': self.frames,
            'queue_ticks': queue_ticks,
            'fps': self.frames / wall if wall > 0 else float('inf'),
            'hit_rate': float(np.mean(self.hit_rates)) if self.hit_rates else 0.0,
            'saved_frac': (float(np.mean(self.saved_fracs))
                           if self.saved_fracs else 0.0),
            'p50_ms': float(np.percentile(lat, 50) * 1e3) if self.frames else 0.0,
            'p99_ms': float(np.percentile(lat, 99) * 1e3) if self.frames else 0.0,
            'sorts_per_frame': (float(np.mean(self.sorted_flags))
                                if self.sorted_flags else 0.0),
        }


def format_table(summaries: list[dict]) -> str:
    """Render session summaries as an aligned text table."""
    if not summaries:
        return '(no sessions)'
    cols = list(summaries[0].keys())

    def fmt(v):
        return f'{v:.3g}' if isinstance(v, float) else str(v)

    width = {c: max(len(c), max(len(fmt(s[c])) for s in summaries))
             for c in cols}
    lines = ['  '.join(c.rjust(width[c]) for c in cols)]
    for s in summaries:
        lines.append('  '.join(fmt(s[c]).rjust(width[c]) for c in cols))
    return '\n'.join(lines)


def aggregate(summaries: list[dict]) -> dict:
    """Fleet-level rollup across sessions."""
    if not summaries:
        return {'sessions': 0, 'frames': 0}
    frames = sum(s['frames'] for s in summaries)
    return {
        'sessions': len(summaries),
        'frames': frames,
        'mean_fps': float(np.mean([s['fps'] for s in summaries])),
        'mean_hit_rate': float(np.mean([s['hit_rate'] for s in summaries])),
        'worst_p99_ms': float(max(s['p99_ms'] for s in summaries)),
    }
