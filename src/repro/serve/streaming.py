"""Streaming scene residency: page pose-cell chunks through a device arena.

Large scenes do not fit device-resident.  ``ResidencyManager`` owns a
fixed-size device **arena** of ``arena_slots`` chunk frames (sized from a
byte budget) and pages the host-side ``ChunkedScene`` chunks in and out of
it, driven by where the live cameras are:

* chunks within ``near_radius`` grid cells (Chebyshev, the ``core/posecell``
  ``floor(p / cell_size)`` quantization) of any active camera are held at
  **FULL** level; within ``lod_radius`` at **LOD** level — the chunk's
  significance-prefix subset (``data.scenes.level_rows``), the budgeted
  approximate sibling of the significance-exact S² trim; beyond that a
  chunk need not be resident at all;
* the render mask per chunk is ``min(required_rows, loaded_rows)``: what the
  trajectory requires, capped by what is actually loaded.  When nothing
  stalls, the mask equals the requirement — a pure function of the camera
  trajectory — so the effective scene (and every rendered frame) is
  **bit-identical across arena budgets**, fully-resident included;
* a chunk some camera requires beyond its loaded rows is a **miss**: the
  load is scheduled, and if it cannot complete this tick (the per-tick load
  budget ``max_loads_per_tick`` models streaming bandwidth; admit-tick
  demand is exempt so cold starts never stall) only the missing viewers'
  slots stall — ``stream.stalls`` counts them and the stepper drops just
  those slots from the tick, so their cursors retry the same frame next
  tick while everyone else renders on;
* when even the **union** of the live working sets exceeds the arena,
  slots reserve capacity in a priority order rotating every
  ``grace_ticks + 2`` ticks: leading slots win the epoch, denied slots
  stall and stop requiring their chunks, which age past the grace window
  and free their frames for the next epoch's leaders — an oversized fleet
  timeshares the arena (degraded but live) instead of livelocking; a
  *single* slot whose own requirement exceeds the whole arena can never
  render and raises immediately (configuration error, not a stall);
* **prefetch**: with spare load budget the manager pulls the next ring in
  (FULL at ``near_radius + 1``, LOD at ``lod_radius + 1``) — the pose-cell
  neighbor structure as the prediction — on the host worker seam, so a
  camera drifting into a new cell finds its chunks warm
  (``stream.prefetch_hits``);
* **eviction** frees arena frames only for chunks unrequired for at least
  ``grace_ticks`` (sort window + slack): a stale sorted tile list may still
  gather an evicted chunk's lanes, and the grace period guarantees every
  such list has expired — meanwhile the render mask neutralizes unrequired
  lanes, so a stale list gathering them contributes exactly nothing.

The plan/apply split mirrors the stepper's scheduler seam: ``plan`` is a
pure function of the host mirrors (safe on the async host worker thread,
bit-identical under SyncDriver replay), ``apply`` mutates mirrors and the
device arena inside dispatch.  ``apply`` is idempotent per tick, so the
hardened dispatch path may retry a faulted tick without double-loading.

Residency state is checkpoint geometry: ``state_dict``/``load_state``
round-trip the arena pytree plus the JSON-able mirrors, so a restore at a
partially-resident state resumes bit-identically.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.scenes import (BYTES_PER_GAUSSIAN, LEVEL_FULL, LEVEL_LOD,
                               ChunkedScene, chunk_levels, level_rows,
                               masked_scene, neutral_scene)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


class StreamPlan(NamedTuple):
    """One tick's residency decisions (pure output of ``plan``)."""

    tick: int
    evict: tuple          # chunk ids to free (grace-expired, farthest first)
    assign: tuple         # ((chunk, arena_slot), ...) for newly placed chunks
    loads: tuple          # ((chunk, rows, block_rows, is_prefetch), ...)
    stalled: frozenset    # slots whose demand could not be satisfied
    mask_rows: tuple      # [arena_slots] render rows per frame AFTER loads
    hits: tuple           # chunk ids whose demand was served by a prefetch
    required_now: tuple   # chunk ids required (> 0 rows) this tick


class ResidencyManager:
    """Pose-cell chunk residency over a fixed device arena (see module
    docstring).  One per stepper; the stepper's effective ``scene`` is this
    manager's masked arena view."""

    def __init__(self, chunked: ChunkedScene, *, near_radius: int = 2,
                 lod_radius: int = 4, lod_frac: float = 0.5,
                 budget_bytes: Optional[int] = None,
                 max_loads_per_tick: Optional[int] = None,
                 grace_ticks: Optional[int] = None):
        self.chunked = chunked
        self.near_radius = int(near_radius)
        self.lod_radius = int(lod_radius)
        self.lod_frac = float(lod_frac)
        self.budget_bytes = budget_bytes
        self.max_loads_per_tick = max_loads_per_tick
        # default grace is set by the stepper at attach (sort window + 2)
        self.grace_ticks = grace_ticks
        cap = chunked.chunk_cap
        frame_bytes = cap * BYTES_PER_GAUSSIAN
        if budget_bytes is None:
            self.arena_slots = chunked.num_chunks
        else:
            self.arena_slots = max(1, min(chunked.num_chunks,
                                          int(budget_bytes) // frame_bytes))
        # LOD transfer block: one fixed height so loads compile twice (full
        # and LOD), not once per distinct chunk fill
        self.lod_block = max(1, int(np.ceil(cap * self.lod_frac)))
        self.metrics = obs_metrics.Registry()
        self.tracer = obs_trace.NULL
        self._load_jit = jax.jit(self._load_fn, donate_argnums=(0,))
        self._mask_jit = jax.jit(
            lambda packed, rows: masked_scene(packed, rows, cap))
        self._init_state()

    # -- state ---------------------------------------------------------------

    def _init_state(self) -> None:
        n, r = self.chunked.num_chunks, self.arena_slots
        self._loaded = np.zeros((n,), np.int64)     # rows resident per chunk
        self._prefetched = np.zeros((n,), bool)     # loaded by prefetch,
                                                    # not yet demanded
        self._last_required = np.full((n,), -(10 ** 9), np.int64)
        self._chunk_slot = {}                       # chunk -> arena slot
        self._slot_chunk = np.full((r,), -1, np.int64)
        self._mask_rows = np.zeros((r,), np.int64)
        self._applied_tick = -1
        self._counters = {'loads': 0, 'prefetch': 0, 'prefetch_hits': 0,
                          'stalls': 0, 'evictions': 0, 'loaded_bytes': 0}
        self._arena = jax.tree.map(
            jnp.asarray, neutral_scene(r * self.chunked.chunk_cap))
        self._scene = self._mask_jit(self._arena,
                                     jnp.zeros((r,), jnp.int32))
        self.dirty = True    # stepper must (re)take scene()

    def reset(self) -> None:
        """Cold-start between benchmark repetitions: empty arena, zeroed
        mirrors and counters on the already-jitted callables."""
        self._init_state()

    def scene(self):
        """The current effective scene: the arena with every lane past its
        chunk's render budget neutralized.  Consumes the dirty flag."""
        self.dirty = False
        return self._scene

    @property
    def resident_bytes(self) -> int:
        return int(self._loaded.sum()) * BYTES_PER_GAUSSIAN

    @property
    def arena_bytes(self) -> int:
        return self.arena_slots * self.chunked.chunk_cap * BYTES_PER_GAUSSIAN

    def counters(self) -> dict:
        return dict(self._counters)

    # -- jitted device load --------------------------------------------------

    @staticmethod
    def _load_fn(arena, block, start):
        return jax.tree.map(
            lambda a, b: jax.lax.dynamic_update_slice(
                a, b, (start,) + (0,) * (a.ndim - 1)),
            arena, block)

    # -- planning (pure) -----------------------------------------------------

    def _slot_requirements(self, cams: dict) -> tuple:
        """Per-slot required rows [C] and per-chunk min camera distance."""
        ch = self.chunked
        per_slot = {}
        min_dist = np.full((ch.num_chunks,), 10 ** 9, np.int64)
        for slot in sorted(cams):
            pos = np.asarray(cams[slot].position, np.float64)
            cam_cell = np.floor(pos / ch.cell_size).astype(np.int64)
            dist = np.abs(ch.cells - cam_cell[None, :]).max(axis=1)
            lvl = np.where(dist <= self.near_radius, LEVEL_FULL,
                           np.where(dist <= self.lod_radius, LEVEL_LOD, 0))
            per_slot[slot] = (level_rows(ch, lvl, self.lod_frac), dist)
            min_dist = np.minimum(min_dist, dist)
        return per_slot, min_dist

    def plan(self, tick: int, cams: dict, admits=frozenset()) -> StreamPlan:
        """Pure residency plan for ``tick``: reads only host mirrors.  The
        caller sequences it after the previous ``apply`` (same contract as
        the stepper's scheduler mirrors).  ``admits`` names slots admitted
        this tick — their demand loads are exempt from the per-tick load
        budget, so cold starts burst instead of stalling."""
        ch = self.chunked
        per_slot, min_dist = self._slot_requirements(cams)
        grace = self.grace_ticks if self.grace_ticks is not None else 8

        # -- capacity reservation in epoch-rotated priority order ----------
        # When the union working set fits the arena every slot reserves and
        # the order is irrelevant (the no-stall regime the bit-identity
        # contract lives in).  When it does not, slots reserve arena frames
        # in a priority order that rotates every ``grace + 2`` ticks:
        # the leading slots' requirements win, the rest are denied for the
        # epoch so their chunks stop being required, age past the grace
        # window and free their frames — the arena timeshares across
        # oversized fleets instead of livelocking on an unsatisfiable
        # union requirement.  Admit-tick slots always lead (cold starts).
        for slot in sorted(per_slot):
            need = int((per_slot[slot][0] > 0).sum())
            if need > self.arena_slots:
                raise RuntimeError(
                    f'streaming arena too small: slot {slot} requires '
                    f'{need} chunk frames but the arena holds only '
                    f'{self.arena_slots} — raise the byte budget or '
                    f'shrink near/lod radii')
        slots_sorted = sorted(per_slot)
        epoch = grace + 2
        lead = ((tick // epoch) % len(slots_sorted)) if slots_sorted else 0
        rotated = slots_sorted[lead:] + slots_sorted[:lead]
        order_slots = ([s for s in rotated if s in admits]
                       + [s for s in rotated if s not in admits])
        req = np.zeros((ch.num_chunks,), np.int64)
        reserved = []
        stalled = set()
        frames_left = self.arena_slots
        for slot in order_slots:
            rows, _ = per_slot[slot]
            new_chunks = int(((rows > 0) & (req == 0)).sum())
            if new_chunks > frames_left:
                stalled.add(slot)
                continue
            frames_left -= new_chunks
            req = np.maximum(req, rows)
            reserved.append(slot)
        loaded_after = self._loaded.copy()

        # demand: chunks some reserved slot needs beyond what is resident
        demand = np.nonzero(req > loaded_after)[0]
        exempt = set()
        for slot in (set(admits) & set(reserved)):
            rows, _ = per_slot[slot]
            exempt.update(np.nonzero(rows > loaded_after)[0].tolist())
        order = sorted(demand.tolist(),
                       key=lambda c: (c not in exempt, int(min_dist[c]), c))

        # arena frames available: free ones, then grace-expired evictions
        # (farthest from every camera first; never evict a required chunk)
        free = sorted(set(range(self.arena_slots))
                      - set(int(s) for s in self._chunk_slot.values()))
        evictable = sorted(
            (c for c in self._chunk_slot
             if req[c] == 0 and tick - int(self._last_required[c]) >= grace),
            key=lambda c: (-int(min_dist[c]), c))
        budget = (self.max_loads_per_tick if self.max_loads_per_tick
                  is not None else float('inf'))
        evict, assign, loads, hits = [], [], [], []
        spent = 0
        for c in order:
            is_exempt = c in exempt
            if not is_exempt and spent >= budget:
                continue
            if c not in self._chunk_slot and c not in dict(assign):
                if free:
                    slot = free.pop(0)
                elif evictable:
                    victim = evictable.pop(0)
                    evict.append(victim)
                    slot = int(self._chunk_slot[victim])
                else:
                    continue
                assign.append((c, slot))
            level = LEVEL_FULL if req[c] >= int(ch.fill[c]) else LEVEL_LOD
            block = (ch.chunk_cap if level == LEVEL_FULL else self.lod_block)
            loads.append((int(c), int(req[c]), int(block), False))
            loaded_after[c] = int(req[c])
            if not is_exempt:
                spent += 1

        # prefetch hits: demanded chunks already warm from a prior prefetch
        for c in np.nonzero((req > 0) & self._prefetched)[0].tolist():
            if self._loaded[c] >= req[c]:
                hits.append(int(c))

        # prefetch the next ring with spare budget and FREE frames only
        # (prefetch never evicts -- demand owns the reclaim path)
        pre_lvl = chunk_levels(
            ch, [np.asarray(cams[s].position, np.float64)
                 for s in sorted(cams)],
            self.near_radius + 1, self.lod_radius + 1) if cams else None
        prefetch = []
        if pre_lvl is not None:
            pre_rows = level_rows(ch, pre_lvl, self.lod_frac)
            cand = sorted(
                np.nonzero(pre_rows > loaded_after)[0].tolist(),
                key=lambda c: (int(min_dist[c]), c))
            for c in cand:
                if spent >= budget or not free:
                    break
                if c in self._chunk_slot or c in dict(assign):
                    slot = None   # resident upgrade uses its own frame
                else:
                    slot = free.pop(0)
                    assign.append((int(c), slot))
                level = (LEVEL_FULL if pre_rows[c] >= int(ch.fill[c])
                         else LEVEL_LOD)
                block = (ch.chunk_cap if level == LEVEL_FULL
                         else self.lod_block)
                prefetch.append((int(c), int(pre_rows[c]), int(block), True))
                loaded_after[c] = int(pre_rows[c])
                spent += 1

        # stall reserved slots whose own requirement stays unmet (denied
        # slots are already stalled; partial loads above still made
        # cross-tick progress toward unstalling them)
        for slot in reserved:
            rows, _ = per_slot[slot]
            if (rows > loaded_after).any():
                stalled.add(slot)

        # render mask: required capped by loaded, per arena frame
        # frames of evicted chunks are overwritten by ``assign`` entries
        slot_chunk = self._slot_chunk.copy()
        for c, s in assign:
            slot_chunk[s] = c
        mask_rows = np.zeros((self.arena_slots,), np.int64)
        for s in range(self.arena_slots):
            c = int(slot_chunk[s])
            if c >= 0:
                mask_rows[s] = min(int(req[c]), int(loaded_after[c]))
        return StreamPlan(
            tick=int(tick), evict=tuple(evict), assign=tuple(assign),
            loads=tuple(loads) + tuple(prefetch),
            stalled=frozenset(stalled), mask_rows=tuple(mask_rows),
            hits=tuple(hits),
            required_now=tuple(np.nonzero(req > 0)[0].tolist()))

    # -- apply (mutates mirrors + device arena) ------------------------------

    def apply(self, plan: StreamPlan) -> None:
        """Execute a plan: evictions, host->device chunk loads, render-mask
        rebuild, counters.  Idempotent per tick (hardened retries)."""
        if plan.tick == self._applied_tick:
            return
        self._applied_tick = plan.tick
        ch = self.chunked
        n_demand = sum(1 for l in plan.loads if not l[3])
        with self.tracer.span('stream.apply', tick=plan.tick,
                              loads=len(plan.loads), evict=len(plan.evict),
                              stalled=len(plan.stalled)):
            for c in plan.evict:
                self._counters['evictions'] += 1
                slot = self._chunk_slot.pop(c)
                self._slot_chunk[slot] = -1
                self._loaded[c] = 0
                self._prefetched[c] = False
            for c, slot in plan.assign:
                self._chunk_slot[c] = slot
                self._slot_chunk[slot] = c
            for c, rows, block, is_prefetch in plan.loads:
                slot = int(self._chunk_slot[c])
                host_block = ch.chunk_block(c, block, keep=rows)
                self._arena = self._load_jit(
                    self._arena, jax.tree.map(jnp.asarray, host_block),
                    slot * ch.chunk_cap)
                self._loaded[c] = rows
                self._prefetched[c] = is_prefetch
                self._counters['loaded_bytes'] += rows * BYTES_PER_GAUSSIAN
                self._counters['prefetch' if is_prefetch else 'loads'] += 1
            for c in plan.hits:
                self._prefetched[c] = False
            self._counters['prefetch_hits'] += len(plan.hits)
            self._counters['stalls'] += len(plan.stalled)
            for c in plan.required_now:
                self._last_required[c] = plan.tick
            new_mask = np.asarray(plan.mask_rows, np.int64)
            if plan.loads or plan.evict \
                    or (new_mask != self._mask_rows).any():
                self._mask_rows = new_mask
                self._scene = self._mask_jit(
                    self._arena, jnp.asarray(new_mask, jnp.int32))
                self.dirty = True
        self.metrics.counter('stream.loads', 'demand chunk loads').inc(
            n_demand)
        self.metrics.counter('stream.prefetch',
                             'speculative chunk loads').inc(
                                 len(plan.loads) - n_demand)
        self.metrics.counter(
            'stream.prefetch_hits',
            'demands served warm by a prior prefetch').inc(len(plan.hits))
        self.metrics.counter(
            'stream.stalls',
            'slot-ticks stalled on a missing chunk').inc(len(plan.stalled))
        self.metrics.counter('stream.evictions',
                             'arena frames reclaimed').inc(len(plan.evict))
        self.metrics.gauge(
            'stream.resident_bytes',
            'Gaussian bytes resident in the arena').set(
                float(self.resident_bytes))
        self.metrics.gauge(
            'stream.arena_bytes',
            'device bytes allocated to the streaming arena').set(
                float(self.arena_bytes))

    # -- checkpoint/restore --------------------------------------------------

    def state_dict(self) -> tuple:
        """``(arrays, meta)``: the device arena pytree plus JSON-able
        residency mirrors and partition geometry."""
        arrays = {'arena': self._arena}
        meta = {
            'geometry': self.chunked.meta_dict(),
            'near_radius': self.near_radius,
            'lod_radius': self.lod_radius,
            'lod_frac': self.lod_frac,
            'budget_bytes': self.budget_bytes,
            'max_loads_per_tick': self.max_loads_per_tick,
            'grace_ticks': self.grace_ticks,
            'arena_slots': self.arena_slots,
            'applied_tick': int(self._applied_tick),
            'resident': [[int(c), int(s), int(self._loaded[c]),
                          int(self._last_required[c]),
                          bool(self._prefetched[c])]
                         for c, s in sorted(self._chunk_slot.items())],
            'mask_rows': [int(r) for r in self._mask_rows],
            'counters': dict(self._counters),
        }
        return arrays, meta

    def load_state(self, arrays, meta: dict) -> None:
        geo = meta['geometry']
        if (geo['num_chunks'] != self.chunked.num_chunks
                or geo['chunk_cap'] != self.chunked.chunk_cap
                or geo['source_count'] != self.chunked.source_count):
            raise ValueError(
                f'streaming checkpoint geometry mismatch: snapshot '
                f'{geo["num_chunks"]}x{geo["chunk_cap"]} '
                f'(source {geo["source_count"]}) vs live partition '
                f'{self.chunked.num_chunks}x{self.chunked.chunk_cap} '
                f'(source {self.chunked.source_count})')
        self._init_state()
        self._arena = jax.tree.map(jnp.asarray, arrays['arena'])
        self._applied_tick = int(meta['applied_tick'])
        for c, s, rows, last_req, prefetched in meta['resident']:
            self._chunk_slot[int(c)] = int(s)
            self._slot_chunk[int(s)] = int(c)
            self._loaded[int(c)] = int(rows)
            self._last_required[int(c)] = int(last_req)
            self._prefetched[int(c)] = bool(prefetched)
        self._mask_rows = np.asarray(meta['mask_rows'], np.int64)
        self._counters = dict(meta['counters'])
        self._scene = self._mask_jit(
            self._arena, jnp.asarray(self._mask_rows, jnp.int32))
        self.dirty = True

    def state_template(self) -> dict:
        """Arena-shaped arrays template for the checkpoint loader."""
        return {'arena': jax.tree.map(
            np.asarray, neutral_scene(self.arena_slots
                                      * self.chunked.chunk_cap))}
