"""Architecture registry: one uniform interface over the model zoo.

Provides, per config:
  * ``init_params`` / ``abstract_params`` (eval_shape — no allocation),
  * ``train_step`` (loss + grads + AdamW update),
  * ``prefill`` / ``decode_step`` serving entry points,
  * ``input_specs`` — ShapeDtypeStruct stand-ins for every model input of an
    (arch x shape) cell (the dry-run contract),
  * ``param_specs`` / input shardings — the recipe's PartitionSpecs.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import moe, transformer, whisper, xlstm, zamba2
from repro.optim import adam
from repro.runtime.sharding import (ShardCtx, adaptive_spec, all_axes,
                                    axes_size, batch_axes)

_FAMILY = {
    'dense': transformer,
    'vlm': transformer,      # chameleon backbone == dense + qk_norm
    'moe': moe,
    'encdec': whisper,
    'ssm': xlstm,
    'hybrid': zamba2,
}


def module_for(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init_params(key, cfg: ModelConfig, tp: int = 1):
    return module_for(cfg).init_params(key, cfg, tp)


def abstract_params(cfg: ModelConfig, tp: int = 1):
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg, tp=tp), jax.random.PRNGKey(0))


def make_ctx(mesh, cfg: ModelConfig, *, long_context: bool = False) -> ShardCtx:
    # activation constraints are divisibility-adaptive and recipe-agnostic
    return ShardCtx(mesh=mesh, recipe=cfg.recipe,
                    tp=tp_of(mesh, cfg), seq_shard_kv=long_context)


def tp_of(mesh, cfg: ModelConfig) -> int:
    # Every recipe pads q heads to the model axis: head-sharded attention is
    # what keeps score-block HBM traffic per chip sane even for replicated-
    # param (dp) models — see EXPERIMENTS.md §Dry-run notes.
    if mesh is not None:
        return mesh.shape.get('model', 1)
    return 1


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, ctx: ShardCtx,
                    adam_cfg: Optional[adam.AdamConfig] = None):
    mod = module_for(cfg)
    acfg = adam_cfg or adam.AdamConfig(
        state_dtype=jnp.dtype(cfg.opt_state_dtype))

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: mod.train_loss(p, batch, cfg, ctx))(params)
        params, opt_state, gnorm = adam.step(params, grads, opt_state, acfg)
        return params, opt_state, {'loss': loss, 'grad_norm': gnorm}

    return train_step, acfg


def make_prefill(cfg: ModelConfig, ctx: ShardCtx):
    mod = module_for(cfg)
    if cfg.family == 'encdec':
        def prefill(params, batch):
            # encode + precompute cross KV; decoder prefill == teacher-forced
            # pass that also emits self-attention caches
            enc = whisper.encode(params, batch['frames'], cfg, ctx)
            h = whisper.decode_train(params, batch['tokens'], enc, cfg, ctx)
            from repro.models import layers as L
            lg = L.logits(params['tok'], h[:, -1:], cfg, ctx)
            return lg[:, 0]
        return prefill
    if cfg.family in ('ssm', 'hybrid'):
        def prefill(params, batch):
            h = mod.forward(params, batch['tokens'], cfg, ctx)
            from repro.models import layers as L
            lg = L.logits(params['tok'], h[:, -1:], cfg, ctx)
            return lg[:, 0]
        return prefill
    if cfg.family == 'moe':
        def prefill(params, batch):
            h, _ = moe.forward(params, batch['tokens'], cfg, ctx)
            from repro.models import layers as L
            lg = L.logits(params['tok'], h[:, -1:], cfg, ctx)
            return lg[:, 0]
        return prefill

    def prefill(params, batch):
        lg, caches = transformer.prefill(params, batch['tokens'], cfg, ctx)
        return lg
    return prefill


def make_decode_step(cfg: ModelConfig, ctx: ShardCtx):
    mod = module_for(cfg)

    if cfg.family == 'encdec':
        def step(params, token, state, pos):
            lg, caches = whisper.decode_step(params, token, state['self'],
                                             state['cross'], pos, cfg, ctx)
            return lg, dict(state, self=caches)
        return step
    if cfg.family == 'ssm':
        def step(params, token, state, pos):
            return xlstm.decode_step(params, token, state, pos, cfg, ctx)
        return step
    if cfg.family == 'hybrid':
        def step(params, token, state, pos):
            return zamba2.decode_step(params, token, state, pos, cfg, ctx)
        return step
    if cfg.family == 'moe':
        def step(params, token, state, pos):
            lg, caches = moe.decode_step(params, token, state, pos, cfg, ctx)
            return lg, caches
        return step

    def step(params, token, state, pos):
        lg, caches = transformer.decode_step(params, token, state, pos, cfg, ctx)
        return lg, caches
    return step


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int, tp: int = 1):
    if cfg.family == 'encdec':
        return {
            'self': whisper.init_kv_cache(cfg, batch, max_seq, tp),
            'cross': (jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
                                 cfg.resolved_head_dim()), jnp.dtype(cfg.dtype)),
                      jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
                                 cfg.resolved_head_dim()), jnp.dtype(cfg.dtype))),
        }
    if cfg.family == 'ssm':
        return xlstm.init_state(cfg, batch)
    if cfg.family == 'hybrid':
        return zamba2.init_state(cfg, batch, max_seq, tp)
    if cfg.family == 'moe':
        return moe.init_kv_cache(cfg, batch, max_seq, tp)
    return transformer.init_kv_cache(cfg, batch, max_seq, tp)


def abstract_decode_state(cfg: ModelConfig, batch: int, max_seq: int, tp: int = 1):
    return jax.eval_shape(
        lambda: init_decode_state(cfg, batch, max_seq, tp))


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs for the dry-run)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == 'train':
        batch = {'tokens': tok, 'labels': tok}
        if cfg.family == 'encdec':
            batch['frames'] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), jnp.dtype(cfg.dtype))
        return batch
    if shape.kind == 'prefill':
        batch = {'tokens': tok}
        if cfg.family == 'encdec':
            batch['frames'] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), jnp.dtype(cfg.dtype))
        return batch
    # decode: one new token against a cache of length s
    return {'token': jax.ShapeDtypeStruct((b, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs (recipe rules, path + rank based)
# ---------------------------------------------------------------------------

_TP_LAST2 = {
    'wq': ('data', 'model'), 'w_up': ('data', 'model'),
    'w_gate': ('data', 'model'), 'w_in': ('data', 'model'),
    'w_x': ('data', 'model'), 'w_h': ('data', 'model'),
    'wk': ('data', None), 'wv': ('data', None), 'w_if': ('data', None),
    'wo': ('model', 'data'), 'w_down': ('model', 'data'),
    'w_out': ('model', 'data'),
    # embed shards d_model, NOT vocab: a vocab-sharded table turns every
    # token lookup into a full-table all-gather (4 GB/device on maverick)
    'embed': (None, 'model'), 'unembed': (None, 'model'),
    'router': (None, None), 'frontend_proj': (None, None),
    'conv': (None, None),
}
_EXPERT_LAST3 = {
    'w_up': ('model', 'data', None), 'w_gate': ('model', 'data', None),
    'w_down': ('model', None, 'data'),
}


def _guard_divisible(spec: P, shape, mesh) -> P:
    """Drop spec axes whose size does not divide the tensor dimension."""
    if mesh is None:
        return spec
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        size = axes_size(mesh, entry)
        out.append(entry if size and shape[i] % size == 0 else None)
    return P(*out)


def _leaf_spec(path, leaf, recipe: str, mesh=None) -> P:
    # 'dp' replicates params (small models).  'ssm' follows the same
    # FSDP('data') x TP('model') table as 'tp' — xlstm-1.3b with fp32
    # moments does not fit replicated (see DESIGN.md §4).
    if recipe == 'dp':
        return P()
    if recipe == 'fsdp':
        # ZeRO-3: 256-way sharding of every weight's largest trailing dim;
        # no tensor parallelism (the model axis carries batch instead)
        return adaptive_spec(leaf.shape, mesh,
                             [(-2, ('data', 'model')),
                              (-1, ('data', 'model'))]) if mesh else P()
    name = None
    for entry in reversed(path):
        if hasattr(entry, 'key'):
            name = entry.key
            break
    nd = leaf.ndim
    in_moe = any(getattr(e, 'key', None) == 'moe' for e in path)
    in_shared = any(getattr(e, 'key', None) == 'shared' for e in path)
    if in_moe and not in_shared and name in _EXPERT_LAST3 and nd >= 3:
        tail = _EXPERT_LAST3[name]
        spec = P(*((None,) * (nd - 3) + tail))
    elif name in _TP_LAST2 and nd >= 2:
        tail = _TP_LAST2[name]
        spec = P(*((None,) * (nd - 2) + tail))
    else:
        spec = P(*((None,) * nd))
    return _guard_divisible(spec, leaf.shape, mesh)


def param_specs(cfg: ModelConfig, params_tree, mesh=None) -> Any:
    """PartitionSpec pytree matching ``params_tree`` per the config's recipe."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, cfg.recipe, mesh), params_tree)


def batch_shardings(cfg: ModelConfig, mesh, batch_tree) -> Any:
    """Input-batch PartitionSpecs: batch dim over pod x data, sequence over
    'model' where divisible (matches the SP residual layout downstream);
    recipe 'fsdp' sharding batch over every axis."""
    baxes = all_axes(mesh) if cfg.recipe == 'fsdp' else batch_axes(mesh)

    def rule(leaf):
        if mesh is None:
            return P()
        return adaptive_spec(leaf.shape, mesh, [(0, baxes), (1, 'model')])

    return jax.tree.map(rule, batch_tree)


def decode_state_specs(cfg: ModelConfig, state_tree, mesh, *,
                       long_context: bool):
    """KV caches: batch over pod x data, sequence over 'model'
    (flash-decoding layout — even split regardless of GQA head count);
    long-context (batch=1): sequence over 'data', heads (else head_dim) over
    'model'.  SSM recurrent states: batch + largest inner dim."""
    baxes = batch_axes(mesh)

    def rule(path, leaf):
        if mesh is None:
            return P()
        names = [getattr(e, 'key', None) for e in path]
        shape = leaf.shape
        nd = leaf.ndim
        if cfg.family == 'ssm':
            if 'mlstm' in names:   # [ns, se-1, B, H, dk, dv]
                return adaptive_spec(shape, mesh,
                                     [(2, baxes), (3, 'model'), (4, 'model')])
            return adaptive_spec(shape, mesh,  # slstm [ns, B, di]
                                 [(1, baxes), (2, 'model')])
        if cfg.family == 'hybrid':
            if 'kv_k' in names or 'kv_v' in names:   # [pts, B, T, H, hd]
                if long_context:
                    return adaptive_spec(shape, mesh,
                                         [(2, 'data'), (3, 'model'),
                                          (4, 'model')])
                return adaptive_spec(shape, mesh, [(1, baxes), (2, 'model')])
            # mamba states: ssm [L,B,h,ds,hd] / conv [L,B,K-1,C]
            return adaptive_spec(shape, mesh,
                                 [(1, baxes), (2, 'model'), (-1, 'model')])
        # dense/moe/encdec stacked caches [L(,A),B,T,Hkv,hd]
        lead = nd - 4
        if long_context:
            return adaptive_spec(shape, mesh,
                                 [(lead + 1, 'data'), (lead + 2, 'model'),
                                  (lead + 3, 'model')])
        return adaptive_spec(shape, mesh,
                             [(lead, baxes), (lead + 1, 'model')])
    return jax.tree_util.tree_map_with_path(rule, state_tree)
