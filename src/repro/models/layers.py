"""Shared neural-net layers for the architecture zoo (pure JAX, dict params).

Conventions:
  * activations [batch, seq, d_model]; attention heads [B, S, H, head_dim];
  * params are nested dicts of arrays; layer-stacked params carry a leading
    [L] axis (consumed by ``lax.scan``);
  * every function takes a ``ShardCtx`` and calls its constraint helpers so
    the same code runs unsharded (tests) and on the 512-chip mesh (dry-run);
  * TP head-padding: head counts are padded to the model-axis size with
    masked extra heads (exact forward/backward equivalence — extra heads'
    outputs are zeroed so their projections receive zero gradients).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.sharding import ShardCtx, padded_heads

# ---------------------------------------------------------------------------
# Basics
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm: statistics in f32, scale applied in the input dtype.

    The f32 upcast of x feeds ONLY the variance reduce (fused away by XLA);
    applying the normalizer as ``x * scale.astype(x.dtype)`` avoids
    materializing an f32 copy of x — with the layer-stacked residual save
    under remat, XLA otherwise hoists ``convert(f32)`` of the WHOLE [L,B,S,D]
    stack out of the backward loop (measured: +7 GB/device on yi-34b).
    """
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps)
    return x * (scale * w.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [B, S, H, hd], positions: [B, S] (int)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs        # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, (d_in, d_out))).astype(dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_params(key, cfg, dtype, tp: int) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    hp = padded_heads(cfg.n_heads, tp)   # pad q heads to the model axis;
    # kv heads stay at the TRUE count — repeat_kv maps q->kv by gather, so
    # no kv padding is ever needed (smollm's 15q/5kv pads q to 16, kv stays 5)
    ks = jax.random.split(key, 6)
    p = {
        'wq': dense_init(ks[0], d, hp * hd, dtype),
        'wk': dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        'wv': dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        'wo': dense_init(ks[3], hp * hd, d, dtype,
                         scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        p['q_norm'] = jnp.ones((hd,), dtype)
        p['k_norm'] = jnp.ones((hd,), dtype)
    return p


def _head_mask(hp: int, n_heads: int, dtype):
    if hp == n_heads:
        return None
    return (jnp.arange(hp) < n_heads).astype(dtype)


def _qkv(p, x, cfg, ctx: ShardCtx, positions):
    b, s, d = x.shape
    hd = cfg.resolved_head_dim()
    hp = p['wq'].shape[1] // hd
    q = (x @ p['wq']).reshape(b, s, hp, hd)
    k = (x @ p['wk']).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p['wv']).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p['q_norm'], cfg.norm_eps)
        k = rmsnorm(k, p['k_norm'], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = ctx.bthd(q)
    return q, k, v, hp, hd


def repeat_kv(k: jax.Array, hp: int, n_heads: Optional[int] = None) -> jax.Array:
    """[B, T, Hkv, hd] -> [B, T, Hp, hd]: GQA head-group expansion by gather.

    Real q head i attends kv head ``i * Hkv // n_heads`` (the standard GQA
    grouping); padded q heads (i >= n_heads, masked downstream) clamp to the
    last kv head.  A gather instead of ``jnp.repeat`` keeps the TRUE kv-head
    count in params/caches even when Hp % Hkv != 0.
    """
    hkv = k.shape[2]
    n_real = n_heads or hp
    idx = jnp.minimum(jnp.arange(hp), n_real - 1) * hkv // n_real
    return k[:, :, idx, :]


def flash_attention(q, k, v, *, causal: bool, q_offset=0,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    ctx: Optional[ShardCtx] = None) -> jax.Array:
    """Memory-streamed attention (lazy softmax over KV chunks).

    q: [B, S, H, hd]; k, v: [B, T, H, hd] (already GQA-repeated).
    Never materializes an [S, T] score matrix — scores exist only per
    (q_chunk x kv_chunk) block, so 32k-token prefill fits in HBM.
    ``q_offset``: absolute position of q[0] (for decode windows).
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    qc = min(q_chunk, s)
    while s % qc:
        qc -= 1
    kc = min(kv_chunk, t)
    while t % kc:
        kc -= 1
    nq, nk = s // qc, t // kc
    scale = 1.0 / math.sqrt(hd)

    qr = jnp.moveaxis(q.reshape(b, nq, qc, h, hd), 1, 0)      # [nq,B,qc,H,hd]
    kr = jnp.moveaxis(k.reshape(b, nk, kc, h, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, kc, h, hd), 1, 0)

    # flash-attention memory contract under AD: scan's default gradient
    # saves every iteration's intermediates — for attention that is the
    # [nq, nk, B, qc, H, kc] probability tensor (measured 16 GB/device on
    # smollm train_4k).  Nested checkpoints make the backward recompute
    # score blocks instead, exactly like a hand-written flash bwd kernel:
    # only per-iteration carries (m, l, acc) survive to HBM.
    def q_step(_, qi_and_chunk):
        qi, q_c = qi_and_chunk
        q32 = q_c.astype(jnp.float32) * scale
        qpos = qi * qc + jnp.arange(qc) + q_offset

        def kv_step(carry, kj_and_chunk):
            m, l, acc = carry
            kj, (k_c, v_c) = kj_and_chunk
            # QK in bf16 with f32 accumulation (the MXU-native layout);
            # the f32 score block was the largest HBM tensor of dense train
            # cells — §Perf command-r iteration 4
            sc = jnp.einsum('bqhd,bkhd->bqhk', q32.astype(jnp.bfloat16),
                            k_c.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
            if causal:
                kpos = kj * kc + jnp.arange(kc)
                mask = kpos[None, :] > qpos[:, None]           # [qc, kc]
                sc = jnp.where(mask[None, :, None, :], -1e30, sc)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            # PV in bf16: p is in [0,1] and the accumulator stays f32 —
            # the layout real flash kernels use; halves the probability-
            # block HBM traffic (the dominant memory term on dense train
            # cells — §Perf command-r iteration 3)
            acc = acc * corr[..., None] + jnp.einsum(
                'bqhk,bkhd->bqhd', p.astype(jnp.bfloat16),
                v_c.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((b, qc, h), -1e30, jnp.float32),
                jnp.zeros((b, qc, h), jnp.float32),
                jnp.zeros((b, qc, h, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), init, (jnp.arange(nk), (kr, vr)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None,
                           (jnp.arange(nq), qr))               # [nq,B,qc,H,hd]
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)


def attention_train(p, x, cfg, ctx: ShardCtx, positions,
                    causal: bool = True) -> jax.Array:
    """Self-attention over a full sequence (train / prefill / encoder)."""
    q, k, v, hp, hd = _qkv(p, x, cfg, ctx, positions)
    k = ctx.bthd(repeat_kv(k, hp, cfg.n_heads))
    v = ctx.bthd(repeat_kv(v, hp, cfg.n_heads))
    out = flash_attention(q, k, v, causal=causal, ctx=ctx)
    mask = _head_mask(hp, cfg.n_heads, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    out = ctx.bthd(out)
    b, s = x.shape[:2]
    return ctx.btd(out.reshape(b, s, hp * hd) @ p['wo'])


def attention_prefill(p, x, cfg, ctx: ShardCtx, positions):
    """Like attention_train but also returns the (k, v) cache [B,S,Hkv,hd]."""
    q, k, v, hp, hd = _qkv(p, x, cfg, ctx, positions)
    kr = ctx.bthd(repeat_kv(k, hp, cfg.n_heads))
    vr = ctx.bthd(repeat_kv(v, hp, cfg.n_heads))
    out = flash_attention(q, kr, vr, causal=True, ctx=ctx)
    mask = _head_mask(hp, cfg.n_heads, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    b, s = x.shape[:2]
    y = ctx.btd(out.reshape(b, s, hp * hd) @ p['wo'])
    return y, (ctx.kv_cache(k), ctx.kv_cache(v))


def attention_decode(p, x, cfg, ctx: ShardCtx, cache, pos):
    """One-token decode: x [B,1,D], cache (k,v) [B,T,Hkv,hd], pos scalar.

    Returns (y [B,1,D], new cache).  The new token's k/v are written at
    ``pos``; attention reads positions <= pos.
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim()
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new, hp, _ = _qkv(p, x, cfg, ctx, positions)
    k_cache, v_cache = cache
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype),
                                           (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype),
                                           (0, pos, 0, 0))
    k_cache = ctx.kv_cache(k_cache)
    v_cache = ctx.kv_cache(v_cache)

    kr = repeat_kv(k_cache, hp, cfg.n_heads)       # [B, T, Hp, hd]
    vr = repeat_kv(v_cache, hp, cfg.n_heads)
    scale = 1.0 / math.sqrt(hd)
    sc = jnp.einsum('bqhd,bkhd->bhqk', q.astype(jnp.float32) * scale,
                    kr.astype(jnp.float32))        # [B, Hp, 1, T]
    t = kr.shape[1]
    valid = jnp.arange(t)[None, None, None, :] <= pos
    sc = jnp.where(valid, sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum('bhqk,bkhd->bqhd', w, vr.astype(jnp.float32)).astype(x.dtype)
    mask = _head_mask(hp, cfg.n_heads, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = ctx.btd(out.reshape(b, 1, hp * hd) @ p['wo'])
    return y, (k_cache, v_cache)


def attention_cross(p, x, cfg, ctx: ShardCtx, kv) -> jax.Array:
    """Cross-attention (whisper decoder): kv = (k, v) from encoder states."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim()
    hp = p['wq'].shape[1] // hd
    q = (x @ p['wq']).reshape(b, s, hp, hd)
    q = ctx.bthd(q)
    k, v = kv
    kr = ctx.bthd(repeat_kv(k, hp, cfg.n_heads))
    vr = ctx.bthd(repeat_kv(v, hp, cfg.n_heads))
    out = flash_attention(q, kr, vr, causal=False, ctx=ctx)
    mask = _head_mask(hp, cfg.n_heads, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    return ctx.btd(out.reshape(b, s, hp * hd) @ p['wo'])


def cross_kv(p, enc: jax.Array, cfg, ctx: ShardCtx):
    """Precompute cross-attention k/v from encoder output."""
    b, s, _ = enc.shape
    hd = cfg.resolved_head_dim()
    k = (enc @ p['wk']).reshape(b, s, cfg.n_kv_heads, hd)
    v = (enc @ p['wv']).reshape(b, s, cfg.n_kv_heads, hd)
    return ctx.kv_cache(k), ctx.kv_cache(v)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_params(key, cfg, dtype, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {'w_up': dense_init(ks[0], d, f, dtype),
         'w_down': dense_init(ks[1], f, d, dtype,
                              scale=0.02 / math.sqrt(2 * cfg.n_layers))}
    if cfg.act == 'swiglu':
        p['w_gate'] = dense_init(ks[2], d, f, dtype)
    return p


def mlp(p, x, cfg, ctx: ShardCtx) -> jax.Array:
    up = ctx.btf(x @ p['w_up'])
    if cfg.act == 'swiglu':
        gate = ctx.btf(x @ p['w_gate'])
        h = jax.nn.silu(gate) * up
    elif cfg.act == 'relu2':           # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(up))
    elif cfg.act == 'gelu':
        h = jax.nn.gelu(up)
    else:
        raise ValueError(cfg.act)
    return ctx.btd(h @ p['w_down'])


# ---------------------------------------------------------------------------
# Embedding / loss
# ---------------------------------------------------------------------------

def padded_vocab(cfg, tp: int) -> int:
    """Vocab padded for TP divisibility / MXU alignment (pad logits masked)."""
    if tp <= 1:
        return cfg.vocab
    m = 128 * tp // math.gcd(128, tp)
    return (cfg.vocab + m - 1) // m * m


def embed_params(key, cfg, dtype, tp: int = 1) -> dict:
    k1, k2 = jax.random.split(key)
    vp = padded_vocab(cfg, tp)
    p = {'embed': (0.02 * jax.random.normal(k1, (vp, cfg.d_model))
                   ).astype(dtype),
         'final_norm': jnp.ones((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        p['unembed'] = dense_init(k2, cfg.d_model, vp, dtype)
    return p


def embed(p, tokens: jax.Array, ctx: ShardCtx) -> jax.Array:
    return ctx.btd(p['embed'][tokens])


def _unembed_matrix(p):
    return p['unembed'] if 'unembed' in p else p['embed'].T


def logits(p, x: jax.Array, cfg, ctx: ShardCtx) -> jax.Array:
    h = rmsnorm(x, p['final_norm'], cfg.norm_eps)
    lg = ctx.btv(h @ _unembed_matrix(p))
    vp = lg.shape[-1]
    if vp != cfg.vocab:   # mask vocab padding
        lg = jnp.where(jnp.arange(vp) < cfg.vocab, lg, -1e30)
    return lg


def chunked_ce_loss(p, x: jax.Array, labels: jax.Array, cfg,
                    ctx: ShardCtx) -> jax.Array:
    """Sequence-chunked cross entropy: never materializes [B, S, V] at once.

    x: [B, S, D] final hidden states; labels: [B, S] int32 (-1 = ignore).
    """
    b, s, d = x.shape
    c = min(cfg.loss_chunk, s)
    while s % c:
        c -= 1
    n = s // c
    w = _unembed_matrix(p)
    h = rmsnorm(x, p['final_norm'], cfg.norm_eps)
    hr = jnp.moveaxis(h.reshape(b, n, c, d), 1, 0)
    lr = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)

    vp = w.shape[-1]

    def step(carry, xs):
        nll_sum, count = carry
        h_c, l_c = xs
        lg = ctx.btv((h_c @ w).astype(jnp.float32))            # [B, c, V]
        if vp != cfg.vocab:   # mask vocab padding out of the partition fn
            lg = jnp.where(jnp.arange(vp) < cfg.vocab, lg, -1e30)
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(
            lg, jnp.maximum(l_c, 0)[..., None], axis=-1)[..., 0]
        valid = l_c >= 0
        nll = jnp.where(valid, lse - tgt, 0.0)
        return (nll_sum + jnp.sum(nll), count + jnp.sum(valid)), None

    # checkpoint: the bwd recomputes each chunk's logits instead of saving
    # the f32 [B, chunk, V] stack (1 GB/device on yi-34b)
    (nll_sum, count), _ = jax.lax.scan(
        jax.checkpoint(step), (jnp.float32(0), jnp.int32(0)), (hr, lr))
    return nll_sum / jnp.maximum(count, 1)
