"""xLSTM (xlstm-1.3b): mLSTM blocks with interspersed sLSTM blocks.

mLSTM = matrix-memory LSTM == decayed linear attention with a normalizer —
trained with the chunkwise-parallel core in ``linear_scan``; decoded with the
O(1) recurrent step (this is why xlstm runs the long_500k shape).

sLSTM = scalar-memory recurrent block (every ``slstm_every``-th block);
inherently sequential, trained with a ``lax.scan`` over time.

Stabilization note (DESIGN.md): the paper's exponential input gate with the
running-max stabilizer is replaced by a bounded sigmoid gate so the chunked
form stays overflow-free; forget gates are sigmoid (log a <= 0), matching
the structure and FLOP count of the original.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.linear_scan import chunked_linear_attention, linear_attention_step
from repro.models.transformer import _stack_init
from repro.runtime.sharding import ShardCtx

UP_FACTOR = 2  # block up-projection factor (xLSTM uses ~2x inner dim)


def _inner(cfg):
    return UP_FACTOR * cfg.d_model


def mlstm_params(key, cfg, dtype):
    d = cfg.d_model
    di = _inner(cfg)
    hd = di // cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        'ln': jnp.ones((d,), dtype),
        'w_up': L.dense_init(ks[0], d, di, dtype),
        'w_gate': L.dense_init(ks[1], d, di, dtype),
        'wq': L.dense_init(ks[2], di, di, dtype),
        'wk': L.dense_init(ks[3], di, di, dtype),
        'wv': L.dense_init(ks[4], di, di, dtype),
        'w_if': L.dense_init(ks[5], di, 2 * cfg.n_heads, dtype),  # i/f gates
        'w_down': L.dense_init(ks[6], di, d, dtype,
                               scale=0.02 / math.sqrt(2 * cfg.n_layers)),
        'out_norm': jnp.ones((hd,), dtype),
    }


def slstm_params(key, cfg, dtype):
    d = cfg.d_model
    di = _inner(cfg)
    h = cfg.n_heads
    hd = di // h
    ks = jax.random.split(key, 4)
    return {
        'ln': jnp.ones((d,), dtype),
        'w_x': L.dense_init(ks[0], d, 4 * di, dtype),   # z, i, f, o pre-acts
        # recurrent matrix is BLOCK-DIAGONAL per head (the xLSTM paper's
        # sLSTM design): [H, hd, 4*hd].  This is both faithful and the perf
        # fix for the recurrent scan — w_h_blocks is small enough to stay
        # replicated per chip, so the 4096-step scan runs with ZERO
        # collectives (the dense FSDP-sharded w_h generated a collective
        # per timestep: 813k collective-permutes on the dry-run — §Perf).
        'w_h_blocks': (0.02 * jax.random.normal(ks[1], (h, hd, 4 * hd))
                       ).astype(dtype),
        'w_down': L.dense_init(ks[2], di, d, dtype,
                               scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _mlstm_qkvg(p, x, cfg, ctx: ShardCtx):
    b, s, _ = x.shape
    h = cfg.n_heads
    di = _inner(cfg)
    hd = di // h
    u = x @ p['w_up']
    g = jax.nn.silu(x @ p['w_gate'])
    q = (u @ p['wq']).reshape(b, s, h, hd)
    k = (u @ p['wk']).reshape(b, s, h, hd) / math.sqrt(hd)
    v = ctx.btdv((u @ p['wv']).reshape(b, s, h, hd))
    gates = (u @ p['w_if']).reshape(b, s, 2, h).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gates[:, :, 0])              # [B,S,H] <= 0
    i_gate = jax.nn.sigmoid(gates[:, :, 1])                 # bounded input gate
    k = k * i_gate[..., None].astype(k.dtype)
    return q, k, v, g, log_f, hd


def mlstm_block(p, x, cfg, ctx: ShardCtx):
    res = x
    x = L.rmsnorm(x, p['ln'], cfg.norm_eps)
    q, k, v, g, log_f, hd = _mlstm_qkvg(p, x, cfg, ctx)
    y, _ = chunked_linear_attention(q, k, v, log_f, normalize=True)
    y = L.rmsnorm(y, p['out_norm'], cfg.norm_eps)
    b, s = x.shape[:2]
    y = (y.reshape(b, s, -1) * g)
    return ctx.btd(res + y @ p['w_down'])


def mlstm_decode(p, x, state, cfg, ctx: ShardCtx):
    """x [B,1,D]; state [B,H,hd,hd+1].  Returns (y [B,1,D], new state)."""
    res = x
    x = L.rmsnorm(x, p['ln'], cfg.norm_eps)
    q, k, v, g, log_f, hd = _mlstm_qkvg(p, x, cfg, ctx)
    y, state = linear_attention_step(
        state, q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], normalize=True)
    y = L.rmsnorm(y, p['out_norm'], cfg.norm_eps)
    b = x.shape[0]
    y = (y.reshape(b, 1, -1) * g)
    return ctx.btd(res + y @ p['w_down']), state


def _slstm_recur(pre_t, h, c, w32, n_heads, hd):
    """One sLSTM timestep: block-diagonal recurrence + gate nonlinearities.

    pre_t [B, 4*di] f32, h/c [B, di] f32, w32 [H, hd, 4*hd] f32.
    Callers MUST pass pre-converted f32 operands: a per-step ``astype``
    inside the scan makes XLA convert whole stacked blocks every timestep
    (measured: 26 TB/chip of convert traffic on train_4k — §Perf).
    """
    b = h.shape[0]
    hh = h.reshape(b, n_heads, hd)
    # [B,H,4,hd] -> gate-major [B,4,H,hd] -> [B, 4*di] so the layout lines
    # up with w_x's (z,i,f,o) concatenation before jnp.split
    rec = jnp.einsum('bhd,hde->bhe', hh, w32)
    rec = rec.reshape(b, n_heads, 4, hd).transpose(0, 2, 1, 3).reshape(b, -1)
    pre = pre_t + rec
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(z)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def slstm_block(p, x, cfg, ctx: ShardCtx):
    """Scalar-memory LSTM over time (sequential scan — that's its nature)."""
    res = x
    xx = L.rmsnorm(x, p['ln'], cfg.norm_eps)
    b, s, _ = xx.shape
    di = _inner(cfg)
    h_heads = cfg.n_heads
    hd = di // h_heads
    pre_x = xx @ p['w_x']                # [B,S,4*di] in model dtype
    w32 = p['w_h_blocks'].astype(jnp.float32)   # hoisted loop invariant

    # two-level scan: chunks of the time axis, converted to f32 ONCE per
    # chunk; jax.checkpoint keeps only per-chunk (h, c) carries for bwd.
    # (A flat per-step scan makes XLA either save the f32 stream — 4.3 GB —
    # or re-convert stacked blocks every step — 26 TB of traffic.)
    w = 256
    while s % w:
        w -= 1
    nc = s // w
    pre_cs = jnp.moveaxis(pre_x, 1, 0).reshape(nc, w, b, 4 * di)

    def chunk_step(carry, pre_chunk):
        pre32 = pre_chunk.astype(jnp.float32)     # one convert per chunk

        def step(carry, pre_t):
            h, c = carry
            h, c = _slstm_recur(pre_t, h, c, w32, h_heads, hd)
            return (h, c), h

        carry, hs = jax.lax.scan(step, carry, pre32)
        return carry, hs.astype(pre_chunk.dtype)

    init = (jnp.zeros((b, di), jnp.float32), jnp.zeros((b, di), jnp.float32))
    (_, _), hs = jax.lax.scan(jax.checkpoint(chunk_step), init, pre_cs)
    y = jnp.moveaxis(hs.reshape(s, b, di), 0, 1)             # [B,S,di]
    return ctx.btd(res + y @ p['w_down'])


def slstm_decode(p, x, state, cfg, ctx: ShardCtx):
    res = x
    xx = L.rmsnorm(x, p['ln'], cfg.norm_eps)
    h, c = state
    di = _inner(cfg)
    pre = (xx[:, 0] @ p['w_x']).astype(jnp.float32)
    h, c = _slstm_recur(pre, h, c, p['w_h_blocks'].astype(jnp.float32),
                        cfg.n_heads, di // cfg.n_heads)
    y = h[:, None].astype(x.dtype)
    return ctx.btd(res + y @ p['w_down']), (h, c)


# ---------------------------------------------------------------------------
# Model = super-blocks of (slstm_every-1 mLSTM + 1 sLSTM), scanned.
# ---------------------------------------------------------------------------

def _super(cfg) -> tuple[int, int]:
    se = cfg.slstm_every or (cfg.n_layers + 1)
    if cfg.n_layers % se == 0:
        return cfg.n_layers // se, se
    return 1, 0   # no clean grouping -> single group, handled unscanned


def init_params(key, cfg, tp: int = 1) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    n_super, se = _super(cfg)
    if se:
        def super_block(kk):
            km, ks_ = jax.random.split(kk)
            return {
                'mlstm': _stack_init(lambda q: mlstm_params(q, cfg, dtype),
                                     km, se - 1),
                'slstm': slstm_params(ks_, cfg, dtype),
            }
        blocks = _stack_init(super_block, k2, n_super)
    else:
        blocks = _stack_init(lambda q: mlstm_params(q, cfg, dtype),
                             k2, cfg.n_layers)
    return {'tok': L.embed_params(k1, cfg, dtype, tp), 'blocks': blocks}


def forward(params, tokens, cfg, ctx: ShardCtx) -> jax.Array:
    x = L.embed(params['tok'], tokens, ctx)
    n_super, se = _super(cfg)

    if se:
        def body(x, p_sb):
            for i in range(se - 1):
                p_m = jax.tree.map(lambda a: a[i], p_sb['mlstm'])
                x = mlstm_block(p_m, x, cfg, ctx)
            x = slstm_block(p_sb['slstm'], x, cfg, ctx)
            return x, None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(lambda c, p: body(c, p), x, params['blocks'])
    else:
        def body(x, p_m):
            return mlstm_block(p_m, x, cfg, ctx), None
        x, _ = jax.lax.scan(body, x, params['blocks'])
    return x


def train_loss(params, batch, cfg, ctx: ShardCtx) -> jax.Array:
    h = forward(params, batch['tokens'], cfg, ctx)
    return L.chunked_ce_loss(params['tok'], h, batch['labels'], cfg, ctx)


def init_state(cfg, batch: int, tp: int = 1):
    """Recurrent decode state — O(1) in sequence length (long_500k!)."""
    n_super, se = _super(cfg)
    h = cfg.n_heads
    di = _inner(cfg)
    hd = di // h
    m = jnp.zeros((n_super, max(se - 1, 1), batch, h, hd, hd + 1), jnp.float32)
    s_h = jnp.zeros((n_super, batch, di), jnp.float32)
    s_c = jnp.zeros((n_super, batch, di), jnp.float32)
    return {'mlstm': m, 'slstm_h': s_h, 'slstm_c': s_c}


def decode_step(params, token, state, pos, cfg, ctx: ShardCtx):
    del pos  # recurrent state carries position implicitly
    x = L.embed(params['tok'], token, ctx)
    n_super, se = _super(cfg)

    def body(x, xs):
        p_sb, m_states, sh, sc = xs
        new_m = []
        for i in range(se - 1):
            p_m = jax.tree.map(lambda a: a[i], p_sb['mlstm'])
            x, ns = mlstm_decode(p_m, x, m_states[i], cfg, ctx)
            new_m.append(ns)
        x, (sh, sc) = slstm_decode(p_sb['slstm'], x, (sh, sc), cfg, ctx)
        return x, (jnp.stack(new_m), sh, sc)

    x, (m_new, sh_new, sc_new) = jax.lax.scan(
        body, x, (params['blocks'], state['mlstm'],
                  state['slstm_h'], state['slstm_c']))
    lg = L.logits(params['tok'], x, cfg, ctx)
    return lg[:, 0], {'mlstm': m_new, 'slstm_h': sh_new, 'slstm_c': sc_new}
