"""Mamba2 (SSD) layer — used standalone and inside the Zamba2 hybrid.

State-space duality: the Mamba2 recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * x_t B_t^T ;   y_t = h_t C_t + D x_t

is decayed linear attention with q=C_t, k=B_t, v=dt_t*x_t and per-head scalar
log-decay dt_t*A — so training uses the same chunkwise-parallel MXU core as
mLSTM (``linear_scan``), and decode is the O(1) recurrent step (long_500k).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.linear_scan import chunked_linear_attention, linear_attention_step
from repro.runtime.sharding import ShardCtx

EXPAND = 2


def _dims(cfg):
    d_inner = EXPAND * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba_params(key, cfg, dtype):
    d = cfg.d_model
    di, h, hd, ds = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        'ln': jnp.ones((d,), dtype),
        # fused in-projection: [z (gate), x, B, C, dt]
        'w_in': L.dense_init(ks[0], d, 2 * di + 2 * ds + h, dtype),
        'conv': (0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, di + 2 * ds))
                 ).astype(dtype),
        'a_log': jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        'dt_bias': jnp.zeros((h,), jnp.float32),
        'd_skip': jnp.ones((h,), jnp.float32),
        'out_norm': jnp.ones((hd,), dtype),
        'w_out': L.dense_init(ks[2], di, d, dtype,
                              scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _split_proj(p, u, cfg):
    di, h, hd, ds = _dims(cfg)
    z = u[..., :di]
    xbc = u[..., di:di + di + 2 * ds]
    dt = u[..., di + di + 2 * ds:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, cache=None):
    """Depthwise causal conv over time. xbc [B,S,C]; conv_w [K,C].

    With ``cache`` [B,K-1,C] given (decode), returns (out [B,1,C], new cache).
    """
    kk = conv_w.shape[0]
    if cache is None:
        pad = jnp.pad(xbc, ((0, 0), (kk - 1, 0), (0, 0)))
        out = sum(pad[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(kk))
        return jax.nn.silu(out), None
    window = jnp.concatenate([cache, xbc], axis=1)          # [B,K,C]
    out = jnp.einsum('bkc,kc->bc', window, conv_w)[:, None]
    return jax.nn.silu(out), window[:, 1:]


def _ssm_inputs(p, x, cfg, conv_cache=None):
    di, h, hd, ds = _dims(cfg)
    u = x @ p['w_in']
    z, xbc, dt = _split_proj(p, u, cfg)
    xbc, new_conv = _causal_conv(xbc, p['conv'], conv_cache)
    xs = xbc[..., :di]
    b_in = xbc[..., di:di + ds]
    c_in = xbc[..., di + ds:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p['dt_bias'])      # [B,S,H]
    log_a = -jnp.exp(p['a_log'])[None, None, :] * dt                 # <= 0
    bsz, s = x.shape[:2]
    v = (xs.reshape(bsz, s, h, hd).astype(jnp.float32)
         * dt[..., None]).astype(x.dtype)
    q = jnp.broadcast_to(c_in[:, :, None, :], (bsz, s, h, ds))
    k = jnp.broadcast_to(b_in[:, :, None, :], (bsz, s, h, ds))
    d_skip = (xs.reshape(bsz, s, h, hd)
              * p['d_skip'][None, None, :, None]).astype(x.dtype)
    return q, k, v, log_a, z, d_skip, new_conv


def mamba_block(p, x, cfg, ctx: ShardCtx):
    res = x
    xx = L.rmsnorm(x, p['ln'], cfg.norm_eps)
    q, k, v, log_a, z, d_skip, _ = _ssm_inputs(p, xx, cfg)
    y, _ = chunked_linear_attention(q, k, v, log_a)
    y = y + d_skip
    y = L.rmsnorm(y, p['out_norm'], cfg.norm_eps)
    bsz, s = x.shape[:2]
    y = y.reshape(bsz, s, -1) * jax.nn.silu(z)
    return ctx.btd(res + y @ p['w_out'])


def init_state(cfg, batch: int):
    di, h, hd, ds = _dims(cfg)
    return {'ssm': jnp.zeros((batch, h, ds, hd), jnp.float32),
            'conv': jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * ds),
                              jnp.dtype(cfg.dtype))}


def mamba_decode(p, x, state, cfg, ctx: ShardCtx):
    """x [B,1,D]; recurrent O(1) step."""
    res = x
    xx = L.rmsnorm(x, p['ln'], cfg.norm_eps)
    q, k, v, log_a, z, d_skip, new_conv = _ssm_inputs(
        p, xx, cfg, conv_cache=state['conv'])
    y, ssm = linear_attention_step(state['ssm'], q[:, 0], k[:, 0], v[:, 0],
                                   log_a[:, 0])
    y = y[:, None] + d_skip
    y = L.rmsnorm(y, p['out_norm'], cfg.norm_eps)
    bsz = x.shape[0]
    y = y.reshape(bsz, 1, -1) * jax.nn.silu(z)
    return ctx.btd(res + y @ p['w_out']), {'ssm': ssm, 'conv': new_conv}
