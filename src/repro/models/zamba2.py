"""Zamba2 hybrid (zamba2-1.2b): Mamba2 backbone + ONE shared attention block
invoked every ``attn_every`` layers (the Zamba trick — the attention block's
parameters are shared across all its invocation points, so the KV caches are
per-invocation but the weights appear once).

Runs long_500k: the Mamba2 state is O(1); the shared-attention KV caches at
524288 tokens are sequence-sharded over the ``data`` mesh axis
(``ShardCtx.seq_shard_kv``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2
from repro.models.transformer import _stack_init
from repro.runtime.sharding import ShardCtx


def _attn_points(cfg) -> list[int]:
    ae = cfg.attn_every or (cfg.n_layers + 1)
    return [l for l in range(cfg.n_layers) if (l + 1) % ae == 0]


def init_params(key, cfg, tp: int = 1) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ka, kb = jax.random.split(k3)
    shared = {
        'ln1': jnp.ones((cfg.d_model,), dtype),
        'ln2': jnp.ones((cfg.d_model,), dtype),
        'attn': L.attention_params(ka, cfg, dtype, tp),
        'mlp': L.mlp_params(kb, cfg, dtype),
    }
    return {
        'tok': L.embed_params(k1, cfg, dtype, tp),
        'mamba': _stack_init(lambda q: mamba2.mamba_params(q, cfg, dtype),
                             k2, cfg.n_layers),
        'shared': shared,
    }


def _shared_attn(params, x, cfg, ctx, positions):
    p = params['shared']
    x = x + L.attention_train(p['attn'], L.rmsnorm(x, p['ln1'], cfg.norm_eps),
                              cfg, ctx, positions)
    x = x + L.mlp(p['mlp'], L.rmsnorm(x, p['ln2'], cfg.norm_eps), cfg, ctx)
    return ctx.btd(x)


def forward(params, tokens, cfg, ctx: ShardCtx) -> jax.Array:
    b, s = tokens.shape
    x = L.embed(params['tok'], tokens, ctx)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    points = _attn_points(cfg)

    # Segment structure: scan over the mamba layers BETWEEN attention points,
    # apply the (weight-shared, unscanned) attention block at each point.
    # No lax.cond inside the scan — every scan body executes exactly
    # trip-count times, which keeps the HLO cost analysis exact.
    def body(x, p_m):
        return mamba2.mamba_block(p_m, x, cfg, ctx), None

    if cfg.remat:
        body = jax.checkpoint(body)
    seg_bounds = [0] + [p + 1 for p in points]
    if seg_bounds[-1] != cfg.n_layers:
        seg_bounds.append(cfg.n_layers)
    for si in range(len(seg_bounds) - 1):
        lo, hi = seg_bounds[si], seg_bounds[si + 1]
        if hi > lo:
            seg_params = jax.tree.map(lambda a: a[lo:hi], params['mamba'])
            x, _ = jax.lax.scan(body, x, seg_params)
        if si < len(points):
            x = _shared_attn(params, x, cfg, ctx, positions)
    return x


def train_loss(params, batch, cfg, ctx: ShardCtx) -> jax.Array:
    h = forward(params, batch['tokens'], cfg, ctx)
    return L.chunked_ce_loss(params['tok'], h, batch['labels'], cfg, ctx)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_state(cfg, batch: int, max_seq: int, tp: int = 1, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim()
    n_pts = len(_attn_points(cfg))
    kv_shape = (n_pts, batch, max_seq, cfg.n_kv_heads, hd)
    ssm = mamba2.init_state(cfg, batch)
    return {
        'ssm': jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), ssm),
        'kv_k': jnp.zeros(kv_shape, dtype),
        'kv_v': jnp.zeros(kv_shape, dtype),
    }


def decode_step(params, token, state, pos, cfg, ctx: ShardCtx):
    x = L.embed(params['tok'], token, ctx)
    points = _attn_points(cfg)

    # mamba layers: scan with per-layer recurrent states
    def body(x, xs):
        p_m, st = xs
        x, st = mamba2.mamba_decode(p_m, x, st, cfg, ctx)
        return x, st

    # process in segments between attention points so the shared attention
    # block (unscanned, shared weights, per-point KV) interleaves correctly
    n_pts = len(points)
    seg_bounds = [0] + [p + 1 for p in points]
    if seg_bounds[-1] != cfg.n_layers:
        seg_bounds.append(cfg.n_layers)
    new_ssm = []
    kv_k, kv_v = state['kv_k'], state['kv_v']
    p_shared = params['shared']
    for si in range(len(seg_bounds) - 1):
        lo, hi = seg_bounds[si], seg_bounds[si + 1]
        seg_params = jax.tree.map(lambda a: a[lo:hi], params['mamba'])
        seg_state = jax.tree.map(lambda a: a[lo:hi], state['ssm'])
        x, seg_new = jax.lax.scan(body, x, (seg_params, seg_state))
        new_ssm.append(seg_new)
        if si < n_pts:
            h = L.rmsnorm(x, p_shared['ln1'], cfg.norm_eps)
            y, (k_i, v_i) = L.attention_decode(
                p_shared['attn'], h, cfg, ctx, (kv_k[si], kv_v[si]), pos)
            x = x + y
            x = x + L.mlp(p_shared['mlp'],
                          L.rmsnorm(x, p_shared['ln2'], cfg.norm_eps), cfg, ctx)
            x = ctx.btd(x)
            kv_k = kv_k.at[si].set(k_i)
            kv_v = kv_v.at[si].set(v_i)

    ssm_new = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm)
    lg = L.logits(params['tok'], x, cfg, ctx)
    return lg[:, 0], {'ssm': ssm_new, 'kv_k': kv_k, 'kv_v': kv_v}
