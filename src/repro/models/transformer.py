"""Dense decoder-only transformer (yi-34b, command-r-35b, smollm-360m,
nemotron-4-15b, chameleon-34b).

Layers are parameter-stacked on a leading [L] axis and consumed by
``lax.scan`` (small HLO, fast 512-way GSPMD compile); ``cfg.remat`` wraps the
block in ``jax.checkpoint`` for activation recomputation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.runtime.sharding import ShardCtx


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(key, cfg, tp: int = 1) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)

    def block(k):
        ka, kb = jax.random.split(k)
        return {
            'ln1': jnp.ones((cfg.d_model,), dtype),
            'ln2': jnp.ones((cfg.d_model,), dtype),
            'attn': L.attention_params(ka, cfg, dtype, tp),
            'mlp': L.mlp_params(kb, cfg, dtype),
        }

    return {
        'tok': L.embed_params(k1, cfg, dtype, tp),
        'blocks': _stack_init(block, k2, cfg.n_layers),
    }


def _block_train(p, x, cfg, ctx: ShardCtx, positions):
    x = x + L.attention_train(p['attn'], L.rmsnorm(x, p['ln1'], cfg.norm_eps),
                              cfg, ctx, positions)
    x = x + L.mlp(p['mlp'], L.rmsnorm(x, p['ln2'], cfg.norm_eps), cfg, ctx)
    return ctx.btd(x)


def forward(params, tokens, cfg, ctx: ShardCtx) -> jax.Array:
    """tokens [B, S] -> final hidden [B, S, D]."""
    b, s = tokens.shape
    x = L.embed(params['tok'], tokens, ctx)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    blk = functools.partial(_block_train, cfg=cfg, ctx=ctx, positions=positions)
    if cfg.remat:
        blk = jax.checkpoint(blk)

    if cfg.scan_layers:
        def body(x, p_l):
            return blk(p_l, x), None
        x, _ = jax.lax.scan(body, x, params['blocks'])
    else:
        for i in range(cfg.n_layers):
            p_l = jax.tree.map(lambda a: a[i], params['blocks'])
            x = blk(p_l, x)
    return x


def train_loss(params, batch, cfg, ctx: ShardCtx) -> jax.Array:
    h = forward(params, batch['tokens'], cfg, ctx)
    return L.chunked_ce_loss(params['tok'], h, batch['labels'], cfg, ctx)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_seq: int, tp: int = 1, dtype=None):
    """Per-layer stacked KV cache [L, B, T, Hkv, hd] (pair)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim()
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def prefill(params, tokens, cfg, ctx: ShardCtx):
    """tokens [B, S] -> (logits of last position [B, V], kv caches)."""
    b, s = tokens.shape
    x = L.embed(params['tok'], tokens, ctx)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p_l):
        h = L.rmsnorm(x, p_l['ln1'], cfg.norm_eps)
        y, (k, v) = L.attention_prefill(p_l['attn'], h, cfg, ctx, positions)
        x = x + y
        x = x + L.mlp(p_l['mlp'], L.rmsnorm(x, p_l['ln2'], cfg.norm_eps),
                      cfg, ctx)
        return ctx.btd(x), (k, v)

    x, caches = jax.lax.scan(body, x, params['blocks'])
    lg = L.logits(params['tok'], x[:, -1:, :], cfg, ctx)
    return lg[:, 0], caches


def decode_step(params, token, caches, pos, cfg, ctx: ShardCtx):
    """One decode step.  token [B, 1] int32; caches [L, B, T, Hkv, hd] pair;
    pos: scalar int32 position to write.  Returns (logits [B, V], caches)."""
    x = L.embed(params['tok'], token, ctx)

    def body(x, xs):
        p_l, kc, vc = xs
        h = L.rmsnorm(x, p_l['ln1'], cfg.norm_eps)
        y, (kc, vc) = L.attention_decode(p_l['attn'], h, cfg, ctx, (kc, vc), pos)
        x = x + y
        x = x + L.mlp(p_l['mlp'], L.rmsnorm(x, p_l['ln2'], cfg.norm_eps),
                      cfg, ctx)
        return ctx.btd(x), (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params['blocks'],) + caches)
    lg = L.logits(params['tok'], x, cfg, ctx)
    return lg[:, 0], (k_new, v_new)
