"""Chunkwise-parallel gated linear attention — the shared compute core of
xLSTM's mLSTM and Mamba2's SSD (both are decayed linear attention).

Recurrence (per head, per step):
    S_t = a_t * S_{t-1} + k_t v_t^T          # state [dk, dv]
    y_t = q_t^T S_t                           # output [dv]

Chunkwise form (chunk width W): within a chunk, cumulative log-decays make
the intra-chunk term a masked (W x W) matmul and the inter-chunk term a rank-
dk update — all MXU work, no per-token scan:

    F_t   = sum_{j<=t} log a_j                           (in-chunk cumsum)
    intra = ((Q K^T) * exp(F_t - F_s) * [s<=t]) V
    inter = exp(F_t) * (Q @ S_prev)
    S_new = exp(F_W) * S_prev + sum_s exp(F_W - F_s) k_s v_s^T

Gates must satisfy log a <= 0 (sigmoid/negative-exponential decay) so every
exponent above is bounded — see DESIGN.md for the xLSTM exponential-gate
stabilization note.

``normalize=True`` appends a ones-column to V so the same recurrence carries
the mLSTM normalizer n_t; outputs are divided by max(|n^T q|, 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_linear_attention(q, k, v, log_a, *, chunk: int = 512,
                             normalize: bool = False,
                             state_in=None):
    """q,k: [B,S,H,dk]; v: [B,S,H,dv]; log_a: [B,S,H] (<= 0).

    Returns (y [B,S,H,dv], final state [B,H,dk,dv(+1)]).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if normalize:
        v = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], -1)
        dv_aug = dv + 1
    else:
        dv_aug = dv
    w = min(chunk, s)
    while s % w:
        w -= 1
    nc = s // w

    def resh(x):
        return jnp.moveaxis(x.reshape(b, nc, w, *x.shape[2:]), 1, 0)

    qr, kr, vr, ar = resh(q), resh(k), resh(v), resh(log_a)   # [nc,B,w,...]

    if state_in is None:
        state_in = jnp.zeros((b, h, dk, dv_aug), jnp.float32)

    def step(state, xs):
        qc, kc, vc, ac = xs                     # [B,w,H,*]
        f = jnp.cumsum(ac.astype(jnp.float32), axis=1)        # [B,w,H]
        f_tot = f[:, -1]                                       # [B,H]
        # intra-chunk: masked decayed attention
        qk = jnp.einsum('bthd,bshd->bhts', qc.astype(jnp.float32),
                        kc.astype(jnp.float32))                # [B,H,w,w]
        decay = f[:, :, None, :].transpose(0, 3, 1, 2) \
            - f[:, None, :, :].transpose(0, 3, 1, 2)           # [B,H,t,s]
        tri = jnp.tril(jnp.ones((w, w), bool))
        # mask BEFORE exp: the upper triangle has positive exponents that
        # overflow, and inf*0 in the cotangent would poison the backward pass
        gate = jnp.exp(jnp.where(tri[None, None], decay, -1e30))
        intra = jnp.einsum('bhts,bshv->bthv', qk * gate,
                           vc.astype(jnp.float32))             # [B,w,H,dv]
        # inter-chunk: carry-in state
        qs = qc.astype(jnp.float32) * jnp.exp(f)[..., None]    # [B,w,H,dk]
        inter = jnp.einsum('bthd,bhdv->bthv', qs, state)
        y = intra + inter
        # state update
        kd = kc.astype(jnp.float32) * jnp.exp(f_tot[:, None] - f)[..., None]
        outer = jnp.einsum('bshd,bshv->bhdv', kd, vc.astype(jnp.float32))
        state = state * jnp.exp(f_tot)[..., None, None] + outer
        return state, y

    # scan-over-checkpoint: the bwd recomputes each chunk's intra/inter
    # matrices instead of saving them; only the carried state (the mLSTM
    # matrix memory — [B,H,dk,dv], 269 MB/chunk at xlstm-1.3b sizes) is
    # saved per iteration, which with chunk=512 is 8 saves instead of 32.
    state, ys = jax.lax.scan(jax.checkpoint(step), state_in, (qr, kr, vr, ar))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dv_aug)

    if normalize:
        out, n_q = y[..., :dv], y[..., dv]
        out = out / jnp.maximum(jnp.abs(n_q), 1.0)[..., None]
        return out.astype(q.dtype), state
    return y.astype(q.dtype), state


def linear_attention_step(state, q, k, v, log_a, *, normalize: bool = False):
    """Single-token recurrent step (decode).  q,k: [B,H,dk]; v: [B,H,dv];
    log_a: [B,H]; state [B,H,dk,dv(+1)].  Returns (y [B,H,dv], new state)."""
    dv = v.shape[-1]
    if normalize:
        v = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], -1)
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    outer = jnp.einsum('bhd,bhv->bhdv', k.astype(jnp.float32),
                       v.astype(jnp.float32))
    state = state * a + outer
    y = jnp.einsum('bhd,bhdv->bhv', q.astype(jnp.float32), state)
    if normalize:
        out, n_q = y[..., :dv], y[..., dv]
        out = out / jnp.maximum(jnp.abs(n_q), 1.0)[..., None]
        return out.astype(q.dtype), state
    return y.astype(q.dtype), state
