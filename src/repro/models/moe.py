"""Mixture-of-Experts transformer (granite-moe-1b top-8, llama4-maverick top-1).

Token dispatch is sort-based (Megablocks-style): assignments are sorted by
expert id with one global ``argsort``, ranked within expert, and scattered
into fixed-capacity buckets [E, C, D].  Expert FFNs run as one batched
einsum over the expert axis — which shards over the mesh ``model`` axis (EP);
GSPMD turns the scatter/gather across data-sharded tokens into all-to-alls.

Capacity overflow drops tokens (standard GShard semantics); drop statistics
are part of the debug outputs so tests can assert the factor is adequate.

Maverick specifics: MoE every other layer (``moe_every=2`` — this is what
makes 400B total / 17B active arithmetic work out), a always-on shared
expert added to the routed output, sigmoid router gate for top-1.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.transformer import _stack_init
from repro.runtime.sharding import ShardCtx


def moe_capacity(cfg, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return (c + 127) // 128 * 128


def moe_params(key, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = 0.02
    p = {
        'router': (scale * jax.random.normal(ks[0], (d, e))).astype(jnp.float32),
        'w_up': (scale * jax.random.normal(ks[1], (e, d, f))).astype(dtype),
        'w_down': (scale / math.sqrt(2 * cfg.n_layers)
                   * jax.random.normal(ks[2], (e, f, d))).astype(dtype),
    }
    if cfg.act == 'swiglu':
        p['w_gate'] = (scale * jax.random.normal(ks[3], (e, d, f))).astype(dtype)
    if cfg.shared_expert:
        p['shared'] = L.mlp_params(ks[4], cfg, dtype)
    return p


def _route(router, xf, k):
    """Top-k routing. xf [n, d] -> (weights [n, k], expert ids [n, k])."""
    rl = xf.astype(jnp.float32) @ router                   # [n, E]
    top_vals, top_idx = jax.lax.top_k(rl, k)               # [n, k]
    if k == 1:
        weights = jax.nn.sigmoid(top_vals)                 # llama4-style gate
    else:
        weights = jax.nn.softmax(top_vals, axis=-1)
    return weights, top_idx


def _dispatch_compute_combine(xf, weights, top_idx, w_up, w_gate, w_down,
                              cfg, cap: int):
    """Sort-based dispatch -> expert FFN -> combine, on LOCAL tokens.

    xf [n, d]; returns ([n, d], drop fraction).  Runs unsharded in tests and
    per-shard inside the EP shard_map (where n = tokens per device and the
    expert einsums see the device's local expert slice).
    """
    n, d = xf.shape
    k = cfg.top_k
    e = w_up.shape[0]

    flat_e = top_idx.reshape(-1)                           # [n*k]
    flat_t = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=jnp.int32), side='left')
    rank = jnp.arange(n * k, dtype=jnp.int32) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)       # out-of-range -> drop

    buckets = jnp.zeros((e * cap, d), xf.dtype).at[slot].set(
        xf[st], mode='drop').reshape(e, cap, d)

    y = _expert_ffn(buckets, w_up, w_gate, w_down, cfg).reshape(e * cap, d)

    back = jnp.where(keep[:, None], y[jnp.minimum(slot, e * cap - 1)], 0.0)
    out = jnp.zeros((n, d), xf.dtype).at[st].add(
        back * sw[:, None].astype(xf.dtype))
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out, drop_frac


def _expert_ffn(buckets, w_up, w_gate, w_down, cfg):
    """[E, C, d] -> [E, C, d] batched expert FFN (one einsum per matrix)."""
    up = jnp.einsum('ecd,edf->ecf', buckets, w_up)
    if cfg.act == 'swiglu':
        gate = jnp.einsum('ecd,edf->ecf', buckets, w_gate)
        h = jax.nn.silu(gate) * up
    else:
        h = jnp.square(jax.nn.relu(up))
    return jnp.einsum('ecf,efd->ecd', h, w_down)


def moe_ffn(p, x: jax.Array, cfg, ctx: ShardCtx):
    """x [B, S, D] -> ([B, S, D], drop_frac) through top-k routed experts.

    Two paths with identical routing semantics per token group:

    * **Local** (mesh=None, or seq not divisible by the model axis — decode):
      one global sort-based dispatch.  Fine at test scale / single-token
      decode, but under GSPMD the global scatter replicates the [B*S, d]
      dispatch buffers on every chip (measured 227 GB/device on maverick
      train_4k) — so sharded full-sequence steps take:
    * **EP shard_map** — the textbook expert-parallel schedule: each device
      dispatches its OWN tokens to local capacity buckets, an all_to_all
      over ``model`` routes bucket slices to the experts' owners, expert
      FFNs run on their 1/TP slice (FSDP-gathering their weights over
      ``data``), and a reverse all_to_all brings results home.  Capacity is
      per device group, as in real EP systems (GShard/DeepSpeed-MoE).
    """
    mesh = ctx.mesh
    ep = mesh is not None and 'model' in mesh.axis_names \
        and x.shape[1] % mesh.shape['model'] == 0 \
        and cfg.n_experts % mesh.shape['model'] == 0
    if not ep:
        b, s, d = x.shape
        xf = x.reshape(b * s, d)
        weights, top_idx = _route(p['router'], xf, cfg.top_k)
        out, drop = _dispatch_compute_combine(
            xf, weights, top_idx, p['w_up'],
            p.get('w_gate'), p['w_down'], cfg, moe_capacity(cfg, b * s))
        out = out.reshape(x.shape)
    else:
        out, drop = _moe_ffn_ep(p, x, cfg, ctx)

    if cfg.shared_expert:
        out = out + L.mlp(p['shared'], x, cfg, ctx)
    return ctx.btd(out), drop


def _moe_ffn_ep(p, x, cfg, ctx: ShardCtx):
    from jax.sharding import PartitionSpec as P
    from repro.runtime.sharding import batch_axes
    mesh = ctx.mesh
    tp = mesh.shape['model']
    baxes = batch_axes(mesh)
    b, s, d = x.shape
    bshard = 1
    for a in baxes:
        bshard *= mesh.shape[a]
    if b % bshard:
        bshard = 1                     # batch not divisible: replicate batch
        baxes = ()
    n_loc = (b // bshard) * (s // tp)
    e = cfg.n_experts
    e_loc = e // tp
    cap = moe_capacity(cfg, n_loc)     # per-device capacity
    has_gate = cfg.act == 'swiglu'

    def body(x_loc, router, w_up, w_gate, w_down):
        bl, sl, _ = x_loc.shape
        xf = x_loc.reshape(bl * sl, d)
        weights, top_idx = _route(router, xf, cfg.top_k)

        # local sort-based dispatch into per-device buckets [E, cap, d]
        k = cfg.top_k
        flat_e = top_idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(bl * sl, dtype=jnp.int32), k)
        flat_w = weights.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        starts = jnp.searchsorted(se, jnp.arange(e, dtype=jnp.int32), 'left')
        rank = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - starts[se]
        keep = rank < cap
        slot = jnp.where(keep, se * cap + rank, e * cap)
        buckets = jnp.zeros((e * cap, d), xf.dtype).at[slot].set(
            xf[st], mode='drop').reshape(e, cap, d)

        # EP all_to_all: device j receives everyone's slices for its experts
        # [E, cap, d] -> [E_loc, tp*cap, d]
        routed = jax.lax.all_to_all(buckets, 'model', split_axis=0,
                                    concat_axis=1, tiled=True)

        # FSDP: gather expert weights over 'data' (they are row-sharded)
        wu = jax.lax.all_gather(w_up, 'data', axis=1, tiled=True)
        wg = jax.lax.all_gather(w_gate, 'data', axis=1, tiled=True) \
            if has_gate else None
        wd = jax.lax.all_gather(w_down, 'data', axis=2, tiled=True)
        y = _expert_ffn(routed, wu, wg, wd, cfg)

        # reverse all_to_all: bring each device's bucket results home
        y = jax.lax.all_to_all(y, 'model', split_axis=1, concat_axis=0,
                               tiled=True).reshape(e * cap, d)

        back = jnp.where(keep[:, None], y[jnp.minimum(slot, e * cap - 1)], 0.0)
        out = jnp.zeros((bl * sl, d), xf.dtype).at[st].add(
            back * sw[:, None].astype(xf.dtype))
        # replicated drop stat (psum over the whole mesh)
        axes = tuple(mesh.axis_names)
        kept = jax.lax.psum(jnp.sum(keep.astype(jnp.float32)), axes)
        tot = jax.lax.psum(jnp.float32(keep.size), axes)
        return out.reshape(bl, sl, d), 1.0 - kept / tot

    x = ctx.btd(x)
    out, drop = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(baxes or None, 'model', None),          # x
                  P(None, None),                            # router (replicated)
                  P('model', 'data', None),                 # w_up
                  (P('model', 'data', None) if has_gate else P(None)),
                  P('model', None, 'data')),                # w_down
        out_specs=(P(baxes or None, 'model', None), P()),
        check_vma=False,
    )(x, p['router'], p['w_up'],
      p['w_gate'] if has_gate else jnp.zeros((1,), x.dtype), p['w_down'])
    return out, jnp.mean(drop)


def init_params(key, cfg, tp: int = 1) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)

    def block(kk):
        ka, kb = jax.random.split(kk)
        prm = {
            'ln1': jnp.ones((cfg.d_model,), dtype),
            'ln2': jnp.ones((cfg.d_model,), dtype),
            'attn': L.attention_params(ka, cfg, dtype, tp),
        }
        if cfg.moe_every == 1:
            prm['moe'] = moe_params(kb, cfg, dtype)
        else:
            # super-block: (dense layer, MoE layer) pair — maverick interleave
            kc, kd = jax.random.split(kb)
            ke, kf = jax.random.split(kc)
            prm['mlp'] = L.mlp_params(kd, cfg, dtype)
            prm['attn2'] = L.attention_params(ke, cfg, dtype, tp)
            prm['ln3'] = jnp.ones((cfg.d_model,), dtype)
            prm['ln4'] = jnp.ones((cfg.d_model,), dtype)
            prm['moe'] = moe_params(kf, cfg, dtype)
        return prm

    n_super = cfg.n_layers // cfg.moe_every
    return {
        'tok': L.embed_params(k1, cfg, dtype, tp),
        'blocks': _stack_init(block, k2, n_super),
    }


def _super_block(p, x, cfg, ctx: ShardCtx, positions):
    """One scan step: a dense layer (maverick) then a MoE layer."""
    if cfg.moe_every > 1:
        x = x + L.attention_train(p['attn2'],
                                  L.rmsnorm(x, p['ln3'], cfg.norm_eps),
                                  cfg, ctx, positions)
        x = x + L.mlp(p['mlp'], L.rmsnorm(x, p['ln4'], cfg.norm_eps), cfg, ctx)
    x = x + L.attention_train(p['attn'], L.rmsnorm(x, p['ln1'], cfg.norm_eps),
                              cfg, ctx, positions)
    y, drop = moe_ffn(p['moe'], L.rmsnorm(x, p['ln2'], cfg.norm_eps), cfg, ctx)
    return ctx.btd(x + y), drop


def forward(params, tokens, cfg, ctx: ShardCtx):
    b, s = tokens.shape
    x = L.embed(params['tok'], tokens, ctx)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    blk = functools.partial(_super_block, cfg=cfg, ctx=ctx, positions=positions)
    if cfg.remat:
        blk = jax.checkpoint(blk)

    def body(x, p_l):
        x, drop = blk(p_l, x)
        return x, drop

    x, drops = jax.lax.scan(body, x, params['blocks'])
    return x, jnp.mean(drops)


def train_loss(params, batch, cfg, ctx: ShardCtx):
    h, drop = forward(params, batch['tokens'], cfg, ctx)
    return L.chunked_ce_loss(params['tok'], h, batch['labels'], cfg, ctx)


# ---------------------------------------------------------------------------
# Serving: decode uses the same attention caches as the dense model; MoE FFN
# for a single token routes as a (tiny) capacity-1-per-expert dispatch.
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_seq: int, tp: int = 1, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim()
    n_super = cfg.n_layers // cfg.moe_every
    n_attn = 2 if cfg.moe_every > 1 else 1
    shape = (n_super, n_attn, batch, max_seq, cfg.n_kv_heads, hd)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def decode_step(params, token, caches, pos, cfg, ctx: ShardCtx):
    x = L.embed(params['tok'], token, ctx)

    def body(x, xs):
        p_l, kc, vc = xs
        if cfg.moe_every > 1:
            h = L.rmsnorm(x, p_l['ln3'], cfg.norm_eps)
            y, (k0, v0) = L.attention_decode(p_l['attn2'], h, cfg, ctx,
                                             (kc[0], vc[0]), pos)
            x = x + y
            x = x + L.mlp(p_l['mlp'], L.rmsnorm(x, p_l['ln4'], cfg.norm_eps),
                          cfg, ctx)
            idx_main = 1
        else:
            k0 = v0 = None
            idx_main = 0
        h = L.rmsnorm(x, p_l['ln1'], cfg.norm_eps)
        y, (k1, v1) = L.attention_decode(p_l['attn'], h, cfg, ctx,
                                         (kc[idx_main], vc[idx_main]), pos)
        x = x + y
        y, _ = moe_ffn(p_l['moe'], L.rmsnorm(x, p_l['ln2'], cfg.norm_eps),
                       cfg, ctx)
        x = ctx.btd(x + y)
        if cfg.moe_every > 1:
            return x, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))
        return x, (k1[None], v1[None])

    x, (k_new, v_new) = jax.lax.scan(body, x, (params['blocks'],) + caches)
    lg = L.logits(params['tok'], x, cfg, ctx)
    return lg[:, 0], (k_new, v_new)
