"""Whisper-style encoder-decoder backbone (whisper-base).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings [B, S_enc, d_model] directly; a single linear
``frontend_proj`` stands in for the projection out of the (stubbed) conv
stack.  Positional scheme is RoPE throughout (deviation from the paper's
sinusoidal/learned embeddings — not performance-relevant; noted in
DESIGN.md).  The decoder is standard: causal self-attention + cross
attention over encoder states + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.transformer import _stack_init
from repro.runtime.sharding import ShardCtx


def init_params(key, cfg, tp: int = 1) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def enc_block(k):
        ka, kb = jax.random.split(k)
        return {'ln1': jnp.ones((cfg.d_model,), dtype),
                'ln2': jnp.ones((cfg.d_model,), dtype),
                'attn': L.attention_params(ka, cfg, dtype, tp),
                'mlp': L.mlp_params(kb, cfg, dtype)}

    def dec_block(k):
        ka, kb, kc = jax.random.split(k, 3)
        return {'ln1': jnp.ones((cfg.d_model,), dtype),
                'ln2': jnp.ones((cfg.d_model,), dtype),
                'ln3': jnp.ones((cfg.d_model,), dtype),
                'attn': L.attention_params(ka, cfg, dtype, tp),
                'cross': L.attention_params(kb, cfg, dtype, tp),
                'mlp': L.mlp_params(kc, cfg, dtype)}

    enc_layers = cfg.enc_layers or cfg.n_layers
    return {
        'tok': L.embed_params(k1, cfg, dtype, tp),
        'frontend_proj': L.dense_init(k2, cfg.d_model, cfg.d_model, dtype),
        'enc': _stack_init(enc_block, k3, enc_layers),
        'dec': _stack_init(dec_block, k4, cfg.n_layers),
        'enc_norm': jnp.ones((cfg.d_model,), dtype),
    }


def encode(params, frames, cfg, ctx: ShardCtx) -> jax.Array:
    """frames [B, S_enc, D] (stub embeddings) -> encoder states."""
    b, s, _ = frames.shape
    x = ctx.btd(frames @ params['frontend_proj'])
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p_l):
        x = x + L.attention_train(p_l['attn'],
                                  L.rmsnorm(x, p_l['ln1'], cfg.norm_eps),
                                  cfg, ctx, positions, causal=False)
        x = x + L.mlp(p_l['mlp'], L.rmsnorm(x, p_l['ln2'], cfg.norm_eps),
                      cfg, ctx)
        return ctx.btd(x), None

    x, _ = jax.lax.scan(body, x, params['enc'])
    return L.rmsnorm(x, params['enc_norm'], cfg.norm_eps)


def decode_train(params, tokens, enc_out, cfg, ctx: ShardCtx) -> jax.Array:
    b, s = tokens.shape
    x = L.embed(params['tok'], tokens, ctx)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p_l):
        x = x + L.attention_train(p_l['attn'],
                                  L.rmsnorm(x, p_l['ln1'], cfg.norm_eps),
                                  cfg, ctx, positions, causal=True)
        kv = L.cross_kv(p_l['cross'], enc_out, cfg, ctx)
        x = x + L.attention_cross(p_l['cross'],
                                  L.rmsnorm(x, p_l['ln2'], cfg.norm_eps),
                                  cfg, ctx, kv)
        x = x + L.mlp(p_l['mlp'], L.rmsnorm(x, p_l['ln3'], cfg.norm_eps),
                      cfg, ctx)
        return ctx.btd(x), None

    x, _ = jax.lax.scan(body, x, params['dec'])
    return x


def train_loss(params, batch, cfg, ctx: ShardCtx) -> jax.Array:
    enc_out = encode(params, batch['frames'], cfg, ctx)
    h = decode_train(params, batch['tokens'], enc_out, cfg, ctx)
    return L.chunked_ce_loss(params['tok'], h, batch['labels'], cfg, ctx)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_seq: int, tp: int = 1, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim()
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def prepare_cross(params, frames, cfg, ctx: ShardCtx):
    """Encode once; precompute per-decoder-layer cross k/v."""
    enc_out = encode(params, frames, cfg, ctx)

    def body(_, p_l):
        return None, L.cross_kv(p_l['cross'], enc_out, cfg, ctx)

    _, cross = jax.lax.scan(body, None, params['dec'])
    return cross   # ([L,B,Se,Hkv,hd], [L,B,Se,Hkv,hd])


def decode_step(params, token, caches, cross, pos, cfg, ctx: ShardCtx):
    x = L.embed(params['tok'], token, ctx)

    def body(x, xs):
        p_l, kc, vc, ck, cv = xs
        h = L.rmsnorm(x, p_l['ln1'], cfg.norm_eps)
        y, (kc, vc) = L.attention_decode(p_l['attn'], h, cfg, ctx, (kc, vc), pos)
        x = x + y
        x = x + L.attention_cross(p_l['cross'],
                                  L.rmsnorm(x, p_l['ln2'], cfg.norm_eps),
                                  cfg, ctx, (ck, cv))
        x = x + L.mlp(p_l['mlp'], L.rmsnorm(x, p_l['ln3'], cfg.norm_eps),
                      cfg, ctx)
        return ctx.btd(x), (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params['dec'],) + caches + cross)
    lg = L.logits(params['tok'], x, cfg, ctx)
    return lg[:, 0], (k_new, v_new)
