import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step for train
shapes, prefill/decode serve steps otherwise), attaches the recipe's
in/out shardings, lowers it against ``input_specs`` ShapeDtypeStructs (no
allocation), compiles for the production mesh, and records:

  * ``compiled.memory_analysis()``  — proves the cell fits 16 GB/chip HBM;
  * ``compiled.cost_analysis()``    — XLA's own FLOPs/bytes counters;
  * parsed optimized-HLO aggregates — per-chip FLOPs / HBM bytes /
    collective bytes with while-loop trip counts applied (the roofline
    inputs; see repro.analysis.hlo_parse for why cost_analysis alone
    under-counts scanned layers);
  * the three-term roofline (repro.analysis.roofline).

Run one cell:     python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
Run everything:   python -m repro.launch.dryrun --all   (subprocess per cell)
Results land in   experiments/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rl
from repro.analysis.flops import model_flops
from repro.configs import ALL_LM_ARCHS, get_config
from repro.configs.base import SHAPES, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.optim import adam
from repro.runtime.sharding import spec_to_sharding

OUT_DIR = Path(__file__).resolve().parents[3] / 'experiments' / 'dryrun'

RENDER_SHAPES = ('render_1080p',)   # the paper-native lumina-3dgs cell


def _opt_overrides(cfg, opt: str):
    """Apply comma-separated perf-iteration overrides (§Perf knobs)."""
    if not opt:
        return cfg
    for item in opt.split(','):
        k, _, v = item.partition('=')
        k = k.strip()
        if not k:
            continue
        field_types = {f.name: f.type for f in dataclasses.fields(cfg)}
        if k not in field_types:
            raise ValueError(f'unknown override {k!r} for {cfg.name}')
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            val = v.lower() in ('1', 'true', 'yes')
        elif isinstance(cur, int):
            val = int(v)
        elif isinstance(cur, float):
            val = float(v)
        else:
            val = v
        cfg = dataclasses.replace(cfg, **{k: val})
    return cfg


# ---------------------------------------------------------------------------
# Cell builders: (fn, abstract args, in_shardings, out_shardings)
# ---------------------------------------------------------------------------

def build_lm_cell(arch: str, shape_name: str, mesh, opt: str = ''):
    cfg = _opt_overrides(get_config(arch), opt)
    shape = SHAPES[shape_name]
    long_context = shape.name == 'long_500k'
    ctx = registry.make_ctx(mesh, cfg, long_context=long_context)
    tp = registry.tp_of(mesh, cfg)

    params_abs = registry.abstract_params(cfg, tp)
    p_spec = registry.param_specs(cfg, params_abs, mesh)
    p_sh = spec_to_sharding(mesh, p_spec)
    batch_abs = registry.input_specs(cfg, shape)
    b_sh = spec_to_sharding(mesh, registry.batch_shardings(cfg, mesh, batch_abs))
    repl = NamedSharding(mesh, P())

    if shape.kind == 'train':
        step, acfg = registry.make_train_step(cfg, ctx)
        opt_abs = jax.eval_shape(lambda p: adam.init(p, acfg), params_abs)
        o_sh = adam.AdamState(step=repl,
                              mu=jax.tree.map(lambda s: s, p_sh),
                              nu=jax.tree.map(lambda s: s, p_sh))
        metrics_sh = {'loss': repl, 'grad_norm': repl}
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, metrics_sh))
        args = (params_abs, opt_abs, batch_abs)
    elif shape.kind == 'prefill':
        prefill = registry.make_prefill(cfg, ctx)
        fn = jax.jit(prefill, in_shardings=(p_sh, b_sh), out_shardings=repl)
        args = (params_abs, batch_abs)
    else:  # decode
        dstep = registry.make_decode_step(cfg, ctx)
        state_abs = registry.abstract_decode_state(
            cfg, shape.global_batch, shape.seq_len, tp)
        if cfg.family == 'encdec':
            # cross caches are precomputed at request admission; the decode
            # dry-run carries them as state (same shapes as init)
            pass
        s_spec = registry.decode_state_specs(cfg, state_abs, mesh,
                                             long_context=long_context)
        s_sh = spec_to_sharding(mesh, s_spec)
        tok_abs = batch_abs['token']
        tok_sh = spec_to_sharding(
            mesh, registry.batch_shardings(cfg, mesh, tok_abs))
        pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

        fn = jax.jit(dstep, in_shardings=(p_sh, tok_sh, s_sh, repl),
                     out_shardings=(repl, s_sh))
        args = (params_abs, tok_abs, state_abs, pos_abs)

    mf = model_flops(cfg, shape)
    return fn, args, mf


def build_render_cell(shape_name: str, mesh, opt: str = ''):
    """The paper-native workload: one LuminSys serve frame, distributed.

    Gaussians shard over 'data' (projection is embarrassingly parallel),
    tiles shard over 'model' for rasterization — the cluster-scale analogue
    of the paper's GPU(sort) / NRU(raster) split.
    """
    from repro.core import render_dist
    cfg = get_config('lumina-3dgs')
    if opt:
        cfg = _opt_overrides(cfg, opt)
    return render_dist.build_dryrun_cell(cfg, mesh, shape_name)


# ---------------------------------------------------------------------------
# One cell: lower -> compile -> analyze -> save
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             opt: str = '', save_hlo: bool = False,
             out_dir: Path = OUT_DIR) -> dict:
    multi = mesh_kind == 'multi'
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size
    pod_size = 256

    t0 = time.time()
    if arch == 'lumina-3dgs':
        fn, args, mf = build_render_cell(shape_name, mesh, opt)
    else:
        fn, args, mf = build_lm_cell(arch, shape_name, mesh, opt)

    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()

    roof = rl.from_compiled(
        arch, shape_name, mesh_kind, chips, hlo,
        model_flops=mf, pod_size=pod_size, memory_analysis=mem,
        note=opt)
    rec = {
        'arch': arch, 'shape': shape_name, 'mesh': mesh_kind,
        'chips': chips, 'opt': opt,
        'lower_s': round(t_lower, 2), 'compile_s': round(t_compile, 2),
        'memory_analysis': {
            k: int(getattr(mem, k, 0) or 0)
            for k in ('argument_size_in_bytes', 'output_size_in_bytes',
                      'temp_size_in_bytes', 'alias_size_in_bytes',
                      'generated_code_size_in_bytes')
        },
        'cost_analysis': {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))
                          and k in ('flops', 'bytes accessed',
                                    'transcendentals', 'optimal_seconds')},
        'roofline': roof.row(),
        'hlo_chars': len(hlo),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    stem = f'{arch}__{shape_name}__{mesh_kind}' + (f'__{_slug(opt)}' if opt else '')
    with open(out_dir / f'{stem}.json', 'w') as f:
        json.dump(rec, f, indent=1, default=str)
    if save_hlo:
        import gzip
        with gzip.open(out_dir / f'{stem}.hlo.txt.gz', 'wt') as f:
            f.write(hlo)
    return rec


def _slug(s: str) -> str:
    return ''.join(c if c.isalnum() else '-' for c in s)[:48]


def all_cells(include_render: bool = True):
    cells = []
    for arch in ALL_LM_ARCHS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if not shape_applicable(cfg, shape):
                continue
            cells.append((arch, sname))
    if include_render:
        for sname in RENDER_SHAPES:
            cells.append(('lumina-3dgs', sname))
    return cells


def run_all(mesh_kinds=('single', 'multi'), *, opt: str = '',
            jobs: int = 1, timeout: int = 7200, force: bool = False,
            include_render: bool = True) -> None:
    """Drive every cell in a subprocess (fresh jax per cell; crash isolation)."""
    work = []
    for arch, sname in all_cells(include_render):
        for mk in mesh_kinds:
            stem = f'{arch}__{sname}__{mk}' + (f'__{_slug(opt)}' if opt else '')
            if not force and (OUT_DIR / f'{stem}.json').exists():
                continue
            work.append((arch, sname, mk))
    print(f'{len(work)} cells to run')
    procs: list = []
    results = {'ok': 0, 'fail': 0}
    log_dir = OUT_DIR / 'logs'
    log_dir.mkdir(parents=True, exist_ok=True)

    def launch(arch, sname, mk):
        stem = f'{arch}__{sname}__{mk}' + (f'__{_slug(opt)}' if opt else '')
        log = open(log_dir / f'{stem}.log', 'w')
        cmd = [sys.executable, '-m', 'repro.launch.dryrun', '--arch', arch,
               '--shape', sname, '--mesh', mk]
        if opt:
            cmd += ['--opt', opt]
        p = subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT)
        return (p, log, time.time(), (arch, sname, mk))

    queue = list(work)
    while queue or procs:
        while queue and len(procs) < jobs:
            procs.append(launch(*queue.pop(0)))
        time.sleep(5)
        still = []
        for p, log, t0, cell in procs:
            if p.poll() is None:
                if time.time() - t0 > timeout:
                    p.kill()
                    print(f'TIMEOUT {cell}')
                    results['fail'] += 1
                    log.close()
                else:
                    still.append((p, log, t0, cell))
            else:
                ok = p.returncode == 0
                results['ok' if ok else 'fail'] += 1
                dt = time.time() - t0
                print(f'{"OK  " if ok else "FAIL"} {cell} ({dt:.0f}s)')
                log.close()
        procs = still
    print(f"done: {results['ok']} ok, {results['fail']} failed")


def collect_table() -> list[dict]:
    rows = []
    for f in sorted(OUT_DIR.glob('*.json')):
        with open(f) as fh:
            rec = json.load(fh)
        rows.append(rec['roofline'] | {
            'compile_s': rec['compile_s'],
            'temp_bytes': rec['memory_analysis'].get('temp_size_in_bytes', 0),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch')
    ap.add_argument('--shape')
    ap.add_argument('--mesh', choices=('single', 'multi'), default='single')
    ap.add_argument('--opt', default='', help='cfg overrides, k=v,k=v')
    ap.add_argument('--all', action='store_true')
    ap.add_argument('--force', action='store_true')
    ap.add_argument('--jobs', type=int, default=1)
    ap.add_argument('--timeout', type=int, default=7200)
    ap.add_argument('--save-hlo', action='store_true')
    ap.add_argument('--table', action='store_true',
                    help='print the collected roofline table and exit')
    args = ap.parse_args()

    if args.table:
        print(rl.fmt_table(collect_table()))
        return
    if args.all:
        run_all(opt=args.opt, jobs=args.jobs, timeout=args.timeout,
                force=args.force)
        return
    assert args.arch and args.shape, '--arch/--shape or --all required'
    rec = run_cell(args.arch, args.shape, args.mesh, opt=args.opt,
                   save_hlo=args.save_hlo)
    print(json.dumps({k: rec[k] for k in
                      ('arch', 'shape', 'mesh', 'lower_s', 'compile_s')},
                     indent=1))
    print('memory_analysis:', rec['memory_analysis'])
    print('cost_analysis:', rec['cost_analysis'])
    r = rec['roofline']
    print(f"roofline: compute={rl.fmt_seconds(r['t_compute_s'])} "
          f"memory={rl.fmt_seconds(r['t_memory_s'])} "
          f"collective={rl.fmt_seconds(r['t_collective_s'])} "
          f"bound={r['bottleneck']} useful={r['useful_ratio']:.2f} "
          f"roofline%={100 * r['roofline_fraction']:.1f}")


if __name__ == '__main__':
    main()
