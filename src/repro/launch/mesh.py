"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's forced-host-
device trick to work and for tests to see a single CPU device.

Production target: TPU v5e pods.  Single pod = 16 x 16 = 256 chips
(data, model); multi-pod adds a leading 'pod' axis (2 x 16 x 16 = 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ('pod', 'data', 'model') if multi_pod else ('data', 'model')
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f'need {n} devices for the production mesh, have {len(devices)} — '
            f'launch with XLA_FLAGS=--xla_force_host_platform_device_count=512 '
            f'for the dry-run (see launch/dryrun.py)')
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=('data', 'model')):
    """Small mesh for unit tests (requires forced host devices)."""
    import numpy as np
    n = 1
    for s in shape:
        n *= s
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def make_serve_mesh(num_devices: int | None = None):
    """1-D ``devices`` mesh for the sharded serving fleet (one scene-block
    worker per device).  Requires genuinely distinct devices — jax meshes
    reject duplicates — so CPU CI launches with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``."""
    import numpy as np
    from repro.runtime.sharding import DEVICES_AXIS
    avail = jax.devices()
    n = len(avail) if num_devices is None else num_devices
    if len(avail) < n:
        raise RuntimeError(
            f'need {n} devices for the serving mesh, have {len(avail)} — '
            f'launch with XLA_FLAGS=--xla_force_host_platform_device_count='
            f'{n} on CPU')
    return jax.sharding.Mesh(np.asarray(avail[:n]), (DEVICES_AXIS,))


def serve_devices(num_workers: int) -> list:
    """Device handle per fleet worker, cycling over the available devices.

    Unlike a mesh, workers may OVERSUBSCRIBE: tier-1 CI runs the N-worker
    fleet on a single CPU device (workers are independent host loops over
    per-device steppers, not collective participants), while the
    multi-device CI job and real deployments get one worker per distinct
    device."""
    avail = jax.devices()
    return [avail[i % len(avail)] for i in range(num_workers)]
