"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's forced-host-
device trick to work and for tests to see a single CPU device.

Production target: TPU v5e pods.  Single pod = 16 x 16 = 256 chips
(data, model); multi-pod adds a leading 'pod' axis (2 x 16 x 16 = 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ('pod', 'data', 'model') if multi_pod else ('data', 'model')
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f'need {n} devices for the production mesh, have {len(devices)} — '
            f'launch with XLA_FLAGS=--xla_force_host_platform_device_count=512 '
            f'for the dry-run (see launch/dryrun.py)')
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=('data', 'model')):
    """Small mesh for unit tests (requires forced host devices)."""
    import numpy as np
    n = 1
    for s in shape:
        n *= s
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)
