"""Batched serving driver: continuous-batching decode loop for any --arch.

A deliberately small but real serving core:
  * request queue with Poisson-ish deterministic arrivals;
  * **continuous batching**: finished slots are refilled between decode
    steps (the KV cache slot is reassigned; its `pos` tracks per-slot);
  * prefill-on-admit (one prefill per admitted request, its KV written
    into the slot), then one fused decode step per tick for all slots;
  * greedy sampling with a per-request max-token budget.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --slots 4 --requests 12 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.tokens import synthetic_tokens
from repro.models import registry


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    admitted_at: float = 0.0
    done_at: float = 0.0


class Server:
    """Slot-based continuous batching over the registry's serve steps."""

    def __init__(self, arch: str, *, slots: int = 4, max_seq: int = 512,
                 full: bool = False, mesh=None):
        cfg = get_config(arch)
        if not full:
            cfg = cfg.reduced()
        self.cfg = cfg
        self.ctx = registry.make_ctx(mesh, cfg)
        tp = registry.tp_of(mesh, cfg)
        self.params = registry.init_params(jax.random.PRNGKey(0), cfg, tp)
        self.slots = slots
        self.max_seq = max_seq

        self.decode_fn = jax.jit(registry.make_decode_step(cfg, self.ctx))
        self.state = registry.init_decode_state(cfg, slots, max_seq, tp)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = [0] * slots
        self.cur_tok = jnp.zeros((slots, 1), jnp.int32)

        # per-slot prefill: write the prompt's KV into this slot via the
        # decode step (teacher-forcing loop) — simple and always correct
        # for every family (ssm/hybrid carry recurrent state the same way).

    def admit(self, req: Request, slot: int) -> None:
        req.admitted_at = time.time()
        self.slot_req[slot] = req
        self.slot_pos[slot] = 0
        if self.cfg.family == 'ssm':
            # recurrent state: zero this slot's entries
            self.state = jax.tree.map(
                lambda a: a.at[..., slot, :, :, :].set(0.0)
                if a.ndim >= 4 else a, self.state)
        # feed the prompt token-by-token through the decode step
        for t in range(req.prompt.shape[0]):
            tok = jnp.zeros((self.slots, 1), jnp.int32).at[slot, 0].set(
                req.prompt[t])
            tok = jnp.where(jnp.arange(self.slots)[:, None] == slot,
                            tok, self.cur_tok)
            logits, self.state = self.decode_fn(
                self.params, tok, self.state, jnp.int32(self.slot_pos[slot]))
            self.slot_pos[slot] += 1
        nxt = int(jnp.argmax(logits[slot]))
        self.cur_tok = self.cur_tok.at[slot, 0].set(nxt)
        req.out.append(nxt)

    def step(self) -> list[Request]:
        """One fused decode tick for all active slots.

        Returns the requests that finished on this tick (their slots are
        freed and can be refilled before the next tick).
        """
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        pos = max(self.slot_pos[i] for i in active)
        logits, self.state = self.decode_fn(
            self.params, self.cur_tok, self.state, jnp.int32(pos))
        nxt = jnp.argmax(logits, axis=-1)
        finished = []
        for i in active:
            r = self.slot_req[i]
            tok = int(nxt[i])
            r.out.append(tok)
            self.slot_pos[i] = pos + 1
            if len(r.out) >= r.max_new or self.slot_pos[i] >= self.max_seq - 1:
                r.done_at = time.time()
                self.slot_req[i] = None
                finished.append(r)
        self.cur_tok = nxt[:, None].astype(jnp.int32)
        return finished

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]


def run(arch: str, *, slots: int = 4, n_requests: int = 8,
        prompt_len: int = 8, max_new: int = 16, max_seq: int = 256,
        print_fn=print) -> dict:
    server = Server(arch, slots=slots, max_seq=max_seq)
    cfg = server.cfg
    pending = [
        Request(rid=i,
                prompt=synthetic_tokens(7, i, 1, prompt_len, cfg.vocab)[0],
                max_new=max_new)
        for i in range(n_requests)
    ]
    done: list[Request] = []
    t0 = time.time()
    ticks = 0
    while pending or any(server.slot_req):
        for slot in server.free_slots():
            if not pending:
                break
            server.admit(pending.pop(0), slot)
        done.extend(server.step())
        ticks += 1
        if ticks > 10000:
            raise RuntimeError('serve loop did not drain')
    dt = time.time() - t0
    # tokens actually emitted (requests can stop early at max_seq)
    total_tokens = sum(len(r.out) for r in done)
    stats = {'requests': n_requests, 'completed': len(done), 'ticks': ticks,
             'tokens': total_tokens, 'wall_s': dt,
             'tok_per_s': total_tokens / dt}
    print_fn(f'{arch}: {len(done)}/{n_requests} requests, {ticks} ticks, '
             f'{total_tokens} tokens, {stats["tok_per_s"]:.1f} tok/s')
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', required=True)
    ap.add_argument('--slots', type=int, default=4)
    ap.add_argument('--requests', type=int, default=8)
    ap.add_argument('--prompt-len', type=int, default=8)
    ap.add_argument('--max-new', type=int, default=16)
    ap.add_argument('--max-seq', type=int, default=256)
    args = ap.parse_args()
    run(args.arch, slots=args.slots, n_requests=args.requests,
        prompt_len=args.prompt_len, max_new=args.max_new,
        max_seq=args.max_seq)


if __name__ == '__main__':
    main()
