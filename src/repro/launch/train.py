"""Production train driver: any --arch, fault-tolerant, instrumented.

Wires together the full substrate:
  data (deterministic sharded stream) -> model (registry) -> optimizer
  (AdamW + schedule + optional int8 error-feedback gradient compression)
  -> checkpoint manager (async, keep-K, auto-resume) -> straggler detector
  -> elastic re-mesh on simulated failures.

On this CPU container it runs reduced configs end-to-end (the examples/
scripts call into here); on a real pod the same driver runs the full
configs — the only difference is the mesh constructor and --full.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.models import registry
from repro.optim import adam, schedule
from repro.runtime.straggler import StragglerDetector


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 256,
          lr: float = 3e-4, warmup: int = 20, ckpt_dir: str = '',
          ckpt_every: int = 50, keep: int = 3, seed: int = 0,
          full: bool = False, mesh=None, log_every: int = 10,
          print_fn=print):
    cfg = get_config(arch)
    if not full:
        cfg = cfg.reduced()
    ctx = registry.make_ctx(mesh, cfg)
    tp = registry.tp_of(mesh, cfg)

    params = registry.init_params(jax.random.PRNGKey(seed), cfg, tp)
    acfg = adam.AdamConfig(lr=lr, state_dtype=jnp.dtype(cfg.opt_state_dtype))

    # NOTE: the schedule must depend only on (step, warmup, steps) as given —
    # checkpoint resume replays a prefix run with a smaller --steps and relies
    # on the overlapping region seeing identical lr scales.
    def sched(step):
        return schedule.linear_warmup_cosine(
            step, warmup_steps=warmup, total_steps=steps)

    mod = registry.module_for(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: mod.train_loss(p, batch, cfg, ctx))(params)
        params, opt_state, gnorm = adam.step(
            params, grads, opt_state, acfg, lr_scale=sched(opt_state.step))
        return params, opt_state, {'loss': loss, 'grad_norm': gnorm}

    step_fn = jax.jit(train_step)
    opt_state = adam.init(params, acfg)

    stream = TokenStream(seed=seed, global_batch=batch, seq=seq,
                         vocab=cfg.vocab)
    mgr = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None
    start = 0
    if mgr is not None:
        restored = mgr.restore_latest((params, opt_state))
        if restored is not None:
            (params, opt_state), start, extra = restored
            stream.load_state_dict(extra['stream'])
            print_fn(f'resumed from step {start}')

    detector = StragglerDetector(num_hosts=1)
    history = []
    for step in range(start, steps):
        t0 = time.time()
        b = stream.next()
        if cfg.family == 'encdec':
            b = dict(b, frames=_frames_for(cfg, b['tokens']))
        params, opt_state, metrics = step_fn(params, opt_state, b)
        loss = float(metrics['loss'])
        dt = time.time() - t0
        detector.observe(0, dt)
        history.append(loss)
        if log_every and step % log_every == 0:
            print_fn(f'step {step:5d}  loss {loss:.4f}  '
                     f'gnorm {float(metrics["grad_norm"]):.3f}  {dt * 1e3:.0f}ms')
        if mgr is not None and ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save((params, opt_state), step=step + 1,
                     extra={'stream': stream.state_dict()})
    if mgr is not None:
        mgr.save((params, opt_state), step=steps,
                 extra={'stream': stream.state_dict()}, blocking=True)
    return params, opt_state, history


def _frames_for(cfg, tokens):
    """Stub modality frontend: hash-embed the token ids as frames."""
    b, s = tokens.shape
    base = jnp.sin(tokens[..., None].astype(jnp.float32)
                   * jnp.arange(1, cfg.d_model + 1) * 0.01)
    return base.astype(jnp.dtype(cfg.dtype))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', required=True)
    ap.add_argument('--steps', type=int, default=100)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq', type=int, default=256)
    ap.add_argument('--lr', type=float, default=3e-4)
    ap.add_argument('--ckpt-dir', default='')
    ap.add_argument('--ckpt-every', type=int, default=50)
    ap.add_argument('--seed', type=int, default=0)
    ap.add_argument('--full', action='store_true',
                    help='full config (pod scale); default: reduced')
    args = ap.parse_args()
    _, _, history = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        seed=args.seed, full=args.full)
    print(f'final loss {history[-1]:.4f} (from {history[0]:.4f})')


if __name__ == '__main__':
    main()
