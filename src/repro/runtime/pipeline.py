"""Pipeline parallelism (GPipe) over the 'pod' axis — beyond-paper optional.

On the 2-pod production mesh the default recipe treats 'pod' as a batch
axis, which puts the full gradient all-reduce on the (slow) cross-pod
links.  ``recipe="pp"`` instead places HALF the layers on each pod:
activations cross pods once per microbatch in each direction
(point-to-point, tiny vs. the gradient sum) and the gradient all-reduce
never leaves a pod.

Implementation: classic GPipe with ``jax.shard_map`` over 'pod' +
``lax.ppermute`` boundary exchange, microbatching with a python loop at
trace time (fixed microbatch count -> static HLO).  Both pods execute the
SAME program (SPMD): each holds its own stage's layer stack; stage-0
iterations where a pod has no work run on zero inputs and are masked out —
the standard SPMD-GPipe bubble.

Scope: 2 stages (matching the assigned 2-pod mesh); tested functionally on
a forced-device mesh against the unpipelined reference.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def split_stage_params(params_blocks, n_stages: int, stage_axis: int = 0):
    """Split a layer-stacked param tree [L, ...] into [n_stages, L/s, ...].

    The result gains a leading stage axis that shards over 'pod'.
    """
    def split(x):
        l = x.shape[stage_axis]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape((n_stages, l // n_stages) + x.shape[1:])
    return jax.tree.map(split, params_blocks)


def gpipe_forward(block_fn: Callable, stage_params, x, *, mesh,
                  n_microbatches: int, axis: str = 'pod'):
    """Run ``x`` [B, S, D] through 2 pipeline stages over ``axis``.

    ``block_fn(params_stack, x) -> x`` applies one stage's layer stack.
    ``stage_params`` has a leading [2, ...] stage axis (sharded over pod).
    Returns the final activations (valid on the LAST stage; both pods hold
    the same values after the closing ppermute).
    """
    n_stages = mesh.shape[axis]
    assert n_stages == 2, 'GPipe schedule instantiated for the 2-pod mesh'
    b = x.shape[0]
    assert b % n_microbatches == 0
    mb = b // n_microbatches

    def body(params_local, x_local):
        # params_local: this pod's stage stack [1, L/2, ...] -> [L/2, ...]
        p_stage = jax.tree.map(lambda a: a[0], params_local)
        stage_id = jax.lax.axis_index(axis)

        micro = [x_local[i * mb:(i + 1) * mb] for i in range(n_microbatches)]
        zeros = jnp.zeros_like(micro[0])
        # schedule: n_micro + (stages-1) ticks; stage s works on microbatch
        # (t - s) at tick t.  Boundary exchange after every tick.
        inflight = zeros
        outputs = []
        for t in range(n_microbatches + n_stages - 1):
            feed_idx = t if t < n_microbatches else 0
            feed = micro[feed_idx]
            stage_in = jnp.where(stage_id == 0, feed, inflight)
            has_work = jnp.where(
                stage_id == 0,
                jnp.asarray(t < n_microbatches),
                jnp.asarray(0 < t <= n_microbatches))
            out = block_fn(p_stage, stage_in)
            out = jnp.where(has_work, out, zeros)
            # stage0 -> stage1 handoff (and stage1's finished microbatch
            # wraps to stage0's slot, where it is ignored)
            inflight = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            if 0 < t <= n_microbatches:
                outputs.append(out)   # stage 1's completed microbatch
        y = jnp.concatenate(outputs, axis=0)
        # broadcast the final activations from the last stage to all pods
        y = jax.lax.ppermute(
            y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
        y = jnp.where(stage_id == 0, y, jnp.concatenate(outputs, axis=0))
        return y

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params),
                  P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)
