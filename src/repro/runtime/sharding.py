"""Sharding recipes: logical activation/parameter layouts per architecture.

The production mesh is fixed — ``(data=16, model=16)`` per pod, with a
leading ``pod`` axis when multi-pod — so recipes map tensor dimensions onto
those axes:

  * ``tp``  : TP over ``model`` (heads / d_ff / vocab), FSDP over ``data``
              (parameter + optimizer-state rows), batch over pod x data,
              **sequence parallelism** for residuals (the [B,S,D] stream is
              sharded over ``model`` between layers — Megatron-SP style; the
              per-layer all-gather/reduce-scatter pair is inserted by GSPMD).
  * ``dp``  : small models — params replicated, batch over pod x data,
              residual sequence over ``model`` (DP+SP).
  * ``ep``  : MoE — experts over ``model``, expert-internal FSDP over
              ``data``; dense submodules follow ``tp``.
  * ``ssm`` : params FSDP over ``data`` + inner-dim TP over ``model`` where
              divisible; batch over pod x data; long-context KV sequence
              sharded over ``data``.

Every constraint is **divisibility-adaptive**: an axis is applied to a
tensor dimension only when the (static) dimension is divisible by the axis
size, so the same model code lowers for every (arch x shape) cell — decode
steps (seq=1), odd head counts (xlstm: 4 heads on a 16-way model axis),
batch-1 long-context — without per-arch special cases.  ``ShardCtx`` with
``mesh=None`` is a no-op, so unit tests run the identical code path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[str, tuple]

#: 1-D serving-fleet mesh axis: scene blocks shard across devices, one host
#: worker per device (see ``repro.serve.fleet`` / ``launch.mesh
#: .make_serve_mesh``).
DEVICES_AXIS = 'devices'


def fleet_axis_sharding(mesh: Optional[Mesh]) -> Optional[NamedSharding]:
    """Leading-axis sharding over the serving fleet's ``devices`` axis
    (None mesh -> None, the single-device no-op)."""
    if mesh is None:
        return None
    return NamedSharding(mesh, P(DEVICES_AXIS))


def batch_axes(mesh: Optional[Mesh]) -> tuple:
    if mesh is None:
        return ()
    return tuple(n for n in mesh.axis_names if n in ('pod', 'data'))


def all_axes(mesh: Optional[Mesh]) -> tuple:
    if mesh is None:
        return ()
    return tuple(mesh.axis_names)


def axes_size(mesh: Optional[Mesh], axes: Axes) -> int:
    if mesh is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def adaptive_spec(shape: Sequence[int], mesh: Optional[Mesh],
                  assignments: Sequence[tuple]) -> P:
    """Build a PartitionSpec from (dim, axes) preferences.

    Each assignment is tried in order; it lands only if the dimension is
    still free, the axes are still free, and the dimension size is divisible
    by the axes' total size.  Negative dims count from the end.
    """
    spec: list = [None] * len(shape)
    used: set = set()
    for dim, axes in assignments:
        if axes is None:
            continue
        was_str = isinstance(axes, str)
        if was_str:
            axes = (axes,)
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            continue
        d = dim if dim >= 0 else len(shape) + dim
        if d < 0 or d >= len(shape) or spec[d] is not None:
            continue
        size = axes_size(mesh, axes)
        if size <= 1 or shape[d] % size != 0:
            continue
        # preserve the caller's spelling: a bare string stays a bare axis,
        # a tuple stays a tuple (even with one element)
        spec[d] = axes[0] if was_str and len(axes) == 1 else axes
        used.update(axes)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Activation-sharding helper threaded through model code."""

    mesh: Optional[Mesh]
    recipe: str = 'tp'
    tp: int = 1                 # model-axis size used for head padding
    seq_shard_kv: bool = False  # long-context: shard KV sequence over 'data'

    def _constrain(self, x, assignments):
        if self.mesh is None:
            return x
        spec = adaptive_spec(x.shape, self.mesh, assignments)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def _baxes(self) -> tuple:
        # 'fsdp' (ZeRO-3): the model axis carries BATCH, not tensor shards —
        # activations stay gather-free; weights all-gather per layer instead
        # (wins when weight bytes/layer << activation bytes: §Perf log)
        if self.recipe == 'fsdp':
            return all_axes(self.mesh)
        return batch_axes(self.mesh)

    # ---- logical activation layouts ----
    def btd(self, x):
        """[batch, seq, d_model] — batch over pod x data, seq over model (SP)."""
        return self._constrain(x, [(0, self._baxes()), (1, 'model')])

    def bthd(self, x):
        """[batch, seq, heads, head_dim] — heads over model.

        Deliberately NO head_dim fallback: sharding the contraction dim of
        QK^T turns every attention score block into a partial-sum all-reduce
        (measured: +1.5 TB/chip of collectives on smollm — see EXPERIMENTS.md
        §Dry-run notes).  Odd head counts leave 'model' idle here instead.
        """
        return self._constrain(x, [(0, self._baxes()), (2, 'model')])

    def btf(self, x):
        """[batch, seq, d_ff] — d_ff over model (TP)."""
        return self._constrain(x, [(0, self._baxes()), (2, 'model')])

    def btv(self, x):
        """[batch, seq, vocab] (logits) — vocab over model."""
        return self._constrain(x, [(0, self._baxes()), (2, 'model')])

    def kv_cache(self, x):
        """[batch, seq, kv_heads, head_dim] — flash-decoding layout: sequence
        over 'model' (even split regardless of GQA head count); long-context
        (batch=1): sequence over 'data', heads (else head_dim) over 'model'."""
        if self.seq_shard_kv:
            return self._constrain(x, [(1, 'data'), (2, 'model'), (3, 'model')])
        return self._constrain(x, [(0, self._baxes()), (1, 'model')])

    def ssm_state(self, x):
        """[batch, heads, dk, dv] recurrent state."""
        return self._constrain(x, [(0, batch_axes(self.mesh)),
                                   (1, 'model'), (-1, 'model')])

    def btdv(self, x):
        """[batch, seq, heads, dv] linear-attention VALUES: dv over model.

        Sharding dv (not dk!) keeps every contraction in the chunked linear
        attention local — the state [B,H,dk,dv] inherits the dv sharding
        through the scan carry, cutting the per-chunk state saves 16x
        (xlstm-1.3b: 269 MB -> 17 MB per chunk per device).
        """
        return self._constrain(x, [(0, batch_axes(self.mesh)),
                                   (3, 'model')])

    def experts(self, x):
        """[experts, capacity, d] bucketed MoE activations — EP over model."""
        return self._constrain(x, [(0, 'model'), (1, batch_axes(self.mesh))])

    def tokens(self, x):
        """Flat routing tensors [N(, d)] — N over every mesh axis.  Without
        this, GSPMD materializes the full [B*S, d] dispatch intermediates on
        every chip (measured 167 GB/device on maverick train_4k)."""
        return self._constrain(x, [(0, all_axes(self.mesh))])


def replicated(mesh: Optional[Mesh]):
    if mesh is None:
        return None
    return NamedSharding(mesh, P())


def spec_to_sharding(mesh: Optional[Mesh], tree_specs):
    """Map a pytree of PartitionSpec to NamedSharding (None mesh -> None)."""
    if mesh is None:
        return jax.tree.map(lambda _: None, tree_specs)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda s: isinstance(s, P))


def pad_to_multiple(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def padded_heads(n_heads: int, tp: int) -> int:
    """Pad a head count to TP divisibility (extra heads are masked)."""
    return pad_to_multiple(n_heads, max(tp, 1))


def replicated_kv_heads(n_kv: int, tp: int) -> int:
    """GQA kv heads replicated so the model axis divides them evenly."""
    if tp <= 1 or n_kv % tp == 0:
        return n_kv
    if tp % n_kv == 0:
        return tp                     # replicate each kv head tp/n_kv times
    return pad_to_multiple(n_kv, tp)  # fall back to padding
