"""Straggler detection + mitigation for 1000+-node training.

On a synchronous SPMD cluster the step time is the MAX over hosts, so one
slow host (thermal throttle, ECC retirement, flaky NIC) drags the fleet.
The detector keeps per-host EWMA step-time statistics; hosts persistently
slower than ``threshold`` x the fleet median are flagged.  Mitigations are
policy callbacks the launcher wires up:

  * ``report``   — log and export (dashboards / alerting);
  * ``exclude``  — hand the host list to repro.runtime.elastic for a
                   shrink-remesh at the next checkpoint boundary;
  * ``restart``  — ask the cluster manager to reschedule the host.

The detector is pure-host-side bookkeeping (no device code), so the train
loop calls ``observe(host_id, step_seconds)`` with timings it already has —
in a real deployment from a heartbeat service; in tests, synthetically.
The serving fleet (``repro.serve.fleet``) reuses it unchanged with
host == device worker.

Cold-start contract: the first observation *seeds* the EWMA (no zero-mix
warmup bias), and a fleet needs at least two observed hosts before anyone
can be flagged — a single host has no fleet to be slower than, and its
median tracks its own EWMA, so self-flagging on a spike would only ever
exclude the entire (one-host) fleet.

Pass ``metrics=`` (a ``repro.obs.metrics.Registry``) to mirror every
``on_straggler`` event onto ``straggler.flagged{host=...}`` counters and
a ``straggler.flagged_total`` counter, so dashboards see exclusions
without wiring a callback.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional


@dataclasses.dataclass
class HostStat:
    ewma: float = 0.0
    var_ewma: float = 0.0
    last: float = 0.0
    count: int = 0
    slow_streak: int = 0


class StragglerDetector:
    """Flags hosts whose EWMA step time exceeds threshold x fleet median."""

    def __init__(self, num_hosts: int, *, alpha: float = 0.2,
                 threshold: float = 1.25, patience: int = 3,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None,
                 metrics=None):
        self.num_hosts = num_hosts
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.on_straggler = on_straggler
        self.metrics = metrics
        self.stats = [HostStat() for _ in range(num_hosts)]
        self.flagged: set[int] = set()

    def observe(self, host_id: int, step_seconds: float) -> None:
        s = self.stats[host_id]
        s.last = step_seconds
        if s.count == 0:
            s.ewma = step_seconds
        else:
            d = step_seconds - s.ewma
            s.ewma += self.alpha * d
            s.var_ewma = (1 - self.alpha) * (s.var_ewma + self.alpha * d * d)
        s.count += 1

    def observe_step(self, timings: dict[int, float]) -> set[int]:
        """Feed one synchronous step's per-host timings; returns new flags."""
        for h, t in timings.items():
            self.observe(h, t)
        return self.evaluate()

    def fleet_median(self) -> float:
        vals = sorted(s.ewma for s in self.stats if s.count > 0)
        if not vals:
            return 0.0
        n = len(vals)
        return vals[n // 2] if n % 2 else 0.5 * (vals[n // 2 - 1] + vals[n // 2])

    def evaluate(self) -> set[int]:
        """Update slow-streaks; flag hosts slow for ``patience`` CONSECUTIVE
        observations.  Streaks count the instantaneous observation (a single
        GC-pause blip must not flag via its lingering EWMA); the EWMA backs
        the reported magnitude and z-scores."""
        med = self.fleet_median()
        observed = sum(1 for s in self.stats if s.count > 0)
        if med <= 0 or observed < 2:
            # A one-host "fleet" compares a host against its own EWMA —
            # a single spike could flag (and exclude) the whole fleet.
            return set()
        new = set()
        for h, s in enumerate(self.stats):
            if s.count == 0:
                continue
            if s.last > self.threshold * med:
                s.slow_streak += 1
            else:
                s.slow_streak = 0
                self.flagged.discard(h)
            if s.slow_streak >= self.patience and h not in self.flagged:
                self.flagged.add(h)
                new.add(h)
                if self.on_straggler:
                    self.on_straggler(h, s.ewma, med)
                if self.metrics is not None:
                    self.metrics.counter('straggler.flagged', host=h).inc()
                    self.metrics.counter('straggler.flagged_total').inc()
        return new

    def zscore(self, host_id: int) -> float:
        s = self.stats[host_id]
        med = self.fleet_median()
        sd = math.sqrt(max(s.var_ewma, 1e-12))
        return (s.ewma - med) / sd if s.count else 0.0

    def healthy_hosts(self) -> list[int]:
        return [h for h in range(self.num_hosts) if h not in self.flagged]
