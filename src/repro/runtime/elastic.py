"""Elastic re-meshing: shrink/grow the device mesh across failures.

Recovery contract (works with repro.checkpoint — state is saved as plain
host arrays, so re-sharding is just a ``device_put`` with new shardings):

  1. a node failure (or straggler exclusion) is detected;
  2. the launcher picks the largest *valid* mesh that fits the survivors —
     valid = the 'model' extent is preserved (TP degree is baked into padded
     head counts / expert placement), the batch axes shrink;
  3. state is restored from the latest checkpoint with the NEW shardings;
  4. gradient accumulation steps increase to keep the global batch constant.

Growing (nodes return) is the same flow with a larger target mesh.

The functions here are deliberately pure/deterministic so every surviving
host computes the identical plan without coordination beyond the shared
failure list.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    axes: tuple                 # mesh axis names
    shape: tuple                # new mesh shape
    devices_used: int
    grad_accum_factor: int      # multiply accumulation steps by this
    dropped_devices: int


def plan_remesh(total_devices: int, failed_devices: int, *,
                model: int = 16, axes: Sequence[str] = ('data', 'model'),
                old_data: Optional[int] = None) -> RemeshPlan:
    """Largest (data', model) mesh fitting the survivors; keep global batch.

    'model' is preserved (TP/EP degree is structural); 'data' shrinks to the
    largest power-of-two-friendly extent that divides the survivor count.
    """
    survivors = total_devices - failed_devices
    if survivors < model:
        raise ValueError(f'cannot keep model={model} with {survivors} devices')
    new_data = survivors // model
    # keep data a divisor of the old extent so the global batch (a multiple
    # of old_data) still shards evenly and grad-accum stays integral
    old_data = old_data or total_devices // model
    while new_data > 1 and old_data % new_data != 0:
        new_data -= 1
    used = new_data * model
    return RemeshPlan(
        axes=tuple(axes), shape=(new_data, model),
        devices_used=used,
        grad_accum_factor=old_data // new_data,
        dropped_devices=total_devices - used,
    )


def build_mesh(plan: RemeshPlan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = plan.devices_used
    if len(devices) < n:
        raise RuntimeError(f'need {n} devices, have {len(devices)}')
    return Mesh(np.asarray(devices[:n]).reshape(plan.shape), plan.axes)


def reshard_tree(tree, spec_tree, mesh: Mesh):
    """Re-place a host-memory pytree onto ``mesh`` with ``spec_tree``.

    Used after restore: checkpoint arrays are host numpy; this is the only
    device-placement step of elastic recovery.
    """
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, spec_tree)


class ElasticRunner:
    """Bookkeeping wrapper the launcher drives.

    ``step_failure(failed)`` returns the new plan; the launcher then rebuilds
    its jitted step with the new mesh and restores from the checkpoint
    manager.  Tested end-to-end in tests/test_fault_tolerance.py with forced
    host devices standing in for a real pod.
    """

    def __init__(self, total_devices: int, model_extent: int):
        self.total = total_devices
        self.model = model_extent
        self.failed: set[int] = set()

    def step_failure(self, failed_ids: Sequence[int]) -> RemeshPlan:
        self.failed.update(failed_ids)
        return plan_remesh(self.total, len(self.failed), model=self.model)

    def step_recovery(self, recovered_ids: Sequence[int]) -> RemeshPlan:
        self.failed.difference_update(recovered_ids)
        return plan_remesh(self.total, len(self.failed), model=self.model)
