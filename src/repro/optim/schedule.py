"""Learning-rate schedules (scalar-in, scalar-out; jit-friendly)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(step, *, warmup_steps: int, total_steps: int,
                         min_ratio: float = 0.1):
    """Warmup then cosine decay to ``min_ratio`` of peak. Returns a scale in (0,1]."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
    frac = jnp.clip((step - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos


def constant(step, *, value: float = 1.0):
    return jnp.full_like(jnp.asarray(step, jnp.float32), value)


def exponential_decay(step, *, decay_steps: int, rate: float = 0.5,
                      staircase: bool = False):
    step = jnp.asarray(step, jnp.float32)
    p = step / decay_steps
    if staircase:
        p = jnp.floor(p)
    return rate ** p
