"""AdamW over arbitrary pytrees — no external optimizer dependency.

Production features:
  * optional bf16 first/second moments (``state_dtype``) — required to fit
    optimizer state for the largest assigned configs (llama4-maverick: 773B
    raw parameters) on 16 GB/chip v5e HBM; see DESIGN.md §4;
  * global-norm gradient clipping;
  * decoupled weight decay;
  * fully functional: ``init`` -> state pytree, ``step`` -> (params, state).

The state pytree shards exactly like the parameters (tree structure is a
prefix match), so FSDP sharding rules apply transparently.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any       # first moment, pytree like params
    nu: Any       # second moment, pytree like params


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    state_dtype: Any = jnp.float32   # jnp.bfloat16 for memory-tight configs


def init(params: Any, cfg: AdamConfig) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def step(params: Any, grads: Any, state: AdamState, cfg: AdamConfig,
         lr_scale: jax.Array | float = 1.0) -> tuple[Any, AdamState, jax.Array]:
    """One AdamW update. Returns (new_params, new_state, pre-clip grad norm)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)

    count = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    # bias-correction folded into the step size
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        update = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        if cfg.weight_decay:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return (new_p.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(count, new_m, new_v), gnorm
