"""Gradient compression for cross-pod all-reduce (beyond-paper optimization).

At 1000+-node scale the data-parallel gradient all-reduce crossing pod
boundaries rides the slowest links.  We provide int8 block-quantized
compression with **error feedback** (the residual of each step is added back
before the next quantization), which preserves convergence in practice
(1-bit Adam / PowerSGD literature) while cutting cross-pod gradient bytes 4x
vs bf16.

Usage inside a train step::

    comp, new_residual = compress_tree(grads, residual)
    comp = jax.lax.pmean-style all-reduce of the *compressed* payload
    grads = decompress_tree(comp)

The quantizer is collective-agnostic: it just maps f32/bf16 leaves to
(int8 payload, per-block scale) pairs; the caller chooses where the
all-reduce happens.  ``psum_compressed`` wires it to ``jax.lax.psum`` for
shard_map-based steps.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: jax.Array       # int8 payload, shape = padded flat
    scale: jax.Array   # f32 per-block scales
    shape: tuple       # original shape (static)


def _pad_len(n: int) -> int:
    return (n + BLOCK - 1) // BLOCK * BLOCK


def compress(x: jax.Array, residual: jax.Array | None = None):
    """Block-quantize one array to int8. Returns (Compressed, new_residual)."""
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    if residual is not None:
        flat = flat + residual.reshape(-1)
    n = flat.shape[0]
    padded = jnp.zeros((_pad_len(n),), jnp.float32).at[:n].set(flat)
    blocks = padded.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0          # [B]
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale[:, None]
    new_residual = (blocks - deq).reshape(-1)[:n].reshape(shape)
    return Compressed(q, scale, shape), new_residual


def decompress(c: Compressed) -> jax.Array:
    deq = c.q.astype(jnp.float32) * c.scale[:, None]
    n = 1
    for d in c.shape:
        n *= d
    return deq.reshape(-1)[:n].reshape(c.shape)


def compress_tree(tree: Any, residuals: Any | None = None):
    leaves, treedef = jax.tree.flatten(tree)
    res_leaves = (treedef.flatten_up_to(residuals)
                  if residuals is not None else [None] * len(leaves))
    outs = [compress(x, r) for x, r in zip(leaves, res_leaves)]
    comp = treedef.unflatten([o[0] for o in outs])
    new_res = treedef.unflatten([o[1] for o in outs])
    return comp, new_res


def decompress_tree(comp: Any) -> Any:
    return jax.tree.map(decompress, comp,
                        is_leaf=lambda x: isinstance(x, Compressed))


def init_residuals(tree: Any) -> Any:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def psum_compressed(grads: Any, residuals: Any, axis_name: str):
    """Error-feedback int8 all-reduce for use inside ``shard_map``.

    The int8 payloads are summed in int32 (exact), scales are shared via max;
    this keeps the wire format at 1 byte/element + 4/BLOCK bytes of scales.
    """
    comp, new_res = compress_tree(grads, residuals)

    def reduce_one(c: Compressed) -> jax.Array:
        # max-scale requantization: align blocks to a common scale, sum in i32
        smax = jax.lax.pmax(c.scale, axis_name)
        ratio = c.scale / smax
        q = jnp.round(c.q.astype(jnp.float32) * ratio[:, None]).astype(jnp.int32)
        total = jax.lax.psum(q, axis_name)
        deq = total.astype(jnp.float32) * smax[:, None]
        n = 1
        for d in c.shape:
            n *= d
        return deq.reshape(-1)[:n].reshape(c.shape)

    reduced = jax.tree.map(reduce_one, comp,
                           is_leaf=lambda x: isinstance(x, Compressed))
    return reduced, new_res
