"""Deterministic sharded synthetic token pipeline (no datasets ship here).

Requirements a real pipeline must meet, reproduced faithfully:

  * **Determinism** — batch content is a pure function of (seed, step,
    position), so a restart resumes mid-epoch with zero drift and two hosts
    never disagree; implemented with a counter-based hash (threefry-style
    mixing), not a stateful RNG.
  * **Host sharding** — each host materializes only its slice of the global
    batch (``host_id/num_hosts``); cross-host order matches a single-host
    run exactly.
  * **Structured enough to learn** — tokens follow a mixed Markov/ngram
    process over the vocab (not iid uniform), so loss curves move and
    overfitting tests are meaningful.
  * **Labels** — next-token shifted, with the final position masked (-1).

``TokenStream`` is the python-side iterator; ``synthetic_batch`` is the
jit-able pure function used inside tests and the example drivers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def _mix(x: jnp.ndarray) -> jnp.ndarray:
    """64->32-bit counter hash (xxhash-style avalanche, uint32 lanes)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def synthetic_tokens(seed: int, step, batch: int, seq: int,
                     vocab: int, *, batch_offset: int = 0) -> jax.Array:
    """[batch, seq] int32 tokens, a pure function of (seed, step, row, col).

    Markov structure: token t depends on the hash of (row-stream, t-1 block)
    so bigram statistics are learnable while remaining O(1) to generate at
    any (step, position) — random access for resume.
    """
    rows = jnp.arange(batch, dtype=jnp.uint32)[:, None] + jnp.uint32(batch_offset)
    cols = jnp.arange(seq, dtype=jnp.uint32)[None, :]
    stream = _mix(rows * jnp.uint32(2654435761) + jnp.uint32(seed))
    base = _mix(stream + cols + jnp.uint32(step) * jnp.uint32(0x9E3779B9))
    # markov-ish: half the entropy comes from the previous 8-token block
    block = _mix(stream + (cols // 8) + jnp.uint32(step) * jnp.uint32(0x85EBCA6B))
    tok = (base % jnp.uint32(vocab // 2)) + (block % jnp.uint32((vocab + 1) // 2))
    return jnp.minimum(tok, vocab - 1).astype(jnp.int32)


def synthetic_batch(seed: int, step, batch: int, seq: int, vocab: int,
                    *, batch_offset: int = 0) -> dict:
    """{'tokens', 'labels'} with next-token labels, final position masked."""
    tokens = synthetic_tokens(seed, step, batch, seq + 1, vocab,
                              batch_offset=batch_offset)
    return {
        'tokens': tokens[:, :-1],
        'labels': jnp.where(
            jnp.arange(seq)[None, :] < seq, tokens[:, 1:], -1).astype(jnp.int32),
    }


@dataclasses.dataclass
class TokenStream:
    """Host-sharded deterministic stream with checkpointable position."""

    seed: int
    global_batch: int
    seq: int
    vocab: int
    host_id: int = 0
    num_hosts: int = 1
    step: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        self.local_batch = self.global_batch // self.num_hosts

    def next(self) -> dict:
        batch = synthetic_batch(
            self.seed, self.step, self.local_batch, self.seq, self.vocab,
            batch_offset=self.host_id * self.local_batch)
        self.step += 1
        return batch

    def __iter__(self):
        return self

    def __next__(self):
        return self.next()

    def state_dict(self) -> dict:
        return {'step': self.step, 'seed': self.seed}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state['step'])
        assert int(state['seed']) == self.seed, 'stream seed mismatch'


def global_batch_view(seed: int, step: int, global_batch: int, seq: int,
                      vocab: int) -> dict:
    """The single-host view of the whole global batch (test oracle for the
    host-sharding invariant: concatenating every host's slice == this)."""
    return synthetic_batch(seed, step, global_batch, seq, vocab)
