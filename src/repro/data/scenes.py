"""Procedural Gaussian scenes and the streaming chunk container.

``structured_scene`` builds a spatially-coherent ground-truth scene —
Gaussians laid on parametric surfaces (sphere / plane / torus) with smooth
color fields — so the temporal/ray-coherence properties Lumina exploits
(significant-Gaussian sparsity, tag stability across nearby rays) actually
hold, as they do for trained scenes.  Purely random scenes would understate
cache hit rates; see DESIGN.md §6.

``partition_scene`` turns any ``GaussianScene`` into a ``ChunkedScene``: the
Gaussians grouped into spatial-cell-indexed chunks (the same ``floor(p /
cell_size)`` quantization ``core/posecell.py`` applies to camera positions),
each chunk padded to a fixed ``chunk_cap`` lanes with **neutral** Gaussians
— means far outside the frustum (``project`` culls them to opacity 0, depth
inf, radius 0, so a neutral lane contributes exactly nothing to any render,
including through a stale sorted tile list).  Within a chunk, Gaussians are
ordered by descending significance (opacity x mean scale), so a
significance-prefix of the chunk IS its LOD subset: ``level_rows`` maps a
residency level to the row count to load/render, and ``masked_scene``
neutralizes everything past the per-chunk row budget.  The streaming
residency manager (``repro.serve.streaming``) pages these fixed-shape chunks
in and out of a device arena.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gaussians import GaussianScene

# one Gaussian = 23 float32 fields (means 3 + log_scales 3 + quats 4 +
# opacity_logit 1 + sh_dc 3 + sh_rest 9)
BYTES_PER_GAUSSIAN = 92

# a neutral lane: far outside any frustum (``project`` culls depth > far),
# identity rotation, opacity ~ 0 even unculled
_NEUTRAL_MEAN = 1.0e6
_NEUTRAL_OPACITY_LOGIT = -30.0

# residency levels, low to high: absent -> coarse LOD prefix -> full chunk
LEVEL_ABSENT, LEVEL_LOD, LEVEL_FULL = 0, 1, 2


def _sphere(key, n, center, radius, base_color):
    k1, k2 = jax.random.split(key)
    d = jax.random.normal(k1, (n, 3))
    d = d / (jnp.linalg.norm(d, axis=-1, keepdims=True) + 1e-9)
    means = jnp.asarray(center) + radius * d
    # color varies smoothly over the surface
    col = jnp.asarray(base_color) + 0.35 * d
    return means, col, k2


def _plane(key, n, origin, u, v, base_color):
    k1, k2 = jax.random.split(key)
    ab = jax.random.uniform(k1, (n, 2), minval=-1.0, maxval=1.0)
    means = (jnp.asarray(origin) + ab[:, :1] * jnp.asarray(u)
             + ab[:, 1:2] * jnp.asarray(v))
    col = jnp.asarray(base_color) + 0.25 * jnp.concatenate(
        [jnp.sin(3 * ab), jnp.cos(2 * ab[:, :1] + ab[:, 1:2])], axis=-1)
    return means, col, k2


def _torus(key, n, center, r_major, r_minor, base_color):
    k1, k2, k3 = jax.random.split(key, 3)
    th = jax.random.uniform(k1, (n,), minval=0, maxval=2 * jnp.pi)
    ph = jax.random.uniform(k2, (n,), minval=0, maxval=2 * jnp.pi)
    x = (r_major + r_minor * jnp.cos(ph)) * jnp.cos(th)
    y = r_minor * jnp.sin(ph)
    z = (r_major + r_minor * jnp.cos(ph)) * jnp.sin(th)
    means = jnp.asarray(center) + jnp.stack([x, y, z], axis=-1)
    col = jnp.asarray(base_color) + 0.3 * jnp.stack(
        [jnp.cos(th), jnp.sin(2 * ph), jnp.sin(th + ph)], axis=-1)
    return means, col, k3


def structured_scene(key: jax.Array, num_gaussians: int,
                     scale_range=(0.015, 0.06),
                     large_gaussian_frac: float = 0.0) -> GaussianScene:
    """A coherent multi-surface scene in the unit-ish cube around the origin.

    ``large_gaussian_frac`` injects a fraction of oversized Gaussians to
    recreate the failure mode cache-aware fine-tuning fixes (Fig. 13).
    """
    n1 = num_gaussians // 3
    n2 = num_gaussians // 3
    n3 = num_gaussians - n1 - n2
    assert n1 + n2 + n3 == num_gaussians, (n1, n2, n3, num_gaussians)
    m1, c1, key = _sphere(key, n1, (0.0, 0.1, 0.0), 0.45, (0.7, 0.3, 0.25))
    m2, c2, key = _plane(key, n2, (0.0, -0.5, 0.0), (1.2, 0.0, 0.0),
                         (0.0, 0.0, 1.2), (0.25, 0.55, 0.3))
    m3, c3, key = _torus(key, n3, (0.0, 0.35, 0.0), 0.7, 0.12, (0.3, 0.35, 0.75))
    means = jnp.concatenate([m1, m2, m3])
    colors = jnp.clip(jnp.concatenate([c1, c2, c3]), 0.02, 0.98)
    assert means.shape[0] == num_gaussians, (means.shape, num_gaussians)

    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    n = num_gaussians
    log_scales = jnp.log(jax.random.uniform(
        k1, (n, 3), minval=scale_range[0], maxval=scale_range[1]))
    if large_gaussian_frac > 0:
        big = jax.random.bernoulli(k5, large_gaussian_frac, (n, 1))
        log_scales = jnp.where(big, jnp.log(0.35), log_scales)
    quats = jax.random.normal(k2, (n, 4))
    quats = quats.at[:, 0].add(3.0)
    opacity_logit = jax.random.uniform(k3, (n,), minval=0.5, maxval=3.0)
    # invert the SH DC activation: c = SH_C0 * dc + 0.5  =>  dc = (c - 0.5)/SH_C0
    sh_dc = (colors - 0.5) / 0.28209479177387814
    sh_rest = 0.08 * jax.random.normal(k4, (n, 3, 3))
    return GaussianScene(means.astype(jnp.float32),
                         log_scales.astype(jnp.float32),
                         quats.astype(jnp.float32),
                         opacity_logit.astype(jnp.float32),
                         sh_dc.astype(jnp.float32),
                         sh_rest.astype(jnp.float32))


# -- streaming chunk container ------------------------------------------------

def neutral_scene(n: int) -> GaussianScene:
    """``n`` neutral lanes: culled by every frustum, zero contribution."""
    return GaussianScene(
        means=np.full((n, 3), _NEUTRAL_MEAN, np.float32),
        log_scales=np.zeros((n, 3), np.float32),
        quats=np.tile(np.asarray([1.0, 0.0, 0.0, 0.0], np.float32), (n, 1)),
        opacity_logit=np.full((n,), _NEUTRAL_OPACITY_LOGIT, np.float32),
        sh_dc=np.zeros((n, 3), np.float32),
        sh_rest=np.zeros((n, 3, 3), np.float32))


def scene_nbytes(scene_or_count) -> int:
    """Payload bytes of a scene (or a Gaussian count)."""
    n = (scene_or_count if isinstance(scene_or_count, int)
         else int(scene_or_count.means.shape[0]))
    return n * BYTES_PER_GAUSSIAN


@dataclasses.dataclass(frozen=True)
class ChunkedScene:
    """A scene partitioned into fixed-capacity, cell-indexed chunks.

    ``packed`` is host-side (numpy) — the "disk/flash" side of the streaming
    data path; chunk ``i`` occupies rows ``[i*chunk_cap, (i+1)*chunk_cap)``,
    its first ``fill[i]`` rows real Gaussians in descending significance,
    the rest neutral padding.  ``cells[i]`` is the chunk's integer grid cell
    (``floor(mean / cell_size)`` — every Gaussian of a chunk shares it).
    """

    packed: GaussianScene        # [num_chunks * chunk_cap] host arrays
    cells: np.ndarray            # [num_chunks, 3] int64 grid cell per chunk
    fill: np.ndarray             # [num_chunks] int64 real rows per chunk
    cell_size: float
    chunk_cap: int
    source_count: int            # Gaussians in the source scene

    @property
    def num_chunks(self) -> int:
        return int(self.fill.shape[0])

    @property
    def scene_bytes(self) -> int:
        """Full-scene payload bytes (what a fully-resident run holds)."""
        return scene_nbytes(self.source_count)

    def chunk_block(self, chunk: int, rows: int,
                    keep: int | None = None) -> GaussianScene:
        """Host copy of one chunk's first ``rows`` lanes with only the first
        ``keep`` real (default: the chunk's fill).  Lanes past ``keep`` are
        neutral, so a device arena write of the block leaves no stale lanes
        behind an LOD prefix."""
        lo = chunk * self.chunk_cap
        block = jax.tree.map(lambda x: np.array(x[lo:lo + rows]), self.packed)
        keep = int(self.fill[chunk]) if keep is None else int(keep)
        keep = min(rows, keep, int(self.fill[chunk]))
        if keep < rows:
            pad = neutral_scene(rows - keep)
            block = jax.tree.map(
                lambda b, p: np.concatenate([b[:keep], p]), block, pad)
        return block

    def meta_dict(self) -> dict:
        """JSON-able partition geometry (checkpoint manifests carry it so a
        restore can verify it resumes onto the same partition)."""
        return {'num_chunks': self.num_chunks,
                'chunk_cap': int(self.chunk_cap),
                'cell_size': float(self.cell_size),
                'source_count': int(self.source_count),
                'fill': [int(f) for f in self.fill]}


def partition_scene(scene: GaussianScene, cell_size: float = 0.4,
                    chunk_cap: int = 64) -> ChunkedScene:
    """Deterministically partition a scene into cell-indexed chunks.

    Gaussians are bucketed by grid cell (``floor(mean / cell_size)``, the
    position quantization ``core/posecell.py`` uses for camera poses), each
    cell's population ordered by descending significance (``sigmoid(opacity)
    * exp(mean log-scale)`` — the S² significance proxy; ties broken by
    source index) and split into chunks of at most ``chunk_cap``.  Chunk
    order is lexicographic in (cell, within-cell chunk index), so the same
    scene always partitions identically.
    """
    host = jax.tree.map(np.asarray, scene)
    n = int(host.means.shape[0])
    cells = np.floor(host.means / cell_size).astype(np.int64)
    sig = (1.0 / (1.0 + np.exp(-host.opacity_logit.astype(np.float64)))
           * np.exp(host.log_scales.astype(np.float64).mean(axis=-1)))
    # lexicographic (cell, -significance, index) order groups cells
    # contiguously with each cell's rows significance-descending
    order = np.lexsort((np.arange(n), -sig,
                        cells[:, 2], cells[:, 1], cells[:, 0]))
    sorted_cells = cells[order]
    chunk_ids, chunk_cells, fill = [], [], []
    start = 0
    while start < n:
        # the run of rows sharing this cell
        end = start
        while end < n and (sorted_cells[end] == sorted_cells[start]).all():
            end += 1
        for lo in range(start, end, chunk_cap):
            hi = min(lo + chunk_cap, end)
            chunk_ids.append(order[lo:hi])
            chunk_cells.append(sorted_cells[start])
            fill.append(hi - lo)
        start = end
    num_chunks = max(len(chunk_ids), 1)
    packed = jax.tree.map(np.array, neutral_scene(num_chunks * chunk_cap))
    for i, idx in enumerate(chunk_ids):
        lo = i * chunk_cap
        packed = jax.tree.map(
            lambda p, s, lo=lo, idx=idx: _scatter_rows(p, lo, s[idx]),
            packed, host)
    return ChunkedScene(
        packed=packed,
        cells=(np.stack(chunk_cells) if chunk_cells
               else np.zeros((1, 3), np.int64)),
        fill=np.asarray(fill if fill else [0], np.int64),
        cell_size=float(cell_size), chunk_cap=int(chunk_cap),
        source_count=n)


def _scatter_rows(dst: np.ndarray, lo: int, rows: np.ndarray) -> np.ndarray:
    dst[lo:lo + rows.shape[0]] = rows
    return dst


def chunk_levels(chunked: ChunkedScene, cam_positions,
                 near_radius: int, lod_radius: int) -> np.ndarray:
    """Per-chunk residency level for a set of camera positions.

    A chunk's level is the max over cameras of: FULL within ``near_radius``
    grid cells (Chebyshev distance between the chunk's cell and the
    camera's ``floor(pos / cell_size)`` cell), LOD within ``lod_radius``,
    ABSENT beyond.  Pure host math — the residency planner and the
    bench_quality LOD leg share it.
    """
    levels = np.zeros((chunked.num_chunks,), np.int64)
    for pos in cam_positions:
        cam_cell = np.floor(np.asarray(pos, np.float64)[:3]
                            / chunked.cell_size).astype(np.int64)
        dist = np.abs(chunked.cells - cam_cell[None, :]).max(axis=1)
        lvl = np.where(dist <= near_radius, LEVEL_FULL,
                       np.where(dist <= lod_radius, LEVEL_LOD, LEVEL_ABSENT))
        levels = np.maximum(levels, lvl)
    return levels


def level_rows(chunked: ChunkedScene, levels: np.ndarray,
               lod_frac: float = 0.5) -> np.ndarray:
    """Rows to hold per chunk at the given residency levels: the full fill
    at FULL, the significance prefix ``ceil(fill * lod_frac)`` at LOD
    (never empty for a non-empty chunk), nothing when absent."""
    fill = chunked.fill
    lod = np.where(fill > 0,
                   np.maximum(np.ceil(fill * lod_frac).astype(np.int64), 1),
                   0)
    return np.where(levels >= LEVEL_FULL, fill,
                    np.where(levels == LEVEL_LOD, lod, 0))


def masked_scene(packed: GaussianScene, rows: jax.Array,
                 chunk_cap: int) -> GaussianScene:
    """Neutralize every lane past its chunk's row budget (pure, jittable).

    ``rows`` is [num_chunks] — lane ``j`` of chunk ``i`` survives iff
    ``j < rows[i]``.  Surviving lanes keep their exact packed values, so a
    mask covering each chunk's live requirement renders bit-identically to
    the fully-resident scene regardless of what the hidden lanes hold.
    """
    lanes = packed.means.shape[0]
    lane_in_chunk = jnp.arange(lanes, dtype=jnp.int32) % chunk_cap
    keep = lane_in_chunk < jnp.asarray(rows, jnp.int32)[
        jnp.arange(lanes, dtype=jnp.int32) // chunk_cap]

    def _mask(x, neutral):
        shape = (lanes,) + (1,) * (x.ndim - 1)
        return jnp.where(keep.reshape(shape), x, neutral)

    return GaussianScene(
        means=_mask(packed.means, _NEUTRAL_MEAN),
        log_scales=_mask(packed.log_scales, 0.0),
        quats=_mask(packed.quats,
                    jnp.asarray([1.0, 0.0, 0.0, 0.0], packed.quats.dtype)),
        opacity_logit=_mask(packed.opacity_logit, _NEUTRAL_OPACITY_LOGIT),
        sh_dc=_mask(packed.sh_dc, 0.0),
        sh_rest=_mask(packed.sh_rest, 0.0))
