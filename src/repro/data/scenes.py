"""Procedural Gaussian scenes (the container ships no datasets).

``structured_scene`` builds a spatially-coherent ground-truth scene —
Gaussians laid on parametric surfaces (sphere / plane / torus) with smooth
color fields — so the temporal/ray-coherence properties Lumina exploits
(significant-Gaussian sparsity, tag stability across nearby rays) actually
hold, as they do for trained scenes.  Purely random scenes would understate
cache hit rates; see DESIGN.md §6.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gaussians import GaussianScene


def _sphere(key, n, center, radius, base_color):
    k1, k2 = jax.random.split(key)
    d = jax.random.normal(k1, (n, 3))
    d = d / (jnp.linalg.norm(d, axis=-1, keepdims=True) + 1e-9)
    means = jnp.asarray(center) + radius * d
    # color varies smoothly over the surface
    col = jnp.asarray(base_color) + 0.35 * d
    return means, col, k2


def _plane(key, n, origin, u, v, base_color):
    k1, k2 = jax.random.split(key)
    ab = jax.random.uniform(k1, (n, 2), minval=-1.0, maxval=1.0)
    means = (jnp.asarray(origin) + ab[:, :1] * jnp.asarray(u)
             + ab[:, 1:2] * jnp.asarray(v))
    col = jnp.asarray(base_color) + 0.25 * jnp.concatenate(
        [jnp.sin(3 * ab), jnp.cos(2 * ab[:, :1] + ab[:, 1:2])], axis=-1)
    return means, col, k2


def _torus(key, n, center, r_major, r_minor, base_color):
    k1, k2, k3 = jax.random.split(key, 3)
    th = jax.random.uniform(k1, (n,), minval=0, maxval=2 * jnp.pi)
    ph = jax.random.uniform(k2, (n,), minval=0, maxval=2 * jnp.pi)
    x = (r_major + r_minor * jnp.cos(ph)) * jnp.cos(th)
    y = r_minor * jnp.sin(ph)
    z = (r_major + r_minor * jnp.cos(ph)) * jnp.sin(th)
    means = jnp.asarray(center) + jnp.stack([x, y, z], axis=-1)
    col = jnp.asarray(base_color) + 0.3 * jnp.stack(
        [jnp.cos(th), jnp.sin(2 * ph), jnp.sin(th + ph)], axis=-1)
    return means, col, k3


def structured_scene(key: jax.Array, num_gaussians: int,
                     scale_range=(0.015, 0.06),
                     large_gaussian_frac: float = 0.0) -> GaussianScene:
    """A coherent multi-surface scene in the unit-ish cube around the origin.

    ``large_gaussian_frac`` injects a fraction of oversized Gaussians to
    recreate the failure mode cache-aware fine-tuning fixes (Fig. 13).
    """
    n1 = num_gaussians // 3
    n2 = num_gaussians // 3
    n3 = num_gaussians - n1 - n2
    m1, c1, key = _sphere(key, n1, (0.0, 0.1, 0.0), 0.45, (0.7, 0.3, 0.25))
    m2, c2, key = _plane(key, n2, (0.0, -0.5, 0.0), (1.2, 0.0, 0.0),
                         (0.0, 0.0, 1.2), (0.25, 0.55, 0.3))
    m3, c3, key = _torus(key, n3, (0.0, 0.35, 0.0), 0.7, 0.12, (0.3, 0.35, 0.75))
    means = jnp.concatenate([m1, m2, m3])
    colors = jnp.clip(jnp.concatenate([c1, c2, c3]), 0.02, 0.98)

    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    n = num_gaussians
    log_scales = jnp.log(jax.random.uniform(
        k1, (n, 3), minval=scale_range[0], maxval=scale_range[1]))
    if large_gaussian_frac > 0:
        big = jax.random.bernoulli(k5, large_gaussian_frac, (n, 1))
        log_scales = jnp.where(big, jnp.log(0.35), log_scales)
    quats = jax.random.normal(k2, (n, 4))
    quats = quats.at[:, 0].add(3.0)
    opacity_logit = jax.random.uniform(k3, (n,), minval=0.5, maxval=3.0)
    # invert the SH DC activation: c = SH_C0 * dc + 0.5  =>  dc = (c - 0.5)/SH_C0
    sh_dc = (colors - 0.5) / 0.28209479177387814
    sh_rest = 0.08 * jax.random.normal(k4, (n, 3, 3))
    return GaussianScene(means.astype(jnp.float32),
                         log_scales.astype(jnp.float32),
                         quats.astype(jnp.float32),
                         opacity_logit.astype(jnp.float32),
                         sh_dc.astype(jnp.float32),
                         sh_rest.astype(jnp.float32))
