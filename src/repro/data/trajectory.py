"""Camera trajectories modelling the paper's evaluation settings.

Synthetic scenes: a VR scenario with ~25 deg/s average head rotation at
90 FPS (paper Sec. 5, citing [34]).  Real scenes: 30 FPS captures with the
same angular speed, i.e. 3x larger inter-frame motion — the regime where S^2
loses 0.1 dB (Sec. 6.1).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.core.camera import Camera, look_at, make_camera


def orbit_trajectory(num_frames: int, *, fps: float = 90.0,
                     deg_per_sec: float = 25.0, radius: float = 2.2,
                     height: float = 0.25, width: int = 128, height_px: int = 128,
                     fov_x_deg: float = 60.0, start_deg: float = 0.0,
                     translate_per_sec: float = 0.05) -> list[Camera]:
    """Orbit around the origin with VR-like angular velocity + slow drift."""
    cams = []
    for i in range(num_frames):
        t = i / fps
        ang = math.radians(start_deg + deg_per_sec * t)
        pos = (radius * math.sin(ang),
               height + translate_per_sec * t,
               radius * math.cos(ang))
        p, q = look_at(pos, (0.0, 0.0, 0.0))
        cams.append(make_camera(p, q, fov_x_deg, width, height_px))
    return cams
