"""Pallas TPU rasterization kernel — the LuminCore NRU, re-expressed for TPU.

One grid program = one 16x16-pixel tile.  The tile's depth-sorted Gaussian
features live in VMEM (streamed there by the Pallas pipeline); the kernel
walks them in chunks of ``chunk`` Gaussians:

  frontend (NRU PE array analogue)
      alpha for the whole (chunk x 256 pixels) block is evaluated *densely*
      on the VPU — conic quadratic form + exp — exactly the cheap uniform
      work the paper's PE frontend does for every Gaussian;
  backend (NRU shared backend analogue)
      the order-sensitive color integration collapses to closed form with an
      exclusive prefix-product of (1 - alpha) along the chunk axis
      (associative scan) followed by ONE [P,C]x[C,3] matmul on the MXU —
      only *significant* Gaussians contribute via masking, mirroring the
      FIFO that feeds the paper's backend;
  early exit (sparsity harvesting)
      a `while`-loop over chunks stops as soon as every pixel in the tile is
      terminated / its alpha-record is full / it is not live — the TPU
      analogue of warp-divergence elimination: whole chunks of work are
      skipped at the granularity the hardware actually schedules.

The same kernel serves three modes (see ops.py):
  * full      — baseline rasterization (S^2 path);
  * prefix    — stop each pixel once its k-record fills (RC phase A:
                "identify the first k significant Gaussians");
  * resume    — continue cache-MISS pixels from their saved state
                (RC phase B), with per-pixel ``start_iter`` gating.

Exact-match contract with ``repro.kernels.ref.rasterize_ref`` (same
floating-point semantics, including the Gamma<eps freeze rule) — verified by
shape/dtype sweep tests.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gaussians import ALPHA_MAX, ALPHA_SIGNIFICANT, TRANSMITTANCE_EPS

P = 256            # pixels per tile (16 x 16)
TILE = 16


def _exclusive_cumprod(x):
    inc = jax.lax.associative_scan(jnp.multiply, x, axis=0)
    exc = jnp.concatenate([jnp.ones_like(x[:1]), inc[:-1]], axis=0)
    return inc, exc


def _exclusive_cumsum_i32(x):
    inc = jax.lax.associative_scan(jnp.add, x.astype(jnp.int32), axis=0)
    return inc - x.astype(jnp.int32)


def _kernel(mean2d_ref, conic_ref, color_ref, opacity_ref, ids_ref,
            acc0_ref, trans0_ref, rec0_ref, cnt0_ref, start_ref, live_ref,
            acc_ref, trans_ref, rec_ref, cnt_ref, nsig_ref, niter_ref,
            itk_ref, chunks_ref,
            *, tiles_x: int, k_record: int, chunk: int, stop_at_k: bool,
            bg: float):
    t = pl.program_id(0)
    k_total = mean2d_ref.shape[1]
    nc = k_total // chunk

    ox = (t % tiles_x) * TILE
    oy = (t // tiles_x) * TILE
    px2 = jax.lax.broadcasted_iota(jnp.float32, (TILE, TILE), 1)
    py2 = jax.lax.broadcasted_iota(jnp.float32, (TILE, TILE), 0)
    px = px2.reshape(P) + ox + 0.5
    py = py2.reshape(P) + oy + 0.5

    live = live_ref[0] != 0                    # [P]
    start = start_ref[0]                       # [P] int32
    # first chunk that any live pixel needs
    start_eff = jnp.where(live, start, k_total)
    c0 = jnp.min(start_eff) // chunk
    c0 = jnp.minimum(c0, nc)

    def body(carry):
        c, acc, trans, rec, cnt, nsig, niter, itk, nchunks = carry
        sl = pl.ds(c * chunk, chunk)
        gmx = mean2d_ref[0, sl, 0]             # [C]
        gmy = mean2d_ref[0, sl, 1]
        ca = conic_ref[0, sl, 0]
        cb = conic_ref[0, sl, 1]
        cc = conic_ref[0, sl, 2]
        col = color_ref[0, sl, :]              # [C, 3]
        op = opacity_ref[0, sl]                # [C]
        gid = ids_ref[0, sl]                   # [C] int32

        dx = px[None, :] - gmx[:, None]        # [C, P]
        dy = py[None, :] - gmy[:, None]
        power = (-0.5 * (ca[:, None] * dx * dx + cc[:, None] * dy * dy)
                 - cb[:, None] * dx * dy)
        alpha = jnp.minimum(ALPHA_MAX, op[:, None] * jnp.exp(power))
        valid = (power <= 0.0) & (gid[:, None] >= 0)

        abs_pos = c * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        allowed = (abs_pos >= start[None, :]) & live[None, :]
        sig = (alpha > ALPHA_SIGNIFICANT) & valid & allowed    # [C, P]

        if stop_at_k:
            pos_sig = cnt[None, :] + _exclusive_cumsum_i32(sig)
            sig = sig & (pos_sig < k_record)

        beta = jnp.where(sig, 1.0 - alpha, 1.0)
        p_inc, p_exc = _exclusive_cumprod(beta)
        p_exc = p_exc * trans[None, :]
        p_inc = p_inc * trans[None, :]
        contrib = sig & (p_exc > TRANSMITTANCE_EPS)

        w = jnp.where(contrib, p_exc * alpha, 0.0)             # [C, P]
        acc = acc + jax.lax.dot_general(
            w, col, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # [P, 3]
        trans = jnp.minimum(trans, jnp.min(
            jnp.where(contrib, p_inc, trans[None, :]), axis=0))

        pos = cnt[None, :] + _exclusive_cumsum_i32(contrib)    # [C, P]
        for kk in range(k_record):
            m = contrib & (pos == kk)
            sel = jnp.max(jnp.where(m, gid[:, None], -1), axis=0)  # [P]
            rec = rec.at[kk].set(jnp.where(sel >= 0, sel, rec[kk]))
        iters = abs_pos + 1                                    # [C, 1]
        m_k = contrib & (pos == (k_record - 1))
        sel_it = jnp.max(jnp.where(m_k, iters, -1), axis=0)
        itk = jnp.where(sel_it >= 0, sel_it, itk)

        cnt = cnt + jnp.sum(contrib.astype(jnp.int32), axis=0)
        nsig = nsig + jnp.sum(contrib.astype(jnp.int32), axis=0)
        active = (p_exc > TRANSMITTANCE_EPS) & (gid[:, None] >= 0) & allowed
        if stop_at_k:
            # a pixel pauses right after its record fills: iterations past the
            # fill point are not examined (hardware would hand off to lookup)
            active = active & (pos < k_record)
        niter = niter + jnp.sum(active.astype(jnp.int32), axis=0)
        return (c + 1, acc, trans, rec, cnt, nsig, niter, itk, nchunks + 1)

    def cond(carry):
        c, acc, trans, rec, cnt, nsig, niter, itk, nchunks = carry
        pix_done = ~live | (trans <= TRANSMITTANCE_EPS)
        if stop_at_k:
            pix_done = pix_done | (cnt >= k_record)
        return (c < nc) & ~jnp.all(pix_done)

    init = (
        c0,
        acc0_ref[0].astype(jnp.float32),       # [P, 3]
        trans0_ref[0].astype(jnp.float32),     # [P]
        rec0_ref[0].T,                          # [k, P] in-kernel layout
        cnt0_ref[0],                            # [P]
        jnp.zeros((P,), jnp.int32),
        jnp.zeros((P,), jnp.int32),
        jnp.full((P,), k_total, jnp.int32),
        jnp.int32(0),
    )
    (c, acc, trans, rec, cnt, nsig, niter, itk, nchunks) = jax.lax.while_loop(
        cond, body, init)

    del bg  # background compositing happens once, in ops.py, after the final phase
    acc_ref[0] = acc
    trans_ref[0] = trans
    rec_ref[0] = rec.T
    cnt_ref[0] = cnt
    nsig_ref[0] = nsig
    niter_ref[0] = niter
    itk_ref[0] = itk
    chunks_ref[0, 0] = nchunks


class RasterState(NamedTuple):
    """Per-pixel kernel state: inputs (phase init) and outputs alike."""

    acc: jax.Array        # [T, P, 3]
    trans: jax.Array      # [T, P]
    record: jax.Array     # [T, P, k]
    rec_cnt: jax.Array    # [T, P]
    n_sig: jax.Array      # [T, P]
    n_iter: jax.Array     # [T, P]
    iter_at_k: jax.Array  # [T, P]
    chunks: jax.Array     # [T, 1] chunks actually processed (early-exit stat)


def rasterize_pallas(mean2d, conic, color, opacity, ids,
                     acc0, trans0, rec0, cnt0, start_iter, live,
                     *, tiles_x: int, k_record: int = 5, chunk: int = 64,
                     stop_at_k: bool = False, bg: float = 0.0,
                     interpret: bool = True) -> RasterState:
    """Invoke the kernel. Feature arrays are [T, K, ...]; K must be a
    multiple of ``chunk`` (ops.py pads).  State arrays are [T, P(=256), ...].
    """
    t, k_total = ids.shape
    assert k_total % chunk == 0, (k_total, chunk)
    kr = rec0.shape[-1]
    assert kr == k_record

    grid = (t,)
    feat = lambda *dims: pl.BlockSpec((1, *dims), lambda i: (i,) + (0,) * len(dims))
    out_shapes = (
        jax.ShapeDtypeStruct((t, P, 3), jnp.float32),   # acc
        jax.ShapeDtypeStruct((t, P), jnp.float32),      # trans
        jax.ShapeDtypeStruct((t, P, k_record), jnp.int32),
        jax.ShapeDtypeStruct((t, P), jnp.int32),        # rec_cnt
        jax.ShapeDtypeStruct((t, P), jnp.int32),        # n_sig
        jax.ShapeDtypeStruct((t, P), jnp.int32),        # n_iter
        jax.ShapeDtypeStruct((t, P), jnp.int32),        # iter_at_k
        jax.ShapeDtypeStruct((t, 1), jnp.int32),        # chunks processed
    )
    out_specs = (
        feat(P, 3), feat(P), feat(P, k_record), feat(P), feat(P), feat(P),
        feat(P), feat(1),
    )
    in_specs = (
        feat(k_total, 2), feat(k_total, 3), feat(k_total, 3), feat(k_total),
        feat(k_total),
        feat(P, 3), feat(P), feat(P, k_record), feat(P), feat(P), feat(P),
    )
    kern = functools.partial(_kernel, tiles_x=tiles_x, k_record=k_record,
                             chunk=chunk, stop_at_k=stop_at_k, bg=bg)
    outs = pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret,
    )(mean2d, conic, color, opacity, ids,
      acc0, trans0, rec0, cnt0, start_iter, live.astype(jnp.int32))
    return RasterState(*outs)
