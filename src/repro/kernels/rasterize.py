"""Pallas rasterization kernel — the LuminCore NRU, re-expressed for TPU.

One grid program = one 16x16-pixel tile.  The tile's depth-sorted Gaussian
features live in VMEM (streamed there by the Pallas pipeline); the kernel
walks them in chunks of ``chunk`` Gaussians:

  frontend (NRU PE array analogue)
      alpha for the whole (chunk x 256 pixels) block is evaluated *densely*
      on the VPU — conic quadratic form + exp — exactly the cheap uniform
      work the paper's PE frontend does for every Gaussian;
  backend (NRU shared backend analogue) — two flavors via ``body``:
      ``'dense'``: the order-sensitive color integration collapses to closed
      form with an exclusive prefix-product of (1 - alpha) along the chunk
      axis (associative scan) followed by ONE [P,C]x[C,3] matmul on the MXU
      — the right shape for TPU vector/matrix units;
      ``'seq'``: a sequential per-Gaussian update over the chunk (the
      faithful analogue of the FIFO feeding the paper's shared backend),
      with a branch that skips Gaussians contributing to no pixel.  On CPU /
      interpret mode this wins big: the associative scans cost ~log(C)
      dense passes that a scalar core pays for real, and most shared-list
      entries are invisible at the render pose.  ops.py picks ``'seq'``
      whenever it interprets and ``'dense'`` when compiling natively.
  early exit (sparsity harvesting)
      a `while`-loop over chunks stops as soon as every pixel in the tile is
      terminated / its alpha-record is full / it is not live / past the
      tile's last valid Gaussian (``ncap``) — the TPU analogue of
      warp-divergence elimination: whole chunks of work are skipped at the
      granularity the hardware actually schedules.

The same kernel serves three modes (see ops.py):
  * full      — baseline rasterization (S^2 path);
  * prefix    — stop each pixel once its k-record fills (RC phase A:
                "identify the first k significant Gaussians");
  * resume    — continue cache-MISS pixels from their saved state
                (RC phase B), with per-pixel ``start_iter`` gating.

``_kernel_compact`` is the fourth mode: miss-compacted resume, where the P
lanes of a program come from *different* source tiles (LuminCore PE
remapping in software) — see ``ops.rasterize_resume_compacted``.

Exact-match contract with ``repro.kernels.ref.rasterize_ref`` (same
floating-point semantics, including the Gamma<eps freeze rule) — verified by
shape/dtype sweep tests over both body flavors.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gaussians import ALPHA_MAX, ALPHA_SIGNIFICANT, TRANSMITTANCE_EPS

P = 256            # pixels per tile (16 x 16)
TILE = 16


def _exclusive_cumprod(x):
    inc = jax.lax.associative_scan(jnp.multiply, x, axis=0)
    exc = jnp.concatenate([jnp.ones_like(x[:1]), inc[:-1]], axis=0)
    return inc, exc


def _exclusive_cumsum_i32(x):
    inc = jax.lax.associative_scan(jnp.add, x.astype(jnp.int32), axis=0)
    return inc - x.astype(jnp.int32)


def _dense_chunk(alpha, sig, gid_cp, abs_pos, allowed, k_record, stop_at_k,
                 col, carry):
    """'dense' backend for one chunk: scan-closed-form integration + MXU
    matmul accumulate.  ``alpha``/``sig``/``gid_cp``/``allowed`` are [C, P];
    ``col`` is [C, 3] ([C, P, 3] in the compact kernel).
    Returns the updated (acc, trans, rec, cnt, nsig, niter, itk).
    """
    acc, trans, rec, cnt, nsig, niter, itk = carry
    if stop_at_k:
        pos_sig = cnt[None, :] + _exclusive_cumsum_i32(sig)
        sig = sig & (pos_sig < k_record)

    beta = jnp.where(sig, 1.0 - alpha, 1.0)
    p_inc, p_exc = _exclusive_cumprod(beta)
    p_exc = p_exc * trans[None, :]
    p_inc = p_inc * trans[None, :]
    contrib = sig & (p_exc > TRANSMITTANCE_EPS)

    w = jnp.where(contrib, p_exc * alpha, 0.0)             # [C, P]
    if col.ndim == 2:   # shared per-tile colors: one MXU matmul
        acc = acc + jax.lax.dot_general(
            w, col, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # [P, 3]
    else:               # per-lane gathered colors (compact kernel)
        acc = acc + jnp.sum(w[..., None] * col, axis=0)
    trans = jnp.minimum(trans, jnp.min(
        jnp.where(contrib, p_inc, trans[None, :]), axis=0))

    pos = cnt[None, :] + _exclusive_cumsum_i32(contrib)    # [C, P]
    for kk in range(k_record):
        m = contrib & (pos == kk)
        sel = jnp.max(jnp.where(m, gid_cp, -1), axis=0)    # [P]
        rec = rec.at[kk].set(jnp.where(sel >= 0, sel, rec[kk]))
    iters = abs_pos + 1                                    # [C, 1]
    m_k = contrib & (pos == (k_record - 1))
    sel_it = jnp.max(jnp.where(m_k, iters, -1), axis=0)
    itk = jnp.where(sel_it >= 0, sel_it, itk)

    cnt = cnt + jnp.sum(contrib.astype(jnp.int32), axis=0)
    nsig = nsig + jnp.sum(contrib.astype(jnp.int32), axis=0)
    active = ((p_exc > TRANSMITTANCE_EPS) & (gid_cp >= 0) & allowed)
    if stop_at_k:
        # a pixel pauses right after its record fills: iterations past the
        # fill point are not examined (hardware would hand off to lookup)
        active = active & (pos < k_record)
    niter = niter + jnp.sum(active.astype(jnp.int32), axis=0)
    return acc, trans, rec, cnt, nsig, niter, itk


def _seq_chunk(alpha, sig_pre, gid_cp, abs0, allowed, k_record, stop_at_k,
               col, carry):
    """'seq' backend for one chunk: per-Gaussian FIFO update (bit-identical
    to the reference oracle's scan body), with a real branch skipping
    Gaussians that are significant for no pixel — under S^2 sharing a large
    fraction of a tile's list is invisible at the render pose, and a scalar
    core should not integrate invisibility.

    ``alpha``/``sig_pre``/``allowed``/``gid_cp`` are [C, P] from the dense
    frontend (``sig_pre`` has no record-count gating — that is per-pixel
    state and is applied inside the loop); ``col`` is [C, 3] or [C, P, 3].
    """
    chunk = alpha.shape[0]

    def gbody(i, carry):
        acc, trans, rec, cnt, nsig, niter, itk = carry
        a_i = alpha[i]                                      # [P]
        s_i = sig_pre[i] & allowed[i]
        gid_i = gid_cp[i]                                   # [P]
        active = trans > TRANSMITTANCE_EPS
        # examined uses this Gaussian's *pre-update* record count, exactly
        # like the oracle (the filling Gaussian itself is still examined)
        examined = active & (gid_i >= 0) & allowed[i]
        if stop_at_k:
            examined = examined & (cnt < k_record)

        def integrate(carry):
            acc, trans, rec, cnt, nsig, itk = carry
            sig = s_i
            if stop_at_k:
                sig = sig & (cnt < k_record)
            contrib = sig & active
            w = jnp.where(contrib, trans * a_i, 0.0)
            col_i = col[i]                                  # [3] or [P, 3]
            acc = acc + (w[:, None] * col_i[None, :] if col_i.ndim == 1
                         else w[:, None] * col_i)
            trans = jnp.where(contrib, trans * (1.0 - a_i), trans)
            can = contrib & (cnt < k_record)
            slot = (jax.lax.broadcasted_iota(
                jnp.int32, (k_record, cnt.shape[0]), 0)
                    == cnt[None, :]) & can[None, :]         # [k, lanes]
            rec = jnp.where(slot, gid_i[None, :], rec)
            new_cnt = cnt + contrib.astype(jnp.int32)
            just = (new_cnt >= k_record) & (cnt < k_record) & contrib
            itk = jnp.where(just, abs0 + i + 1, itk)
            nsig = nsig + contrib.astype(jnp.int32)
            return acc, trans, rec, new_cnt, nsig, itk

        # skip Gaussians that can contribute to no pixel: only the examined
        # counter can change for them, and it is updated unconditionally.
        # In stop-at-k mode a pixel with a full record can't take
        # contributions either — without that gate phase A would keep
        # integrating the tail of every tile after all records filled.
        may_contrib = s_i & active
        if stop_at_k:
            may_contrib = may_contrib & (cnt < k_record)
        acc, trans, rec, cnt, nsig, itk = jax.lax.cond(
            jnp.any(may_contrib), integrate, lambda c: c,
            (acc, trans, rec, cnt, nsig, itk))
        niter = niter + examined.astype(jnp.int32)
        return acc, trans, rec, cnt, nsig, niter, itk

    return jax.lax.fori_loop(0, chunk, gbody, carry)


def _kernel(mean2d_ref, conic_ref, color_ref, opacity_ref, ids_ref,
            acc0_ref, trans0_ref, rec0_ref, cnt0_ref, start_ref, live_ref,
            ncap_ref,
            acc_ref, trans_ref, rec_ref, cnt_ref, nsig_ref, niter_ref,
            itk_ref, chunks_ref,
            *, tiles_x: int, k_record: int, chunk: int, stop_at_k: bool,
            bg: float, body: str = 'dense'):
    t = pl.program_id(0)
    k_total = mean2d_ref.shape[1]
    # per-tile chunk cap: chunks past the tile's last valid Gaussian hold only
    # -1 padding and can never contribute — the while loop must not pay for
    # them (they are what kept empty/short tiles from ever early-exiting)
    nc = jnp.minimum(jnp.int32(k_total // chunk), ncap_ref[0, 0])

    ox = (t % tiles_x) * TILE
    oy = (t // tiles_x) * TILE
    px2 = jax.lax.broadcasted_iota(jnp.float32, (TILE, TILE), 1)
    py2 = jax.lax.broadcasted_iota(jnp.float32, (TILE, TILE), 0)
    px = px2.reshape(P) + ox + 0.5
    py = py2.reshape(P) + oy + 0.5

    live = live_ref[0] != 0                    # [P]
    start = start_ref[0]                       # [P] int32
    # first chunk that any live pixel needs
    start_eff = jnp.where(live, start, k_total)
    c0 = jnp.min(start_eff) // chunk
    c0 = jnp.minimum(c0, nc)

    def loop_body(carry):
        c, acc, trans, rec, cnt, nsig, niter, itk, nchunks = carry
        sl = pl.ds(c * chunk, chunk)
        gmx = mean2d_ref[0, sl, 0]             # [C]
        gmy = mean2d_ref[0, sl, 1]
        ca = conic_ref[0, sl, 0]
        cb = conic_ref[0, sl, 1]
        cc = conic_ref[0, sl, 2]
        col = color_ref[0, sl, :]              # [C, 3]
        op = opacity_ref[0, sl]                # [C]
        gid = ids_ref[0, sl]                   # [C] int32

        # dense frontend: alpha for the whole chunk x tile block
        dx = px[None, :] - gmx[:, None]        # [C, P]
        dy = py[None, :] - gmy[:, None]
        power = (-0.5 * (ca[:, None] * dx * dx + cc[:, None] * dy * dy)
                 - cb[:, None] * dx * dy)
        alpha = jnp.minimum(ALPHA_MAX, op[:, None] * jnp.exp(power))
        valid = (power <= 0.0) & (gid[:, None] >= 0)

        abs_pos = c * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        allowed = (abs_pos >= start[None, :]) & live[None, :]
        sig = (alpha > ALPHA_SIGNIFICANT) & valid & allowed    # [C, P]
        gid_cp = jnp.broadcast_to(gid[:, None], sig.shape)

        inner = (acc, trans, rec, cnt, nsig, niter, itk)
        if body == 'dense':
            inner = _dense_chunk(alpha, sig, gid_cp, abs_pos, allowed,
                                 k_record, stop_at_k, col, inner)
        else:
            inner = _seq_chunk(alpha, sig, gid_cp, c * chunk,
                               jnp.broadcast_to(allowed, sig.shape),
                               k_record, stop_at_k, col, inner)
        acc, trans, rec, cnt, nsig, niter, itk = inner
        return (c + 1, acc, trans, rec, cnt, nsig, niter, itk, nchunks + 1)

    def cond(carry):
        c, acc, trans, rec, cnt, nsig, niter, itk, nchunks = carry
        pix_done = ~live | (trans <= TRANSMITTANCE_EPS)
        if stop_at_k:
            pix_done = pix_done | (cnt >= k_record)
        return (c < nc) & ~jnp.all(pix_done)

    init = (
        c0,
        acc0_ref[0].astype(jnp.float32),       # [P, 3]
        trans0_ref[0].astype(jnp.float32),     # [P]
        rec0_ref[0].T,                          # [k, P] in-kernel layout
        cnt0_ref[0],                            # [P]
        jnp.zeros((P,), jnp.int32),
        jnp.zeros((P,), jnp.int32),
        jnp.full((P,), k_total, jnp.int32),
        jnp.int32(0),
    )
    (c, acc, trans, rec, cnt, nsig, niter, itk, nchunks) = jax.lax.while_loop(
        cond, loop_body, init)

    del bg  # background compositing happens once, in ops.py, after the final phase
    acc_ref[0] = acc
    trans_ref[0] = trans
    rec_ref[0] = rec.T
    cnt_ref[0] = cnt
    nsig_ref[0] = nsig
    niter_ref[0] = niter
    itk_ref[0] = itk
    chunks_ref[0, 0] = nchunks


class RasterState(NamedTuple):
    """Per-pixel kernel state: inputs (phase init) and outputs alike."""

    acc: jax.Array        # [T, P, 3]
    trans: jax.Array      # [T, P]
    record: jax.Array     # [T, P, k]
    rec_cnt: jax.Array    # [T, P]
    n_sig: jax.Array      # [T, P]
    n_iter: jax.Array     # [T, P]
    iter_at_k: jax.Array  # [T, P]
    chunks: jax.Array     # [T, 1] chunks actually processed (early-exit stat)


def rasterize_pallas(mean2d, conic, color, opacity, ids,
                     acc0, trans0, rec0, cnt0, start_iter, live,
                     *, tiles_x: int, k_record: int = 5, chunk: int = 64,
                     stop_at_k: bool = False, bg: float = 0.0,
                     interpret: bool = True, ncap=None,
                     body: str = 'dense') -> RasterState:
    """Invoke the kernel. Feature arrays are [T, K, ...]; K must be a
    multiple of ``chunk`` (ops.py pads).  State arrays are [T, P(=256), ...].

    ``ncap`` [T] int32 optionally caps the chunks each tile may walk (the
    chunk index of its last valid Gaussian); ``None`` means the full padded
    list.  Chunks past the cap hold only padding and cannot change any
    output, so the cap is a pure compute saving.  ``body`` picks the chunk
    backend flavor ('dense' scan+matmul vs 'seq' per-Gaussian FIFO) — both
    implement the same contract; ops.py defaults by platform.
    """
    t, k_total = ids.shape
    assert k_total % chunk == 0, (k_total, chunk)
    kr = rec0.shape[-1]
    assert kr == k_record
    if ncap is None:
        ncap = jnp.full((t,), k_total // chunk, jnp.int32)
    ncap = ncap.reshape(t, 1).astype(jnp.int32)

    grid = (t,)
    feat = lambda *dims: pl.BlockSpec((1, *dims), lambda i: (i,) + (0,) * len(dims))
    out_shapes = (
        jax.ShapeDtypeStruct((t, P, 3), jnp.float32),   # acc
        jax.ShapeDtypeStruct((t, P), jnp.float32),      # trans
        jax.ShapeDtypeStruct((t, P, k_record), jnp.int32),
        jax.ShapeDtypeStruct((t, P), jnp.int32),        # rec_cnt
        jax.ShapeDtypeStruct((t, P), jnp.int32),        # n_sig
        jax.ShapeDtypeStruct((t, P), jnp.int32),        # n_iter
        jax.ShapeDtypeStruct((t, P), jnp.int32),        # iter_at_k
        jax.ShapeDtypeStruct((t, 1), jnp.int32),        # chunks processed
    )
    out_specs = (
        feat(P, 3), feat(P), feat(P, k_record), feat(P), feat(P), feat(P),
        feat(P), feat(1),
    )
    in_specs = (
        feat(k_total, 2), feat(k_total, 3), feat(k_total, 3), feat(k_total),
        feat(k_total),
        feat(P, 3), feat(P), feat(P, k_record), feat(P), feat(P), feat(P),
        feat(1),
    )
    kern = functools.partial(_kernel, tiles_x=tiles_x, k_record=k_record,
                             chunk=chunk, stop_at_k=stop_at_k, bg=bg,
                             body=body)
    outs = pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret,
    )(mean2d, conic, color, opacity, ids,
      acc0, trans0, rec0, cnt0, start_iter, live.astype(jnp.int32), ncap)
    return RasterState(*outs)


# ---------------------------------------------------------------------------
# Miss-compacted resume — the software analogue of LuminCore's PE remapping
# ---------------------------------------------------------------------------

def _kernel_compact(mean2d_ref, conic_ref, color_ref, opacity_ref, ids_ref,
                    px_ref, py_ref, src_ref, ncap_ref,
                    acc0_ref, trans0_ref, rec0_ref, cnt0_ref, start_ref,
                    live_ref,
                    acc_ref, trans_ref, rec_ref, cnt_ref, nsig_ref,
                    niter_ref, itk_ref, chunks_ref,
                    *, k_record: int, chunk: int, body: str = 'dense'):
    """Resume integration for one *compacted* tile of P cache-miss pixels.

    Unlike ``_kernel``, the P pixels of a program do not share a source tile:
    each lane carries its own pixel center (``px``/``py``), its source tile
    id (``src``) and its per-pixel chunk cap.  Feature chunks are therefore
    gathered per lane — ``feats[src, c*chunk:(c+1)*chunk]`` — instead of
    broadcast from one tile's list.  This is LuminCore's PE remapping in
    software: scattered miss pixels are regrouped into dense tiles so the
    chunk loop pays per *miss*, not per source tile.  On TPU the per-lane
    gather would become a scalar-prefetched DMA per source tile (cf.
    PrefetchScalarGridSpec); in interpret mode it lowers to a jnp gather.

    Per-pixel math is identical to ``_kernel``'s resume mode (no stop-at-k),
    so gather -> resume -> scatter reproduces the full-tile resume exactly.
    """
    k_total = mean2d_ref.shape[1]
    nc_total = k_total // chunk

    px = px_ref[0]                             # [P] f32 pixel centers
    py = py_ref[0]
    src = src_ref[0]                           # [P] int32 source tile ids
    ncap = ncap_ref[0]                         # [P] int32 per-pixel chunk cap
    live = live_ref[0] != 0                    # [P]
    start = start_ref[0]                       # [P] int32

    start_eff = jnp.where(live, start, k_total)
    c0 = jnp.minimum(jnp.min(start_eff) // chunk, nc_total)

    def loop_body(carry):
        c, acc, trans, rec, cnt, nsig, niter, itk, nchunks = carry
        sl = pl.ds(c * chunk, chunk)
        # per-lane feature gather: [T, C, ...] sliced once, indexed by src
        gmx = mean2d_ref[:, sl, 0][src].T      # [C, P]
        gmy = mean2d_ref[:, sl, 1][src].T
        ca = conic_ref[:, sl, 0][src].T
        cb = conic_ref[:, sl, 1][src].T
        cc = conic_ref[:, sl, 2][src].T
        col = jnp.moveaxis(color_ref[:, sl, :][src], 0, 1)   # [C, P, 3]
        op = opacity_ref[:, sl][src].T          # [C, P]
        gid = ids_ref[:, sl][src].T             # [C, P] int32

        dx = px[None, :] - gmx
        dy = py[None, :] - gmy
        power = (-0.5 * (ca * dx * dx + cc * dy * dy) - cb * dx * dy)
        alpha = jnp.minimum(ALPHA_MAX, op * jnp.exp(power))
        valid = (power <= 0.0) & (gid >= 0)

        abs_pos = c * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        allowed = (abs_pos >= start[None, :]) & live[None, :]
        sig = (alpha > ALPHA_SIGNIFICANT) & valid & allowed    # [C, P]

        inner = (acc, trans, rec, cnt, nsig, niter, itk)
        if body == 'dense':
            inner = _dense_chunk(alpha, sig, gid, abs_pos, allowed,
                                 k_record, False, col, inner)
        else:
            inner = _seq_chunk(alpha, sig, gid, c * chunk, allowed,
                               k_record, False, col, inner)
        acc, trans, rec, cnt, nsig, niter, itk = inner
        return (c + 1, acc, trans, rec, cnt, nsig, niter, itk, nchunks + 1)

    def cond(carry):
        c, acc, trans, rec, cnt, nsig, niter, itk, nchunks = carry
        # per-chunk early termination: a lane is done once dead, past its
        # transmittance floor, or past its source tile's last valid chunk
        remaining = live & (trans > TRANSMITTANCE_EPS) & (c < ncap)
        return (c < nc_total) & jnp.any(remaining)

    init = (
        c0,
        acc0_ref[0].astype(jnp.float32),       # [P, 3]
        trans0_ref[0].astype(jnp.float32),     # [P]
        rec0_ref[0].T,                          # [k, P] in-kernel layout
        cnt0_ref[0],                            # [P]
        jnp.zeros((P,), jnp.int32),
        jnp.zeros((P,), jnp.int32),
        jnp.full((P,), k_total, jnp.int32),
        jnp.int32(0),
    )
    (c, acc, trans, rec, cnt, nsig, niter, itk, nchunks) = jax.lax.while_loop(
        cond, loop_body, init)

    acc_ref[0] = acc
    trans_ref[0] = trans
    rec_ref[0] = rec.T
    cnt_ref[0] = cnt
    nsig_ref[0] = nsig
    niter_ref[0] = niter
    itk_ref[0] = itk
    chunks_ref[0, 0] = nchunks


def rasterize_compact_pallas(mean2d, conic, color, opacity, ids,
                             px, py, src, ncap,
                             acc0, trans0, rec0, cnt0, start_iter, live,
                             *, k_record: int = 5, chunk: int = 64,
                             interpret: bool = True,
                             body: str = 'dense') -> RasterState:
    """Invoke the miss-compacted resume kernel.

    Features are the *full* [T, K, ...] arrays (every program may gather from
    any source tile); ``px``/``py``/``src``/``ncap`` and the state arrays are
    compacted [CT, P(=256), ...] — CT compacted tiles whose lanes were packed
    miss-first by ``ops.rasterize_resume_compacted``.
    """
    t, k_total = ids.shape
    assert k_total % chunk == 0, (k_total, chunk)
    ct = src.shape[0]
    assert rec0.shape[-1] == k_record

    grid = (ct,)
    full = lambda *dims: pl.BlockSpec(dims, lambda i: (0,) * len(dims))
    lane = lambda *dims: pl.BlockSpec((1, *dims), lambda i: (i,) + (0,) * len(dims))
    out_shapes = (
        jax.ShapeDtypeStruct((ct, P, 3), jnp.float32),
        jax.ShapeDtypeStruct((ct, P), jnp.float32),
        jax.ShapeDtypeStruct((ct, P, k_record), jnp.int32),
        jax.ShapeDtypeStruct((ct, P), jnp.int32),
        jax.ShapeDtypeStruct((ct, P), jnp.int32),
        jax.ShapeDtypeStruct((ct, P), jnp.int32),
        jax.ShapeDtypeStruct((ct, P), jnp.int32),
        jax.ShapeDtypeStruct((ct, 1), jnp.int32),
    )
    out_specs = (
        lane(P, 3), lane(P), lane(P, k_record), lane(P), lane(P), lane(P),
        lane(P), lane(1),
    )
    in_specs = (
        full(t, k_total, 2), full(t, k_total, 3), full(t, k_total, 3),
        full(t, k_total), full(t, k_total),
        lane(P), lane(P), lane(P), lane(P),
        lane(P, 3), lane(P), lane(P, k_record), lane(P), lane(P), lane(P),
    )
    kern = functools.partial(_kernel_compact, k_record=k_record, chunk=chunk,
                             body=body)
    outs = pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret,
    )(mean2d, conic, color, opacity, ids,
      px, py, src.astype(jnp.int32), ncap.astype(jnp.int32),
      acc0, trans0, rec0, cnt0, start_iter, live.astype(jnp.int32))
    return RasterState(*outs)


# ---------------------------------------------------------------------------
# Slot-batched kernel — all serving slots' lanes of one tile per program
# ---------------------------------------------------------------------------

def _kernel_slots(mean2d_ref, conic_ref, color_ref, opacity_ref, ids_ref,
                  acc0_ref, trans0_ref, rec0_ref, cnt0_ref, start_ref,
                  live_ref, ncap_ref,
                  acc_ref, trans_ref, rec_ref, cnt_ref, nsig_ref, niter_ref,
                  itk_ref, chunks_ref,
                  *, tiles_x: int, k_record: int, chunk: int,
                  stop_at_k: bool, body: str):
    """One grid program = one tile position ACROSS ALL S serving slots.

    Under ``vmap`` a pallas_call batches by growing the grid — S x T
    programs that interpret mode executes serially, so multi-viewer serving
    gained no vector width from batching while the pure-JAX reference
    amortized its whole batch per op.  Here the slot axis rides *inside*
    the block instead: refs are [S, 1(tile), ...], the chunk bodies see
    [C, S*P] lanes, and one program does the whole fleet's work for its
    tile.  The while-loop trip count couples slots (a tile iterates until
    every slot's lanes are done) — pure extra *skipped* work for finished
    slots, bit-identical outputs per lane.
    """
    t = pl.program_id(0)
    s = mean2d_ref.shape[0]
    k_total = mean2d_ref.shape[2]
    n = s * P
    nc_total = k_total // chunk

    ox = (t % tiles_x) * TILE
    oy = (t // tiles_x) * TILE
    px2 = jax.lax.broadcasted_iota(jnp.float32, (TILE, TILE), 1)
    py2 = jax.lax.broadcasted_iota(jnp.float32, (TILE, TILE), 0)
    px = jnp.tile(px2.reshape(P) + ox + 0.5, s)        # [N]
    py = jnp.tile(py2.reshape(P) + oy + 0.5, s)

    live = (live_ref[:, 0] != 0).reshape(n)            # [N]
    start = start_ref[:, 0].reshape(n)                 # [N]
    ncap = jnp.repeat(jnp.minimum(ncap_ref[:, 0], nc_total), P)  # [N]
    start_eff = jnp.where(live, start, k_total)
    c0 = jnp.minimum(jnp.min(start_eff) // chunk, nc_total)

    def loop_body(carry):
        c, acc, trans, rec, cnt, nsig, niter, itk, nchunks = carry
        sl = pl.ds(c * chunk, chunk)

        def lanes(x):   # [S, C] per-slot scalars -> [C, N] lane layout
            return jnp.broadcast_to(x.T[:, :, None],
                                    (chunk, s, P)).reshape(chunk, n)

        gmx = lanes(mean2d_ref[:, 0, sl, 0])
        gmy = lanes(mean2d_ref[:, 0, sl, 1])
        ca = lanes(conic_ref[:, 0, sl, 0])
        cb = lanes(conic_ref[:, 0, sl, 1])
        cc = lanes(conic_ref[:, 0, sl, 2])
        op = lanes(opacity_ref[:, 0, sl])
        gid = lanes(ids_ref[:, 0, sl])
        col = jnp.broadcast_to(
            jnp.transpose(color_ref[:, 0, sl, :], (1, 0, 2))[:, :, None, :],
            (chunk, s, P, 3)).reshape(chunk, n, 3)

        dx = px[None, :] - gmx
        dy = py[None, :] - gmy
        power = (-0.5 * (ca * dx * dx + cc * dy * dy) - cb * dx * dy)
        alpha = jnp.minimum(ALPHA_MAX, op * jnp.exp(power))
        valid = (power <= 0.0) & (gid >= 0)

        abs_pos = c * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        allowed = (abs_pos >= start[None, :]) & live[None, :]
        sig = (alpha > ALPHA_SIGNIFICANT) & valid & allowed    # [C, N]

        inner = (acc, trans, rec, cnt, nsig, niter, itk)
        if body == 'dense':
            inner = _dense_chunk(alpha, sig, gid, abs_pos, allowed,
                                 k_record, stop_at_k, col, inner)
        else:
            inner = _seq_chunk(alpha, sig, gid, c * chunk, allowed,
                               k_record, stop_at_k, col, inner)
        acc, trans, rec, cnt, nsig, niter, itk = inner
        return (c + 1, acc, trans, rec, cnt, nsig, niter, itk, nchunks + 1)

    def cond(carry):
        c, acc, trans, rec, cnt, nsig, niter, itk, nchunks = carry
        remaining = live & (trans > TRANSMITTANCE_EPS) & (c < ncap)
        if stop_at_k:
            remaining = remaining & (cnt < k_record)
        return (c < nc_total) & jnp.any(remaining)

    init = (
        c0,
        acc0_ref[:, 0].reshape(n, 3).astype(jnp.float32),
        trans0_ref[:, 0].reshape(n).astype(jnp.float32),
        rec0_ref[:, 0].reshape(n, k_record).T,          # [k, N]
        cnt0_ref[:, 0].reshape(n),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.full((n,), k_total, jnp.int32),
        jnp.int32(0),
    )
    (c, acc, trans, rec, cnt, nsig, niter, itk, nchunks) = jax.lax.while_loop(
        cond, loop_body, init)

    acc_ref[:, 0] = acc.reshape(s, P, 3)
    trans_ref[:, 0] = trans.reshape(s, P)
    rec_ref[:, 0] = rec.T.reshape(s, P, k_record)
    cnt_ref[:, 0] = cnt.reshape(s, P)
    nsig_ref[:, 0] = nsig.reshape(s, P)
    niter_ref[:, 0] = niter.reshape(s, P)
    itk_ref[:, 0] = itk.reshape(s, P)
    chunks_ref[0, 0] = nchunks


def rasterize_slots_pallas(mean2d, conic, color, opacity, ids,
                           acc0, trans0, rec0, cnt0, start_iter, live,
                           *, tiles_x: int, k_record: int = 5,
                           chunk: int = 64, stop_at_k: bool = False,
                           interpret: bool = True, ncap=None,
                           body: str = 'dense'):
    """Slot-batched kernel invocation: features [S, T, K, ...], state
    [S, T, P, ...], ``ncap`` [S, T].  Grid is (T,) — each program handles
    one tile for every slot.  Returns (RasterState with [S, T, ...] leaves,
    chunks [T, 1] — the per-tile trip count, shared by all slots).
    """
    s, t, k_total = ids.shape
    assert k_total % chunk == 0, (k_total, chunk)
    assert rec0.shape[-1] == k_record
    if ncap is None:
        ncap = jnp.full((s, t), k_total // chunk, jnp.int32)

    grid = (t,)
    sb = lambda *dims: pl.BlockSpec((s, 1, *dims),
                                    lambda i: (0, i) + (0,) * len(dims))
    out_shapes = (
        jax.ShapeDtypeStruct((s, t, P, 3), jnp.float32),
        jax.ShapeDtypeStruct((s, t, P), jnp.float32),
        jax.ShapeDtypeStruct((s, t, P, k_record), jnp.int32),
        jax.ShapeDtypeStruct((s, t, P), jnp.int32),
        jax.ShapeDtypeStruct((s, t, P), jnp.int32),
        jax.ShapeDtypeStruct((s, t, P), jnp.int32),
        jax.ShapeDtypeStruct((s, t, P), jnp.int32),
        jax.ShapeDtypeStruct((t, 1), jnp.int32),
    )
    out_specs = (
        sb(P, 3), sb(P), sb(P, k_record), sb(P), sb(P), sb(P), sb(P),
        pl.BlockSpec((1, 1), lambda i: (i, 0)),
    )
    in_specs = (
        sb(k_total, 2), sb(k_total, 3), sb(k_total, 3), sb(k_total),
        sb(k_total),
        sb(P, 3), sb(P), sb(P, k_record), sb(P), sb(P), sb(P),
        pl.BlockSpec((s, 1), lambda i: (0, i)),
    )
    kern = functools.partial(_kernel_slots, tiles_x=tiles_x,
                             k_record=k_record, chunk=chunk,
                             stop_at_k=stop_at_k, body=body)
    outs = pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret,
    )(mean2d, conic, color, opacity, ids,
      acc0, trans0, rec0, cnt0, start_iter, live.astype(jnp.int32),
      ncap.astype(jnp.int32))
    return RasterState(*outs)
