"""Jitted wrappers over the Pallas kernels — the fast path of LuminSys.

Modes (mirroring the LuminCore execution phases):
  * ``rasterize_full``     — baseline / S^2-only rasterization;
  * ``rasterize_prefix``   — RC phase A: integrate until each pixel's
                             alpha-record fills (or terminates);
  * ``rasterize_resume``   — RC phase B: cache-miss pixels continue from
                             their saved state;
  * ``rc_lookup``          — LuminCache probe (one-hot-matmul kernel);
  * ``rasterize_with_rc``  — the full cached-rasterization pipeline
                             (A -> lookup -> B -> insert), bit-identical in
                             output to the functional path in
                             ``repro.core.pipeline`` but with the compute
                             savings realized at chunk granularity.

``interpret`` defaults to True off-TPU (CPU container); on TPU the kernels
compile natively.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import radiance_cache as rc
from repro.core.groups import regroup, ungroup
from repro.core.rasterize import RasterAux
from repro.core.tiling import TileFeatures
from repro.kernels import rasterize as rk
from repro.kernels import rc_lookup as lk


def default_interpret() -> bool:
    return jax.default_backend() != 'tpu'


def pad_features(feats: TileFeatures, chunk: int) -> TileFeatures:
    """Pad the per-tile list length K up to a multiple of ``chunk``."""
    k = feats.ids.shape[1]
    k_pad = (k + chunk - 1) // chunk * chunk
    if k_pad == k:
        return feats
    pad = k_pad - k

    def pz(x, fill=0.0):
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
        return jnp.pad(x, widths, constant_values=fill)

    return TileFeatures(
        mean2d=pz(feats.mean2d), conic=pz(feats.conic), color=pz(feats.color),
        opacity=pz(feats.opacity), ids=pz(feats.ids, -1))


def _baseline_state(t: int, k_record: int):
    p = rk.P
    return (jnp.zeros((t, p, 3), jnp.float32),
            jnp.ones((t, p), jnp.float32),
            jnp.full((t, p, k_record), -1, jnp.int32),
            jnp.zeros((t, p), jnp.int32),
            jnp.zeros((t, p), jnp.int32),            # start_iter
            jnp.ones((t, p), jnp.int32))             # live


def _to_aux(st: rk.RasterState) -> RasterAux:
    return RasterAux(alpha_record=st.record, n_significant=st.n_sig,
                     n_iterated=st.n_iter, iter_at_k=st.iter_at_k,
                     transmittance=st.trans)


def rasterize_full(feats: TileFeatures, tiles_x: int, *, k_record: int = 5,
                   chunk: int = 64, bg: float = 0.0,
                   interpret: bool | None = None):
    """Baseline rasterization. Returns (tile_colors [T,P,3], RasterAux, chunks [T,1])."""
    interpret = default_interpret() if interpret is None else interpret
    feats = pad_features(feats, chunk)
    t = feats.ids.shape[0]
    st = rk.rasterize_pallas(
        feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
        *_baseline_state(t, k_record), tiles_x=tiles_x, k_record=k_record,
        chunk=chunk, stop_at_k=False, interpret=interpret)
    colors = st.acc + st.trans[..., None] * bg
    return colors, _to_aux(st), st.chunks


def rasterize_prefix(feats: TileFeatures, tiles_x: int, *, k_record: int = 5,
                     chunk: int = 64, interpret: bool | None = None) -> rk.RasterState:
    """RC phase A. K must already be padded (call pad_features first)."""
    interpret = default_interpret() if interpret is None else interpret
    t = feats.ids.shape[0]
    return rk.rasterize_pallas(
        feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
        *_baseline_state(t, k_record), tiles_x=tiles_x, k_record=k_record,
        chunk=chunk, stop_at_k=True, interpret=interpret)


def rasterize_resume(feats: TileFeatures, tiles_x: int, state_a: rk.RasterState,
                     miss: jax.Array, *, k_record: int = 5, chunk: int = 64,
                     bg: float = 0.0, interpret: bool | None = None):
    """RC phase B: continue integration for miss pixels whose record filled.

    ``miss``: [T, P] bool.  Returns (tile_colors, RasterAux, chunks).
    Pixels that completed in phase A (record never filled) keep their phase-A
    color; hit pixels' colors are owned by the caller (cache values).
    """
    interpret = default_interpret() if interpret is None else interpret
    from repro.core.gaussians import TRANSMITTANCE_EPS
    live = (miss & (state_a.rec_cnt >= k_record)
            & (state_a.trans > TRANSMITTANCE_EPS))
    st = rk.rasterize_pallas(
        feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
        state_a.acc, state_a.trans, state_a.record, state_a.rec_cnt,
        state_a.iter_at_k, live,
        tiles_x=tiles_x, k_record=k_record, chunk=chunk, stop_at_k=False,
        interpret=interpret)
    colors = st.acc + st.trans[..., None] * bg
    aux = RasterAux(alpha_record=st.record, n_significant=state_a.n_sig + st.n_sig,
                    n_iterated=state_a.n_iter + st.n_iter,
                    iter_at_k=jnp.minimum(state_a.iter_at_k, st.iter_at_k),
                    transmittance=st.trans)
    return colors, aux, st.chunks


def rc_lookup(cache: rc.CacheState, ids: jax.Array, cfg: rc.CacheConfig,
              *, query_chunk: int = 512, interpret: bool | None = None):
    """LuminCache probe for all groups. ids [G, B, k]."""
    interpret = default_interpret() if interpret is None else interpret
    b = ids.shape[1]
    qc = min(query_chunk, b)
    while b % qc:
        qc -= 1
    return lk.rc_lookup_pallas(cache.tags, cache.values, ids, cfg,
                               query_chunk=qc, interpret=interpret)


class RCStats(NamedTuple):
    """Kernel-path statistics. True compute savings are chunk-granular:
    compare (chunks_prefix + chunks_resume) against a baseline run's chunk
    count — the benchmarks do exactly that."""

    hit_rate: jax.Array
    chunks_prefix: jax.Array   # chunk iterations, phase A (sum over tiles)
    chunks_resume: jax.Array   # chunk iterations, phase B


def rasterize_with_rc(feats: TileFeatures, tiles_x: int, tiles_y: int,
                      cache: rc.CacheState, cfg: rc.CacheConfig,
                      group_tiles: int, *, k_record: int = 5, chunk: int = 64,
                      bg: float = 0.0, interpret: bool | None = None):
    """Cached rasterization, hardware-phase ordering (A -> lookup -> B -> insert).

    Returns (final tile colors [T,P,3], new cache, RasterAux, RCStats).
    """
    feats = pad_features(feats, chunk)
    st_a = rasterize_prefix(feats, tiles_x, k_record=k_record, chunk=chunk,
                            interpret=interpret)
    ids_g = regroup(st_a.record, tiles_x, tiles_y, group_tiles)
    hit_g, val_g, _, way_g = rc_lookup(cache, ids_g, cfg, interpret=interpret)
    cache = rc.touch_all_groups(cache, ids_g, hit_g, way_g, cfg)
    hit = ungroup(hit_g[..., None], tiles_x, tiles_y, group_tiles)[..., 0]
    cached = ungroup(val_g, tiles_x, tiles_y, group_tiles)

    colors, aux, chunks_b = rasterize_resume(
        feats, tiles_x, st_a, ~hit, k_record=k_record, chunk=chunk, bg=bg,
        interpret=interpret)
    final = jnp.where(hit[..., None], cached, colors)

    # cache update: completed (miss) pixels insert their fresh values
    raw_g = regroup(colors, tiles_x, tiles_y, group_tiles)
    cache = rc.insert_all_groups(cache, ids_g, raw_g, ~hit_g, cfg)

    stats = RCStats(
        hit_rate=jnp.mean(hit.astype(jnp.float32)),
        chunks_prefix=jnp.sum(st_a.chunks),
        chunks_resume=jnp.sum(chunks_b),
    )
    return final, cache, aux, stats
