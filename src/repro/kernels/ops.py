"""Jitted wrappers over the Pallas kernels — the fast path of LuminSys.

Modes (mirroring the LuminCore execution phases):
  * ``rasterize_full``     — baseline / S^2-only rasterization;
  * ``rasterize_prefix``   — RC phase A: integrate until each pixel's
                             alpha-record fills (or terminates);
  * ``rasterize_resume``   — RC phase B: cache-miss pixels continue from
                             their saved state;
  * ``rc_lookup``          — LuminCache probe (one-hot-matmul kernel);
  * ``rasterize_with_rc``  — the full cached-rasterization pipeline
                             (A -> lookup -> B -> insert), bit-identical in
                             output to the functional path in
                             ``repro.core.pipeline`` but with the compute
                             savings realized at chunk granularity.

``interpret`` defaults to True off-TPU (CPU container); on TPU the kernels
compile natively.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import radiance_cache as rc
from repro.core.groups import regroup, ungroup
from repro.core.rasterize import RasterAux, chunk_caps, pad_tile_features
from repro.core.tiling import TileFeatures
from repro.kernels import rasterize as rk
from repro.kernels import rc_lookup as lk


def default_interpret() -> bool:
    return jax.default_backend() != 'tpu'


def default_body(interpret: bool) -> str:
    """Chunk-backend flavor: the scan+MXU 'dense' body is built for TPU
    vector/matrix units; interpret mode (CPU) pays its log(C) scan passes
    for real, so it gets the sequential FIFO body (which also skips
    render-pose-invisible Gaussians with a real branch)."""
    return 'seq' if interpret else 'dense'


# Canonical implementations live beside the reference rasterizer (which
# shares the chunk accounting); re-exported here for the kernel wrappers.
pad_features = pad_tile_features


def trim_features(feats: TileFeatures, tiles_x: int,
                  t_img: int | None = None) -> TileFeatures:
    """Drop per-tile list entries that provably cannot be *significant*
    anywhere in their tile, and compact survivors to the front.

    Under S^2 a tile's shared list was built at the speculative sort pose
    with an inflated footprint; by the render pose — especially late in a
    sharing window, and for slots whose cohort sorted ticks ago — a sizable
    fraction of entries can no longer reach alpha > 1/255 inside the tile.
    They still cost chunk iterations (and, in the slot-batched kernel,
    couple every slot's trip count to the stalest list).  An entry is kept
    iff the level-set ellipse ``alpha == ALPHA_SIGNIFICANT`` (axis-aligned
    bbox of the conic quadratic at ``q = 2 ln(opacity/alpha_sig)``, inflated
    by a safety margin so float rounding can never flip a kept/dropped
    decision) overlaps its tile.  Only insignificant evaluations are
    dropped, so images, alpha-records, transmittance and every cache
    decision are bit-identical; the *examined* counter (``n_iterated``)
    honestly shrinks — this is the fast path measuring less work, not the
    oracle changing its answer.

    ``t_img``: tiles per image when the leading axis flattens slot x tile
    (the slot-batched path); defaults to "all tiles are one image".
    """
    t, k = feats.ids.shape
    timg = t if t_img is None else t_img
    a = feats.conic[..., 0]
    b = feats.conic[..., 1]
    c = feats.conic[..., 2]
    op = feats.opacity
    from repro.core.gaussians import ALPHA_SIGNIFICANT
    # alpha > sig  <=>  a dx^2 + 2b dx dy + c dy^2 < 2 ln(op / sig)
    q = 2.0 * jnp.log(jnp.maximum(op, 1e-12) / ALPHA_SIGNIFICANT)
    can_sig = q > 0.0
    det = jnp.maximum(a * c - b * b, 1e-12)
    q_safe = jnp.maximum(q, 0.0) * 1.02          # float-rounding headroom
    rx = jnp.sqrt(q_safe * c / det) + 0.5        # bbox half-extents + margin
    ry = jnp.sqrt(q_safe * a / det) + 0.5

    tix = jnp.arange(t, dtype=jnp.int32) % timg
    x0 = ((tix % tiles_x) * rk.TILE).astype(jnp.float32)[:, None]
    y0 = ((tix // tiles_x) * rk.TILE).astype(jnp.float32)[:, None]
    mx, my = feats.mean2d[..., 0], feats.mean2d[..., 1]
    overlap = ((mx + rx >= x0) & (mx - rx <= x0 + rk.TILE)
               & (my + ry >= y0) & (my - ry <= y0 + rk.TILE))
    keep = overlap & can_sig & (feats.ids >= 0)

    # stable partition: survivors first, depth order preserved
    perm = jnp.argsort(~keep, axis=1, stable=True)
    kept = jnp.take_along_axis(keep, perm, axis=1)

    def g(x):
        p = perm[..., None] if x.ndim == 3 else perm
        return jnp.take_along_axis(x, p, axis=1)

    return TileFeatures(
        mean2d=g(feats.mean2d), conic=g(feats.conic), color=g(feats.color),
        opacity=jnp.where(kept, g(feats.opacity), 0.0),
        ids=jnp.where(kept, g(feats.ids), -1))


def _baseline_state(t: int, k_record: int, live=None):
    p = rk.P
    if live is None:
        live_tp = jnp.ones((t, p), jnp.int32)
    else:
        live_tp = jnp.broadcast_to(jnp.asarray(live, bool),
                                   (t, p)).astype(jnp.int32)
    return (jnp.zeros((t, p, 3), jnp.float32),
            jnp.ones((t, p), jnp.float32),
            jnp.full((t, p, k_record), -1, jnp.int32),
            jnp.zeros((t, p), jnp.int32),
            jnp.zeros((t, p), jnp.int32),            # start_iter
            live_tp)                                 # live


def _to_aux(st: rk.RasterState) -> RasterAux:
    return RasterAux(alpha_record=st.record, n_significant=st.n_sig,
                     n_iterated=st.n_iter, iter_at_k=st.iter_at_k,
                     transmittance=st.trans)


def rasterize_full(feats: TileFeatures, tiles_x: int, *, k_record: int = 5,
                   chunk: int = 64, bg: float = 0.0, live=None,
                   interpret: bool | None = None):
    """Baseline rasterization. Returns (tile_colors [T,P,3], RasterAux, chunks [T,1]).

    ``live`` (anything broadcastable to [T, P] bool) masks dead pixels/lanes:
    they contribute nothing, count zero iterations, and whole-dead tiles skip
    their chunk loop entirely.
    """
    interpret = default_interpret() if interpret is None else interpret
    feats = pad_features(feats, chunk)
    t = feats.ids.shape[0]
    st = rk.rasterize_pallas(
        feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
        *_baseline_state(t, k_record, live), tiles_x=tiles_x,
        k_record=k_record, chunk=chunk, stop_at_k=False, interpret=interpret,
        ncap=chunk_caps(feats.ids, chunk), body=default_body(interpret))
    colors = st.acc + st.trans[..., None] * bg
    return colors, _to_aux(st), st.chunks


def rasterize_prefix(feats: TileFeatures, tiles_x: int, *, k_record: int = 5,
                     chunk: int = 64, live=None,
                     interpret: bool | None = None) -> rk.RasterState:
    """RC phase A. K must already be padded (call pad_features first)."""
    interpret = default_interpret() if interpret is None else interpret
    t = feats.ids.shape[0]
    return rk.rasterize_pallas(
        feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
        *_baseline_state(t, k_record, live), tiles_x=tiles_x,
        k_record=k_record, chunk=chunk, stop_at_k=True, interpret=interpret,
        ncap=chunk_caps(feats.ids, chunk), body=default_body(interpret))


def resume_live_mask(state_a: rk.RasterState, miss: jax.Array,
                     k_record: int) -> jax.Array:
    """Which pixels phase B must actually integrate: cache misses whose
    record filled in phase A (others already completed) and whose
    transmittance has not bottomed out."""
    from repro.core.gaussians import TRANSMITTANCE_EPS
    return (miss & (state_a.rec_cnt >= k_record)
            & (state_a.trans > TRANSMITTANCE_EPS))


def _combine_resume(state_a: rk.RasterState, st: rk.RasterState, bg: float):
    colors = st.acc + st.trans[..., None] * bg
    aux = RasterAux(alpha_record=st.record,
                    n_significant=state_a.n_sig + st.n_sig,
                    n_iterated=state_a.n_iter + st.n_iter,
                    iter_at_k=jnp.minimum(state_a.iter_at_k, st.iter_at_k),
                    transmittance=st.trans)
    return colors, aux, st.chunks


def rasterize_resume(feats: TileFeatures, tiles_x: int, state_a: rk.RasterState,
                     miss: jax.Array, *, k_record: int = 5, chunk: int = 64,
                     bg: float = 0.0, interpret: bool | None = None):
    """RC phase B: continue integration for miss pixels whose record filled.

    ``miss``: [T, P] bool.  Returns (tile_colors, RasterAux, chunks).
    Pixels that completed in phase A (record never filled) keep their phase-A
    color; hit pixels' colors are owned by the caller (cache values).
    """
    interpret = default_interpret() if interpret is None else interpret
    live = resume_live_mask(state_a, miss, k_record)
    st = rk.rasterize_pallas(
        feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
        state_a.acc, state_a.trans, state_a.record, state_a.rec_cnt,
        state_a.iter_at_k, live,
        tiles_x=tiles_x, k_record=k_record, chunk=chunk, stop_at_k=False,
        interpret=interpret, ncap=chunk_caps(feats.ids, chunk),
        body=default_body(interpret))
    return _combine_resume(state_a, st, bg)


def rasterize_resume_compacted(feats: TileFeatures, tiles_x: int,
                               state_a: rk.RasterState, miss: jax.Array,
                               *, k_record: int = 5, chunk: int = 64,
                               bg: float = 0.0,
                               interpret: bool | None = None,
                               t_img: int | None = None):
    """RC phase B with **miss compaction** — LuminCore's PE remap in software.

    ``rasterize_resume`` pays per *tile*: one scattered miss pixel forces its
    whole tile back through the chunk loop, so at a 95% hit rate phase B
    still costs nearly a full pass (the warp-divergence pathology, measured
    as negative ``chunk_savings_%`` before this stage existed).  Here the
    miss pixels of the whole frame are gathered — with their saved phase-A
    alpha-record state — into dense compacted tiles (stable sort keeps them
    source-tile-major for locality), only those tiles walk the chunk loop
    (all-hit compacted tiles exit at zero chunks), and the results scatter
    back to their home pixels.  Phase-B chunk count then scales with the
    miss *count*, not the tile count.

    Bit-compatible with ``rasterize_resume`` (same per-pixel op sequence;
    the accumulate is a reduce instead of an MXU dot, so colors agree to
    float32 ulp, integer state exactly).
    """
    interpret = default_interpret() if interpret is None else interpret
    t, p = state_a.trans.shape
    live = resume_live_mask(state_a, miss, k_record)

    # pack miss lanes first, source-tile-major (a stable partition: cheaper
    # than an argsort and order-preserving within each half)
    flat = live.reshape(-1)                                    # [T*P]
    n_live = jnp.sum(flat.astype(jnp.int32))
    rank_live = jnp.cumsum(flat.astype(jnp.int32)) - 1
    rank_dead = jnp.cumsum((~flat).astype(jnp.int32)) - 1 + n_live
    dest = jnp.where(flat, rank_live, rank_dead)               # [T*P]
    idx = jnp.arange(t * p, dtype=jnp.int32)
    perm = jnp.zeros((t * p,), jnp.int32).at[dest].set(idx)
    inv = dest

    # ``t_img`` = tiles per image: when the leading axis is a flattened
    # slot x tile product (cross-slot compaction in the batched serving
    # path), pixel coordinates repeat every t_img tiles
    timg = t if t_img is None else t_img
    tix = jnp.arange(t * p, dtype=jnp.int32) // p
    pix = jnp.arange(t * p, dtype=jnp.int32) % p
    tim = tix % timg
    px = ((tim % tiles_x) * rk.TILE + pix % rk.TILE + 0.5).astype(jnp.float32)
    py = ((tim // tiles_x) * rk.TILE + pix // rk.TILE + 0.5).astype(jnp.float32)
    ncap_t = chunk_caps(feats.ids, chunk)                      # [T]

    def gather(x):
        return x.reshape(t * p, *x.shape[2:])[perm].reshape(t, p, *x.shape[2:])

    st = rk.rasterize_compact_pallas(
        feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
        gather(px.reshape(t, p)), gather(py.reshape(t, p)),
        gather(tix.reshape(t, p)), gather(ncap_t[tix].reshape(t, p)),
        gather(state_a.acc), gather(state_a.trans), gather(state_a.record),
        gather(state_a.rec_cnt), gather(state_a.iter_at_k),
        gather(live),
        k_record=k_record, chunk=chunk, interpret=interpret,
        body=default_body(interpret))

    def scatter(x):
        return x.reshape(t * p, *x.shape[2:])[inv].reshape(t, p, *x.shape[2:])

    st = rk.RasterState(
        acc=scatter(st.acc), trans=scatter(st.trans), record=scatter(st.record),
        rec_cnt=scatter(st.rec_cnt), n_sig=scatter(st.n_sig),
        n_iter=scatter(st.n_iter), iter_at_k=scatter(st.iter_at_k),
        chunks=st.chunks)   # chunk counts belong to compacted tiles; sum is
                            # the phase-B cost either way
    return _combine_resume(state_a, st, bg)


def rc_lookup(cache: rc.CacheState, ids: jax.Array, cfg: rc.CacheConfig,
              *, query_chunk: int = 512, interpret: bool | None = None):
    """LuminCache probe for all groups. ids [G, B, k].

    On TPU this is the one-hot-matmul Pallas kernel (a gather re-expressed
    for the MXU, where vector gathers are weak).  In interpret mode the MXU
    trick is a pure pessimization — a [B, n_sets] one-hot GEMM a scalar core
    must actually execute — so the probe runs the bit-identical gather
    formulation (the kernel's oracle) instead.  Same outputs either way.
    """
    interpret = default_interpret() if interpret is None else interpret
    if interpret:
        from repro.kernels import ref
        return ref.rc_lookup_ref(cache.tags, cache.values, ids, cfg)
    b = ids.shape[1]
    qc = min(query_chunk, b)
    while b % qc:
        qc -= 1
    return lk.rc_lookup_pallas(cache.tags, cache.values, ids, cfg,
                               query_chunk=qc, interpret=interpret)


def rc_probe(cache: rc.CacheState, ids_g: jax.Array, cfg: rc.CacheConfig,
             *, interpret: bool | None = None):
    """Cache lookup + LRU touch for one viewer, implementation chosen by
    platform.  Returns (hit_g, val_g, way_g, cache-with-touch-applied).

    In interpret mode the gather-formulation probe applies the touch inline
    (one pass); the Pallas kernel leaves cache state untouched, so on TPU
    the touch runs as a separate step after it — identical evolution."""
    interp = default_interpret() if interpret is None else interpret
    if interp:
        hit_g, val_g, _, way_g, cache = rc.lookup_all_groups(cache, ids_g,
                                                             cfg)
        return hit_g, val_g, way_g, cache
    hit_g, val_g, _, way_g = rc_lookup(cache, ids_g, cfg, interpret=interp)
    cache = rc.touch_all_groups(cache, ids_g, hit_g, way_g, cfg)
    return hit_g, val_g, way_g, cache


def rc_probe_multi(cache: rc.CacheState, ids: jax.Array, cfg: rc.CacheConfig,
                   live: jax.Array | None = None,
                   *, interpret: bool | None = None):
    """Shared-cache probe for V viewers of one scene: ids [V, G, B, k],
    live [V] bool.  Returns (hit [V,G,B], val [V,G,B,3], way [V,G,B],
    cache-with-touch-applied).

    The viewer axis flattens slot-major into each group's record batch, so
    LRU evolution is the deterministic (slot, pixel) serial order and V == 1
    is bit-identical to ``rc_probe``.  Dead viewers probe without touching.
    On TPU the flattened batch goes through the one-hot-matmul Pallas lookup
    and the (masked) touch runs as a separate step — identical evolution.
    """
    interp = default_interpret() if interpret is None else interpret
    if interp:
        hit, val, _, way, cache = rc.lookup_all_groups_multi(cache, ids, cfg,
                                                             live=live)
        return hit, val, way, cache
    v = ids.shape[0]
    ids_f = rc.slot_major(ids)
    live_f = None
    if live is not None:
        live_f = rc.slot_major(jnp.broadcast_to(live[:, None, None],
                                                ids.shape[:3]))
    hit_f, val_f, _, way_f = rc_lookup(cache, ids_f, cfg, interpret=interp)
    cache = rc.touch_all_groups(cache, ids_f, hit_f, way_f, cfg, live=live_f)
    return (rc.slot_split(hit_f, v), rc.slot_split(val_f, v),
            rc.slot_split(way_f, v), cache)


class RCStats(NamedTuple):
    """Kernel-path statistics. True compute savings are chunk-granular:
    compare (chunks_prefix + chunks_resume) against ``chunks_bound`` (what a
    count-capped full pass over the same tiles would cost) — the benchmarks
    do exactly that."""

    hit_rate: jax.Array
    chunks_prefix: jax.Array   # chunk iterations, phase A (sum over tiles)
    chunks_resume: jax.Array   # chunk iterations, phase B
    chunks_bound: jax.Array    # count-capped full-pass chunk total (scalar)
    hit: jax.Array             # [T, P] bool per-pixel cache-hit mask


def rasterize_with_rc(feats: TileFeatures, tiles_x: int, tiles_y: int,
                      cache: rc.CacheState, cfg: rc.CacheConfig,
                      group_tiles: int, *, k_record: int = 5, chunk: int = 64,
                      bg: float = 0.0, live=None, compact: bool = True,
                      interpret: bool | None = None):
    """Cached rasterization, hardware-phase ordering (A -> lookup -> B -> insert).

    ``live`` (broadcastable to [T, P] bool) masks dead pixels/idle lanes out
    of both phases; ``compact=True`` routes phase B through the
    miss-compacted resume (``rasterize_resume_compacted``) so its chunk cost
    scales with the miss count instead of the tile count.

    Returns (final tile colors [T,P,3], new cache, RasterAux, RCStats).
    """
    feats = pad_features(feats, chunk)
    st_a = rasterize_prefix(feats, tiles_x, k_record=k_record, chunk=chunk,
                            live=live, interpret=interpret)
    ids_g = regroup(st_a.record, tiles_x, tiles_y, group_tiles)
    hit_g, val_g, way_g, cache = rc_probe(cache, ids_g, cfg,
                                          interpret=interpret)
    hit = ungroup(hit_g[..., None], tiles_x, tiles_y, group_tiles)[..., 0]
    cached = ungroup(val_g, tiles_x, tiles_y, group_tiles)

    miss = ~hit
    if live is not None:
        miss = miss & jnp.broadcast_to(jnp.asarray(live, bool), miss.shape)
    resume = rasterize_resume_compacted if compact else rasterize_resume
    colors, aux, chunks_b = resume(
        feats, tiles_x, st_a, miss, k_record=k_record, chunk=chunk, bg=bg,
        interpret=interpret)
    final = jnp.where(hit[..., None], cached, colors)

    # cache update: completed (miss) pixels insert their fresh values
    raw_g = regroup(colors, tiles_x, tiles_y, group_tiles)
    cache = rc.insert_all_groups(cache, ids_g, raw_g, ~hit_g, cfg)

    stats = RCStats(
        hit_rate=jnp.mean(hit.astype(jnp.float32)),
        chunks_prefix=jnp.sum(st_a.chunks),
        chunks_resume=jnp.sum(chunks_b),
        chunks_bound=jnp.sum(chunk_caps(feats.ids, chunk)),
        hit=hit,
    )
    return final, cache, aux, stats


# ---------------------------------------------------------------------------
# Slot-batched wrappers — the multi-viewer serving fast path
# ---------------------------------------------------------------------------
# A vmapped pallas_call batches by growing the grid: S x T programs that
# interpret mode executes serially, so batched serving gained no vector
# width.  These wrappers instead ride the slot axis inside each program's
# block (rk.rasterize_slots_pallas) and compact cache misses ACROSS slots,
# so one tick's shade is T fat programs plus one fleet-wide compacted
# resume.  Outputs are bit-identical per lane to the per-slot functions.

def pad_features_slots(feats_b: TileFeatures, chunk: int) -> TileFeatures:
    """``pad_features`` for [S, T, K, ...] feature stacks."""
    s, t = feats_b.ids.shape[:2]
    flat = TileFeatures(*[x.reshape((s * t,) + x.shape[2:]) for x in feats_b])
    flat = pad_features(flat, chunk)
    return TileFeatures(*[x.reshape((s, t) + x.shape[1:]) for x in flat])


def _slots_state(s: int, t: int, k_record: int, live) -> tuple:
    p = rk.P
    live_stp = jnp.broadcast_to(
        jnp.asarray(live, bool).reshape((-1,) + (1,) * 2), (s, t, p))
    return (jnp.zeros((s, t, p, 3), jnp.float32),
            jnp.ones((s, t, p), jnp.float32),
            jnp.full((s, t, p, k_record), -1, jnp.int32),
            jnp.zeros((s, t, p), jnp.int32),
            jnp.zeros((s, t, p), jnp.int32),
            live_stp.astype(jnp.int32))


def rasterize_prefix_slots(feats_b: TileFeatures, tiles_x: int, *,
                           k_record: int = 5, chunk: int = 64, live=None,
                           interpret: bool | None = None) -> rk.RasterState:
    """RC phase A for all serving slots in one slot-batched kernel call.
    ``feats_b`` leaves are [S, T, K, ...] and must be pre-padded
    (``pad_features_slots``); ``live`` is [S] bool (idle slots).  Returned
    state leaves are [S, T, P, ...]; ``chunks`` is the per-tile trip count
    [T, 1] (slot-coupled)."""
    interpret = default_interpret() if interpret is None else interpret
    s, t = feats_b.ids.shape[:2]
    if live is None:
        live = jnp.ones((s,), bool)
    ncap = chunk_caps(
        feats_b.ids.reshape(s * t, -1), chunk).reshape(s, t)
    return rk.rasterize_slots_pallas(
        feats_b.mean2d, feats_b.conic, feats_b.color, feats_b.opacity,
        feats_b.ids, *_slots_state(s, t, k_record, live),
        tiles_x=tiles_x, k_record=k_record, chunk=chunk, stop_at_k=True,
        interpret=interpret, ncap=ncap, body=default_body(interpret))


def rasterize_full_slots(feats_b: TileFeatures, tiles_x: int, *,
                         k_record: int = 5, chunk: int = 64,
                         bg: float = 0.0, live=None,
                         interpret: bool | None = None):
    """Slot-batched baseline rasterization (no RC).  Returns
    (colors [S,T,P,3], RasterAux with [S,T,P,...] leaves, chunks [T,1])."""
    interpret = default_interpret() if interpret is None else interpret
    feats_b = pad_features_slots(feats_b, chunk)
    s, t = feats_b.ids.shape[:2]
    if live is None:
        live = jnp.ones((s,), bool)
    ncap = chunk_caps(
        feats_b.ids.reshape(s * t, -1), chunk).reshape(s, t)
    st = rk.rasterize_slots_pallas(
        feats_b.mean2d, feats_b.conic, feats_b.color, feats_b.opacity,
        feats_b.ids, *_slots_state(s, t, k_record, live),
        tiles_x=tiles_x, k_record=k_record, chunk=chunk, stop_at_k=False,
        interpret=interpret, ncap=ncap, body=default_body(interpret))
    colors = st.acc + st.trans[..., None] * bg
    return colors, _to_aux(st), st.chunks


def rasterize_resume_compacted_slots(feats_b: TileFeatures, tiles_x: int,
                                     st_a: rk.RasterState, miss: jax.Array,
                                     *, t_img: int, k_record: int = 5,
                                     chunk: int = 64, bg: float = 0.0,
                                     interpret: bool | None = None):
    """Cross-slot miss-compacted phase B: the whole fleet's miss pixels
    pack into one run of compacted tiles (fewer live programs than
    per-slot compaction by up to S x).  ``feats_b``/``st_a``/``miss`` carry
    [S, T, ...] leaves; ``t_img`` = tiles per image (= T)."""
    s, t = feats_b.ids.shape[:2]

    def flat(x):
        return x.reshape((s * t,) + x.shape[2:])

    feats_f = TileFeatures(*[flat(x) for x in feats_b])
    st_f = rk.RasterState(acc=flat(st_a.acc), trans=flat(st_a.trans),
                          record=flat(st_a.record), rec_cnt=flat(st_a.rec_cnt),
                          n_sig=flat(st_a.n_sig), n_iter=flat(st_a.n_iter),
                          iter_at_k=flat(st_a.iter_at_k), chunks=st_a.chunks)
    colors, aux, chunks_b = rasterize_resume_compacted(
        feats_f, tiles_x, st_f, flat(miss), k_record=k_record, chunk=chunk,
        bg=bg, interpret=interpret, t_img=t_img)

    def unflat(x):
        return x.reshape((s, t) + x.shape[1:])

    aux = RasterAux(*[unflat(x) for x in aux])
    return unflat(colors), aux, chunks_b


def rasterize_with_rc_slots(feats_b: TileFeatures, tiles_x: int,
                            tiles_y: int, caches: rc.CacheState,
                            cfg: rc.CacheConfig, group_tiles: int, *,
                            viewers_per_scene: int = 1,
                            k_record: int = 5, chunk: int = 64,
                            bg: float = 0.0, live=None,
                            compact: bool = True,
                            interpret: bool | None = None):
    """Slot-batched cached rasterization: phase A in one slot-batched
    kernel, scene-major shared-cache probe, cross-slot miss-compacted
    resume, scene-major insert.  ``caches`` leaves carry a leading [C] axis
    with ``C = S // viewers_per_scene`` (slot ``i`` probes scene ``i // V``'s
    cache; slots of one scene share it, conflicts resolving in deterministic
    (slot, pixel) order — see ``rc_probe_multi``); ``live`` is [S] bool and
    masks idle slots out of LRU touches and inserts as well as the chunk
    loops.  With ``viewers_per_scene == 1`` every slot owns a private cache
    and per-lane results are bit-identical to mapping ``rasterize_with_rc``
    over slots; only the *chunk accounting* differs (phase-A trips are
    slot-coupled, so ``chunks_prefix``/``chunks_bound`` are fleet totals and
    ``hit_rate`` is per-slot [S]).
    """
    feats_b = pad_features_slots(feats_b, chunk)
    s, t = feats_b.ids.shape[:2]
    v = viewers_per_scene
    c = s // v
    if live is None:
        live = jnp.ones((s,), bool)
    live = jnp.asarray(live, bool).reshape(s)

    st_a = rasterize_prefix_slots(feats_b, tiles_x, k_record=k_record,
                                  chunk=chunk, live=live,
                                  interpret=interpret)

    ids_g = jax.vmap(
        lambda r: regroup(r, tiles_x, tiles_y, group_tiles))(st_a.record)
    ids_cv = ids_g.reshape(c, v, *ids_g.shape[1:])       # [C, V, G, B, k]
    live_cv = live.reshape(c, v)
    hit_cv, val_cv, way_cv, caches = jax.vmap(
        lambda cc, ii, lv: rc_probe_multi(cc, ii, cfg, live=lv,
                                          interpret=interpret)
    )(caches, ids_cv, live_cv)
    hit_g = hit_cv.reshape(s, *hit_cv.shape[2:])         # [S, G, B]
    val_g = val_cv.reshape(s, *val_cv.shape[2:])
    hit = jax.vmap(
        lambda h: ungroup(h[..., None], tiles_x, tiles_y,
                          group_tiles)[..., 0])(hit_g)
    cached = jax.vmap(
        lambda vv: ungroup(vv, tiles_x, tiles_y, group_tiles))(val_g)

    miss = ~hit & live[:, None, None]
    if compact:
        colors, aux, chunks_b = rasterize_resume_compacted_slots(
            feats_b, tiles_x, st_a, miss, t_img=t, k_record=k_record,
            chunk=chunk, bg=bg, interpret=interpret)
    else:
        colors, aux, chunks_b = jax.vmap(
            lambda f, st, m: rasterize_resume(
                TileFeatures(*f), tiles_x,
                rk.RasterState(*st, chunks=jnp.zeros((t, 1), jnp.int32)), m,
                k_record=k_record, chunk=chunk, bg=bg, interpret=interpret)
        )(tuple(feats_b),
          (st_a.acc, st_a.trans, st_a.record, st_a.rec_cnt, st_a.n_sig,
           st_a.n_iter, st_a.iter_at_k), miss)
    final = jnp.where(hit[..., None], cached, colors)

    raw_g = jax.vmap(
        lambda cl: regroup(cl, tiles_x, tiles_y, group_tiles))(colors)
    raw_cv = raw_g.reshape(c, v, *raw_g.shape[1:])
    caches = jax.vmap(
        lambda cc, ii, rr, dd: rc.insert_all_groups_multi(cc, ii, rr, dd, cfg)
    )(caches, ids_cv, raw_cv, ~hit_cv & live_cv[:, :, None, None])

    ncap = chunk_caps(feats_b.ids.reshape(s * t, -1), chunk)
    stats = RCStats(
        hit_rate=jnp.mean(hit.astype(jnp.float32), axis=(1, 2)),   # [S]
        # one slot-coupled trip covers all S slots' lanes of its tile, so
        # scale by S to keep the RCStats contract (chunks_prefix +
        # chunks_resume comparable to chunks_bound, both in per-slot-tile
        # chunk units)
        chunks_prefix=jnp.sum(st_a.chunks) * s,   # fleet
        chunks_resume=jnp.sum(chunks_b),          # fleet (cross-slot packed)
        chunks_bound=jnp.sum(ncap),               # fleet
        hit=hit,                                  # [S, T, P]
    )
    return final, caches, aux, stats
