"""Pallas TPU radiance-cache lookup kernel — LuminCache, re-expressed for TPU.

The paper's LuminCache is an SRAM set-associative cache probed with a
concatenated-Gaussian-ID index (Fig. 16).  TPUs expose no hardware cache and
vector gathers from VMEM are weak, but they have an MXU — so the tag probe
becomes a **one-hot matmul**:

    onehot[b, s] = (set_index(query b) == s)          # [Bc, S] f32
    probed       = onehot @ payload                    # [Bc, W*(k+3)]

one GEMM gathers every way's tags *and* values for the whole query chunk
(exact for int payloads < 2^24 in f32).  Tag compare + way select are then
dense VPU ops.  The grid is (groups, query-chunks); each group's full cache
payload (tags+values, ~128 KB at paper sizes) is VMEM-resident for all its
query chunks — the analogue of LuminCache's per-tile-group double buffering.

Updates (insert/pseudo-LRU) stay in `repro.core.radiance_cache`: they run
once per frame on miss pixels only and are scatter-bound, not lookup-bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import radiance_cache as rc


_MIX_CONSTS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F, 0x165667B1)


def _mix_index(ids, n_sets: int, k: int):
    """Same multiplicative hash as radiance_cache.set_index (mode='hash').

    Constants are inlined as scalars: Pallas kernels may not close over
    array-valued constants.
    """
    h = (ids[..., 0] + 3).astype(jnp.uint32) * jnp.uint32(_MIX_CONSTS[0])
    for i in range(1, k):
        m = ((ids[..., i] + 3).astype(jnp.uint32)
             * jnp.uint32(_MIX_CONSTS[i % len(_MIX_CONSTS)]))
        h = (h ^ m) * jnp.uint32(0x9E3779B1)
    h = h ^ (h >> 15)
    return (h % jnp.uint32(n_sets)).astype(jnp.int32)


def _kernel(tags_ref, values_ref, ids_ref,
            hit_ref, val_ref, sidx_ref, way_ref,
            *, n_sets: int, n_ways: int, k: int, index_mode: str,
            index_bits_shift: int):
    ids = ids_ref[0, 0]                      # [Bc, k] int32
    bc = ids.shape[0]

    if index_mode == 'hash':
        sidx = _mix_index(ids, n_sets, k)    # [Bc]
    else:  # 'bitconcat' — LuminCache Fig. 16 indexing
        bits_total = n_sets.bit_length() - 1
        per_id = max(1, bits_total // k)
        mask = (1 << per_id) - 1
        shifted = (ids >> index_bits_shift) & mask
        weights = (1 << (per_id * jax.lax.broadcasted_iota(
            jnp.int32, (1, k), 1)))
        sidx = jnp.abs(jnp.sum(shifted * weights, axis=-1)) % n_sets

    # one-hot probe: [Bc, S] f32 (exact for payload ints < 2^24)
    sets = jax.lax.broadcasted_iota(jnp.int32, (bc, n_sets), 1)
    onehot = (sidx[:, None] == sets).astype(jnp.float32)

    tags = tags_ref[0].reshape(n_sets, n_ways * k).astype(jnp.float32)
    vals = values_ref[0].reshape(n_sets, n_ways * 3)
    payload = jnp.concatenate([tags, vals], axis=1)      # [S, W*(k+3)]
    probed = jax.lax.dot_general(
        onehot, payload, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # [Bc, W*(k+3)]

    ptags = probed[:, :n_ways * k].reshape(bc, n_ways, k)
    pvals = probed[:, n_ways * k:].reshape(bc, n_ways, 3)
    match = jnp.all(ptags == ids[:, None, :].astype(jnp.float32), axis=-1)
    hit = jnp.any(match, axis=-1)
    way = jnp.argmax(match, axis=-1).astype(jnp.int32)
    sel = jax.nn.one_hot(way, n_ways, dtype=jnp.float32)  # [Bc, W]
    value = jnp.sum(sel[:, :, None] * pvals, axis=1)      # [Bc, 3]

    hit_ref[0, 0] = hit.astype(jnp.int32)
    val_ref[0, 0] = value
    sidx_ref[0, 0] = sidx
    way_ref[0, 0] = way


def rc_lookup_pallas(tags: jax.Array, values: jax.Array, ids: jax.Array,
                     cfg: rc.CacheConfig, *, query_chunk: int = 512,
                     interpret: bool = True):
    """tags [G,S,W,k] i32, values [G,S,W,3] f32, ids [G,B,k] i32 ->
    (hit [G,B] bool, value [G,B,3] f32, set_idx [G,B] i32, way [G,B] i32)."""
    g, s, w, k = tags.shape
    b = ids.shape[1]
    assert b % query_chunk == 0, (b, query_chunk)
    nq = b // query_chunk
    ids3 = ids.reshape(g, nq, query_chunk, k)

    grid = (g, nq)
    kern = functools.partial(
        _kernel, n_sets=s, n_ways=w, k=k, index_mode=cfg.index_mode,
        index_bits_shift=cfg.index_bits_shift)
    outs = pl.pallas_call(
        kern, grid=grid,
        in_specs=(
            pl.BlockSpec((1, s, w, k), lambda gi, qi: (gi, 0, 0, 0)),
            pl.BlockSpec((1, s, w, 3), lambda gi, qi: (gi, 0, 0, 0)),
            pl.BlockSpec((1, 1, query_chunk, k), lambda gi, qi: (gi, qi, 0, 0)),
        ),
        out_specs=(
            pl.BlockSpec((1, 1, query_chunk), lambda gi, qi: (gi, qi, 0)),
            pl.BlockSpec((1, 1, query_chunk, 3), lambda gi, qi: (gi, qi, 0, 0)),
            pl.BlockSpec((1, 1, query_chunk), lambda gi, qi: (gi, qi, 0)),
            pl.BlockSpec((1, 1, query_chunk), lambda gi, qi: (gi, qi, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((g, nq, query_chunk), jnp.int32),
            jax.ShapeDtypeStruct((g, nq, query_chunk, 3), jnp.float32),
            jax.ShapeDtypeStruct((g, nq, query_chunk), jnp.int32),
            jax.ShapeDtypeStruct((g, nq, query_chunk), jnp.int32),
        ),
        interpret=interpret,
    )(tags, values, ids3)
    hit, val, sidx, way = outs
    return (hit.reshape(g, b) != 0, val.reshape(g, b, 3),
            sidx.reshape(g, b), way.reshape(g, b))
