"""Pure-jnp oracles for the Pallas kernels (bit-identical contracts).

``rasterize_ref`` mirrors ``repro.kernels.rasterize.rasterize_pallas``
gaussian-by-gaussian with a sequential ``lax.scan`` — the obviously-correct
formulation of Eqn. 1 with the 1/255 significance rule and the Gamma<eps
freeze, generalized to phase-init state (start_iter / live / record resume).

``rc_lookup_ref`` mirrors ``repro.kernels.rc_lookup.rc_lookup_pallas`` via
the functional cache in ``repro.core.radiance_cache``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import radiance_cache as rc
from repro.core.gaussians import ALPHA_MAX, ALPHA_SIGNIFICANT, TRANSMITTANCE_EPS
from repro.kernels.rasterize import P, TILE, RasterState


def rasterize_ref(mean2d, conic, color, opacity, ids,
                  acc0, trans0, rec0, cnt0, start_iter, live,
                  *, tiles_x: int, k_record: int = 5, chunk: int = 64,
                  stop_at_k: bool = False, bg: float = 0.0) -> RasterState:
    t, k_total = ids.shape
    live = live.astype(bool)

    tix = jnp.arange(t, dtype=jnp.int32)
    ox = (tix % tiles_x) * TILE
    oy = (tix // tiles_x) * TILE
    py2, px2 = jnp.meshgrid(jnp.arange(TILE), jnp.arange(TILE), indexing='ij')
    px = px2.reshape(-1)[None, :] + ox[:, None] + 0.5   # [T, P]
    py = py2.reshape(-1)[None, :] + oy[:, None] + 0.5

    def per_tile(px_t, py_t, gm, gc, gcol, gop, gid,
                 acc0_t, trans0_t, rec0_t, cnt0_t, start_t, live_t):
        def step(carry, g):
            acc, trans, rec, cnt, nsig, niter, itk, i = carry
            m, c3, col, op, idd = g
            dx = px_t - m[0]
            dy = py_t - m[1]
            power = -0.5 * (c3[0] * dx * dx + c3[2] * dy * dy) - c3[1] * dx * dy
            alpha = jnp.minimum(ALPHA_MAX, op * jnp.exp(power))
            valid = (power <= 0.0) & (idd >= 0)
            allowed = (i >= start_t) & live_t
            active = trans > TRANSMITTANCE_EPS
            sig = (alpha > ALPHA_SIGNIFICANT) & valid & allowed
            if stop_at_k:
                sig = sig & (cnt < k_record)
            contrib = sig & active

            w = jnp.where(contrib, trans * alpha, 0.0)
            acc = acc + w[:, None] * col[None, :]
            trans = jnp.where(contrib, trans * (1.0 - alpha), trans)

            can = contrib & (cnt < k_record)
            slot = jax.nn.one_hot(cnt, k_record, dtype=bool) & can[:, None]
            rec = jnp.where(slot, idd, rec)
            new_cnt = cnt + contrib.astype(jnp.int32)
            just = (new_cnt >= k_record) & (cnt < k_record) & contrib
            itk = jnp.where(just, i + 1, itk)
            nsig = nsig + contrib.astype(jnp.int32)
            examined = active & (idd >= 0) & allowed
            if stop_at_k:
                examined = examined & (cnt < k_record)
            niter = niter + examined.astype(jnp.int32)
            return (acc, trans, rec, new_cnt, nsig, niter, itk, i + 1), None

        init = (acc0_t.astype(jnp.float32), trans0_t.astype(jnp.float32),
                rec0_t, cnt0_t,
                jnp.zeros((P,), jnp.int32), jnp.zeros((P,), jnp.int32),
                jnp.full((P,), k_total, jnp.int32), jnp.int32(0))
        (acc, trans, rec, cnt, nsig, niter, itk, _), _ = jax.lax.scan(
            step, init, (gm, gc, gcol, gop, gid))
        return acc, trans, rec, cnt, nsig, niter, itk

    acc, trans, rec, cnt, nsig, niter, itk = jax.vmap(per_tile)(
        px, py, mean2d, conic, color, opacity, ids,
        acc0, trans0, rec0, cnt0, start_iter, live)
    del bg  # compositing is ops-level in both implementations
    # the oracle has no chunk structure; report the dense-equivalent count
    chunks = jnp.full((t, 1), k_total // chunk, jnp.int32)
    return RasterState(acc, trans, rec, cnt, nsig, niter, itk, chunks)


def rc_lookup_ref(tags, values, ids, cfg: rc.CacheConfig):
    """Oracle for the lookup kernel: tags [G,S,W,k], values [G,S,W,3],
    ids [G,B,k] -> (hit [G,B], value [G,B,3], set_idx [G,B], way [G,B])."""
    def one(tg, vg, qg):
        sidx = rc.set_index(qg, cfg)
        cand = tg[sidx]                       # [B, W, k]
        m = jnp.all(cand == qg[:, None, :], axis=-1)
        hit = jnp.any(m, axis=-1)
        way = jnp.argmax(m, axis=-1)
        val = vg[sidx, way]
        return hit, val, sidx, way.astype(jnp.int32)
    return jax.vmap(one)(tags, values, ids)
