"""Image quality metrics: PSNR and SSIM (pure JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def psnr(a: jax.Array, b: jax.Array, max_val: float = 1.0) -> jax.Array:
    mse = jnp.mean((a - b) ** 2)
    return 10.0 * jnp.log10(max_val ** 2 / jnp.maximum(mse, 1e-12))


def _gaussian_kernel(size: int = 11, sigma: float = 1.5) -> jax.Array:
    x = jnp.arange(size, dtype=jnp.float32) - (size - 1) / 2.0
    g = jnp.exp(-(x ** 2) / (2 * sigma ** 2))
    g = g / jnp.sum(g)
    return jnp.outer(g, g)


def _filter2d(img: jax.Array, kern: jax.Array) -> jax.Array:
    """Depthwise 2D convolution, VALID padding. img: [H, W, C]."""
    c = img.shape[-1]
    x = img.transpose(2, 0, 1)[:, None]                   # [C,1,H,W]
    k = kern[None, None]                                   # [1,1,kh,kw]
    y = jax.lax.conv_general_dilated(x, k, (1, 1), 'VALID')
    return y[:, 0].transpose(1, 2, 0)


def ssim(a: jax.Array, b: jax.Array, max_val: float = 1.0) -> jax.Array:
    """Standard single-scale SSIM with an 11x11 Gaussian window."""
    c1 = (0.01 * max_val) ** 2
    c2 = (0.03 * max_val) ** 2
    kern = _gaussian_kernel()
    mu_a = _filter2d(a, kern)
    mu_b = _filter2d(b, kern)
    mu_aa, mu_bb, mu_ab = mu_a * mu_a, mu_b * mu_b, mu_a * mu_b
    s_aa = _filter2d(a * a, kern) - mu_aa
    s_bb = _filter2d(b * b, kern) - mu_bb
    s_ab = _filter2d(a * b, kern) - mu_ab
    num = (2 * mu_ab + c1) * (2 * s_ab + c2)
    den = (mu_aa + mu_bb + c1) * (s_aa + s_bb + c2)
    return jnp.mean(num / den)
