"""Sorting stage wrapper + order-stability diagnostics.

The actual (tile, depth) sort lives in ``repro.core.tiling`` (it is the
"duplicate + global key sort" used by 3DGS).  This module provides the
stage-level interface the pipeline and the cost models consume, plus the
order-agreement diagnostic backing the paper's claim that only ~0.2% of
depth-order pairs flip between adjacent poses (Sec. 3.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projection import Projected
from repro.core.tiling import TileLists, tile_lists_dense, tile_lists_sorted


def sort_scene(proj: Projected, width: int, height: int, capacity: int,
               method: str = 'dense', radius_margin: float = 0.0,
               max_tiles_per_gaussian: int = 16) -> TileLists:
    """Build depth-sorted per-tile lists.

    radius_margin inflates each Gaussian's footprint by that many pixels —
    this is the per-tile half of the S^2 expanded viewport: a Gaussian within
    `margin` px of a tile is included in that tile's list so small camera
    motion within the sharing window cannot move it out of coverage.
    """
    if radius_margin:
        proj = proj._replace(radius=jnp.where(proj.valid, proj.radius + radius_margin,
                                              proj.radius))
    if method == 'dense':
        return tile_lists_dense(proj, width, height, capacity)
    elif method == 'sorted':
        return tile_lists_sorted(proj, width, height, capacity,
                                 max_tiles_per_gaussian=max_tiles_per_gaussian)
    raise ValueError(f'unknown sorting method: {method}')


def pairwise_order_agreement(lists_a: TileLists, lists_b: TileLists) -> jax.Array:
    """Fraction of adjacent-pair depth orderings preserved between two sorts.

    For each tile we compare the relative order of consecutive entries of
    ``lists_a`` as they appear in ``lists_b`` (position lookup).  Entries
    missing from ``lists_b`` are ignored.  Returns a scalar in [0, 1]; the
    paper reports ~99.8% agreement for adjacent VR poses.
    """
    a, b = lists_a.indices, lists_b.indices           # [T, K]
    k = a.shape[1]

    def per_tile(row_a, row_b):
        # position of each id of row_a inside row_b (or -1)
        eq = row_a[:, None] == row_b[None, :]          # [K, K]
        present = jnp.any(eq & (row_a[:, None] >= 0), axis=1)
        pos = jnp.argmax(eq, axis=1)
        pos = jnp.where(present, pos, -1)
        p0, p1 = pos[:-1], pos[1:]
        both = (p0 >= 0) & (p1 >= 0)
        keep_order = (p1 > p0) & both
        return jnp.sum(keep_order), jnp.sum(both)

    kept, total = jax.vmap(per_tile)(a, b)
    return jnp.sum(kept) / jnp.maximum(jnp.sum(total), 1)
