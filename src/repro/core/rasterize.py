"""Rasterization (color integration) — pure-JAX reference implementation.

This is the paper's Eqn. 1 evaluated tile-by-tile in depth order:

    C(p) = sum_i  Gamma_i * alpha_i * c_i,   Gamma_i = prod_{j<i} (1 - alpha_j)

with the two reference-implementation rules Lumina exploits:
  * Gaussians with alpha <= 1/255 are *insignificant* and skipped;
  * integration terminates once Gamma < theta (1e-4).

Besides the image, the rasterizer emits the statistics Lumina's algorithm and
hardware model need:
  * the **alpha-record**: ids of the first `k_record` significant Gaussians of
    every pixel (the RC cache tag material, Sec. 3.2);
  * per-pixel significant / iterated counts (Fig. 4 characterization, and the
    LuminCore cost model inputs);
  * the iteration index at which the k-th significant Gaussian was found
    (everything after it is skippable on an RC hit).

The Pallas kernel in ``repro/kernels/rasterize.py`` implements the same
contract with VMEM tiling and chunk-level early exit; this module is its
oracle (``repro/kernels/ref.py`` re-exports from here).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.gaussians import ALPHA_MAX, ALPHA_SIGNIFICANT, TRANSMITTANCE_EPS
from repro.core.tiling import TILE, TileFeatures, TileLists


class RasterAux(NamedTuple):
    """Per-pixel rasterization statistics, shapes [T, P] (P = TILE*TILE)."""

    alpha_record: jax.Array   # [T, P, k_record] int32, -1 padded
    n_significant: jax.Array  # [T, P] int32
    n_iterated: jax.Array     # [T, P] int32 (Gaussians seen before termination)
    iter_at_k: jax.Array      # [T, P] int32 (iterations to find k-th significant)
    transmittance: jax.Array  # [T, P] final Gamma


def _pixel_coords(tiles_x: int, num_tiles: int):
    """Pixel-center coordinates for every tile: [T, P, 2]."""
    t = jnp.arange(num_tiles, dtype=jnp.int32)
    ox = (t % tiles_x) * TILE
    oy = (t // tiles_x) * TILE
    py, px = jnp.meshgrid(jnp.arange(TILE), jnp.arange(TILE), indexing='ij')
    px = px.reshape(-1)[None, :] + ox[:, None]   # [T, P]
    py = py.reshape(-1)[None, :] + oy[:, None]
    return jnp.stack([px + 0.5, py + 0.5], axis=-1).astype(jnp.float32)


def chunk_caps(ids: jax.Array, chunk: int) -> jax.Array:
    """Per-tile chunk cap: the chunk index one past each tile's last valid
    Gaussian ([T, K] ids -> [T] int32).  Robust to -1 holes mid-list.

    Single source of truth for the chunk accounting shared by this
    reference rasterizer and the Pallas kernel wrappers (re-exported as
    ``repro.kernels.ops.chunk_caps``) — the measured savings stay comparable
    only if both sides cap identically.
    """
    k = ids.shape[1]
    pos = jnp.arange(k, dtype=jnp.int32)
    last = jnp.max(jnp.where(ids >= 0, pos[None, :] + 1, 0), axis=1)
    return (last + chunk - 1) // chunk


def pad_tile_features(feats: TileFeatures, chunk: int) -> TileFeatures:
    """Pad the per-tile list length K up to a multiple of ``chunk``.
    Padding ids are -1 and opacity 0, so padded iterations (when reached at
    all) touch nothing.  Shared by the reference rasterizer and the kernel
    wrappers (``repro.kernels.ops.pad_features``)."""
    k = feats.ids.shape[1]
    k_pad = (k + chunk - 1) // chunk * chunk
    if k_pad == k:
        return feats
    pad = k_pad - k

    def pz(x, fill=0.0):
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
        return jnp.pad(x, widths, constant_values=fill)

    return TileFeatures(mean2d=pz(feats.mean2d), conic=pz(feats.conic),
                        color=pz(feats.color), opacity=pz(feats.opacity),
                        ids=pz(feats.ids, -1))


def rasterize_tiles(feats: TileFeatures, tiles_x: int, *, k_record: int = 5,
                    bg: float = 0.0, live=None, chunk: int = 64,
                    early_exit: bool = True) -> tuple[jax.Array, RasterAux]:
    """Integrate colors for all tiles.

    ``live`` mirrors the Pallas kernel's per-pixel liveness input: anything
    broadcastable to [T, P] bool (a scalar masks the whole call — e.g. one
    idle lane under vmap in the batched serving path).  Dead pixels
    contribute nothing and count zero iterations, so the stats of masked
    lanes stay out of the fleet telemetry; on the kernel fast path the same
    mask skips whole chunks.  ``None`` means all live.

    With ``early_exit`` (the default) the Gaussian walk is chunked
    (``chunk`` Gaussians per step) behind an early-exit ``while_loop``
    mirroring the Pallas kernel's: a tile stops as soon as every live
    pixel's transmittance bottoms out or its last valid Gaussian is behind
    it, and a fully masked tile runs **zero** chunks — idle serving lanes
    no longer pay for a dense scan of dead work, so the reference/
    sequential numbers the kernel path is judged against are honest.
    (Under ``vmap`` the loop runs to the *batch-wide* max trip count —
    per-lane savings there come from the slot compaction in
    ``repro.serve.stepper``.)  Skipped iterations could never contribute to
    any output or statistic, so results are bit-identical either way.

    ``early_exit=False`` keeps the single dense ``lax.scan`` over the whole
    list: a dynamic-trip ``while_loop`` is not reverse-mode differentiable,
    so gradient consumers (the fine-tuning loss) must take this path.

    Returns (tile_colors [T, P, 3], aux).
    """
    num_tiles = feats.mean2d.shape[0]
    p = TILE * TILE
    k = feats.mean2d.shape[1]
    pix = _pixel_coords(tiles_x, num_tiles)      # [T, P, 2]
    if live is None:
        live = True
    live_tp = jnp.broadcast_to(jnp.asarray(live, bool), (num_tiles, p))

    if early_exit:
        feats = pad_tile_features(feats, chunk)
        ncap = chunk_caps(feats.ids, chunk)      # [T]
    else:
        ncap = jnp.zeros((num_tiles,), jnp.int32)   # unused

    def per_tile(pix_t, mean2d, conic, color, opacity, ids, live_t, ncap_t):
        def step(carry, g):
            (acc, trans, rec_ids, rec_cnt, n_sig, n_iter, it_k, i) = carry
            g_mean, g_conic, g_color, g_op, g_id = g
            d = pix_t - g_mean[None, :]                     # [P, 2]
            dx, dy = d[:, 0], d[:, 1]
            power = -0.5 * (g_conic[0] * dx * dx + g_conic[2] * dy * dy) \
                - g_conic[1] * dx * dy
            alpha = jnp.minimum(ALPHA_MAX, g_op * jnp.exp(power))
            valid = (power <= 0.0) & (g_id >= 0)
            active = (trans > TRANSMITTANCE_EPS) & live_t
            sig = (alpha > ALPHA_SIGNIFICANT) & valid
            contrib = sig & active

            w = jnp.where(contrib, trans * alpha, 0.0)
            acc = acc + w[:, None] * g_color[None, :]
            trans = jnp.where(contrib, trans * (1.0 - alpha), trans)

            # alpha-record update (first k significant ids).
            can_rec = contrib & (rec_cnt < k_record)
            slot = jax.nn.one_hot(rec_cnt, k_record, dtype=bool) \
                & can_rec[:, None]                           # [P, k]
            rec_ids = jnp.where(slot, g_id, rec_ids)
            new_cnt = rec_cnt + can_rec.astype(jnp.int32)
            just_filled = (new_cnt == k_record) & (rec_cnt < k_record)
            it_k = jnp.where(just_filled, i + 1, it_k)
            n_sig = n_sig + contrib.astype(jnp.int32)
            n_iter = n_iter + (active & (g_id >= 0)).astype(jnp.int32)
            return (acc, trans, rec_ids, new_cnt, n_sig, n_iter, it_k, i + 1), None

        init = (
            jnp.zeros((p, 3), jnp.float32),
            jnp.ones((p,), jnp.float32),
            jnp.full((p, k_record), -1, jnp.int32),
            jnp.zeros((p,), jnp.int32),
            jnp.zeros((p,), jnp.int32),
            jnp.zeros((p,), jnp.int32),
            jnp.full((p,), k, jnp.int32),   # iter_at_k defaults to "all of them"
            jnp.int32(0),
        )

        if not early_exit:
            # dense scan over the whole list — the reverse-mode
            # differentiable formulation (see docstring)
            (acc, trans, rec_ids, rec_cnt, n_sig, n_iter, it_k, _), _ = \
                jax.lax.scan(step, init,
                             (mean2d, conic, color, opacity, ids))
            acc = acc + trans[:, None] * bg
            return acc, trans, rec_ids, n_sig, n_iter, it_k

        def chunk_body(carry):
            c, inner = carry
            sl = lambda x: jax.lax.dynamic_slice_in_dim(x, c * chunk, chunk)
            inner, _ = jax.lax.scan(
                step, inner,
                (sl(mean2d), sl(conic), sl(color), sl(opacity), sl(ids)))
            return (c + 1, inner)

        def chunk_cond(carry):
            c, inner = carry
            trans = inner[1]
            return (c < ncap_t) & jnp.any(live_t
                                          & (trans > TRANSMITTANCE_EPS))

        _, (acc, trans, rec_ids, rec_cnt, n_sig, n_iter, it_k, _) = \
            jax.lax.while_loop(chunk_cond, chunk_body, (jnp.int32(0), init))
        acc = acc + trans[:, None] * bg
        return acc, trans, rec_ids, n_sig, n_iter, it_k

    acc, trans, rec, n_sig, n_iter, it_k = jax.vmap(per_tile)(
        pix, feats.mean2d, feats.conic, feats.color, feats.opacity, feats.ids,
        live_tp, ncap)
    aux = RasterAux(alpha_record=rec, n_significant=n_sig, n_iterated=n_iter,
                    iter_at_k=it_k, transmittance=trans)
    return acc, aux


def assemble_image(tile_colors: jax.Array, tiles_x: int, tiles_y: int,
                   width: int, height: int) -> jax.Array:
    """[T, P, 3] tile colors -> [H, W, 3] image (crops tile padding)."""
    img = tile_colors.reshape(tiles_y, tiles_x, TILE, TILE, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(tiles_y * TILE, tiles_x * TILE, 3)
    return img[:height, :width]


def scatter_tile_pixels(values: jax.Array, tiles_x: int, tiles_y: int,
                        width: int, height: int) -> jax.Array:
    """Like assemble_image but for scalar per-pixel stats: [T, P] -> [H, W]."""
    img = values.reshape(tiles_y, tiles_x, TILE, TILE)
    img = img.transpose(0, 2, 1, 3).reshape(tiles_y * TILE, tiles_x * TILE)
    return img[:height, :width]
