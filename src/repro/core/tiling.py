"""Tile intersection + depth-sorted per-tile Gaussian lists.

3DGS rasterizes tile-by-tile (16x16 pixels).  This module builds, for every
tile, the depth-sorted list of Gaussians whose screen footprint overlaps it.
Fixed shapes throughout: each tile keeps at most `capacity` Gaussians
(closest-K by depth; overflow beyond capacity is dropped, as any fixed-budget
renderer must).

Two interchangeable constructions:

* ``tile_lists_dense``  — O(T*N) overlap matrix + top-k.  Simple, exact,
  used for small scenes and as the test oracle.
* ``tile_lists_sorted`` — the scalable path mirroring the real 3DGS
  "duplicate + global key sort" algorithm (THE Sorting stage of the paper):
  every Gaussian is duplicated once per covered tile (bounded statically),
  all duplicates are sorted by (tile, depth) with a single ``lax.sort``, and
  per-tile slices are recovered with ``searchsorted``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.projection import Projected

TILE = 16  # pixels per tile side (paper's tile size)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TileLists:
    """Depth-sorted per-tile Gaussian lists.

    indices : [T, K] int32 — Gaussian ids sorted near-to-far; -1 padding.
    count   : [T]   int32 — number of valid entries per tile.
    tiles_x, tiles_y : static ints — tile-grid dimensions.
    """

    indices: jax.Array
    count: jax.Array
    tiles_x: int = dataclasses.field(metadata=dict(static=True))
    tiles_y: int = dataclasses.field(metadata=dict(static=True))


def tile_grid(width: int, height: int) -> tuple[int, int]:
    return (width + TILE - 1) // TILE, (height + TILE - 1) // TILE


def _tile_bounds(tiles_x: int, tiles_y: int):
    """Pixel-space bounds of each tile: [T] arrays x0,y0,x1,y1."""
    tx = jnp.arange(tiles_x * tiles_y, dtype=jnp.int32) % tiles_x
    ty = jnp.arange(tiles_x * tiles_y, dtype=jnp.int32) // tiles_x
    x0 = (tx * TILE).astype(jnp.float32)
    y0 = (ty * TILE).astype(jnp.float32)
    return x0, y0, x0 + TILE, y0 + TILE


def tile_lists_dense(proj: Projected, width: int, height: int,
                     capacity: int) -> TileLists:
    """Exact per-tile lists via a dense [T, N] overlap test (small scenes)."""
    tiles_x, tiles_y = tile_grid(width, height)
    x0, y0, x1, y1 = _tile_bounds(tiles_x, tiles_y)          # [T]
    mx, my = proj.mean2d[:, 0], proj.mean2d[:, 1]            # [N]
    r = proj.radius                                           # [N]

    overlap = (
        (mx[None, :] + r[None, :] >= x0[:, None])
        & (mx[None, :] - r[None, :] < x1[:, None])
        & (my[None, :] + r[None, :] >= y0[:, None])
        & (my[None, :] - r[None, :] < y1[:, None])
        & proj.valid[None, :]
        & (r[None, :] > 0)
    )                                                         # [T, N]
    key = jnp.where(overlap, proj.depth[None, :], jnp.inf)
    k = min(capacity, key.shape[1])
    neg_top, idx = jax.lax.top_k(-key, k)                     # ascending depth
    got = jnp.isfinite(-neg_top)
    idx = jnp.where(got, idx, -1).astype(jnp.int32)
    if k < capacity:  # pad to requested capacity
        pad = capacity - k
        idx = jnp.pad(idx, ((0, 0), (0, pad)), constant_values=-1)
        got = jnp.pad(got, ((0, 0), (0, pad)))
    count = jnp.sum(got, axis=1).astype(jnp.int32)
    return TileLists(idx, count, tiles_x, tiles_y)


def tile_lists_sorted(proj: Projected, width: int, height: int,
                      capacity: int, max_tiles_per_gaussian: int = 16) -> TileLists:
    """Scalable per-tile lists: duplicate Gaussians per covered tile and run a
    single global (tile, depth) sort — the paper's Sorting stage.

    ``max_tiles_per_gaussian`` statically bounds a Gaussian's footprint; it
    must be a perfect square (d x d tile window).  Gaussians covering more
    tiles contribute only to the d x d window anchored at their bbox min —
    matching the fixed-footprint bound used by tile-based hardware rasterizers.
    """
    d = int(round(max_tiles_per_gaussian ** 0.5))
    assert d * d == max_tiles_per_gaussian, "max_tiles_per_gaussian must be square"
    tiles_x, tiles_y = tile_grid(width, height)
    n = proj.mean2d.shape[0]

    mx, my, r = proj.mean2d[:, 0], proj.mean2d[:, 1], proj.radius
    tx0 = jnp.floor((mx - r) / TILE).astype(jnp.int32)
    ty0 = jnp.floor((my - r) / TILE).astype(jnp.int32)
    tx1 = jnp.floor((mx + r) / TILE).astype(jnp.int32)  # inclusive
    ty1 = jnp.floor((my + r) / TILE).astype(jnp.int32)
    tx0c = jnp.clip(tx0, 0, tiles_x - 1)
    ty0c = jnp.clip(ty0, 0, tiles_y - 1)

    di = jnp.arange(d, dtype=jnp.int32)
    # [N, d] candidate tile coordinates
    cand_x = tx0c[:, None] + di[None, :]
    cand_y = ty0c[:, None] + di[None, :]
    # cand >= tx0 (UNCLIPPED) rejects footprints entirely off-grid: clipping
    # alone would relocate a gaussian at tile column tiles_x into the last
    # column (found by the dense-vs-sorted membership test)
    ok_x = (cand_x >= tx0[:, None]) & (cand_x <= tx1[:, None]) \
        & (cand_x < tiles_x)
    ok_y = (cand_y >= ty0[:, None]) & (cand_y <= ty1[:, None]) \
        & (cand_y < tiles_y)

    # [N, d, d] -> flatten to [N*D]
    tile_id = (cand_y[:, :, None] * tiles_x + cand_x[:, None, :]).reshape(-1)
    ok = (ok_y[:, :, None] & ok_x[:, None, :]).reshape(-1)
    ok = ok & jnp.repeat(proj.valid & (proj.radius > 0), d * d)

    num_tiles = tiles_x * tiles_y
    tile_key = jnp.where(ok, tile_id, num_tiles).astype(jnp.int32)  # invalid -> sentinel
    depth_key = jnp.repeat(proj.depth, d * d)
    depth_key = jnp.where(ok, depth_key, jnp.inf)
    gauss_idx = jnp.repeat(jnp.arange(n, dtype=jnp.int32), d * d)

    tile_sorted, _, idx_sorted = jax.lax.sort(
        (tile_key, depth_key, gauss_idx), num_keys=2)

    # Per-tile slice boundaries.
    tids = jnp.arange(num_tiles, dtype=jnp.int32)
    start = jnp.searchsorted(tile_sorted, tids, side='left')
    end = jnp.searchsorted(tile_sorted, tids, side='right')
    count = jnp.minimum(end - start, capacity).astype(jnp.int32)

    offs = jnp.arange(capacity, dtype=jnp.int32)
    pos = start[:, None] + offs[None, :]                       # [T, K]
    in_range = offs[None, :] < (end - start)[:, None]
    pos = jnp.clip(pos, 0, tile_sorted.shape[0] - 1)
    gathered = idx_sorted[pos]
    indices = jnp.where(in_range, gathered, -1).astype(jnp.int32)
    return TileLists(indices, count, tiles_x, tiles_y)


class TileFeatures(NamedTuple):
    """Per-tile gathered screen-space features (fixed [T, K, ...])."""

    mean2d: jax.Array   # [T, K, 2]
    conic: jax.Array    # [T, K, 3]
    color: jax.Array    # [T, K, 3]
    opacity: jax.Array  # [T, K]
    ids: jax.Array      # [T, K] int32 global Gaussian ids (-1 pad)


def gather_tile_features(proj: Projected, lists: TileLists) -> TileFeatures:
    idx = lists.indices
    safe = jnp.maximum(idx, 0)
    pad = idx < 0
    return TileFeatures(
        mean2d=proj.mean2d[safe],
        conic=proj.conic[safe],
        color=proj.color[safe],
        opacity=jnp.where(pad, 0.0, proj.opacity[safe]),
        ids=idx,
    )
