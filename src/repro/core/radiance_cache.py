"""RC — Radiance Caching (paper Sec. 3.2) as a functional set-associative cache.

Cache key  : the ids of the first ``k`` *significant* Gaussians a pixel's ray
             intersects (the alpha-record emitted by the rasterizer).
Cache value: the pixel RGB.
Geometry   : ``n_sets`` sets x ``n_ways`` ways, one independent cache per
             tile *group* (the paper shares one LuminCache across a 4x4 block
             of 16x16 tiles = 64x64 pixels, double-buffered per group).

Indexing follows LuminCache (Fig. 16): ``log2(n_sets)/k`` low bits of each id
are concatenated to form the set index.  For the tag we store the exact ids
(int32) instead of the paper's 16-bit slices — strictly stronger matching
with zero aliasing; the hardware cost model still charges the 10-byte tag.

Replacement: LRU via an age counter (a faithful stand-in for the paper's
pseudo-LRU tree bits; both approximate LRU).  In-batch insert conflicts
(two pixels mapping to the same victim slot in the same frame) are resolved
deterministically: the lowest pixel index wins, mirroring the sequential
insert order of the hardware.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CacheConfig(NamedTuple):
    n_sets: int = 1024
    n_ways: int = 4
    k: int = 5              # alpha-record length (ids per tag)
    index_bits_shift: int = 3   # paper uses bits [3:18]; index starts at bit 3
    index_mode: str = 'hash'    # 'hash' (mixed, default) | 'bitconcat' (paper HW)
    insert_rounds: int = 4      # batch-insert rounds (hardware inserts serially;
                                # each round lands at most one entry per slot)


class CacheState(NamedTuple):
    """Functional cache state; leading dim = tile group."""

    tags: jax.Array    # [G, S, W, k] int32 (-2 = invalid slot)
    values: jax.Array  # [G, S, W, 3] float32
    age: jax.Array     # [G, S, W] int32 (higher = more recently used)
    clock: jax.Array   # [G] int32 monotonic insert counter


INVALID_TAG = -2  # -1 is a legal record padding value, so invalid slots use -2


def init_cache(num_groups: int, cfg: CacheConfig) -> CacheState:
    g, s, w, k = num_groups, cfg.n_sets, cfg.n_ways, cfg.k
    return CacheState(
        tags=jnp.full((g, s, w, k), INVALID_TAG, jnp.int32),
        values=jnp.zeros((g, s, w, 3), jnp.float32),
        age=jnp.zeros((g, s, w), jnp.int32),
        clock=jnp.zeros((g,), jnp.int32),
    )


def occupancy(cache: CacheState) -> jax.Array:
    """Fraction of valid (non-invalid-tag) slots across all groups — a cheap
    telemetry signal for how warmed-up a viewer's cache is."""
    valid = jnp.any(cache.tags != INVALID_TAG, axis=-1)   # [G, S, W]
    return jnp.mean(valid.astype(jnp.float32))


def set_index(ids: jax.Array, cfg: CacheConfig) -> jax.Array:
    """Set index from the k record ids ([..., k] -> [...]).

    'bitconcat' concatenates ``log2(n_sets)/k`` low bits of each id — exactly
    LuminCache's indexing (Fig. 16).  It relies on ids being numerous enough
    to fill those bits; for the small procedural scenes used on CPU we default
    to 'hash', a multiplicative mix of the same ids (same hardware cost class:
    a few adders), which distributes small-id populations uniformly.
    """
    if cfg.index_mode == 'bitconcat':
        bits_total = cfg.n_sets.bit_length() - 1   # log2(n_sets)
        per_id = max(1, bits_total // cfg.k)
        mask = (1 << per_id) - 1
        shifted = (ids >> cfg.index_bits_shift) & mask      # [..., k]
        weights = (1 << (per_id * jnp.arange(cfg.k, dtype=jnp.int32)))
        idx = jnp.sum(shifted.astype(jnp.int32) * weights, axis=-1)
        return jnp.abs(idx) % cfg.n_sets
    # 'hash': odd-constant multiplicative mixing, xor-folded.  Must stay in
    # exact lockstep with repro.kernels.rc_lookup._mix_index.
    consts = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F, 0x165667B1)
    h = (ids[..., 0] + 3).astype(jnp.uint32) * jnp.uint32(consts[0])
    for i in range(1, ids.shape[-1]):
        m = ((ids[..., i] + 3).astype(jnp.uint32)
             * jnp.uint32(consts[i % len(consts)]))
        h = (h ^ m) * jnp.uint32(0x9E3779B1)
    h = h ^ (h >> 15)
    return (h % jnp.uint32(cfg.n_sets)).astype(jnp.int32)


def _match(tags_at_set: jax.Array, ids: jax.Array) -> jax.Array:
    """tags_at_set [B, W, k] vs ids [B, k] -> [B, W] exact-match mask."""
    return jnp.all(tags_at_set == ids[:, None, :], axis=-1)


def lookup(cache: CacheState, group: int | jax.Array, ids: jax.Array,
           cfg: CacheConfig, live: jax.Array | None = None):
    """Query one group's cache with B records. Returns (hit [B], value [B,3],
    set_idx [B], way [B], cache-with-updated-LRU-age).

    ``live`` ([B] bool, optional) suppresses the LRU touch for dead records
    (idle serving lanes probing a *shared* cache must not age-bump entries);
    hit/value outputs are unaffected — callers mask them.  The clock still
    advances by the full batch so the age sequence is independent of which
    lanes happen to be live.
    """
    tags, values, age, clock = (cache.tags[group], cache.values[group],
                                cache.age[group], cache.clock[group])
    sidx = set_index(ids, cfg)                    # [B]
    cand = tags[sidx]                              # [B, W, k]
    m = _match(cand, ids)                          # [B, W]
    hit = jnp.any(m, axis=-1)
    way = jnp.argmax(m, axis=-1)
    val = values[sidx, way]
    # LRU touch for hits (deterministic: later pixels touch later).
    b = ids.shape[0]
    touched = hit if live is None else hit & live
    touch_age = clock + 1 + jnp.arange(b, dtype=jnp.int32)
    age = age.at[sidx, way].max(jnp.where(touched, touch_age, -1))
    new_clock = clock + b
    new_cache = CacheState(cache.tags,
                           cache.values,
                           cache.age.at[group].set(age),
                           cache.clock.at[group].set(new_clock))
    return hit, val, sidx, way, new_cache


def _insert_round(tags, values, age, clock, sidx, ids, rgb, do_insert):
    """One insert round: at most one new entry lands per (set, way) slot.

    Winners-only scatter: losing lanes get out-of-range indices and are
    dropped (``mode='drop'``), so no stale value can clobber a winner.
    Victim way = first invalid way, else least-recently-used (min age).
    Conflicts on the same slot: lowest pixel index wins (mirrors the
    hardware's sequential insert order).
    """
    s, w = age.shape
    b = ids.shape[0]
    slot_tags = tags[sidx]                                   # [B, W, k]
    invalid = jnp.all(slot_tags == INVALID_TAG, axis=-1)     # [B, W]
    slot_age = jnp.where(invalid, jnp.iinfo(jnp.int32).min, age[sidx])
    victim = jnp.argmin(slot_age, axis=-1)                   # [B]

    slot = sidx * w + victim                                 # [B]
    pix = jnp.arange(b, dtype=jnp.int32)
    winner = jnp.full((s * w,), b, jnp.int32).at[slot].min(
        jnp.where(do_insert, pix, b))
    wins = do_insert & (winner[slot] == pix)

    sidx_eff = jnp.where(wins, sidx, s)                      # out of range -> drop
    new_age_val = clock + 1 + pix
    tags = tags.at[sidx_eff, victim].set(ids, mode='drop')
    values = values.at[sidx_eff, victim].set(rgb, mode='drop')
    age = age.at[sidx_eff, victim].set(new_age_val, mode='drop')
    return tags, values, age, clock + b


def touch_all_groups(cache: CacheState, ids: jax.Array, hit: jax.Array,
                     way: jax.Array, cfg: CacheConfig,
                     live: jax.Array | None = None) -> CacheState:
    """Apply the LRU side effect of a lookup (age bump for hits) without
    re-probing — used by the kernel fast path, whose Pallas lookup returns
    (hit, way) but leaves cache state untouched.  Matches ``lookup``'s age
    and clock evolution exactly so both paths stay bit-identical.
    ``live`` ([G, B] bool, optional) masks dead records out of the touch
    (see ``lookup``)."""
    def one(tags, values, age, clock, gids, ghit, gway, glive):
        b = gids.shape[0]
        sidx = set_index(gids, cfg)
        touch_age = clock + 1 + jnp.arange(b, dtype=jnp.int32)
        age = age.at[sidx, gway].max(jnp.where(ghit & glive, touch_age, -1))
        return age, clock + b

    if live is None:
        live = jnp.ones(hit.shape, bool)
    age, clock = jax.vmap(one)(cache.tags, cache.values, cache.age,
                               cache.clock, ids, hit, way, live)
    return CacheState(cache.tags, cache.values, age, clock)


def insert(cache: CacheState, group: int | jax.Array, ids: jax.Array,
           rgb: jax.Array, do_insert: jax.Array, cfg: CacheConfig) -> CacheState:
    """Insert B (ids -> rgb) entries into one group's cache where ``do_insert``.

    Hardware inserts pixels serially; a vectorized batch can land at most one
    entry per slot per scatter, so we run ``cfg.insert_rounds`` rounds.  Each
    round first re-probes the cache so duplicates of already-landed tags
    become hits and drop out of the insert set.
    """
    tags, values, age, clock = (cache.tags[group], cache.values[group],
                                cache.age[group], cache.clock[group])
    sidx = set_index(ids, cfg)                               # [B]
    pending = do_insert
    for _ in range(max(1, cfg.insert_rounds)):
        present = jnp.any(_match(tags[sidx], ids), axis=-1)
        pending = pending & ~present
        tags, values, age, clock = _insert_round(
            tags, values, age, clock, sidx, ids, rgb, pending)

    return CacheState(cache.tags.at[group].set(tags),
                      cache.values.at[group].set(values),
                      cache.age.at[group].set(age),
                      cache.clock.at[group].set(clock))


def lookup_all_groups(cache: CacheState, ids: jax.Array, cfg: CacheConfig,
                      live: jax.Array | None = None):
    """vmapped lookup over all groups. ids: [G, B, k]; live: [G, B] bool
    (optional, masks dead records out of the LRU touch)."""
    def one(tags, values, age, clock, gids, glive):
        sub = CacheState(tags[None], values[None], age[None], clock[None])
        hit, val, sidx, way, new = lookup(sub, 0, gids, cfg, live=glive)
        return hit, val, sidx, way, (new.tags[0], new.values[0], new.age[0], new.clock[0])
    if live is None:
        live = jnp.ones(ids.shape[:-1], bool)
    hit, val, sidx, way, (t, v, a, c) = jax.vmap(one)(
        cache.tags, cache.values, cache.age, cache.clock, ids, live)
    return hit, val, sidx, way, CacheState(t, v, a, c)


def insert_all_groups(cache: CacheState, ids: jax.Array, rgb: jax.Array,
                      do_insert: jax.Array, cfg: CacheConfig) -> CacheState:
    """vmapped insert over all groups. ids: [G, B, k], rgb: [G, B, 3].

    Non-finite values are never inserted: a NaN/Inf escaping the rasterizer
    (device corruption, fault injection) must not be published to a cache
    other viewers of the scene read back.  The gate is bit-neutral on
    finite data — the mask is unchanged — so golden traces are untouched.
    """
    do_insert = do_insert & jnp.isfinite(rgb).all(axis=-1)
    def one(tags, values, age, clock, gids, grgb, gdo):
        sub = CacheState(tags[None], values[None], age[None], clock[None])
        new = insert(sub, 0, gids, grgb, gdo, cfg)
        return new.tags[0], new.values[0], new.age[0], new.clock[0]
    t, v, a, c = jax.vmap(one)(cache.tags, cache.values, cache.age, cache.clock,
                               ids, rgb, do_insert)
    return CacheState(t, v, a, c)


# ---------------------------------------------------------------------------
# Multi-viewer (scene-shared) forms
# ---------------------------------------------------------------------------
# One cache serves every viewer of a scene.  The batched forms flatten the
# viewer axis *slot-major* into the record batch, so the whole fleet's probes
# and inserts evolve the cache exactly as if one sequential stream had issued
# them in (slot, pixel) order: cross-viewer insert conflicts resolve by that
# order (lowest slot, then lowest pixel, wins — the multi-viewer extension of
# the hardware's sequential insert), duplicate records across viewers dedupe
# through the insert rounds' re-probe, and the result depends only on the
# slot -> records mapping, never on host-side iteration order.  With V == 1
# the flatten is the identity, so the shared path is bit-identical to the
# per-viewer functions — the parity anchor for single-viewer serving.

def slot_major(x: jax.Array) -> jax.Array:
    """[V, G, B, ...] per-viewer grouped records -> [G, V*B, ...] one
    slot-major batch per group (viewer 0's pixels first)."""
    v, g, b = x.shape[:3]
    return jnp.moveaxis(x, 0, 1).reshape(g, v * b, *x.shape[3:])


def slot_split(x: jax.Array, v: int) -> jax.Array:
    """Inverse of ``slot_major``: [G, V*B, ...] -> [V, G, B, ...]."""
    g, vb = x.shape[:2]
    return jnp.moveaxis(x.reshape(g, v, vb // v, *x.shape[2:]), 0, 1)


def lookup_all_groups_multi(cache: CacheState, ids: jax.Array,
                            cfg: CacheConfig,
                            live: jax.Array | None = None):
    """Shared-cache lookup for V viewers: ids [V, G, B, k], live [V] bool.

    Returns (hit [V, G, B], val [V, G, B, 3], sidx, way, new cache).  LRU
    touches land in (slot, pixel) order; dead viewers (``live`` False) probe
    without touching."""
    v = ids.shape[0]
    live_f = None
    if live is not None:
        live_f = slot_major(jnp.broadcast_to(live[:, None, None],
                                             ids.shape[:3]))
    hit, val, sidx, way, cache = lookup_all_groups(cache, slot_major(ids),
                                                   cfg, live=live_f)
    return (slot_split(hit, v), slot_split(val, v), slot_split(sidx, v),
            slot_split(way, v), cache)


def insert_all_groups_multi(cache: CacheState, ids: jax.Array,
                            rgb: jax.Array, do_insert: jax.Array,
                            cfg: CacheConfig) -> CacheState:
    """Shared-cache insert for V viewers: ids [V, G, B, k], rgb [V, G, B, 3],
    do_insert [V, G, B].  Conflicts resolve deterministically by
    (slot, pixel) order; duplicate tags across viewers land once."""
    return insert_all_groups(cache, slot_major(ids), slot_major(rgb),
                             slot_major(do_insert), cfg)
