"""S^2 — the Sorting-Shared algorithm (paper Sec. 3.1).

Two concurrent paths:
  * **speculative sorting** — predict the camera pose at the center of the
    next sharing window (constant-velocity extrapolation, Eqns. 2-3), run
    Projection + Sorting there once, with an *expanded viewport* so every
    rendered frustum in the window is covered;
  * **sorting-shared rendering** — each rendered frame reuses the speculative
    tile lists / depth order, refreshing only the cheap per-Gaussian
    screen-space arithmetic (and, per the paper, the SH colors) at its own
    pose, then rasterizes.

Viewport expansion is applied at two granularities (see DESIGN.md):
the camera frustum grows by ``margin`` px per side (rounded up to whole
tiles so the expanded tile grid embeds the render grid), and every tile's
gather footprint is inflated by ``margin`` px so Gaussians drifting across
tile boundaries inside the window stay covered.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.camera import Camera, expand_viewport, slerp
from repro.core.projection import Projected, project, reproject_geometry
from repro.core.sorting import sort_scene
from repro.core.tiling import TILE, TileLists, gather_tile_features
from repro.core.gaussians import GaussianScene


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SortShared:
    """Speculative sorting result shared across one window."""

    proj: Projected       # projection at the (expanded) sorting pose
    lists: TileLists      # tile lists on the expanded grid
    margin_tiles: int = dataclasses.field(metadata=dict(static=True))
    render_tiles_x: int = dataclasses.field(metadata=dict(static=True))
    render_tiles_y: int = dataclasses.field(metadata=dict(static=True))


def predict_pose(prev: Camera, cur: Camera, window: int) -> Camera:
    """Predict the pose at the center of the next sharing window.

    v = (F_j - F_{j-1}) / dt;  S_k = F_j + v * (window/2) * dt  (Eqns. 2-3).
    dt cancels, so the prediction is purely in pose deltas.  Rotation is
    extrapolated with slerp at the same horizon.
    """
    t = 1.0 + window / 2.0   # extrapolation factor from `prev` through `cur`
    position = prev.position + t * (cur.position - prev.position)
    quat = slerp(prev.quat, cur.quat, t)
    return cur._replace(position=position, quat=quat)


def predict_window_pose(prev: Camera, cur: Camera, frame_idx: jax.Array,
                        window: int) -> Camera:
    """``predict_pose`` with the cold-start guard: frame 0 has no real previous
    pose, so prediction degenerates to the identity (predict from ``cur``).

    This is the pose every speculative sort uses — factored out so the
    single-viewer ``render_step`` and the cohort-scheduled serving path
    (``repro.serve.stepper``) share one definition.
    """
    is_first = frame_idx == 0
    prev = jax.tree.map(lambda p, c: jnp.where(is_first, c, p), prev, cur)
    return predict_pose(prev, cur, window)


def speculative_sort(scene: GaussianScene, pred_cam: Camera, *,
                     margin: int, capacity: int, method: str = 'dense',
                     max_tiles_per_gaussian: int = 16) -> SortShared:
    """Projection + Sorting at the predicted pose with the expanded viewport."""
    rtx = (pred_cam.width + TILE - 1) // TILE
    rty = (pred_cam.height + TILE - 1) // TILE
    margin_tiles = -(-margin // TILE) if margin > 0 else 0  # ceil to whole tiles
    cam_exp = expand_viewport(pred_cam, margin_tiles * TILE)
    proj = project(scene, cam_exp)
    lists = sort_scene(proj, cam_exp.width, cam_exp.height, capacity,
                       method=method, radius_margin=float(margin),
                       max_tiles_per_gaussian=max_tiles_per_gaussian)
    return SortShared(proj=proj, lists=lists, margin_tiles=margin_tiles,
                      render_tiles_x=rtx, render_tiles_y=rty)


def empty_sort_shared(scene: GaussianScene, cam: Camera, *,
                      margin: int, capacity: int, method: str = 'dense',
                      max_tiles_per_gaussian: int = 16) -> SortShared:
    """A zero-filled ``SortShared`` with the exact structure ``speculative_sort``
    would produce for this (scene, cam, config).

    Used to initialise functional viewer state: the pipeline always sorts on
    frame 0 (``frame_idx % window == 0``), so the zeros are never rendered —
    they only give ``lax.cond`` a branch-compatible carry.
    """
    shapes = jax.eval_shape(
        lambda s, c: speculative_sort(
            s, c, margin=margin, capacity=capacity, method=method,
            max_tiles_per_gaussian=max_tiles_per_gaussian),
        scene, cam)
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), shapes)


def _render_sublists(shared: SortShared) -> TileLists:
    """Extract the render-grid tile lists out of the expanded grid."""
    mt = shared.margin_tiles
    lists = shared.lists
    k = lists.indices.shape[1]
    grid = lists.indices.reshape(lists.tiles_y, lists.tiles_x, k)
    cnt = lists.count.reshape(lists.tiles_y, lists.tiles_x)
    sub = grid[mt:mt + shared.render_tiles_y, mt:mt + shared.render_tiles_x]
    sub_cnt = cnt[mt:mt + shared.render_tiles_y, mt:mt + shared.render_tiles_x]
    t = shared.render_tiles_x * shared.render_tiles_y
    return TileLists(sub.reshape(t, k), sub_cnt.reshape(t),
                     shared.render_tiles_x, shared.render_tiles_y)


def shared_features(scene: GaussianScene, cam: Camera, shared: SortShared):
    """Sorting-shared per-frame prep: refresh screen-space geometry + SH colors
    at the *render* pose, reuse the speculative tile lists / depth order.

    Returns (TileFeatures on the render grid, render TileLists).
    """
    proj_now = reproject_geometry(scene, cam, shared.proj)
    lists = _render_sublists(shared)
    feats = gather_tile_features(proj_now, lists)
    return feats, lists
