"""Pinhole camera model and pose utilities (pytree-friendly)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.gaussians import quat_to_rotmat


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Camera:
    """A camera pose + intrinsics.

    position : [3]   camera center in world coordinates
    quat     : [4]   world-from-camera rotation quaternion (w,x,y,z)
    fx, fy   : focal lengths (pixels)
    cx, cy   : principal point (pixels)
    width, height : static python ints (image size in pixels)
    near, far     : clip planes (static)
    """

    position: jax.Array
    quat: jax.Array
    fx: jax.Array
    fy: jax.Array
    cx: jax.Array
    cy: jax.Array
    width: int = dataclasses.field(metadata=dict(static=True))
    height: int = dataclasses.field(metadata=dict(static=True))
    near: float = dataclasses.field(default=0.05, metadata=dict(static=True))
    far: float = dataclasses.field(default=100.0, metadata=dict(static=True))

    def _replace(self, **kw) -> "Camera":
        return dataclasses.replace(self, **kw)


def make_camera(position, quat, fov_x_deg: float, width: int, height: int,
                near: float = 0.05, far: float = 100.0) -> Camera:
    fov_x = jnp.deg2rad(fov_x_deg)
    fx = (width / 2.0) / jnp.tan(fov_x / 2.0)
    fy = fx  # square pixels
    return Camera(
        position=jnp.asarray(position, jnp.float32),
        quat=jnp.asarray(quat, jnp.float32),
        fx=jnp.asarray(fx, jnp.float32),
        fy=jnp.asarray(fy, jnp.float32),
        cx=jnp.asarray(width / 2.0, jnp.float32),
        cy=jnp.asarray(height / 2.0, jnp.float32),
        width=width, height=height, near=near, far=far)


def stack_cameras(cams: list) -> Camera:
    """Stack cameras sharing intrinsics' static fields into one batched Camera
    (dynamic leaves gain a leading axis) — the input to a vmapped render step."""
    first = cams[0]
    for c in cams[1:]:
        if (c.width, c.height, c.near, c.far) != (first.width, first.height,
                                                  first.near, first.far):
            raise ValueError('stack_cameras requires identical static fields')
    return jax.tree.map(lambda *xs: jnp.stack(xs), *cams)


def world_to_camera(cam: Camera, points: jax.Array) -> jax.Array:
    """World points [N,3] -> camera-frame points [N,3] (z = depth)."""
    r_wc = quat_to_rotmat(cam.quat)          # world-from-camera
    r_cw = r_wc.T                            # camera-from-world
    return (points - cam.position[None, :]) @ r_cw.T


def expand_viewport(cam: Camera, margin_px: int) -> Camera:
    """Expanded sorting viewport for S^2 (Sec. 3.1 of the paper).

    The viewport grows by `margin_px` pixels on each side; the principal point
    shifts so world geometry stays put.  Tile grids built on the expanded
    camera therefore cover every rendering viewport in the sharing window.
    """
    return cam._replace(
        cx=cam.cx + margin_px,
        cy=cam.cy + margin_px,
        width=cam.width + 2 * margin_px,
        height=cam.height + 2 * margin_px,
    )


def look_at(position, target, up=(0.0, 1.0, 0.0)):
    """Return a (position, quat) pose looking from `position` toward `target`.

    Camera convention (COLMAP/3DGS): +z forward into the scene, +x right,
    +y down — a proper right-handed rotation (x cross y = z).
    """
    position = jnp.asarray(position, jnp.float32)
    target = jnp.asarray(target, jnp.float32)
    up = jnp.asarray(up, jnp.float32)
    fwd = target - position
    fwd = fwd / (jnp.linalg.norm(fwd) + 1e-12)
    right = jnp.cross(fwd, up)
    right = right / (jnp.linalg.norm(right) + 1e-12)
    down = jnp.cross(fwd, right)  # z cross x = y (down, since image y grows down)
    # world-from-camera columns: x=right, y=down, z=fwd
    r = jnp.stack([right, down, fwd], axis=1)
    return position, rotmat_to_quat(r)


def rotmat_to_quat(r: jax.Array) -> jax.Array:
    """Rotation matrix [3,3] -> quaternion (w,x,y,z). Branch-free (Shepperd)."""
    m00, m01, m02 = r[0, 0], r[0, 1], r[0, 2]
    m10, m11, m12 = r[1, 0], r[1, 1], r[1, 2]
    m20, m21, m22 = r[2, 0], r[2, 1], r[2, 2]
    tr = m00 + m11 + m22
    # four candidate constructions; pick numerically best
    qw = jnp.sqrt(jnp.maximum(1 + tr, 1e-12)) / 2
    qx = jnp.sqrt(jnp.maximum(1 + m00 - m11 - m22, 1e-12)) / 2
    qy = jnp.sqrt(jnp.maximum(1 - m00 + m11 - m22, 1e-12)) / 2
    qz = jnp.sqrt(jnp.maximum(1 - m00 - m11 + m22, 1e-12)) / 2
    cand = jnp.stack([
        jnp.stack([qw, (m21 - m12) / (4 * qw), (m02 - m20) / (4 * qw), (m10 - m01) / (4 * qw)]),
        jnp.stack([(m21 - m12) / (4 * qx), qx, (m01 + m10) / (4 * qx), (m02 + m20) / (4 * qx)]),
        jnp.stack([(m02 - m20) / (4 * qy), (m01 + m10) / (4 * qy), qy, (m12 + m21) / (4 * qy)]),
        jnp.stack([(m10 - m01) / (4 * qz), (m02 + m20) / (4 * qz), (m12 + m21) / (4 * qz), qz]),
    ])
    idx = jnp.argmax(jnp.stack([tr, m00, m11, m22]))
    q = cand[idx]
    return q / (jnp.linalg.norm(q) + 1e-12)


def slerp(q0: jax.Array, q1: jax.Array, t) -> jax.Array:
    """Spherical interpolation/extrapolation of quaternions (t may exceed 1)."""
    q0 = q0 / (jnp.linalg.norm(q0) + 1e-12)
    q1 = q1 / (jnp.linalg.norm(q1) + 1e-12)
    dot = jnp.sum(q0 * q1)
    q1 = jnp.where(dot < 0, -q1, q1)
    dot = jnp.abs(dot)
    dot = jnp.clip(dot, -1.0, 1.0)
    theta = jnp.arccos(dot)
    sin_theta = jnp.sin(theta)
    use_lerp = sin_theta < 1e-5
    w0 = jnp.where(use_lerp, 1.0 - t, jnp.sin((1.0 - t) * theta) / jnp.where(use_lerp, 1.0, sin_theta))
    w1 = jnp.where(use_lerp, t, jnp.sin(t * theta) / jnp.where(use_lerp, 1.0, sin_theta))
    q = w0 * q0 + w1 * q1
    return q / (jnp.linalg.norm(q) + 1e-12)
