"""Pixel <-> cache-tile-group reshaping shared by the functional pipeline and
the kernel fast path (LuminCache is shared across group_tiles x group_tiles
image tiles; one independent cache state per group)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tiling import tile_grid


def group_dims(tiles_x: int, tiles_y: int, group_tiles: int) -> tuple[int, int, int]:
    gt = group_tiles
    while tiles_x % gt or tiles_y % gt:
        gt -= 1   # fall back to the largest divisor (1 always works)
    return tiles_x // gt, tiles_y // gt, gt


def regroup(x: jax.Array, tiles_x: int, tiles_y: int, group_tiles: int) -> jax.Array:
    """[T, P, ...] tile-major -> [G, B, ...] group-major."""
    gx, gy, gt = group_dims(tiles_x, tiles_y, group_tiles)
    rest = x.shape[2:]
    x = x.reshape(gy, gt, gx, gt, *x.shape[1:])
    x = jnp.moveaxis(x, 2, 1)                   # [gy, gx, gt, gt, P, ...]
    return x.reshape(gy * gx, gt * gt * x.shape[4], *rest)


def ungroup(x: jax.Array, tiles_x: int, tiles_y: int, group_tiles: int) -> jax.Array:
    """[G, B, ...] group-major -> [T, P, ...] tile-major."""
    gx, gy, gt = group_dims(tiles_x, tiles_y, group_tiles)
    p = x.shape[1] // (gt * gt)
    rest = x.shape[2:]
    x = x.reshape(gy, gx, gt, gt, p, *rest)
    x = jnp.moveaxis(x, 1, 2)                   # [gy, gt, gx, gt, P, ...]
    return x.reshape(gy * gx * gt * gt, p, *rest)


def num_groups(width: int, height: int, group_tiles: int) -> int:
    tx, ty = tile_grid(width, height)
    gx, gy, _ = group_dims(tx, ty, group_tiles)
    return gx * gy
