"""Power-of-two capacity buckets.

Dynamic batch widths (active scene blocks, live lanes inside a scene
block, live sort-pool entries per scene) are rounded up to the next
power of two before they reach a jitted call. That bounds the number
of distinct compiled shapes to ``log2(max_width)`` instead of
``max_width`` — the same capacity-bucket trick dropless-MoE routers
use for token→expert dispatch.

One helper, used by the stepper's scene-block compaction, the
within-scene lane compaction, and the bucketed sort-pool capacity.
"""

from __future__ import annotations


def pow2_bucket(n: int, cap: int | None = None) -> int:
    """Smallest power of two >= ``n``, optionally clamped to ``cap``.

    ``n <= 0`` maps to the minimum bucket of 1 (a jitted call always
    has at least one lane).  When ``cap`` is given the result is
    ``min(bucket, cap)`` — callers clamp to the physical width, and
    ``cap`` itself need not be a power of two (a full-width dispatch
    at an odd width is still a single compiled shape).
    """
    if cap is not None and cap < 1:
        raise ValueError(f"cap must be >= 1, got {cap}")
    b = 1
    while b < n:
        b *= 2
    if cap is not None and b > cap:
        b = cap
    return b
