"""Distributed LuminSys — the paper's own workload on the production mesh.

Cluster-scale mapping of the paper's pipeline (DESIGN.md §5):

  * **Gaussians shard over the batch axes** (pod x data): Projection, SH
    color evaluation and per-Gaussian culling are embarrassingly parallel —
    the cluster analogue of the paper's GPU-side Projection.
  * **Tiles shard over 'model'**: Rasterization is tile-parallel — the
    analogue of the 8x8 NRU array, one tile per grid cell.
  * Between the two stages sits the paper's Sorting: per-tile top-K depth
    selection.  The dense [T, N] overlap matrix shards over (tiles x
    gaussians) and the top-k reduces over the Gaussian axis, leaving
    [T, K] survivor lists sharded by tile — GSPMD inserts the (small)
    survivor all-gather, mirroring the paper's sorted-splatting-table
    handoff from GPU to NRU.

The serve step is the S^2 sorting-shared frame: recompute per-Gaussian
screen geometry + SH colors at the render pose (cheap, sharded over
Gaussians), reuse tile lists from the speculative sort, rasterize.  The
train step is the differentiable full render + L1/SSIM + scale loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.camera import Camera, make_camera
from repro.core.gaussians import GaussianScene
from repro.core.pipeline import LuminaConfig
from repro.core.projection import project
from repro.core.rasterize import rasterize_tiles
from repro.core.sorting import sort_scene
from repro.core.tiling import TILE, gather_tile_features, tile_grid
from repro.runtime.sharding import adaptive_spec, batch_axes


RENDER_SHAPE_TABLE = {
    # name: (num_gaussians, width, height, capacity)
    'render_1080p': (1_048_576, 1920, 1088, 512),
    'render_720p': (1_048_576, 1280, 720, 512),
}


def scene_specs(mesh, n: int):
    """Gaussian arrays shard over pod x data (projection parallelism)."""
    baxes = batch_axes(mesh)

    def rule(leaf):
        return adaptive_spec(leaf.shape, mesh, [(0, baxes)])
    return rule


def abstract_scene(n: int) -> GaussianScene:
    f32 = jnp.float32
    return GaussianScene(
        means=jax.ShapeDtypeStruct((n, 3), f32),
        log_scales=jax.ShapeDtypeStruct((n, 3), f32),
        quats=jax.ShapeDtypeStruct((n, 4), f32),
        opacity_logit=jax.ShapeDtypeStruct((n,), f32),
        sh_dc=jax.ShapeDtypeStruct((n, 3), f32),
        sh_rest=jax.ShapeDtypeStruct((n, 3, 3), f32),
    )


def _serve_frame(scene: GaussianScene, cam: Camera, mesh, cfg: LuminaConfig):
    """One sorting-shared frame, sharding annotated for the mesh."""
    baxes = batch_axes(mesh)

    def gshard(x):
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, adaptive_spec(x.shape, mesh, [(0, baxes)])))

    # tiles would ideally shard over model x data (256-way) since after
    # projection the per-Gaussian work is done — but the 1080p tile count
    # (120 x 68 = 8160) is not divisible by 256, so the adaptive spec falls
    # back to 16-way 'model' sharding (§Perf render iteration 1, refuted:
    # forcing the composite axis silently replicated everything, 14x worse;
    # a tile-grid pad to 8192 is the recorded follow-up)
    taxes = ('model',) + tuple(batch_axes(mesh) or ())

    def tshard(x):
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, adaptive_spec(x.shape, mesh,
                                                 [(0, taxes), (0, 'model')])))

    proj = project(scene, cam)
    proj = jax.tree.map(gshard, proj)
    lists = sort_scene(proj, cam.width, cam.height, cfg.capacity,
                       method=cfg.sort_method,
                       max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)
    lists = type(lists)(tshard(lists.indices), tshard(lists.count),
                        lists.tiles_x, lists.tiles_y)
    feats = gather_tile_features(proj, lists)
    feats = jax.tree.map(tshard, feats)
    colors, aux = rasterize_tiles(feats, lists.tiles_x, k_record=cfg.k_record,
                                  bg=cfg.bg)
    return tshard(colors), aux.n_significant


def build_dryrun_cell(arch_cfg, mesh, shape_name: str):
    """(fn, abstract args, model_flops) for the render dry-run cell."""
    n, w, h, cap = RENDER_SHAPE_TABLE[shape_name]
    lcfg = LuminaConfig(capacity=cap, window=arch_cfg.window,
                        margin=arch_cfg.margin, k_record=arch_cfg.k_record,
                        sort_method='sorted')

    cam = make_camera((0.0, 0.0, 2.5), (1.0, 0.0, 0.0, 0.0), 60.0, w, h)
    scene_abs = abstract_scene(n)
    rule = scene_specs(mesh, n)
    s_sh = jax.tree.map(
        lambda leaf: NamedSharding(mesh, rule(leaf)), scene_abs)
    repl = NamedSharding(mesh, P())

    def serve_step(scene):
        colors, nsig = _serve_frame(scene, cam, mesh, lcfg)
        return colors, jnp.sum(nsig)

    fn = jax.jit(serve_step, in_shardings=(s_sh,), out_shardings=repl)

    # MODEL_FLOPS for rendering: alpha-eval + blend per (pixel, listed
    # gaussian): ~30 flops for the conic/exp frontend + 8 for integration.
    tx, ty = tile_grid(w, h)
    mf = tx * ty * cap * (TILE * TILE) * 38.0
    return fn, (scene_abs,), mf
