"""First-order cycle/energy models: mobile GPU, NRU+GPU, LuminCore, GSCore.

These models consume *measured statistics from the functional pipeline*
(per-pixel iterated/significant counts, warp-max iteration counts, cache hit
rates, chunk counts) and the paper's hardware constants, and produce the
Fig. 3 / Fig. 22 / Fig. 25-style tables.  They are analytic first-order
models — not RTL — but every input that depends on the *scene and
algorithm* is measured, not assumed; only per-op throughputs/energies are
constants (Sec. 5 of the paper + standard energy ratios).

Hardware constants (paper Sec. 5):
  * mobile GPU: Volta on Xavier, 2.8 TFLOPS fp32 ~ 1.37 GHz x 512 lanes x 2;
    SIMT warp = 32 threads -> a warp retires at the pace of its SLOWEST
    thread (this is where the measured 69% masking comes from);
  * LuminCore: 8x8 NRUs @ 1 GHz, 4 three-stage PEs each (frontend), one
    shared backend per NRU; LuminCache 4-way x 1024 sets, 2-cycle probe,
    double-buffered (fills overlap compute);
  * GSCore: CCU + GSU + 16-unit rasterizer @ 1 GHz (their Table 2 scale),
    subtile skipping but NO frontend/backend alpha split;
  * energy: DRAM:SRAM access ratio 25:1 [30, 76]; ASIC MAC at 16/12 nm vs
    GPU fp32 FMA ~ 1:5 (DeepScaleTool-scaled, Sec. 5).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rasterize import RasterAux
from repro.core.tiling import TILE, TileLists

# ---------------------------------------------------------------------------
# Hardware constants
# ---------------------------------------------------------------------------

WARP = 32

# per-Gaussian-per-pixel instruction counts (3DGS reference rasterizer)
OPS_ALPHA = 10.0        # conic quadratic form + exp + compare
OPS_BLEND = 8.0         # color integration (3 ch MAC + transmittance)
FEAT_BYTES = 48.0       # mean2d, conic, color, opacity, id (fp32)
PIX_BYTES = 12.0


@dataclasses.dataclass(frozen=True)
class GPUParams:
    lanes: int = 512            # CUDA cores (Xavier Volta)
    freq: float = 1.377e9
    ops_per_lane_cycle: float = 2.0       # FMA
    sort_cycles_per_key: float = 6.0      # radix passes amortized
    proj_ops: float = 120.0               # EWA projection per gaussian
    dram_bw: float = 25.6e9               # LPDDR4x-ish on Xavier
    # energy per op/byte (relative units; eref = 1 SRAM byte)
    e_op: float = 5.0
    e_sram: float = 1.0
    e_dram: float = 25.0
    idle_power_frac: float = 0.25         # static+leakage share


@dataclasses.dataclass(frozen=True)
class NRUParams:
    n_nru: int = 64             # 8 x 8
    pes_per_nru: int = 4
    freq: float = 1.0e9
    # frontend: one alpha evaluation per PE per cycle (3-stage pipeline)
    # backend: one significant-Gaussian integration per NRU per cycle
    cache_probe_cycles: float = 2.0
    e_op: float = 1.0           # ASIC MAC (DeepScale-scaled vs GPU 5.0)
    e_sram: float = 1.0
    e_dram: float = 25.0


@dataclasses.dataclass(frozen=True)
class GSCoreParams:
    units: int = 16             # gaussian-parallel volume-rendering units
    px_per_cycle: float = 4.0   # pixels each unit blends per cycle
    freq: float = 1.0e9
    ccu_speedup: float = 8.0    # Culling&Conversion Unit vs GPU projection
    gsu_speedup: float = 8.0    # Gaussian Sorting Unit vs GPU sorting
    e_op: float = 1.2
    e_sram: float = 1.0
    e_dram: float = 25.0
    subtile_skip: float = 0.55  # fraction of alpha evals skipped (their OBB/
                                # subtile culling, from the GSCore paper)


# ---------------------------------------------------------------------------
# Measured per-frame statistics
# ---------------------------------------------------------------------------

class FrameHWStats(NamedTuple):
    """Everything scene/algorithm-dependent, measured from the pipeline."""

    n_projected: float       # Gaussians surviving culling
    n_dup: float             # tile-Gaussian pairs (sort keys)
    iterated: float          # sum over pixels of Gaussians examined
    significant: float       # sum over pixels of significant Gaussians
    warp_max_iter: float     # sum over warps of max-per-warp iterations
    warp_max_iter_k: float   # same, but iterations to fill the k-record
    hit_rate: float          # RC cache hit rate (0 if RC off)
    iter_to_k: float         # sum over pixels of iterations to fill k-record
    n_pixels: float
    sorted_this_frame: float  # 1.0 if Projection+Sorting ran (S^2 amortizes)

    @property
    def masked_fraction(self) -> float:
        """Fraction of occupied GPU lane slots doing no useful work — the
        paper's ~69% warp-masking characterization (Sec. 2.2)."""
        slots = self.warp_max_iter * WARP
        return 1.0 - self.significant / max(slots, 1.0)

    @property
    def sig_fraction(self) -> float:
        return self.significant / max(self.iterated, 1.0)


def measure_frame(lists: TileLists, aux: RasterAux, *, hit_rate=0.0,
                  sorted_this_frame=1.0, n_projected=None) -> FrameHWStats:
    n_iter = np.asarray(aux.n_iterated, np.float64)       # [T, P]
    n_sig = np.asarray(aux.n_significant, np.float64)
    it_k = np.minimum(np.asarray(aux.iter_at_k, np.float64), n_iter)
    t, p = n_iter.shape
    warps = n_iter.reshape(t, p // WARP, WARP)
    warps_k = it_k.reshape(t, p // WARP, WARP)
    return FrameHWStats(
        n_projected=float(n_projected if n_projected is not None
                          else np.asarray(lists.count).sum()),
        n_dup=float(np.asarray(lists.count, np.float64).sum()),
        iterated=float(n_iter.sum()),
        significant=float(n_sig.sum()),
        warp_max_iter=float(warps.max(axis=-1).sum()),
        warp_max_iter_k=float(warps_k.max(axis=-1).sum()),
        hit_rate=float(hit_rate),
        iter_to_k=float(it_k.sum()),
        n_pixels=float(t * p),
        sorted_this_frame=float(sorted_this_frame),
    )


# ---------------------------------------------------------------------------
# Stage time models (seconds per frame)
# ---------------------------------------------------------------------------

def gpu_stage_times(s: FrameHWStats, hw: GPUParams = GPUParams(),
                    *, rc: bool = False) -> dict:
    """Projection / Sorting / Rasterization on the mobile GPU.

    Rasterization: one thread per pixel; a warp occupies its lanes until
    its slowest thread finishes, so lane-cycles = warp_max_iter x WARP.
    Work per lane-cycle-occupied slot: alpha ops always; blend ops only for
    significant (others masked -> wasted issue slots, the Fig. 5 effect).
    RC on GPU adds the lookup + LOCK contention overhead the paper
    measures as a net slowdown (Sec. 6.2): tag identification runs the
    same warps, and cache probes serialize on shared-memory banks.
    """
    lane_ops = hw.lanes * hw.ops_per_lane_cycle * hw.freq
    t_proj = s.n_projected * hw.proj_ops / lane_ops
    t_sort = s.n_dup * hw.sort_cycles_per_key / (hw.lanes * hw.freq / WARP)
    # warp-granular occupancy: every masked slot still holds the lane
    warp_slots = s.warp_max_iter * WARP
    t_rast = warp_slots * (OPS_ALPHA + OPS_BLEND) / lane_ops
    if rc:
        # phase A runs each warp to its slowest pixel's k-record fill; the
        # probe serializes ~8 cycles/pixel on shared-memory bank conflicts
        # + lock contention; a warp resumes phase B if ANY of its pixels
        # missed — with hits uniformly scattered (Fig. 15) that is nearly
        # every warp, which is why RC-GPU is a net slowdown (Sec. 6.2)
        slots_a = s.warp_max_iter_k * WARP
        probe = s.n_pixels * 8.0 * WARP / (hw.lanes * hw.freq)
        warp_has_miss = 1.0 - s.hit_rate ** WARP
        resume = warp_has_miss * (s.warp_max_iter - s.warp_max_iter_k) * WARP
        t_rast = (slots_a + resume) * (OPS_ALPHA + OPS_BLEND) / lane_ops + probe
    return {'projection': t_proj, 'sorting': t_sort, 'rasterization': t_rast}


def nru_raster_time(s: FrameHWStats, hw: NRUParams = NRUParams(),
                    *, rc: bool = False) -> float:
    """LuminCore rasterization: dense frontend + sparse shared backend.

    Frontend retires n_pe alpha evaluations per NRU-cycle regardless of
    masking (no divergence: PEs evaluate consecutive Gaussians of the same
    tile); backend retires one significant integration per cycle and is
    the bottleneck only when sig density > pes/backend ratio.  Sparsity-
    aware remapping keeps PEs busy when RC terminates pixels early.
    """
    fe_tput = hw.n_nru * hw.pes_per_nru * hw.freq   # alpha evals / s
    be_tput = hw.n_nru * hw.freq                     # integrations / s
    if not rc:
        t_fe = s.iterated / fe_tput
        t_be = s.significant / be_tput
        return max(t_fe, t_be)
    # phase A: everyone identifies its first-k significant
    t_a = max(s.iter_to_k / fe_tput,
              min(s.significant, s.n_pixels * 5.0) / be_tput)
    # probe: pipelined through LuminCache, n_nru probes per cycle
    t_probe = s.n_pixels * hw.cache_probe_cycles / (hw.n_nru * hw.freq)
    # phase B: only miss pixels continue; remapping keeps PEs on them
    miss = 1.0 - s.hit_rate
    t_b = max(miss * (s.iterated - s.iter_to_k) / fe_tput,
              miss * s.significant / be_tput)
    return t_a + t_probe + t_b


def gscore_raster_time(s: FrameHWStats, hw: GSCoreParams = GSCoreParams()) -> float:
    """GSCore: gaussian-parallel units with subtile skipping, but alpha
    evaluation and integration share the same units (no dense/sparse split),
    so every surviving eval occupies a unit-cycle whether significant or not.
    """
    evals = s.iterated * (1.0 - hw.subtile_skip)
    return evals / (hw.units * hw.px_per_cycle * hw.freq)


# ---------------------------------------------------------------------------
# Energy models (relative units: 1.0 = one SRAM byte access)
# ---------------------------------------------------------------------------

def gpu_energy(s: FrameHWStats, t: dict, hw: GPUParams = GPUParams(),
               *, rc: bool = False) -> float:
    ops = (s.n_projected * hw.proj_ops
           + s.n_dup * hw.sort_cycles_per_key * 2
           + s.warp_max_iter * WARP * (OPS_ALPHA + OPS_BLEND))
    if rc:
        ops += s.n_pixels * 16.0
    dram = s.n_dup * FEAT_BYTES + s.n_pixels * PIX_BYTES
    sram = s.iterated * FEAT_BYTES
    total_t = sum(t.values())
    dyn = ops * hw.e_op + dram * hw.e_dram + sram * hw.e_sram
    return dyn * (1 + hw.idle_power_frac)


def lumincore_energy(s: FrameHWStats, *, rc: bool = False, s2: bool = False,
                     gpu: GPUParams = GPUParams(),
                     nru: NRUParams = NRUParams()) -> float:
    """System energy: GPU does Projection+Sorting (amortized by S^2),
    LuminCore does Rasterization, DRAM is shared."""
    sort_e = (s.n_projected * gpu.proj_ops
              + s.n_dup * gpu.sort_cycles_per_key * 2) * gpu.e_op \
        * (1 + gpu.idle_power_frac)
    sort_e *= s.sorted_this_frame        # S^2: sorting every N-th frame
    if rc:
        evals = s.iter_to_k + (1 - s.hit_rate) * (s.iterated - s.iter_to_k)
        integ = s.iter_to_k / max(s.iterated, 1) * s.significant \
            + (1 - s.hit_rate) * s.significant
        probe_e = s.n_pixels * 10 * nru.e_sram   # 10-byte tag probe
    else:
        evals, integ, probe_e = s.iterated, s.significant, 0.0
    raster_ops = evals * OPS_ALPHA + integ * OPS_BLEND
    dram = s.n_dup * FEAT_BYTES * s.sorted_this_frame \
        + s.n_pixels * PIX_BYTES
    sram = evals * FEAT_BYTES
    return (sort_e + raster_ops * nru.e_op + probe_e
            + dram * nru.e_dram + sram * nru.e_sram)


def gscore_energy(s: FrameHWStats, hw: GSCoreParams = GSCoreParams(),
                  gpu: GPUParams = GPUParams()) -> float:
    evals = s.iterated * (1.0 - hw.subtile_skip)
    ops = (s.n_projected * 40.0 + s.n_dup * 4.0     # CCU + GSU
           + evals * OPS_ALPHA + s.significant * OPS_BLEND)
    dram = s.n_dup * FEAT_BYTES + s.n_pixels * PIX_BYTES
    sram = evals * FEAT_BYTES
    return ops * hw.e_op + dram * hw.e_dram + sram * hw.e_sram


# ---------------------------------------------------------------------------
# Variant composition (Fig. 22 / Fig. 25)
# ---------------------------------------------------------------------------

VARIANTS = ('GPU', 'S2-GPU', 'RC-GPU', 'NRU+GPU', 'S2-Acc', 'RC-Acc', 'Lumina')


def variant_frame_time(variant: str, s: FrameHWStats,
                       *, window: int = 6) -> float:
    """End-to-end frame time of one Lumina variant.

    S^2 runs Projection+Sorting once per window at the predicted pose.  On
    the accelerator variants that work runs on the GPU *concurrently* with
    NRU rasterization, so the frame time is the MAX of the two engines
    (amortized over the window).  On S2-GPU both share one engine, so the
    amortized sort serializes after rasterization — which is why S2-GPU
    only reaches ~1.2x (Fig. 22) while S2-Acc gains much more.
    """
    g = gpu_stage_times(s)
    spec = (g['projection'] + g['sorting']) / window   # amortized S^2 work
    if variant == 'GPU':
        return g['projection'] + g['sorting'] + g['rasterization']
    if variant == 'S2-GPU':
        return g['rasterization'] + spec              # one engine: serialize
    if variant == 'RC-GPU':
        grc = gpu_stage_times(s, rc=True)
        return g['projection'] + g['sorting'] + grc['rasterization']
    if variant == 'NRU+GPU':
        return g['projection'] + g['sorting'] + nru_raster_time(s)
    if variant == 'S2-Acc':
        return max(nru_raster_time(s), spec)          # two engines: overlap
    if variant == 'RC-Acc':
        return g['projection'] + g['sorting'] + nru_raster_time(s, rc=True)
    if variant == 'Lumina':
        return max(nru_raster_time(s, rc=True), spec)
    raise ValueError(variant)


def variant_energy(variant: str, s: FrameHWStats) -> float:
    g = gpu_stage_times(s)
    if variant == 'GPU':
        return gpu_energy(s, g)
    if variant == 'S2-GPU':
        return gpu_energy(s._replace(
            n_projected=s.n_projected * s.sorted_this_frame,
            n_dup=s.n_dup * s.sorted_this_frame), g)
    if variant == 'RC-GPU':
        return gpu_energy(s, gpu_stage_times(s, rc=True), rc=True) \
            + s.n_pixels * 10.0   # lock traffic
    if variant == 'NRU+GPU':
        return lumincore_energy(s._replace(sorted_this_frame=1.0))
    if variant == 'S2-Acc':
        return lumincore_energy(s, s2=True)
    if variant == 'RC-Acc':
        return lumincore_energy(s._replace(sorted_this_frame=1.0), rc=True)
    if variant == 'Lumina':
        return lumincore_energy(s, rc=True, s2=True)
    raise ValueError(variant)


def evaluate_variants(stats: list[FrameHWStats], *, window: int = 6) -> dict:
    """Average speedup + normalized energy over a frame sequence."""
    out = {}
    base_t = np.mean([variant_frame_time('GPU', s) for s in stats])
    base_e = np.mean([variant_energy('GPU', s) for s in stats])
    for v in VARIANTS:
        t = np.mean([variant_frame_time(v, s, window=window) for s in stats])
        e = np.mean([variant_energy(v, s) for s in stats])
        out[v] = {'speedup': base_t / t, 'norm_energy': e / base_e,
                  'fps': 1.0 / t}
    # GSCore comparison row (Fig. 25): everything normalized to GPU
    gs = GSCoreParams()
    t_gs = np.mean([gpu_stage_times(s)['projection'] / gs.ccu_speedup
                    + gpu_stage_times(s)['sorting'] / gs.gsu_speedup
                    + gscore_raster_time(s) for s in stats])
    e_gs = np.mean([gscore_energy(s) for s in stats])
    out['GSCore'] = {'speedup': base_t / t_gs, 'norm_energy': e_gs / base_e,
                     'fps': 1.0 / t_gs}
    return out


def rescale_to_paper_mix(s: FrameHWStats) -> FrameHWStats:
    """Re-weight a measured frame to the paper's Fig. 3 stage mix.

    Our procedural scenes produce far fewer sort keys per rendered pixel
    than 6M-Gaussian real captures (sorting is 8% of GPU time here vs 23%
    in Fig. 3), which inflates rasterization-side speedups by Amdahl.  This
    helper scales n_dup / n_projected so the GPU-baseline stage shares
    match Fig. 3 (10/23/67) while keeping every per-pixel statistic
    measured — reported as the 'paper-mix' scenario next to 'measured'.
    """
    t = gpu_stage_times(s)
    target_proj, target_sort = 10.0 / 67.0, 23.0 / 67.0   # vs rasterization
    f_proj = target_proj * t['rasterization'] / max(t['projection'], 1e-30)
    f_sort = target_sort * t['rasterization'] / max(t['sorting'], 1e-30)
    return s._replace(n_projected=s.n_projected * f_proj,
                      n_dup=s.n_dup * f_sort)
