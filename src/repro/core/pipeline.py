"""LuminSys — the full frame pipeline (paper Sec. 3.3).

Combines the three stages with both optimizations:

  pose history --> predict pose --> [Projection + Sorting] at predicted pose
       (speculative, once per sharing window, expanded viewport)
  every frame  --> sorting-shared prep (refresh geometry + SH colors)
               --> Rasterization with alpha-record extraction
               --> Radiance-Cache lookup: hits take the cached RGB and
                   terminate early; misses complete integration and insert.

Everything is expressed as pure functions over fixed shapes: per-viewer state
(radiance cache, S^2 sort-shared buffers, previous pose, frame counter) lives
in a ``ViewerState`` pytree, and the frame is split into two phases:

  * ``sort_phase``  — pose prediction + speculative Projection/Sorting,
    producing a ``SortShared`` (runs once per sharing window);
  * ``shade_phase`` — sorting-shared prep + rasterization + radiance cache,
    consuming the current ``SortShared`` (runs every frame, sort-free).

``render_step`` composes the two with a ``lax.cond`` on
``frame_idx % window`` — the single-viewer contract is unchanged and it still
jits/vmaps as one step.  The multi-viewer serving path
(``repro.serve.stepper``) instead schedules the phases itself: a cohort sort
scheduler runs ``sort_phase`` for only the due slots each tick and advances
all slots through a vmapped ``shade_phase``, restoring the 1-in-window sort
amortization that a per-lane cond (lowered to a select under vmap) destroys.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import radiance_cache as rc
from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.projection import project
from repro.core.rasterize import RasterAux, assemble_image, rasterize_tiles
from repro.core.s2 import (SortShared, empty_sort_shared,
                           predict_window_pose, shared_features,
                           speculative_sort)
from repro.core.sorting import sort_scene
from repro.core.tiling import TILE, gather_tile_features, tile_grid


@dataclasses.dataclass(frozen=True)
class LuminaConfig:
    """Algorithm configuration (paper defaults: window=6, margin=4, k=5).

    ``backend`` selects the shade implementation: ``'reference'`` is the
    pure-JAX rasterizer + functional cache (the oracle), ``'pallas'`` routes
    shading through the chunked Pallas kernels (``repro.kernels.ops``) —
    phase A/lookup/resume/insert with the ``live`` mask reaching the kernel
    so idle serving lanes skip chunk iterations, and (with ``rc_compact``)
    the miss-compacted phase-B resume.  The switch threads everywhere the
    config does: ``LuminSys``, both serve steppers, and the serve CLI's
    ``--backend`` flag.
    """

    window: int = 6            # sharing window N (frames per sort)
    margin: int = 4            # expanded-viewport margin, pixels per side
    capacity: int = 256        # per-tile Gaussian budget
    k_record: int = 5          # alpha-record length
    group_tiles: int = 4       # cache shared across group_tiles^2 tiles (4x4 in paper)
    cache: rc.CacheConfig = rc.CacheConfig()
    sort_method: str = 'dense'
    max_tiles_per_gaussian: int = 16
    bg: float = 0.0
    use_s2: bool = True
    use_rc: bool = True
    backend: str = 'reference'  # 'reference' | 'pallas'
    shade_chunk: int = 64       # pallas backend: Gaussians per chunk iteration
    rc_compact: bool = True     # pallas backend: miss-compacted phase B

    def __post_init__(self):
        if self.backend not in ('reference', 'pallas'):
            raise ValueError(f'unknown shade backend: {self.backend!r}')
        object.__setattr__(self, 'cache',
                           self.cache._replace(k=self.k_record))


class FrameStats(NamedTuple):
    hit_rate: jax.Array          # fraction of pixels served from the cache
    sig_frac: jax.Array          # significant / iterated Gaussians
    mean_iterated: jax.Array     # average Gaussians iterated per pixel
    saved_frac: jax.Array        # fraction of integration skipped thanks to RC
    sorted_this_frame: jax.Array # 1.0 if Projection+Sorting ran


# Pixel <-> cache-group reshaping lives in repro.core.groups (shared with the
# kernel fast path); re-exported here for convenience.
from repro.core.groups import group_dims, num_groups, regroup, ungroup  # noqa: E402


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------

def render_frame_baseline(scene: GaussianScene, cam: Camera, cfg: LuminaConfig,
                          live=None, early_exit: bool = True):
    """Full 3DGS pipeline (Projection -> Sorting -> Rasterization), no reuse.

    ``early_exit=False`` selects the dense-scan rasterizer formulation —
    required by gradient consumers (the fine-tuning loss): the chunked
    early-exit ``while_loop`` is not reverse-mode differentiable.
    """
    proj = project(scene, cam)
    lists = sort_scene(proj, cam.width, cam.height, cfg.capacity,
                       method=cfg.sort_method,
                       max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)
    feats = gather_tile_features(proj, lists)
    colors, aux = rasterize_tiles(feats, lists.tiles_x, k_record=cfg.k_record,
                                  bg=cfg.bg, live=live,
                                  early_exit=early_exit)
    image = assemble_image(colors, lists.tiles_x, lists.tiles_y,
                           cam.width, cam.height)
    return image, colors, aux, lists


def rc_apply(cache: rc.CacheState, tile_colors: jax.Array, aux: RasterAux,
             tiles_x: int, tiles_y: int, cfg: LuminaConfig):
    """Radiance-cache lookup + update for one frame's tile colors.

    Returns (final tile colors, new cache, hit mask [T,P], saved-iteration
    fraction scalar).
    """
    ids_g = regroup(aux.alpha_record, tiles_x, tiles_y, cfg.group_tiles)
    raw_g = regroup(tile_colors, tiles_x, tiles_y, cfg.group_tiles)
    hit, val, _, _, cache = rc.lookup_all_groups(cache, ids_g, cfg.cache)
    final_g = jnp.where(hit[..., None], val, raw_g)
    cache = rc.insert_all_groups(cache, ids_g, raw_g, ~hit, cfg.cache)

    hit_t = ungroup(hit[..., None], tiles_x, tiles_y, cfg.group_tiles)[..., 0]
    final = ungroup(final_g, tiles_x, tiles_y, cfg.group_tiles)
    # A hit pixel stops after identifying its k significant Gaussians; pixels
    # whose record never filled (iter_at_k >= n_iterated) save nothing.
    saved = jnp.where(hit_t, jnp.maximum(aux.n_iterated - aux.iter_at_k, 0), 0)
    saved_frac = jnp.sum(saved) / jnp.maximum(jnp.sum(aux.n_iterated), 1)
    return final, cache, hit_t, saved_frac


def _stats(aux: RasterAux, hit, saved_frac, sorted_flag) -> FrameStats:
    tot_iter = jnp.maximum(jnp.sum(aux.n_iterated), 1)
    return FrameStats(
        hit_rate=jnp.mean(hit.astype(jnp.float32)),
        sig_frac=jnp.sum(aux.n_significant) / tot_iter,
        mean_iterated=jnp.mean(aux.n_iterated.astype(jnp.float32)),
        saved_frac=saved_frac,
        sorted_this_frame=jnp.asarray(sorted_flag, jnp.float32),
    )


# ---------------------------------------------------------------------------
# Functional core: ViewerState + render_step
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ViewerState:
    """Everything one viewer carries between frames, as a pure pytree.

    cache     : radiance-cache state (tags/values/LRU age per tile group)
    shared    : the S^2 speculative-sort result for the current window
    prev_cam  : camera of the previous rendered frame (pose prediction input)
    frame_idx : int32 scalar frame counter (drives the sort cadence)

    Being a pytree, a batch of viewers is just a ``ViewerState`` whose leaves
    carry a leading slot axis — ``render_step`` vmaps over it unchanged.
    """

    cache: rc.CacheState
    shared: SortShared
    prev_cam: Camera
    frame_idx: jax.Array


def copy_pytree(tree):
    """Fresh buffers for every array leaf — required before handing a pytree
    to a donating jitted call while the original is referenced elsewhere."""
    return jax.tree.map(lambda x: jnp.array(x, copy=True), tree)


def init_viewer_state(scene: GaussianScene, cfg: LuminaConfig,
                      cam0: Camera) -> ViewerState:
    """Cold-start state for one viewer rendering at ``cam0``'s resolution."""
    cache = rc.init_cache(num_groups(cam0.width, cam0.height, cfg.group_tiles),
                          cfg.cache)
    shared = empty_sort_shared(
        scene, cam0, margin=cfg.margin, capacity=cfg.capacity,
        method=cfg.sort_method,
        max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)
    # prev_cam gets its own buffers: the state is donated into jitted steps,
    # and the first step is typically called with cam0 itself — donating
    # aliased leaves is an XLA error (`f(donate(a), a)`).
    return ViewerState(cache=cache, shared=shared, prev_cam=copy_pytree(cam0),
                       frame_idx=jnp.int32(0))


def sort_phase(scene: GaussianScene, state: ViewerState, cam: Camera,
               cfg: LuminaConfig) -> SortShared:
    """Phase 1 of a frame: pose prediction + speculative Projection/Sorting.

    Pure and unconditional — the *caller* decides when it runs (``render_step``
    guards it with a ``lax.cond`` on the per-viewer cadence; the cohort
    scheduler in ``repro.serve.stepper`` gathers only the due slots and calls
    it once per window per slot).  Returns the ``SortShared`` for the next
    sharing window.
    """
    pred = predict_window_pose(state.prev_cam, cam, state.frame_idx,
                               cfg.window)
    return speculative_sort(
        scene, pred, margin=cfg.margin, capacity=cfg.capacity,
        method=cfg.sort_method,
        max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)


def shade_phase(scene: GaussianScene, state: ViewerState, cam: Camera,
                cfg: LuminaConfig, *, sorted_flag=0.0, active=None):
    """Phase 2 of a frame: sorting-shared prep + rasterization + radiance
    cache, consuming ``state.shared``.  Sort-free by construction — its cost
    is the per-frame cost S^2 amortizes the sort against.

    ``sorted_flag`` is threaded into ``FrameStats.sorted_this_frame`` (the
    phase itself never sorts, so whoever scheduled the sort reports it).
    ``active`` (scalar bool per call/lane) reaches the rasterizer's ``live``
    input: evicted/idle lanes in the batched serving path contribute nothing
    and count zero iterations instead of burning chunk iterations.

    ``cfg.backend`` picks the shade implementation: ``'reference'`` shades
    through the pure-JAX rasterizer and applies the radiance cache after the
    fact (RC savings *modeled*); ``'pallas'`` shades through the chunked
    kernel pipeline — prefix / lookup / miss-compacted resume / insert —
    where hits genuinely stop integration at the alpha-record and the
    ``live`` mask skips chunk iterations (RC savings *measured*).  The two
    agree on every integer cache decision; images agree to float32 ulp
    (the kernel evaluates alpha densely per chunk, so contraction order
    differs).  ``FrameStats.saved_frac`` keeps per-backend semantics: the
    modeled per-pixel integration saving on ``reference``, the realized
    chunk-level saving vs a count-capped full pass on ``pallas``.

    Returns ``(new_state, image, FrameStats)``.
    """
    tiles_x, tiles_y = tile_grid(cam.width, cam.height)
    feats, lists = _prep_features(scene, state, cam, cfg)

    if cfg.backend == 'pallas':
        from repro.kernels import ops
        # significance-exact list trim: entries that cannot reach
        # alpha > 1/255 inside their tile at the *render* pose (stale S^2
        # margin entries) are dropped and survivors compacted — images,
        # records and cache decisions are bit-unchanged, only examined-work
        # counters shrink (see ops.trim_features)
        feats = ops.trim_features(feats, tiles_x)
        if cfg.use_rc:
            colors, cache, aux, kst = ops.rasterize_with_rc(
                feats, tiles_x, tiles_y, state.cache, cfg.cache,
                cfg.group_tiles, k_record=cfg.k_record,
                chunk=cfg.shade_chunk, bg=cfg.bg, live=active,
                compact=cfg.rc_compact)
            hit = kst.hit
            saved_frac = 1.0 - ((kst.chunks_prefix + kst.chunks_resume)
                                .astype(jnp.float32)
                                / jnp.maximum(kst.chunks_bound, 1))
        else:
            colors, aux, _ = ops.rasterize_full(
                feats, tiles_x, k_record=cfg.k_record, chunk=cfg.shade_chunk,
                bg=cfg.bg, live=active)
            cache = state.cache
            hit = jnp.zeros(aux.n_iterated.shape, bool)
            saved_frac = jnp.float32(0.0)
    else:
        colors, aux = rasterize_tiles(feats, lists.tiles_x,
                                      k_record=cfg.k_record, bg=cfg.bg,
                                      live=active)
        if cfg.use_rc:
            colors, cache, hit, saved_frac = rc_apply(state.cache, colors,
                                                      aux, tiles_x, tiles_y,
                                                      cfg)
        else:
            cache = state.cache
            hit = jnp.zeros(aux.n_iterated.shape, bool)
            saved_frac = jnp.float32(0.0)

    image = assemble_image(colors, tiles_x, tiles_y, cam.width, cam.height)
    stats = _stats(aux, hit, saved_frac,
                   jnp.asarray(sorted_flag, jnp.float32))
    new_state = ViewerState(cache=cache, shared=state.shared, prev_cam=cam,
                            frame_idx=state.frame_idx + 1)
    return new_state, image, stats


def render_step(scene: GaussianScene, state: ViewerState, cam: Camera,
                cfg: LuminaConfig):
    """One frame of the Lumina pipeline as a pure function: the composition
    ``sort_phase`` (under a ``lax.cond`` on ``frame_idx % window``) followed
    by ``shade_phase``.

    Returns ``(new_state, image, FrameStats)``.  The cond keeps the whole
    step one jittable function; note that under vmap the cond lowers to a
    select and every lane pays the sort — batched serving uses the cohort
    scheduler in ``repro.serve.stepper`` instead.
    """
    if cfg.use_s2:
        do_sort = (state.frame_idx % cfg.window) == 0
        shared = jax.lax.cond(do_sort,
                              lambda st: sort_phase(scene, st, cam, cfg),
                              lambda st: st.shared,
                              state)
        state = dataclasses.replace(state, shared=shared)
        sorted_flag = do_sort.astype(jnp.float32)
    else:
        sorted_flag = jnp.float32(1.0)
    return shade_phase(scene, state, cam, cfg, sorted_flag=sorted_flag)


def batched_render_step(scene: GaussianScene, states: ViewerState,
                        cams: Camera, cfg: LuminaConfig):
    """vmap of ``render_step`` over a slot axis: states/cams carry a leading
    [S] axis (build cams with ``repro.core.camera.stack_cameras``); the scene
    is shared.  Returns batched ``(states, images, FrameStats)``.

    Each lane keeps its own sort cadence (exact parity with independent
    ``LuminSys`` runs), so the per-lane ``lax.cond`` lowers to a select under
    vmap and the speculative sort executes for every lane on every tick —
    this is the parity oracle, not the serving fast path.  The serving path
    (``repro.serve.stepper.BatchedStepper``) staggers sort phases across
    slots and runs ``sort_phase`` only for the due cohort each tick.
    """
    return jax.vmap(lambda st, cm: render_step(scene, st, cm, cfg))(
        states, cams)


def batched_shade_phase(scene: GaussianScene, states: ViewerState,
                        cams: Camera, sorted_flags: jax.Array,
                        active: jax.Array, cfg: LuminaConfig):
    """The per-tick shade for all serving slots.  ``sorted_flags`` [S]
    float32 and ``active`` [S] bool are per-slot scalars from the scheduler.

    On the reference backend this is a vmap of ``shade_phase`` (the
    cond-free no-sort path stays scalar and sort-free under vmap).  On the
    pallas backend a vmapped ``pallas_call`` would batch by growing the
    grid — S x T programs that interpret mode executes serially — so the
    kernel stages run **slot-batched** instead: phase A puts every slot's
    lanes of a tile in one program and phase B compacts misses across the
    whole fleet (``ops.rasterize_with_rc_slots``).  Per-lane results are
    bit-identical to the vmap; only chunk *accounting* is fleet-coupled, so
    ``FrameStats.saved_frac`` on this path is the fleet-level measured
    saving (same value reported to every slot)."""
    if cfg.backend == 'pallas':
        return _batched_shade_pallas(scene, states, cams, sorted_flags,
                                     active, cfg)
    return jax.vmap(
        lambda st, cm, sf, ac: shade_phase(scene, st, cm, cfg,
                                           sorted_flag=sf, active=ac)
    )(states, cams, sorted_flags, active)


def _prep_features(scene: GaussianScene, state: ViewerState, cam: Camera,
                   cfg: LuminaConfig):
    """Per-frame shade prep: S^2 sorting-shared feature refresh, or a fresh
    Projection+Sorting in baseline mode.  One definition for the per-slot
    and slot-batched paths — their bit-identity depends on it."""
    if cfg.use_s2:
        return shared_features(scene, cam, state.shared)
    proj = project(scene, cam)
    lists = sort_scene(proj, cam.width, cam.height, cfg.capacity,
                       method=cfg.sort_method,
                       max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)
    return gather_tile_features(proj, lists), lists


def batched_prep_features(scene: GaussianScene, states: ViewerState,
                          cams: Camera, cfg: LuminaConfig):
    """Per-slot shade prep (``_prep_features``) over a slot axis:
    [S, T, K, ...] feature stacks."""
    return jax.vmap(
        lambda st, cm: _prep_features(scene, st, cm, cfg)[0])(states, cams)


def trim_features_slots(feats_b, tiles_x: int):
    """``ops.trim_features`` over [S, T, K, ...] feature stacks (same
    per-row math as the unbatched trim, so slot-batched and per-slot shades
    stay bit-identical)."""
    from repro.core.tiling import TileFeatures
    from repro.kernels import ops
    s, t = feats_b.ids.shape[:2]
    flat = TileFeatures(*[x.reshape((s * t,) + x.shape[2:]) for x in feats_b])
    flat = ops.trim_features(flat, tiles_x, t_img=t)
    return TileFeatures(*[x.reshape((s, t) + x.shape[1:]) for x in flat])


def _batched_shade_pallas(scene: GaussianScene, states: ViewerState,
                          cams: Camera, sorted_flags: jax.Array,
                          active: jax.Array, cfg: LuminaConfig):
    """Slot-batched pallas shade (see ``batched_shade_phase``)."""
    from repro.kernels import ops
    tiles_x, tiles_y = tile_grid(cams.width, cams.height)
    s = sorted_flags.shape[0]
    feats_b = batched_prep_features(scene, states, cams, cfg)
    feats_b = trim_features_slots(feats_b, tiles_x)

    if cfg.use_rc:
        colors, caches, aux, kst = ops.rasterize_with_rc_slots(
            feats_b, tiles_x, tiles_y, states.cache, cfg.cache,
            cfg.group_tiles, k_record=cfg.k_record, chunk=cfg.shade_chunk,
            bg=cfg.bg, live=active, compact=cfg.rc_compact)
        hit = kst.hit                                    # [S, T, P]
        # fleet-coupled chunk accounting -> fleet-level measured saving
        saved = 1.0 - ((kst.chunks_prefix + kst.chunks_resume)
                       .astype(jnp.float32)
                       / jnp.maximum(kst.chunks_bound, 1))
        saved_b = jnp.broadcast_to(saved, (s,))
    else:
        colors, aux, _ = ops.rasterize_full_slots(
            feats_b, tiles_x, k_record=cfg.k_record, chunk=cfg.shade_chunk,
            bg=cfg.bg, live=active)
        caches = states.cache
        hit = jnp.zeros(aux.n_iterated.shape, bool)
        saved_b = jnp.zeros((s,), jnp.float32)

    images = jax.vmap(
        lambda c: assemble_image(c, tiles_x, tiles_y, cams.width,
                                 cams.height))(colors)
    stats = jax.vmap(_stats)(aux, hit, saved_b, sorted_flags)
    new_states = ViewerState(cache=caches, shared=states.shared,
                             prev_cam=cams,
                             frame_idx=states.frame_idx + 1)
    return new_states, images, stats


def batched_sort_phase(scene: GaussianScene, states: ViewerState,
                       cams: Camera, cfg: LuminaConfig) -> SortShared:
    """vmap of ``sort_phase`` over a (small) cohort axis: states/cams carry a
    leading [C] axis of just the due slots."""
    return jax.vmap(lambda st, cm: sort_phase(scene, st, cm, cfg))(
        states, cams)


# ---------------------------------------------------------------------------
# The runner — thin single-viewer wrapper over the functional core
# ---------------------------------------------------------------------------

class LuminSys:
    """Stateful frame-sequencer: carries one ``ViewerState`` through the
    jitted ``render_step``.

    Usage::

        sys = LuminSys(scene, cfg, example_cam)
        for cam in trajectory:
            image, stats = sys.step(cam)
    """

    def __init__(self, scene: GaussianScene, cfg: LuminaConfig, cam0: Camera):
        self.scene = scene
        self.cfg = cfg
        self.tiles_x, self.tiles_y = tile_grid(cam0.width, cam0.height)
        self.state = init_viewer_state(scene, cfg, cam0)
        # The previous ViewerState is dead the instant the step returns —
        # donate it so XLA updates the cache/shared buffers in place instead
        # of copying the full O(N) state every frame.
        self._step = jax.jit(functools.partial(render_step, cfg=cfg),
                             donate_argnums=(1,))

    @property
    def cache(self) -> rc.CacheState:
        """The *current* cache state.  The step donates its input state, so a
        reference held across a later ``step`` call points at deleted buffers
        — re-read the property (or copy) instead of caching it."""
        return self.state.cache

    @property
    def frame_idx(self) -> int:
        return int(self.state.frame_idx)

    def step(self, cam: Camera):
        self.state, image, stats = self._step(self.scene, self.state, cam)
        return image, stats
