"""LuminSys — the full frame pipeline (paper Sec. 3.3).

Combines the three stages with both optimizations:

  pose history --> predict pose --> [Projection + Sorting] at predicted pose
       (speculative, once per sharing window, expanded viewport)
  every frame  --> sorting-shared prep (refresh geometry + SH colors)
               --> Rasterization with alpha-record extraction
               --> Radiance-Cache lookup: hits take the cached RGB and
                   terminate early; misses complete integration and insert.

Everything is expressed as jitted stages over fixed shapes; the Python-level
``LuminSys`` class only sequences them and carries functional state, so the
same stages drive tests, benchmarks, and the hardware cost models.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import radiance_cache as rc
from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.projection import project
from repro.core.rasterize import RasterAux, assemble_image, rasterize_tiles
from repro.core.s2 import SortShared, predict_pose, shared_features, speculative_sort
from repro.core.sorting import sort_scene
from repro.core.tiling import TILE, gather_tile_features, tile_grid


@dataclasses.dataclass(frozen=True)
class LuminaConfig:
    """Algorithm configuration (paper defaults: window=6, margin=4, k=5)."""

    window: int = 6            # sharing window N (frames per sort)
    margin: int = 4            # expanded-viewport margin, pixels per side
    capacity: int = 256        # per-tile Gaussian budget
    k_record: int = 5          # alpha-record length
    group_tiles: int = 4       # cache shared across group_tiles^2 tiles (4x4 in paper)
    cache: rc.CacheConfig = rc.CacheConfig()
    sort_method: str = 'dense'
    max_tiles_per_gaussian: int = 16
    bg: float = 0.0
    use_s2: bool = True
    use_rc: bool = True

    def __post_init__(self):
        object.__setattr__(self, 'cache',
                           self.cache._replace(k=self.k_record))


class FrameStats(NamedTuple):
    hit_rate: jax.Array          # fraction of pixels served from the cache
    sig_frac: jax.Array          # significant / iterated Gaussians
    mean_iterated: jax.Array     # average Gaussians iterated per pixel
    saved_frac: jax.Array        # fraction of integration skipped thanks to RC
    sorted_this_frame: jax.Array # 1.0 if Projection+Sorting ran


# Pixel <-> cache-group reshaping lives in repro.core.groups (shared with the
# kernel fast path); re-exported here for convenience.
from repro.core.groups import group_dims, num_groups, regroup, ungroup  # noqa: E402


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------

def render_frame_baseline(scene: GaussianScene, cam: Camera, cfg: LuminaConfig):
    """Full 3DGS pipeline (Projection -> Sorting -> Rasterization), no reuse."""
    proj = project(scene, cam)
    lists = sort_scene(proj, cam.width, cam.height, cfg.capacity,
                       method=cfg.sort_method,
                       max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)
    feats = gather_tile_features(proj, lists)
    colors, aux = rasterize_tiles(feats, lists.tiles_x, k_record=cfg.k_record,
                                  bg=cfg.bg)
    image = assemble_image(colors, lists.tiles_x, lists.tiles_y,
                           cam.width, cam.height)
    return image, colors, aux, lists


def rc_apply(cache: rc.CacheState, tile_colors: jax.Array, aux: RasterAux,
             tiles_x: int, tiles_y: int, cfg: LuminaConfig):
    """Radiance-cache lookup + update for one frame's tile colors.

    Returns (final tile colors, new cache, hit mask [T,P], saved-iteration
    fraction scalar).
    """
    ids_g = regroup(aux.alpha_record, tiles_x, tiles_y, cfg.group_tiles)
    raw_g = regroup(tile_colors, tiles_x, tiles_y, cfg.group_tiles)
    hit, val, _, _, cache = rc.lookup_all_groups(cache, ids_g, cfg.cache)
    final_g = jnp.where(hit[..., None], val, raw_g)
    cache = rc.insert_all_groups(cache, ids_g, raw_g, ~hit, cfg.cache)

    hit_t = ungroup(hit[..., None], tiles_x, tiles_y, cfg.group_tiles)[..., 0]
    final = ungroup(final_g, tiles_x, tiles_y, cfg.group_tiles)
    # A hit pixel stops after identifying its k significant Gaussians; pixels
    # whose record never filled (iter_at_k >= n_iterated) save nothing.
    saved = jnp.where(hit_t, jnp.maximum(aux.n_iterated - aux.iter_at_k, 0), 0)
    saved_frac = jnp.sum(saved) / jnp.maximum(jnp.sum(aux.n_iterated), 1)
    return final, cache, hit_t, saved_frac


def _stats(aux: RasterAux, hit, saved_frac, sorted_flag) -> FrameStats:
    tot_iter = jnp.maximum(jnp.sum(aux.n_iterated), 1)
    return FrameStats(
        hit_rate=jnp.mean(hit.astype(jnp.float32)),
        sig_frac=jnp.sum(aux.n_significant) / tot_iter,
        mean_iterated=jnp.mean(aux.n_iterated.astype(jnp.float32)),
        saved_frac=saved_frac,
        sorted_this_frame=jnp.asarray(sorted_flag, jnp.float32),
    )


# ---------------------------------------------------------------------------
# The runner
# ---------------------------------------------------------------------------

class LuminSys:
    """Stateful frame-sequencer over the jitted stages.

    Usage::

        sys = LuminSys(scene, cfg, example_cam)
        for cam in trajectory:
            image, stats = sys.step(cam)
    """

    def __init__(self, scene: GaussianScene, cfg: LuminaConfig, cam0: Camera):
        self.scene = scene
        self.cfg = cfg
        tx, ty = tile_grid(cam0.width, cam0.height)
        self.tiles_x, self.tiles_y = tx, ty
        self.cache = rc.init_cache(num_groups(cam0.width, cam0.height,
                                              cfg.group_tiles), cfg.cache)
        self.shared: Optional[SortShared] = None
        self.prev_cam: Optional[Camera] = None
        self.frame_idx = 0

        cfgc = cfg

        def _sort(scene, cam_pred):
            return speculative_sort(
                scene, cam_pred, margin=cfgc.margin, capacity=cfgc.capacity,
                method=cfgc.sort_method,
                max_tiles_per_gaussian=cfgc.max_tiles_per_gaussian)

        def _render_shared(scene, cam, shared):
            feats, lists = shared_features(scene, cam, shared)
            colors, aux = rasterize_tiles(feats, lists.tiles_x,
                                          k_record=cfgc.k_record, bg=cfgc.bg)
            return colors, aux

        def _render_full(scene, cam):
            return render_frame_baseline(scene, cam, cfgc)

        def _rc(cache, colors, aux):
            return rc_apply(cache, colors, aux, tx, ty, cfgc)

        self._sort = jax.jit(_sort)
        self._render_shared = jax.jit(_render_shared)
        self._render_full = jax.jit(_render_full)
        self._rc = jax.jit(_rc)

    def step(self, cam: Camera):
        cfg = self.cfg
        sorted_flag = 0.0
        if cfg.use_s2:
            if self.frame_idx % cfg.window == 0 or self.shared is None:
                prev = self.prev_cam if self.prev_cam is not None else cam
                pred = predict_pose(prev, cam, cfg.window)
                self.shared = self._sort(self.scene, pred)
                sorted_flag = 1.0
            colors, aux = self._render_shared(self.scene, cam, self.shared)
        else:
            _, colors, aux, _ = self._render_full(self.scene, cam)
            sorted_flag = 1.0

        if cfg.use_rc:
            colors, self.cache, hit, saved_frac = self._rc(self.cache, colors, aux)
        else:
            hit = jnp.zeros(aux.n_iterated.shape, bool)
            saved_frac = jnp.float32(0.0)

        image = assemble_image(colors, self.tiles_x, self.tiles_y,
                               cam.width, cam.height)
        stats = _stats(aux, hit, saved_frac, sorted_flag)
        self.prev_cam = cam
        self.frame_idx += 1
        return image, stats
