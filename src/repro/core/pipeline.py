"""LuminSys — the full frame pipeline (paper Sec. 3.3).

Combines the three stages with both optimizations:

  pose history --> predict pose --> [Projection + Sorting] at predicted pose
       (speculative, once per sharing window, expanded viewport)
  every frame  --> sorting-shared prep (refresh geometry + SH colors)
               --> Rasterization with alpha-record extraction
               --> Radiance-Cache lookup: hits take the cached RGB and
                   terminate early; misses complete integration and insert.

Everything is expressed as one pure, jitted ``render_step`` over fixed shapes:
per-viewer state (radiance cache, S^2 sort-shared buffers, previous pose,
frame counter) lives in a ``ViewerState`` pytree, and the sort-or-reuse
decision is a ``lax.cond`` — so the same step function drives the
single-viewer ``LuminSys`` wrapper, the vmapped multi-viewer serving path
(``repro.serve``), tests, benchmarks, and the hardware cost models.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import radiance_cache as rc
from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.projection import project
from repro.core.rasterize import RasterAux, assemble_image, rasterize_tiles
from repro.core.s2 import (SortShared, empty_sort_shared, predict_pose,
                           shared_features, speculative_sort)
from repro.core.sorting import sort_scene
from repro.core.tiling import TILE, gather_tile_features, tile_grid


@dataclasses.dataclass(frozen=True)
class LuminaConfig:
    """Algorithm configuration (paper defaults: window=6, margin=4, k=5)."""

    window: int = 6            # sharing window N (frames per sort)
    margin: int = 4            # expanded-viewport margin, pixels per side
    capacity: int = 256        # per-tile Gaussian budget
    k_record: int = 5          # alpha-record length
    group_tiles: int = 4       # cache shared across group_tiles^2 tiles (4x4 in paper)
    cache: rc.CacheConfig = rc.CacheConfig()
    sort_method: str = 'dense'
    max_tiles_per_gaussian: int = 16
    bg: float = 0.0
    use_s2: bool = True
    use_rc: bool = True

    def __post_init__(self):
        object.__setattr__(self, 'cache',
                           self.cache._replace(k=self.k_record))


class FrameStats(NamedTuple):
    hit_rate: jax.Array          # fraction of pixels served from the cache
    sig_frac: jax.Array          # significant / iterated Gaussians
    mean_iterated: jax.Array     # average Gaussians iterated per pixel
    saved_frac: jax.Array        # fraction of integration skipped thanks to RC
    sorted_this_frame: jax.Array # 1.0 if Projection+Sorting ran


# Pixel <-> cache-group reshaping lives in repro.core.groups (shared with the
# kernel fast path); re-exported here for convenience.
from repro.core.groups import group_dims, num_groups, regroup, ungroup  # noqa: E402


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------

def render_frame_baseline(scene: GaussianScene, cam: Camera, cfg: LuminaConfig):
    """Full 3DGS pipeline (Projection -> Sorting -> Rasterization), no reuse."""
    proj = project(scene, cam)
    lists = sort_scene(proj, cam.width, cam.height, cfg.capacity,
                       method=cfg.sort_method,
                       max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)
    feats = gather_tile_features(proj, lists)
    colors, aux = rasterize_tiles(feats, lists.tiles_x, k_record=cfg.k_record,
                                  bg=cfg.bg)
    image = assemble_image(colors, lists.tiles_x, lists.tiles_y,
                           cam.width, cam.height)
    return image, colors, aux, lists


def rc_apply(cache: rc.CacheState, tile_colors: jax.Array, aux: RasterAux,
             tiles_x: int, tiles_y: int, cfg: LuminaConfig):
    """Radiance-cache lookup + update for one frame's tile colors.

    Returns (final tile colors, new cache, hit mask [T,P], saved-iteration
    fraction scalar).
    """
    ids_g = regroup(aux.alpha_record, tiles_x, tiles_y, cfg.group_tiles)
    raw_g = regroup(tile_colors, tiles_x, tiles_y, cfg.group_tiles)
    hit, val, _, _, cache = rc.lookup_all_groups(cache, ids_g, cfg.cache)
    final_g = jnp.where(hit[..., None], val, raw_g)
    cache = rc.insert_all_groups(cache, ids_g, raw_g, ~hit, cfg.cache)

    hit_t = ungroup(hit[..., None], tiles_x, tiles_y, cfg.group_tiles)[..., 0]
    final = ungroup(final_g, tiles_x, tiles_y, cfg.group_tiles)
    # A hit pixel stops after identifying its k significant Gaussians; pixels
    # whose record never filled (iter_at_k >= n_iterated) save nothing.
    saved = jnp.where(hit_t, jnp.maximum(aux.n_iterated - aux.iter_at_k, 0), 0)
    saved_frac = jnp.sum(saved) / jnp.maximum(jnp.sum(aux.n_iterated), 1)
    return final, cache, hit_t, saved_frac


def _stats(aux: RasterAux, hit, saved_frac, sorted_flag) -> FrameStats:
    tot_iter = jnp.maximum(jnp.sum(aux.n_iterated), 1)
    return FrameStats(
        hit_rate=jnp.mean(hit.astype(jnp.float32)),
        sig_frac=jnp.sum(aux.n_significant) / tot_iter,
        mean_iterated=jnp.mean(aux.n_iterated.astype(jnp.float32)),
        saved_frac=saved_frac,
        sorted_this_frame=jnp.asarray(sorted_flag, jnp.float32),
    )


# ---------------------------------------------------------------------------
# Functional core: ViewerState + render_step
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ViewerState:
    """Everything one viewer carries between frames, as a pure pytree.

    cache     : radiance-cache state (tags/values/LRU age per tile group)
    shared    : the S^2 speculative-sort result for the current window
    prev_cam  : camera of the previous rendered frame (pose prediction input)
    frame_idx : int32 scalar frame counter (drives the sort cadence)

    Being a pytree, a batch of viewers is just a ``ViewerState`` whose leaves
    carry a leading slot axis — ``render_step`` vmaps over it unchanged.
    """

    cache: rc.CacheState
    shared: SortShared
    prev_cam: Camera
    frame_idx: jax.Array


def init_viewer_state(scene: GaussianScene, cfg: LuminaConfig,
                      cam0: Camera) -> ViewerState:
    """Cold-start state for one viewer rendering at ``cam0``'s resolution."""
    cache = rc.init_cache(num_groups(cam0.width, cam0.height, cfg.group_tiles),
                          cfg.cache)
    shared = empty_sort_shared(
        scene, cam0, margin=cfg.margin, capacity=cfg.capacity,
        method=cfg.sort_method,
        max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)
    return ViewerState(cache=cache, shared=shared, prev_cam=cam0,
                       frame_idx=jnp.int32(0))


def render_step(scene: GaussianScene, state: ViewerState, cam: Camera,
                cfg: LuminaConfig):
    """One frame of the Lumina pipeline as a pure function.

    Returns ``(new_state, image, FrameStats)``.  The S^2 sort-or-reuse
    decision is a ``lax.cond`` on ``frame_idx % window`` so the whole step
    jits once and vmaps over batched (state, cam) for multi-viewer serving.
    """
    tiles_x, tiles_y = tile_grid(cam.width, cam.height)

    if cfg.use_s2:
        do_sort = (state.frame_idx % cfg.window) == 0
        # Frame 0 has no real previous pose: predict from the current one
        # (LuminSys semantics — prediction degenerates to the identity).
        is_first = state.frame_idx == 0
        prev_cam = jax.tree.map(lambda p, c: jnp.where(is_first, c, p),
                                state.prev_cam, cam)
        pred = predict_pose(prev_cam, cam, cfg.window)

        def _sort(_):
            return speculative_sort(
                scene, pred, margin=cfg.margin, capacity=cfg.capacity,
                method=cfg.sort_method,
                max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)

        shared = jax.lax.cond(do_sort, _sort, lambda _: state.shared, None)
        feats, lists = shared_features(scene, cam, shared)
        colors, aux = rasterize_tiles(feats, lists.tiles_x,
                                      k_record=cfg.k_record, bg=cfg.bg)
        sorted_flag = do_sort.astype(jnp.float32)
    else:
        _, colors, aux, _ = render_frame_baseline(scene, cam, cfg)
        shared = state.shared
        sorted_flag = jnp.float32(1.0)

    if cfg.use_rc:
        colors, cache, hit, saved_frac = rc_apply(state.cache, colors, aux,
                                                  tiles_x, tiles_y, cfg)
    else:
        cache = state.cache
        hit = jnp.zeros(aux.n_iterated.shape, bool)
        saved_frac = jnp.float32(0.0)

    image = assemble_image(colors, tiles_x, tiles_y, cam.width, cam.height)
    stats = _stats(aux, hit, saved_frac, sorted_flag)
    new_state = ViewerState(cache=cache, shared=shared, prev_cam=cam,
                            frame_idx=state.frame_idx + 1)
    return new_state, image, stats


def batched_render_step(scene: GaussianScene, states: ViewerState,
                        cams: Camera, cfg: LuminaConfig):
    """vmap of ``render_step`` over a slot axis: states/cams carry a leading
    [S] axis (build cams with ``repro.core.camera.stack_cameras``); the scene
    is shared.  Returns batched ``(states, images, FrameStats)``.

    Because each lane keeps its own sort cadence (required for exact parity
    with independent ``LuminSys`` runs), the per-lane ``lax.cond`` lowers to
    a select under vmap and the speculative sort executes for every lane on
    every tick.  A cadence synchronized across slots would keep the cond
    scalar and restore the 1-in-window amortization — see ROADMAP.
    """
    return jax.vmap(lambda st, cm: render_step(scene, st, cm, cfg))(
        states, cams)


# ---------------------------------------------------------------------------
# The runner — thin single-viewer wrapper over the functional core
# ---------------------------------------------------------------------------

class LuminSys:
    """Stateful frame-sequencer: carries one ``ViewerState`` through the
    jitted ``render_step``.

    Usage::

        sys = LuminSys(scene, cfg, example_cam)
        for cam in trajectory:
            image, stats = sys.step(cam)
    """

    def __init__(self, scene: GaussianScene, cfg: LuminaConfig, cam0: Camera):
        self.scene = scene
        self.cfg = cfg
        self.tiles_x, self.tiles_y = tile_grid(cam0.width, cam0.height)
        self.state = init_viewer_state(scene, cfg, cam0)
        self._step = jax.jit(functools.partial(render_step, cfg=cfg))

    @property
    def cache(self) -> rc.CacheState:
        return self.state.cache

    @property
    def frame_idx(self) -> int:
        return int(self.state.frame_idx)

    def step(self, cam: Camera):
        self.state, image, stats = self._step(self.scene, self.state, cam)
        return image, stats
