"""LuminSys — the full frame pipeline (paper Sec. 3.3).

Combines the three stages with both optimizations:

  pose history --> predict pose --> [Projection + Sorting] at predicted pose
       (speculative, once per sharing window, expanded viewport)
  every frame  --> sorting-shared prep (refresh geometry + SH colors)
               --> Rasterization with alpha-record extraction
               --> Radiance-Cache lookup: hits take the cached RGB and
                   terminate early; misses complete integration and insert.

Everything is expressed as pure functions over fixed shapes.  State is split
along the sharing axis of a serving fleet:

  * ``SceneShared``  — what every viewer of one *scene* shares: ONE radiance
    cache, plus a pose-cell-keyed pool of ``SortShared`` entries (refcounted
    by the viewers consuming them);
  * ``ViewerPrivate`` — what stays per-viewer: previous pose, frame counter,
    current pose-cell id, pool index;
  * ``ViewerState``  — the single-viewer composition (one scene, one viewer,
    a pool of one): exactly the pre-split state model, carried by
    ``render_step``/``LuminSys``.

The frame is split into two phases over that state:

  * ``sort_phase``  — pose prediction + speculative Projection/Sorting,
    writing a ``SortShared`` pool entry (runs once per sharing window);
  * ``shade_phase`` — sorting-shared prep + rasterization + radiance cache,
    consuming the viewer's pool entry and returning the updated
    ``SceneShared`` functionally (runs every frame, sort-free).

``render_step`` composes the two with a ``lax.cond`` on
``frame_idx % window`` — the single-viewer contract is unchanged and it still
jits/vmaps as one step.  The multi-viewer serving path
(``repro.serve.stepper``) instead schedules the phases itself: a pose-cell
sort scheduler elects one sorter per due (scene, cell) group each tick and
advances all slots through ``batched_shade_phase``, whose cache stages run
scene-major so viewers of one scene probe and fill one shared cache in
deterministic (slot, pixel) order.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import radiance_cache as rc
from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene
from repro.core.projection import project
from repro.core.rasterize import RasterAux, assemble_image, rasterize_tiles
from repro.core.s2 import (SortShared, empty_sort_shared,
                           predict_window_pose, shared_features,
                           speculative_sort)
from repro.core.sorting import sort_scene
from repro.core.tiling import TILE, gather_tile_features, tile_grid


@dataclasses.dataclass(frozen=True)
class LuminaConfig:
    """Algorithm configuration (paper defaults: window=6, margin=4, k=5).

    ``backend`` selects the shade implementation: ``'reference'`` is the
    pure-JAX rasterizer + functional cache (the oracle), ``'pallas'`` routes
    shading through the chunked Pallas kernels (``repro.kernels.ops``) —
    phase A/lookup/resume/insert with the ``live`` mask reaching the kernel
    so idle serving lanes skip chunk iterations, and (with ``rc_compact``)
    the miss-compacted phase-B resume.  The switch threads everywhere the
    config does: ``LuminSys``, both serve steppers, and the serve CLI's
    ``--backend`` flag.
    """

    window: int = 6            # sharing window N (frames per sort)
    margin: int = 4            # expanded-viewport margin, pixels per side
    capacity: int = 256        # per-tile Gaussian budget
    k_record: int = 5          # alpha-record length
    group_tiles: int = 4       # cache shared across group_tiles^2 tiles (4x4 in paper)
    cache: rc.CacheConfig = rc.CacheConfig()
    sort_method: str = 'dense'
    max_tiles_per_gaussian: int = 16
    bg: float = 0.0
    use_s2: bool = True
    use_rc: bool = True
    backend: str = 'reference'  # 'reference' | 'pallas'
    shade_chunk: int = 64       # pallas backend: Gaussians per chunk iteration
    rc_compact: bool = True     # pallas backend: miss-compacted phase B

    def __post_init__(self):
        if self.backend not in ('reference', 'pallas'):
            raise ValueError(f'unknown shade backend: {self.backend!r}')
        object.__setattr__(self, 'cache',
                           self.cache._replace(k=self.k_record))


class FrameStats(NamedTuple):
    hit_rate: jax.Array          # fraction of pixels served from the cache
    sig_frac: jax.Array          # significant / iterated Gaussians
    mean_iterated: jax.Array     # average Gaussians iterated per pixel
    saved_frac: jax.Array        # fraction of integration skipped thanks to RC
    sorted_this_frame: jax.Array # 1.0 if Projection+Sorting ran


# Pixel <-> cache-group reshaping lives in repro.core.groups (shared with the
# kernel fast path); re-exported here for convenience.
from repro.core.groups import group_dims, num_groups, regroup, ungroup  # noqa: E402


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------

def render_frame_baseline(scene: GaussianScene, cam: Camera, cfg: LuminaConfig,
                          live=None, early_exit: bool = True):
    """Full 3DGS pipeline (Projection -> Sorting -> Rasterization), no reuse.

    ``early_exit=False`` selects the dense-scan rasterizer formulation —
    required by gradient consumers (the fine-tuning loss): the chunked
    early-exit ``while_loop`` is not reverse-mode differentiable.
    """
    proj = project(scene, cam)
    lists = sort_scene(proj, cam.width, cam.height, cfg.capacity,
                       method=cfg.sort_method,
                       max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)
    feats = gather_tile_features(proj, lists)
    colors, aux = rasterize_tiles(feats, lists.tiles_x, k_record=cfg.k_record,
                                  bg=cfg.bg, live=live,
                                  early_exit=early_exit)
    image = assemble_image(colors, lists.tiles_x, lists.tiles_y,
                           cam.width, cam.height)
    return image, colors, aux, lists


def rc_apply(cache: rc.CacheState, tile_colors: jax.Array, aux: RasterAux,
             tiles_x: int, tiles_y: int, cfg: LuminaConfig):
    """Radiance-cache lookup + update for one frame's tile colors.

    Returns (final tile colors, new cache, hit mask [T,P], saved-iteration
    fraction scalar).
    """
    ids_g = regroup(aux.alpha_record, tiles_x, tiles_y, cfg.group_tiles)
    raw_g = regroup(tile_colors, tiles_x, tiles_y, cfg.group_tiles)
    hit, val, _, _, cache = rc.lookup_all_groups(cache, ids_g, cfg.cache)
    final_g = jnp.where(hit[..., None], val, raw_g)
    cache = rc.insert_all_groups(cache, ids_g, raw_g, ~hit, cfg.cache)

    hit_t = ungroup(hit[..., None], tiles_x, tiles_y, cfg.group_tiles)[..., 0]
    final = ungroup(final_g, tiles_x, tiles_y, cfg.group_tiles)
    # A hit pixel stops after identifying its k significant Gaussians; pixels
    # whose record never filled (iter_at_k >= n_iterated) save nothing.
    saved = jnp.where(hit_t, jnp.maximum(aux.n_iterated - aux.iter_at_k, 0), 0)
    saved_frac = jnp.sum(saved) / jnp.maximum(jnp.sum(aux.n_iterated), 1)
    return final, cache, hit_t, saved_frac


def _stats(aux: RasterAux, hit, saved_frac, sorted_flag) -> FrameStats:
    tot_iter = jnp.maximum(jnp.sum(aux.n_iterated), 1)
    return FrameStats(
        hit_rate=jnp.mean(hit.astype(jnp.float32)),
        sig_frac=jnp.sum(aux.n_significant) / tot_iter,
        mean_iterated=jnp.mean(aux.n_iterated.astype(jnp.float32)),
        saved_frac=saved_frac,
        sorted_this_frame=jnp.asarray(sorted_flag, jnp.float32),
    )


# ---------------------------------------------------------------------------
# Functional core: SceneShared + ViewerPrivate (+ the single-viewer
# composition ViewerState) and the two-phase render step
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ViewerPrivate:
    """What one viewer carries that no one else can share.

    prev_cam  : camera of the previous rendered frame (pose prediction input)
    frame_idx : int32 scalar frame counter (drives the sort cadence)
    cell_id   : int32 pose-cell key of the sort entry this viewer consumes
                (``repro.core.posecell``; -1 before the first sort)
    pool_idx  : int32 index into its scene's ``SceneShared.pool``
    """

    prev_cam: Camera
    frame_idx: jax.Array
    cell_id: jax.Array
    pool_idx: jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SceneShared:
    """Per-*scene* state shared by every viewer of that scene.

    cache     : ONE radiance cache for the scene — all viewers probe and
                insert into it in deterministic (slot, pixel) order
                (``radiance_cache.lookup_all_groups_multi`` / ``_multi``)
    pool      : pose-cell-keyed pool of ``SortShared`` entries, leaves with
                a leading [P] axis; viewers in the same pose cell consume
                one entry, so the pool holds O(distinct cells) live buffers
                instead of one per viewer
    pool_cell : [P] int32 pose-cell key held by each entry (-1 = free)
    pool_refs : [P] int32 count of live viewers referencing each entry
    pool_tick : [P] int32 tick of each entry's last speculative sort
                (scheduler freshness; -window before any sort)

    The pool bookkeeping (``pool_cell``/``pool_refs``/``pool_tick``) is
    owned by the host-side scheduler, which keeps these device copies in
    sync so the functional state stays self-describing — no jitted
    computation reads them.

    A fleet of scenes is this pytree with a leading scene axis [C]; see
    ``init_fleet``.
    """

    cache: rc.CacheState
    pool: SortShared
    pool_cell: jax.Array
    pool_refs: jax.Array
    pool_tick: jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ViewerState:
    """The single-viewer composition: one scene, one viewer, a pool of one —
    its own cache and its own sort, exactly the pre-split state model.  This
    is what ``render_step``/``LuminSys`` carry; multi-viewer serving holds
    ``SceneShared``/``ViewerPrivate`` separately (``repro.serve.stepper``).

    Being a pytree, a batch of viewers is just a ``ViewerState`` whose leaves
    carry a leading slot axis — ``render_step`` vmaps over it unchanged.
    """

    scene_shared: SceneShared
    viewer: ViewerPrivate

    # Convenience views mirroring the pre-split field names.
    @property
    def cache(self) -> rc.CacheState:
        return self.scene_shared.cache

    @property
    def shared(self) -> SortShared:
        """The sort entry this viewer consumes (entry 0 of its own pool)."""
        return jax.tree.map(lambda x: x[0], self.scene_shared.pool)

    @property
    def prev_cam(self) -> Camera:
        return self.viewer.prev_cam

    @property
    def frame_idx(self) -> jax.Array:
        return self.viewer.frame_idx


def copy_pytree(tree):
    """Fresh buffers for every array leaf — required before handing a pytree
    to a donating jitted call while the original is referenced elsewhere."""
    return jax.tree.map(lambda x: jnp.array(x, copy=True), tree)


def pytree_nbytes(tree) -> int:
    """Total device bytes across a pytree's array leaves (telemetry)."""
    return sum(int(x.nbytes) for x in jax.tree.leaves(tree))


def init_scene_shared(scene: GaussianScene, cfg: LuminaConfig, cam0: Camera,
                      pool_size: int = 1) -> SceneShared:
    """Cold-start shared state for one scene at ``cam0``'s resolution."""
    cache = rc.init_cache(num_groups(cam0.width, cam0.height, cfg.group_tiles),
                          cfg.cache)
    entry = empty_sort_shared(
        scene, cam0, margin=cfg.margin, capacity=cfg.capacity,
        method=cfg.sort_method,
        max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)
    pool = jax.tree.map(lambda x: jnp.stack([x] * pool_size), entry)
    return SceneShared(
        cache=cache, pool=pool,
        pool_cell=jnp.full((pool_size,), -1, jnp.int32),
        pool_refs=jnp.zeros((pool_size,), jnp.int32),
        pool_tick=jnp.full((pool_size,), -cfg.window, jnp.int32))


def init_viewer_private(cam0: Camera) -> ViewerPrivate:
    """Cold-start private state for one viewer."""
    # prev_cam gets its own buffers: the state is donated into jitted steps,
    # and the first step is typically called with cam0 itself — donating
    # aliased leaves is an XLA error (`f(donate(a), a)`).
    return ViewerPrivate(prev_cam=copy_pytree(cam0), frame_idx=jnp.int32(0),
                         cell_id=jnp.int32(-1), pool_idx=jnp.int32(0))


def init_viewer_state(scene: GaussianScene, cfg: LuminaConfig,
                      cam0: Camera) -> ViewerState:
    """Cold-start state for one viewer rendering at ``cam0``'s resolution."""
    return ViewerState(scene_shared=init_scene_shared(scene, cfg, cam0),
                       viewer=init_viewer_private(cam0))


def init_fleet(scene: GaussianScene, cfg: LuminaConfig, cam0: Camera,
               slots: int, viewers_per_scene: int = 1,
               pool_size: int | None = None):
    """Cold-start serving state: ``slots`` viewers over
    ``slots // viewers_per_scene`` scenes.

    Returns ``(SceneShared with [C]-leading leaves, ViewerPrivate with
    [S]-leading leaves)``; slot ``i`` belongs to scene ``i //
    viewers_per_scene`` (a static block layout, so the scene-major cache
    reshapes in ``batched_shade_phase`` are pure views).  ``pool_size``
    defaults to ``viewers_per_scene`` — the worst case of every viewer in
    its own pose cell — so pool allocation can never fail; co-located
    viewers leave all but one entry free (live count is what telemetry and
    the benchmarks watch).
    """
    v = viewers_per_scene
    if slots % v:
        raise ValueError(f'slots ({slots}) must be a multiple of '
                         f'viewers_per_scene ({v})')
    c = slots // v
    p = v if pool_size is None else pool_size
    shared1 = init_scene_shared(scene, cfg, cam0, pool_size=p)
    priv1 = init_viewer_private(cam0)
    shared = jax.tree.map(lambda x: jnp.stack([x] * c), shared1)
    priv = jax.tree.map(lambda x: jnp.stack([x] * slots), priv1)
    return shared, priv


def sort_entry(scene: GaussianScene, private: ViewerPrivate, cam: Camera,
               cfg: LuminaConfig) -> SortShared:
    """Pose prediction + speculative Projection/Sorting for one viewer:
    the raw ``SortShared`` entry a sharing window consumes.

    Pure and unconditional — the *caller* decides when it runs and where the
    entry lands (``sort_phase`` writes the single-viewer pool; the pose-cell
    scheduler in ``repro.serve.stepper`` scatters entries into each scene's
    pool, one per distinct cell).
    """
    pred = predict_window_pose(private.prev_cam, cam, private.frame_idx,
                               cfg.window)
    return speculative_sort(
        scene, pred, margin=cfg.margin, capacity=cfg.capacity,
        method=cfg.sort_method,
        max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)


def sort_phase(scene: GaussianScene, shared: SceneShared,
               private: ViewerPrivate, cam: Camera,
               cfg: LuminaConfig) -> SceneShared:
    """Phase 1 of a frame: run ``sort_entry`` and write it into the viewer's
    pool entry, stamping ``pool_tick`` with the viewer's frame counter.
    Returns the updated ``SceneShared`` (cache untouched).  Pose-cell
    bookkeeping (``pool_cell``/``pool_refs``) is the serving scheduler's
    job — the single-viewer cadence never needs it.
    """
    entry = sort_entry(scene, private, cam, cfg)
    pool = jax.tree.map(
        lambda full, upd: full.at[private.pool_idx].set(upd),
        shared.pool, entry)
    return dataclasses.replace(
        shared, pool=pool,
        pool_tick=shared.pool_tick.at[private.pool_idx].set(
            private.frame_idx.astype(jnp.int32)))


def shade_phase(scene: GaussianScene, shared: SceneShared,
                private: ViewerPrivate, cam: Camera,
                cfg: LuminaConfig, *, sorted_flag=0.0, active=None):
    """Phase 2 of a frame: sorting-shared prep + rasterization + radiance
    cache, consuming the viewer's pool entry
    (``shared.pool[private.pool_idx]``).  Sort-free by construction — its
    cost is the per-frame cost S^2 amortizes the sort against.

    ``sorted_flag`` is threaded into ``FrameStats.sorted_this_frame`` (the
    phase itself never sorts, so whoever scheduled the sort reports it).
    ``active`` (scalar bool per call/lane) reaches the rasterizer's ``live``
    input: evicted/idle lanes in the batched serving path contribute nothing
    and count zero iterations instead of burning chunk iterations.

    ``cfg.backend`` picks the shade implementation: ``'reference'`` shades
    through the pure-JAX rasterizer and applies the radiance cache after the
    fact (RC savings *modeled*); ``'pallas'`` shades through the chunked
    kernel pipeline — prefix / lookup / miss-compacted resume / insert —
    where hits genuinely stop integration at the alpha-record and the
    ``live`` mask skips chunk iterations (RC savings *measured*).  The two
    agree on every integer cache decision; images agree to float32 ulp
    (the kernel evaluates alpha densely per chunk, so contraction order
    differs).  ``FrameStats.saved_frac`` keeps per-backend semantics: the
    modeled per-pixel integration saving on ``reference``, the realized
    chunk-level saving vs a count-capped full pass on ``pallas``.

    Returns ``(new_shared, new_private, image, FrameStats)`` — the shared
    state comes back functionally updated (cache evolution), the pool is
    never touched by a shade.
    """
    tiles_x, tiles_y = tile_grid(cam.width, cam.height)
    sort = jax.tree.map(lambda x: x[private.pool_idx], shared.pool)
    feats, lists = _prep_features(scene, sort, cam, cfg)

    if cfg.backend == 'pallas':
        from repro.kernels import ops
        # significance-exact list trim: entries that cannot reach
        # alpha > 1/255 inside their tile at the *render* pose (stale S^2
        # margin entries) are dropped and survivors compacted — images,
        # records and cache decisions are bit-unchanged, only examined-work
        # counters shrink (see ops.trim_features)
        feats = ops.trim_features(feats, tiles_x)
        if cfg.use_rc:
            colors, cache, aux, kst = ops.rasterize_with_rc(
                feats, tiles_x, tiles_y, shared.cache, cfg.cache,
                cfg.group_tiles, k_record=cfg.k_record,
                chunk=cfg.shade_chunk, bg=cfg.bg, live=active,
                compact=cfg.rc_compact)
            hit = kst.hit
            saved_frac = 1.0 - ((kst.chunks_prefix + kst.chunks_resume)
                                .astype(jnp.float32)
                                / jnp.maximum(kst.chunks_bound, 1))
        else:
            colors, aux, _ = ops.rasterize_full(
                feats, tiles_x, k_record=cfg.k_record, chunk=cfg.shade_chunk,
                bg=cfg.bg, live=active)
            cache = shared.cache
            hit = jnp.zeros(aux.n_iterated.shape, bool)
            saved_frac = jnp.float32(0.0)
    else:
        colors, aux = rasterize_tiles(feats, lists.tiles_x,
                                      k_record=cfg.k_record, bg=cfg.bg,
                                      live=active)
        if cfg.use_rc:
            colors, cache, hit, saved_frac = rc_apply(shared.cache, colors,
                                                      aux, tiles_x, tiles_y,
                                                      cfg)
        else:
            cache = shared.cache
            hit = jnp.zeros(aux.n_iterated.shape, bool)
            saved_frac = jnp.float32(0.0)

    image = assemble_image(colors, tiles_x, tiles_y, cam.width, cam.height)
    stats = _stats(aux, hit, saved_frac,
                   jnp.asarray(sorted_flag, jnp.float32))
    new_shared = dataclasses.replace(shared, cache=cache)
    new_private = dataclasses.replace(private, prev_cam=cam,
                                      frame_idx=private.frame_idx + 1)
    return new_shared, new_private, image, stats


def render_step(scene: GaussianScene, state: ViewerState, cam: Camera,
                cfg: LuminaConfig):
    """One frame of the Lumina pipeline as a pure function: the composition
    ``sort_phase`` (under a ``lax.cond`` on ``frame_idx % window``) followed
    by ``shade_phase``, over the single-viewer state composition.

    Returns ``(new_state, image, FrameStats)``.  The cond keeps the whole
    step one jittable function; note that under vmap the cond lowers to a
    select and every lane pays the sort — batched serving uses the pose-cell
    scheduler in ``repro.serve.stepper`` instead.
    """
    shared, private = state.scene_shared, state.viewer
    if cfg.use_s2:
        do_sort = (private.frame_idx % cfg.window) == 0
        shared = jax.lax.cond(
            do_sort,
            lambda sh: sort_phase(scene, sh, private, cam, cfg),
            lambda sh: sh,
            shared)
        sorted_flag = do_sort.astype(jnp.float32)
    else:
        sorted_flag = jnp.float32(1.0)
    shared, private, image, stats = shade_phase(
        scene, shared, private, cam, cfg, sorted_flag=sorted_flag)
    return ViewerState(scene_shared=shared, viewer=private), image, stats


def batched_render_step(scene: GaussianScene, states: ViewerState,
                        cams: Camera, cfg: LuminaConfig):
    """vmap of ``render_step`` over a slot axis: states/cams carry a leading
    [S] axis (build cams with ``repro.core.camera.stack_cameras``); the scene
    is shared.  Returns batched ``(states, images, FrameStats)``.

    Each lane keeps its own sort cadence (exact parity with independent
    ``LuminSys`` runs), so the per-lane ``lax.cond`` lowers to a select under
    vmap and the speculative sort executes for every lane on every tick —
    this is the parity oracle, not the serving fast path.  The serving path
    (``repro.serve.stepper.BatchedStepper``) staggers sort phases across
    slots and runs the sort only for the due pose cells each tick.
    """
    return jax.vmap(lambda st, cm: render_step(scene, st, cm, cfg))(
        states, cams)


def scene_of_slot(slots: int, viewers_per_scene: int) -> jax.Array:
    """Static slot -> scene map: slot ``i`` serves scene ``i // V`` (block
    layout, so per-scene reshapes of slot-major arrays are pure views)."""
    return jnp.arange(slots, dtype=jnp.int32) // viewers_per_scene


def gather_sort_entries(shared: SceneShared, priv: ViewerPrivate,
                        viewers_per_scene: int = 1) -> SortShared:
    """Per-slot ``SortShared`` views out of the scene pools:
    entry ``pool[scene_of(slot), priv.pool_idx[slot]]`` for every slot."""
    s = priv.frame_idx.shape[0]
    c_of = scene_of_slot(s, viewers_per_scene)
    return jax.tree.map(lambda x: x[c_of, priv.pool_idx], shared.pool)


def batched_shade_phase(scene: GaussianScene, shared: SceneShared,
                        priv: ViewerPrivate, cams: Camera,
                        sorted_flags: jax.Array, active: jax.Array,
                        cfg: LuminaConfig, viewers_per_scene: int = 1):
    """The per-tick shade for all serving slots over scene-shared state.
    ``shared`` carries [C]-leading leaves (C = S // viewers_per_scene),
    ``priv``/``cams`` [S]-leading; ``sorted_flags`` [S] float32 and
    ``active`` [S] bool are per-slot scalars from the scheduler.  Returns
    ``(new_shared, new_priv, images, FrameStats)``.

    Rasterization is per-slot (vmapped); the radiance-cache stages run
    **scene-major**: each scene's cache serves all its viewers' probes and
    inserts as one slot-major batch (``rc.lookup_all_groups_multi`` /
    ``insert_all_groups_multi``), so cross-viewer conflicts resolve in
    deterministic (slot, pixel) order and idle lanes (``active`` False)
    neither touch LRU state nor insert.  With ``viewers_per_scene == 1``
    the scene-major reshape is the identity and every slot owns a private
    cache — bit-identical to pre-split serving.

    On the pallas backend the kernel stages run **slot-batched** (phase A
    puts every slot's lanes of a tile in one program, phase B compacts
    misses across the whole fleet) against the same shared caches
    (``ops.rasterize_with_rc_slots``); only chunk *accounting* is
    fleet-coupled, so ``FrameStats.saved_frac`` on that path is the
    fleet-level measured saving (same value reported to every slot)."""
    if cfg.backend == 'pallas':
        return _batched_shade_pallas(scene, shared, priv, cams, sorted_flags,
                                     active, cfg, viewers_per_scene)
    s = sorted_flags.shape[0]
    v = viewers_per_scene
    c = s // v
    tiles_x, tiles_y = tile_grid(cams.width, cams.height)
    sorts = gather_sort_entries(shared, priv, v)

    def raster_one(sort, cam, act):
        feats, lists = _prep_features(scene, sort, cam, cfg)
        return rasterize_tiles(feats, lists.tiles_x, k_record=cfg.k_record,
                               bg=cfg.bg, live=act)

    colors, aux = jax.vmap(raster_one)(sorts, cams, active)

    if cfg.use_rc:
        ids_g = jax.vmap(
            lambda r: regroup(r, tiles_x, tiles_y, cfg.group_tiles)
        )(aux.alpha_record)                                  # [S, G, B, k]
        raw_g = jax.vmap(
            lambda x: regroup(x, tiles_x, tiles_y, cfg.group_tiles))(colors)
        ids_cv = ids_g.reshape(c, v, *ids_g.shape[1:])       # [C, V, G, B, k]
        raw_cv = raw_g.reshape(c, v, *raw_g.shape[1:])
        act_cv = active.reshape(c, v)
        hit_cv, val_cv, _, _, caches = jax.vmap(
            lambda cc, ii, lv: rc.lookup_all_groups_multi(cc, ii, cfg.cache,
                                                          live=lv)
        )(shared.cache, ids_cv, act_cv)
        final_cv = jnp.where(hit_cv[..., None], val_cv, raw_cv)
        caches = jax.vmap(
            lambda cc, ii, rr, dd: rc.insert_all_groups_multi(cc, ii, rr, dd,
                                                              cfg.cache)
        )(caches, ids_cv, raw_cv, ~hit_cv & act_cv[:, :, None, None])
        hit = jax.vmap(
            lambda h: ungroup(h[..., None], tiles_x, tiles_y,
                              cfg.group_tiles)[..., 0]
        )(hit_cv.reshape(s, *hit_cv.shape[2:]))
        colors = jax.vmap(
            lambda x: ungroup(x, tiles_x, tiles_y, cfg.group_tiles)
        )(final_cv.reshape(s, *final_cv.shape[2:]))
        # A hit pixel stops after identifying its k significant Gaussians
        # (same modeled-saving formula as rc_apply, per slot).
        saved = jnp.where(hit, jnp.maximum(aux.n_iterated - aux.iter_at_k,
                                           0), 0)
        saved_frac = (jnp.sum(saved, axis=(1, 2))
                      / jnp.maximum(jnp.sum(aux.n_iterated, axis=(1, 2)), 1))
    else:
        caches = shared.cache
        hit = jnp.zeros(aux.n_iterated.shape, bool)
        saved_frac = jnp.zeros((s,), jnp.float32)

    images = jax.vmap(
        lambda cg: assemble_image(cg, tiles_x, tiles_y, cams.width,
                                  cams.height))(colors)
    stats = jax.vmap(_stats)(aux, hit, saved_frac, sorted_flags)
    new_shared = dataclasses.replace(shared, cache=caches)
    new_priv = dataclasses.replace(priv, prev_cam=cams,
                                   frame_idx=priv.frame_idx + 1)
    return new_shared, new_priv, images, stats


def _prep_features(scene: GaussianScene, sort: SortShared, cam: Camera,
                   cfg: LuminaConfig):
    """Per-frame shade prep: S^2 sorting-shared feature refresh of the given
    sort entry, or a fresh Projection+Sorting in baseline mode.  One
    definition for the per-slot and slot-batched paths — their bit-identity
    depends on it."""
    if cfg.use_s2:
        return shared_features(scene, cam, sort)
    proj = project(scene, cam)
    lists = sort_scene(proj, cam.width, cam.height, cfg.capacity,
                       method=cfg.sort_method,
                       max_tiles_per_gaussian=cfg.max_tiles_per_gaussian)
    return gather_tile_features(proj, lists), lists


def batched_prep_features(scene: GaussianScene, shared: SceneShared,
                          priv: ViewerPrivate, cams: Camera,
                          cfg: LuminaConfig, viewers_per_scene: int = 1):
    """Per-slot shade prep (``_prep_features``) over a slot axis:
    [S, T, K, ...] feature stacks."""
    sorts = gather_sort_entries(shared, priv, viewers_per_scene)
    return jax.vmap(
        lambda so, cm: _prep_features(scene, so, cm, cfg)[0])(sorts, cams)


def trim_features_slots(feats_b, tiles_x: int):
    """``ops.trim_features`` over [S, T, K, ...] feature stacks (same
    per-row math as the unbatched trim, so slot-batched and per-slot shades
    stay bit-identical)."""
    from repro.core.tiling import TileFeatures
    from repro.kernels import ops
    s, t = feats_b.ids.shape[:2]
    flat = TileFeatures(*[x.reshape((s * t,) + x.shape[2:]) for x in feats_b])
    flat = ops.trim_features(flat, tiles_x, t_img=t)
    return TileFeatures(*[x.reshape((s, t) + x.shape[1:]) for x in flat])


def _batched_shade_pallas(scene: GaussianScene, shared: SceneShared,
                          priv: ViewerPrivate, cams: Camera,
                          sorted_flags: jax.Array, active: jax.Array,
                          cfg: LuminaConfig, viewers_per_scene: int = 1):
    """Slot-batched pallas shade over scene-shared caches (see
    ``batched_shade_phase``)."""
    from repro.kernels import ops
    tiles_x, tiles_y = tile_grid(cams.width, cams.height)
    s = sorted_flags.shape[0]
    feats_b = batched_prep_features(scene, shared, priv, cams, cfg,
                                    viewers_per_scene)
    feats_b = trim_features_slots(feats_b, tiles_x)

    if cfg.use_rc:
        colors, caches, aux, kst = ops.rasterize_with_rc_slots(
            feats_b, tiles_x, tiles_y, shared.cache, cfg.cache,
            cfg.group_tiles, viewers_per_scene=viewers_per_scene,
            k_record=cfg.k_record, chunk=cfg.shade_chunk,
            bg=cfg.bg, live=active, compact=cfg.rc_compact)
        hit = kst.hit                                    # [S, T, P]
        # fleet-coupled chunk accounting -> fleet-level measured saving
        saved = 1.0 - ((kst.chunks_prefix + kst.chunks_resume)
                       .astype(jnp.float32)
                       / jnp.maximum(kst.chunks_bound, 1))
        saved_b = jnp.broadcast_to(saved, (s,))
    else:
        colors, aux, _ = ops.rasterize_full_slots(
            feats_b, tiles_x, k_record=cfg.k_record, chunk=cfg.shade_chunk,
            bg=cfg.bg, live=active)
        caches = shared.cache
        hit = jnp.zeros(aux.n_iterated.shape, bool)
        saved_b = jnp.zeros((s,), jnp.float32)

    images = jax.vmap(
        lambda c: assemble_image(c, tiles_x, tiles_y, cams.width,
                                 cams.height))(colors)
    stats = jax.vmap(_stats)(aux, hit, saved_b, sorted_flags)
    new_shared = dataclasses.replace(shared, cache=caches)
    new_priv = dataclasses.replace(priv, prev_cam=cams,
                                   frame_idx=priv.frame_idx + 1)
    return new_shared, new_priv, images, stats


def batched_sort_phase(scene: GaussianScene, privates: ViewerPrivate,
                       cams: Camera, cfg: LuminaConfig) -> SortShared:
    """vmap of ``sort_entry`` over a (small) cohort axis: privates/cams carry
    a leading [C] axis of just the slots elected to sort.  Where the entries
    land (which scene pool, which pose cell) is the scheduler's decision —
    this just produces them."""
    return jax.vmap(lambda pv, cm: sort_entry(scene, pv, cm, cfg))(
        privates, cams)


# ---------------------------------------------------------------------------
# The runner — thin single-viewer wrapper over the functional core
# ---------------------------------------------------------------------------

class LuminSys:
    """Stateful frame-sequencer: carries one ``ViewerState`` through the
    jitted ``render_step``.

    Usage::

        sys = LuminSys(scene, cfg, example_cam)
        for cam in trajectory:
            image, stats = sys.step(cam)
    """

    def __init__(self, scene: GaussianScene, cfg: LuminaConfig, cam0: Camera):
        self.scene = scene
        self.cfg = cfg
        self.tiles_x, self.tiles_y = tile_grid(cam0.width, cam0.height)
        self.state = init_viewer_state(scene, cfg, cam0)
        # The previous ViewerState is dead the instant the step returns —
        # donate it so XLA updates the cache/shared buffers in place instead
        # of copying the full O(N) state every frame.
        self._step = jax.jit(functools.partial(render_step, cfg=cfg),
                             donate_argnums=(1,))

    @property
    def cache(self) -> rc.CacheState:
        """The *current* cache state.  The step donates its input state, so a
        reference held across a later ``step`` call points at deleted buffers
        — re-read the property (or copy) instead of caching it."""
        return self.state.cache

    @property
    def frame_idx(self) -> int:
        return int(self.state.frame_idx)

    def step(self, cam: Camera):
        self.state, image, stats = self._step(self.scene, self.state, cam)
        return image, stats
