"""Projection stage of the 3DGS pipeline (EWA splatting).

Given a camera and a scene, produce per-Gaussian screen-space quantities:
2D means, conics (inverse 2D covariances), projected radii, depths, colors,
opacities and an in-frustum validity mask.  All fixed shape [N, ...].
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gaussians as G
from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene

# Low-pass filter added to 2D covariance (anti-aliasing), as in 3DGS.
COV2D_BLUR = 0.3
# Cutoff: a Gaussian's footprint is bounded by 3 sigma.
CUTOFF_SIGMA = 3.0


class Projected(NamedTuple):
    """Screen-space Gaussians (all [N, ...])."""

    mean2d: jax.Array    # [N, 2] pixel coordinates
    conic: jax.Array     # [N, 3] (a, b, c): inverse covariance [[a,b],[b,c]]
    radius: jax.Array    # [N] bounding radius in pixels
    depth: jax.Array     # [N] camera-space z
    color: jax.Array     # [N, 3] view-dependent RGB (SH-evaluated)
    opacity: jax.Array   # [N]
    valid: jax.Array     # [N] bool — inside frustum and non-degenerate


def project(scene: GaussianScene, cam: Camera) -> Projected:
    """Project all Gaussians onto the screen of `cam` (vectorized EWA)."""
    r_wc = G.quat_to_rotmat(cam.quat)        # world-from-camera
    r_cw = r_wc.T
    t = (scene.means - cam.position[None, :]) @ r_cw.T    # [N,3] camera frame
    tx, ty, tz = t[:, 0], t[:, 1], t[:, 2]

    in_depth = (tz > cam.near) & (tz < cam.far)
    tz_safe = jnp.where(tz > cam.near, tz, cam.near)

    # Frustum test with 30% guard band (as in the 3DGS reference).
    tan_fov_x = (cam.width / 2.0) / cam.fx
    tan_fov_y = (cam.height / 2.0) / cam.fy
    lim_x = 1.3 * tan_fov_x
    lim_y = 1.3 * tan_fov_y
    in_fov = (jnp.abs(tx / tz_safe) < lim_x) & (jnp.abs(ty / tz_safe) < lim_y)

    # Clamped camera coords for the Jacobian (avoids blow-up at frustum edge).
    txc = jnp.clip(tx / tz_safe, -lim_x, lim_x) * tz_safe
    tyc = jnp.clip(ty / tz_safe, -lim_y, lim_y) * tz_safe

    mean2d = jnp.stack([
        cam.fx * tx / tz_safe + cam.cx,
        cam.fy * ty / tz_safe + cam.cy,
    ], axis=-1)

    # Jacobian of perspective projection, [N,2,3].
    zero = jnp.zeros_like(tz_safe)
    j = jnp.stack([
        jnp.stack([cam.fx / tz_safe, zero, -cam.fx * txc / (tz_safe ** 2)], axis=-1),
        jnp.stack([zero, cam.fy / tz_safe, -cam.fy * tyc / (tz_safe ** 2)], axis=-1),
    ], axis=-2)

    cov3d = G.covariances_3d(scene)                       # [N,3,3] world
    # camera-frame covariance: R_cw Sigma R_cw^T
    cov_cam = jnp.einsum('ij,njk,lk->nil', r_cw, cov3d, r_cw)
    cov2d = jnp.einsum('nij,njk,nlk->nil', j, cov_cam, j)  # [N,2,2]
    a = cov2d[:, 0, 0] + COV2D_BLUR
    b = cov2d[:, 0, 1]
    c = cov2d[:, 1, 1] + COV2D_BLUR

    det = a * c - b * b
    det_ok = det > 1e-12
    det_safe = jnp.where(det_ok, det, 1.0)
    conic = jnp.stack([c / det_safe, -b / det_safe, a / det_safe], axis=-1)

    # Bounding radius: 3 sigma of the major axis.
    mid = 0.5 * (a + c)
    lam = mid + jnp.sqrt(jnp.maximum(mid * mid - det, 1e-12))
    radius = jnp.ceil(CUTOFF_SIGMA * jnp.sqrt(lam))

    view_dir = scene.means - cam.position[None, :]
    color = G.eval_sh(scene, view_dir)
    opacity = G.opacities(scene)

    valid = in_depth & in_fov & det_ok
    return Projected(
        mean2d=mean2d,
        conic=conic,
        radius=jnp.where(valid, radius, 0.0),
        depth=jnp.where(valid, tz, jnp.inf),
        color=color,
        opacity=jnp.where(valid, opacity, 0.0),
        valid=valid,
    )


def recolor(scene: GaussianScene, cam: Camera, proj: Projected) -> Projected:
    """Recompute only the view-dependent colors at a (new) camera pose.

    Used by the S^2 sorting-shared path: the paper requires colors to be
    re-evaluated from SH at every rendered pose even when sorting is reused.
    """
    view_dir = scene.means - cam.position[None, :]
    return proj._replace(color=G.eval_sh(scene, view_dir))


def reproject_geometry(scene: GaussianScene, cam: Camera, proj: Projected) -> Projected:
    """Recompute screen-space geometry + color at pose `cam`, but KEEP the
    validity/culling decisions of `proj` (made at the speculative pose).

    This is the sorting-shared render path: no culling, no tile rebuild, no
    sort — only the cheap per-Gaussian arithmetic is refreshed so the image is
    geometrically correct at the new pose.
    """
    fresh = project(scene, cam)
    # Keep the speculative culling mask: Gaussians culled at the sorting pose
    # stay culled (the expanded viewport makes this safe); Gaussians valid at
    # the sorting pose but degenerate now are dropped.
    valid = proj.valid & fresh.valid
    return fresh._replace(
        valid=valid,
        opacity=jnp.where(valid, fresh.opacity, 0.0),
        radius=jnp.where(valid, fresh.radius, 0.0),
        depth=jnp.where(valid, fresh.depth, jnp.inf),
    )
