"""Cache-aware end-to-end fine-tuning (paper Sec. 3.3, Eqn. 4).

    L_total = L_orig + alpha * L_scale(S, theta)

where L_orig is the original 3DGS loss ((1-lam)*L1 + lam*(1-SSIM), lam=0.2)
and L_scale penalizes the geometric mean S of each Gaussian's three scales
above a threshold theta — keeping Gaussians small so the RC assumption
("rays sharing the first k significant Gaussians have the same color") holds.

Sorting and cache lookup stay outside the gradient path: tile lists are
integer indices (no cotangents flow), and training renders through the full
integration (the cache only affects inference), so the pipeline is
end-to-end differentiable exactly as the paper describes (Fig. 14).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import metrics
from repro.core.camera import Camera
from repro.core.gaussians import GaussianScene, geometric_mean_scale
from repro.core.pipeline import LuminaConfig, render_frame_baseline
from repro.optim import adam


@dataclasses.dataclass(frozen=True)
class FinetuneConfig:
    lam_dssim: float = 0.2       # 3DGS loss mixing weight
    scale_alpha: float = 0.0     # alpha in Eqn. 4 (0 = plain 3DGS loss)
    scale_theta: float = 0.03    # theta: allowed geometric-mean scale
    adam: adam.AdamConfig = adam.AdamConfig(lr=5e-3, clip_norm=None,
                                            weight_decay=0.0)


class FinetuneMetrics(NamedTuple):
    loss: jax.Array
    l1: jax.Array
    dssim: jax.Array
    l_scale: jax.Array
    psnr: jax.Array


def scale_loss(scene: GaussianScene, theta: float) -> jax.Array:
    """L_scale: mean penalty on geometric-mean scales exceeding theta."""
    s = geometric_mean_scale(scene)
    return jnp.mean(jnp.maximum(s - theta, 0.0))


def total_loss(scene: GaussianScene, cam: Camera, gt: jax.Array,
               cfg: FinetuneConfig, render_cfg: LuminaConfig):
    # early_exit=False: the loss is differentiated, and the rasterizer's
    # chunked early-exit while_loop has no reverse-mode rule
    image, _, _, _ = render_frame_baseline(scene, cam, render_cfg,
                                           early_exit=False)
    l1 = jnp.mean(jnp.abs(image - gt))
    dssim = 1.0 - metrics.ssim(image, gt)
    l_orig = (1 - cfg.lam_dssim) * l1 + cfg.lam_dssim * dssim
    l_sc = scale_loss(scene, cfg.scale_theta)
    loss = l_orig + cfg.scale_alpha * l_sc
    aux = FinetuneMetrics(loss=loss, l1=l1, dssim=dssim, l_scale=l_sc,
                          psnr=metrics.psnr(image, gt))
    return loss, aux


def make_train_step(cfg: FinetuneConfig, render_cfg: LuminaConfig):
    """Returns a jitted (scene, opt_state, cam, gt) -> (scene, opt_state, metrics)."""

    def train_step(scene: GaussianScene, opt_state: adam.AdamState,
                   cam: Camera, gt: jax.Array):
        (loss, aux), grads = jax.value_and_grad(total_loss, has_aux=True)(
            scene, cam, gt, cfg, render_cfg)
        scene, opt_state, _ = adam.step(scene, grads, opt_state, cfg.adam)
        return scene, opt_state, aux

    return jax.jit(train_step)


def finetune(scene: GaussianScene, cams, gts, cfg: FinetuneConfig,
             render_cfg: LuminaConfig, steps: int, log_every: int = 0):
    """Simple fine-tuning loop cycling through (cams, gts) pairs."""
    opt_state = adam.init(scene, cfg.adam)
    train_step = make_train_step(cfg, render_cfg)
    history = []
    for i in range(steps):
        j = i % len(cams)
        scene, opt_state, aux = train_step(scene, opt_state, cams[j], gts[j])
        history.append(aux)
        if log_every and i % log_every == 0:
            print(f'  step {i}: loss={float(aux.loss):.4f} psnr={float(aux.psnr):.2f} '
                  f'l_scale={float(aux.l_scale):.5f}')
    return scene, history
