"""Gaussian scene representation for 3DGS.

A scene is a pytree of per-Gaussian parameters (the trainable representation
from Kerbl et al. 2023, used unchanged by Lumina).  All fields are fixed-shape
arrays so the whole pipeline stays jit/pjit friendly.

Raw (trainable) parameterization:
  means         [N, 3]   world-space centers
  log_scales    [N, 3]   log of per-axis scales (activation: exp)
  quats         [N, 4]   unnormalized rotation quaternions (activation: normalize)
  opacity_logit [N]      (activation: sigmoid)
  sh_dc         [N, 3]   degree-0 spherical-harmonic coefficients
  sh_rest       [N, 3, 3] degree-1 SH coefficients (3 basis fns x RGB)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

SH_C0 = 0.28209479177387814
SH_C1 = 0.4886025119029199

# Alpha below which a Gaussian is insignificant (paper: 1/255).
ALPHA_SIGNIFICANT = 1.0 / 255.0
# Transmittance termination threshold theta (3DGS reference uses 1e-4).
TRANSMITTANCE_EPS = 1.0e-4
ALPHA_MAX = 0.99


class GaussianScene(NamedTuple):
    """Trainable scene parameters (raw, pre-activation)."""

    means: jax.Array          # [N, 3]
    log_scales: jax.Array     # [N, 3]
    quats: jax.Array          # [N, 4]
    opacity_logit: jax.Array  # [N]
    sh_dc: jax.Array          # [N, 3]
    sh_rest: jax.Array        # [N, 3, 3]

    @property
    def num_gaussians(self) -> int:
        return self.means.shape[0]


def quat_to_rotmat(q: jax.Array) -> jax.Array:
    """Normalized quaternion(s) [..., 4] (w,x,y,z) -> rotation matrix [..., 3, 3]."""
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
    w, x, y, z = q[..., 0], q[..., 1], q[..., 2], q[..., 3]
    r00 = 1 - 2 * (y * y + z * z)
    r01 = 2 * (x * y - w * z)
    r02 = 2 * (x * z + w * y)
    r10 = 2 * (x * y + w * z)
    r11 = 1 - 2 * (x * x + z * z)
    r12 = 2 * (y * z - w * x)
    r20 = 2 * (x * z - w * y)
    r21 = 2 * (y * z + w * x)
    r22 = 1 - 2 * (x * x + y * y)
    rows = jnp.stack(
        [
            jnp.stack([r00, r01, r02], axis=-1),
            jnp.stack([r10, r11, r12], axis=-1),
            jnp.stack([r20, r21, r22], axis=-1),
        ],
        axis=-2,
    )
    return rows


def scales(scene: GaussianScene) -> jax.Array:
    return jnp.exp(scene.log_scales)


def opacities(scene: GaussianScene) -> jax.Array:
    return jax.nn.sigmoid(scene.opacity_logit)


def covariances_3d(scene: GaussianScene) -> jax.Array:
    """Sigma = R S S^T R^T, [N, 3, 3]."""
    rot = quat_to_rotmat(scene.quats)                    # [N,3,3]
    s = scales(scene)                                    # [N,3]
    m = rot * s[:, None, :]                              # R @ diag(s)
    return m @ jnp.swapaxes(m, -1, -2)


def eval_sh(scene: GaussianScene, view_dirs: jax.Array) -> jax.Array:
    """Evaluate degree-1 SH color for each Gaussian given unit view dirs [N,3].

    Returns RGB in [0, inf) (clamped at 0 after the +0.5 shift, as in 3DGS).
    """
    d = view_dirs / (jnp.linalg.norm(view_dirs, axis=-1, keepdims=True) + 1e-12)
    x, y, z = d[..., 0:1], d[..., 1:2], d[..., 2:3]
    c = SH_C0 * scene.sh_dc
    c = c - SH_C1 * y * scene.sh_rest[..., 0, :]
    c = c + SH_C1 * z * scene.sh_rest[..., 1, :]
    c = c - SH_C1 * x * scene.sh_rest[..., 2, :]
    return jnp.maximum(c + 0.5, 0.0)


def geometric_mean_scale(scene: GaussianScene) -> jax.Array:
    """Geometric mean of the three scale parameters, [N].

    This is the `S` in the paper's scale-constrained loss (Eqn. 4).
    """
    return jnp.exp(jnp.mean(scene.log_scales, axis=-1))


def init_scene(key: jax.Array, num_gaussians: int,
               extent: float = 1.0, dtype=jnp.float32) -> GaussianScene:
    """Random scene initialization (centers uniform in a cube of half-side `extent`)."""
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    means = jax.random.uniform(k1, (num_gaussians, 3), dtype, -extent, extent)
    log_scales = jnp.log(
        jax.random.uniform(k2, (num_gaussians, 3), dtype, 0.02, 0.08) * extent)
    quats = jax.random.normal(k3, (num_gaussians, 4), dtype)
    quats = quats.at[:, 0].add(2.0)  # bias toward identity
    opacity_logit = jax.random.uniform(k4, (num_gaussians,), dtype, -1.0, 2.0)
    sh_dc = jax.random.uniform(k5, (num_gaussians, 3), dtype, -1.0, 1.0)
    sh_rest = 0.1 * jax.random.normal(k6, (num_gaussians, 3, 3), dtype)
    return GaussianScene(means, log_scales, quats, opacity_logit, sh_dc, sh_rest)


def scene_num_params(scene: GaussianScene) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(scene))
