"""Pose cells — quantized camera poses for scene-level sort sharing.

The S^2 speculative sort is built with an expanded viewport whose ``margin``
(pixels per side, rounded up to whole tiles) absorbs the pose drift of one
sharing window.  The same margin headroom lets *different viewers* of one
scene consume one sort, provided their poses are close enough that the
projection error between them stays inside it.  A **pose cell** is the
bucket of poses the scheduler treats as "close enough": position quantized
on a world-space grid of pitch ``cell_size`` and view direction quantized
into ``ang_bins`` azimuth/elevation (and roll) buckets.

Margin safety is a small-angle budget, not a proof: two cameras in one cell
differ by at most the cell diagonal ``sqrt(3) * cell_size`` in position and
one angular bin in orientation.  A position error ``d`` at scene depth ``z``
shifts projections by ~``f * d / z`` pixels and an orientation error
``theta`` by ~``f * theta``; with the repo defaults (f ~= 55 px at 64 px /
60 deg fov, z >~ 1, margin = 4 px rounded up to a 16 px tile) the defaults
below keep the combined shift a fraction of the *tile-rounded* margin the
expanded grid actually allocates.  Scenes with extreme close-ups should
shrink ``cell_size`` (the scheduler degrades gracefully: smaller cells just
mean less sharing, never wrong tiles beyond what the single-viewer window
drift already permits).

Keys are computed host-side (the sort scheduler is host-driven and a camera
is seven floats); they are plain non-negative ``int32`` values so they can
ride in the device-side ``SceneShared.pool_cell`` bookkeeping.
"""
from __future__ import annotations

import numpy as np

CELL_SIZE = 0.05     # world-units position quantum (see margin budget above)
ANG_BINS = 256       # direction buckets per axis (360/256 ~= 1.4 deg)


def _fwd_up(quat: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Camera forward (+z) and up (-y, since image y grows down) axes in
    world coordinates, from a (w,x,y,z) world-from-camera quaternion."""
    w, x, y, z = quat / (np.linalg.norm(quat) + 1e-12)
    fwd = np.array([2 * (x * z + w * y),
                    2 * (y * z - w * x),
                    1 - 2 * (x * x + y * y)])
    down = np.array([2 * (x * y - w * z),
                     1 - 2 * (x * x + z * z),
                     2 * (y * z + w * x)])
    return fwd, -down


def angle_bucket(x: float, lo: float, span: float, ang_bins: int,
                 periodic: bool = True) -> int:
    """Quantize an angle into one of ``ang_bins`` buckets over [lo, lo+span).

    Bins are **zero-centered**: a bin CENTER sits at every ``lo + k * span /
    ang_bins`` (half-bin offset before the floor), so the ubiquitous
    upright-camera roll ~= 0 (and axis-aligned headings) cannot flip buckets
    on float noise around a floor boundary.  Periodic axes wrap modulo
    ``ang_bins``; non-periodic axes clamp — elevation must NOT wrap, or
    straight-up (el = +pi/2) would fuse with straight-down (el = -pi/2).
    """
    b = int(np.floor((x - lo) / span * ang_bins + 0.5))
    if periodic:
        return b % ang_bins
    return min(ang_bins - 1, max(0, b))


def pose_cell_buckets(cam, *, cell_size: float = CELL_SIZE,
                      ang_bins: int = ANG_BINS) -> tuple:
    """The raw quantization a pose-cell key hashes: ``(ix, iy, iz, az, el,
    roll)`` — three integer position-grid coordinates (floor at pitch
    ``cell_size``) and three ``angle_bucket`` indices.

    Two cameras share a pose cell iff these six coordinates all coincide;
    neighboring position cells differ in exactly one coordinate by exactly
    one.  Exposed separately from ``pose_cell_key`` so tests (and any future
    adaptive-cell logic) can reason about the geometry instead of a hash.
    """
    p = np.asarray(cam.position, np.float64).reshape(3)
    q = np.asarray(cam.quat, np.float64).reshape(4)
    fwd, up = _fwd_up(q)

    az = np.arctan2(fwd[0], fwd[2])
    el = np.arcsin(np.clip(fwd[1], -1.0, 1.0))
    # roll: angle of the up vector around the forward axis, measured against
    # a forward-orthogonal reference frame
    ref = np.array([0.0, 1.0, 0.0])
    if abs(fwd[1]) > 0.9:                       # forward ~ vertical
        ref = np.array([1.0, 0.0, 0.0])
    e1 = np.cross(ref, fwd)
    e1 /= np.linalg.norm(e1) + 1e-12
    e2 = np.cross(fwd, e1)
    roll = np.arctan2(float(up @ e1), float(up @ e2))

    two_pi = 2.0 * np.pi
    return (
        int(np.floor(p[0] / cell_size)),
        int(np.floor(p[1] / cell_size)),
        int(np.floor(p[2] / cell_size)),
        angle_bucket(az, -np.pi, two_pi, ang_bins),
        angle_bucket(el, -np.pi / 2, np.pi, ang_bins, periodic=False),
        angle_bucket(roll, -np.pi, two_pi, ang_bins),
    )


def pose_cell_key(cam, *, cell_size: float = CELL_SIZE,
                  ang_bins: int = ANG_BINS) -> int:
    """Quantize a camera pose into a deterministic pose-cell key.

    Two cameras get the same key iff their quantized position cells and
    direction buckets (forward azimuth/elevation plus an up-vector roll
    bucket) all coincide — see ``pose_cell_buckets``.  Returns a
    non-negative python int < 2**31.
    """
    buckets = pose_cell_buckets(cam, cell_size=cell_size, ang_bins=ang_bins)
    # FNV-1a over the bucket tuple -> stable 31-bit key (non-negative, so -1
    # stays free as the "empty pool entry" sentinel)
    h = 2166136261
    for b in buckets:
        h = ((h ^ (b & 0xFFFFFFFF)) * 16777619) & 0xFFFFFFFF
    return int(h & 0x7FFFFFFF)
