"""whisper-base — enc-dec audio backbone; conv/mel frontend is a STUB
(input_specs supplies precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='whisper-base', family='encdec',
    n_layers=6, enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865,
    act='gelu',
    recipe='dp', remat=True,
)
