"""lumina-3dgs — the paper's own workload as the 11th selectable config.

Scene/render scale follows the paper's mobile setting (1M Gaussians,
1920x1080 target); reduced sizes are used for CPU tests and quality benches.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LuminaArchConfig:
    name: str = 'lumina-3dgs'
    family: str = 'render'
    num_gaussians: int = 1_000_000
    width: int = 1920
    height: int = 1080
    capacity: int = 1024          # per-tile Gaussian budget
    window: int = 6               # S^2 sharing window
    margin: int = 4               # expanded-viewport margin (px)
    k_record: int = 5             # alpha-record length
    group_tiles: int = 4          # LuminCache shared across 4x4 tiles
    sort_method: str = 'sorted'   # scalable duplicate+global-sort path
    recipe: str = 'render'

    def reduced(self, **overrides):
        small = dict(num_gaussians=3000, width=128, height=128,
                     capacity=192, sort_method='dense')
        small.update(overrides)
        return dataclasses.replace(self, **small)


CONFIG = LuminaArchConfig()
