"""xlstm-1.3b — sLSTM + mLSTM blocks (one sLSTM per 8) [arXiv:2405.04517].
d_ff=0: xLSTM blocks carry their own 2x up-projection instead of an FFN."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='xlstm-1.3b', family='ssm',
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_every=8,
    recipe='ssm', remat=True,
)
