"""Model/arch configuration schema + the shape suite assigned to this paper."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Fields cover every family in the assigned pool."""

    name: str
    family: str                 # dense | moe | encdec | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE layer every k-th layer (maverick: 2)
    shared_expert: bool = False
    capacity_factor: float = 1.25

    # --- enc-dec (whisper) ---
    enc_layers: int = 0

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    slstm_every: int = 0        # xlstm: every k-th block is sLSTM
    attn_every: int = 0         # zamba2: shared attention block every k layers

    # --- misc architecture switches ---
    act: str = 'swiglu'         # 'swiglu' | 'relu2' (nemotron) | 'gelu' (whisper)
    qk_norm: bool = False       # chameleon
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = 'bfloat16'

    # --- distribution recipe ---
    recipe: str = 'tp'          # 'tp' | 'dp' | 'ep' | 'ssm'
    remat: bool = True          # activation checkpointing over layer scan
    scan_layers: bool = True
    loss_chunk: int = 512       # sequence-chunked cross-entropy
    opt_state_dtype: str = 'float32'   # 'bfloat16' for memory-tight configs

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self, **overrides) -> 'ModelConfig':
        """A small same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=32 if self.resolved_head_dim() > 32 else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            slstm_every=2 if self.slstm_every else 0,
            attn_every=2 if self.attn_every else 0,
            dtype='float32',
            remat=False,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str       # 'train' | 'prefill' | 'decode'


# The four assigned input shapes (identical suite for every LM arch).
SHAPES = {
    'train_4k':    ShapeConfig('train_4k',    4_096,   256, 'train'),
    'prefill_32k': ShapeConfig('prefill_32k', 32_768,  32,  'prefill'),
    'decode_32k':  ShapeConfig('decode_32k',  32_768,  128, 'decode'),
    'long_500k':   ShapeConfig('long_500k',   524_288, 1,   'decode'),
}

# long_500k requires a sub-quadratic attention path: only SSM/hybrid archs
# run it (see DESIGN.md §Arch-applicability for the mandated skip list).
LONG_CONTEXT_FAMILIES = ('ssm', 'hybrid')


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == 'long_500k':
        return cfg.family in LONG_CONTEXT_FAMILIES
    return True
