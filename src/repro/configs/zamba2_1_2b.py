"""zamba2-1.2b — Mamba2 backbone + ONE shared attention block applied every
6 layers [arXiv:2411.15242]. ssm_state=64."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='zamba2-1.2b', family='hybrid',
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    ssm_state=64, attn_every=6,
    recipe='ssm', remat=True,
)
