"""smollm-360m — small llama-arch GQA [hf:HuggingFaceTB/SmolLM-360M].

Small model: pure data parallelism (batch over every mesh axis, params
replicated) — TP would waste the mesh on a 360M model.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='smollm-360m', family='dense',
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49152, head_dim=64,
    recipe='dp', remat=True,
)
