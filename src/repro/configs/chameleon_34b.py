"""chameleon-34b — early-fusion VLM; VQ image tokens share the 65536 vocab,
so the backbone is a dense GQA transformer with qk-norm [arXiv:2405.09818].
The VQ tokenizer frontend is a stub: input token ids already interleave
text and image tokens."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='chameleon-34b', family='vlm',
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536,
    qk_norm=True,
    recipe='tp', remat=True,
)
