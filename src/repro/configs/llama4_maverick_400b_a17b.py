"""llama4-maverick-400b-a17b — interleaved MoE, 128 experts top-1 + shared
expert [hf:meta-llama/Llama-4-*].

moe_every=2 (MoE on alternating layers) is what reconciles the assigned
"48L / 128e / d_ff 8192" line with the 400B-total / 17B-active name:
24 MoE layers x 128 experts x 3 x 5120 x 8192 = 386B routed params (+ dense
layers + shared experts ~= 400B); top-1 + shared expert + dense layers
~= 17B active.  bf16 optimizer state — fp32 moments would not fit
16 GB/chip on the 256-way mesh (see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='llama4-maverick-400b-a17b', family='moe',
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    n_experts=128, top_k=1, moe_every=2, shared_expert=True,
    recipe='ep', remat=True, opt_state_dtype='bfloat16',
)
