"""Architecture configs — exact assigned pool + the paper's own lumina-3dgs.

``get_config(name)`` resolves any assigned id; ``ALL_LM_ARCHS`` lists the ten
LM-family cells of the dry-run matrix.
"""
from __future__ import annotations

import importlib

ALL_LM_ARCHS = (
    'yi-34b', 'command-r-35b', 'smollm-360m', 'nemotron-4-15b',
    'granite-moe-1b-a400m', 'llama4-maverick-400b-a17b', 'whisper-base',
    'chameleon-34b', 'xlstm-1.3b', 'zamba2-1.2b',
)

_MODULES = {
    'yi-34b': 'yi_34b',
    'command-r-35b': 'command_r_35b',
    'smollm-360m': 'smollm_360m',
    'nemotron-4-15b': 'nemotron_4_15b',
    'granite-moe-1b-a400m': 'granite_moe_1b_a400m',
    'llama4-maverick-400b-a17b': 'llama4_maverick_400b_a17b',
    'whisper-base': 'whisper_base',
    'chameleon-34b': 'chameleon_34b',
    'xlstm-1.3b': 'xlstm_1_3b',
    'zamba2-1.2b': 'zamba2_1_2b',
    'lumina-3dgs': 'lumina_3dgs',
}


def get_config(name: str):
    mod = importlib.import_module(f'repro.configs.{_MODULES[name]}')
    return mod.CONFIG
